//! The IO500 campaign (Table 5): run the full phase list against the
//! modelled /scratch filesystem, then sweep client counts and striping
//! to show where the pool saturates (the knobs a real submission tunes).
//!
//! ```text
//! cargo run --release --example io500_campaign
//! ```

use leonardo_twin::coordinator::Twin;
use leonardo_twin::metrics::{f1, Table};
use leonardo_twin::storage::{io500, StorageSystem, Stripe};

fn main() {
    let twin = Twin::leonardo();
    println!("{}", twin.table3().to_console());
    println!("{}", twin.table5().to_console());

    let sys = StorageSystem::leonardo();
    let scratch = sys.namespace("/scratch").unwrap();

    // Client-count sweep: the submission needs enough clients to saturate
    // the appliance pool.
    let mut t = Table::new(
        "IO500 client sweep (/scratch)",
        &["Clients", "BW [GiB/s]", "MD [kIOP/s]", "Score"],
    );
    for clients in [4u32, 8, 16, 32, 64, 128] {
        let r = io500::run(
            scratch,
            io500::Io500Config {
                client_nodes: clients,
                client_link_gbs: 45.0,
            },
        );
        t.row(vec![
            clients.to_string(),
            f1(r.bw_gibs),
            f1(r.md_kiops),
            f1(r.score),
        ]);
    }
    println!("{}", t.to_console());

    // Striping sweep: single-client file bandwidth vs stripe count.
    let mut t = Table::new(
        "Lustre striping: single-client file bandwidth (/scratch)",
        &["Stripe count", "Read [GB/s]", "Write [GB/s]"],
    );
    for count in [1u32, 2, 4, 8, 16, 32, 64] {
        let s = Stripe {
            count,
            size_mib: 16,
        };
        t.row(vec![
            count.to_string(),
            f1(s.file_bw_gbs(45.0, scratch, false)),
            f1(s.file_bw_gbs(45.0, scratch, true)),
        ]);
    }
    println!("{}", t.to_console());
    println!("paper: IO500 score 649 (BW 807 GiB/s, MD 522 kIOP/s), rank 1 in bandwidth at ISC23");
}
