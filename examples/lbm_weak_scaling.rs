//! End-to-end driver for the paper's headline experiment: the LBM weak
//! scaling study (Table 7 + Fig 5), run through the *whole* stack:
//!
//!   1. the real Pallas D3Q19 kernel executes via PJRT and calibrates the
//!      per-GPU rate (projected onto the A100 HBM roofline);
//!   2. each scaling point is submitted to the SLURM-like scheduler as a
//!      batch job, getting a topology-aware placement on the dragonfly+
//!      fabric;
//!   3. per-step time composes real compute rate + network-simulated halo
//!      exchange + amortised diagnostics allreduce;
//!   4. the power model integrates energy for every run.
//!
//! Results are recorded in EXPERIMENTS.md. Run:
//! ```text
//! make artifacts && cargo run --release --example lbm_weak_scaling
//! ```

use leonardo_twin::coordinator::Twin;
use leonardo_twin::lbm::{LbmConfig, LbmDriver, TABLE7_NODES};
use leonardo_twin::metrics::{f1, f2, sig3, Table};
use leonardo_twin::power::Utilization;
use leonardo_twin::runtime::Engine;
use leonardo_twin::scheduler::{CheckpointPolicy, Job, Partition, Scheduler};

fn main() -> anyhow::Result<()> {
    let twin = Twin::leonardo();

    // ---- 1. Calibrate against the real kernel when artifacts exist.
    let _calib = match Engine::load(Engine::default_dir()) {
        Ok(engine) => {
            let c = twin.calibrate(&engine)?;
            println!("{}", twin.calibration_table(&c).to_console());
            println!(
                "(host interpret-mode Pallas is dispatch-overhead bound; the \
                 campaign below uses the A100 HBM-roofline rate — see \
                 EXPERIMENTS.md §Calibration)\n"
            );
            Some(c)
        }
        Err(e) => {
            eprintln!("(no artifacts: {e:#}; using roofline model only)\n");
            None
        }
    };

    // ---- 2+3. Submit the whole campaign as scheduler jobs.
    let node = twin.cfg.gpu_node_spec().unwrap().clone();
    let driver = LbmDriver::new(&node, &twin.net, LbmConfig::default());

    let mut sched = Scheduler::new(&twin.cfg);
    let steps = 1000u32; // steps per scaling point (paper-style run)
    let mut table = Table::new(
        "Table 7 + energy — LBM weak scaling campaign (end-to-end)",
        &[
            "Nodes",
            "GPUs",
            "Cells",
            "TLUPS",
            "Eff",
            "Job wall [s]",
            "Energy [kWh]",
        ],
    );

    // The campaign runs as a FIFO of jobs so scheduler behaviour (wait
    // times, placement) is part of the experiment.
    let mut rows = Vec::new();
    for (i, &nodes) in TABLE7_NODES.iter().enumerate() {
        let placement = sched
            .place(Partition::Booster, nodes)
            .expect("machine is large enough");
        let point = driver.point(nodes, &placement);
        let wall = point.step_seconds * steps as f64;
        // LBM is memory-bound: GPUs busy but below TDP-max utilisation.
        let util = Utilization {
            cpu: 0.25,
            gpu: Some(0.75),
        };
        let energy = twin.power.energy_kwh(nodes, util, wall);
        rows.push((nodes, point.clone(), placement.cells_used(), wall, energy));
        sched.release(Partition::Booster, &placement);
        // also exercise the batch queue path for a subset
        if i < 3 {
            let rec = sched.run(vec![Job {
                id: i as u64,
                partition: Partition::Booster,
                nodes,
                est_seconds: wall,
                run_seconds: wall,
                submit_time: 0.0,
                boundness: 0.3,
                comm_fraction: 0.15,
                checkpoint: CheckpointPolicy::None,
            }]);
            assert_eq!(rec.len(), 1);
        }
    }
    let base = rows[0].1.lups / rows[0].1.gpus as f64;
    for (nodes, point, cells, wall, energy) in rows {
        table.row(vec![
            nodes.to_string(),
            point.gpus.to_string(),
            cells.to_string(),
            sig3(point.lups / 1e12),
            f2((point.lups / point.gpus as f64) / base),
            f1(wall),
            f2(energy),
        ]);
    }
    println!("{}", table.to_console());

    // ---- 4. The Fig 5 comparison (LEONARDO vs Marconi100).
    println!("{}", twin.fig5()?.to_console());

    println!("paper: 51.2 TLUPS at 9900 GPUs, efficiency 0.88 — see Table 7 above");
    Ok(())
}
