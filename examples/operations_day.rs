//! A day in the life of LEONARDO: the operations-side subsystems the
//! paper describes outside the benchmark appendix, composed end-to-end:
//!
//!   1. an ISCRA/EuroHPC allocation round awards node-hour budgets (§3);
//!   2. users land on the login balancer (§2.4) and submit a morning's
//!      job mix; admission checks project budgets;
//!   3. the SLURM-like scheduler runs the day under the facility power
//!      cap (§2.6), backfilling and DVFS-throttling as needed;
//!   4. IPMI-style telemetry logs every job's power profile; the health
//!      checker watches the §2.6 envelope;
//!   5. accounting charges the budgets and reports.
//!
//! ```text
//! cargo run --release --example operations_day
//! ```

use leonardo_twin::allocation::{run_round, CallKind, Proposal};
use leonardo_twin::coordinator::Twin;
use leonardo_twin::frontend::{fleet_table, leonardo_service_fleet, LoginBalancer};
use leonardo_twin::power::{PowerModel, Utilization};
use leonardo_twin::scheduler::{CheckpointPolicy, Job, Partition, PowerCap, Scheduler};
use leonardo_twin::telemetry::{health_summary, log_job_power, MetricStore};
use leonardo_twin::util::rng::Rng;

fn main() {
    let twin = Twin::leonardo();
    println!("{}", fleet_table().to_console());

    // ---- 1. Allocation round: 30M node-hours on offer this cycle.
    let mut rng = Rng::new(2023);
    let proposals: Vec<Proposal> = (0..12)
        .map(|i| Proposal {
            id: i,
            call: if i % 2 == 0 {
                CallKind::EuroHpc
            } else {
                CallKind::Iscra
            },
            title: format!("project-{i:02}"),
            merit: 5.0 + 5.0 * rng.f64(),
            technical: 4.0 + 6.0 * rng.f64(),
            requested_nh: 2e6 + 6e6 * rng.f64(),
        })
        .collect();
    let mut round = run_round(proposals, 30e6);
    println!(
        "allocation round: {} projects awarded, {:.1}M node-hours total\n",
        round.projects.len(),
        round.total_awarded() / 1e6
    );

    // ---- 2. Login + submission.
    let fleet = leonardo_service_fleet();
    let mut balancer = LoginBalancer::new(&fleet);
    let project_ids: Vec<u64> = round.projects.keys().copied().collect();
    let mut jobs = Vec::new();
    let mut owners = Vec::new();
    for i in 0..40u64 {
        let _login_node = balancer.connect().expect("login capacity");
        let project = *rng.choose(&project_ids);
        let job = Job {
            id: i,
            partition: Partition::Booster,
            nodes: rng.range_u32(16, 1024),
            est_seconds: rng.range_f64(600.0, 7200.0),
            run_seconds: rng.range_f64(300.0, 7200.0),
            submit_time: rng.range_f64(0.0, 14_400.0), // over four hours
            boundness: rng.f64(),
            comm_fraction: rng.f64() * 0.4,
            checkpoint: CheckpointPolicy::None,
        };
        if round.admit(project, &job) {
            owners.push((i, project));
            jobs.push(job);
        }
    }
    println!(
        "{} sessions connected, {} jobs admitted against budgets",
        balancer.total_sessions(),
        jobs.len()
    );

    // ---- 3. Run the day under a 6 MW facility cap (the Booster at full load
    // draws ~7.7 MW, so heavy phases must throttle).
    let power = PowerModel::new(twin.power.node.clone(), twin.cfg.pue);
    let mut sched = Scheduler::new(&twin.cfg);
    sched.power_cap = Some(PowerCap {
        cap_mw: 6.0,
        node_watts: power.node_power_w(Utilization::hpl()),
        idle_watts: power.node_power_w(Utilization::idle()),
    });
    let records = sched.run(jobs.clone());
    let makespan = records
        .values()
        .fold(0f64, |m, r| m.max(r.end_time));
    let throttled = records.values().filter(|r| r.dvfs_scale < 1.0).count();
    println!(
        "day complete: makespan {:.1} h, {} jobs DVFS-throttled under the cap",
        makespan / 3600.0,
        throttled
    );

    // ---- 4. Telemetry: per-job power profiles + health.
    let mut store = MetricStore::default();
    let u = Utilization {
        cpu: 0.4,
        gpu: Some(0.8),
    };
    let mut jobs_by_id = std::collections::BTreeMap::new();
    for j in &jobs {
        jobs_by_id.insert(j.id, j);
    }
    for (id, rec) in &records {
        let j = jobs_by_id[id];
        let watts = power.node_power_w(u) * j.nodes as f64 * rec.dvfs_scale;
        log_job_power(
            &mut store,
            &format!("job{id:02}_power_w"),
            rec.start_time,
            rec.end_time,
            watts,
            600.0,
        );
    }
    store.record("gpu_temp_c", makespan, 78.0);
    store.record("inlet_temp_c", makespan, 37.0);
    let total_kwh: f64 = records
        .keys()
        .map(|id| store.energy_kwh(&format!("job{id:02}_power_w")))
        .sum();
    println!("IT energy for the day's jobs: {total_kwh:.0} kWh (+10% cooling at PUE 1.1)");
    let (health, worst) = health_summary(&store);
    println!("{}", health.to_console());
    println!("fleet health: {worst:?}\n");

    // ---- 5. Accounting.
    for (job_id, project) in &owners {
        if let Some(rec) = records.get(job_id) {
            round.charge(*project, jobs_by_id[job_id], rec);
        }
    }
    println!("{}", round.report().to_console());
}
