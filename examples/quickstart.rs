//! Quickstart: build the LEONARDO twin, print the machine facts, and run
//! one *real* D3Q19 lattice-Boltzmann step through the PJRT runtime.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use leonardo_twin::coordinator::{equilibrium_f32, Twin};
use leonardo_twin::runtime::{literal_f32, Engine};

fn main() -> anyhow::Result<()> {
    // 1. The machine, straight from Table 1/2 of the paper.
    let twin = Twin::leonardo();
    println!("{}", twin.table1().to_console());
    println!(
        "fabric: {} switches, max latency {:.2} us",
        twin.topo.total_switches(),
        twin.topo.max_latency_ns() / 1000.0
    );

    // 2. A real kernel: the Pallas D3Q19 collide+stream step, AOT-lowered
    //    by `make artifacts`, executed on the PJRT CPU client.
    let engine = Engine::load(Engine::default_dir())?;
    println!("\nPJRT platform: {}", engine.platform());
    println!("modules: {:?}", engine.modules());

    let n = 32usize;
    let f = literal_f32(&equilibrium_f32(n), &[19, n, n, n])?;
    let omega = literal_f32(&[1.2f32], &[1])?;

    let outputs = engine.execute("lbm_step_32", &[f, omega])?;
    let result: Vec<f32> = outputs[0].to_vec()?;

    // Mass conservation is the LBM sanity check: rho must stay 1 at
    // every site (quiescent equilibrium is a fixed point of the step).
    let sites = n * n * n;
    let mut max_err = 0f32;
    for s in 0..sites {
        let rho: f32 = (0..19).map(|q| result[q * sites + s]).sum();
        max_err = max_err.max((rho - 1.0).abs());
    }
    println!("\nLBM step on {n}^3: max |rho - 1| = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-5, "mass not conserved");

    // 3. Timed: per-site update rate on this host, projected to the A100.
    let f = literal_f32(&equilibrium_f32(n), &[19, n, n, n])?;
    let omega = literal_f32(&[1.2f32], &[1])?;
    let secs = engine.time_execute("lbm_steps8_32", &[f, omega], 2)?;
    let mlups = 8.0 * (sites as f64) / secs / 1e6;
    println!("host rate: {mlups:.1} MLUPS (scan of 8 steps, one dispatch)");
    println!("\nquickstart OK");
    Ok(())
}
