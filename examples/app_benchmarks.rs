//! The application benchmark campaign (Table 6): QuantumEspresso, MILC,
//! SPECFEM3D and PLUTO at the paper's job sizes, plus a node-count sweep
//! per application showing the TTS/ETS trade-off the Bull Dynamic Power
//! Optimizer navigates.
//!
//! ```text
//! cargo run --release --example app_benchmarks
//! ```

use leonardo_twin::coordinator::Twin;
use leonardo_twin::metrics::{f1, f2, Table};
use leonardo_twin::power::{best_workpoint, DvfsPoint};
use leonardo_twin::workloads::AppBenchmark;

fn main() {
    let twin = Twin::leonardo();
    println!("{}", twin.table6().expect("reference sizes fit").to_console());

    // Strong-scaling sweep per app.
    let mut t = Table::new(
        "Application strong scaling (TTS [s] / ETS [kWh])",
        &["Application", "N/2", "N (paper)", "2N", "4N"],
    );
    for app in AppBenchmark::table6() {
        let mut cells = vec![app.name.to_string()];
        for factor in [0.5f64, 1.0, 2.0, 4.0] {
            let nodes = ((app.ref_nodes as f64 * factor) as u32).max(2);
            let placement = twin.place(nodes).expect("sweep sizes fit");
            let tts = app.tts(nodes, &twin.net, &placement);
            let ets = app.ets(nodes, tts, &twin.power);
            cells.push(format!("{} / {}", f1(tts), f2(ets)));
        }
        t.row(cells);
    }
    println!("{}", t.to_console());

    // DVFS workpoints: what the Bull Dynamic Power Optimizer would pick
    // per app (memory-bound codes downclock almost for free).
    let mut t = Table::new(
        "Bull Dynamic Power Optimizer analogue: best DVFS workpoints",
        &["Application", "Boundness", "Best scale", "Energy saved", "Slowdown"],
    );
    for (app, boundness) in AppBenchmark::table6().iter().zip([0.6, 0.8, 0.5, 0.4]) {
        let p = best_workpoint(&twin.power, app.util, boundness, 1.10);
        let nominal = twin.power.node_power_w(app.util);
        let idle = twin.power.node_power_w(leonardo_twin::power::Utilization::idle());
        let dynamic = nominal - idle;
        let capped = idle + dynamic * p.power_factor();
        let slowdown = DvfsPoint { scale: p.scale }.time_factor(boundness);
        let saved = 1.0 - capped * slowdown / nominal;
        t.row(vec![
            app.name.to_string(),
            f2(boundness),
            f2(p.scale),
            format!("{:.1}%", saved * 100.0),
            format!("{:.1}%", (slowdown - 1.0) * 100.0),
        ]);
    }
    println!("{}", t.to_console());
    println!("paper Table 6: QE 439s/1.14kWh@12, MILC 178s/0.56kWh@12, SPECFEM3D 270s/1.43kWh@16, PLUTO 2874s/11.7kWh@32");
}
