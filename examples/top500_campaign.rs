//! The TOP500/Green500 campaign (Table 4): HPL and HPCG at the paper's
//! submission scale, with the HPL model fed by the *measured* blocked
//! Pallas DGEMM when artifacts are available.
//!
//! ```text
//! make artifacts && cargo run --release --example top500_campaign
//! ```

use leonardo_twin::coordinator::Twin;
use leonardo_twin::hardware::NodeSpec;
use leonardo_twin::metrics::{f1, f2, Table};
use leonardo_twin::perfmodel::{HpcgModel, HplModel};
use leonardo_twin::power::Utilization;
use leonardo_twin::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let twin = Twin::leonardo();

    let calib = match Engine::load(Engine::default_dir()) {
        Ok(engine) => Some(twin.calibrate(&engine)?),
        Err(e) => {
            eprintln!("(no artifacts: {e:#})");
            None
        }
    };

    println!("{}", twin.table4(calib.as_ref()).to_console());

    // Scaling sweep: how Rmax, efficiency, power and Green500 evolve with
    // machine fraction — the "what if we submitted with N nodes" table.
    let hpl = HplModel::new(NodeSpec::davinci());
    let hpcg = HpcgModel::new(NodeSpec::davinci());
    let mut t = Table::new(
        "HPL/HPCG scaling sweep (what-if submissions)",
        &[
            "Nodes",
            "N (fills 80% HBM)",
            "Rmax [PF]",
            "Eff",
            "HPCG [PF]",
            "Power [MW]",
            "GFLOPS/W",
        ],
    );
    for nodes in [256u32, 1024, 2048, 3300, 3456] {
        let rmax = hpl.rmax(nodes);
        let power = twin.power.fleet_power_mw(nodes, Utilization::hpl());
        t.row(vec![
            nodes.to_string(),
            hpl.problem_size(nodes, 0.8).to_string(),
            f1(rmax / 1e15),
            f2(hpl.efficiency(nodes)),
            f2(hpcg.rate(nodes) / 1e15),
            f1(power),
            f1(rmax / 1e9 / (power * 1e6)),
        ]);
    }
    println!("{}", t.to_console());

    if let Some(c) = &calib {
        println!("{}", twin.calibration_table(c).to_console());
    }
    println!("paper: Rmax 238.7 PF (rank 4), HPCG 3.11 PF (rank 4), 32.2 GFLOPS/W (rank 15)");
    Ok(())
}
