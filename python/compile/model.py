"""L2: JAX compute graphs for the LEONARDO benchmark motifs.

Each public function here is AOT-lowered by `aot.py` into an
`artifacts/*.hlo.txt` module that the Rust runtime executes via PJRT.
They call the L1 Pallas kernels (`kernels/`) so kernel and graph lower
into one HLO module; Python never runs at serve time.

Motifs:
  - LBM D3Q19 step(s): the weak-scaling benchmark of Appendix A.3
    (collision = Pallas, streaming = jnp rolls XLA fuses into the
    surrounding graph).
  - HPL trailing update: the DGEMM that dominates Linpack (Table 4).
  - HPCG CG iteration: 27-point stencil SpMV + dots + axpys (Table 4).
"""

import jax
import jax.numpy as jnp

from .kernels import gemm, lbm, stencil


# ---------------------------------------------------------------------------
# LBM
# ---------------------------------------------------------------------------

def lbm_step(f, omega):
    """One D3Q19 BGK step: collide (Pallas) then periodic stream.

    Periodic boundaries model the *interior* of one node's subdomain; the
    Rust driver owns inter-node halo exchange (network-simulated), exactly
    as the MPI version the paper benchmarks does.
    """
    fc = lbm.collide(f, omega)
    out = [None] * lbm.Q
    for q in range(lbm.Q):
        cx, cy, cz = (int(v) for v in lbm.C[q])
        out[q] = jnp.roll(fc[q], (cx, cy, cz), axis=(0, 1, 2))
    return jnp.stack(out)


def lbm_steps(f, omega, n_steps):
    """n_steps LBM steps via lax.scan (no unroll: keeps the HLO compact)."""

    def body(carry, _):
        return lbm_step(carry, omega), None

    out, _ = jax.lax.scan(body, f, None, length=n_steps)
    return out


def lbm_macroscopics(f):
    """Density and momentum fields — used for conservation checks."""
    c = jnp.asarray(lbm.C, f.dtype)
    rho = jnp.sum(f, axis=0)
    mom = jnp.einsum("qd,qxyz->dxyz", c, f)
    return rho, mom


# ---------------------------------------------------------------------------
# HPL
# ---------------------------------------------------------------------------

def hpl_update(c, a, b):
    """Trailing-matrix update C <- C - A @ B (the HPL hot loop)."""
    return gemm.gemm_update(c, a, b, alpha=-1.0)


def dgemm(a, b):
    """Plain blocked matmul — the calibration kernel for the HPL model."""
    return gemm.matmul(a, b)


# ---------------------------------------------------------------------------
# HPCG
# ---------------------------------------------------------------------------

def spmv(x):
    """y = A x for the HPCG 27-point operator."""
    return stencil.stencil27(x)


def cg_iter(x, r, p, rz):
    """One unpreconditioned CG iteration on the stencil operator.

    State: solution x, residual r, direction p, and rz = <r, r>.
    Returns the advanced state. Fusing the whole iteration into one HLO
    module keeps the Rust hot path at one PJRT dispatch per iteration.
    """
    tiny = jnp.float32(1e-30)  # keeps the iteration a no-op at convergence
    ap = stencil.stencil27(p)
    pap = jnp.sum(p * ap)
    alpha = rz / (pap + tiny)
    x = x + alpha * p
    r = r - alpha * ap
    rz_new = jnp.sum(r * r)
    beta = rz_new / (rz + tiny)
    p = r + beta * p
    return x, r, p, rz_new


def cg_iters(x, r, p, rz, n_iters):
    """n CG iterations via scan; returns final state."""

    def body(carry, _):
        return cg_iter(*carry), None

    (x, r, p, rz), _ = jax.lax.scan(body, (x, r, p, rz), None, length=n_iters)
    return x, r, p, rz
