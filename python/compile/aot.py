"""AOT exporter: lower every L2 entry point to HLO *text* + a manifest.

HLO text (NOT `lowered.compile()`/`.serialize()`) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Writes: artifacts/<name>.hlo.txt for every registry entry, plus
        artifacts/manifest.json describing argument shapes/dtypes so the
        Rust runtime can allocate input literals without guessing.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _scalar():
    return jax.ShapeDtypeStruct((1,), jnp.float32)


def _lbm_step_entry(n):
    return (model.lbm_step, [_spec((19, n, n, n)), _scalar()])


def _lbm_steps_entry(n, steps):
    fn = functools.partial(model.lbm_steps, n_steps=steps)
    return (fn, [_spec((19, n, n, n)), _scalar()])


def _dgemm_entry(n):
    return (model.dgemm, [_spec((n, n)), _spec((n, n))])


def _hpl_update_entry(n):
    return (model.hpl_update, [_spec((n, n)), _spec((n, n)), _spec((n, n))])


def _spmv_entry(n):
    return (model.spmv, [_spec((n, n, n))])


def _cg_iter_entry(n):
    g = _spec((n, n, n))
    return (model.cg_iter, [g, g, g, _spec((), jnp.float32)])


def _cg_iters_entry(n, iters):
    fn = functools.partial(model.cg_iters, n_iters=iters)
    g = _spec((n, n, n))
    return (fn, [g, g, g, _spec((), jnp.float32)])


def _sparse_entry(n):
    from .kernels import sparse

    return (sparse.sparse_matmul, [_spec((n, n)), _spec((n, n))])


# name -> (fn, [arg specs]); names are load-bearing: the Rust runtime and
# coordinator refer to artifacts by these keys.
REGISTRY = {
    "lbm_step_32": _lbm_step_entry(32),
    "lbm_step_48": _lbm_step_entry(48),
    "lbm_steps8_32": _lbm_steps_entry(32, 8),
    "dgemm_256": _dgemm_entry(256),
    "dgemm_512": _dgemm_entry(512),
    "hpl_update_256": _hpl_update_entry(256),
    "spmv_64": _spmv_entry(64),
    "cg_iter_64": _cg_iter_entry(64),
    "cg_iters8_64": _cg_iters_entry(64, 8),
    "sparse_matmul_256": _sparse_entry(256),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_one(name, fn, specs, out_dir):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    flat, _ = jax.tree_util.tree_flatten(
        jax.eval_shape(fn, *specs)
    )
    return {
        "inputs": [
            {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
        ],
        "outputs": [
            {"shape": list(s.shape), "dtype": str(s.dtype)} for s in flat
        ],
        "hlo_chars": len(text),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated registry subset"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = list(REGISTRY) if not args.only else args.only.split(",")
    # --only must not clobber the other entries: merge into any existing
    # manifest so partial re-exports keep artifacts/ self-describing.
    manifest = {}
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    if args.only and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    for name in names:
        fn, specs = REGISTRY[name]
        manifest[name] = export_one(name, fn, specs, args.out_dir)
        print(f"exported {name}: {manifest[name]['hlo_chars']} chars")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest for {len(manifest)} modules to {args.out_dir}")


if __name__ == "__main__":
    main()
