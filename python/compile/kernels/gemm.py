"""L1 Pallas kernel: blocked GEMM (the HPL hot spot).

HPL spends >90% of its time in the trailing-matrix DGEMM update
C <- C - A @ B. On the A100 this runs on tensor cores with threadblock
tiles staged through shared memory; the MXU analogue is a 128x128 output
tile with the K dimension marched through VMEM (DESIGN.md
§Hardware-Adaptation). Accumulation is f32.

Default block edge is 256: a perf sweep on the interpret/CPU path (the
execution target of this repo) measured 9.9 / 18.2 / 30.9 GFLOPS at
block 128 / 256 / 512 on a 512^2 matmul — per-block dispatch overhead
dominates interpret mode, so fewer, larger blocks win; 256 keeps three
levels of blocking (the TPU-structural shape) while recovering most of
the win (EXPERIMENTS.md §Perf). On a real MXU the 128 tile is optimal;
pass bm/bn/bk explicitly when lowering for hardware.

interpret=True (CPU PJRT cannot run Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    # K is the innermost grid axis: initialize the output tile on the first
    # K step, then accumulate — the canonical MXU pipeline structure.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, bm=256, bn=256, bk=256):
    """Blocked matmul a @ b via Pallas.

    Shapes must tile evenly: a (M, K), b (K, N) with bm|M, bk|K, bn|N.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"({m},{k})x({k},{n}) not tiled by ({bm},{bn},{bk})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def gemm_update(c, a, b, alpha=-1.0, bm=256, bn=256, bk=256):
    """HPL trailing update C <- C + alpha * A @ B (alpha=-1 in HPL)."""
    return c + alpha * matmul(a, b, bm=bm, bn=bn, bk=bk)
