"""L1 Pallas kernel: D3Q19 lattice-Boltzmann BGK collision.

The collision operator is the FLOP hot spot of the LBM benchmark the paper
scales to 9,900 GPUs (Appendix A.3): ~250 flops per lattice site per step.
Streaming (pure data movement) lives at L2 (`model.lbm_step`) as jnp rolls
that XLA fuses with the collision output.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): on the A100 this
kernel is HBM-bandwidth bound and written with one threadblock per lattice
tile staged in shared memory; here the BlockSpec tiles the lattice into
x-slabs sized for a ~16 MB VMEM budget, the 19 distributions stay in the
leading axis so each slab is a contiguous (19, BX, NY, NZ) block, and the
kernel reads and writes each distribution exactly once (single pass).

Pallas runs with interpret=True: CPU-PJRT cannot execute Mosaic custom
calls; numerics are identical to the compiled path.
"""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# D3Q19 velocity set: rest particle, 6 face neighbours, 12 edge neighbours.
# Order matters: model.lbm_step streams with the same table.
C = np.array(
    [
        [0, 0, 0],
        [1, 0, 0], [-1, 0, 0], [0, 1, 0], [0, -1, 0], [0, 0, 1], [0, 0, -1],
        [1, 1, 0], [-1, -1, 0], [1, -1, 0], [-1, 1, 0],
        [1, 0, 1], [-1, 0, -1], [1, 0, -1], [-1, 0, 1],
        [0, 1, 1], [0, -1, -1], [0, 1, -1], [0, -1, 1],
    ],
    dtype=np.int32,
)

W = np.array(
    [1.0 / 3.0]
    + [1.0 / 18.0] * 6
    + [1.0 / 36.0] * 12,
    dtype=np.float32,
)

# Index of the opposite direction (used for bounce-back boundaries at L2).
OPP = np.array(
    [0, 2, 1, 4, 3, 6, 5, 8, 7, 10, 9, 12, 11, 14, 13, 16, 15, 18, 17],
    dtype=np.int32,
)

Q = 19


def _collide_kernel(f_ref, omega_ref, out_ref):
    """BGK collision, fully unrolled over the 19 directions.

    The unrolled form (Python-float coefficients, one moment accumulation
    pass + one equilibrium/relax pass) mirrors the production CUDA kernel
    and sidesteps Pallas's no-captured-array-constants rule: every
    coefficient is a compile-time scalar.
    """
    omega = omega_ref[0]
    f = [f_ref[q] for q in range(Q)]

    rho = f[0]
    for q in range(1, Q):
        rho = rho + f[q]
    inv_rho = 1.0 / rho

    ux = uy = uz = None
    for q in range(Q):
        cx, cy, cz = (float(v) for v in C[q])
        if cx:
            ux = cx * f[q] if ux is None else ux + cx * f[q]
        if cy:
            uy = cy * f[q] if uy is None else uy + cy * f[q]
        if cz:
            uz = cz * f[q] if uz is None else uz + cz * f[q]
    ux, uy, uz = ux * inv_rho, uy * inv_rho, uz * inv_rho
    usq = ux * ux + uy * uy + uz * uz

    for q in range(Q):
        cx, cy, cz = (float(v) for v in C[q])
        cu = cx * ux + cy * uy + cz * uz
        feq = float(W[q]) * rho * (1.0 + 3.0 * cu + 4.5 * cu * cu - 1.5 * usq)
        out_ref[q] = f[q] + omega * (feq - f[q])


def collide(f, omega, block_x=None):
    """Pallas D3Q19 BGK collision.

    Args:
      f: distributions, shape (19, NX, NY, NZ), float32.
      omega: relaxation rate scalar (array shape (1,)) in (0, 2).
      block_x: x-slab width; must divide NX. Default: whole extent if the
        slab fits a 16 MB VMEM budget, else the largest divisor that does.
    Returns:
      post-collision distributions, same shape.
    """
    q, nx, ny, nz = f.shape
    assert q == Q, f"expected leading axis 19, got {q}"
    if block_x is None:
        block_x = _default_block_x(nx, ny, nz)
    assert nx % block_x == 0, f"block_x={block_x} must divide NX={nx}"
    omega = jnp.asarray(omega, jnp.float32).reshape((1,))

    grid = (nx // block_x,)
    return pl.pallas_call(
        _collide_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q, block_x, ny, nz), lambda i: (0, i, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((Q, block_x, ny, nz), lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(f.shape, f.dtype),
        interpret=True,
    )(f, omega)


def _default_block_x(nx, ny, nz, vmem_bytes=16 * 2**20):
    """Largest divisor of nx whose in+out blocks fit the VMEM budget."""
    site_bytes = 2 * Q * 4 * ny * nz  # in + out slabs, f32
    best = 1
    for bx in range(1, nx + 1):
        if nx % bx == 0 and bx * site_bytes <= vmem_bytes:
            best = bx
    return best


@partial(jax.jit, static_argnames=())
def equilibrium(rho, ux, uy, uz):
    """Equilibrium distributions from macroscopic fields (used to init)."""
    shape = rho.shape
    w = jnp.asarray(W).reshape((Q,) + (1,) * len(shape))
    cx = jnp.asarray(C[:, 0], rho.dtype).reshape(w.shape)
    cy = jnp.asarray(C[:, 1], rho.dtype).reshape(w.shape)
    cz = jnp.asarray(C[:, 2], rho.dtype).reshape(w.shape)
    cu = cx * ux[None] + cy * uy[None] + cz * uz[None]
    usq = (ux * ux + uy * uy + uz * uz)[None]
    return w * rho[None] * (1.0 + 3.0 * cu + 4.5 * cu * cu - 1.5 * usq)
