"""L1 Pallas kernel: HPCG 27-point stencil SpMV.

HPCG's operator is the 3D 27-point stencil (diagonal 26, off-diagonals -1)
with zero Dirichlet boundaries. The A100 implementation stages a halo'd
tile in shared memory; here each grid step owns an x-slab and reads a
halo'd input slab expressed through an element-offset BlockSpec is not
available in interpret mode for ragged edges, so the kernel takes the halo
explicitly: the input block is the full lattice (VMEM analysis in
DESIGN.md §Perf notes the compiled-TPU variant would use an overlapped
(BX+2) slab; the arithmetic per site is identical).

interpret=True throughout.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DIAG = 26.0
OFF = -1.0


def _shifted_sum(xp):
    """Sum of the 26 neighbours of the interior of a zero-padded field."""
    acc = None
    for dx in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dz in (-1, 0, 1):
                if dx == 0 and dy == 0 and dz == 0:
                    continue
                nx, ny, nz = xp.shape
                sl = xp[
                    1 + dx : nx - 1 + dx,
                    1 + dy : ny - 1 + dy,
                    1 + dz : nz - 1 + dz,
                ]
                acc = sl if acc is None else acc + sl
    return acc


def _stencil_kernel(x_ref, o_ref, *, block_x):
    i = pl.program_id(0)
    xfull = x_ref[...]
    xp = jnp.pad(xfull, 1)  # zero Dirichlet halo
    # interior slab [i*block_x, (i+1)*block_x) of the padded field
    slab = jax.lax.dynamic_slice_in_dim(xp, i * block_x, block_x + 2, axis=0)
    o_ref[...] = DIAG * jax.lax.dynamic_slice_in_dim(
        xfull, i * block_x, block_x, axis=0
    ) + OFF * _shifted_sum(slab)


@functools.partial(jax.jit, static_argnames=("block_x",))
def stencil27(x, block_x=None):
    """y = A x for the HPCG 27-point operator, zero boundaries.

    x: (NX, NY, NZ) float32.
    """
    nx, ny, nz = x.shape
    if block_x is None:
        block_x = nx
    assert nx % block_x == 0
    grid = (nx // block_x,)
    kernel = functools.partial(_stencil_kernel, block_x=block_x)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((nx, ny, nz), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((block_x, ny, nz), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)
