"""Pure-jnp correctness oracles for the Pallas kernels.

Every L1 kernel has an oracle here; pytest (python/tests/) asserts
allclose between kernel and oracle across hypothesis-generated shapes.
"""

import jax.numpy as jnp
import numpy as np

from .lbm import C, Q, W


def lbm_collide_ref(f, omega):
    """D3Q19 BGK collision, straight transcription of the physics."""
    w = jnp.asarray(W).reshape((Q, 1, 1, 1))
    c = jnp.asarray(C, f.dtype)  # (19, 3)
    rho = jnp.sum(f, axis=0)
    u = jnp.einsum("qd,qxyz->dxyz", c, f) / rho[None]
    cu = jnp.einsum("qd,dxyz->qxyz", c, u)
    usq = jnp.sum(u * u, axis=0)
    feq = w * rho[None] * (1.0 + 3.0 * cu + 4.5 * cu**2 - 1.5 * usq[None])
    return f + omega * (feq - f)


def lbm_stream_ref(f):
    """Periodic streaming: shift each distribution along its velocity."""
    out = []
    for q in range(Q):
        cx, cy, cz = (int(v) for v in C[q])
        out.append(jnp.roll(f[q], (cx, cy, cz), axis=(0, 1, 2)))
    return jnp.stack(out)


def matmul_ref(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def stencil27_ref(x):
    """HPCG 27-point operator with zero Dirichlet boundaries."""
    xp = jnp.pad(x, 1)
    nx, ny, nz = xp.shape
    acc = jnp.zeros_like(x)
    for dx in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dz in (-1, 0, 1):
                if dx == dy == dz == 0:
                    continue
                acc = acc + xp[
                    1 + dx : nx - 1 + dx,
                    1 + dy : ny - 1 + dy,
                    1 + dz : nz - 1 + dz,
                ]
    return 26.0 * x - acc


def stencil27_dense(n):
    """Dense matrix of the operator on an (n, n, n) grid (tiny n only)."""
    size = n**3
    a = np.zeros((size, size))

    def idx(i, j, k):
        return (i * n + j) * n + k

    for i in range(n):
        for j in range(n):
            for k in range(n):
                a[idx(i, j, k), idx(i, j, k)] = 26.0
                for di in (-1, 0, 1):
                    for dj in (-1, 0, 1):
                        for dk in (-1, 0, 1):
                            if di == dj == dk == 0:
                                continue
                            ii, jj, kk = i + di, j + dj, k + dk
                            if 0 <= ii < n and 0 <= jj < n and 0 <= kk < n:
                                a[idx(i, j, k), idx(ii, jj, kk)] = -1.0
    return a
