"""L1 Pallas kernel: 2:4 structured-sparse matmul (paper §2.1.1).

The A100's Sparse Tensor Cores double matmul throughput when the weight
matrix is pruned so that every group of 4 consecutive elements along K
keeps at most 2 non-zeros ("Structural Sparsity"). This kernel implements
the *semantics* of that path: prune-to-2:4, then multiply. On real
hardware the pruned representation is compressed and the MXU skips the
zeros (the 2x of Table 2's sparse rows); under interpret-mode CPU we
verify numerics and model the speedup in `rust/src/hardware/gpu.rs`
(`peak_flops_sparse`).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def prune_2_4(w):
    """Keep the 2 largest-|.| of every 4 consecutive elements along axis 1.

    Deterministic tie-break (first occurrence wins) so kernel and oracle
    agree bit-for-bit.
    """
    k, n = w.shape
    assert k % 4 == 0, f"K={k} must be a multiple of 4"
    g = w.reshape(k // 4, 4, n)
    a = jnp.abs(g)
    # rank elements within each group of 4; keep top 2
    order = jnp.argsort(-a, axis=1, stable=True)
    rank = jnp.argsort(order, axis=1, stable=True)
    mask = rank < 2
    return (g * mask).reshape(k, n)


def _sparse_matmul_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def sparse_matmul(x, w, bm=128, bn=128, bk=128):
    """x @ prune_2_4(w) via Pallas (pruning fused ahead of the blocks)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    wp = prune_2_4(w)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _sparse_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, wp)


def sparsity_ratio(w):
    """Fraction of zeros after pruning (exactly 0.5 for 2:4)."""
    wp = prune_2_4(w)
    return float(jnp.mean(wp == 0.0))
