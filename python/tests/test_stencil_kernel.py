"""Kernel-vs-oracle tests for the HPCG 27-point stencil."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import stencil
from compile.kernels.ref import stencil27_dense, stencil27_ref

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=10, deadline=None)
@given(
    nx=st.sampled_from([2, 4, 8]),
    ny=st.sampled_from([2, 3, 6]),
    nz=st.sampled_from([2, 5, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_stencil_matches_ref(nx, ny, nz, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (nx, ny, nz), jnp.float32)
    np.testing.assert_allclose(
        stencil.stencil27(x), stencil27_ref(x), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("block_x", [1, 2, 4, 8])
def test_blocking_invariance(block_x):
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 4), jnp.float32)
    np.testing.assert_allclose(
        stencil.stencil27(x, block_x=block_x),
        stencil27_ref(x),
        rtol=1e-5,
        atol=1e-5,
    )


def test_matches_dense_matrix():
    """Cross-check against an explicitly assembled operator matrix."""
    n = 3
    x = jax.random.normal(jax.random.PRNGKey(1), (n, n, n), jnp.float32)
    a = stencil27_dense(n)
    want = (a @ np.asarray(x).ravel()).reshape((n, n, n))
    np.testing.assert_allclose(stencil.stencil27(x), want, rtol=1e-5, atol=1e-5)


def test_operator_is_symmetric():
    """<Ax, y> == <x, Ay> — CG requires a symmetric operator."""
    kx, ky = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(kx, (6, 6, 6), jnp.float32)
    y = jax.random.normal(ky, (6, 6, 6), jnp.float32)
    lhs = jnp.sum(stencil.stencil27(x) * y)
    rhs = jnp.sum(x * stencil.stencil27(y))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


def test_operator_is_positive_definite_sample():
    for seed in range(5):
        x = jax.random.normal(jax.random.PRNGKey(seed), (5, 5, 5), jnp.float32)
        assert jnp.sum(x * stencil.stencil27(x)) > 0


def test_constant_field_interior():
    """On the interior, A @ 1 = 26 - 26 = 0; boundary rows are positive."""
    x = jnp.ones((6, 6, 6), jnp.float32)
    y = stencil.stencil27(x)
    np.testing.assert_allclose(y[2:-2, 2:-2, 2:-2], 0.0, atol=1e-5)
    assert float(y[0, 0, 0]) > 0.0
