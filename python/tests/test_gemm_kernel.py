"""Kernel-vs-oracle tests for the blocked Pallas GEMM (HPL hot spot)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm
from compile.kernels.ref import matmul_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([16, 32, 64]),
    k=st.sampled_from([16, 48, 64]),
    n=st.sampled_from([16, 32, 80]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, b = rand(k1, (m, k)), rand(k2, (k, n))
    got = gemm.matmul(a, b, bm=16, bn=16, bk=16)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (32, 32, 32)])
def test_blocking_invariance(bm, bn, bk):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a, b = rand(k1, (32, 32)), rand(k2, (32, 32))
    base = matmul_ref(a, b)
    got = gemm.matmul(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, base, rtol=2e-4, atol=2e-4)


def test_identity():
    a = jnp.eye(32, dtype=jnp.float32)
    b = rand(jax.random.PRNGKey(1), (32, 32))
    np.testing.assert_allclose(
        gemm.matmul(a, b, bm=16, bn=16, bk=16), b, rtol=1e-6, atol=1e-6
    )


def test_block_larger_than_matrix_is_clamped():
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    a, b = rand(k1, (8, 8)), rand(k2, (8, 8))
    got = gemm.matmul(a, b)  # defaults 128 > 8, clamped
    np.testing.assert_allclose(got, matmul_ref(a, b), rtol=1e-5, atol=1e-5)


def test_gemm_update_is_hpl_trailing_update():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    c = rand(k1, (32, 32))
    a = rand(k2, (32, 16))
    b = rand(k3, (16, 32))
    got = gemm.gemm_update(c, a, b, bm=16, bn=16, bk=16)
    np.testing.assert_allclose(
        got, c - a @ b, rtol=2e-4, atol=2e-4
    )


def test_ragged_shapes_rejected():
    a = jnp.zeros((30, 32), jnp.float32)
    b = jnp.zeros((32, 32), jnp.float32)
    with pytest.raises(AssertionError):
        gemm.matmul(a, b, bm=16, bn=16, bk=16)
