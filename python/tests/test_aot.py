"""AOT exporter tests: every registry entry lowers to loadable HLO text."""

import json
import os

import pytest

from compile import aot


def test_registry_names_are_stable():
    # Load-bearing: the Rust coordinator refers to these keys.
    expected = {
        "lbm_step_32",
        "lbm_step_48",
        "lbm_steps8_32",
        "dgemm_256",
        "dgemm_512",
        "hpl_update_256",
        "spmv_64",
        "cg_iter_64",
        "cg_iters8_64",
    }
    assert expected <= set(aot.REGISTRY)


@pytest.mark.parametrize("name", ["dgemm_256", "spmv_64"])
def test_export_produces_hlo_text(tmp_path, name):
    fn, specs = aot.REGISTRY[name]
    meta = aot.export_one(name, fn, specs, str(tmp_path))
    text = (tmp_path / f"{name}.hlo.txt").read_text()
    assert "HloModule" in text
    assert "ENTRY" in text
    assert meta["hlo_chars"] == len(text)
    assert len(meta["inputs"]) == len(specs)


def test_manifest_matches_artifacts_if_built():
    """If `make artifacts` already ran, manifest and files must agree."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    with open(manifest_path) as fh:
        manifest = json.load(fh)
    for name in manifest:
        path = os.path.join(art, f"{name}.hlo.txt")
        assert os.path.exists(path), f"missing artifact {name}"
        assert manifest[name]["hlo_chars"] == os.path.getsize(path)


def test_scalar_omega_spec():
    _, specs = aot.REGISTRY["lbm_step_32"]
    assert tuple(specs[0].shape) == (19, 32, 32, 32)
    assert tuple(specs[1].shape) == (1,)
