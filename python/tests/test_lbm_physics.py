"""Physics validation of the D3Q19 LBM: quantitative checks against
analytic hydrodynamics, not just oracle agreement.

The decisive test is the shear-wave decay rate: for BGK with relaxation
rate omega, kinematic viscosity is nu = (1/omega - 1/2)/3 (lattice
units); a sinusoidal shear wave u_y(x) = U sin(2 pi x / L) must decay as
exp(-nu k^2 t). Getting this right requires the collision *and* the
streaming to be correct together — it is the standard LBM acceptance
test (cf. the Succi et al. code lineage the paper benchmarks).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import lbm

jax.config.update("jax_platform_name", "cpu")


def shear_wave_state(n, amplitude):
    x = jnp.arange(n)
    uy = amplitude * jnp.sin(2 * jnp.pi * x / n)
    uy = jnp.broadcast_to(uy[:, None, None], (n, 4, 4)).astype(jnp.float32)
    zero = jnp.zeros_like(uy)
    rho = jnp.ones_like(uy)
    return lbm.equilibrium(rho, zero, uy, zero)


def measure_amplitude(f):
    _, mom = model.lbm_macroscopics(f)
    return float(jnp.max(jnp.abs(mom[1])))


@pytest.mark.parametrize("omega", [0.8, 1.0, 1.4])
def test_shear_wave_decay_matches_bgk_viscosity(omega):
    n = 32
    nu = (1.0 / omega - 0.5) / 3.0
    k = 2 * np.pi / n
    steps = 60
    f = shear_wave_state(n, 0.02)
    a0 = measure_amplitude(f)
    f = model.lbm_steps(f, omega, steps)
    a1 = measure_amplitude(f)
    measured_rate = -np.log(a1 / a0) / steps
    expected_rate = nu * k * k
    rel_err = abs(measured_rate - expected_rate) / expected_rate
    assert rel_err < 0.05, (
        f"omega={omega}: decay {measured_rate:.3e} vs analytic "
        f"{expected_rate:.3e} ({rel_err:.1%})"
    )


def test_higher_omega_means_lower_viscosity():
    """Decay must order by viscosity: omega 1.6 decays slower than 0.8."""
    n = 24
    rates = []
    for omega in [0.8, 1.2, 1.6]:
        f = shear_wave_state(n, 0.02)
        a0 = measure_amplitude(f)
        f = model.lbm_steps(f, omega, 40)
        rates.append(-np.log(measure_amplitude(f) / a0) / 40)
    assert rates[0] > rates[1] > rates[2], rates


def test_uniform_advection_preserves_momentum_direction():
    """A uniformly moving fluid stays uniformly moving (Galilean)."""
    n = 8
    shape = (n, n, n)
    u = 0.05
    f = lbm.equilibrium(
        jnp.ones(shape, jnp.float32),
        jnp.full(shape, u, jnp.float32),
        jnp.zeros(shape, jnp.float32),
        jnp.zeros(shape, jnp.float32),
    )
    f = model.lbm_steps(f, 1.0, 20)
    rho, mom = model.lbm_macroscopics(f)
    np.testing.assert_allclose(rho, 1.0, atol=1e-5)
    np.testing.assert_allclose(mom[0], u, atol=1e-5)
    np.testing.assert_allclose(mom[1], 0.0, atol=1e-6)
    np.testing.assert_allclose(mom[2], 0.0, atol=1e-6)


def test_stability_at_moderate_reynolds():
    """A randomly perturbed field stays finite and positive over time."""
    n = 12
    key = jax.random.PRNGKey(0)
    noise = 0.01 * jax.random.normal(key, (3, n, n, n), jnp.float32)
    f = lbm.equilibrium(
        jnp.ones((n, n, n), jnp.float32), noise[0], noise[1], noise[2]
    )
    f = model.lbm_steps(f, 1.6, 50)
    assert bool(jnp.all(jnp.isfinite(f)))
    rho, _ = model.lbm_macroscopics(f)
    assert float(jnp.min(rho)) > 0.5
    np.testing.assert_allclose(float(jnp.mean(rho)), 1.0, rtol=1e-5)
