"""Kernel-vs-oracle tests for the D3Q19 Pallas collision kernel.

Hypothesis sweeps shapes and relaxation rates; fixed tests pin the physics
invariants (conservation, equilibrium fixed point, velocity-set algebra).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lbm
from compile.kernels.ref import lbm_collide_ref, lbm_stream_ref

jax.config.update("jax_platform_name", "cpu")


def random_f(key, shape, eps=0.05):
    """Random positive distributions near equilibrium weights."""
    w = jnp.asarray(lbm.W).reshape((lbm.Q, 1, 1, 1))
    noise = jax.random.uniform(
        key, (lbm.Q,) + shape, minval=-eps, maxval=eps
    )
    return (w * (1.0 + noise)).astype(jnp.float32)


# ----------------------------------------------------------------------
# velocity-set algebra
# ----------------------------------------------------------------------

def test_velocity_set_is_d3q19():
    assert lbm.C.shape == (19, 3)
    norms = np.sum(lbm.C**2, axis=1)
    assert norms[0] == 0
    assert np.sum(norms == 1) == 6
    assert np.sum(norms == 2) == 12


def test_weights_sum_to_one():
    np.testing.assert_allclose(np.sum(lbm.W), 1.0, rtol=1e-6)


def test_weights_match_speed_class():
    norms = np.sum(lbm.C**2, axis=1)
    assert np.allclose(lbm.W[norms == 0], 1 / 3)
    assert np.allclose(lbm.W[norms == 1], 1 / 18)
    assert np.allclose(lbm.W[norms == 2], 1 / 36)


def test_opposite_table():
    for q in range(lbm.Q):
        assert (lbm.C[lbm.OPP[q]] == -lbm.C[q]).all()


def test_velocity_moments_isotropy():
    """Second moment sum_q w_q c_qa c_qb = cs^2 delta_ab with cs^2=1/3."""
    m = np.einsum("q,qa,qb->ab", lbm.W, lbm.C.astype(float), lbm.C.astype(float))
    np.testing.assert_allclose(m, np.eye(3) / 3.0, atol=1e-7)


# ----------------------------------------------------------------------
# kernel vs oracle
# ----------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    nx=st.sampled_from([2, 4, 8]),
    ny=st.sampled_from([2, 3, 5, 8]),
    nz=st.sampled_from([2, 4, 7]),
    omega=st.floats(0.1, 1.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_collide_matches_ref(nx, ny, nz, omega, seed):
    f = random_f(jax.random.PRNGKey(seed), (nx, ny, nz))
    got = lbm.collide(f, omega)
    want = lbm_collide_ref(f, jnp.float32(omega))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-7)


@pytest.mark.parametrize("block_x", [1, 2, 4, 8])
def test_collide_blocking_invariance(block_x):
    """Result must not depend on the BlockSpec tiling."""
    f = random_f(jax.random.PRNGKey(7), (8, 4, 4))
    base = lbm.collide(f, 1.2, block_x=8)
    got = lbm.collide(f, 1.2, block_x=block_x)
    np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-8)


@settings(max_examples=8, deadline=None)
@given(omega=st.floats(0.2, 1.8), seed=st.integers(0, 2**31 - 1))
def test_collision_conserves_mass_momentum(omega, seed):
    f = random_f(jax.random.PRNGKey(seed), (4, 4, 4))
    fc = lbm.collide(f, omega)
    c = jnp.asarray(lbm.C, jnp.float32)
    np.testing.assert_allclose(
        jnp.sum(fc, 0), jnp.sum(f, 0), rtol=1e-5, atol=1e-7
    )
    np.testing.assert_allclose(
        jnp.einsum("qd,qxyz->dxyz", c, fc),
        jnp.einsum("qd,qxyz->dxyz", c, f),
        rtol=1e-4,
        atol=1e-6,
    )


def test_equilibrium_is_fixed_point():
    """Collision leaves an equilibrium distribution unchanged."""
    shape = (4, 4, 4)
    rho = jnp.full(shape, 1.0, jnp.float32)
    ux = jnp.full(shape, 0.03, jnp.float32)
    uy = jnp.full(shape, -0.01, jnp.float32)
    uz = jnp.full(shape, 0.02, jnp.float32)
    feq = lbm.equilibrium(rho, ux, uy, uz)
    fc = lbm.collide(feq, 1.7)
    np.testing.assert_allclose(fc, feq, rtol=1e-5, atol=1e-7)


def test_collide_rest_fluid_identity():
    """Zero-velocity uniform fluid: f = w, collision is the identity."""
    f = jnp.tile(
        jnp.asarray(lbm.W).reshape((lbm.Q, 1, 1, 1)), (1, 4, 4, 4)
    ).astype(jnp.float32)
    fc = lbm.collide(f, 1.0)
    np.testing.assert_allclose(fc, f, rtol=1e-6, atol=1e-8)


def test_omega_zero_is_identity():
    f = random_f(jax.random.PRNGKey(3), (4, 4, 4))
    np.testing.assert_allclose(
        lbm.collide(f, 0.0), f, rtol=1e-6, atol=1e-8
    )


def test_stream_ref_is_permutation():
    """Streaming permutes sites: global mass per direction unchanged."""
    f = random_f(jax.random.PRNGKey(11), (4, 5, 6))
    fs = lbm_stream_ref(f)
    np.testing.assert_allclose(
        jnp.sum(fs, axis=(1, 2, 3)), jnp.sum(f, axis=(1, 2, 3)), rtol=1e-6
    )


def test_default_block_x_fits_budget():
    bx = lbm._default_block_x(64, 64, 64)
    assert 64 % bx == 0
    assert 2 * 19 * 4 * 64 * 64 * bx <= 16 * 2**20
