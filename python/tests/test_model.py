"""L2 model tests: step composition, CG convergence, HPL update."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import lbm
from compile.kernels.ref import lbm_collide_ref, lbm_stream_ref, stencil27_ref

jax.config.update("jax_platform_name", "cpu")


def random_f(key, shape, eps=0.05):
    w = jnp.asarray(lbm.W).reshape((lbm.Q, 1, 1, 1))
    noise = jax.random.uniform(key, (lbm.Q,) + shape, minval=-eps, maxval=eps)
    return (w * (1.0 + noise)).astype(jnp.float32)


# ----------------------------------------------------------------------
# LBM step
# ----------------------------------------------------------------------

def test_lbm_step_is_stream_of_collide():
    f = random_f(jax.random.PRNGKey(0), (4, 4, 4))
    got = model.lbm_step(f, 1.3)
    want = lbm_stream_ref(lbm_collide_ref(f, jnp.float32(1.3)))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-7)


def test_lbm_step_conserves_global_mass_momentum():
    f = random_f(jax.random.PRNGKey(1), (4, 6, 4))
    f2 = model.lbm_step(f, 1.1)
    rho0, mom0 = model.lbm_macroscopics(f)
    rho1, mom1 = model.lbm_macroscopics(f2)
    np.testing.assert_allclose(jnp.sum(rho1), jnp.sum(rho0), rtol=1e-5)
    np.testing.assert_allclose(
        jnp.sum(mom1, (1, 2, 3)), jnp.sum(mom0, (1, 2, 3)), rtol=1e-3, atol=1e-5
    )


@settings(max_examples=4, deadline=None)
@given(n=st.sampled_from([2, 4]), seed=st.integers(0, 1000))
def test_lbm_steps_scan_equals_loop(n, seed):
    f = random_f(jax.random.PRNGKey(seed), (4, 4, 4))
    scanned = model.lbm_steps(f, 1.5, n)
    looped = f
    for _ in range(n):
        looped = model.lbm_step(looped, 1.5)
    np.testing.assert_allclose(scanned, looped, rtol=1e-4, atol=1e-6)


def test_lbm_shear_wave_decays():
    """A sinusoidal shear wave must decay monotonically (viscosity > 0)."""
    n = 16
    x = jnp.arange(n)
    uy = 0.02 * jnp.sin(2 * jnp.pi * x / n)
    uy = jnp.broadcast_to(uy[:, None, None], (n, 4, 4)).astype(jnp.float32)
    zero = jnp.zeros_like(uy)
    f = lbm.equilibrium(jnp.ones_like(uy), zero, uy, zero)
    amp = []
    for _ in range(3):
        _, mom = model.lbm_macroscopics(f)
        amp.append(float(jnp.max(jnp.abs(mom[1]))))
        f = model.lbm_steps(f, 1.0, 8)
    assert amp[0] > amp[1] > amp[2]


# ----------------------------------------------------------------------
# HPL / HPCG
# ----------------------------------------------------------------------

def test_hpl_update():
    k = jax.random.split(jax.random.PRNGKey(2), 3)
    c = jax.random.normal(k[0], (32, 32), jnp.float32)
    a = jax.random.normal(k[1], (32, 32), jnp.float32)
    b = jax.random.normal(k[2], (32, 32), jnp.float32)
    np.testing.assert_allclose(
        model.hpl_update(c, a, b), c - a @ b, rtol=2e-4, atol=2e-4
    )


def test_spmv_equals_ref():
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 8, 8), jnp.float32)
    np.testing.assert_allclose(
        model.spmv(x), stencil27_ref(x), rtol=1e-5, atol=1e-5
    )


def _cg_state(b):
    x = jnp.zeros_like(b)
    r = b
    p = r
    rz = jnp.sum(r * r)
    return x, r, p, rz


def test_cg_iter_reduces_residual():
    b = jax.random.normal(jax.random.PRNGKey(4), (8, 8, 8), jnp.float32)
    x, r, p, rz = _cg_state(b)
    for _ in range(5):
        x, r, p, rz_new = model.cg_iter(x, r, p, rz)
        assert float(rz_new) < float(rz) * 1.0001
        rz = rz_new


def test_cg_converges_on_stencil_system():
    """CG must actually solve A x = b to high accuracy."""
    b = jax.random.normal(jax.random.PRNGKey(5), (6, 6, 6), jnp.float32)
    state = _cg_state(b)
    x, r, p, rz = model.cg_iters(*state, n_iters=25)
    res = b - model.spmv(x)
    rel = float(jnp.linalg.norm(res.ravel()) / jnp.linalg.norm(b.ravel()))
    assert rel < 1e-4, rel


def test_cg_is_noop_after_convergence():
    """Past convergence rz underflows; guarded divisions must not NaN."""
    b = jax.random.normal(jax.random.PRNGKey(7), (4, 4, 4), jnp.float32)
    x, r, p, rz = model.cg_iters(*_cg_state(b), n_iters=120)
    assert bool(jnp.all(jnp.isfinite(x)))
    res = b - model.spmv(x)
    rel = float(jnp.linalg.norm(res.ravel()) / jnp.linalg.norm(b.ravel()))
    assert rel < 1e-4, rel


def test_cg_iters_scan_equals_loop():
    b = jax.random.normal(jax.random.PRNGKey(6), (6, 6, 6), jnp.float32)
    scanned = model.cg_iters(*_cg_state(b), n_iters=4)
    state = _cg_state(b)
    for _ in range(4):
        state = model.cg_iter(*state)
    for s, l in zip(scanned, state):
        np.testing.assert_allclose(s, l, rtol=1e-3, atol=1e-5)
