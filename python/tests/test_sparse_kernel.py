"""Tests for the 2:4 structured-sparsity kernel (paper §2.1.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import sparse

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def test_prune_keeps_exactly_two_of_four():
    w = rand(jax.random.PRNGKey(0), (16, 8))
    wp = sparse.prune_2_4(w)
    groups = np.asarray(wp).reshape(4, 4, 8)
    nonzero = (groups != 0).sum(axis=1)
    assert (nonzero <= 2).all()
    # Generic Gaussian weights: exactly two survive per group.
    assert (nonzero == 2).all()


def test_prune_keeps_the_largest_magnitudes():
    w = jnp.asarray(
        [[1.0], [-5.0], [0.1], [3.0]], dtype=jnp.float32
    )  # K=4, N=1
    wp = sparse.prune_2_4(w)
    np.testing.assert_allclose(
        wp.ravel(), jnp.asarray([0.0, -5.0, 0.0, 3.0]), atol=0
    )


def test_sparsity_ratio_is_half():
    w = rand(jax.random.PRNGKey(1), (64, 32))
    assert abs(sparse.sparsity_ratio(w) - 0.5) < 1e-6


@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([8, 16]),
    k=st.sampled_from([16, 32]),
    n=st.sampled_from([8, 24]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sparse_matmul_matches_dense_on_pruned(m, k, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x, w = rand(k1, (m, k)), rand(k2, (k, n))
    got = sparse.sparse_matmul(x, w, bm=8, bn=8, bk=8)
    want = x @ sparse.prune_2_4(w)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_pruned_product_approximates_dense_for_spiky_weights():
    """2:4 pruning is near-lossless when weights are naturally sparse-ish."""
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    # two dominant entries per group of 4
    base = rand(k1, (32, 16)) * 0.01
    spikes = rand(k2, (8, 16))
    w = base.at[::4].add(spikes).at[1::4].add(rand(k3, (8, 16)))
    x = rand(key, (8, 32))
    dense = x @ w
    pruned = sparse.sparse_matmul(x, w, bm=8, bn=8, bk=8)
    rel = float(
        jnp.linalg.norm(pruned - dense) / jnp.linalg.norm(dense)
    )
    assert rel < 0.05, rel


def test_ragged_k_rejected():
    x = jnp.zeros((8, 6), jnp.float32)
    w = jnp.zeros((6, 8), jnp.float32)
    with pytest.raises(AssertionError):
        sparse.prune_2_4(w)
