//! Bench for Table 3: storage capacity/bandwidth derivation and the
//! striping model.

use leonardo_twin::util::bench::{black_box, Criterion};
use leonardo_twin::coordinator::Twin;
use leonardo_twin::storage::{StorageSystem, Stripe};

fn bench(c: &mut Criterion) {
    println!("{}", Twin::leonardo().table3().to_console());

    c.bench_function("table3/build_storage", |b| {
        b.iter(|| black_box(StorageSystem::leonardo()).appliance_count())
    });
    let sys = StorageSystem::leonardo();
    let scratch = sys.namespace("/scratch").unwrap();
    c.bench_function("table3/namespace_derivations", |b| {
        b.iter(|| {
            (
                black_box(scratch).net_pib(),
                scratch.peak_write_gbs(),
                scratch.peak_read_gbs(),
                scratch.md_kiops(),
            )
        })
    });
    c.bench_function("table3/stripe_sweep", |b| {
        b.iter(|| {
            let mut acc = 0.0;
            for count in 1..=64u32 {
                acc += Stripe {
                    count,
                    size_mib: 16,
                }
                .file_bw_gbs(45.0, black_box(scratch), count % 2 == 0);
            }
            acc
        })
    });
}

fn main() {
    let mut c = Criterion::default();
    bench(&mut c);
}
