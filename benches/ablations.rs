//! Ablation benches for the design choices DESIGN.md calls out:
//!   * routing policy (minimal vs Valiant) on the latency budget;
//!   * placement policy (packed vs spread) on LBM step time;
//!   * DVFS workpoint sweep on energy-to-solution;
//!   * real HPL LU: host-only vs PJRT-offloaded trailing updates.

use leonardo_twin::coordinator::Twin;
use leonardo_twin::hpl;
use leonardo_twin::lbm::{LbmConfig, LbmDriver};
use leonardo_twin::metrics::{f1, f2, Table};
use leonardo_twin::network::Placement;
use leonardo_twin::power::{DvfsPoint, Utilization};
use leonardo_twin::runtime::Engine;
use leonardo_twin::util::bench::{black_box, Criterion};

fn placement_ablation(twin: &Twin) {
    let node = twin.cfg.gpu_node_spec().unwrap().clone();
    let mut t = Table::new(
        "Ablation — placement policy x fabric load (512-node LBM step [ms])",
        &["Placement", "Cells", "Idle fabric", "Busy fabric (80% global load)"],
    );
    let packed = twin.place(512).unwrap();
    let spread = Placement {
        nodes_per_cell: (0..16).map(|c| (c, 32)).collect(),
    };
    let mut busy_net = twin.net.clone();
    busy_net.background_global_load = 0.8;
    let step = |net: &leonardo_twin::network::Network, p: &Placement| {
        LbmDriver::new(&node, net, LbmConfig::default())
            .point(512, p)
            .step_seconds
            * 1e3
    };
    t.row(vec![
        "packed (scheduler)".into(),
        packed.cells_used().to_string(),
        f2(step(&twin.net, &packed)),
        f2(step(&busy_net, &packed)),
    ]);
    t.row(vec![
        "spread (round-robin)".into(),
        spread.cells_used().to_string(),
        f2(step(&twin.net, &spread)),
        f2(step(&busy_net, &spread)),
    ]);
    println!("{}", t.to_console());
}

fn dvfs_ablation(twin: &Twin) {
    let mut t = Table::new(
        "Ablation — DVFS workpoint (HPL-class load, boundness 0.9)",
        &["Scale", "Power [W/node]", "Time factor", "Energy factor"],
    );
    let u = Utilization::hpl();
    let idle = twin.power.node_power_w(Utilization::idle());
    let dynamic = twin.power.node_power_w(u) - idle;
    for scale in [1.0f64, 0.9, 0.8, 0.7, 0.6, 0.5] {
        let p = DvfsPoint { scale };
        let power = idle + dynamic * p.power_factor();
        let tf = p.time_factor(0.9);
        let nominal = idle + dynamic;
        t.row(vec![
            f2(scale),
            f1(power),
            f2(tf),
            f2(power * tf / nominal),
        ]);
    }
    println!("{}", t.to_console());
}

fn bench(c: &mut Criterion) {
    let twin = Twin::leonardo();
    placement_ablation(&twin);
    dvfs_ablation(&twin);

    // Routing policy ablation as a micro-bench (hot path of every
    // collective estimate).
    use leonardo_twin::topology::Routing;
    c.bench_function("ablation/route_minimal", |b| {
        b.iter(|| twin.topo.route(black_box(3), black_box(4100), Routing::Minimal))
    });
    c.bench_function("ablation/route_valiant", |b| {
        b.iter(|| twin.topo.route(black_box(3), black_box(4100), Routing::Valiant))
    });

    // Real blocked LU: host vs PJRT-offloaded trailing update.
    let n = 512; // two 256-panels: the trailing update offloads one full tile
    let mut host = hpl::random_matrix(n, 5);
    let r_host = hpl::lu_factor(&mut host, n, None).unwrap();
    println!(
        "hpl-lu/host        n={n}: {:.2} s, {:.2} GFLOPS",
        r_host.seconds, r_host.gflops
    );
    if let Ok(engine) = Engine::load(Engine::default_dir()) {
        let mut dev = hpl::random_matrix(n, 5);
        let r_dev = hpl::lu_factor(&mut dev, n, Some(&engine)).unwrap();
        println!(
            "hpl-lu/pjrt-offload n={n}: {:.2} s, {:.2} GFLOPS ({}% offloaded)",
            r_dev.seconds,
            r_dev.gflops,
            (r_dev.offload_fraction * 100.0) as u32
        );
    } else {
        eprintln!("artifacts/ missing — PJRT LU ablation skipped");
    }
}

fn main() {
    let mut c = Criterion::default();
    bench(&mut c);
}
