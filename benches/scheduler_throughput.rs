//! Scheduler throughput: simulated jobs/sec on mixed HPC+AI day traces
//! across the three engine generations —
//!
//! 1. `run_rescan`    — the seed's scan-and-rescan loop;
//! 2. `run_event_baseline` — the PR 1 event engine (alloc-and-sort
//!    placement, full queue scan per pass, per-event placement copies);
//! 3. `run`           — the allocation-free hot path (O(1) counters,
//!    cached placement order, indexed release, interned `Start`/`End`
//!    placements, min-queued pass pruning, reused dispatch buffers).
//!
//! All three are record-identical (asserted below on a trace prefix, and
//! bit-for-bit in `rust/tests/sim_scheduler.rs`); the contrast is pure
//! engine cost.
//!
//! Tiers: the 10k-job day (the PR 1 flagship trace, where the gates
//! apply) and a 100k-job ten-day stress tier (same offered load per
//! day; the rescan loop is quadratic there and is skipped). Results are
//! also written to `BENCH_scheduler.json` so future PRs have a
//! perf trajectory to diff against.
//!
//! Gates (assert-enforced, also run by CI in `--smoke` mode):
//!   * optimized >= 5x rescan     on the 10k-job day;
//!   * optimized >= 2x event base on the 10k-job day.
//!
//! `cargo bench --bench scheduler_throughput -- --smoke` runs single-rep
//! timings and skips the 100k tier — the short mode CI uses.

use std::time::Instant;

use leonardo_twin::config::MachineConfig;
use leonardo_twin::metrics::{f1, Table};
use leonardo_twin::scheduler::{Job, JobRecord, Scheduler};
use leonardo_twin::workloads::TraceGen;

fn time_best<F: FnMut() -> usize>(reps: u32, mut f: F) -> (f64, usize) {
    let mut best = f64::INFINITY;
    let mut jobs = 0;
    for _ in 0..reps {
        let t0 = Instant::now();
        jobs = f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    (best, jobs)
}

fn assert_identical(
    a: &std::collections::BTreeMap<u64, JobRecord>,
    b: &std::collections::BTreeMap<u64, JobRecord>,
    tag: &str,
) {
    assert_eq!(a.len(), b.len(), "{tag}: record counts differ");
    for (id, r) in a {
        let o = &b[id];
        assert_eq!(r.start_time, o.start_time, "{tag}: job {id} start");
        assert_eq!(r.end_time, o.end_time, "{tag}: job {id} end");
        assert_eq!(
            r.placement.nodes_per_cell, o.placement.nodes_per_cell,
            "{tag}: job {id} placement"
        );
    }
}

struct TierResult {
    jobs: usize,
    rescan_rate: Option<f64>,
    event_rate: f64,
    optimized_rate: f64,
}

fn json_num(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x:.1}"),
        None => "null".to_string(),
    }
}

fn write_json(tiers: &[TierResult], smoke: bool) {
    let mut entries = Vec::new();
    for t in tiers {
        entries.push(format!(
            concat!(
                "    {{\"jobs\": {}, \"rescan_jobs_per_s\": {}, ",
                "\"event_jobs_per_s\": {:.1}, \"optimized_jobs_per_s\": {:.1}, ",
                "\"optimized_vs_rescan\": {}, \"optimized_vs_event\": {:.2}}}"
            ),
            t.jobs,
            json_num(t.rescan_rate),
            t.event_rate,
            t.optimized_rate,
            json_num(t.rescan_rate.map(|r| t.optimized_rate / r)),
            t.optimized_rate / t.event_rate,
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"scheduler_throughput\",\n  \"trace\": \"booster_day\",\n  \"smoke\": {},\n  \"tiers\": [\n{}\n  ]\n}}\n",
        smoke,
        entries.join(",\n")
    );
    match std::fs::write("BENCH_scheduler.json", &json) {
        Ok(()) => println!("wrote BENCH_scheduler.json"),
        Err(e) => eprintln!("warning: could not write BENCH_scheduler.json: {e}"),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = MachineConfig::leonardo();
    let trace = TraceGen::booster_day(10_000, 7).generate();

    // Correctness gate: all three engines agree on a 1.5k-job prefix.
    let prefix: Vec<Job> = trace.iter().take(1500).cloned().collect();
    let opt = Scheduler::new(&cfg).run(prefix.clone());
    let base = Scheduler::new(&cfg).run_event_baseline(prefix.clone());
    let legacy = Scheduler::new(&cfg).run_rescan(prefix);
    assert_identical(&opt, &base, "optimized vs event baseline");
    assert_identical(&opt, &legacy, "optimized vs rescan");
    println!("equivalence check passed on 1500-job prefix (3 engines)\n");

    let (opt_reps, base_reps, rescan_reps) = if smoke { (2, 1, 1) } else { (3, 2, 2) };

    // ---- Tier 1: the 10k-job day (the gated tier).
    let (opt_s, n) = time_best(opt_reps, || Scheduler::new(&cfg).run(trace.clone()).len());
    let (base_s, _) = time_best(base_reps, || {
        Scheduler::new(&cfg).run_event_baseline(trace.clone()).len()
    });
    let (rescan_s, _) = time_best(rescan_reps, || {
        Scheduler::new(&cfg).run_rescan(trace.clone()).len()
    });
    let day = TierResult {
        jobs: n,
        rescan_rate: Some(n as f64 / rescan_s),
        event_rate: n as f64 / base_s,
        optimized_rate: n as f64 / opt_s,
    };

    let mut tiers = vec![day];

    // ---- Tier 2: 100k jobs over ten days (same offered load per day);
    // the quadratic rescan loop is skipped here.
    if !smoke {
        let mut big = TraceGen::booster_day(100_000, 7);
        big.duration_s *= 10.0;
        let big_trace = big.generate();
        let (opt_s, n) =
            time_best(2, || Scheduler::new(&cfg).run(big_trace.clone()).len());
        let (base_s, _) = time_best(1, || {
            Scheduler::new(&cfg)
                .run_event_baseline(big_trace.clone())
                .len()
        });
        tiers.push(TierResult {
            jobs: n,
            rescan_rate: None,
            event_rate: n as f64 / base_s,
            optimized_rate: n as f64 / opt_s,
        });
    }

    let mut t = Table::new(
        "Scheduler throughput — mixed HPC+AI day traces (Booster)",
        &["Engine", "Jobs", "Simulated jobs/sec", "vs rescan", "vs event"],
    );
    for tier in &tiers {
        if let Some(rr) = tier.rescan_rate {
            t.row(vec![
                "legacy rescan loop (seed)".into(),
                tier.jobs.to_string(),
                f1(rr),
                "1.0x".into(),
                "-".into(),
            ]);
        }
        t.row(vec![
            "event engine (PR 1 baseline)".into(),
            tier.jobs.to_string(),
            f1(tier.event_rate),
            tier.rescan_rate
                .map(|rr| format!("{:.1}x", tier.event_rate / rr))
                .unwrap_or_else(|| "-".into()),
            "1.0x".into(),
        ]);
        t.row(vec![
            "optimized hot path".into(),
            tier.jobs.to_string(),
            f1(tier.optimized_rate),
            tier.rescan_rate
                .map(|rr| format!("{:.1}x", tier.optimized_rate / rr))
                .unwrap_or_else(|| "-".into()),
            format!("{:.1}x", tier.optimized_rate / tier.event_rate),
        ]);
    }
    println!("{}", t.to_console());

    write_json(&tiers, smoke);

    let day = &tiers[0];
    let vs_rescan = day.optimized_rate / day.rescan_rate.expect("day tier has rescan");
    let vs_event = day.optimized_rate / day.event_rate;
    assert!(
        vs_rescan >= 5.0,
        "optimized engine must be >= 5x the seed loop, got {vs_rescan:.2}x"
    );
    assert!(
        vs_event >= 2.0,
        "optimized engine must be >= 2x the PR 1 event engine, got {vs_event:.2}x"
    );
    println!(
        "OK: optimized path is {vs_rescan:.1}x the seed loop, {vs_event:.1}x the PR 1 event engine"
    );
}
