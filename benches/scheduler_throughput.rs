//! Scheduler throughput: simulated jobs/sec on the 10k-job mixed HPC+AI
//! day trace — the event-driven engine (`Scheduler::run`) vs the seed's
//! scan-and-rescan loop (`Scheduler::run_rescan`).
//!
//! The two implementations are semantically identical (asserted below on
//! a prefix of the trace); the contrast is pure engine cost: the legacy
//! loop recomputes the next wake-up by scanning the running vector,
//! re-sorts it for every head reservation and rescans per-cell free
//! counts per queued job, while the event engine keeps running jobs in
//! an end-time-ordered map, free nodes in O(1) counters, and wakes only
//! on events.

use std::time::Instant;

use leonardo_twin::config::MachineConfig;
use leonardo_twin::metrics::{f1, Table};
use leonardo_twin::scheduler::{Job, Scheduler};
use leonardo_twin::workloads::TraceGen;

fn time_best<F: FnMut() -> usize>(reps: u32, mut f: F) -> (f64, usize) {
    let mut best = f64::INFINITY;
    let mut jobs = 0;
    for _ in 0..reps {
        let t0 = Instant::now();
        jobs = f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    (best, jobs)
}

fn main() {
    let cfg = MachineConfig::leonardo();
    let trace = TraceGen::booster_day(10_000, 7).generate();

    // Correctness gate: both engines agree on a 1.5k-job prefix.
    let prefix: Vec<Job> = trace.iter().take(1500).cloned().collect();
    let ev = Scheduler::new(&cfg).run(prefix.clone());
    let legacy = Scheduler::new(&cfg).run_rescan(prefix);
    assert_eq!(ev.len(), legacy.len());
    for (id, r) in &ev {
        assert_eq!(r.start_time, legacy[id].start_time, "job {id}");
        assert_eq!(r.end_time, legacy[id].end_time, "job {id}");
    }
    println!("equivalence check passed on 1500-job prefix\n");

    let (event_s, n) = time_best(3, || {
        Scheduler::new(&cfg).run(trace.clone()).len()
    });
    let (rescan_s, _) = time_best(2, || {
        Scheduler::new(&cfg).run_rescan(trace.clone()).len()
    });

    let event_rate = n as f64 / event_s;
    let rescan_rate = n as f64 / rescan_s;
    let speedup = event_rate / rescan_rate;

    let mut t = Table::new(
        "Scheduler throughput — 10k-job mixed HPC+AI day (Booster)",
        &["Engine", "Wall [s]", "Simulated jobs/sec", "Speedup"],
    );
    t.row(vec![
        "legacy rescan loop (seed)".into(),
        format!("{rescan_s:.3}"),
        f1(rescan_rate),
        "1.0x".into(),
    ]);
    t.row(vec![
        "event engine (sim kernel)".into(),
        format!("{event_s:.3}"),
        f1(event_rate),
        format!("{speedup:.1}x"),
    ]);
    println!("{}", t.to_console());

    assert!(
        speedup >= 5.0,
        "event engine must be >= 5x the seed loop, got {speedup:.2}x"
    );
    println!("OK: event engine is {speedup:.1}x the seed loop");
}
