//! Bench for Table 2: GPU peak-rate derivation across all precisions.

use leonardo_twin::util::bench::{black_box, Criterion};
use leonardo_twin::coordinator::Twin;
use leonardo_twin::hardware::{GpuSpec, Precision};

fn bench(c: &mut Criterion) {
    println!("{}", Twin::leonardo().table2().to_console());

    let precisions = [
        Precision::Fp64,
        Precision::Fp32,
        Precision::Fp64TensorCore,
        Precision::Tf32TensorCore,
        Precision::Fp16TensorCore,
        Precision::Int8TensorCore,
        Precision::Int4TensorCore,
    ];
    c.bench_function("table2/peaks_all_precisions", |b| {
        let gpus = [
            GpuSpec::a100_custom(),
            GpuSpec::a100_standard(),
            GpuSpec::v100(),
        ];
        b.iter(|| {
            let mut acc = 0.0;
            for g in &gpus {
                for p in precisions {
                    acc += black_box(g).peak_flops(p).unwrap_or(0.0);
                }
            }
            acc
        })
    });
    c.bench_function("table2/render", |b| {
        let twin = Twin::leonardo();
        b.iter(|| black_box(&twin).table2().to_markdown())
    });
}

fn main() {
    let mut c = Criterion::default();
    bench(&mut c);
}
