//! Campaign sweep throughput: scenarios/sec on a 24-scenario acceptance
//! grid (4 seeds x 3 caps x 2 mixes), fanned across all available
//! cores, in seven tiers:
//!
//! 1. **uncoupled / streaming** — the feedback-free ceiling;
//! 2. **coupled / incremental streaming** — the production engine:
//!    cell-indexed incremental retiming + per-worker scenario arenas +
//!    mpsc merge-as-they-finish (PackFirst placement);
//! 3. **coupled / retime-all join-then-merge** — the PR 3 baseline:
//!    every perturbation re-derives every running coupled job, every
//!    scenario pays a fresh rig, results merge after the join;
//! 4. **coupled / SpreadLinks streaming** — tier 2 under the link-aware
//!    anti-fragmentation policy (ISSUE 5): the policy pays a richer
//!    sort key and different (less packed) placements.
//! 5. **coupled / divergence-tree forked** — ISSUE 6: the same coupled
//!    grid with the cap deferred to late in the day, so the cap axis
//!    shares one long event prefix per (seed, mix). The forked engine
//!    simulates that prefix once, snapshots, and replays only the
//!    divergent suffix per cap level; its baseline is streaming on the
//!    *same* deferred-cap grid.
//! 6. **coupled / faulted streaming** — ISSUE 7: tier 2 under a
//!    node-failure trace (MTBF-driven group outages, exponential
//!    repair) with periodic checkpoints, so every kill requeues the
//!    victim with truncated rework and the survivors re-time.
//! 7. **distributed fleet** — ISSUE 8: the coordinator + worker-fleet
//!    service running tier 6's coupled faulted grid with 1, 2 and 4
//!    in-process workers over real loopback TCP — consistent-hash
//!    sharding, the length-prefixed JSON wire, and the grid-index slot
//!    merge all on the timed path. Reports are asserted byte-identical
//!    to tier 6 in both modes; the 2-worker fleet must reach >= 1.6x
//!    the 1-worker fleet's scenario throughput at full scale (the ring
//!    splits the 24 groups exactly 12/12). ISSUE 9 extends the tier
//!    with a multi-job probe (a persistent coordinator serving three
//!    concurrently submitted copies of the grid through one fleet —
//!    queue makespan and jobs/s) and a churn probe (one worker crashes
//!    mid-sweep — reassignment latency from the service stats); both
//!    ride into `BENCH_distributed.json`. ISSUE 10 adds the skew
//!    probe: a deliberately imbalanced forked+faulted grid whose
//!    static ring layout piles half the groups onto one worker, run
//!    at 4 workers under both dispatch modes — adaptive pull must cut
//!    the makespan >= 1.4x vs static sharding (>= 1.2x smoke), with
//!    both reports byte-identical to the forked oracle.
//!
//! Gates: the incremental engine must run the coupled grid at >= 2x the
//! PR 3 baseline, coupled throughput must land within 3x of uncoupled —
//! "coupled sweeps as cheap as uncoupled ones" is the ISSUE 4
//! acceptance bar — SpreadLinks placement overhead must stay within
//! 1.5x of PackFirst scenario throughput (ISSUE 5), the forked sweep
//! must beat streaming on the deferred-cap grid by >= 2x scenarios/sec
//! (ISSUE 6), and the faulted sweep must land within 2x of the
//! fault-free coupled streaming tier (ISSUE 7 — resilience bookkeeping
//! must not dominate the sweep). Smoke mode gates with noise headroom
//! (1.5x/4x/2x/1.5x/2.5x — shared-runner wall-clock ratios at small
//! scale jitter). Reports are asserted byte-identical between tiers 2
//! and 3 (same numbers, different cost) and between tier 5 and its
//! streaming baseline (modulo the fork counters), and the trajectory is
//! written to `BENCH_campaign.json`.
//!
//! `cargo bench --bench campaign_throughput -- --smoke` shrinks the
//! per-scenario day and runs one rep — the CI smoke that both gates the
//! coupled engines end-to-end and emits the JSON artifact.

use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

use leonardo_twin::campaign::{
    run_sweep, run_sweep_forked, run_sweep_streaming, CampaignReport, SweepGrid,
};
use leonardo_twin::coordinator::Twin;
use leonardo_twin::scheduler::{CheckpointPolicy, Coupling, PolicyKind};
use leonardo_twin::service::{
    drain, run_distributed, run_fleet, run_worker, serve_listener, submit, CoordinatorConfig,
    DispatchMode, HashRing, SweepSpec, WorkerOptions, DEFAULT_REPLICAS,
};
use leonardo_twin::workloads::FaultTrace;

fn best_of<F: FnMut() -> CampaignReport>(reps: usize, mut f: F) -> (f64, CampaignReport) {
    let mut best = f64::INFINITY;
    let mut report = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = f();
        best = best.min(t0.elapsed().as_secs_f64());
        report = Some(r);
    }
    (best, report.expect("at least one rep"))
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // Smoke still runs best-of-2 on a 300-job day: the gates below are
    // ratios of wall-clock timings, and a single one-shot rep of a tiny
    // grid (where thread-spawn and rig-build fixed costs rival the
    // retiming work being measured) would make the required CI step
    // timing-flaky on shared runners.
    let jobs = if smoke { 300 } else { 1_000 };
    let reps = if smoke { 2 } else { 3 };
    let threads = std::thread::available_parallelism().map_or(1, |n| n.get());

    let twin = Twin::leonardo();
    // hpc first: capability heroes span cells and communicate, so the
    // retimer — not the trace — is what the coupled tiers measure.
    let grid = SweepGrid::new(
        vec![1, 2, 3, 4],
        vec![None, Some(7.5), Some(6.0)],
        vec!["hpc".into(), "day".into()],
        jobs,
    )
    .expect("static grid");
    assert_eq!(grid.len(), 24, "the acceptance grid is 24 scenarios");
    let coupled_grid = grid.clone().with_coupling(Coupling::full());
    let oracle_grid = coupled_grid.clone().with_retime_all(true);
    let spread_grid = coupled_grid
        .clone()
        .with_policies(vec![PolicyKind::SpreadLinks]);

    let (uncoupled_s, _) = best_of(reps, || run_sweep_streaming(&twin, &grid, threads));
    let (coupled_s, coupled) = best_of(reps, || run_sweep_streaming(&twin, &coupled_grid, threads));
    let (oracle_s, oracle) = best_of(reps, || run_sweep(&twin, &oracle_grid, threads));
    let (spread_s, spread) = best_of(reps, || run_sweep_streaming(&twin, &spread_grid, threads));

    // Tier 5 (ISSUE 6): defer the cap to 90% of the shortest uncapped
    // makespan, so every (seed, mix) group shares a long common prefix
    // and diverges only on the cap axis. The streaming baseline runs
    // the *same* deferred-cap grid, so the two reports are comparable
    // byte-for-byte and the timing ratio isolates the fork machinery.
    let base_makespan_h = coupled
        .stats
        .iter()
        .filter(|s| s.cap_mw.is_none())
        .map(|s| s.makespan_h)
        .fold(f64::INFINITY, f64::min);
    assert!(base_makespan_h.is_finite() && base_makespan_h > 0.0);
    let cap_time = 0.9 * base_makespan_h * 3600.0;
    let deferred_grid = coupled_grid.clone().with_cap_time(cap_time);
    let (fork_base_s, fork_base) =
        best_of(reps, || run_sweep_streaming(&twin, &deferred_grid, threads));
    let (forked_s, forked) = best_of(reps, || run_sweep_forked(&twin, &deferred_grid, threads));

    // Tier 6 (ISSUE 7): the coupled grid under a node-failure process.
    // Every scenario replays the same 24-cell grid, but ~300 group
    // outages/day kill overlapping jobs, requeue them with
    // checkpoint-truncated rework and force the survivors through the
    // retimer. The gate compares against tier 2 — same grid, same
    // engine, zero faults.
    let faults = FaultTrace {
        seed: 7,
        duration_s: 86_400.0,
        node_mtbf_s: 1.0e6,
        repair_mean_s: 7_200.0,
        group: 32,
        ..FaultTrace::none()
    };
    let faulted_grid = coupled_grid
        .clone()
        .with_fault_traces(vec![faults])
        .with_checkpoint(Some(CheckpointPolicy::Periodic(1800.0)));
    assert_eq!(faulted_grid.len(), 24, "the fault axis replaces, not doubles");
    let (faulted_s, faulted) =
        best_of(reps, || run_sweep_streaming(&twin, &faulted_grid, threads));

    // Tier 7 (ISSUE 8): the distributed service on the same coupled
    // faulted grid. Each fleet size pays the whole service — TCP
    // accept, spec push, ring dispatch, JSON rows, slot merge — so the
    // 2-vs-1 ratio measures how well consistent-hash sharding scales
    // real sweep work, not an idealized kernel.
    let (dist1_s, dist1) = best_of(reps, || {
        twin.sweep_distributed(&faulted_grid, false, 1)
            .expect("1-worker distributed sweep")
    });
    let (dist2_s, dist2) = best_of(reps, || {
        twin.sweep_distributed(&faulted_grid, false, 2)
            .expect("2-worker distributed sweep")
    });
    let (dist4_s, dist4) = best_of(reps, || {
        twin.sweep_distributed(&faulted_grid, false, 4)
            .expect("4-worker distributed sweep")
    });

    // Byte-identity is the service's contract and is asserted in both
    // modes: sharding, the wire format and merge order are invisible.
    assert_eq!(faulted, dist1, "1-worker distributed sweep diverged");
    assert_eq!(faulted, dist2, "2-worker distributed sweep diverged");
    assert_eq!(faulted, dist4, "4-worker distributed sweep diverged");

    // ISSUE 9 multi-job probe: one persistent coordinator, one
    // 2-worker fleet, three copies of the grid submitted concurrently.
    // The elapsed time is the whole queue's makespan — accept, FIFO
    // dispatch, per-job merge and report delivery all on the clock.
    let sp = SweepSpec {
        grid: faulted_grid.clone(),
        routing: twin.net.routing,
        fork: false,
    };
    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind bench listener");
    let addr = listener.local_addr().expect("bench listener addr");
    let cfg = CoordinatorConfig {
        expect: 2,
        persist: true,
        queue_cap: 8,
        ..CoordinatorConfig::default()
    };
    let t0 = Instant::now();
    let (multi_reports, queue_stats) = thread::scope(|s| {
        let serve = s.spawn(|| serve_listener(listener, None, &cfg));
        for k in 0..2 {
            let mut wt = twin.clone();
            s.spawn(move || {
                let sock = TcpStream::connect(addr).expect("bench worker connect");
                run_worker(&mut wt, sock, &WorkerOptions::named(&format!("w{k}")))
                    .expect("bench worker")
            });
        }
        let subs: Vec<_> = (0..3)
            .map(|_| s.spawn(|| submit(addr, &sp, Duration::from_secs(60)).expect("bench submit")))
            .collect();
        let reports: Vec<CampaignReport> = subs.into_iter().map(|h| h.join().unwrap()).collect();
        drain(addr, Duration::from_secs(30)).expect("bench drain");
        let (_, stats) = serve.join().unwrap().expect("bench coordinator");
        (reports, stats)
    });
    let multi_s = t0.elapsed().as_secs_f64();
    for r in &multi_reports {
        assert_eq!(&faulted, r, "multi-job distributed sweep diverged");
    }
    assert_eq!(queue_stats.jobs_served, 3, "the queue did not serve all jobs");
    assert_eq!(queue_stats.workers_lost, 0, "a bench worker was convicted");
    let multi_jobs_per_s = 3.0 / multi_s;

    // ISSUE 9 churn probe: a 3-worker fleet where one member crashes
    // after its first ack. The service stats expose how long the loss
    // held its groups hostage (assignment → re-dispatch latency).
    let (churn_report, churn_stats) =
        run_distributed(&twin, &sp, 3, &[(0, 1)]).expect("churned distributed sweep");
    assert_eq!(faulted, churn_report, "churned distributed sweep diverged");
    assert_eq!(churn_stats.workers_lost, 1, "the scripted crash went unnoticed");

    // ISSUE 10 skew probe: a deliberately imbalanced forked grid — one
    // mix, five seeds, a clean and a heavy fault trace, so ten fork
    // groups of very uneven cost — whose pinned static ring layout
    // piles half the groups (most of them faulted) onto one worker. A
    // 4-worker fleet serves it under both dispatch modes: adaptive
    // pull-based LPT must beat static consistent-hash sharding on
    // makespan, and both reports must stay byte-identical to the
    // single-process forked oracle.
    let skew_faults = FaultTrace {
        seed: 11,
        duration_s: 86_400.0,
        node_mtbf_s: 2.0e5,
        repair_mean_s: 7_200.0,
        group: 32,
        ..FaultTrace::none()
    };
    let skew_grid = SweepGrid::new(
        vec![1, 2, 3, 4, 5],
        vec![None, Some(7.5), Some(6.0)],
        vec!["hpc".into()],
        jobs,
    )
    .expect("skew grid")
    .with_coupling(Coupling::full())
    .with_cap_time(cap_time)
    .with_fault_traces(vec![FaultTrace::none(), skew_faults]);
    let skew_groups = skew_grid.work_groups(true);
    assert_eq!(skew_groups.len(), 10, "5 seeds x 2 traces = 10 fork groups");
    // The probe only measures what it claims if the static layout
    // really is skewed: recompute the ring assignment and demand a hot
    // shard owning at least four of the ten groups.
    let mut skew_ring = HashRing::new(DEFAULT_REPLICAS);
    for k in 0..4 {
        skew_ring.add(&format!("w{k}"));
    }
    let skew_hot = (0..4)
        .map(|k| {
            let name = format!("w{k}");
            (0..skew_groups.len())
                .filter(|&g| skew_ring.assign_group(g) == Some(name.as_str()))
                .count()
        })
        .max()
        .unwrap_or(0);
    assert!(
        skew_hot >= 4,
        "static ring layout is too balanced ({skew_hot}/10 on the hottest \
         worker) for the skew probe to measure anything"
    );
    let (skew_oracle_s, skew_oracle) =
        best_of(reps, || run_sweep_forked(&twin, &skew_grid, threads));
    let skew_sp = SweepSpec {
        grid: skew_grid.clone(),
        routing: twin.net.routing,
        fork: true,
    };
    let time_fleet = |dispatch: DispatchMode| {
        let cfg = CoordinatorConfig {
            dispatch,
            ..CoordinatorConfig::default()
        };
        let mut best = f64::INFINITY;
        let mut out = None;
        for _ in 0..reps {
            let t0 = Instant::now();
            let pair = run_fleet(&twin, &skew_sp, 4, 1, &[], &cfg).expect("skew fleet");
            best = best.min(t0.elapsed().as_secs_f64());
            out = Some(pair);
        }
        let (report, stats) = out.expect("at least one rep");
        (best, report, stats)
    };
    let (skew_static_s, skew_static, _) = time_fleet(DispatchMode::Static);
    let (skew_adaptive_s, skew_adaptive, skew_stats) = time_fleet(DispatchMode::Adaptive);
    assert_eq!(skew_oracle, skew_static, "static skewed fleet diverged");
    assert_eq!(skew_oracle, skew_adaptive, "adaptive skewed fleet diverged");
    assert_eq!(
        skew_stats.starved_ticks, 0,
        "a worker idled while groups sat in the adaptive ready queue"
    );
    let skew_speedup = skew_static_s / skew_adaptive_s;

    // The faulted sweep must be a real failure campaign: kills landed,
    // every kill requeued (all jobs carry the periodic checkpoint), and
    // destroyed node-hours show up as goodput < 1.
    assert_eq!(faulted.stats.len(), 24);
    let killed: u64 = faulted.stats.iter().map(|s| s.killed).sum();
    let requeued: u64 = faulted.stats.iter().map(|s| s.requeued).sum();
    let wasted_nh: f64 = faulted.stats.iter().map(|s| s.wasted_node_h).sum();
    assert!(killed > 0, "the failure trace killed nothing");
    assert_eq!(requeued, killed, "periodic checkpoints requeue every kill");
    assert!(wasted_nh > 0.0, "kills destroyed no node-hours");
    assert!(
        faulted.stats.iter().all(|s| s.jobs == jobs),
        "a killed job never completed"
    );
    assert!(
        faulted.stats.iter().any(|s| s.goodput < 1.0),
        "wasted work did not dent goodput"
    );

    // Same numbers, different cost, again: the divergence tree may only
    // differ from its streaming baseline in the fork bookkeeping.
    assert_eq!(
        fork_base,
        forked.with_fork_counters_zeroed(),
        "forked sweep diverged from streaming on the deferred-cap grid"
    );
    let forks: u64 = forked.stats.iter().map(|s| s.forks).sum();
    let restores: u64 = forked.stats.iter().map(|s| s.restores).sum();
    assert_eq!(forks, 24, "every scenario should ride a shared prefix");
    assert_eq!(restores, 16, "8 groups of 3 caps: two restores per group");

    // The coupled sweep must be a real sweep: every scenario completed,
    // capped scenarios throttled, the coupled stretch shows up, and the
    // incremental engine actually elided re-time work.
    assert_eq!(coupled.stats.len(), 24);
    assert!(coupled.stats.iter().all(|s| s.jobs == jobs));
    let throttled: usize = coupled
        .stats
        .iter()
        .filter(|s| s.cap_mw.is_some())
        .map(|s| s.throttled)
        .sum();
    assert!(throttled > 0, "capped scenarios did not throttle");
    let max_stretch = coupled
        .stats
        .iter()
        .map(|s| s.p95_stretch)
        .fold(0.0f64, f64::max);
    assert!(max_stretch > 1.0, "coupling produced no stretch");
    let elided: u64 = coupled.stats.iter().map(|s| s.retimes_elided).sum();
    assert!(elided > 0, "the cell index elided no re-times");

    // Same numbers, different cost: the incremental streaming engine
    // and the retime-all join-then-merge baseline may only differ in
    // the elision counter.
    for (a, b) in coupled.stats.iter().zip(&oracle.stats) {
        assert_eq!(a.makespan_h, b.makespan_h, "engines diverged");
        assert_eq!(a.energy_mwh, b.energy_mwh, "engines diverged");
        assert_eq!(a.p95_stretch, b.p95_stretch, "engines diverged");
        assert_eq!(a.events_skipped, b.events_skipped, "engines diverged");
    }

    // The policy tier is a real sweep too, under the other policy.
    assert_eq!(spread.stats.len(), 24);
    for s in &spread.stats {
        assert_eq!(s.jobs, jobs);
        assert_eq!(s.policy, PolicyKind::SpreadLinks);
    }

    let per_s = |secs: f64| 24.0 / secs;
    let speedup_vs_oracle = oracle_s / coupled_s;
    let coupled_penalty = coupled_s / uncoupled_s;
    let spread_penalty = spread_s / coupled_s;
    let fork_speedup = fork_base_s / forked_s;
    let fault_penalty = faulted_s / coupled_s;
    let fleet2_speedup = dist1_s / dist2_s;
    let fleet4_speedup = dist1_s / dist4_s;
    println!(
        "campaign sweep: 24 scenarios x {jobs} jobs on {threads} threads\n\
         \x20 uncoupled streaming            {uncoupled_s:.2} s = {:.2} scenarios/s\n\
         \x20 coupled incremental streaming  {coupled_s:.2} s = {:.2} scenarios/s\n\
         \x20 coupled retime-all join-merge  {oracle_s:.2} s = {:.2} scenarios/s\n\
         \x20 coupled SpreadLinks streaming  {spread_s:.2} s = {:.2} scenarios/s\n\
         \x20 deferred-cap streaming         {fork_base_s:.2} s = {:.2} scenarios/s\n\
         \x20 deferred-cap forked            {forked_s:.2} s = {:.2} scenarios/s\n\
         \x20 coupled faulted streaming      {faulted_s:.2} s = {:.2} scenarios/s\n\
         \x20 distributed fleet x1           {dist1_s:.2} s = {:.2} scenarios/s\n\
         \x20 distributed fleet x2           {dist2_s:.2} s = {:.2} scenarios/s\n\
         \x20 distributed fleet x4           {dist4_s:.2} s = {:.2} scenarios/s\n\
         \x20 incremental vs PR 3 baseline   {speedup_vs_oracle:.2}x\n\
         \x20 coupled vs uncoupled           {coupled_penalty:.2}x\n\
         \x20 SpreadLinks vs PackFirst       {spread_penalty:.2}x\n\
         \x20 forked vs streaming            {fork_speedup:.2}x\n\
         \x20 faulted vs fault-free          {fault_penalty:.2}x\n\
         \x20 fleet x2 / x4 vs x1            {fleet2_speedup:.2}x / {fleet4_speedup:.2}x\n\
         \x20 3-job queue makespan           {multi_s:.2} s = {multi_jobs_per_s:.2} jobs/s\n\
         \x20 skew forked oracle             {skew_oracle_s:.2} s ({skew_hot}/10 groups on hot shard)\n\
         \x20 skew fleet x4 static           {skew_static_s:.2} s\n\
         \x20 skew fleet x4 adaptive         {skew_adaptive_s:.2} s\n\
         \x20 skew adaptive vs static        {skew_speedup:.2}x\n\
         \x20 churn reassign latency         {:.3} s mean / {:.3} s max ({} groups)\n\
         \x20 re-times elided                {elided}\n\
         \x20 prefix forks / restores        {forks} / {restores}\n\
         \x20 kills / requeues / wasted nh   {killed} / {requeued} / {wasted_nh:.1}",
        per_s(uncoupled_s),
        per_s(coupled_s),
        per_s(oracle_s),
        per_s(spread_s),
        per_s(fork_base_s),
        per_s(forked_s),
        per_s(faulted_s),
        per_s(dist1_s),
        per_s(dist2_s),
        per_s(dist4_s),
        churn_stats.reassign_latency_mean_s,
        churn_stats.reassign_latency_max_s,
        churn_stats.groups_reassigned,
    );
    println!("max p95 stretch across the grid: {max_stretch:.3}x nominal");

    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"campaign_throughput\",\n",
            "  \"grid\": \"4 seeds x 3 caps x 2 mixes (hpc+day)\",\n",
            "  \"smoke\": {},\n",
            "  \"jobs_per_scenario\": {},\n",
            "  \"threads\": {},\n",
            "  \"uncoupled_seconds\": {:.3},\n",
            "  \"uncoupled_scenarios_per_s\": {:.3},\n",
            "  \"coupled_seconds\": {:.3},\n",
            "  \"coupled_scenarios_per_s\": {:.3},\n",
            "  \"retime_all_seconds\": {:.3},\n",
            "  \"retime_all_scenarios_per_s\": {:.3},\n",
            "  \"spread_seconds\": {:.3},\n",
            "  \"spread_scenarios_per_s\": {:.3},\n",
            "  \"forked_baseline_seconds\": {:.3},\n",
            "  \"forked_baseline_scenarios_per_s\": {:.3},\n",
            "  \"forked_seconds\": {:.3},\n",
            "  \"forked_scenarios_per_s\": {:.3},\n",
            "  \"faulted_seconds\": {:.3},\n",
            "  \"faulted_scenarios_per_s\": {:.3},\n",
            "  \"incremental_speedup_vs_retime_all\": {:.3},\n",
            "  \"coupled_over_uncoupled\": {:.3},\n",
            "  \"spread_over_pack\": {:.3},\n",
            "  \"forked_speedup_vs_streaming\": {:.3},\n",
            "  \"faulted_over_fault_free\": {:.3},\n",
            "  \"retimes_elided\": {},\n",
            "  \"prefix_forks\": {},\n",
            "  \"snapshot_restores\": {},\n",
            "  \"jobs_killed\": {},\n",
            "  \"jobs_requeued\": {},\n",
            "  \"wasted_node_hours\": {:.3}\n",
            "}}\n"
        ),
        smoke,
        jobs,
        threads,
        uncoupled_s,
        per_s(uncoupled_s),
        coupled_s,
        per_s(coupled_s),
        oracle_s,
        per_s(oracle_s),
        spread_s,
        per_s(spread_s),
        fork_base_s,
        per_s(fork_base_s),
        forked_s,
        per_s(forked_s),
        faulted_s,
        per_s(faulted_s),
        speedup_vs_oracle,
        coupled_penalty,
        spread_penalty,
        fork_speedup,
        fault_penalty,
        elided,
        forks,
        restores,
        killed,
        requeued,
        wasted_nh,
    );
    match std::fs::write("BENCH_campaign.json", &json) {
        Ok(()) => println!("wrote BENCH_campaign.json"),
        Err(e) => eprintln!("warning: could not write BENCH_campaign.json: {e}"),
    }

    // The distributed-service trajectory rides in its own artifact so
    // the fleet-scaling history is diffable independently of the
    // single-process tiers.
    let dist_json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"campaign_distributed\",\n",
            "  \"grid\": \"4 seeds x 3 caps x 2 mixes (hpc+day), coupled + faulted\",\n",
            "  \"smoke\": {},\n",
            "  \"jobs_per_scenario\": {},\n",
            "  \"fleet1_seconds\": {:.3},\n",
            "  \"fleet1_scenarios_per_s\": {:.3},\n",
            "  \"fleet2_seconds\": {:.3},\n",
            "  \"fleet2_scenarios_per_s\": {:.3},\n",
            "  \"fleet4_seconds\": {:.3},\n",
            "  \"fleet4_scenarios_per_s\": {:.3},\n",
            "  \"fleet2_speedup_vs_fleet1\": {:.3},\n",
            "  \"fleet4_speedup_vs_fleet1\": {:.3},\n",
            "  \"multi_job_jobs\": {},\n",
            "  \"multi_job_seconds\": {:.3},\n",
            "  \"multi_job_jobs_per_s\": {:.3},\n",
            "  \"reassign_latency_mean_s\": {:.4},\n",
            "  \"reassign_latency_max_s\": {:.4},\n",
            "  \"churn_workers_lost\": {},\n",
            "  \"churn_groups_reassigned\": {},\n",
            "  \"skew_groups\": {},\n",
            "  \"skew_hot_static_groups\": {},\n",
            "  \"skew_oracle_seconds\": {:.3},\n",
            "  \"skew_static_seconds\": {:.3},\n",
            "  \"skew_adaptive_seconds\": {:.3},\n",
            "  \"skew_adaptive_speedup_vs_static\": {:.3},\n",
            "  \"skew_starved_ticks\": {},\n",
            "  \"reports_identical_to_streaming\": true\n",
            "}}\n"
        ),
        smoke,
        jobs,
        dist1_s,
        per_s(dist1_s),
        dist2_s,
        per_s(dist2_s),
        dist4_s,
        per_s(dist4_s),
        fleet2_speedup,
        fleet4_speedup,
        3,
        multi_s,
        multi_jobs_per_s,
        churn_stats.reassign_latency_mean_s,
        churn_stats.reassign_latency_max_s,
        churn_stats.workers_lost,
        churn_stats.groups_reassigned,
        skew_groups.len(),
        skew_hot,
        skew_oracle_s,
        skew_static_s,
        skew_adaptive_s,
        skew_speedup,
        skew_stats.starved_ticks,
    );
    match std::fs::write("BENCH_distributed.json", &dist_json) {
        Ok(()) => println!("wrote BENCH_distributed.json"),
        Err(e) => eprintln!("warning: could not write BENCH_distributed.json: {e}"),
    }

    // Acceptance gates (ISSUE 4): incremental >= 2x the PR 3 retime-all
    // baseline on the coupled grid, and coupled within 3x of uncoupled.
    // ISSUE 5 adds the policy tier: SpreadLinks placement overhead
    // within 1.5x of PackFirst scenario throughput. ISSUE 6 adds the
    // divergence tree: forked >= 2x streaming on the deferred-cap grid
    // (the shared prefix is ~90% of the day, so three cap levels cost
    // one prefix plus three short suffixes instead of three full days).
    // The smoke tier gates with headroom: its ratios come from
    // independently timed ~seconds-long runs on a shared CI runner, so
    // a stall in either tier alone moves the ratio — the strict numbers
    // are enforced at full scale, where the retiming volume dominates.
    // ISSUE 7 adds the faulted tier: kills, requeues and fault retimes
    // must stay within 2x of the fault-free streaming sweep.
    let (min_speedup, max_penalty, max_spread, min_fork_speedup, max_fault) = if smoke {
        (1.5, 4.0, 2.0, 1.5, 2.5)
    } else {
        (2.0, 3.0, 1.5, 2.0, 2.0)
    };
    assert!(
        speedup_vs_oracle >= min_speedup,
        "incremental coupled engine only {speedup_vs_oracle:.2}x the retime-all baseline \
         (gate: >= {min_speedup}x)"
    );
    assert!(
        coupled_penalty <= max_penalty,
        "coupled sweep {coupled_penalty:.2}x slower than uncoupled \
         (gate: within {max_penalty}x)"
    );
    assert!(
        spread_penalty <= max_spread,
        "SpreadLinks sweep {spread_penalty:.2}x slower than PackFirst \
         (gate: within {max_spread}x)"
    );
    assert!(
        fork_speedup >= min_fork_speedup,
        "forked sweep only {fork_speedup:.2}x the streaming baseline on the \
         deferred-cap grid (gate: >= {min_fork_speedup}x)"
    );
    assert!(
        fault_penalty <= max_fault,
        "faulted sweep {fault_penalty:.2}x slower than the fault-free streaming \
         tier (gate: within {max_fault}x)"
    );

    // ISSUE 8 gate, full scale only: the 2-worker fleet must reach
    // >= 1.6x the 1-worker fleet's throughput. The ring splits the 24
    // groups exactly 12/12, so the shortfall from 2.0x is pure service
    // overhead (connection setup, JSON rows, merge). The smoke grid is
    // too small to gate — a 1-second run is dominated by the fixed
    // per-fleet costs the full-scale run amortizes — but its reports
    // were still asserted byte-identical above.
    if !smoke {
        assert!(
            fleet2_speedup >= 1.6,
            "2-worker fleet only {fleet2_speedup:.2}x the 1-worker fleet \
             (gate: >= 1.6x)"
        );
    }

    // ISSUE 10 gate, both scales: on the skewed grid the adaptive pull
    // dispatcher must cut the makespan >= 1.4x vs static sharding
    // (>= 1.2x smoke — small grids leave fixed per-fleet costs on both
    // sides of the ratio). The hot static shard owns at least 4 of the
    // 10 groups, so the ideal LPT-vs-static ratio is >= 1.6x; the gate
    // leaves the rest for wire and merge overhead.
    let min_skew = if smoke { 1.2 } else { 1.4 };
    assert!(
        skew_speedup >= min_skew,
        "adaptive dispatch only {skew_speedup:.2}x static sharding on the \
         skewed grid (gate: >= {min_skew}x)"
    );
}
