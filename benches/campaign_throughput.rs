//! Campaign sweep throughput: scenarios/sec on the coupled 24-scenario
//! acceptance grid (4 seeds x 3 caps x 2 mixes), fanned across all
//! available cores.
//!
//! This is the perf trajectory of the *campaign* layer — the scheduler
//! bench (`BENCH_scheduler.json`) tracks the per-event hot path, this
//! one tracks the end-to-end scenario engine with runtime coupling on
//! (provisional-End retiming, congestion + cap feedback), which is the
//! configuration operators actually sweep. Results are written to
//! `BENCH_campaign.json`.
//!
//! `cargo bench --bench campaign_throughput -- --smoke` shrinks the
//! per-scenario day and runs one rep — the CI smoke that both gates the
//! coupled sweep end-to-end and emits the JSON artifact.

use std::time::Instant;

use leonardo_twin::campaign::{run_sweep, SweepGrid};
use leonardo_twin::coordinator::Twin;
use leonardo_twin::scheduler::Coupling;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let jobs = if smoke { 200 } else { 1_000 };
    let reps = if smoke { 1 } else { 3 };
    let threads = std::thread::available_parallelism().map_or(1, |n| n.get());

    let twin = Twin::leonardo();
    let grid = SweepGrid::new(
        vec![1, 2, 3, 4],
        vec![None, Some(7.5), Some(6.0)],
        vec!["day".into(), "ai".into()],
        jobs,
    )
    .expect("static grid")
    .with_coupling(Coupling::full());
    assert_eq!(grid.len(), 24, "the acceptance grid is 24 scenarios");

    let mut best = f64::INFINITY;
    let mut report = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = run_sweep(&twin, &grid, threads);
        best = best.min(t0.elapsed().as_secs_f64());
        report = Some(r);
    }
    let report = report.expect("at least one rep");

    // The coupled sweep must be a real sweep: every scenario completed,
    // capped scenarios throttled, and the coupled stretch shows up.
    assert_eq!(report.stats.len(), 24);
    assert!(report.stats.iter().all(|s| s.jobs == jobs));
    let throttled: usize = report
        .stats
        .iter()
        .filter(|s| s.cap_mw.is_some())
        .map(|s| s.throttled)
        .sum();
    assert!(throttled > 0, "capped scenarios did not throttle");
    let max_stretch = report
        .stats
        .iter()
        .map(|s| s.p95_stretch)
        .fold(0.0f64, f64::max);
    assert!(max_stretch > 1.0, "coupling produced no stretch");

    let scenarios_per_s = 24.0 / best;
    let jobs_per_s = (24 * jobs) as f64 / best;
    println!(
        "campaign sweep: 24 coupled scenarios x {jobs} jobs on {threads} threads \
         in {best:.2} s = {scenarios_per_s:.2} scenarios/s ({jobs_per_s:.0} jobs/s)"
    );
    println!("max p95 stretch across the grid: {max_stretch:.3}x nominal");

    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"campaign_throughput\",\n",
            "  \"grid\": \"4 seeds x 3 caps x 2 mixes (coupled)\",\n",
            "  \"smoke\": {},\n",
            "  \"jobs_per_scenario\": {},\n",
            "  \"threads\": {},\n",
            "  \"seconds\": {:.3},\n",
            "  \"scenarios_per_s\": {:.3},\n",
            "  \"jobs_per_s\": {:.1}\n",
            "}}\n"
        ),
        smoke, jobs, threads, best, scenarios_per_s, jobs_per_s
    );
    match std::fs::write("BENCH_campaign.json", &json) {
        Ok(()) => println!("wrote BENCH_campaign.json"),
        Err(e) => eprintln!("warning: could not write BENCH_campaign.json: {e}"),
    }
}
