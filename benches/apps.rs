//! Bench for Table 6: the application benchmark campaign (workload models
//! + scheduler placement + network + power composition).

use leonardo_twin::util::bench::{black_box, Criterion};
use leonardo_twin::coordinator::Twin;
use leonardo_twin::workloads::AppBenchmark;

fn bench(c: &mut Criterion) {
    let twin = Twin::leonardo();
    println!("{}", twin.table6().unwrap().to_console());

    c.bench_function("table6/full_campaign", |b| {
        b.iter(|| black_box(&twin).table6().unwrap())
    });
    c.bench_function("table6/single_app_scaling_sweep", |b| {
        let app = AppBenchmark::milc();
        b.iter(|| {
            let mut acc = 0.0;
            for n in [12u32, 24, 48, 96, 192] {
                let placement = twin.place(n).unwrap();
                let tts = app.tts(n, &twin.net, &placement);
                acc += tts + app.ets(n, tts, &twin.power);
            }
            acc
        })
    });
}

fn main() {
    let mut c = Criterion::default();
    bench(&mut c);
}
