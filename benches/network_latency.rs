//! Bench for the §2.2 latency budget and the network simulator hot path
//! (routing + effective-bandwidth computation drive every scaling bench).

use leonardo_twin::util::bench::{black_box, Criterion};
use leonardo_twin::config::MachineConfig;
use leonardo_twin::coordinator::Twin;
use leonardo_twin::network::{Network, Placement};
use leonardo_twin::topology::{Routing, Topology};

fn bench(c: &mut Criterion) {
    println!("{}", Twin::leonardo().latency_table().to_console());

    let cfg = MachineConfig::leonardo();
    let topo = Topology::build(&cfg);
    let net = Network::new(topo.clone(), 400.0);

    c.bench_function("network/route_minimal", |b| {
        b.iter(|| topo.route(black_box(0), black_box(4000), Routing::Minimal))
    });
    c.bench_function("network/route_valiant", |b| {
        b.iter(|| topo.route(black_box(17), black_box(4900), Routing::Valiant))
    });
    c.bench_function("network/p2p_1mib", |b| {
        b.iter(|| net.p2p_time(black_box(0), black_box(2000), 1 << 20))
    });
    let placement = Placement {
        nodes_per_cell: (0..8).map(|c| (c, 256)).collect(),
    };
    c.bench_function("network/effective_bw_8cells", |b| {
        b.iter(|| net.effective_node_bw(black_box(&placement)))
    });
    c.bench_function("network/halo_exchange", |b| {
        b.iter(|| net.halo_exchange_time(black_box(&placement), 6, 5 << 20))
    });
    c.bench_function("network/allreduce_2048", |b| {
        b.iter(|| net.allreduce_time(black_box(&placement), 1 << 20))
    });
}

fn main() {
    let mut c = Criterion::default();
    bench(&mut c);
}
