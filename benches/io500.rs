//! Bench for Table 5: the IO500 workload engine.

use leonardo_twin::util::bench::{black_box, Criterion};
use leonardo_twin::coordinator::Twin;
use leonardo_twin::storage::{io500, StorageSystem};

fn bench(c: &mut Criterion) {
    println!("{}", Twin::leonardo().table5().to_console());

    c.bench_function("io500/full_run", |b| {
        b.iter(|| black_box(io500::run_leonardo()).score)
    });

    let sys = StorageSystem::leonardo();
    let scratch = sys.namespace("/scratch").unwrap();
    c.bench_function("io500/client_sweep", |b| {
        b.iter(|| {
            let mut acc = 0.0;
            for clients in [4u32, 16, 64, 256] {
                acc += io500::run(
                    black_box(scratch),
                    io500::Io500Config {
                        client_nodes: clients,
                        client_link_gbs: 45.0,
                    },
                )
                .score;
            }
            acc
        })
    });
}

fn main() {
    let mut c = Criterion::default();
    bench(&mut c);
}
