//! Bench for Table 4: HPL/HPCG/Green500 models, plus the *real* DGEMM
//! kernel through PJRT when artifacts are available (the calibration
//! that ties the model to measured execution).

use leonardo_twin::util::bench::{black_box, Criterion};
use leonardo_twin::coordinator::Twin;
use leonardo_twin::hardware::NodeSpec;
use leonardo_twin::perfmodel::{HpcgModel, HplModel};
use leonardo_twin::runtime::{literal_f32, Engine};

fn bench(c: &mut Criterion) {
    println!("{}", Twin::leonardo().table4(None).to_console());

    let hpl = HplModel::new(NodeSpec::davinci());
    let hpcg = HpcgModel::new(NodeSpec::davinci());
    c.bench_function("table4/hpl_model_sweep", |b| {
        b.iter(|| {
            let mut acc = 0.0;
            for n in [64u32, 256, 1024, 3300, 3456] {
                acc += hpl.rmax(black_box(n)) + hpcg.rate(n);
            }
            acc
        })
    });

    // Real kernel: blocked Pallas DGEMM via PJRT (skipped without artifacts).
    if let Ok(engine) = Engine::load(Engine::default_dir()) {
        let n = 256usize;
        let inputs = [
            literal_f32(&vec![1.0f32; n * n], &[n, n]).unwrap(),
            literal_f32(&vec![0.5f32; n * n], &[n, n]).unwrap(),
        ];
        let _ = engine.execute("dgemm_256", &inputs).unwrap(); // compile
        let mut group = c.benchmark_group("table4/pjrt");
        group.sample_size(10);
        group.bench_function("dgemm_256", |bch| {
            bch.iter(|| engine.execute("dgemm_256", black_box(&inputs)).unwrap())
        });
        group.finish();
    } else {
        eprintln!("artifacts/ missing — run `make artifacts` for PJRT benches");
    }
}

fn main() {
    let mut c = Criterion::default();
    bench(&mut c);
}
