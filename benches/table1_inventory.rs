//! Bench for Table 1: machine construction + inventory derivation.
//! Regenerates the paper's rack/cell/node census and measures how fast
//! the config layer assembles the full 155-rack machine description.

use leonardo_twin::util::bench::{black_box, Criterion};
use leonardo_twin::config::MachineConfig;
use leonardo_twin::coordinator::Twin;

fn bench(c: &mut Criterion) {
    // Print the regenerated table once, like the paper prints it.
    println!("{}", Twin::leonardo().table1().to_console());

    c.bench_function("table1/build_machine", |b| {
        b.iter(|| black_box(MachineConfig::leonardo()).total_nodes())
    });
    c.bench_function("table1/derive_inventory", |b| {
        let cfg = MachineConfig::leonardo();
        b.iter(|| black_box(&cfg).table1())
    });
}

fn main() {
    let mut c = Criterion::default();
    bench(&mut c);
}
