//! Bench for Table 7 / Fig 5: the LBM weak-scaling campaign, plus the
//! *real* Pallas LBM kernel through PJRT when artifacts are available.

use leonardo_twin::util::bench::{black_box, Criterion};
use leonardo_twin::coordinator::{equilibrium_f32, Twin};
use leonardo_twin::lbm::{LbmConfig, LbmDriver, TABLE7_NODES};
use leonardo_twin::runtime::{literal_f32, Engine};

fn bench(c: &mut Criterion) {
    let twin = Twin::leonardo();
    println!("{}", twin.table7(None).unwrap().to_console());
    println!("{}", twin.fig5().unwrap().to_console());

    let node = twin.cfg.gpu_node_spec().unwrap().clone();
    c.bench_function("table7/full_sweep", |b| {
        let driver = LbmDriver::new(&node, &twin.net, LbmConfig::default());
        b.iter(|| driver.sweep(black_box(TABLE7_NODES), |n| twin.place(n)).unwrap())
    });
    c.bench_function("fig5/both_machines", |b| {
        b.iter(|| black_box(&twin).fig5())
    });

    // Real kernel: one D3Q19 step (32^3) via PJRT.
    if let Ok(engine) = Engine::load(Engine::default_dir()) {
        let f = literal_f32(&equilibrium_f32(32), &[19, 32, 32, 32]).unwrap();
        let omega = literal_f32(&[1.2f32], &[1]).unwrap();
        let inputs = [f, omega];
        let _ = engine.execute("lbm_step_32", &inputs).unwrap(); // compile
        let mut group = c.benchmark_group("table7/pjrt");
        group.sample_size(10);
        group.bench_function("lbm_step_32", |bch| {
            bch.iter(|| engine.execute("lbm_step_32", black_box(&inputs)).unwrap())
        });
        group.bench_function("lbm_steps8_32", |bch| {
            let _ = engine.execute("lbm_steps8_32", &inputs).unwrap();
            bch.iter(|| engine.execute("lbm_steps8_32", black_box(&inputs)).unwrap())
        });
        group.finish();
    } else {
        eprintln!("artifacts/ missing — run `make artifacts` for PJRT benches");
    }
}

fn main() {
    let mut c = Criterion::default();
    bench(&mut c);
}
