//! Monitoring and energy telemetry (paper §2.5-2.6): the Atos SMC
//! xScale / Prometheus-style metric pipeline, the Bull Energy Optimizer's
//! IPMI/SNMP time-profile logging, and a Parastation-HealthChecker-like
//! node health framework.
//!
//! Everything is virtual-time and deterministic so campaign runs are
//! exactly reproducible: the scheduler/power layers push samples, the
//! [`MetricStore`] aggregates them, and reports (energy profiles, PUE
//! accounting, health summaries) come out as [`crate::metrics::Table`]s.
//!
//! [`EventCounter`] subscribes to the shared [`crate::sim`] event stream
//! and scrapes queue/running gauges per event — utilization series come
//! out of the simulation itself rather than being reconstructed from job
//! records afterwards.

use std::collections::BTreeMap;

use crate::metrics::{f1, f2, Table};
use crate::sim::{Component, Event, ScheduledEvent};

/// One time-stamped sample of a named series.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    pub t: f64,
    pub value: f64,
}

/// An append-only time series (samples must arrive in time order, the
/// way a scrape loop produces them).
#[derive(Debug, Clone, Default)]
pub struct Series {
    samples: Vec<Sample>,
}

impl Series {
    pub fn push(&mut self, t: f64, value: f64) {
        if let Some(last) = self.samples.last() {
            assert!(t >= last.t, "out-of-order sample: {t} after {}", last.t);
        }
        self.samples.push(Sample { t, value });
    }

    /// Drop all samples, keeping the buffer allocated (arena reuse).
    pub fn clear(&mut self) {
        self.samples.clear();
    }

    /// Rewind the series to its first `len` samples, keeping the buffer
    /// allocated — the restore half of a snapshot mark.
    pub fn truncate(&mut self, len: usize) {
        self.samples.truncate(len);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn last(&self) -> Option<Sample> {
        self.samples.last().copied()
    }

    /// The raw sample sequence, time-ordered.
    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().fold(f64::NEG_INFINITY, |m, s| m.max(s.value))
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|s| s.value).sum::<f64>() / self.samples.len() as f64
    }

    /// Trapezoidal integral over time — watts in, joules out.
    pub fn integral(&self) -> f64 {
        self.samples
            .windows(2)
            .map(|w| 0.5 * (w[0].value + w[1].value) * (w[1].t - w[0].t))
            .sum()
    }

    /// Left-constant step integral over time — exact for event-sampled
    /// gauges that hold their value until the next sample (the power
    /// monitor's piecewise-constant facility draw: each sample opens a
    /// rate segment that lasts until the next Start/End/Retime).
    pub fn step_integral(&self) -> f64 {
        self.samples
            .windows(2)
            .map(|w| w[0].value * (w[1].t - w[0].t))
            .sum()
    }
}

/// The metric store: named series, Prometheus-flavoured.
#[derive(Debug, Clone, Default)]
pub struct MetricStore {
    series: BTreeMap<String, Series>,
}

impl MetricStore {
    pub fn record(&mut self, name: &str, t: f64, value: f64) {
        self.series.entry(name.to_string()).or_default().push(t, value);
    }

    /// Clear every series' samples, keeping names and buffers allocated
    /// (arena reuse across campaign scenarios).
    pub fn reset(&mut self) {
        for s in self.series.values_mut() {
            s.clear();
        }
    }

    /// Save a snapshot mark: the current length of every series, in key
    /// order, into a caller-retained buffer (cleared and reused — no
    /// fresh allocation once the name strings are warm).
    pub fn save_marks(&self, marks: &mut Vec<(String, usize)>) {
        // Reuse the existing String allocations where possible by
        // overwriting in place before truncating/extending.
        for (i, (name, s)) in self.series.iter().enumerate() {
            if let Some(slot) = marks.get_mut(i) {
                slot.0.clear();
                slot.0.push_str(name);
                slot.1 = s.len();
            } else {
                marks.push((name.clone(), s.len()));
            }
        }
        marks.truncate(self.series.len());
    }

    /// Rewind every series to a mark saved by
    /// [`MetricStore::save_marks`]. Series created after the mark (no
    /// entry) are cleared; both mark list and store iterate in key
    /// order, so one parallel walk suffices.
    pub fn restore_marks(&mut self, marks: &[(String, usize)]) {
        let mut it = marks.iter().peekable();
        for (name, s) in &mut self.series {
            match it.peek() {
                Some((mark_name, len)) if mark_name == name => {
                    s.truncate(*len);
                    it.next();
                }
                _ => s.clear(),
            }
        }
        debug_assert!(it.peek().is_none(), "snapshot mark for a vanished series");
    }

    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.series.keys().map(|s| s.as_str()).collect()
    }

    /// Energy (kWh) of a power series logged in watts.
    pub fn energy_kwh(&self, name: &str) -> f64 {
        self.get(name).map_or(0.0, |s| s.integral() / 3.6e6)
    }

    /// Energy (kWh) of a piecewise-constant power series logged in
    /// watts: the step integral, exact for event-sampled draws that
    /// hold their level between samples.
    pub fn step_energy_kwh(&self, name: &str) -> f64 {
        self.get(name).map_or(0.0, |s| s.step_integral() / 3.6e6)
    }

    /// The Bull Energy Optimizer report: per-series mean/max/integral.
    pub fn energy_report(&self) -> Table {
        let mut t = Table::new(
            "Energy telemetry (Bull Energy Optimizer analogue)",
            &["Series", "Samples", "Mean", "Max", "Energy [kWh]"],
        );
        for (name, s) in &self.series {
            t.row(vec![
                name.clone(),
                s.len().to_string(),
                f1(s.mean()),
                f1(s.max()),
                f2(s.integral() / 3.6e6),
            ]);
        }
        t
    }
}

/// Health states the checker reports (Parastation HealthChecker model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Ok,
    Degraded,
    Failed,
}

/// A health check over node telemetry.
pub struct HealthCheck {
    pub name: &'static str,
    /// (metric name, warn threshold, fail threshold); value above warn
    /// => Degraded, above fail => Failed.
    pub metric: &'static str,
    pub warn: f64,
    pub fail: f64,
}

impl HealthCheck {
    /// LEONARDO's §2.6 operating envelope: warm-water inlet at 37 C,
    /// GPUs capped by DCGM when the energy threshold is passed.
    pub fn standard_set() -> Vec<HealthCheck> {
        vec![
            HealthCheck {
                name: "gpu-temperature",
                metric: "gpu_temp_c",
                warn: 85.0,
                fail: 95.0,
            },
            HealthCheck {
                name: "coolant-inlet",
                metric: "inlet_temp_c",
                warn: 40.0,
                fail: 45.0,
            },
            HealthCheck {
                name: "node-power",
                metric: "node_power_w",
                warn: 2400.0,
                fail: 2800.0,
            },
            HealthCheck {
                name: "ib-link-errors",
                metric: "ib_symbol_errors_per_s",
                warn: 1.0,
                fail: 100.0,
            },
        ]
    }

    pub fn evaluate(&self, store: &MetricStore) -> Health {
        let Some(series) = store.get(self.metric) else {
            return Health::Ok; // no data, no alarm (scrape gap)
        };
        let Some(last) = series.last() else {
            return Health::Ok;
        };
        if last.value >= self.fail {
            Health::Failed
        } else if last.value >= self.warn {
            Health::Degraded
        } else {
            Health::Ok
        }
    }
}

/// Run the standard check set and summarise.
pub fn health_summary(store: &MetricStore) -> (Table, Health) {
    let mut worst = Health::Ok;
    let mut t = Table::new(
        "Node health (Parastation HealthChecker analogue)",
        &["Check", "Metric", "Last", "State"],
    );
    for check in HealthCheck::standard_set() {
        let state = check.evaluate(store);
        if state == Health::Failed
            || (state == Health::Degraded && worst == Health::Ok)
        {
            worst = state;
        }
        let last = store
            .get(check.metric)
            .and_then(Series::last)
            .map(|s| f1(s.value))
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            check.name.to_string(),
            check.metric.to_string(),
            last,
            format!("{state:?}"),
        ]);
    }
    (t, worst)
}

/// Prometheus-style scheduler gauges scraped from the event stream: a
/// [`Component`] that samples cumulative job counts, queue depth and
/// running jobs at every `Submit`/`Start`/`End`.
#[derive(Debug, Clone, Default)]
pub struct EventCounter {
    pub store: MetricStore,
    submitted: u64,
    started: u64,
    ended: u64,
    killed: u64,
    /// Nodes currently failed (gauge): NodeDown raises it, NodeUp lowers
    /// it. Fault traces always pair the two with equal counts, so the
    /// saturating arithmetic only matters for hand-crafted streams.
    nodes_down: u64,
    /// Internal snapshot slot ([`Component::snapshot`]): counter values
    /// plus per-series length marks, buffers reused across snapshots.
    snap: Option<Box<CounterSnapshot>>,
}

/// Saved [`EventCounter`] state: the lifecycle totals and a length mark
/// per store series (restore truncates rather than copies samples).
#[derive(Debug, Clone, Default)]
struct CounterSnapshot {
    submitted: u64,
    started: u64,
    ended: u64,
    killed: u64,
    nodes_down: u64,
    marks: Vec<(String, usize)>,
}

impl EventCounter {
    /// (submitted, started, ended) totals so far.
    pub fn totals(&self) -> (u64, u64, u64) {
        (self.submitted, self.started, self.ended)
    }

    /// (jobs killed by faults, nodes currently down) so far.
    pub fn fault_totals(&self) -> (u64, u64) {
        (self.killed, self.nodes_down)
    }

    fn sample(&mut self, now: f64) {
        self.store
            .record("jobs_submitted_total", now, self.submitted as f64);
        self.store.record(
            "queue_depth",
            now,
            (self.submitted - self.started) as f64,
        );
        // A killed incarnation left the machine without an End; stale
        // Ends of killed generations are vetoed at pop time, so every
        // dispatched End is a real completion and the subtraction never
        // underflows. Fault-free runs have killed == 0: byte-identical.
        self.store.record(
            "running_jobs",
            now,
            (self.started - self.ended - self.killed) as f64,
        );
    }
}

impl Component for EventCounter {
    fn on_event(&mut self, now: f64, ev: &Event, _out: &mut Vec<ScheduledEvent>) {
        match ev {
            Event::Submit { .. } => self.submitted += 1,
            Event::Start { .. } => self.started += 1,
            Event::End { .. } => self.ended += 1,
            // A fault killed a running incarnation: the running gauge
            // drops, and the kill total gets its own series. The series
            // is only created on the first kill, so fault-free reports
            // list exactly the series they always did.
            Event::Kill { .. } => {
                self.killed += 1;
                self.store
                    .record("jobs_killed_total", now, self.killed as f64);
            }
            // Failed-capacity gauge, sampled on the fault events
            // themselves (which only exist in faulted runs).
            Event::NodeDown { nodes, .. } => {
                self.nodes_down = self.nodes_down.saturating_add(u64::from(*nodes));
                self.store.record("nodes_down", now, self.nodes_down as f64);
                return;
            }
            Event::NodeUp { nodes, .. } => {
                self.nodes_down = self.nodes_down.saturating_sub(u64::from(*nodes));
                self.store.record("nodes_down", now, self.nodes_down as f64);
                return;
            }
            // Not job lifecycle: cap moves, provisional-End re-times and
            // link-health episodes change rates, not job counts.
            Event::CapChange { .. }
            | Event::Retime { .. }
            | Event::LinkDegraded { .. }
            | Event::LinkRestored { .. } => return,
        }
        self.sample(now);
    }

    fn snapshot(&mut self) {
        let mut snap = self.snap.take().unwrap_or_default();
        snap.submitted = self.submitted;
        snap.started = self.started;
        snap.ended = self.ended;
        snap.killed = self.killed;
        snap.nodes_down = self.nodes_down;
        self.store.save_marks(&mut snap.marks);
        self.snap = Some(snap);
    }

    fn restore(&mut self) {
        let snap = self
            .snap
            .take()
            .expect("EventCounter::restore without a prior snapshot");
        self.submitted = snap.submitted;
        self.started = snap.started;
        self.ended = snap.ended;
        self.killed = snap.killed;
        self.nodes_down = snap.nodes_down;
        self.store.restore_marks(&snap.marks);
        self.snap = Some(snap);
    }
}

/// Log a job's power profile into the store, sampling every `dt` seconds
/// — what the IPMI/SNMP collectors do on the real machine.
pub fn log_job_power(
    store: &mut MetricStore,
    series: &str,
    start: f64,
    end: f64,
    watts: f64,
    dt: f64,
) {
    let mut t = start;
    while t < end {
        store.record(series, t, watts);
        t += dt;
    }
    store.record(series, end, watts);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_integral_is_trapezoidal() {
        let mut s = Series::default();
        s.push(0.0, 100.0);
        s.push(10.0, 100.0);
        assert!((s.integral() - 1000.0).abs() < 1e-9);
        s.push(20.0, 0.0); // ramp down
        assert!((s.integral() - 1500.0).abs() < 1e-9);
    }

    #[test]
    fn series_step_integral_is_left_constant() {
        let mut s = Series::default();
        s.push(0.0, 100.0);
        s.push(10.0, 100.0);
        assert!((s.step_integral() - 1000.0).abs() < 1e-9);
        // A step down at t=10 contributes nothing over (10, 20] at the
        // old level — unlike the trapezoid, which would average.
        s.push(20.0, 0.0);
        assert!((s.step_integral() - 2000.0).abs() < 1e-9);
        let mut store = MetricStore::default();
        store.record("p", 0.0, 3.6e6);
        store.record("p", 1.0, 0.0);
        assert!((store.step_energy_kwh("p") - 1.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "out-of-order")]
    fn series_rejects_time_travel() {
        let mut s = Series::default();
        s.push(5.0, 1.0);
        s.push(4.0, 1.0);
    }

    #[test]
    fn energy_kwh_of_constant_load() {
        let mut store = MetricStore::default();
        // 2238 W for one hour = 2.238 kWh.
        log_job_power(&mut store, "node0_power_w", 0.0, 3600.0, 2238.0, 60.0);
        let kwh = store.energy_kwh("node0_power_w");
        assert!((kwh - 2.238).abs() < 1e-6, "{kwh}");
    }

    #[test]
    fn health_thresholds() {
        let mut store = MetricStore::default();
        store.record("gpu_temp_c", 0.0, 70.0);
        let (_, h) = health_summary(&store);
        assert_eq!(h, Health::Ok);
        store.record("gpu_temp_c", 1.0, 88.0);
        let (_, h) = health_summary(&store);
        assert_eq!(h, Health::Degraded);
        store.record("gpu_temp_c", 2.0, 96.0);
        let (table, h) = health_summary(&store);
        assert_eq!(h, Health::Failed);
        assert_eq!(table.rows.len(), 4);
    }

    #[test]
    fn missing_metric_is_not_an_alarm() {
        let store = MetricStore::default();
        let (_, h) = health_summary(&store);
        assert_eq!(h, Health::Ok);
    }

    #[test]
    fn report_table_lists_all_series() {
        let mut store = MetricStore::default();
        store.record("a", 0.0, 1.0);
        store.record("b", 0.0, 2.0);
        let t = store.energy_report();
        assert_eq!(t.rows.len(), 2);
        assert_eq!(store.names(), vec!["a", "b"]);
    }

    #[test]
    fn store_marks_rewind_series_and_clear_latecomers() {
        let mut store = MetricStore::default();
        store.record("a", 0.0, 1.0);
        store.record("b", 0.0, 2.0);
        store.record("b", 1.0, 3.0);
        let mut marks = Vec::new();
        store.save_marks(&mut marks);
        assert_eq!(marks, vec![("a".into(), 1), ("b".into(), 2)]);
        // Perturb: extend both, create a series unseen at mark time.
        store.record("a", 5.0, 9.0);
        store.record("b", 5.0, 9.0);
        store.record("zz_new", 5.0, 9.0);
        store.restore_marks(&marks);
        assert_eq!(store.get("a").unwrap().len(), 1);
        assert_eq!(store.get("b").unwrap().len(), 2);
        assert_eq!(store.get("b").unwrap().last().unwrap().value, 3.0);
        assert!(store.get("zz_new").unwrap().is_empty());
        // Saving again reuses the mark buffer and sees the cleared
        // latecomer at length zero.
        store.save_marks(&mut marks);
        assert_eq!(
            marks,
            vec![("a".into(), 1), ("b".into(), 2), ("zz_new".into(), 0)]
        );
    }

    #[test]
    fn event_counter_snapshot_restores_totals_and_gauges() {
        let mut out = Vec::new();
        let mut c = EventCounter::default();
        c.on_event(0.0, &Event::Submit { job: 1 }, &mut out);
        c.snapshot();
        c.on_event(1.0, &Event::Submit { job: 2 }, &mut out);
        c.on_event(
            1.0,
            &Event::Start {
                job: 1,
                booster: true,
                dvfs_scale: 1.0,
                cells: vec![(0, 8)].into(),
            },
            &mut out,
        );
        c.restore();
        assert_eq!(c.totals(), (1, 0, 0));
        assert_eq!(c.store.get("queue_depth").unwrap().len(), 1);
        // The replayed suffix matches what the snapshot saw.
        c.on_event(1.0, &Event::Submit { job: 2 }, &mut out);
        assert_eq!(c.totals(), (2, 0, 0));
        assert_eq!(c.store.get("queue_depth").unwrap().last().unwrap().value, 2.0);
    }

    #[test]
    fn event_counter_scrapes_lifecycle_gauges() {
        let mut out = Vec::new();
        let mut c = EventCounter::default();
        c.on_event(0.0, &Event::Submit { job: 1 }, &mut out);
        c.on_event(0.0, &Event::Submit { job: 2 }, &mut out);
        c.on_event(
            0.0,
            &Event::Start {
                job: 1,
                booster: true,
                dvfs_scale: 1.0,
                cells: vec![(0, 8)].into(),
            },
            &mut out,
        );
        c.on_event(
            5.0,
            &Event::End {
                job: 1,
                booster: true,
                cells: vec![(0, 8)].into(),
                gen: 0,
            },
            &mut out,
        );
        assert_eq!(c.totals(), (2, 1, 1));
        let depth = c.store.get("queue_depth").unwrap();
        assert_eq!(depth.last().unwrap().value, 1.0);
        let running = c.store.get("running_jobs").unwrap();
        assert_eq!(running.last().unwrap().value, 0.0);
        // Cap changes are not job lifecycle: no sample.
        let before = depth.len();
        c.on_event(6.0, &Event::CapChange { cap_mw: None }, &mut out);
        assert_eq!(c.store.get("queue_depth").unwrap().len(), before);
        assert!(out.is_empty(), "observer pushed no events");
    }

    #[test]
    fn fault_events_move_kill_and_down_gauges() {
        let mut out = Vec::new();
        let mut c = EventCounter::default();
        let cells: crate::sim::Cells = vec![(0u32, 8u32)].into();
        c.on_event(0.0, &Event::Submit { job: 1 }, &mut out);
        c.on_event(
            0.0,
            &Event::Start {
                job: 1,
                booster: true,
                dvfs_scale: 1.0,
                cells: cells.clone(),
            },
            &mut out,
        );
        // Fault-free so far: no fault series exist yet.
        assert!(c.store.get("jobs_killed_total").is_none());
        assert!(c.store.get("nodes_down").is_none());
        c.on_event(1.0, &Event::NodeDown { cell: 0, nodes: 8 }, &mut out);
        c.on_event(
            1.0,
            &Event::Kill {
                job: 1,
                booster: true,
                cells,
                wasted_s: 1.0,
                requeued: false,
            },
            &mut out,
        );
        assert_eq!(c.fault_totals(), (1, 8));
        assert_eq!(
            c.store.get("running_jobs").unwrap().last().unwrap().value,
            0.0,
            "kill drains the running gauge"
        );
        assert_eq!(
            c.store.get("nodes_down").unwrap().last().unwrap().value,
            8.0
        );
        c.on_event(2.0, &Event::NodeUp { cell: 0, nodes: 8 }, &mut out);
        assert_eq!(c.fault_totals().1, 0);
        // Link episodes touch no counters.
        let samples = c.store.get("nodes_down").unwrap().len();
        c.on_event(
            3.0,
            &Event::LinkDegraded {
                bundle: 0,
                factor: 0.5,
            },
            &mut out,
        );
        c.on_event(3.0, &Event::LinkRestored { bundle: 0 }, &mut out);
        assert_eq!(c.store.get("nodes_down").unwrap().len(), samples);
        assert!(out.is_empty(), "observer pushed no events");
    }
}
