//! Performance models: rooflines and the analytic HPL/HPCG models behind
//! Table 4, calibrated against the paper's TOP500 submission and fed by
//! *measured* kernel rates from the PJRT runtime (see
//! [`crate::coordinator`]).
//!
//! Model constants and where they come from:
//! * `GEMM_EFFICIENCY` = 0.85 — sustained DGEMM / peak FP64-TC on the
//!   A100 (datasheet-class; also what our Pallas GEMM achieves against
//!   its own roofline, see EXPERIMENTS.md §Perf);
//! * HPL communication decay `E0 - A ln(P)/ln(4096)` — the weak
//!   logarithmic panel-broadcast overhead of blocked LU once N grows as
//!   sqrt(P) (memory-filled runs); fit to the single published point
//!   (238.7 PF at 3300 nodes) and validated against Rpeak/Rmax = 0.784;
//! * HPCG arithmetic intensity 0.25 flop/byte x 0.575 HBM efficiency —
//!   the 27-point stencil's f64 SpMV byte traffic and the fraction of
//!   HBM bandwidth a latency-bound SpMV sustains.



use crate::hardware::{NodeSpec, Precision};

/// Sustained-DGEMM fraction of tensor-core FP64 peak.
pub const GEMM_EFFICIENCY: f64 = 0.85;
/// HPL network-efficiency fit: E(P) = E0 - A * ln(P)/ln(4096).
pub const HPL_E0: f64 = 0.975;
pub const HPL_DECAY: f64 = 0.025;
/// HPCG: effective flop/byte of the f64 27-point SpMV.
pub const HPCG_AI: f64 = 0.25;
/// Fraction of HBM bandwidth a latency-bound SpMV sustains.
pub const HPCG_MEM_EFF: f64 = 0.575;

/// A simple roofline.
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    pub peak_flops: f64,
    pub mem_bw_bytes: f64,
}

impl Roofline {
    /// Attainable FLOPS at arithmetic intensity `ai` (flop/byte).
    pub fn attainable(&self, ai: f64) -> f64 {
        self.peak_flops.min(ai * self.mem_bw_bytes)
    }

    /// The ridge point (flop/byte) where compute takes over.
    pub fn ridge(&self) -> f64 {
        self.peak_flops / self.mem_bw_bytes
    }
}

/// HPL performance model over a GPU node fleet.
#[derive(Debug, Clone)]
pub struct HplModel {
    pub node: NodeSpec,
}

impl HplModel {
    pub fn new(node: NodeSpec) -> Self {
        HplModel { node }
    }

    /// Per-node FP64 peak used for Rpeak accounting (tensor-core DMMA on
    /// Ampere; plain FP64 on Volta).
    pub fn node_peak_flops(&self) -> f64 {
        let g = self.node.gpu.as_ref().expect("HPL model needs GPUs");
        let per_gpu = g
            .peak_flops(Precision::Fp64TensorCore)
            .or_else(|| g.peak_flops(Precision::Fp64))
            .unwrap();
        per_gpu * self.node.gpus as f64
            + self.node.cpu.peak_fp64_flops() * self.node.cpu_sockets as f64
    }

    /// Theoretical Rpeak for `nodes` nodes, FLOPS.
    pub fn rpeak(&self, nodes: u32) -> f64 {
        nodes as f64 * self.node_peak_flops()
    }

    /// Network efficiency at scale.
    pub fn network_efficiency(&self, nodes: u32) -> f64 {
        if nodes <= 1 {
            return HPL_E0;
        }
        (HPL_E0 - HPL_DECAY * (nodes as f64).ln() / 4096f64.ln()).max(0.5)
    }

    /// Modelled Rmax, FLOPS.
    pub fn rmax(&self, nodes: u32) -> f64 {
        self.rpeak(nodes) * GEMM_EFFICIENCY * self.network_efficiency(nodes)
    }

    /// Overall HPL efficiency Rmax/Rpeak.
    pub fn efficiency(&self, nodes: u32) -> f64 {
        self.rmax(nodes) / self.rpeak(nodes)
    }

    /// Problem size N that fills `frac` of the fleet's GPU memory.
    pub fn problem_size(&self, nodes: u32, frac: f64) -> u64 {
        let bytes =
            self.node.gpu_memory_gib() as f64 * 1.073741824e9 * nodes as f64;
        (frac * bytes / 8.0).sqrt() as u64
    }
}

/// HPCG performance model (bandwidth-bound CG on the 27-point stencil).
#[derive(Debug, Clone)]
pub struct HpcgModel {
    pub node: NodeSpec,
}

impl HpcgModel {
    pub fn new(node: NodeSpec) -> Self {
        HpcgModel { node }
    }

    /// Modelled HPCG rate for `nodes` nodes, FLOPS.
    pub fn rate(&self, nodes: u32) -> f64 {
        let bw = self.node.gpu_memory_bw_gbs() * 1e9;
        nodes as f64 * bw * HPCG_AI * HPCG_MEM_EFF
    }
}

/// Calibration record: measured kernel rates from the PJRT runtime,
/// used to tie the simulator to real execution (EXPERIMENTS.md §Calib).
#[derive(Debug, Clone, Copy, Default)]
pub struct Calibration {
    /// Measured blocked-GEMM rate on this host, GFLOPS.
    pub dgemm_gflops: f64,
    /// Measured LBM site-update rate on this host, MLUPS.
    pub lbm_mlups: f64,
    /// Measured CG iteration time on a 64^3 grid, seconds.
    pub cg_iter_seconds: f64,
}

impl Calibration {
    /// Scale a host-measured rate to a device with `device_roof` /
    /// `host_roof` rooflines: rate_dev = rate_host * (dev/host), capped
    /// at the device roofline. The *structure* (kernel, schedule) is
    /// identical — only the iron changes.
    pub fn project(&self, host_rate: f64, host_roof: f64, device_roof: f64) -> f64 {
        if host_roof <= 0.0 {
            return 0.0;
        }
        (host_rate * device_roof / host_roof).min(device_roof)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::NodeSpec;

    #[test]
    fn roofline_attainable() {
        let r = Roofline {
            peak_flops: 100.0,
            mem_bw_bytes: 10.0,
        };
        assert_eq!(r.attainable(1.0), 10.0);
        assert_eq!(r.attainable(100.0), 100.0);
        assert_eq!(r.ridge(), 10.0);
    }

    #[test]
    fn table4_hpl_rmax_at_3300_nodes() {
        // Paper: 238.7 PF measured on 3300 nodes.
        let m = HplModel::new(NodeSpec::davinci());
        let rmax_pf = m.rmax(3300) / 1e15;
        assert!((rmax_pf - 238.7).abs() / 238.7 < 0.02, "{rmax_pf}");
    }

    #[test]
    fn table4_rpeak_consistent_with_top500() {
        // Paper: 304.5 PF Rpeak quoted (full submission); our per-node
        // accounting gives ~296 PF for the 3300-node run.
        let m = HplModel::new(NodeSpec::davinci());
        let rpeak_pf = m.rpeak(3300) / 1e15;
        assert!((rpeak_pf - 296.0).abs() < 6.0, "{rpeak_pf}");
        // Full Booster:
        let full = m.rpeak(3456) / 1e15;
        assert!(full > 304.5, "{full}");
    }

    #[test]
    fn hpl_efficiency_is_about_0_8() {
        let m = HplModel::new(NodeSpec::davinci());
        let e = m.efficiency(3300);
        assert!((e - 0.807).abs() < 0.02, "{e}");
    }

    #[test]
    fn hpl_efficiency_decays_with_scale() {
        let m = HplModel::new(NodeSpec::davinci());
        assert!(m.efficiency(64) > m.efficiency(512));
        assert!(m.efficiency(512) > m.efficiency(3300));
        assert!(m.efficiency(3300) > 0.5);
    }

    #[test]
    fn table4_hpcg_at_3300_nodes() {
        // Paper: 3.11 PF HPCG.
        let m = HpcgModel::new(NodeSpec::davinci());
        let pf = m.rate(3300) / 1e15;
        assert!((pf - 3.11).abs() / 3.11 < 0.02, "{pf}");
    }

    #[test]
    fn hpcg_is_two_orders_below_hpl() {
        let hpl = HplModel::new(NodeSpec::davinci()).rmax(3300);
        let hpcg = HpcgModel::new(NodeSpec::davinci()).rate(3300);
        let ratio = hpcg / hpl;
        assert!(ratio > 0.005 && ratio < 0.03, "{ratio}");
    }

    #[test]
    fn problem_size_fills_memory() {
        let m = HplModel::new(NodeSpec::davinci());
        let n = m.problem_size(3300, 0.8);
        // N^2 * 8 bytes ~ 0.8 x 3300 x 256 GiB.
        let bytes = (n as f64).powi(2) * 8.0;
        let budget = 0.8 * 3300.0 * 256.0 * 1.073741824e9;
        assert!((bytes / budget - 1.0).abs() < 0.01);
    }

    #[test]
    fn calibration_projection_caps_at_roofline() {
        let c = Calibration {
            dgemm_gflops: 50.0,
            ..Default::default()
        };
        // Host achieves 50 of 100 (50%); device roof 1000 -> 500.
        assert_eq!(c.project(50.0, 100.0, 1000.0), 500.0);
        // Can never exceed the device roofline.
        assert_eq!(c.project(150.0, 100.0, 1000.0), 1000.0);
    }

    #[test]
    fn v100_node_hpl_uses_plain_fp64() {
        let m = HplModel::new(NodeSpec::marconi100_node());
        // 4 x 7.8 + CPU ~ 36 TF/node.
        let tf = m.node_peak_flops() / 1e12;
        assert!((tf - 36.6).abs() < 2.0, "{tf}");
    }
}
