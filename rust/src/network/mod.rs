//! Flow-level network simulator over the dragonfly+ fabric (§2.2).
//!
//! Models what the paper's benchmarks exercise: point-to-point transfer
//! time (latency budget + bandwidth), message-rate limits, collective
//! operations (allreduce/allgather used by HPL, HPCG and the LBM global
//! diagnostics) and nearest-neighbour halo exchange (the LBM communication
//! pattern), including contention on the inter-cell global links when a
//! job spans multiple cells.
//!
//! The simulator is analytic and deterministic: given a placement it
//! computes the bandwidth share of every traffic class on the narrowest
//! link it crosses (max-min style), which is what drives the weak-scaling
//! efficiency shape of Table 7 / Fig 5.
//!
//! Congestion is event-driven: [`CongestionTracker`] subscribes to the
//! shared [`crate::sim`] stream, and every multi-cell job `Start`/`End`
//! updates *per-global-link* background load (one entry per unordered
//! cell pair, see [`crate::topology::Topology::link_bundle_id`]) plus
//! the per-cell spine-stage load, which [`Network::effective_node_bw`]
//! folds into the global-link capacity — so a job's achievable
//! bandwidth depends on what else the scheduler is running, not just
//! its own shape.
//!
//! A route's bottleneck utilization is
//! `max(pair-bundle load, endpoint cell loads)`: traffic between cells
//! `a` and `b` crosses `a`'s shared leaf→spine stage, the dedicated
//! `(a, b)` bundle, and `b`'s spine stage. [`Network::link_bw_for_cells`]
//! prices minimal routing against the **max-loaded link** on the
//! placement's routes (all routes are driven concurrently, the worst
//! one gates completion) and Valiant against the **detour** background
//! ([`route_backgrounds`]: detours dodge the hottest bundle and spread
//! over the wider population, but the endpoint spine stages stay at
//! their max — no detour routes around them) — which is what turns
//! minimal-vs-Valiant into a *per-flow* decision under
//! [`Routing::Adaptive`]: a flow detours exactly when the measured
//! imbalance makes the Valiant expression the better deal.

use std::collections::BTreeMap;

use crate::config::MachineConfig;
use crate::sim::{Component, Event, ScheduledEvent};
use crate::topology::{Routing, Topology, HDR_GBPS, HDR100_GBPS};

/// Per-route global-link contributions of a placement under minimal
/// routing: every unordered cell pair of a multi-cell placement feeds
/// its link bundle with the nodes on both ends (`n_a + n_b` — the
/// endpoints that inject surface traffic into that bundle). The one
/// definition the scheduler engine's link table, the observing
/// [`CongestionTracker`] and the link-load conservation property test
/// all share, so the three accountings cannot drift.
pub fn link_contributions(cells: &[(u32, u32)]) -> impl Iterator<Item = ((u32, u32), u32)> + '_ {
    cells.iter().enumerate().flat_map(move |(i, &(a, na))| {
        cells[i + 1..].iter().map(move |&(b, nb)| {
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            ((lo, hi), na + nb)
        })
    })
}

/// Combine a placement's load aggregates into the `(direct, detour)`
/// backgrounds the bandwidth model prices. The direct (minimal) path
/// is gated by the hottest pair bundle and the worst endpoint spine
/// stage. A Valiant detour re-rolls the *bundles*: it avoids the hot
/// direct bundle and rides hops drawn from the wider population,
/// priced by `bundle_rest_mean` — the placement's bundles with the
/// hottest excluded (0 for a single-pair placement, whose detours ride
/// entirely off-placement bundles) — but still crosses both endpoints'
/// spine stages, which no detour can route around, so the cell-stage
/// max applies to both expressions. Every input is local to the
/// placement's own cells and bundles, which is what keeps the
/// incremental retimer's dirty-cell walk exact under every routing
/// policy. The one formula the Network-side placement path and the
/// scheduler engine's cross tables both feed, so the two accountings
/// cannot drift.
pub fn route_backgrounds(cell_max: f64, bundle_max: f64, bundle_rest_mean: f64) -> (f64, f64) {
    (cell_max.max(bundle_max), cell_max.max(bundle_rest_mean))
}

/// Aggregate a placement's per-route loads into the `(direct, detour)`
/// backgrounds: worst endpoint spine stage, hottest pair bundle and
/// the rest-mean of its bundles, combined by [`route_backgrounds`].
/// `cell_load(cell, own_nodes)` and `bundle_load(a, b, own)` supply
/// the (possibly self-excluded) loads: the scheduler engine feeds its
/// dense cross tables through this, the [`Network`] placement path its
/// background tables — one aggregation, so the two sides cannot
/// drift. `(0, 0)` for single-cell placements.
pub fn placement_backgrounds(
    cells: &[(u32, u32)],
    cell_load: impl Fn(u32, u32) -> f64,
    bundle_load: impl Fn(u32, u32, u32) -> f64,
) -> (f64, f64) {
    if cells.len() <= 1 {
        return (0.0, 0.0);
    }
    let mut cell_max = 0.0f64;
    let mut bundle_max = 0.0f64;
    let mut bundle_sum = 0.0f64;
    let mut bundles = 0usize;
    for (i, &(a, na)) in cells.iter().enumerate() {
        cell_max = cell_max.max(cell_load(a, na));
        for &(b, nb) in &cells[i + 1..] {
            let load = bundle_load(a, b, na + nb);
            bundle_max = bundle_max.max(load);
            bundle_sum += load;
            bundles += 1;
        }
    }
    let rest_mean = (bundle_sum - bundle_max) / (bundles - 1).max(1) as f64;
    route_backgrounds(cell_max, bundle_max, rest_mean)
}

/// Loads below this are treated as zero (and their cells as unloaded).
const LOAD_EPS: f64 = 1e-12;

/// Message-rate ceilings (§2.2).
pub const NIC_MSGS_PER_S: f64 = 200e6;
pub const SWITCH_PORT_MSGS_PER_S: f64 = 390e6;

/// Fabric efficiency actually achievable by verbs/RDMA on HDR links
/// (protocol + PCIe overheads; ~90% of line rate is the accepted figure).
pub const WIRE_EFFICIENCY: f64 = 0.90;

/// A placement of a job on the machine: how many nodes in each cell.
#[derive(Debug, Clone, Default)]
pub struct Placement {
    pub nodes_per_cell: Vec<(u32, u32)>, // (cell id, node count)
}

impl Placement {
    pub fn total_nodes(&self) -> u32 {
        self.nodes_per_cell.iter().map(|(_, n)| n).sum()
    }

    pub fn cells_used(&self) -> usize {
        self.nodes_per_cell.iter().filter(|(_, n)| *n > 0).count()
    }
}

/// The network model: topology + node injection capability.
#[derive(Debug, Clone)]
pub struct Network {
    pub topo: Topology,
    /// Per-node injection bandwidth, Gbps (Booster: 4 x HDR100 = 400).
    pub injection_gbps: f64,
    pub routing: Routing,
    /// Above-leaf pruning of the fabric: 1.0 for LEONARDO's dragonfly+,
    /// >1 for oversubscribed fat-trees (Marconi100's two 2:1 tiers).
    pub oversubscription: f64,
    /// Fraction of global-link capacity consumed by *other* jobs
    /// (0 = idle machine). Drives the locality-vs-spread trade-off the
    /// scheduler's packed placement exists for.
    pub background_global_load: f64,
    /// Per-cell background load on the shared leaf→spine stage
    /// (fraction 0..=1), maintained by a [`CongestionTracker`] from job
    /// start/end events. Added to `background_global_load` for the
    /// cells a placement touches. Dense (indexed by cell id, grown on
    /// demand) so the retime-path queries and the tracker's updates are
    /// allocation-free in steady state — no tree walks, no node churn.
    cell_background: Vec<f64>,
    /// Cells currently carrying a non-negligible background load (keeps
    /// the all-idle fast path an O(1) check).
    loaded_cells: usize,
    /// Per-global-link background load (fraction 0..=1), one entry per
    /// unordered cell pair, indexed by
    /// [`crate::topology::Topology::link_bundle_id`]. Dense and sized
    /// to the topology at construction, so link queries and tracker
    /// updates are allocation-free.
    link_background: Vec<f64>,
    /// Link bundles currently carrying a non-negligible load.
    loaded_links: usize,
    /// Per-bundle health factor (0 < h <= 1): the fraction of the
    /// bundle's capacity a `LinkDegraded` fault leaves usable. 1.0
    /// everywhere on a healthy fabric.
    link_health: Vec<f64>,
    /// Bundles currently below full health (keeps the healthy-fabric
    /// capacity query an O(1) constant read).
    degraded_links: usize,
}

impl Network {
    pub fn new(topo: Topology, injection_gbps: f64) -> Self {
        let cells = topo.cells.len();
        let links = topo.num_link_bundles();
        Network {
            topo,
            injection_gbps,
            routing: Routing::Minimal,
            oversubscription: 1.0,
            background_global_load: 0.0,
            cell_background: vec![0.0; cells],
            loaded_cells: 0,
            link_background: vec![0.0; links],
            loaded_links: 0,
            link_health: vec![1.0; links],
            degraded_links: 0,
        }
    }

    /// Set the health factor of link bundle `bundle` (clamped to
    /// `(0, 1]`; out-of-range bundle ids are ignored). A `LinkDegraded`
    /// fault lands here; `LinkRestored` passes 1.0.
    pub fn set_link_health(&mut self, bundle: usize, factor: f64) {
        let Some(h) = self.link_health.get_mut(bundle) else {
            return;
        };
        let factor = if factor.is_finite() {
            factor.clamp(f64::MIN_POSITIVE, 1.0)
        } else {
            1.0
        };
        let was_degraded = *h < 1.0;
        let is_degraded = factor < 1.0;
        *h = factor;
        match (was_degraded, is_degraded) {
            (false, true) => self.degraded_links += 1,
            (true, false) => self.degraded_links -= 1,
            _ => {}
        }
    }

    /// Health factor of bundle `bundle` (1.0 when unaddressable).
    pub fn link_health(&self, bundle: usize) -> f64 {
        self.link_health.get(bundle).copied().unwrap_or(1.0)
    }

    /// Restore every bundle to full health (arena reuse across
    /// scenarios: the campaign rig resets fault state between replays).
    pub fn reset_link_health(&mut self) {
        if self.degraded_links > 0 {
            self.link_health.fill(1.0);
            self.degraded_links = 0;
        }
    }

    /// Copy of the per-bundle health table (snapshot support: the fork
    /// path must rewind `LinkDegraded` state with everything else).
    pub fn save_link_health(&self, into: &mut Vec<f64>) {
        into.clone_from(&self.link_health);
    }

    /// Restore a health table saved by [`Network::save_link_health`].
    pub fn restore_link_health(&mut self, saved: &[f64]) {
        self.link_health.copy_from_slice(saved);
        self.degraded_links = self.link_health.iter().filter(|&&h| h < 1.0).count();
    }

    /// Capacity of the narrowest (effective) bundle among a placement's
    /// unordered cell pairs, Gbps — the bottleneck a max-min share
    /// prices. On a uniform healthy fabric (the LEONARDO default) this
    /// is an O(1) constant read, bit-for-bit the uniform
    /// `cell_pair_bw_gbps` the model used before heterogeneous bundles
    /// existed.
    fn pair_capacity_gbps(&self, cells: &[(u32, u32)]) -> f64 {
        if self.topo.uniform_bundles() && self.degraded_links == 0 {
            return self.topo.cell_pair_bw_gbps();
        }
        let mut min_cap = f64::INFINITY;
        for (i, &(a, _)) in cells.iter().enumerate() {
            for &(b, _) in &cells[i + 1..] {
                if let Some(id) = self.topo.link_bundle_id(a, b) {
                    let cap = self.topo.link_bundle_capacity_gbps(id) * self.link_health[id];
                    min_cap = min_cap.min(cap);
                }
            }
        }
        if min_cap.is_finite() {
            min_cap
        } else {
            self.topo.cell_pair_bw_gbps()
        }
    }

    /// Set the background global-link load of one cell (clamped 0..=1;
    /// ~zero loads are treated as idle). Allocation-free once the cell
    /// has been seen (the dense table is sized to the topology).
    pub fn set_cell_background_load(&mut self, cell: u32, load: f64) {
        let load = load.clamp(0.0, 1.0);
        let idx = cell as usize;
        if idx >= self.cell_background.len() {
            if load < LOAD_EPS {
                return; // out-of-table idle cell: nothing to record
            }
            self.cell_background.resize(idx + 1, 0.0);
        }
        let was_loaded = self.cell_background[idx] >= LOAD_EPS;
        let is_loaded = load >= LOAD_EPS;
        self.cell_background[idx] = if is_loaded { load } else { 0.0 };
        match (was_loaded, is_loaded) {
            (false, true) => self.loaded_cells += 1,
            (true, false) => self.loaded_cells -= 1,
            _ => {}
        }
    }

    pub fn cell_background_load(&self, cell: u32) -> f64 {
        self.cell_background
            .get(cell as usize)
            .copied()
            .unwrap_or(0.0)
    }

    /// Set the background load of the global link bundle joining cells
    /// `a` and `b` (clamped 0..=1; ~zero loads are treated as idle).
    /// No-op for `a == b` or out-of-fabric cells.
    pub fn set_link_background_load(&mut self, a: u32, b: u32, load: f64) {
        let Some(idx) = self.topo.link_bundle_id(a, b) else {
            return;
        };
        let load = load.clamp(0.0, 1.0);
        let was_loaded = self.link_background[idx] >= LOAD_EPS;
        let is_loaded = load >= LOAD_EPS;
        self.link_background[idx] = if is_loaded { load } else { 0.0 };
        match (was_loaded, is_loaded) {
            (false, true) => self.loaded_links += 1,
            (true, false) => self.loaded_links -= 1,
            _ => {}
        }
    }

    /// Background load of the `(a, b)` link bundle (0 when unset or
    /// unaddressable).
    pub fn link_background_load(&self, a: u32, b: u32) -> f64 {
        self.topo
            .link_bundle_id(a, b)
            .map_or(0.0, |idx| self.link_background[idx])
    }

    /// `(direct, detour)` background load over the inter-cell routes a
    /// placement drives — the two backgrounds
    /// [`Network::link_bw_for_cells`] prices (direct gates minimal
    /// routing, detour gates Valiant), aggregated by the shared
    /// [`placement_backgrounds`]. `(0, 0)` for single-cell placements
    /// or an idle fabric.
    fn placement_link_backgrounds(&self, cells: &[(u32, u32)]) -> (f64, f64) {
        if self.loaded_cells == 0 && self.loaded_links == 0 {
            return (0.0, 0.0);
        }
        placement_backgrounds(
            cells,
            |cell, _own| self.cell_background_load(cell),
            |a, b, _own| self.link_background_load(a, b),
        )
    }

    /// Effective node injection bandwidth, GB/s.
    pub fn injection_gbs(&self) -> f64 {
        self.injection_gbps / 8.0 * WIRE_EFFICIENCY
    }

    /// Point-to-point transfer time for `bytes`, seconds.
    pub fn p2p_time(&self, a: u32, b: u32, bytes: u64) -> f64 {
        let route = self.topo.route(a, b, self.routing);
        let lat = route.latency_ns() * 1e-9;
        if a == b {
            return 0.0; // intra-node: handled by the NVLink model
        }
        // A single flow cannot exceed one rail (ports are HDR100 at the
        // leaf level); multi-rail striping applies to multi-flow traffic.
        let bw = (HDR100_GBPS / 8.0 * WIRE_EFFICIENCY) * 1e9;
        lat + bytes as f64 / bw
    }

    /// Small-message latency between two nodes, seconds.
    pub fn latency(&self, a: u32, b: u32) -> f64 {
        self.topo.route(a, b, self.routing).latency_ns() * 1e-9
    }

    /// Ring allreduce across `p` nodes of `bytes` payload, seconds.
    ///
    /// 2(p-1) steps, each moving bytes/p at the per-node effective
    /// bandwidth, plus the per-step latency of the longest hop in the
    /// ring. This is the NCCL/UCC algorithm the paper's stack (NCCL,
    /// SHARP-less fallback) uses for large payloads.
    pub fn allreduce_time(&self, placement: &Placement, bytes: u64) -> f64 {
        let p = placement.total_nodes() as f64;
        if p <= 1.0 {
            return 0.0;
        }
        let hop_lat = self.worst_latency(placement);
        let chunk = bytes as f64 / p;
        let bw = self.effective_node_bw(placement) * 1e9;
        2.0 * (p - 1.0) * (hop_lat + chunk / bw)
    }

    /// Nearest-neighbour halo exchange: each node sends `bytes_per_face`
    /// to each of `faces` logical neighbours, seconds.
    ///
    /// All faces transfer concurrently: the node's rails stripe the
    /// aggregate, so the completion time is the aggregate volume over the
    /// effective (possibly congested) per-node bandwidth plus one
    /// synchronisation latency.
    pub fn halo_exchange_time(
        &self,
        placement: &Placement,
        faces: u32,
        bytes_per_face: u64,
    ) -> f64 {
        if placement.total_nodes() <= 1 {
            return 0.0;
        }
        let volume = faces as f64 * bytes_per_face as f64;
        let bw = self.effective_node_bw(placement) * 1e9;
        self.worst_latency(placement) + volume / bw
    }

    /// Effective per-node bandwidth under this placement, GB/s: the
    /// injection rate, reduced when the job's inter-cell traffic
    /// oversubscribes the global links (the dragonfly pruning factor at
    /// scale).
    ///
    /// Model: nearest-neighbour traffic leaving a cell scales with the
    /// surface-to-volume ratio of the per-cell node block (~n^-1/3 of a
    /// node's halo crosses a cell boundary for n nodes per cell); packed
    /// placements line cells along the decomposition's slowest axis, so
    /// k cells expose k-1 global boundaries. Cross traffic beyond the
    /// boundary capacity is throttled; intra-cell traffic continues at
    /// full rate. `oversubscription` models fat-tree-style pruning above
    /// the leaf level (1.0 on LEONARDO's dragonfly+).
    pub fn effective_node_bw(&self, placement: &Placement) -> f64 {
        let (max_bg, mean_bg) = self.placement_link_backgrounds(&placement.nodes_per_cell);
        self.link_bw_for_cells(&placement.nodes_per_cell, max_bg, mean_bg)
    }

    /// The bandwidth-share core: effective per-node bandwidth of a
    /// placement whose routes carry `background` load, with the flow's
    /// global traffic multiplied by `route_factor` (1 = minimal paths,
    /// 2 = Valiant detours — every byte crosses two global bundles).
    fn bw_for(&self, cells: &[(u32, u32)], background: f64, route_factor: f64) -> f64 {
        let inj = self.injection_gbs();
        let k = cells.iter().filter(|(_, n)| *n > 0).count();
        let total_nodes: u32 = cells.iter().map(|(_, n)| n).sum();
        if k <= 1 || total_nodes <= 1 {
            return inj;
        }
        let total = total_nodes as f64;
        let avg_cell = total / k as f64;
        let cross_fraction = (1.0 / avg_cell.cbrt()).min(1.0);
        let background = (self.background_global_load + background).clamp(0.0, 0.95);
        let global_gbs =
            self.pair_capacity_gbps(cells) / 8.0 * WIRE_EFFICIENCY * (1.0 - background);
        let supply_per_node =
            global_gbs * (k as f64 - 1.0) / total / self.oversubscription / route_factor;
        let demand_per_node = inj * cross_fraction;
        let scale = if demand_per_node <= supply_per_node {
            1.0
        } else {
            (1.0 - cross_fraction)
                + cross_fraction * (supply_per_node / demand_per_node)
        };
        inj * scale
    }

    /// [`Network::effective_node_bw`] over a raw cell list with one
    /// *uniform* background load supplied by the caller — the
    /// scalar-view entry point retained for callers without a per-link
    /// picture. Under a uniform background the minimal path is never
    /// worse than a detour, so [`Routing::Adaptive`] prices like
    /// minimal here; the per-flow decision needs the per-link loads of
    /// [`Network::link_bw_for_cells`].
    pub fn node_bw_for_cells(&self, cells: &[(u32, u32)], background: f64) -> f64 {
        match self.routing {
            Routing::Minimal | Routing::Adaptive => self.bw_for(cells, background, 1.0),
            Routing::Valiant => self.bw_for(cells, background, 2.0),
        }
    }

    /// Effective per-node bandwidth of a flow under the
    /// `(direct, detour)` backgrounds of [`route_backgrounds`] — the
    /// per-link entry point the scheduler's congestion coupling uses
    /// (its engine tracks per-link cross loads itself, self-excluded
    /// per job).
    ///
    /// * **Minimal** drives every route concurrently: the max-loaded
    ///   link on the placement's routes gates completion (`direct_bg`).
    /// * **Valiant** detours every byte over two bundles drawn from the
    ///   whole population: it pays `route_factor` 2 against `detour_bg`
    ///   (mean bundle load, endpoint spine stages still included — no
    ///   detour routes around them) — the §2.2 adaptive-routing worst
    ///   case.
    /// * **Adaptive** decides per flow from the measured imbalance:
    ///   the flow detours exactly when the Valiant expression beats the
    ///   minimal one (a hot direct bundle next to an idle fabric), so
    ///   the result is the max of the two.
    pub fn link_bw_for_cells(&self, cells: &[(u32, u32)], direct_bg: f64, detour_bg: f64) -> f64 {
        match self.routing {
            Routing::Minimal => self.bw_for(cells, direct_bg, 1.0),
            Routing::Valiant => self.bw_for(cells, detour_bg, 2.0),
            Routing::Adaptive => self
                .bw_for(cells, direct_bg, 1.0)
                .max(self.bw_for(cells, detour_bg, 2.0)),
        }
    }

    /// Per-placement runtime slowdown factor (>= 1) for a job that
    /// spends `comm_fraction` of its runtime communicating, under
    /// `cell_background` load on its cells' global links: the compute
    /// share is untouched, the communication share stretches by the
    /// ratio of idle-fabric injection to the achievable bandwidth. This
    /// is the coupling lever — comm-bound multi-cell jobs stretch under
    /// contention, compute-bound (or single-cell) jobs don't.
    pub fn comm_slowdown(
        &self,
        cells: &[(u32, u32)],
        comm_fraction: f64,
        cell_background: f64,
    ) -> f64 {
        let cf = comm_fraction.clamp(0.0, 1.0);
        if cf <= 0.0 {
            return 1.0;
        }
        let bw = self.node_bw_for_cells(cells, cell_background).max(1e-9);
        (1.0 - cf) + cf * (self.injection_gbs() / bw)
    }

    /// [`Network::comm_slowdown`] over the per-link picture: the
    /// communication share stretches by the ratio of idle-fabric
    /// injection to what [`Network::link_bw_for_cells`] says the
    /// placement's routes can actually move under the
    /// `(direct, detour)` backgrounds — the coupling lever of the
    /// per-global-link model (and, under [`Routing::Adaptive`], where
    /// the per-flow detour decision lands in runtimes).
    pub fn comm_slowdown_links(
        &self,
        cells: &[(u32, u32)],
        comm_fraction: f64,
        direct_bg: f64,
        detour_bg: f64,
    ) -> f64 {
        let cf = comm_fraction.clamp(0.0, 1.0);
        if cf <= 0.0 {
            return 1.0;
        }
        let bw = self.link_bw_for_cells(cells, direct_bg, detour_bg);
        (1.0 - cf) + cf * (self.injection_gbs() / bw.max(1e-9))
    }

    /// Worst small-message latency inside the placement, seconds.
    pub fn worst_latency(&self, placement: &Placement) -> f64 {
        let multi_cell = placement.cells_used() > 1;
        let r = if multi_cell {
            // representative inter-cell route
            crate::topology::Route {
                switch_hops: 4,
                fiber_m: 32.0,
                global_hops: 1,
            }
        } else {
            crate::topology::Route {
                switch_hops: 3,
                fiber_m: 12.0,
                global_hops: 0,
            }
        };
        r.latency_ns() * 1e-9
    }

    /// Can the fabric sustain `msgs_per_s` per node? (§2.2 rate limits.)
    pub fn message_rate_ok(&self, msgs_per_s: f64) -> bool {
        msgs_per_s <= NIC_MSGS_PER_S && msgs_per_s <= SWITCH_PORT_MSGS_PER_S
    }

    /// Gateways aggregate bandwidth to external networks, Tbps (§2.2:
    /// 4 units x 8 x 200 Gbps = 6.4 Tbps).
    pub fn gateway_aggregate_tbps(&self) -> f64 {
        crate::topology::GATEWAYS as f64 * 8.0 * HDR_GBPS / 1000.0
    }
}

/// Per-cell load state of one cell tracked by [`CongestionTracker`].
#[derive(Debug, Clone, Copy)]
struct CellLoad {
    /// Nodes in this cell belonging to running *multi-cell* jobs (the
    /// traffic class that crosses the global links).
    cross_nodes: u32,
    total: u32,
}

/// Per-link load state of one global link bundle tracked by
/// [`CongestionTracker`].
#[derive(Debug, Clone, Copy)]
struct LinkLoad {
    /// Sum over running multi-cell jobs of their per-route contribution
    /// to this bundle ([`link_contributions`]: `n_a + n_b` per job
    /// spanning both endpoints).
    cross_nodes: u32,
    /// Capacity proxy: the endpoint cells' node totals.
    total: u32,
}

/// Event-driven congestion accounting: a [`Component`] that watches job
/// `Start`/`End` events and maintains, per cell *and per global link
/// bundle*, the traffic of running multi-cell jobs — the surface
/// traffic that loads the dragonfly global links. Apply the result to a
/// [`Network`] (or query the loads directly) to couple application
/// performance to what the scheduler is concurrently running.
#[derive(Debug, Clone)]
pub struct CongestionTracker {
    cells: BTreeMap<u32, CellLoad>,
    /// Global link bundles among the tracked cells, keyed by the
    /// `(low, high)` cell pair.
    links: BTreeMap<(u32, u32), LinkLoad>,
    /// Count only Booster-partition jobs (set by [`Self::for_booster`]).
    /// Cell totals are partition-scoped, so a tracker built over GPU
    /// cells must not charge DataCentric traffic to them — the Hybrid
    /// cell hosts both partitions.
    pub booster_only: bool,
    /// Mean cross-traffic load over all tracked cells, sampled per event.
    pub series: crate::telemetry::Series,
    /// Mean per-link utilization over all tracked bundles, sampled per
    /// event.
    pub link_series: crate::telemetry::Series,
    peak: f64,
    peak_link: f64,
    /// Internal snapshot slot ([`Component::snapshot`]). The cell/link
    /// key sets never change after construction, so the snapshot only
    /// carries values (in map iteration order) and series length marks.
    snap: Option<Box<TrackerSnapshot>>,
}

/// Saved [`CongestionTracker`] run state: per-cell and per-link cross
/// counts in `BTreeMap` iteration order, the run peaks, and how long
/// each sample series was (restore truncates, never reallocates).
#[derive(Debug, Clone, Default)]
struct TrackerSnapshot {
    cells: Vec<u32>,
    links: Vec<u32>,
    peak: f64,
    peak_link: f64,
    series_len: usize,
    link_series_len: usize,
}

impl CongestionTracker {
    /// Track the given `(cell id, node total)` set, counting every job.
    pub fn new(cells: impl IntoIterator<Item = (u32, u32)>) -> Self {
        let cells: BTreeMap<u32, CellLoad> = cells
            .into_iter()
            .map(|(id, total)| {
                (
                    id,
                    CellLoad {
                        cross_nodes: 0,
                        total: total.max(1),
                    },
                )
            })
            .collect();
        // Every bundle among the tracked cells, pre-built so event
        // updates never allocate.
        let ids: Vec<u32> = cells.keys().copied().collect();
        let mut links = BTreeMap::new();
        for (i, &a) in ids.iter().enumerate() {
            for &b in &ids[i + 1..] {
                links.insert(
                    (a, b),
                    LinkLoad {
                        cross_nodes: 0,
                        total: cells[&a].total + cells[&b].total,
                    },
                );
            }
        }
        CongestionTracker {
            cells,
            links,
            booster_only: false,
            series: crate::telemetry::Series::default(),
            link_series: crate::telemetry::Series::default(),
            peak: 0.0,
            peak_link: 0.0,
            snap: None,
        }
    }

    /// Track the Booster partition's GPU cells of `cfg`, counting only
    /// Booster jobs.
    pub fn for_booster(cfg: &MachineConfig) -> Self {
        let mut t = Self::new(cfg.cells.iter().enumerate().filter_map(|(id, cell)| {
            let gpu: u32 = cell.groups.iter().map(|g| g.gpu_nodes()).sum();
            (gpu > 0).then_some((id as u32, gpu))
        }));
        t.booster_only = true;
        t
    }

    /// Zero every cell's and link's cross load, the peaks and the
    /// series, keeping the cell/link maps and sample buffers allocated
    /// (arena reuse).
    pub fn reset(&mut self) {
        for c in self.cells.values_mut() {
            c.cross_nodes = 0;
        }
        for l in self.links.values_mut() {
            l.cross_nodes = 0;
        }
        self.peak = 0.0;
        self.peak_link = 0.0;
        self.series.clear();
        self.link_series.clear();
    }

    /// Cross-traffic load fraction of one cell (0 when untracked).
    pub fn cell_load(&self, cell: u32) -> f64 {
        self.cells
            .get(&cell)
            .map(|c| c.cross_nodes as f64 / c.total as f64)
            .unwrap_or(0.0)
    }

    /// Utilization fraction of the `(a, b)` link bundle (0 when
    /// untracked).
    pub fn link_load(&self, a: u32, b: u32) -> f64 {
        let key = if a < b { (a, b) } else { (b, a) };
        self.links
            .get(&key)
            .map(|l| l.cross_nodes as f64 / l.total as f64)
            .unwrap_or(0.0)
    }

    /// Raw cross-node count charged to the `(a, b)` bundle — the
    /// quantity the link-load conservation property test re-derives
    /// from the running job set.
    pub fn link_cross_nodes(&self, a: u32, b: u32) -> u32 {
        let key = if a < b { (a, b) } else { (b, a) };
        self.links.get(&key).map(|l| l.cross_nodes).unwrap_or(0)
    }

    /// Sum of raw cross-node counts over every tracked bundle.
    pub fn total_link_cross_nodes(&self) -> u64 {
        self.links.values().map(|l| l.cross_nodes as u64).sum()
    }

    /// Mean utilization over all tracked link bundles.
    pub fn mean_link_load(&self) -> f64 {
        if self.links.is_empty() {
            return 0.0;
        }
        let sum: f64 = self
            .links
            .values()
            .map(|l| l.cross_nodes as f64 / l.total as f64)
            .sum();
        sum / self.links.len() as f64
    }

    /// Utilization of the most-loaded tracked bundle right now.
    pub fn max_link_load(&self) -> f64 {
        self.links
            .values()
            .map(|l| l.cross_nodes as f64 / l.total as f64)
            .fold(0.0, f64::max)
    }

    /// Highest single-bundle utilization observed over the run.
    pub fn peak_link_load(&self) -> f64 {
        self.peak_link
    }

    /// Mean load over all tracked cells.
    pub fn mean_load(&self) -> f64 {
        if self.cells.is_empty() {
            return 0.0;
        }
        let sum: f64 = self
            .cells
            .values()
            .map(|c| c.cross_nodes as f64 / c.total as f64)
            .sum();
        sum / self.cells.len() as f64
    }

    /// Highest mean load observed over the run.
    pub fn peak_load(&self) -> f64 {
        self.peak
    }

    /// Write the current per-cell and per-link loads into `net` so
    /// [`Network::effective_node_bw`] sees them.
    pub fn apply_to(&self, net: &mut Network) {
        for (&cell, load) in &self.cells {
            net.set_cell_background_load(cell, load.cross_nodes as f64 / load.total as f64);
        }
        for (&(a, b), load) in &self.links {
            net.set_link_background_load(a, b, load.cross_nodes as f64 / load.total as f64);
        }
    }

    fn update(&mut self, cells: &[(u32, u32)], sign: i64) {
        // Single-cell jobs never touch the global links.
        if cells.len() <= 1 {
            return;
        }
        for &(cell, nodes) in cells {
            if let Some(c) = self.cells.get_mut(&cell) {
                let next = c.cross_nodes as i64 + sign * nodes as i64;
                c.cross_nodes = next.clamp(0, c.total as i64) as u32;
            }
        }
        // Per-route bundle contributions: one shared definition
        // (`link_contributions`) with the engine's table and the
        // conservation property test.
        for ((a, b), nodes) in link_contributions(cells) {
            if let Some(l) = self.links.get_mut(&(a, b)) {
                let next = l.cross_nodes as i64 + sign * nodes as i64;
                l.cross_nodes = next.clamp(0, l.total as i64) as u32;
            }
        }
    }
}

impl Component for CongestionTracker {
    fn on_event(&mut self, now: f64, ev: &Event, _out: &mut Vec<ScheduledEvent>) {
        match ev {
            Event::Start { booster, cells, .. } if *booster || !self.booster_only => {
                self.update(cells, 1)
            }
            Event::End { booster, cells, .. } if *booster || !self.booster_only => {
                self.update(cells, -1)
            }
            // A killed job's traffic leaves the fabric like a completed
            // one's — the same unwind as End, so the load tables stay
            // conserved under faults.
            Event::Kill { booster, cells, .. } if *booster || !self.booster_only => {
                self.update(cells, -1)
            }
            _ => return,
        }
        let mean = self.mean_load();
        self.peak = self.peak.max(mean);
        self.series.push(now, mean);
        // One pass over the bundles feeds both the peak fold and the
        // mean sample; the loads derive from integer counts, so
        // recomputing per event is exact (no accumulated residue).
        let mut link_max = 0.0f64;
        let mut link_sum = 0.0f64;
        for l in self.links.values() {
            let load = l.cross_nodes as f64 / l.total as f64;
            link_max = link_max.max(load);
            link_sum += load;
        }
        self.peak_link = self.peak_link.max(link_max);
        let link_mean = if self.links.is_empty() {
            0.0
        } else {
            link_sum / self.links.len() as f64
        };
        self.link_series.push(now, link_mean);
    }

    fn snapshot(&mut self) {
        let mut snap = self.snap.take().unwrap_or_default();
        snap.cells.clear();
        snap.cells.extend(self.cells.values().map(|c| c.cross_nodes));
        snap.links.clear();
        snap.links.extend(self.links.values().map(|l| l.cross_nodes));
        snap.peak = self.peak;
        snap.peak_link = self.peak_link;
        snap.series_len = self.series.len();
        snap.link_series_len = self.link_series.len();
        self.snap = Some(snap);
    }

    fn restore(&mut self) {
        let snap = self
            .snap
            .take()
            .expect("CongestionTracker::restore without a prior snapshot");
        for (c, &cross) in self.cells.values_mut().zip(&snap.cells) {
            c.cross_nodes = cross;
        }
        for (l, &cross) in self.links.values_mut().zip(&snap.links) {
            l.cross_nodes = cross;
        }
        self.peak = snap.peak;
        self.peak_link = snap.peak_link;
        self.series.truncate(snap.series_len);
        self.link_series.truncate(snap.link_series_len);
        self.snap = Some(snap);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;

    fn net() -> Network {
        let cfg = MachineConfig::leonardo();
        let inj = cfg.gpu_node_spec().unwrap().injection_gbps();
        Network::new(Topology::build(&cfg), inj)
    }

    fn placement(cells: &[(u32, u32)]) -> Placement {
        Placement {
            nodes_per_cell: cells.to_vec(),
        }
    }

    #[test]
    fn p2p_time_has_latency_floor() {
        let n = net();
        let t0 = n.p2p_time(0, 1, 0);
        assert!(t0 > 1.3e-6 && t0 < 3.0e-6, "{t0}");
        // 1 MiB at ~11 GB/s adds ~90 us.
        let t1 = n.p2p_time(0, 1, 1 << 20);
        assert!(t1 > t0 + 80e-6 && t1 < t0 + 120e-6, "{t1}");
    }

    #[test]
    fn p2p_is_monotone_in_bytes() {
        let n = net();
        let mut last = 0.0;
        for b in [0u64, 1 << 10, 1 << 16, 1 << 22, 1 << 26] {
            let t = n.p2p_time(0, 3000, b);
            assert!(t >= last);
            last = t;
        }
    }

    #[test]
    fn single_cell_placement_gets_full_injection() {
        let n = net();
        let p = placement(&[(0, 64)]);
        assert!((n.effective_node_bw(&p) - n.injection_gbs()).abs() < 1e-9);
    }

    #[test]
    fn multi_cell_placement_may_throttle_but_never_boosts() {
        let n = net();
        for k in [2u32, 4, 8, 16] {
            let cells: Vec<_> = (0..k).map(|c| (c, 180)).collect();
            let p = placement(&cells);
            let bw = n.effective_node_bw(&p);
            assert!(bw <= n.injection_gbs() + 1e-9);
            assert!(bw > 0.2 * n.injection_gbs(), "k={k} bw={bw}");
        }
    }

    #[test]
    fn spreading_a_job_never_beats_packing_it() {
        let n = net();
        let packed = n.effective_node_bw(&placement(&[(0, 512)]));
        for k in [2u32, 4, 8, 16] {
            let per = 512 / k;
            let cells: Vec<_> = (0..k).map(|c| (c, per)).collect();
            let bw = n.effective_node_bw(&placement(&cells));
            assert!(bw <= packed + 1e-9, "k={k}: {bw} > {packed}");
            assert!(bw >= 0.5 * packed, "k={k}: collapse to {bw}");
        }
    }

    #[test]
    fn oversubscription_reduces_multi_cell_bandwidth() {
        let mut a = net();
        let p = placement(&[(0, 180), (1, 180), (2, 152)]);
        let base = a.effective_node_bw(&p);
        a.oversubscription = 4.0;
        let pruned = a.effective_node_bw(&p);
        assert!(pruned < base, "{pruned} vs {base}");
        // Single-cell jobs are below the leaf layer: unaffected.
        let single = placement(&[(0, 128)]);
        assert_eq!(a.effective_node_bw(&single), a.injection_gbs());
    }

    #[test]
    fn allreduce_grows_with_node_count() {
        let n = net();
        let bytes = 1 << 20;
        let mut last = 0.0;
        for k in [2u32, 8, 32, 128] {
            let cells: Vec<_> = (0..(k / 2).max(1)).map(|c| (c, 2 * k / k.max(1))).collect();
            let p = placement(&cells);
            let t = n.allreduce_time(&p, bytes);
            assert!(t >= last * 0.5, "k={k}");
            last = t;
        }
    }

    #[test]
    fn allreduce_zero_for_single_node() {
        let n = net();
        assert_eq!(n.allreduce_time(&placement(&[(0, 1)]), 1 << 20), 0.0);
    }

    #[test]
    fn halo_exchange_scales_with_volume() {
        let n = net();
        let p = placement(&[(0, 128), (1, 128)]);
        let t1 = n.halo_exchange_time(&p, 6, 1 << 20);
        let t2 = n.halo_exchange_time(&p, 6, 1 << 22);
        assert!(t2 > t1 * 2.0, "{t1} {t2}");
        assert!(t2 < t1 * 8.0);
    }

    #[test]
    fn message_rates_within_paper_limits() {
        let n = net();
        assert!(n.message_rate_ok(150e6));
        assert!(!n.message_rate_ok(250e6));
    }

    #[test]
    fn gateway_bandwidth_is_6_4_tbps() {
        assert!((net().gateway_aggregate_tbps() - 6.4).abs() < 1e-9);
    }

    #[test]
    fn wire_efficiency_applied() {
        let n = net();
        // 400 Gbps x 0.9 / 8 = 45 GB/s
        assert!((n.injection_gbs() - 45.0).abs() < 1e-9);
    }

    #[test]
    fn cell_background_load_throttles_touched_cells_only() {
        let mut n = net();
        let loaded = placement(&[(0, 180), (1, 180)]);
        let elsewhere = placement(&[(4, 180), (5, 180)]);
        let base = n.effective_node_bw(&loaded);
        n.set_cell_background_load(0, 0.8);
        n.set_cell_background_load(1, 0.8);
        assert!(n.effective_node_bw(&loaded) < base);
        assert!((n.effective_node_bw(&elsewhere) - base).abs() < 1e-9);
        // Clearing restores the idle-fabric bandwidth.
        n.set_cell_background_load(0, 0.0);
        n.set_cell_background_load(1, 0.0);
        assert!((n.effective_node_bw(&loaded) - base).abs() < 1e-9);
        // Single-cell placements stay below the global links regardless.
        let single = placement(&[(0, 64)]);
        n.set_cell_background_load(0, 0.9);
        assert_eq!(n.effective_node_bw(&single), n.injection_gbs());
    }

    #[test]
    fn valiant_routing_halves_global_supply() {
        let mut n = net();
        let multi = placement(&[(0, 180), (1, 180), (2, 180)]);
        let minimal_bw = n.effective_node_bw(&multi);
        n.routing = Routing::Valiant;
        let valiant_bw = n.effective_node_bw(&multi);
        assert!(valiant_bw < minimal_bw, "{valiant_bw} vs {minimal_bw}");
        // Single-cell placements never touch the global links.
        let single = placement(&[(0, 64)]);
        assert_eq!(n.effective_node_bw(&single), n.injection_gbs());
    }

    #[test]
    fn comm_slowdown_stretches_comm_bound_multi_cell_jobs_only() {
        let n = net();
        let multi = [(0u32, 180u32), (1, 180)];
        let single = [(0u32, 64u32)];
        // Compute-bound: no stretch regardless of congestion.
        assert_eq!(n.comm_slowdown(&multi, 0.0, 0.8), 1.0);
        // Single-cell: below the global links, no stretch.
        assert_eq!(n.comm_slowdown(&single, 0.9, 0.8), 1.0);
        // Comm-bound multi-cell: stretches, and more under background.
        let idle = n.comm_slowdown(&multi, 0.6, 0.0);
        let busy = n.comm_slowdown(&multi, 0.6, 0.5);
        assert!(idle >= 1.0);
        assert!(busy > idle, "{busy} vs {idle}");
        // More comm fraction, more stretch.
        assert!(n.comm_slowdown(&multi, 0.9, 0.5) > busy);
    }

    #[test]
    fn node_bw_for_cells_matches_effective_node_bw() {
        let mut n = net();
        n.set_cell_background_load(0, 0.3);
        n.set_cell_background_load(1, 0.3);
        let p = placement(&[(0, 120), (1, 120), (2, 120)]);
        let via_placement = n.effective_node_bw(&p);
        // Route bottlenecks: every pair touching cell 0 or 1 sees 0.3,
        // so the placement's max route load is 0.3 — the background the
        // scalar-view API must be handed to agree.
        let via_cells = n.node_bw_for_cells(&p.nodes_per_cell, 0.3);
        assert!((via_placement - via_cells).abs() < 1e-12);
    }

    #[test]
    fn link_background_throttles_only_routes_crossing_it() {
        let mut n = net();
        let crossing = placement(&[(0, 120), (1, 120)]);
        let elsewhere = placement(&[(2, 120), (3, 120)]);
        let base = n.effective_node_bw(&crossing);
        n.set_link_background_load(0, 1, 0.8);
        assert!(n.effective_node_bw(&crossing) < base, "loaded bundle ignored");
        assert!((n.effective_node_bw(&elsewhere) - base).abs() < 1e-9);
        assert!((n.link_background_load(1, 0) - 0.8).abs() < 1e-12, "unordered");
        n.set_link_background_load(1, 0, 0.0);
        assert!((n.effective_node_bw(&crossing) - base).abs() < 1e-9);
        // Self-pairs and out-of-fabric cells are unaddressable no-ops.
        n.set_link_background_load(5, 5, 0.9);
        n.set_link_background_load(0, 999, 0.9);
        assert_eq!(n.link_background_load(5, 5), 0.0);
    }

    #[test]
    fn adaptive_flows_detour_around_a_hot_bundle() {
        let mut n = net();
        let p = placement(&[(0, 180), (1, 180)]);
        let idle = n.effective_node_bw(&p);
        // One hot direct bundle, idle fabric elsewhere: minimal is
        // gated by the hot link; the detour dodges it (a single-pair
        // placement's detours ride entirely off-placement bundles), so
        // the adaptive flow strictly wins even at two cells.
        n.set_link_background_load(0, 1, 0.9);
        n.routing = Routing::Minimal;
        let minimal = n.effective_node_bw(&p);
        n.routing = Routing::Adaptive;
        let adaptive = n.effective_node_bw(&p);
        assert!(minimal < idle);
        assert!(adaptive > minimal, "{adaptive} vs {minimal}");
        // A wider placement with only one hot link out of three leaves
        // the mean low: the detour wins and adaptive strictly beats
        // minimal.
        let wide = placement(&[(0, 120), (1, 120), (2, 120)]);
        n.routing = Routing::Minimal;
        let min_wide = n.effective_node_bw(&wide);
        n.routing = Routing::Adaptive;
        let ad_wide = n.effective_node_bw(&wide);
        assert!(
            ad_wide > min_wide,
            "imbalanced load must trigger the detour: {ad_wide} vs {min_wide}"
        );
        // And adaptive never beats an idle fabric's minimal path.
        n.set_link_background_load(0, 1, 0.0);
        let uniform = n.node_bw_for_cells(&wide.nodes_per_cell, 0.0);
        assert!((n.effective_node_bw(&wide) - uniform).abs() < 1e-9);
    }

    /// Satellite: a heterogeneous capacity table actually prices the
    /// narrow bundle — a placement crossing it gets less bandwidth (and
    /// a bigger comm slowdown) than one crossing full-width bundles.
    #[test]
    fn link_bw_for_cells_prices_the_narrow_bundle() {
        let cfg = MachineConfig::leonardo();
        let inj = cfg.gpu_node_spec().unwrap().injection_gbps();
        let topo = Topology::build(&cfg);
        let narrow = topo.link_bundle_id(0, 1).unwrap();
        let mut caps = vec![topo.cell_pair_bw_gbps(); topo.num_link_bundles()];
        caps[narrow] = 360.0; // a tenth of the nominal 3600 Gbps
        let n = Network::new(topo.with_bundle_capacities(caps), inj);
        let over_narrow = [(0u32, 180u32), (1, 180)];
        let over_wide = [(2u32, 180u32), (3, 180)];
        let bw_narrow = n.link_bw_for_cells(&over_narrow, 0.0, 0.0);
        let bw_wide = n.link_bw_for_cells(&over_wide, 0.0, 0.0);
        assert!(bw_narrow < bw_wide, "{bw_narrow} vs {bw_wide}");
        // The slowdown model sees it too.
        let slow_narrow = n.comm_slowdown_links(&over_narrow, 0.5, 0.0, 0.0);
        let slow_wide = n.comm_slowdown_links(&over_wide, 0.5, 0.0, 0.0);
        assert!(slow_narrow > slow_wide, "{slow_narrow} vs {slow_wide}");
        // A wider placement is gated by its narrowest bundle.
        let spanning = [(0u32, 120u32), (1, 120), (2, 120)];
        let clean = [(2u32, 120u32), (3, 120), (4, 120)];
        assert!(
            n.link_bw_for_cells(&spanning, 0.0, 0.0) < n.link_bw_for_cells(&clean, 0.0, 0.0)
        );
    }

    /// `LinkDegraded` semantics: health scales the effective bundle
    /// capacity, restore brings back the exact healthy bandwidth, and a
    /// uniform healthy fabric stays bit-for-bit the constant-capacity
    /// fast path.
    #[test]
    fn link_health_degrades_and_restores_capacity() {
        let mut n = net();
        let p = [(0u32, 180u32), (1, 180)];
        let healthy = n.link_bw_for_cells(&p, 0.0, 0.0);
        let bundle = n.topo.link_bundle_id(0, 1).unwrap();
        n.set_link_health(bundle, 0.25);
        assert_eq!(n.link_health(bundle), 0.25);
        let degraded = n.link_bw_for_cells(&p, 0.0, 0.0);
        assert!(degraded < healthy, "{degraded} vs {healthy}");
        // Placements elsewhere are untouched.
        let elsewhere = [(2u32, 180u32), (3, 180)];
        assert_eq!(n.link_bw_for_cells(&elsewhere, 0.0, 0.0), healthy);
        n.set_link_health(bundle, 1.0);
        assert_eq!(n.link_bw_for_cells(&p, 0.0, 0.0), healthy);
        // Save/restore round-trips the health table.
        n.set_link_health(bundle, 0.5);
        let mut saved = Vec::new();
        n.save_link_health(&mut saved);
        n.reset_link_health();
        assert_eq!(n.link_health(bundle), 1.0);
        n.restore_link_health(&saved);
        assert_eq!(n.link_health(bundle), 0.5);
        // Out-of-range ids are ignored, non-finite factors are healthy.
        n.set_link_health(usize::MAX, 0.1);
        n.set_link_health(bundle, f64::NAN);
        assert_eq!(n.link_health(bundle), 1.0);
    }

    #[test]
    fn link_contributions_cover_every_pair_once() {
        let cells = [(3u32, 10u32), (1, 20), (7, 5)];
        let got: Vec<((u32, u32), u32)> = link_contributions(&cells).collect();
        assert_eq!(got, vec![((1, 3), 30), ((3, 7), 15), ((1, 7), 25)]);
        assert!(link_contributions(&cells[..1]).next().is_none());
    }

    #[test]
    fn tracker_maintains_link_loads() {
        use crate::sim::{Component, Event};
        let mut out = Vec::new();
        let mut t = CongestionTracker::new([(0, 180), (1, 180), (2, 180)]);
        t.on_event(
            0.0,
            &Event::Start {
                job: 1,
                booster: true,
                dvfs_scale: 1.0,
                cells: vec![(0, 90), (1, 90)].into(),
            },
            &mut out,
        );
        assert!((t.link_load(0, 1) - 0.5).abs() < 1e-12, "{}", t.link_load(0, 1));
        assert_eq!(t.link_cross_nodes(0, 1), 180);
        assert_eq!(t.link_load(0, 2), 0.0);
        assert!(t.max_link_load() > t.mean_link_load());
        t.on_event(
            1.0,
            &Event::End {
                job: 1,
                booster: true,
                cells: vec![(0, 90), (1, 90)].into(),
                gen: 0,
            },
            &mut out,
        );
        assert_eq!(t.max_link_load(), 0.0, "links drain with the job");
        assert_eq!(t.total_link_cross_nodes(), 0);
        assert!(t.peak_link_load() > 0.0, "peak survives the drain");
        assert_eq!(t.link_series.len(), 2, "one sample per event");
        t.reset();
        assert_eq!(t.peak_link_load(), 0.0);
        assert!(t.link_series.is_empty());
    }

    /// snapshot → perturb → restore rewinds loads, peaks and both sample
    /// series to the snapshot point so a replayed suffix matches the
    /// unperturbed run exactly.
    #[test]
    fn tracker_snapshot_restore_round_trips() {
        use crate::sim::{Component, Event};
        let mut out = Vec::new();
        let mut t = CongestionTracker::new([(0, 180), (1, 180), (2, 180)]);
        let start = |job, cells: Vec<(u32, u32)>| Event::Start {
            job,
            booster: true,
            dvfs_scale: 1.0,
            cells: cells.into(),
        };
        t.on_event(0.0, &start(1, vec![(0, 90), (1, 90)]), &mut out);
        t.snapshot();
        t.on_event(1.0, &start(2, vec![(1, 90), (2, 90)]), &mut out);
        t.restore();
        assert!((t.link_load(0, 1) - 0.5).abs() < 1e-12);
        assert_eq!(t.link_cross_nodes(1, 2), 0);
        assert_eq!(t.series.len(), 1);
        assert_eq!(t.link_series.len(), 1);
        // Replaying the same suffix reproduces the perturbed state.
        t.on_event(1.0, &start(2, vec![(1, 90), (2, 90)]), &mut out);
        assert_eq!(t.link_cross_nodes(1, 2), 180);
        assert_eq!(t.series.len(), 2);
    }

    #[test]
    fn congestion_tracker_follows_start_end_events() {
        use crate::sim::{Component, Event};
        let mut out = Vec::new();
        let mut t = CongestionTracker::new([(0, 180), (1, 180), (2, 180)]);
        let start = Event::Start {
            job: 1,
            booster: true,
            dvfs_scale: 1.0,
            cells: vec![(0, 90), (1, 90)].into(),
        };
        t.on_event(0.0, &start, &mut out);
        assert!((t.cell_load(0) - 0.5).abs() < 1e-12);
        assert!((t.cell_load(2) - 0.0).abs() < 1e-12);
        assert!(t.mean_load() > 0.0);
        // Single-cell jobs do not load the global links.
        t.on_event(
            1.0,
            &Event::Start {
                job: 2,
                booster: true,
                dvfs_scale: 1.0,
                cells: vec![(2, 180)].into(),
            },
            &mut out,
        );
        assert_eq!(t.cell_load(2), 0.0);
        t.on_event(
            2.0,
            &Event::End {
                job: 1,
                booster: true,
                cells: vec![(0, 90), (1, 90)].into(),
                gen: 0,
            },
            &mut out,
        );
        assert_eq!(t.mean_load(), 0.0);
        assert!(t.peak_load() > 0.0);
        // One sample per Start/End event, including the no-op single-cell
        // start.
        assert_eq!(t.series.len(), 3);
    }

    #[test]
    fn booster_tracker_ignores_datacentric_jobs() {
        use crate::sim::{Component, Event};
        let mut t = CongestionTracker::for_booster(&MachineConfig::leonardo());
        assert!(t.booster_only);
        // A wide DataCentric job spanning CPU cells (incl. the Hybrid
        // cell's CPU side) must not register as GPU-fabric load.
        t.on_event(
            0.0,
            &Event::Start {
                job: 1,
                booster: false,
                dvfs_scale: 1.0,
                cells: vec![(19, 300), (20, 300), (21, 100)].into(),
            },
            &mut Vec::new(),
        );
        assert_eq!(t.mean_load(), 0.0);
        assert_eq!(t.peak_load(), 0.0);
    }

    #[test]
    fn tracker_applies_loads_to_network() {
        use crate::sim::{Component, Event};
        let mut n = net();
        let mut t = CongestionTracker::for_booster(&MachineConfig::leonardo());
        t.on_event(
            0.0,
            &Event::Start {
                job: 1,
                booster: true,
                dvfs_scale: 1.0,
                cells: vec![(0, 180), (1, 180)].into(),
            },
            &mut Vec::new(),
        );
        t.apply_to(&mut n);
        assert!(n.cell_background_load(0) > 0.9);
        let p = placement(&[(0, 90), (1, 90)]);
        let idle = net().effective_node_bw(&p);
        assert!(n.effective_node_bw(&p) < idle);
    }
}
