//! Flow-level network simulator over the dragonfly+ fabric (§2.2).
//!
//! Models what the paper's benchmarks exercise: point-to-point transfer
//! time (latency budget + bandwidth), message-rate limits, collective
//! operations (allreduce/allgather used by HPL, HPCG and the LBM global
//! diagnostics) and nearest-neighbour halo exchange (the LBM communication
//! pattern), including contention on the inter-cell global links when a
//! job spans multiple cells.
//!
//! The simulator is analytic and deterministic: given a placement it
//! computes the bandwidth share of every traffic class on the narrowest
//! link it crosses (max-min style), which is what drives the weak-scaling
//! efficiency shape of Table 7 / Fig 5.
//!
//! Congestion is event-driven: [`CongestionTracker`] subscribes to the
//! shared [`crate::sim`] stream, and every multi-cell job `Start`/`End`
//! updates per-cell background load that [`Network::effective_node_bw`]
//! folds into the global-link capacity — so a job's achievable bandwidth
//! depends on what else the scheduler is running, not just its own shape.

use std::collections::BTreeMap;

use crate::config::MachineConfig;
use crate::sim::{Component, Event, ScheduledEvent};
use crate::topology::{Routing, Topology, HDR_GBPS, HDR100_GBPS};

/// Loads below this are treated as zero (and their cells as unloaded).
const LOAD_EPS: f64 = 1e-12;

/// Message-rate ceilings (§2.2).
pub const NIC_MSGS_PER_S: f64 = 200e6;
pub const SWITCH_PORT_MSGS_PER_S: f64 = 390e6;

/// Fabric efficiency actually achievable by verbs/RDMA on HDR links
/// (protocol + PCIe overheads; ~90% of line rate is the accepted figure).
pub const WIRE_EFFICIENCY: f64 = 0.90;

/// A placement of a job on the machine: how many nodes in each cell.
#[derive(Debug, Clone, Default)]
pub struct Placement {
    pub nodes_per_cell: Vec<(u32, u32)>, // (cell id, node count)
}

impl Placement {
    pub fn total_nodes(&self) -> u32 {
        self.nodes_per_cell.iter().map(|(_, n)| n).sum()
    }

    pub fn cells_used(&self) -> usize {
        self.nodes_per_cell.iter().filter(|(_, n)| *n > 0).count()
    }
}

/// The network model: topology + node injection capability.
#[derive(Debug, Clone)]
pub struct Network {
    pub topo: Topology,
    /// Per-node injection bandwidth, Gbps (Booster: 4 x HDR100 = 400).
    pub injection_gbps: f64,
    pub routing: Routing,
    /// Above-leaf pruning of the fabric: 1.0 for LEONARDO's dragonfly+,
    /// >1 for oversubscribed fat-trees (Marconi100's two 2:1 tiers).
    pub oversubscription: f64,
    /// Fraction of global-link capacity consumed by *other* jobs
    /// (0 = idle machine). Drives the locality-vs-spread trade-off the
    /// scheduler's packed placement exists for.
    pub background_global_load: f64,
    /// Per-cell background load on the global links (fraction 0..=1),
    /// maintained by a [`CongestionTracker`] from job start/end events.
    /// Added to `background_global_load` for the cells a placement
    /// touches. Dense (indexed by cell id, grown on demand) so the
    /// retime-path queries and the tracker's updates are allocation-free
    /// in steady state — no tree walks, no node churn.
    cell_background: Vec<f64>,
    /// Cells currently carrying a non-negligible background load (keeps
    /// the all-idle fast path an O(1) check).
    loaded_cells: usize,
}

impl Network {
    pub fn new(topo: Topology, injection_gbps: f64) -> Self {
        let cells = topo.cells.len();
        Network {
            topo,
            injection_gbps,
            routing: Routing::Minimal,
            oversubscription: 1.0,
            background_global_load: 0.0,
            cell_background: vec![0.0; cells],
            loaded_cells: 0,
        }
    }

    /// Set the background global-link load of one cell (clamped 0..=1;
    /// ~zero loads are treated as idle). Allocation-free once the cell
    /// has been seen (the dense table is sized to the topology).
    pub fn set_cell_background_load(&mut self, cell: u32, load: f64) {
        let load = load.clamp(0.0, 1.0);
        let idx = cell as usize;
        if idx >= self.cell_background.len() {
            if load < LOAD_EPS {
                return; // out-of-table idle cell: nothing to record
            }
            self.cell_background.resize(idx + 1, 0.0);
        }
        let was_loaded = self.cell_background[idx] >= LOAD_EPS;
        let is_loaded = load >= LOAD_EPS;
        self.cell_background[idx] = if is_loaded { load } else { 0.0 };
        match (was_loaded, is_loaded) {
            (false, true) => self.loaded_cells += 1,
            (true, false) => self.loaded_cells -= 1,
            _ => {}
        }
    }

    pub fn cell_background_load(&self, cell: u32) -> f64 {
        self.cell_background
            .get(cell as usize)
            .copied()
            .unwrap_or(0.0)
    }

    /// Mean per-cell background load over the cells a placement spans.
    fn placement_background(&self, placement: &Placement) -> f64 {
        if self.loaded_cells == 0 || placement.nodes_per_cell.is_empty() {
            return 0.0;
        }
        let sum: f64 = placement
            .nodes_per_cell
            .iter()
            .map(|&(c, _)| self.cell_background_load(c))
            .sum();
        sum / placement.nodes_per_cell.len() as f64
    }

    /// Effective node injection bandwidth, GB/s.
    pub fn injection_gbs(&self) -> f64 {
        self.injection_gbps / 8.0 * WIRE_EFFICIENCY
    }

    /// Point-to-point transfer time for `bytes`, seconds.
    pub fn p2p_time(&self, a: u32, b: u32, bytes: u64) -> f64 {
        let route = self.topo.route(a, b, self.routing);
        let lat = route.latency_ns() * 1e-9;
        if a == b {
            return 0.0; // intra-node: handled by the NVLink model
        }
        // A single flow cannot exceed one rail (ports are HDR100 at the
        // leaf level); multi-rail striping applies to multi-flow traffic.
        let bw = (HDR100_GBPS / 8.0 * WIRE_EFFICIENCY) * 1e9;
        lat + bytes as f64 / bw
    }

    /// Small-message latency between two nodes, seconds.
    pub fn latency(&self, a: u32, b: u32) -> f64 {
        self.topo.route(a, b, self.routing).latency_ns() * 1e-9
    }

    /// Ring allreduce across `p` nodes of `bytes` payload, seconds.
    ///
    /// 2(p-1) steps, each moving bytes/p at the per-node effective
    /// bandwidth, plus the per-step latency of the longest hop in the
    /// ring. This is the NCCL/UCC algorithm the paper's stack (NCCL,
    /// SHARP-less fallback) uses for large payloads.
    pub fn allreduce_time(&self, placement: &Placement, bytes: u64) -> f64 {
        let p = placement.total_nodes() as f64;
        if p <= 1.0 {
            return 0.0;
        }
        let hop_lat = self.worst_latency(placement);
        let chunk = bytes as f64 / p;
        let bw = self.effective_node_bw(placement) * 1e9;
        2.0 * (p - 1.0) * (hop_lat + chunk / bw)
    }

    /// Nearest-neighbour halo exchange: each node sends `bytes_per_face`
    /// to each of `faces` logical neighbours, seconds.
    ///
    /// All faces transfer concurrently: the node's rails stripe the
    /// aggregate, so the completion time is the aggregate volume over the
    /// effective (possibly congested) per-node bandwidth plus one
    /// synchronisation latency.
    pub fn halo_exchange_time(
        &self,
        placement: &Placement,
        faces: u32,
        bytes_per_face: u64,
    ) -> f64 {
        if placement.total_nodes() <= 1 {
            return 0.0;
        }
        let volume = faces as f64 * bytes_per_face as f64;
        let bw = self.effective_node_bw(placement) * 1e9;
        self.worst_latency(placement) + volume / bw
    }

    /// Effective per-node bandwidth under this placement, GB/s: the
    /// injection rate, reduced when the job's inter-cell traffic
    /// oversubscribes the global links (the dragonfly pruning factor at
    /// scale).
    ///
    /// Model: nearest-neighbour traffic leaving a cell scales with the
    /// surface-to-volume ratio of the per-cell node block (~n^-1/3 of a
    /// node's halo crosses a cell boundary for n nodes per cell); packed
    /// placements line cells along the decomposition's slowest axis, so
    /// k cells expose k-1 global boundaries. Cross traffic beyond the
    /// boundary capacity is throttled; intra-cell traffic continues at
    /// full rate. `oversubscription` models fat-tree-style pruning above
    /// the leaf level (1.0 on LEONARDO's dragonfly+).
    pub fn effective_node_bw(&self, placement: &Placement) -> f64 {
        self.node_bw_for_cells(
            &placement.nodes_per_cell,
            self.placement_background(placement),
        )
    }

    /// Core of [`Network::effective_node_bw`] over a raw cell list, with
    /// the per-cell background load supplied by the caller instead of
    /// read from [`Network::cell_background`] — the entry point the
    /// scheduler's congestion coupling uses (its engine tracks cross
    /// loads itself, self-excluded per job).
    ///
    /// Valiant routing detours every global flow through an intermediate
    /// cell, doubling the load its traffic puts on the global links —
    /// the adaptive-routing worst case of §2.2.
    pub fn node_bw_for_cells(&self, cells: &[(u32, u32)], cell_background: f64) -> f64 {
        let inj = self.injection_gbs();
        let k = cells.iter().filter(|(_, n)| *n > 0).count();
        let total_nodes: u32 = cells.iter().map(|(_, n)| n).sum();
        if k <= 1 || total_nodes <= 1 {
            return inj;
        }
        let total = total_nodes as f64;
        let avg_cell = total / k as f64;
        let cross_fraction = (1.0 / avg_cell.cbrt()).min(1.0);
        let background = (self.background_global_load + cell_background).clamp(0.0, 0.95);
        let route_factor = match self.routing {
            Routing::Minimal => 1.0,
            Routing::Valiant => 2.0,
        };
        let global_gbs =
            self.topo.cell_pair_bw_gbps() / 8.0 * WIRE_EFFICIENCY * (1.0 - background);
        let supply_per_node =
            global_gbs * (k as f64 - 1.0) / total / self.oversubscription / route_factor;
        let demand_per_node = inj * cross_fraction;
        let scale = if demand_per_node <= supply_per_node {
            1.0
        } else {
            (1.0 - cross_fraction)
                + cross_fraction * (supply_per_node / demand_per_node)
        };
        inj * scale
    }

    /// Per-placement runtime slowdown factor (>= 1) for a job that
    /// spends `comm_fraction` of its runtime communicating, under
    /// `cell_background` load on its cells' global links: the compute
    /// share is untouched, the communication share stretches by the
    /// ratio of idle-fabric injection to the achievable bandwidth. This
    /// is the coupling lever — comm-bound multi-cell jobs stretch under
    /// contention, compute-bound (or single-cell) jobs don't.
    pub fn comm_slowdown(
        &self,
        cells: &[(u32, u32)],
        comm_fraction: f64,
        cell_background: f64,
    ) -> f64 {
        let cf = comm_fraction.clamp(0.0, 1.0);
        if cf <= 0.0 {
            return 1.0;
        }
        let bw = self.node_bw_for_cells(cells, cell_background).max(1e-9);
        (1.0 - cf) + cf * (self.injection_gbs() / bw)
    }

    /// Worst small-message latency inside the placement, seconds.
    pub fn worst_latency(&self, placement: &Placement) -> f64 {
        let multi_cell = placement.cells_used() > 1;
        let r = if multi_cell {
            // representative inter-cell route
            crate::topology::Route {
                switch_hops: 4,
                fiber_m: 32.0,
                global_hops: 1,
            }
        } else {
            crate::topology::Route {
                switch_hops: 3,
                fiber_m: 12.0,
                global_hops: 0,
            }
        };
        r.latency_ns() * 1e-9
    }

    /// Can the fabric sustain `msgs_per_s` per node? (§2.2 rate limits.)
    pub fn message_rate_ok(&self, msgs_per_s: f64) -> bool {
        msgs_per_s <= NIC_MSGS_PER_S && msgs_per_s <= SWITCH_PORT_MSGS_PER_S
    }

    /// Gateways aggregate bandwidth to external networks, Tbps (§2.2:
    /// 4 units x 8 x 200 Gbps = 6.4 Tbps).
    pub fn gateway_aggregate_tbps(&self) -> f64 {
        crate::topology::GATEWAYS as f64 * 8.0 * HDR_GBPS / 1000.0
    }
}

/// Per-cell load state of one cell tracked by [`CongestionTracker`].
#[derive(Debug, Clone, Copy)]
struct CellLoad {
    /// Nodes in this cell belonging to running *multi-cell* jobs (the
    /// traffic class that crosses the global links).
    cross_nodes: u32,
    total: u32,
}

/// Event-driven congestion accounting: a [`Component`] that watches job
/// `Start`/`End` events and maintains, per cell, the fraction of nodes
/// busy with multi-cell jobs — the surface traffic that loads the
/// dragonfly global links. Apply the result to a [`Network`] (or query
/// the load directly) to couple application performance to what the
/// scheduler is concurrently running.
#[derive(Debug, Clone)]
pub struct CongestionTracker {
    cells: BTreeMap<u32, CellLoad>,
    /// Count only Booster-partition jobs (set by [`Self::for_booster`]).
    /// Cell totals are partition-scoped, so a tracker built over GPU
    /// cells must not charge DataCentric traffic to them — the Hybrid
    /// cell hosts both partitions.
    pub booster_only: bool,
    /// Mean cross-traffic load over all tracked cells, sampled per event.
    pub series: crate::telemetry::Series,
    peak: f64,
}

impl CongestionTracker {
    /// Track the given `(cell id, node total)` set, counting every job.
    pub fn new(cells: impl IntoIterator<Item = (u32, u32)>) -> Self {
        CongestionTracker {
            cells: cells
                .into_iter()
                .map(|(id, total)| {
                    (
                        id,
                        CellLoad {
                            cross_nodes: 0,
                            total: total.max(1),
                        },
                    )
                })
                .collect(),
            booster_only: false,
            series: crate::telemetry::Series::default(),
            peak: 0.0,
        }
    }

    /// Track the Booster partition's GPU cells of `cfg`, counting only
    /// Booster jobs.
    pub fn for_booster(cfg: &MachineConfig) -> Self {
        let mut t = Self::new(cfg.cells.iter().enumerate().filter_map(|(id, cell)| {
            let gpu: u32 = cell.groups.iter().map(|g| g.gpu_nodes()).sum();
            (gpu > 0).then_some((id as u32, gpu))
        }));
        t.booster_only = true;
        t
    }

    /// Zero every cell's cross load, the peak and the series, keeping
    /// the cell map and sample buffers allocated (arena reuse).
    pub fn reset(&mut self) {
        for c in self.cells.values_mut() {
            c.cross_nodes = 0;
        }
        self.peak = 0.0;
        self.series.clear();
    }

    /// Cross-traffic load fraction of one cell (0 when untracked).
    pub fn cell_load(&self, cell: u32) -> f64 {
        self.cells
            .get(&cell)
            .map(|c| c.cross_nodes as f64 / c.total as f64)
            .unwrap_or(0.0)
    }

    /// Mean load over all tracked cells.
    pub fn mean_load(&self) -> f64 {
        if self.cells.is_empty() {
            return 0.0;
        }
        let sum: f64 = self
            .cells
            .values()
            .map(|c| c.cross_nodes as f64 / c.total as f64)
            .sum();
        sum / self.cells.len() as f64
    }

    /// Highest mean load observed over the run.
    pub fn peak_load(&self) -> f64 {
        self.peak
    }

    /// Write the current per-cell loads into `net` so
    /// [`Network::effective_node_bw`] sees them.
    pub fn apply_to(&self, net: &mut Network) {
        for (&cell, load) in &self.cells {
            net.set_cell_background_load(cell, load.cross_nodes as f64 / load.total as f64);
        }
    }

    fn update(&mut self, cells: &[(u32, u32)], sign: i64) {
        // Single-cell jobs never touch the global links.
        if cells.len() <= 1 {
            return;
        }
        for &(cell, nodes) in cells {
            if let Some(c) = self.cells.get_mut(&cell) {
                let next = c.cross_nodes as i64 + sign * nodes as i64;
                c.cross_nodes = next.clamp(0, c.total as i64) as u32;
            }
        }
    }
}

impl Component for CongestionTracker {
    fn on_event(&mut self, now: f64, ev: &Event, _out: &mut Vec<ScheduledEvent>) {
        match ev {
            Event::Start { booster, cells, .. } if *booster || !self.booster_only => {
                self.update(cells, 1)
            }
            Event::End { booster, cells, .. } if *booster || !self.booster_only => {
                self.update(cells, -1)
            }
            _ => return,
        }
        let mean = self.mean_load();
        self.peak = self.peak.max(mean);
        self.series.push(now, mean);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;

    fn net() -> Network {
        let cfg = MachineConfig::leonardo();
        let inj = cfg.gpu_node_spec().unwrap().injection_gbps();
        Network::new(Topology::build(&cfg), inj)
    }

    fn placement(cells: &[(u32, u32)]) -> Placement {
        Placement {
            nodes_per_cell: cells.to_vec(),
        }
    }

    #[test]
    fn p2p_time_has_latency_floor() {
        let n = net();
        let t0 = n.p2p_time(0, 1, 0);
        assert!(t0 > 1.3e-6 && t0 < 3.0e-6, "{t0}");
        // 1 MiB at ~11 GB/s adds ~90 us.
        let t1 = n.p2p_time(0, 1, 1 << 20);
        assert!(t1 > t0 + 80e-6 && t1 < t0 + 120e-6, "{t1}");
    }

    #[test]
    fn p2p_is_monotone_in_bytes() {
        let n = net();
        let mut last = 0.0;
        for b in [0u64, 1 << 10, 1 << 16, 1 << 22, 1 << 26] {
            let t = n.p2p_time(0, 3000, b);
            assert!(t >= last);
            last = t;
        }
    }

    #[test]
    fn single_cell_placement_gets_full_injection() {
        let n = net();
        let p = placement(&[(0, 64)]);
        assert!((n.effective_node_bw(&p) - n.injection_gbs()).abs() < 1e-9);
    }

    #[test]
    fn multi_cell_placement_may_throttle_but_never_boosts() {
        let n = net();
        for k in [2u32, 4, 8, 16] {
            let cells: Vec<_> = (0..k).map(|c| (c, 180)).collect();
            let p = placement(&cells);
            let bw = n.effective_node_bw(&p);
            assert!(bw <= n.injection_gbs() + 1e-9);
            assert!(bw > 0.2 * n.injection_gbs(), "k={k} bw={bw}");
        }
    }

    #[test]
    fn spreading_a_job_never_beats_packing_it() {
        let n = net();
        let packed = n.effective_node_bw(&placement(&[(0, 512)]));
        for k in [2u32, 4, 8, 16] {
            let per = 512 / k;
            let cells: Vec<_> = (0..k).map(|c| (c, per)).collect();
            let bw = n.effective_node_bw(&placement(&cells));
            assert!(bw <= packed + 1e-9, "k={k}: {bw} > {packed}");
            assert!(bw >= 0.5 * packed, "k={k}: collapse to {bw}");
        }
    }

    #[test]
    fn oversubscription_reduces_multi_cell_bandwidth() {
        let mut a = net();
        let p = placement(&[(0, 180), (1, 180), (2, 152)]);
        let base = a.effective_node_bw(&p);
        a.oversubscription = 4.0;
        let pruned = a.effective_node_bw(&p);
        assert!(pruned < base, "{pruned} vs {base}");
        // Single-cell jobs are below the leaf layer: unaffected.
        let single = placement(&[(0, 128)]);
        assert_eq!(a.effective_node_bw(&single), a.injection_gbs());
    }

    #[test]
    fn allreduce_grows_with_node_count() {
        let n = net();
        let bytes = 1 << 20;
        let mut last = 0.0;
        for k in [2u32, 8, 32, 128] {
            let cells: Vec<_> = (0..(k / 2).max(1)).map(|c| (c, 2 * k / k.max(1))).collect();
            let p = placement(&cells);
            let t = n.allreduce_time(&p, bytes);
            assert!(t >= last * 0.5, "k={k}");
            last = t;
        }
    }

    #[test]
    fn allreduce_zero_for_single_node() {
        let n = net();
        assert_eq!(n.allreduce_time(&placement(&[(0, 1)]), 1 << 20), 0.0);
    }

    #[test]
    fn halo_exchange_scales_with_volume() {
        let n = net();
        let p = placement(&[(0, 128), (1, 128)]);
        let t1 = n.halo_exchange_time(&p, 6, 1 << 20);
        let t2 = n.halo_exchange_time(&p, 6, 1 << 22);
        assert!(t2 > t1 * 2.0, "{t1} {t2}");
        assert!(t2 < t1 * 8.0);
    }

    #[test]
    fn message_rates_within_paper_limits() {
        let n = net();
        assert!(n.message_rate_ok(150e6));
        assert!(!n.message_rate_ok(250e6));
    }

    #[test]
    fn gateway_bandwidth_is_6_4_tbps() {
        assert!((net().gateway_aggregate_tbps() - 6.4).abs() < 1e-9);
    }

    #[test]
    fn wire_efficiency_applied() {
        let n = net();
        // 400 Gbps x 0.9 / 8 = 45 GB/s
        assert!((n.injection_gbs() - 45.0).abs() < 1e-9);
    }

    #[test]
    fn cell_background_load_throttles_touched_cells_only() {
        let mut n = net();
        let loaded = placement(&[(0, 180), (1, 180)]);
        let elsewhere = placement(&[(4, 180), (5, 180)]);
        let base = n.effective_node_bw(&loaded);
        n.set_cell_background_load(0, 0.8);
        n.set_cell_background_load(1, 0.8);
        assert!(n.effective_node_bw(&loaded) < base);
        assert!((n.effective_node_bw(&elsewhere) - base).abs() < 1e-9);
        // Clearing restores the idle-fabric bandwidth.
        n.set_cell_background_load(0, 0.0);
        n.set_cell_background_load(1, 0.0);
        assert!((n.effective_node_bw(&loaded) - base).abs() < 1e-9);
        // Single-cell placements stay below the global links regardless.
        let single = placement(&[(0, 64)]);
        n.set_cell_background_load(0, 0.9);
        assert_eq!(n.effective_node_bw(&single), n.injection_gbs());
    }

    #[test]
    fn valiant_routing_halves_global_supply() {
        let mut n = net();
        let multi = placement(&[(0, 180), (1, 180), (2, 180)]);
        let minimal_bw = n.effective_node_bw(&multi);
        n.routing = Routing::Valiant;
        let valiant_bw = n.effective_node_bw(&multi);
        assert!(valiant_bw < minimal_bw, "{valiant_bw} vs {minimal_bw}");
        // Single-cell placements never touch the global links.
        let single = placement(&[(0, 64)]);
        assert_eq!(n.effective_node_bw(&single), n.injection_gbs());
    }

    #[test]
    fn comm_slowdown_stretches_comm_bound_multi_cell_jobs_only() {
        let n = net();
        let multi = [(0u32, 180u32), (1, 180)];
        let single = [(0u32, 64u32)];
        // Compute-bound: no stretch regardless of congestion.
        assert_eq!(n.comm_slowdown(&multi, 0.0, 0.8), 1.0);
        // Single-cell: below the global links, no stretch.
        assert_eq!(n.comm_slowdown(&single, 0.9, 0.8), 1.0);
        // Comm-bound multi-cell: stretches, and more under background.
        let idle = n.comm_slowdown(&multi, 0.6, 0.0);
        let busy = n.comm_slowdown(&multi, 0.6, 0.5);
        assert!(idle >= 1.0);
        assert!(busy > idle, "{busy} vs {idle}");
        // More comm fraction, more stretch.
        assert!(n.comm_slowdown(&multi, 0.9, 0.5) > busy);
    }

    #[test]
    fn node_bw_for_cells_matches_effective_node_bw() {
        let mut n = net();
        n.set_cell_background_load(0, 0.3);
        n.set_cell_background_load(1, 0.3);
        let p = placement(&[(0, 120), (1, 120), (2, 120)]);
        let via_placement = n.effective_node_bw(&p);
        let bg = (0.3 + 0.3 + 0.0) / 3.0;
        let via_cells = n.node_bw_for_cells(&p.nodes_per_cell, bg);
        assert!((via_placement - via_cells).abs() < 1e-12);
    }

    #[test]
    fn congestion_tracker_follows_start_end_events() {
        use crate::sim::{Component, Event};
        let mut out = Vec::new();
        let mut t = CongestionTracker::new([(0, 180), (1, 180), (2, 180)]);
        let start = Event::Start {
            job: 1,
            booster: true,
            dvfs_scale: 1.0,
            cells: vec![(0, 90), (1, 90)].into(),
        };
        t.on_event(0.0, &start, &mut out);
        assert!((t.cell_load(0) - 0.5).abs() < 1e-12);
        assert!((t.cell_load(2) - 0.0).abs() < 1e-12);
        assert!(t.mean_load() > 0.0);
        // Single-cell jobs do not load the global links.
        t.on_event(
            1.0,
            &Event::Start {
                job: 2,
                booster: true,
                dvfs_scale: 1.0,
                cells: vec![(2, 180)].into(),
            },
            &mut out,
        );
        assert_eq!(t.cell_load(2), 0.0);
        t.on_event(
            2.0,
            &Event::End {
                job: 1,
                booster: true,
                cells: vec![(0, 90), (1, 90)].into(),
                gen: 0,
            },
            &mut out,
        );
        assert_eq!(t.mean_load(), 0.0);
        assert!(t.peak_load() > 0.0);
        // One sample per Start/End event, including the no-op single-cell
        // start.
        assert_eq!(t.series.len(), 3);
    }

    #[test]
    fn booster_tracker_ignores_datacentric_jobs() {
        use crate::sim::{Component, Event};
        let mut t = CongestionTracker::for_booster(&MachineConfig::leonardo());
        assert!(t.booster_only);
        // A wide DataCentric job spanning CPU cells (incl. the Hybrid
        // cell's CPU side) must not register as GPU-fabric load.
        t.on_event(
            0.0,
            &Event::Start {
                job: 1,
                booster: false,
                dvfs_scale: 1.0,
                cells: vec![(19, 300), (20, 300), (21, 100)].into(),
            },
            &mut Vec::new(),
        );
        assert_eq!(t.mean_load(), 0.0);
        assert_eq!(t.peak_load(), 0.0);
    }

    #[test]
    fn tracker_applies_loads_to_network() {
        use crate::sim::{Component, Event};
        let mut n = net();
        let mut t = CongestionTracker::for_booster(&MachineConfig::leonardo());
        t.on_event(
            0.0,
            &Event::Start {
                job: 1,
                booster: true,
                dvfs_scale: 1.0,
                cells: vec![(0, 180), (1, 180)].into(),
            },
            &mut Vec::new(),
        );
        t.apply_to(&mut n);
        assert!(n.cell_background_load(0) > 0.9);
        let p = placement(&[(0, 90), (1, 90)]);
        let idle = net().effective_node_bw(&p);
        assert!(n.effective_node_bw(&p) < idle);
    }
}
