//! # leonardo-twin
//!
//! A digital-twin reproduction of the LEONARDO pre-exascale supercomputer
//! ("LEONARDO: A Pan-European Pre-Exascale Supercomputer for HPC and AI
//! Applications", Turisini, Amati, Cestari — 2023).
//!
//! The crate models every subsystem the paper describes, layered over a
//! shared discrete-event core (see ARCHITECTURE.md for the diagram) —
//!
//! * [`sim`] — the deterministic discrete-event kernel: virtual
//!   [`sim::Clock`], `BinaryHeap`-backed [`sim::EventQueue`] and the
//!   [`sim::Component`] trait every operational layer plugs into;
//! * [`hardware`] — the Da Vinci blade: custom A100 GPUs, Ice Lake host,
//!   HBM2e/DDR4 memory systems, PCIe/NVLink intra-node fabric (Table 2,
//!   Fig 3);
//! * [`config`] — machine presets: cell/rack/blade/node inventory for
//!   LEONARDO's Booster, Data-Centric and Hybrid partitions (Table 1), plus
//!   the Marconi100 comparator used by Fig 5;
//! * [`topology`] — the 23-cell dragonfly+ InfiniBand fabric: spine/leaf
//!   wiring, port budgets, gateways, minimal and Valiant routing (Fig 4);
//! * [`network`] — a flow-level network simulator: the paper's latency
//!   budget (§2.2), bandwidth sharing, collectives, halo exchanges, and
//!   event-driven per-cell congestion from concurrently running jobs;
//! * [`storage`] — the DDN/Lustre two-tier storage system: appliances, OST
//!   striping, namespaces (Table 3) and an IO500-style workload engine
//!   (Table 5);
//! * [`scheduler`] — a SLURM-like batch scheduler on the event kernel:
//!   topology-aware placement, FIFO + EASY backfill and power capping
//!   (§2.5, §2.6), emitting the `Start`/`End` stream observers subscribe
//!   to;
//! * [`power`] — node/facility power and energy models, PUE, DVFS capping,
//!   Green500 arithmetic (§2.6, Table 4), and the per-event
//!   [`power::PowerMonitor`];
//! * [`telemetry`] — Prometheus-style metric store, health checks, and the
//!   event-stream scraper (§2.5–2.6);
//! * [`perfmodel`] — rooflines and the HPL/HPCG analytic performance models
//!   calibrated by real kernel runs (Table 4, Appendix A);
//! * [`workloads`] — the four application benchmarks of Table 6 and the
//!   mixed HPC+AI operational trace generator [`workloads::TraceGen`];
//! * [`lbm`] — the distributed lattice-Boltzmann driver behind the paper's
//!   weak-scaling study (Table 7, Fig 5);
//! * [`runtime`] — the PJRT bridge that loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them from Rust
//!   (feature `pjrt`; a host-only stub otherwise);
//! * [`allocation`] / [`frontend`] / [`software`] — ISCRA/EuroHPC award
//!   rounds, login balancing and the programming-environment inventory
//!   (§2.4, §3);
//! * [`coordinator`] — the campaign runner that composes all of the above
//!   to regenerate every table and figure of the paper, plus the
//!   operations-day replay ([`coordinator::Twin::operations_replay`]);
//! * [`campaign`] — the multi-threaded scenario-sweep engine: a
//!   `seeds x power caps x mixes` grid fanned across cores with
//!   `std::thread::scope`, workers replaying on persistent scenario
//!   arenas and streaming results over an mpsc channel into a
//!   deterministic, thread-count-independent report
//!   ([`campaign::run_sweep_streaming`], with [`campaign::run_sweep`]
//!   kept as the join-then-merge baseline; CLI `sweep`);
//! * [`service`] — the distributed sweep service: a coordinator +
//!   worker fleet sharding scenario groups over a consistent-hash ring
//!   and streaming rows back over length-prefixed JSON on TCP, with
//!   reports byte-identical to the single-process engines (CLI
//!   `serve` / `work`);
//! * [`metrics`] — table/CSV/markdown emitters used by the CLI and benches.
//!
//! Compute is real: the LBM/GEMM/CG kernels are JAX + Pallas programs
//! AOT-lowered to HLO at build time (`make artifacts`) and executed through
//! the PJRT CPU client — Python never runs on the Rust hot path.

pub mod allocation;
pub mod campaign;
pub mod config;
pub mod coordinator;
pub mod frontend;
pub mod hardware;
pub mod hpcg;
pub mod hpl;
pub mod lbm;
pub mod metrics;
pub mod network;
pub mod perfmodel;
pub mod power;
pub mod runtime;
pub mod scheduler;
pub mod service;
pub mod sim;
pub mod software;
pub mod storage;
pub mod telemetry;
pub mod topology;
pub mod util;
pub mod workloads;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
