//! The real PJRT engine (feature `pjrt`): XLA client, compiled-executable
//! cache, literal/buffer plumbing. Requires the toolchain's vendored
//! `xla` bindings (see Cargo.toml).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Context};

use super::{default_artifacts_dir, ModuleSpec, TensorSpec};
use crate::Result;

/// The engine: PJRT client + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: HashMap<String, ModuleSpec>,
    exes: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Open an artifacts directory (reads `manifest.json`, lazy-compiles
    /// modules on first use).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!("reading {manifest_path:?} — run `make artifacts` first")
        })?;
        let manifest = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            dir,
            manifest,
            exes: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifacts location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        default_artifacts_dir()
    }

    /// Whether artifacts exist where [`Engine::load`] would look.
    pub fn available() -> bool {
        default_artifacts_dir().join("manifest.json").exists()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Names of available modules.
    pub fn modules(&self) -> Vec<String> {
        let mut names: Vec<String> = self.manifest.keys().cloned().collect();
        names.sort();
        names
    }

    pub fn spec(&self, name: &str) -> Option<&ModuleSpec> {
        self.manifest.get(name)
    }

    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        if !self.manifest.contains_key(name) {
            return Err(anyhow!(
                "unknown module '{name}'; available: {:?}",
                self.modules()
            ));
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.exes
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Force-compile a module (useful to amortize JIT cost up front).
    pub fn warmup(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Execute a module on host literals; returns the untupled outputs.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let spec = &self.manifest[name];
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "'{name}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let out = exe.execute::<xla::Literal>(inputs)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Stage a literal on device for buffer-based hot loops.
    pub fn buffer_from_literal(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    /// Execute on staged device buffers; returns raw output buffers
    /// (still on device — chain them into the next step without a host
    /// round-trip).
    pub fn execute_buffers(
        &self,
        name: &str,
        inputs: &[xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let exe = self.executable(name)?;
        let mut out = exe.execute_b::<xla::PjRtBuffer>(inputs)?;
        Ok(out.remove(0))
    }

    /// Read an output buffer back as a tuple of literals.
    pub fn buffers_to_literals(&self, buf: &xla::PjRtBuffer) -> Result<Vec<xla::Literal>> {
        Ok(buf.to_literal_sync()?.to_tuple()?)
    }

    /// Time `iters` executions of `name` on `inputs`, seconds per call
    /// (first call compiles and is excluded).
    pub fn time_execute(
        &self,
        name: &str,
        inputs: &[xla::Literal],
        iters: u32,
    ) -> Result<f64> {
        self.execute(name, inputs)?; // warmup + compile
        let t0 = Instant::now();
        for _ in 0..iters {
            self.execute(name, inputs)?;
        }
        Ok(t0.elapsed().as_secs_f64() / iters.max(1) as f64)
    }
}

/// Parse `manifest.json` with the in-crate JSON parser (offline build —
/// no serde_json; see `util::json`).
fn parse_manifest(text: &str) -> Result<HashMap<String, ModuleSpec>> {
    use crate::util::json::Json;
    let root = Json::parse(text)?;
    let mut out = HashMap::new();
    for (name, entry) in root.as_obj()? {
        let tensors = |key: &str| -> Result<Vec<TensorSpec>> {
            entry
                .get(key)?
                .as_arr()?
                .iter()
                .map(|t| {
                    Ok(TensorSpec {
                        shape: t
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<_>>()?,
                        dtype: t.get("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect()
        };
        out.insert(
            name.clone(),
            ModuleSpec {
                inputs: tensors("inputs")?,
                outputs: tensors("outputs")?,
                hlo_chars: entry.get("hlo_chars")?.as_usize()?,
            },
        );
    }
    Ok(out)
}

/// Build an f32 literal of `shape` from a flat slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if n != data.len() {
        return Err(anyhow!(
            "shape {shape:?} wants {n} elements, got {}",
            data.len()
        ));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// A scalar f32 literal (rank-0, as the CG state uses).
pub fn scalar_f32(v: f32) -> Result<xla::Literal> {
    Ok(xla::Literal::scalar(v))
}

/// Zero-filled f32 literal for a manifest spec.
pub fn zeros_for(spec: &TensorSpec) -> Result<xla::Literal> {
    let data = vec![0f32; spec.element_count()];
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&data).reshape(&dims)?)
}
