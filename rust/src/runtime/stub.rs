//! Host-only stand-in for the PJRT engine (feature `pjrt` off).
//!
//! Keeps the whole crate compiling and testable without the `xla`
//! bindings: `Literal` carries real f32 data so literal plumbing and its
//! tests work, while [`Engine::load`] always errs — callers that guard
//! with `if let Ok(engine) = Engine::load(...)` (every artifact-dependent
//! test, bench and example) skip exactly as they do on a checkout that
//! has not run `make artifacts`.

use std::path::{Path, PathBuf};

use anyhow::anyhow;

use super::{default_artifacts_dir, ModuleSpec};
use crate::Result;

/// Host-side stand-in for `xla::Literal`: flat f32 data + shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    shape: Vec<usize>,
}

impl Literal {
    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn to_vec<T: From<f32>>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from(v)).collect())
    }
}

/// Opaque stand-in for `xla::PjRtBuffer` (never constructed).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

fn unavailable() -> anyhow::Error {
    anyhow!(
        "PJRT engine unavailable: built without the `pjrt` cargo feature \
         (run `make artifacts` and rebuild with `--features pjrt` plus the \
         toolchain's xla bindings)"
    )
}

/// The stub engine. [`Engine::load`] always errs, so no other method is
/// reachable on a value — they exist to keep call sites compiling.
pub struct Engine {
    _private: (),
}

impl Engine {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = dir.as_ref().join("manifest.json");
        Err(unavailable().context(format!(
            "loading {manifest:?} — run `make artifacts` first"
        )))
    }

    /// Default artifacts location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        default_artifacts_dir()
    }

    /// The stub engine can never execute artifacts.
    pub fn available() -> bool {
        false
    }

    pub fn platform(&self) -> String {
        "stub".into()
    }

    pub fn modules(&self) -> Vec<String> {
        Vec::new()
    }

    pub fn spec(&self, _name: &str) -> Option<&ModuleSpec> {
        None
    }

    pub fn warmup(&self, _name: &str) -> Result<()> {
        Err(unavailable())
    }

    pub fn execute(&self, _name: &str, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn buffer_from_literal(&self, _lit: &Literal) -> Result<PjRtBuffer> {
        Err(unavailable())
    }

    pub fn execute_buffers(
        &self,
        _name: &str,
        _inputs: &[PjRtBuffer],
    ) -> Result<Vec<PjRtBuffer>> {
        Err(unavailable())
    }

    pub fn buffers_to_literals(&self, _buf: &PjRtBuffer) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn time_execute(&self, _name: &str, _inputs: &[Literal], _iters: u32) -> Result<f64> {
        Err(unavailable())
    }
}

/// Build an f32 literal of `shape` from a flat slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if n != data.len() {
        return Err(anyhow!(
            "shape {shape:?} wants {n} elements, got {}",
            data.len()
        ));
    }
    Ok(Literal {
        data: data.to_vec(),
        shape: shape.to_vec(),
    })
}

/// A scalar f32 literal (rank-0, as the CG state uses).
pub fn scalar_f32(v: f32) -> Result<Literal> {
    Ok(Literal {
        data: vec![v],
        shape: Vec::new(),
    })
}

/// Zero-filled f32 literal for a manifest spec.
pub fn zeros_for(spec: &super::TensorSpec) -> Result<Literal> {
    Ok(Literal {
        data: vec![0f32; spec.element_count()],
        shape: spec.shape.clone(),
    })
}
