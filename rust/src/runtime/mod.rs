//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (`artifacts/*.hlo.txt` + `manifest.json`) and executes them on the
//! PJRT CPU client — the only place the twin touches real compute.
//!
//! The real engine lives behind the `pjrt` cargo feature (it needs the
//! toolchain's vendored `xla` bindings — see Cargo.toml). Without the
//! feature this module compiles the host-only stub in [`stub`]: the same
//! API surface, working `Literal` plumbing, and an [`Engine::load`] that
//! always errs — so every artifact-dependent test, bench and example
//! skips cleanly instead of failing the build.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md). All modules are
//! lowered with `return_tuple=True`, so execution unwraps one tuple.
//!
//! Hot-path notes (EXPERIMENTS.md §Perf): executables are compiled once
//! and cached; steady-state loops should stage inputs as device buffers
//! via `Engine::buffer_from_literal` and drive `Engine::execute_buffers`
//! so host literals are not re-uploaded per step.

use std::path::PathBuf;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{literal_f32, scalar_f32, zeros_for, Engine};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{literal_f32, scalar_f32, zeros_for, Engine, Literal, PjRtBuffer};

/// Input/output slot description from `manifest.json`.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT module's manifest entry.
#[derive(Debug, Clone)]
pub struct ModuleSpec {
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub hlo_chars: usize,
}

/// Artifacts location: `$LEONARDO_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("LEONARDO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_f32_roundtrip() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(lit.element_count(), 4);
        let v: Vec<f32> = lit.to_vec().unwrap();
        assert_eq!(v, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn tensor_spec_count() {
        let s = TensorSpec {
            shape: vec![19, 32, 32, 32],
            dtype: "float32".into(),
        };
        assert_eq!(s.element_count(), 19 * 32768);
        let scalar = TensorSpec {
            shape: vec![],
            dtype: "float32".into(),
        };
        assert_eq!(scalar.element_count(), 1);
    }

    #[test]
    fn missing_dir_is_a_clear_error() {
        let err = match Engine::load("/nonexistent/path") {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn availability_matches_load() {
        // Without artifacts (and/or without the pjrt feature) the engine
        // reports unavailable, and load errs accordingly.
        if !Engine::available() {
            assert!(Engine::load(default_artifacts_dir()).is_err());
        }
    }
}
