//! Software ecosystem (paper §2.5): the environment-modules / Spack-style
//! stack LEONARDO ships — architecture-specific suites (Intel OneAPI,
//! NVIDIA HPC SDK, GNU), category-organised scientific software, and a
//! dependency-resolving module loader with conflict detection (what
//! `module load` does on the real frontends).

use std::collections::{BTreeMap, BTreeSet};

use crate::metrics::Table;

/// A software package in the module tree.
#[derive(Debug, Clone)]
pub struct Package {
    pub name: &'static str,
    pub version: &'static str,
    pub category: &'static str,
    /// Module names this one needs loaded first.
    pub requires: Vec<&'static str>,
    /// Module names this one cannot coexist with (compiler families,
    /// MPI implementations).
    pub conflicts: Vec<&'static str>,
}

/// The §2.5 baseline stack.
pub fn leonardo_stack() -> Vec<Package> {
    fn p(
        name: &'static str,
        version: &'static str,
        category: &'static str,
        requires: Vec<&'static str>,
        conflicts: Vec<&'static str>,
    ) -> Package {
        Package {
            name,
            version,
            category,
            requires,
            conflicts,
        }
    }
    vec![
        // compilers
        p("gcc", "12.2.0", "compilers", vec![], vec!["intel-oneapi"]),
        p("intel-oneapi", "2023.1", "compilers", vec![], vec!["gcc"]),
        p("nvhpc", "23.5", "compilers", vec![], vec![]),
        p("cuda", "12.1", "compilers", vec![], vec![]),
        // MPI
        p("openmpi", "4.1.5", "mpi", vec!["gcc"], vec!["intel-mpi"]),
        p("intel-mpi", "2021.9", "mpi", vec!["intel-oneapi"], vec!["openmpi"]),
        // numerical libraries
        p("mkl", "2023.1", "numerics", vec!["intel-oneapi"], vec![]),
        p("gsl", "2.7", "numerics", vec!["gcc"], vec![]),
        p("cudnn", "8.9", "ai", vec!["cuda"], vec![]),
        p("nccl", "2.18", "ai", vec!["cuda"], vec![]),
        // tools
        p("gdb", "13.1", "tools", vec![], vec![]),
        p("vtune", "2023.1", "tools", vec!["intel-oneapi"], vec![]),
        p("nsight", "2023.2", "tools", vec!["cuda"], vec![]),
        p("valgrind", "3.21", "tools", vec![], vec![]),
        p("singularity", "3.11", "containers", vec![], vec![]),
        p("pyxis", "0.15", "containers", vec!["singularity"], vec![]),
        // scientific categories (§2.5: chemistry-physics, deep learning,
        // life sciences, meteo)
        p("quantum-espresso", "7.2", "chemistry-physics", vec!["openmpi", "gsl"], vec![]),
        p("specfem3d", "4.0", "chemistry-physics", vec!["openmpi"], vec![]),
        p("pytorch", "2.0", "deep-learning", vec!["cuda", "cudnn", "nccl"], vec![]),
        p("gromacs", "2023", "life-sciences", vec!["openmpi"], vec![]),
        p("wrf", "4.5", "meteo", vec!["openmpi"], vec![]),
    ]
}

/// The module environment: resolves `load` requests like Lmod does.
#[derive(Debug, Default)]
pub struct ModuleEnv {
    index: BTreeMap<&'static str, Package>,
    loaded: BTreeSet<&'static str>,
}

impl ModuleEnv {
    pub fn new(stack: Vec<Package>) -> Self {
        let mut index = BTreeMap::new();
        for p in stack {
            index.insert(p.name, p);
        }
        ModuleEnv {
            index,
            loaded: BTreeSet::new(),
        }
    }

    pub fn loaded(&self) -> Vec<&'static str> {
        self.loaded.iter().copied().collect()
    }

    /// Load a module and (recursively) its requirements.
    /// Fails on unknown modules, dependency cycles and conflicts.
    pub fn load(&mut self, name: &str) -> Result<Vec<&'static str>, String> {
        let mut order = Vec::new();
        let mut visiting = BTreeSet::new();
        self.resolve(name, &mut order, &mut visiting)?;
        // conflict check against everything already loaded + the batch
        for &m in &order {
            let pkg = &self.index[m];
            for &c in &pkg.conflicts {
                if self.loaded.contains(c) || order.contains(&c) {
                    return Err(format!("{m} conflicts with loaded {c}"));
                }
            }
        }
        for &m in &order {
            self.loaded.insert(m);
        }
        Ok(order)
    }

    fn resolve(
        &self,
        name: &str,
        order: &mut Vec<&'static str>,
        visiting: &mut BTreeSet<String>,
    ) -> Result<(), String> {
        let pkg = self
            .index
            .get(name)
            .ok_or_else(|| format!("unknown module '{name}'"))?;
        if self.loaded.contains(pkg.name) || order.contains(&pkg.name) {
            return Ok(());
        }
        if !visiting.insert(name.to_string()) {
            return Err(format!("dependency cycle through '{name}'"));
        }
        for &req in &pkg.requires {
            self.resolve(req, order, visiting)?;
        }
        visiting.remove(name);
        order.push(pkg.name);
        Ok(())
    }

    /// Unload a module; refuses while something loaded requires it.
    pub fn unload(&mut self, name: &str) -> Result<(), String> {
        for &m in &self.loaded {
            if m != name && self.index[m].requires.contains(&name) {
                return Err(format!("'{m}' still requires '{name}'"));
            }
        }
        if self.loaded.remove(name) {
            Ok(())
        } else {
            Err(format!("'{name}' is not loaded"))
        }
    }

    /// `module avail`-style category listing.
    pub fn avail(&self) -> Table {
        let mut t = Table::new(
            "Software ecosystem (§2.5)",
            &["Category", "Modules"],
        );
        let mut by_cat: BTreeMap<&str, Vec<String>> = BTreeMap::new();
        for p in self.index.values() {
            by_cat
                .entry(p.category)
                .or_default()
                .push(format!("{}/{}", p.name, p.version));
        }
        for (cat, mods) in by_cat {
            t.row(vec![cat.to_string(), mods.join(", ")]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> ModuleEnv {
        ModuleEnv::new(leonardo_stack())
    }

    #[test]
    fn load_resolves_transitive_dependencies_in_order() {
        let mut e = env();
        let order = e.load("pytorch").unwrap();
        // cuda before cudnn/nccl, all before pytorch
        let pos = |m: &str| order.iter().position(|&x| x == m).unwrap();
        assert!(pos("cuda") < pos("cudnn"));
        assert!(pos("cuda") < pos("nccl"));
        assert!(pos("cudnn") < pos("pytorch"));
        assert!(e.loaded().contains(&"pytorch"));
    }

    #[test]
    fn compiler_families_conflict() {
        let mut e = env();
        e.load("gcc").unwrap();
        let err = e.load("intel-oneapi").unwrap_err();
        assert!(err.contains("conflicts"), "{err}");
        // And transitively: intel-mpi needs intel-oneapi which conflicts.
        let err = e.load("intel-mpi").unwrap_err();
        assert!(err.contains("conflicts"), "{err}");
    }

    #[test]
    fn load_is_idempotent() {
        let mut e = env();
        e.load("quantum-espresso").unwrap();
        let n = e.loaded().len();
        let second = e.load("quantum-espresso").unwrap();
        assert!(second.is_empty());
        assert_eq!(e.loaded().len(), n);
    }

    #[test]
    fn unload_protects_dependents() {
        let mut e = env();
        e.load("pytorch").unwrap();
        let err = e.unload("cuda").unwrap_err();
        assert!(err.contains("requires"), "{err}");
        e.unload("pytorch").unwrap();
        e.unload("nsight").unwrap_err(); // never loaded
    }

    #[test]
    fn unknown_module_is_an_error() {
        let mut e = env();
        assert!(e.load("fortranpp").is_err());
    }

    #[test]
    fn avail_covers_paper_categories() {
        let t = env().avail();
        let cats: Vec<&str> = t.rows.iter().map(|r| r[0].as_str()).collect();
        for want in [
            "chemistry-physics",
            "deep-learning",
            "life-sciences",
            "meteo",
            "containers",
        ] {
            assert!(cats.contains(&want), "missing {want}");
        }
    }
}
