//! IO500-style workload engine (paper Appendix A.2, Table 5).
//!
//! Runs the standard phase list — ior-easy / ior-hard (write+read),
//! mdtest-easy / mdtest-hard (create/stat/delete) and find — against the
//! [`StorageSystem`] model and scores them exactly as the list does:
//! `score = sqrt(gm(bandwidth phases, GiB/s) x gm(metadata phases,
//! kIOP/s))`.
//!
//! Phase efficiencies encode what separates "easy" from "hard" on a real
//! Lustre: easy IOR is wide-striped aligned sequential I/O at media speed;
//! hard IOR is interleaved small unaligned writes to a single shared file
//! (a well-documented ~5x penalty); mdtest-hard serializes on the shared
//! directory. The constants are calibrated once against LEONARDO's
//! ISC-2023 submission and kept fixed for all what-if runs.



use super::{Namespace, StorageSystem};

const GIB: f64 = 1.073741824e9 / 1e9; // GiB per GB... (GB -> GiB divisor)

/// Phase efficiency constants (fractions of the easy-phase rate).
pub mod eff {
    /// ior-hard-write / ior-easy-write (unaligned interlocked writes).
    pub const IOR_HARD_WRITE: f64 = 0.196;
    /// ior-hard-read / ior-easy-read.
    pub const IOR_HARD_READ: f64 = 0.26;
    /// mdtest phase factors relative to the MDS pool's create capability
    /// (stat and find run above it — cached lookups; creates/deletes
    /// below — journaled updates).
    pub const MD_EASY_CREATE: f64 = 0.63;
    pub const MD_EASY_STAT: f64 = 1.57;
    pub const MD_EASY_DELETE: f64 = 0.55;
    pub const MD_HARD_CREATE: f64 = 0.39;
    pub const MD_HARD_STAT: f64 = 1.10;
    pub const MD_HARD_DELETE: f64 = 0.47;
    pub const FIND: f64 = 2.20;
}

/// One scored phase.
#[derive(Debug, Clone)]
pub struct Phase {
    pub name: &'static str,
    /// GiB/s for bandwidth phases, kIOP/s for metadata phases.
    pub value: f64,
    pub is_bandwidth: bool,
}

/// A complete IO500 run.
#[derive(Debug, Clone)]
pub struct Io500Result {
    pub phases: Vec<Phase>,
    pub bw_gibs: f64,
    pub md_kiops: f64,
    pub score: f64,
}

fn geometric_mean(values: impl Iterator<Item = f64>) -> f64 {
    let (sum, n) = values.fold((0.0, 0u32), |(s, n), v| (s + v.ln(), n + 1));
    if n == 0 {
        0.0
    } else {
        (sum / n as f64).exp()
    }
}

/// Number of client nodes driving the benchmark (LEONARDO's submission
/// used a small fleet of Booster nodes; rates here are pool-bound).
#[derive(Debug, Clone, Copy)]
pub struct Io500Config {
    pub client_nodes: u32,
    /// Per-client injection bandwidth, GB/s.
    pub client_link_gbs: f64,
}

impl Default for Io500Config {
    fn default() -> Self {
        Io500Config {
            client_nodes: 64,
            client_link_gbs: 45.0,
        }
    }
}

/// Run the IO500 phase list against `ns` (LEONARDO used /scratch).
pub fn run(ns: &Namespace, cfg: Io500Config) -> Io500Result {
    let client_agg = cfg.client_nodes as f64 * cfg.client_link_gbs;
    // Easy IOR: wide stripes, every client at full rate, pool-bound.
    let easy_write_gbs = ns.peak_write_gbs().min(client_agg);
    let easy_read_gbs = ns.peak_read_gbs().min(client_agg);
    let to_gib = |gbs: f64| gbs / GIB / 1e0; // GB/s -> GiB/s

    let md_pool = ns.md_kiops();
    let md_scale = (cfg.client_nodes as f64 / 64.0).min(1.0);
    let md = |f: f64| md_pool * f * md_scale;

    let phases = vec![
        Phase {
            name: "ior-easy-write",
            value: to_gib(easy_write_gbs),
            is_bandwidth: true,
        },
        Phase {
            name: "ior-easy-read",
            value: to_gib(easy_read_gbs),
            is_bandwidth: true,
        },
        Phase {
            name: "ior-hard-write",
            value: to_gib(easy_write_gbs * eff::IOR_HARD_WRITE),
            is_bandwidth: true,
        },
        Phase {
            name: "ior-hard-read",
            value: to_gib(easy_read_gbs * eff::IOR_HARD_READ),
            is_bandwidth: true,
        },
        Phase {
            name: "mdtest-easy-create",
            value: md(eff::MD_EASY_CREATE),
            is_bandwidth: false,
        },
        Phase {
            name: "mdtest-easy-stat",
            value: md(eff::MD_EASY_STAT),
            is_bandwidth: false,
        },
        Phase {
            name: "mdtest-easy-delete",
            value: md(eff::MD_EASY_DELETE),
            is_bandwidth: false,
        },
        Phase {
            name: "mdtest-hard-create",
            value: md(eff::MD_HARD_CREATE),
            is_bandwidth: false,
        },
        Phase {
            name: "mdtest-hard-stat",
            value: md(eff::MD_HARD_STAT),
            is_bandwidth: false,
        },
        Phase {
            name: "mdtest-hard-delete",
            value: md(eff::MD_HARD_DELETE),
            is_bandwidth: false,
        },
        Phase {
            name: "find",
            value: md(eff::FIND),
            is_bandwidth: false,
        },
    ];

    let bw_gibs =
        geometric_mean(phases.iter().filter(|p| p.is_bandwidth).map(|p| p.value));
    let md_kiops = geometric_mean(
        phases.iter().filter(|p| !p.is_bandwidth).map(|p| p.value),
    );
    let score = (bw_gibs * md_kiops).sqrt();
    Io500Result {
        phases,
        bw_gibs,
        md_kiops,
        score,
    }
}

/// Convenience: run against LEONARDO's /scratch with defaults (Table 5).
pub fn run_leonardo() -> Io500Result {
    let sys = StorageSystem::leonardo();
    run(sys.namespace("/scratch").unwrap(), Io500Config::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ior_easy_matches_table5() {
        let r = run_leonardo();
        let w = r
            .phases
            .iter()
            .find(|p| p.name == "ior-easy-write")
            .unwrap()
            .value;
        let rd = r
            .phases
            .iter()
            .find(|p| p.name == "ior-easy-read")
            .unwrap()
            .value;
        // Paper: 1533 GiB/s write, 1883 GiB/s read (±5%).
        assert!((w - 1533.0).abs() / 1533.0 < 0.05, "write {w}");
        assert!((rd - 1883.0).abs() / 1883.0 < 0.05, "read {rd}");
    }

    #[test]
    fn score_matches_table5_within_10pct() {
        let r = run_leonardo();
        // Paper: score 649, BW 807 GiB/s, MD 522 kIOP/s.
        assert!((r.bw_gibs - 807.0).abs() / 807.0 < 0.10, "bw {}", r.bw_gibs);
        assert!(
            (r.md_kiops - 522.0).abs() / 522.0 < 0.15,
            "md {}",
            r.md_kiops
        );
        assert!((r.score - 649.0).abs() / 649.0 < 0.10, "score {}", r.score);
    }

    #[test]
    fn score_is_sqrt_of_bw_times_md() {
        let r = run_leonardo();
        assert!((r.score - (r.bw_gibs * r.md_kiops).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn hard_phases_are_slower_than_easy() {
        let r = run_leonardo();
        let get = |n: &str| r.phases.iter().find(|p| p.name == n).unwrap().value;
        assert!(get("ior-hard-write") < get("ior-easy-write"));
        assert!(get("ior-hard-read") < get("ior-easy-read"));
        assert!(get("mdtest-hard-create") < get("mdtest-easy-create"));
    }

    #[test]
    fn few_clients_cannot_saturate_the_pool() {
        let sys = StorageSystem::leonardo();
        let ns = sys.namespace("/scratch").unwrap();
        let small = run(
            ns,
            Io500Config {
                client_nodes: 4,
                client_link_gbs: 45.0,
            },
        );
        let full = run_leonardo();
        assert!(small.bw_gibs < full.bw_gibs);
        assert!(small.score < full.score);
    }

    #[test]
    fn geometric_mean_sanity() {
        let gm = geometric_mean([4.0, 9.0].into_iter());
        assert!((gm - 6.0).abs() < 1e-9);
        assert_eq!(geometric_mean(std::iter::empty()), 0.0);
    }
}
