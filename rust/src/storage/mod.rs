//! The DDN/Lustre storage system (paper §2.3, Table 3, Table 5).
//!
//! Two tiers — a full-flash Fast Tier (31 x ES400NVX2) and a Capacity
//! Tier (31 x ES7990X + SS9012 expansions, 4 x ES400NV metadata) — mapped
//! onto three Lustre namespaces (/home, /archive, /scratch). Capacities
//! are *derived* from the component inventory of Appendix B (drive counts
//! x sizes x the declustered-RAID efficiency), and an IOR/mdtest-style
//! workload engine reproduces the IO500 submission of Table 5.

pub mod io500;



/// Declustered-RAID (8+2 + spare) usable fraction observed across all
/// three namespaces of Table 3 (net/raw = 0.766 on each; see tests).
pub const RAID_EFFICIENCY: f64 = 0.766;

/// A DDN appliance model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Appliance {
    pub name: &'static str,
    /// Raw media capacity, TB.
    pub raw_tb: f64,
    /// Sustained media write bandwidth, GB/s.
    pub write_gbs: f64,
    /// Sustained media read bandwidth, GB/s.
    pub read_gbs: f64,
    /// InfiniBand ports aggregate, Gbps.
    pub ports_gbps: f64,
    /// Metadata capability, kIOP/s (0 for pure data movers).
    pub md_kiops: f64,
}

impl Appliance {
    /// Fast-tier ES400NVX2: 24 x 7.68 TB NVMe, 4 x HDR (800 Gbps).
    /// Media rates are the DDN-class sustained figures that reproduce the
    /// ior-easy results of Table 5 (51/64 GB/s write/read per appliance).
    pub fn es400nvx2() -> Self {
        Appliance {
            name: "ES400NVX2",
            raw_tb: 24.0 * 7.68,
            write_gbs: 51.3,
            read_gbs: 64.3,
            ports_gbps: 800.0,
            md_kiops: 0.0, // data mover; metadata lives on the ES400NVs
        }
    }

    /// Capacity-tier module: ES7990X head + 2 x SS9012 = 246 x 18 TB HDD,
    /// 4 x HDR100 (400 Gbps).
    pub fn es7990x() -> Self {
        Appliance {
            name: "ES7990X",
            raw_tb: 246.0 * 18.0,
            write_gbs: 20.0,
            read_gbs: 22.0,
            ports_gbps: 400.0,
            md_kiops: 0.0,
        }
    }

    /// Flash metadata unit (ES400NV / SFA400NVX class): 21 x 3.84 TB.
    pub fn es400nv() -> Self {
        Appliance {
            name: "ES400NV",
            raw_tb: 21.0 * 3.84,
            write_gbs: 30.0,
            read_gbs: 40.0,
            ports_gbps: 800.0,
            md_kiops: 320.0,
        }
    }

    /// Deliverable bandwidth is media- or port-limited, GB/s.
    pub fn deliverable_write_gbs(&self) -> f64 {
        self.write_gbs.min(self.ports_gbps / 8.0)
    }

    pub fn deliverable_read_gbs(&self) -> f64 {
        self.read_gbs.min(self.ports_gbps / 8.0)
    }
}

/// A Lustre namespace backed by a pool of appliances (one Table 3 row).
#[derive(Debug, Clone)]
pub struct Namespace {
    pub mount: &'static str,
    pub data_appliances: Vec<(Appliance, u32)>,
    pub md_appliances: Vec<(Appliance, u32)>,
    /// Vendor-quoted sustained namespace bandwidth, GB/s (Table 3) —
    /// mixed-workload figure below the raw media aggregate.
    pub nominal_bw_gbs: f64,
}

impl Namespace {
    pub fn raw_tb(&self) -> f64 {
        self.data_appliances
            .iter()
            .map(|(a, n)| a.raw_tb * *n as f64)
            .sum()
    }

    /// Net usable size in PiB after RAID overhead (Table 3 "NetSize").
    pub fn net_pib(&self) -> f64 {
        self.raw_tb() * RAID_EFFICIENCY * 1e12 / (1u64 << 50) as f64
    }

    /// Aggregate deliverable write/read bandwidth of the pool, GB/s.
    pub fn peak_write_gbs(&self) -> f64 {
        self.data_appliances
            .iter()
            .map(|(a, n)| a.deliverable_write_gbs() * *n as f64)
            .sum()
    }

    pub fn peak_read_gbs(&self) -> f64 {
        self.data_appliances
            .iter()
            .map(|(a, n)| a.deliverable_read_gbs() * *n as f64)
            .sum()
    }

    /// Aggregate metadata rate, kIOP/s.
    pub fn md_kiops(&self) -> f64 {
        self.md_appliances
            .iter()
            .chain(self.data_appliances.iter())
            .map(|(a, n)| a.md_kiops * *n as f64)
            .sum()
    }

    /// Number of object storage targets exposed (one OST per data
    /// appliance controller pair, the DDN EXAScaler layout).
    pub fn ost_count(&self) -> u32 {
        self.data_appliances.iter().map(|(_, n)| *n * 2).sum()
    }
}

/// The whole storage system (Table 3).
#[derive(Debug, Clone)]
pub struct StorageSystem {
    pub namespaces: Vec<Namespace>,
}

impl StorageSystem {
    /// LEONARDO's layout (Table 3 / Appendix B).
    pub fn leonardo() -> Self {
        StorageSystem {
            namespaces: vec![
                Namespace {
                    mount: "/home",
                    data_appliances: vec![(Appliance::es400nvx2(), 4)],
                    md_appliances: vec![],
                    nominal_bw_gbs: 240.0,
                },
                Namespace {
                    mount: "/archive",
                    data_appliances: vec![(Appliance::es7990x(), 18)],
                    md_appliances: vec![(Appliance::es400nv(), 2)],
                    nominal_bw_gbs: 360.0,
                },
                Namespace {
                    mount: "/scratch",
                    data_appliances: vec![
                        (Appliance::es7990x(), 13),
                        (Appliance::es400nvx2(), 27),
                    ],
                    md_appliances: vec![(Appliance::es400nv(), 2)],
                    nominal_bw_gbs: 1300.0,
                },
            ],
        }
    }

    pub fn namespace(&self, mount: &str) -> Option<&Namespace> {
        self.namespaces.iter().find(|n| n.mount == mount)
    }

    /// Total DDN appliances (paper: 66 overall).
    pub fn appliance_count(&self) -> u32 {
        self.namespaces
            .iter()
            .flat_map(|n| n.data_appliances.iter().chain(n.md_appliances.iter()))
            .map(|(_, n)| *n)
            .sum()
    }

    /// Fast-tier raw capacity, PB (paper: 5.7 PB).
    pub fn fast_tier_raw_pb(&self) -> f64 {
        self.namespaces
            .iter()
            .flat_map(|n| n.data_appliances.iter())
            .filter(|(a, _)| a.name == "ES400NVX2")
            .map(|(a, n)| a.raw_tb * *n as f64 / 1000.0)
            .sum()
    }

    /// Capacity-tier raw capacity, PB (paper: 137.6 PB).
    pub fn capacity_tier_raw_pb(&self) -> f64 {
        self.namespaces
            .iter()
            .flat_map(|n| n.data_appliances.iter())
            .filter(|(a, _)| a.name == "ES7990X")
            .map(|(a, n)| a.raw_tb * *n as f64 / 1000.0)
            .sum()
    }
}

/// Lustre file striping: a file striped over `stripe_count` OSTs moves at
/// min(client link, stripe_count x per-OST share) — near-wire speed for
/// wide stripes (§2.3).
#[derive(Debug, Clone, Copy)]
pub struct Stripe {
    pub count: u32,
    pub size_mib: u32,
}

impl Stripe {
    /// Single-client file bandwidth, GB/s.
    pub fn file_bw_gbs(
        &self,
        client_link_gbs: f64,
        ns: &Namespace,
        write: bool,
    ) -> f64 {
        let pool = if write {
            ns.peak_write_gbs()
        } else {
            ns.peak_read_gbs()
        };
        let per_ost = pool / ns.ost_count() as f64;
        client_link_gbs.min(self.count.min(ns.ost_count()) as f64 * per_ost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_net_sizes() {
        let s = StorageSystem::leonardo();
        let home = s.namespace("/home").unwrap();
        let archive = s.namespace("/archive").unwrap();
        let scratch = s.namespace("/scratch").unwrap();
        // Table 3: 0.5 / 53.9 / 42.4 PiB net.
        assert!((home.net_pib() - 0.5).abs() < 0.03, "{}", home.net_pib());
        assert!(
            (archive.net_pib() - 53.9).abs() < 1.0,
            "{}",
            archive.net_pib()
        );
        assert!(
            (scratch.net_pib() - 42.4).abs() < 1.2,
            "{}",
            scratch.net_pib()
        );
    }

    #[test]
    fn table3_bandwidths() {
        let s = StorageSystem::leonardo();
        assert_eq!(s.namespace("/home").unwrap().nominal_bw_gbs, 240.0);
        assert_eq!(s.namespace("/archive").unwrap().nominal_bw_gbs, 360.0);
        assert_eq!(s.namespace("/scratch").unwrap().nominal_bw_gbs, 1300.0);
        // The nominal figure must not exceed what the media can deliver.
        for ns in &s.namespaces {
            assert!(
                ns.nominal_bw_gbs <= ns.peak_read_gbs() * 1.05,
                "{}: nominal {} > peak read {}",
                ns.mount,
                ns.nominal_bw_gbs,
                ns.peak_read_gbs()
            );
        }
    }

    #[test]
    fn appliance_census_is_66() {
        // §2.3: "the storage system consists of 66 DDN's appliances".
        assert_eq!(StorageSystem::leonardo().appliance_count(), 66);
    }

    #[test]
    fn tier_raw_capacities() {
        let s = StorageSystem::leonardo();
        assert!((s.fast_tier_raw_pb() - 5.7).abs() < 0.1, "{}", s.fast_tier_raw_pb());
        assert!(
            (s.capacity_tier_raw_pb() - 137.3).abs() < 1.0,
            "{}",
            s.capacity_tier_raw_pb()
        );
    }

    #[test]
    fn archive_uses_es7990x_only() {
        let s = StorageSystem::leonardo();
        let a = s.namespace("/archive").unwrap();
        assert_eq!(a.data_appliances.len(), 1);
        assert_eq!(a.data_appliances[0].0.name, "ES7990X");
        assert_eq!(a.data_appliances[0].1, 18);
    }

    #[test]
    fn port_limits_respected() {
        let a = Appliance::es400nvx2();
        // 800 Gbps = 100 GB/s ports; media 64 GB/s read is the binding cap.
        assert_eq!(a.deliverable_read_gbs(), a.read_gbs);
        assert!(a.deliverable_read_gbs() <= a.ports_gbps / 8.0);
    }

    #[test]
    fn wide_stripes_reach_near_wire_speed() {
        let s = StorageSystem::leonardo();
        let scratch = s.namespace("/scratch").unwrap();
        // A 400 Gbps (50 GB/s) client striping wide saturates its link.
        let wide = Stripe {
            count: 64,
            size_mib: 16,
        };
        assert!((wide.file_bw_gbs(50.0, scratch, false) - 50.0).abs() < 1e-9);
        // A single-OST file is OST-bound instead.
        let narrow = Stripe {
            count: 1,
            size_mib: 16,
        };
        assert!(narrow.file_bw_gbs(50.0, scratch, false) < 30.0);
    }

    #[test]
    fn stripe_bw_monotone_in_count() {
        let s = StorageSystem::leonardo();
        let ns = s.namespace("/scratch").unwrap();
        let mut last = 0.0;
        for c in [1u32, 2, 4, 8, 16, 128] {
            let bw = Stripe {
                count: c,
                size_mib: 4,
            }
            .file_bw_gbs(1e9, ns, true);
            assert!(bw >= last);
            last = bw;
        }
    }
}
