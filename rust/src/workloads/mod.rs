//! The application benchmarks of Table 6 (Appendix A.3): workload models
//! for QuantumEspresso, MILC, SPECFEM3D and PLUTO.
//!
//! Each application is characterised by its job size (the paper's), a
//! per-fleet work budget (node-seconds at nominal clocks, calibrated so
//! the paper's TTS is reproduced at the paper's node count), a
//! communication fraction that drives strong-scaling behaviour through
//! the network model, and component utilisations that drive
//! energy-to-solution through the power model. The utilisations are the
//! physically-meaningful decomposition of the paper's own ETS/TTS ratios
//! (see tests: each app's mean node power in watts is ETS/TTS).
//!
//! [`TraceGen`] synthesizes mixed HPC+AI *operational* traces — Poisson
//! arrivals, bimodal node counts and per-class boundness, the job-mix
//! shape the JUWELS Booster (Kesselheim et al., 2021) and Isambard-AI
//! (McIntosh-Smith et al., 2024) operations reports describe — for the
//! coordinator's day-replay and the scheduler throughput bench.

use crate::config::MachineConfig;
use crate::network::{Network, Placement};
use crate::power::{PowerModel, Utilization};
use crate::scheduler::{CheckpointPolicy, Job, Partition};
use crate::sim::{Event, ScheduledEvent};
use crate::topology::cell_pair_index;
use crate::util::rng::Rng;

/// One application benchmark.
#[derive(Debug, Clone)]
pub struct AppBenchmark {
    pub name: &'static str,
    pub domain: &'static str,
    /// Node count of the paper's run.
    pub ref_nodes: u32,
    /// Paper's time-to-solution, s.
    pub ref_tts: f64,
    /// Paper's energy-to-solution, kWh.
    pub ref_ets: f64,
    /// Fraction of runtime spent communicating at the reference size.
    pub comm_fraction: f64,
    /// Component utilisations during the run (fit from ETS/TTS).
    pub util: Utilization,
    /// Whether the code uses GPUs at all (PLUTO does not).
    pub uses_gpu: bool,
}

impl AppBenchmark {
    pub fn quantum_espresso() -> Self {
        AppBenchmark {
            name: "QuantumEspresso",
            domain: "Quantum Chemistry",
            ref_nodes: 12,
            ref_tts: 439.0,
            ref_ets: 1.14,
            comm_fraction: 0.25, // dense FFT/transpose heavy
            util: Utilization {
                cpu: 0.35,
                gpu: Some(0.086),
            },
            uses_gpu: true,
        }
    }

    pub fn milc() -> Self {
        AppBenchmark {
            name: "MILC",
            domain: "Quantum Chromodynamics",
            ref_nodes: 12,
            ref_tts: 178.0,
            ref_ets: 0.56,
            comm_fraction: 0.20, // 4-D halo exchange
            util: Utilization {
                cpu: 0.40,
                gpu: Some(0.186),
            },
            uses_gpu: true,
        }
    }

    pub fn specfem3d() -> Self {
        AppBenchmark {
            name: "SPECFEM3D",
            domain: "Solid Earth",
            ref_nodes: 16,
            ref_tts: 270.0,
            ref_ets: 1.43,
            comm_fraction: 0.12, // spectral elements, surface exchange
            util: Utilization {
                cpu: 0.30,
                gpu: Some(0.360),
            },
            uses_gpu: true,
        }
    }

    pub fn pluto() -> Self {
        AppBenchmark {
            name: "PLUTO",
            domain: "Astrophysics",
            ref_nodes: 32,
            ref_tts: 2874.0,
            ref_ets: 11.7,
            comm_fraction: 0.15,
            util: Utilization {
                cpu: 0.503,
                gpu: None, // paper: ETS from CPU power only
            },
            uses_gpu: false,
        }
    }

    /// All four Table 6 applications.
    pub fn table6() -> Vec<AppBenchmark> {
        vec![
            Self::quantum_espresso(),
            Self::milc(),
            Self::specfem3d(),
            Self::pluto(),
        ]
    }

    /// Total useful work in node-seconds (calibrated at the reference).
    pub fn work_node_seconds(&self) -> f64 {
        self.ref_nodes as f64 * self.ref_tts * (1.0 - self.comm_fraction)
    }

    /// Predicted time-to-solution on `nodes` nodes, seconds.
    ///
    /// Compute shrinks with node count; the communication term scales
    /// with the network model's effective bandwidth under `placement`
    /// relative to the single-cell reference.
    pub fn tts(&self, nodes: u32, net: &Network, placement: &Placement) -> f64 {
        let compute = self.work_node_seconds() / nodes as f64;
        let ref_bw = net.injection_gbs();
        let bw = net.effective_node_bw(placement).max(1e-9);
        // Per-node comm volume is roughly constant for these strong-ish
        // scaled runs; time scales with the reference comm share.
        let comm_ref = self.ref_tts * self.comm_fraction;
        let comm = comm_ref * (self.ref_nodes as f64 / nodes as f64).sqrt()
            * (ref_bw / bw);
        compute + comm
    }

    /// Energy-to-solution, kWh, via the power model (IT power, like the
    /// paper's accounting).
    pub fn ets(&self, nodes: u32, tts: f64, power: &PowerModel) -> f64 {
        power.energy_kwh(nodes, self.util, tts)
    }
}

/// Application classes of a mixed operational day. Each class fixes the
/// distributions a sampled job draws from: node count (bimodal:
/// a common small mode and a rarer large mode), nominal runtime, and
/// clock-boundness (1 = fully clock-bound, so DVFS hurts; low values are
/// memory/communication-bound and throttle almost for free).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppClass {
    /// Hero runs: wide jobs, long runtimes, compute-bound.
    HpcCapability,
    /// Bread-and-butter MPI jobs: small, moderate runtimes.
    HpcCapacity,
    /// Data-parallel training: bimodal between debug and full runs,
    /// memory/communication-bound.
    AiTraining,
    /// Inference/evaluation batches: tiny and short.
    AiInference,
}

impl AppClass {
    pub fn all() -> [AppClass; 4] {
        [
            AppClass::HpcCapability,
            AppClass::HpcCapacity,
            AppClass::AiTraining,
            AppClass::AiInference,
        ]
    }

    /// Sample a node count (bimodal per class).
    fn nodes(&self, rng: &mut Rng) -> u32 {
        match self {
            AppClass::HpcCapability => {
                if rng.f64() < 0.7 {
                    rng.range_u32(32, 64)
                } else {
                    rng.range_u32(128, 256)
                }
            }
            AppClass::HpcCapacity => {
                if rng.f64() < 0.7 {
                    rng.range_u32(1, 8)
                } else {
                    rng.range_u32(8, 32)
                }
            }
            AppClass::AiTraining => {
                if rng.f64() < 0.7 {
                    rng.range_u32(2, 16)
                } else {
                    rng.range_u32(32, 64)
                }
            }
            AppClass::AiInference => rng.range_u32(1, 4),
        }
    }

    /// Sample a nominal runtime, seconds.
    fn run_seconds(&self, rng: &mut Rng) -> f64 {
        match self {
            AppClass::HpcCapability => rng.range_f64(600.0, 3600.0),
            AppClass::HpcCapacity => rng.range_f64(600.0, 3600.0),
            AppClass::AiTraining => rng.range_f64(900.0, 5400.0),
            AppClass::AiInference => rng.range_f64(300.0, 1800.0),
        }
    }

    /// Sample a clock-boundness.
    fn boundness(&self, rng: &mut Rng) -> f64 {
        match self {
            AppClass::HpcCapability => rng.range_f64(0.75, 0.95),
            AppClass::HpcCapacity => rng.range_f64(0.50, 0.90),
            AppClass::AiTraining => rng.range_f64(0.20, 0.50),
            AppClass::AiInference => rng.range_f64(0.10, 0.40),
        }
    }

    /// Fraction of runtime spent communicating — the per-class lever
    /// the runtime-coupling model pulls: comm-bound classes stretch
    /// under fabric contention, compute-bound ones don't. Constant per
    /// class (no RNG draw) so traces generated before this field
    /// existed are byte-identical.
    pub fn comm_fraction(&self) -> f64 {
        match self {
            // Wide halo/collective-heavy MPI heroes.
            AppClass::HpcCapability => 0.25,
            // Bread-and-butter MPI, mostly node-local.
            AppClass::HpcCapacity => 0.15,
            // Data-parallel training: allreduce every step.
            AppClass::AiTraining => 0.35,
            // Tiny batches, nearly no fabric traffic.
            AppClass::AiInference => 0.05,
        }
    }

    /// Checkpoint/restart behaviour under fault kills — constant per
    /// class (no RNG draw, like [`AppClass::comm_fraction`]) so traces
    /// generated before this field existed are byte-identical. Hero
    /// runs and training jobs checkpoint (the operational practice the
    /// JUWELS Booster and Isambard-AI reports describe); short capacity
    /// and inference work just reruns.
    pub fn checkpoint_policy(&self) -> CheckpointPolicy {
        match self {
            AppClass::HpcCapability => CheckpointPolicy::Periodic(3600.0),
            AppClass::HpcCapacity => CheckpointPolicy::None,
            AppClass::AiTraining => CheckpointPolicy::Periodic(1800.0),
            AppClass::AiInference => CheckpointPolicy::None,
        }
    }
}

/// Deterministic generator of mixed HPC+AI arrival traces.
#[derive(Debug, Clone)]
pub struct TraceGen {
    pub seed: u64,
    /// Number of jobs to synthesize.
    pub jobs: usize,
    /// Window the Poisson arrivals cover, seconds.
    pub duration_s: f64,
    pub partition: Partition,
    /// Node-count cap (partition size).
    pub max_nodes: u32,
    /// Class mixture `(class, weight)`; weights need not sum to 1.
    pub mix: Vec<(AppClass, f64)>,
    /// Checkpoint policy override: `None` uses each class's own
    /// [`AppClass::checkpoint_policy`]; `Some` forces one policy on
    /// every job (the campaign's `--checkpoint` axis).
    pub checkpoint: Option<CheckpointPolicy>,
}

impl TraceGen {
    /// A day of mixed operations on the Booster partition, sized so the
    /// offered load roughly saturates the 3456 nodes (queues form,
    /// backfill matters) — the JUWELS/Isambard-AI style mixed day.
    pub fn booster_day(jobs: usize, seed: u64) -> Self {
        TraceGen {
            seed,
            jobs,
            duration_s: 86_400.0,
            partition: Partition::Booster,
            max_nodes: 3456,
            mix: vec![
                (AppClass::HpcCapability, 0.05),
                (AppClass::HpcCapacity, 0.45),
                (AppClass::AiTraining, 0.20),
                (AppClass::AiInference, 0.30),
            ],
            checkpoint: None,
        }
    }

    /// An AI-dominated burst day: training and inference own the
    /// partition (a "model release week" load shape), HPC bread-and-
    /// butter squeezed to the margins. The second mix axis of the
    /// campaign sweep.
    pub fn booster_ai_day(jobs: usize, seed: u64) -> Self {
        TraceGen {
            mix: vec![
                (AppClass::HpcCapability, 0.02),
                (AppClass::HpcCapacity, 0.18),
                (AppClass::AiTraining, 0.45),
                (AppClass::AiInference, 0.35),
            ],
            ..Self::booster_day(jobs, seed)
        }
    }

    /// A classic HPC-dominated day: capability heroes plus capacity MPI
    /// jobs, AI a trickle — the pre-AI-era LEONARDO load shape.
    pub fn booster_hpc_day(jobs: usize, seed: u64) -> Self {
        TraceGen {
            mix: vec![
                (AppClass::HpcCapability, 0.12),
                (AppClass::HpcCapacity, 0.68),
                (AppClass::AiTraining, 0.12),
                (AppClass::AiInference, 0.08),
            ],
            ..Self::booster_day(jobs, seed)
        }
    }

    /// Preset mixes by name — the mix axis of the campaign sweep grid
    /// (`"day"` mixed HPC+AI, `"ai"` AI-burst, `"hpc"` HPC-classic).
    /// `None` for an unknown name.
    pub fn named(mix: &str, jobs: usize, seed: u64) -> Option<Self> {
        match mix {
            "day" => Some(Self::booster_day(jobs, seed)),
            "ai" => Some(Self::booster_ai_day(jobs, seed)),
            "hpc" => Some(Self::booster_hpc_day(jobs, seed)),
            _ => None,
        }
    }

    /// The preset mix names [`TraceGen::named`] accepts.
    pub fn known_mixes() -> &'static [&'static str] {
        &["day", "ai", "hpc"]
    }

    fn pick_class(&self, rng: &mut Rng) -> AppClass {
        let total: f64 = self.mix.iter().map(|(_, w)| w).sum();
        let mut draw = rng.f64() * total;
        for &(class, w) in &self.mix {
            if draw < w {
                return class;
            }
            draw -= w;
        }
        self.mix.last().map(|&(c, _)| c).unwrap_or(AppClass::HpcCapacity)
    }

    /// Synthesize the trace: Poisson arrivals at rate `jobs/duration_s`,
    /// job shapes drawn per class. Deterministic in `seed`.
    pub fn generate(&self) -> Vec<Job> {
        assert!(self.duration_s > 0.0 && !self.mix.is_empty());
        let mut rng = Rng::new(self.seed);
        let rate = self.jobs as f64 / self.duration_s;
        let mut t = 0.0f64;
        (0..self.jobs)
            .map(|i| {
                // Exponential inter-arrival gap (1 - u in (0, 1]).
                t += -(1.0 - rng.f64()).ln() / rate;
                let class = self.pick_class(&mut rng);
                let nodes = class.nodes(&mut rng).clamp(1, self.max_nodes);
                let run_seconds = class.run_seconds(&mut rng);
                // Users overestimate wall time; EASY reservations rely on
                // est >= run.
                let est_seconds = run_seconds * rng.range_f64(1.05, 1.60);
                Job {
                    id: i as u64,
                    partition: self.partition,
                    nodes,
                    est_seconds,
                    run_seconds,
                    submit_time: t,
                    boundness: class.boundness(&mut rng),
                    comm_fraction: class.comm_fraction(),
                    // No RNG draw: byte-neutral for older traces.
                    checkpoint: self.checkpoint.unwrap_or_else(|| class.checkpoint_policy()),
                }
            })
            .collect()
    }
}

/// A seeded fault-injection trace: node-failure events (a per-node
/// MTBF with exponentially distributed repair times, failing `group`
/// nodes at a time — a blade/switch granularity) and link-degradation
/// episodes over the Booster partition's cell-pair bundles. Rendered as
/// [`crate::sim`] fault events (`NodeDown`/`NodeUp`,
/// `LinkDegraded`/`LinkRestored`) the scheduler consumes; every
/// failure emits its matching repair, even past `duration_s`, so
/// capacity always returns and no workload can strand.
/// [`FaultTrace::none`] renders no events at all, keeping fault-free
/// campaigns byte-identical to runs that predate fault injection.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultTrace {
    pub seed: u64,
    /// Window failures arrive in, seconds (repairs may land later).
    pub duration_s: f64,
    /// Mean time between failures per node, seconds (0 = no node
    /// faults).
    pub node_mtbf_s: f64,
    /// Mean repair time of a failed node group, seconds.
    pub repair_mean_s: f64,
    /// Nodes taken down per failure event.
    pub group: u32,
    /// Mean time between degradation episodes per link bundle, seconds
    /// (0 = no link faults).
    pub link_mtbf_s: f64,
    /// Mean duration of a degradation episode, seconds.
    pub link_repair_mean_s: f64,
    /// Capacity factor of a degraded bundle, in (0, 1].
    pub degraded_factor: f64,
}

impl Default for FaultTrace {
    fn default() -> Self {
        Self::none()
    }
}

impl FaultTrace {
    /// The empty trace: no failure processes, no events — the
    /// fault-free axis value.
    pub fn none() -> Self {
        FaultTrace {
            seed: 0,
            duration_s: 0.0,
            node_mtbf_s: 0.0,
            repair_mean_s: 0.0,
            group: 0,
            link_mtbf_s: 0.0,
            link_repair_mean_s: 0.0,
            degraded_factor: 1.0,
        }
    }

    /// No failure process is armed (renders zero events).
    pub fn is_none(&self) -> bool {
        self.node_mtbf_s <= 0.0 && self.link_mtbf_s <= 0.0
    }

    /// Short report label for the campaign's fault axis.
    pub fn label(&self) -> String {
        if self.is_none() {
            return "none".to_string();
        }
        let mut s = String::new();
        if self.node_mtbf_s > 0.0 {
            s.push_str(&format!("mtbf{:.0}k", self.node_mtbf_s / 1000.0));
        }
        if self.link_mtbf_s > 0.0 {
            if !s.is_empty() {
                s.push('+');
            }
            s.push_str(&format!("link{:.0}k", self.link_mtbf_s / 1000.0));
        }
        s
    }

    /// Render the trace against a machine: Poisson failure arrivals at
    /// the partition-aggregate rate (`booster nodes / node_mtbf_s`),
    /// each picking a uniform Booster cell and downing `group` nodes,
    /// plus link episodes over the Booster cell pairs. Deterministic in
    /// `seed`; events are emitted in arrival order (paired repairs
    /// directly after their failures), which fixes the rank order the
    /// campaign's divergent-band scheduling relies on.
    pub fn events(&self, cfg: &MachineConfig) -> Vec<ScheduledEvent> {
        let mut out = Vec::new();
        if self.is_none() || self.duration_s <= 0.0 {
            return out;
        }
        let booster: Vec<u32> = cfg
            .cells
            .iter()
            .enumerate()
            .filter(|(_, c)| c.groups.iter().map(|g| g.gpu_nodes()).sum::<u32>() > 0)
            .map(|(i, _)| i as u32)
            .collect();
        if booster.is_empty() {
            return out;
        }
        let total_nodes: u32 = cfg
            .cells
            .iter()
            .flat_map(|c| c.groups.iter())
            .map(|g| g.gpu_nodes())
            .sum();
        let mut rng = Rng::new(self.seed);
        if self.node_mtbf_s > 0.0 && self.group > 0 && total_nodes > 0 {
            let rate = total_nodes as f64 / self.node_mtbf_s;
            let mut t = 0.0f64;
            loop {
                t += -(1.0 - rng.f64()).ln() / rate;
                if t >= self.duration_s {
                    break;
                }
                let cell = *rng.choose(&booster);
                let repair = -(1.0 - rng.f64()).ln() * self.repair_mean_s.max(0.0);
                out.push(ScheduledEvent::at(
                    t,
                    Event::NodeDown {
                        cell,
                        nodes: self.group,
                    },
                ));
                out.push(ScheduledEvent::at(
                    t + repair,
                    Event::NodeUp {
                        cell,
                        nodes: self.group,
                    },
                ));
            }
        }
        if self.link_mtbf_s > 0.0 && booster.len() > 1 {
            let pairs = booster.len() * (booster.len() - 1) / 2;
            let rate = pairs as f64 / self.link_mtbf_s;
            let n = cfg.cells.len();
            let mut t = 0.0f64;
            loop {
                t += -(1.0 - rng.f64()).ln() / rate;
                if t >= self.duration_s {
                    break;
                }
                let a = *rng.choose(&booster);
                let b = loop {
                    let b = *rng.choose(&booster);
                    if b != a {
                        break b;
                    }
                };
                let bundle = cell_pair_index(n, a, b) as u32;
                let repair = -(1.0 - rng.f64()).ln() * self.link_repair_mean_s.max(0.0);
                out.push(ScheduledEvent::at(
                    t,
                    Event::LinkDegraded {
                        bundle,
                        factor: self.degraded_factor,
                    },
                ));
                out.push(ScheduledEvent::at(t + repair, Event::LinkRestored { bundle }));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::hardware::NodeSpec;
    use crate::network::Network;
    use crate::topology::Topology;

    fn infra() -> (Network, PowerModel) {
        let cfg = MachineConfig::leonardo();
        let node = cfg.gpu_node_spec().unwrap().clone();
        let net = Network::new(Topology::build(&cfg), node.injection_gbps());
        let power = PowerModel::new(NodeSpec::davinci(), cfg.pue);
        (net, power)
    }

    fn one_cell(nodes: u32) -> Placement {
        Placement {
            nodes_per_cell: vec![(0, nodes)],
        }
    }

    #[test]
    fn table6_tts_reproduced_at_reference_size() {
        let (net, _) = infra();
        for app in AppBenchmark::table6() {
            let tts = app.tts(app.ref_nodes, &net, &one_cell(app.ref_nodes));
            let err = (tts - app.ref_tts).abs() / app.ref_tts;
            assert!(err < 0.01, "{}: {tts} vs {}", app.name, app.ref_tts);
        }
    }

    #[test]
    fn table6_ets_reproduced_at_reference_size() {
        let (net, power) = infra();
        for app in AppBenchmark::table6() {
            let tts = app.tts(app.ref_nodes, &net, &one_cell(app.ref_nodes));
            let ets = app.ets(app.ref_nodes, tts, &power);
            let err = (ets - app.ref_ets).abs() / app.ref_ets;
            assert!(err < 0.05, "{}: {ets} vs {}", app.name, app.ref_ets);
        }
    }

    #[test]
    fn mean_node_power_decomposition_matches_paper_ratios() {
        // ETS/TTS gives the paper's mean power; our utilisation fit must
        // reproduce it: QE 779 W, MILC 944 W, SPECFEM3D 1191 W, PLUTO 458 W.
        let (_, power) = infra();
        let expect = [779.0, 944.0, 1191.0, 458.0];
        for (app, want) in AppBenchmark::table6().iter().zip(expect) {
            let w = power.node_power_w(app.util);
            assert!((w - want).abs() / want < 0.02, "{}: {w} vs {want}", app.name);
        }
    }

    #[test]
    fn more_nodes_reduce_tts() {
        let (net, _) = infra();
        let app = AppBenchmark::milc();
        let t12 = app.tts(12, &net, &one_cell(12));
        let t48 = app.tts(48, &net, &one_cell(48));
        assert!(t48 < t12);
        // But not perfectly: communication does not vanish.
        assert!(t48 > t12 / 4.0);
    }

    #[test]
    fn pluto_is_cpu_only() {
        let app = AppBenchmark::pluto();
        assert!(!app.uses_gpu);
        assert!(app.util.gpu.is_none());
    }

    #[test]
    fn spread_placement_increases_tts() {
        let (net, _) = infra();
        let app = AppBenchmark::milc();
        let packed = app.tts(512, &net, &one_cell(512));
        let spread = Placement {
            nodes_per_cell: (0..16).map(|c| (c, 32)).collect(),
        };
        let scattered = app.tts(512, &net, &spread);
        assert!(scattered >= packed, "{scattered} < {packed}");
    }

    #[test]
    fn tracegen_is_deterministic_and_well_formed() {
        let tg = TraceGen::booster_day(500, 42);
        let a = tg.generate();
        let b = tg.generate();
        assert_eq!(a.len(), 500);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.nodes, y.nodes);
            assert_eq!(x.submit_time, y.submit_time);
            assert_eq!(x.run_seconds, y.run_seconds);
        }
        let mut last = 0.0;
        for j in &a {
            assert!(j.nodes >= 1 && j.nodes <= 3456);
            assert!(j.run_seconds > 0.0);
            assert!(j.est_seconds >= j.run_seconds, "EASY needs est >= run");
            assert!((0.0..=1.0).contains(&j.boundness));
            assert!((0.0..=1.0).contains(&j.comm_fraction));
            assert!(j.submit_time >= last, "arrivals must be ordered");
            last = j.submit_time;
        }
        // Per-class comm fractions show up in the mix: AI-burst days are
        // comm-heavier than HPC-classic days on average.
        let comm = |js: &[Job]| {
            js.iter().map(|j| j.comm_fraction).sum::<f64>() / js.len() as f64
        };
        let ai = TraceGen::booster_ai_day(2000, 5).generate();
        let hpc = TraceGen::booster_hpc_day(2000, 5).generate();
        assert!(comm(&ai) > comm(&hpc), "{} vs {}", comm(&ai), comm(&hpc));
    }

    #[test]
    fn tracegen_arrivals_roughly_poisson() {
        let tg = TraceGen::booster_day(2000, 7);
        let jobs = tg.generate();
        // Mean inter-arrival gap should be close to duration/jobs.
        let span = jobs.last().unwrap().submit_time;
        let expect = tg.duration_s;
        assert!(
            (span - expect).abs() / expect < 0.15,
            "arrival span {span} vs {expect}"
        );
    }

    #[test]
    fn tracegen_mix_is_bimodal_in_nodes() {
        let jobs = TraceGen::booster_day(2000, 11).generate();
        let small = jobs.iter().filter(|j| j.nodes <= 8).count();
        let large = jobs.iter().filter(|j| j.nodes >= 64).count();
        assert!(small > 500, "small mode missing: {small}");
        assert!(large > 20, "large mode missing: {large}");
    }

    #[test]
    fn tracegen_different_seeds_differ() {
        let a = TraceGen::booster_day(100, 1).generate();
        let b = TraceGen::booster_day(100, 2).generate();
        assert!(a.iter().zip(&b).any(|(x, y)| x.nodes != y.nodes));
    }

    #[test]
    fn per_class_checkpoint_policies_flow_into_traces() {
        let jobs = TraceGen::booster_day(500, 42).generate();
        assert!(jobs
            .iter()
            .any(|j| matches!(j.checkpoint, CheckpointPolicy::Periodic(_))));
        assert!(jobs.iter().any(|j| j.checkpoint == CheckpointPolicy::None));
        // The override forces one policy on every job without touching
        // any other sampled field (no RNG draw).
        let mut tg = TraceGen::booster_day(500, 42);
        tg.checkpoint = Some(CheckpointPolicy::Periodic(600.0));
        let forced = tg.generate();
        for (a, b) in jobs.iter().zip(&forced) {
            assert_eq!(b.checkpoint, CheckpointPolicy::Periodic(600.0));
            assert_eq!(a.nodes, b.nodes);
            assert_eq!(a.submit_time, b.submit_time);
            assert_eq!(a.run_seconds, b.run_seconds);
            assert_eq!(a.boundness, b.boundness);
        }
    }

    #[test]
    fn fault_trace_none_renders_no_events() {
        let cfg = MachineConfig::leonardo();
        assert!(FaultTrace::none().is_none());
        assert!(FaultTrace::none().events(&cfg).is_empty());
        assert_eq!(FaultTrace::none().label(), "none");
    }

    #[test]
    fn fault_trace_is_deterministic_and_paired() {
        let cfg = MachineConfig::leonardo();
        let ft = FaultTrace {
            seed: 7,
            duration_s: 86_400.0,
            node_mtbf_s: 2.0e7,
            repair_mean_s: 3600.0,
            group: 30,
            link_mtbf_s: 5.0e6,
            link_repair_mean_s: 1800.0,
            degraded_factor: 0.5,
        };
        let a = ft.events(&cfg);
        let b = ft.events(&cfg);
        assert_eq!(a.len(), b.len());
        assert!(!a.is_empty(), "expected some failures in a day");
        let mut downs = 0i64;
        let mut degrades = 0i64;
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.time, y.time);
            match &x.event {
                Event::NodeDown { cell, nodes } => {
                    assert!(*nodes == 30, "group size respected");
                    assert!((*cell as usize) < cfg.cells.len());
                    assert!(x.time < ft.duration_s, "failures inside the window");
                    downs += 1;
                }
                Event::NodeUp { .. } => downs -= 1,
                Event::LinkDegraded { factor, .. } => {
                    assert_eq!(*factor, 0.5);
                    degrades += 1;
                }
                Event::LinkRestored { .. } => degrades -= 1,
                other => panic!("unexpected event {other:?}"),
            }
        }
        assert_eq!(downs, 0, "every NodeDown has its NodeUp");
        assert_eq!(degrades, 0, "every LinkDegraded has its LinkRestored");
        assert!(!ft.label().is_empty());
        // Different seeds give different traces.
        let c = FaultTrace { seed: 8, ..ft.clone() };
        let c = c.events(&cfg);
        assert!(
            a.len() != c.len() || a.iter().zip(&c).any(|(x, y)| x.time != y.time),
            "seed must matter"
        );
    }

    #[test]
    fn named_mixes_resolve_and_differ_in_shape() {
        for name in TraceGen::known_mixes() {
            let tg = TraceGen::named(name, 500, 3).expect("known mix");
            assert_eq!(tg.jobs, 500);
            assert_eq!(tg.seed, 3);
            assert!(!tg.generate().is_empty());
        }
        assert!(TraceGen::named("bogus", 10, 0).is_none());
        // The AI day is training/inference-heavy relative to the HPC day.
        let ai = TraceGen::booster_ai_day(2000, 5).generate();
        let hpc = TraceGen::booster_hpc_day(2000, 5).generate();
        let big = |js: &[Job]| js.iter().filter(|j| j.nodes >= 64).count();
        assert!(big(&hpc) > big(&ai), "hpc mix lost its capability mode");
        let bound = |js: &[Job]| {
            js.iter().map(|j| j.boundness).sum::<f64>() / js.len() as f64
        };
        assert!(
            bound(&hpc) > bound(&ai),
            "AI jobs should be less clock-bound on average"
        );
    }
}
