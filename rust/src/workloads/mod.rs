//! The application benchmarks of Table 6 (Appendix A.3): workload models
//! for QuantumEspresso, MILC, SPECFEM3D and PLUTO.
//!
//! Each application is characterised by its job size (the paper's), a
//! per-fleet work budget (node-seconds at nominal clocks, calibrated so
//! the paper's TTS is reproduced at the paper's node count), a
//! communication fraction that drives strong-scaling behaviour through
//! the network model, and component utilisations that drive
//! energy-to-solution through the power model. The utilisations are the
//! physically-meaningful decomposition of the paper's own ETS/TTS ratios
//! (see tests: each app's mean node power in watts is ETS/TTS).



use crate::network::{Network, Placement};
use crate::power::{PowerModel, Utilization};

/// One application benchmark.
#[derive(Debug, Clone)]
pub struct AppBenchmark {
    pub name: &'static str,
    pub domain: &'static str,
    /// Node count of the paper's run.
    pub ref_nodes: u32,
    /// Paper's time-to-solution, s.
    pub ref_tts: f64,
    /// Paper's energy-to-solution, kWh.
    pub ref_ets: f64,
    /// Fraction of runtime spent communicating at the reference size.
    pub comm_fraction: f64,
    /// Component utilisations during the run (fit from ETS/TTS).
    pub util: Utilization,
    /// Whether the code uses GPUs at all (PLUTO does not).
    pub uses_gpu: bool,
}

impl AppBenchmark {
    pub fn quantum_espresso() -> Self {
        AppBenchmark {
            name: "QuantumEspresso",
            domain: "Quantum Chemistry",
            ref_nodes: 12,
            ref_tts: 439.0,
            ref_ets: 1.14,
            comm_fraction: 0.25, // dense FFT/transpose heavy
            util: Utilization {
                cpu: 0.35,
                gpu: Some(0.086),
            },
            uses_gpu: true,
        }
    }

    pub fn milc() -> Self {
        AppBenchmark {
            name: "MILC",
            domain: "Quantum Chromodynamics",
            ref_nodes: 12,
            ref_tts: 178.0,
            ref_ets: 0.56,
            comm_fraction: 0.20, // 4-D halo exchange
            util: Utilization {
                cpu: 0.40,
                gpu: Some(0.186),
            },
            uses_gpu: true,
        }
    }

    pub fn specfem3d() -> Self {
        AppBenchmark {
            name: "SPECFEM3D",
            domain: "Solid Earth",
            ref_nodes: 16,
            ref_tts: 270.0,
            ref_ets: 1.43,
            comm_fraction: 0.12, // spectral elements, surface exchange
            util: Utilization {
                cpu: 0.30,
                gpu: Some(0.360),
            },
            uses_gpu: true,
        }
    }

    pub fn pluto() -> Self {
        AppBenchmark {
            name: "PLUTO",
            domain: "Astrophysics",
            ref_nodes: 32,
            ref_tts: 2874.0,
            ref_ets: 11.7,
            comm_fraction: 0.15,
            util: Utilization {
                cpu: 0.503,
                gpu: None, // paper: ETS from CPU power only
            },
            uses_gpu: false,
        }
    }

    /// All four Table 6 applications.
    pub fn table6() -> Vec<AppBenchmark> {
        vec![
            Self::quantum_espresso(),
            Self::milc(),
            Self::specfem3d(),
            Self::pluto(),
        ]
    }

    /// Total useful work in node-seconds (calibrated at the reference).
    pub fn work_node_seconds(&self) -> f64 {
        self.ref_nodes as f64 * self.ref_tts * (1.0 - self.comm_fraction)
    }

    /// Predicted time-to-solution on `nodes` nodes, seconds.
    ///
    /// Compute shrinks with node count; the communication term scales
    /// with the network model's effective bandwidth under `placement`
    /// relative to the single-cell reference.
    pub fn tts(&self, nodes: u32, net: &Network, placement: &Placement) -> f64 {
        let compute = self.work_node_seconds() / nodes as f64;
        let ref_bw = net.injection_gbs();
        let bw = net.effective_node_bw(placement).max(1e-9);
        // Per-node comm volume is roughly constant for these strong-ish
        // scaled runs; time scales with the reference comm share.
        let comm_ref = self.ref_tts * self.comm_fraction;
        let comm = comm_ref * (self.ref_nodes as f64 / nodes as f64).sqrt()
            * (ref_bw / bw);
        compute + comm
    }

    /// Energy-to-solution, kWh, via the power model (IT power, like the
    /// paper's accounting).
    pub fn ets(&self, nodes: u32, tts: f64, power: &PowerModel) -> f64 {
        power.energy_kwh(nodes, self.util, tts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::hardware::NodeSpec;
    use crate::network::Network;
    use crate::topology::Topology;

    fn infra() -> (Network, PowerModel) {
        let cfg = MachineConfig::leonardo();
        let node = cfg.gpu_node_spec().unwrap().clone();
        let net = Network::new(Topology::build(&cfg), node.injection_gbps());
        let power = PowerModel::new(NodeSpec::davinci(), cfg.pue);
        (net, power)
    }

    fn one_cell(nodes: u32) -> Placement {
        Placement {
            nodes_per_cell: vec![(0, nodes)],
        }
    }

    #[test]
    fn table6_tts_reproduced_at_reference_size() {
        let (net, _) = infra();
        for app in AppBenchmark::table6() {
            let tts = app.tts(app.ref_nodes, &net, &one_cell(app.ref_nodes));
            let err = (tts - app.ref_tts).abs() / app.ref_tts;
            assert!(err < 0.01, "{}: {tts} vs {}", app.name, app.ref_tts);
        }
    }

    #[test]
    fn table6_ets_reproduced_at_reference_size() {
        let (net, power) = infra();
        for app in AppBenchmark::table6() {
            let tts = app.tts(app.ref_nodes, &net, &one_cell(app.ref_nodes));
            let ets = app.ets(app.ref_nodes, tts, &power);
            let err = (ets - app.ref_ets).abs() / app.ref_ets;
            assert!(err < 0.05, "{}: {ets} vs {}", app.name, app.ref_ets);
        }
    }

    #[test]
    fn mean_node_power_decomposition_matches_paper_ratios() {
        // ETS/TTS gives the paper's mean power; our utilisation fit must
        // reproduce it: QE 779 W, MILC 944 W, SPECFEM3D 1191 W, PLUTO 458 W.
        let (_, power) = infra();
        let expect = [779.0, 944.0, 1191.0, 458.0];
        for (app, want) in AppBenchmark::table6().iter().zip(expect) {
            let w = power.node_power_w(app.util);
            assert!((w - want).abs() / want < 0.02, "{}: {w} vs {want}", app.name);
        }
    }

    #[test]
    fn more_nodes_reduce_tts() {
        let (net, _) = infra();
        let app = AppBenchmark::milc();
        let t12 = app.tts(12, &net, &one_cell(12));
        let t48 = app.tts(48, &net, &one_cell(48));
        assert!(t48 < t12);
        // But not perfectly: communication does not vanish.
        assert!(t48 > t12 / 4.0);
    }

    #[test]
    fn pluto_is_cpu_only() {
        let app = AppBenchmark::pluto();
        assert!(!app.uses_gpu);
        assert!(app.util.gpu.is_none());
    }

    #[test]
    fn spread_placement_increases_tts() {
        let (net, _) = infra();
        let app = AppBenchmark::milc();
        let packed = app.tts(512, &net, &one_cell(512));
        let spread = Placement {
            nodes_per_cell: (0..16).map(|c| (c, 32)).collect(),
        };
        let scattered = app.tts(512, &net, &spread);
        assert!(scattered >= packed, "{scattered} < {packed}");
    }
}
