//! Deterministic discrete-event simulation kernel — the shared clock the
//! scheduler, network, power and coordinator layers all march to.
//!
//! The seed modelled each subsystem with its own hand-rolled notion of
//! virtual time (the scheduler's scan-and-rescan loop, the telemetry
//! scrape loop, per-table network evaluations). This module extracts the
//! one thing they all need — *a totally ordered stream of timestamped
//! events* — so that mixed HPC+AI operational scenarios (the JUWELS
//! Booster / Isambard-AI style day traces) can drive every layer from a
//! single queue:
//!
//! * [`Clock`] — monotone virtual time in seconds;
//! * [`EventQueue`] — a `BinaryHeap` min-queue of [`Event`]s ordered by
//!   `(time, insertion seq)`, so equal-time events pop in the order they
//!   were scheduled and runs are bit-for-bit reproducible;
//! * [`Component`] — anything that reacts to events
//!   (`on_event(&mut self, now, ev, out)` pushing follow-up events into
//!   `out`) and may do work once a timestamp's batch has fully drained
//!   (`on_quiescent`);
//! * [`Simulation`] — the driver loop: pop the earliest batch, dispatch
//!   each event to every component in registration order, feed pushed
//!   events back into the queue, then give components their quiescent
//!   callback.
//!
//! Batching semantics replicate the scheduler's legacy loop exactly: all
//! events at the batch time are processed together, and an [`Event::End`]
//! within [`TIME_EPS`] of the batch time joins it (the legacy loop
//! completed jobs whose end fell within `1e-9` of the wake-up instant).
//! `Submit`s inside that window do *not* join — the legacy loop admitted
//! arrivals only at `submit_time <= now`.
//!
//! ## Provisional events
//!
//! A scheduled [`Event::End`] is *provisional*: the scheduler's coupled
//! mode may re-time it when the machine state around the job changes
//! (congestion, a power-cap move). Invalidation is generation-stamped
//! and lazy — the owner bumps the job's generation, enqueues a fresh
//! `End`, and vetoes the stale one at pop time through
//! [`Component::accept_event`], so the queue itself never needs a
//! decrease-key and the `(time, seq)` FIFO tie-break stays intact.
//! [`Event::Retime`] notifies observers of the rate change so they can
//! close a piecewise-constant segment (energy integration).
//!
//! ## Hot-path discipline
//!
//! The dispatch loop is allocation-free in steady state: components
//! write follow-up events into a caller-owned scratch buffer
//! (`out: &mut Vec<ScheduledEvent>`) that [`Simulation::run`] drains
//! into the queue and reuses for every dispatch, and `Start`/`End`
//! events carry their placement as a shared [`Cells`]
//! (`Arc<[(cell, nodes)]>`) so the scheduler, power monitor, congestion
//! tracker and telemetry scraper all read one interned copy instead of
//! each event cloning the cell list.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

/// Job identifier used in lifecycle events.
pub type JobId = u64;

/// A shared placement payload: `(cell id, node count)` pairs. `Start`
/// and `End` events of one job hold clones of the same `Arc`, so the
/// placement is materialised once per job, not once per event.
pub type Cells = Arc<[(u32, u32)]>;

/// Completion tolerance: an `End` within this window of a batch time is
/// processed with the batch (inherited from the legacy scheduler loop).
pub const TIME_EPS: f64 = 1e-9;

/// Sequence-number floor for *divergent* events: injected scenario
/// events (cap moves scheduled upfront by a streaming sweep, or pushed
/// at fork time by a divergence-tree sweep) are stamped
/// `DIVERGENT_SEQ_BASE + rank` instead of the running FIFO counter, so
/// they tie-break after every runtime-emitted event at the same
/// timestamp *no matter when they were pushed*. That is what keeps a
/// forked suffix byte-identical to an uninterrupted replay that had the
/// same event sitting in the queue from t=0.
pub const DIVERGENT_SEQ_BASE: u64 = 1 << 63;

/// Totally ordered wrapper over `f64` seconds (orders by `total_cmp`;
/// pushes assert finiteness so NaN never enters the queue).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimTime(pub f64);

impl Eq for SimTime {}

impl PartialOrd for SimTime {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for SimTime {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// The event vocabulary of the machine-operations domain.
///
/// `Start`/`End` carry the placement as shared [`Cells`] so observers
/// (power, telemetry, network congestion) need no access to scheduler
/// internals and no per-observer copies are made.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A job arrived in the scheduler queue.
    Submit { job: JobId },
    /// A job began running on `cells` at DVFS scale `dvfs_scale`.
    Start {
        job: JobId,
        booster: bool,
        dvfs_scale: f64,
        cells: Cells,
    },
    /// A job finished and released `cells`.
    ///
    /// `gen` is the generation stamp of this completion: the scheduler's
    /// coupled mode re-times provisional `End`s by bumping the job's
    /// generation and enqueueing a fresh `End`, leaving the stale one in
    /// the queue to be skipped at pop time (see
    /// [`Component::accept_event`]). Uncoupled paths always emit gen 0.
    End {
        job: JobId,
        booster: bool,
        cells: Cells,
        gen: u64,
    },
    /// The facility power cap changed (`None` lifts the cap).
    CapChange { cap_mw: Option<f64> },
    /// A running job's provisional completion moved (coupled mode): it
    /// now runs at `dvfs_scale` and its current `End` is scheduled at
    /// `end`. Observers use this to close a piecewise-constant rate
    /// segment (the power monitor re-weights dynamic power and samples,
    /// so capped intervals show up in joules, not just watts).
    Retime {
        job: JobId,
        dvfs_scale: f64,
        end: f64,
    },
    /// `nodes` nodes of `cell` failed. The scheduler shrinks the cell's
    /// free pool (killing or checkpoint-requeueing running jobs if the
    /// free capacity doesn't cover the loss) and re-times survivors.
    NodeDown { cell: u32, nodes: u32 },
    /// `nodes` previously failed nodes of `cell` were repaired and
    /// rejoin the free pool (clamped to what is actually down — a
    /// repair can never double-free).
    NodeUp { cell: u32, nodes: u32 },
    /// Global-link bundle `bundle` degraded to `factor` (0 < factor
    /// <= 1) of its nominal capacity. Priced by the congestion-coupled
    /// retimer through [`crate::network::Network::set_link_health`].
    LinkDegraded { bundle: u32, factor: f64 },
    /// Bundle `bundle` restored to nominal capacity.
    LinkRestored { bundle: u32 },
    /// A running job was killed by a fault. Emitted by the scheduler so
    /// observers unwind their `Start` bookkeeping; `wasted_s` is the
    /// wall-clock work lost (elapsed minus checkpointed progress) the
    /// power monitor attributes as wasted joules. `requeued` tells
    /// telemetry whether the job resubmits (checkpointed) or reworks
    /// from scratch.
    Kill {
        job: JobId,
        booster: bool,
        cells: Cells,
        wasted_s: f64,
        requeued: bool,
    },
}

impl Event {
    pub fn is_end(&self) -> bool {
        matches!(self, Event::End { .. })
    }

    /// The job this event concerns, if any.
    pub fn job(&self) -> Option<JobId> {
        match self {
            Event::Submit { job }
            | Event::Start { job, .. }
            | Event::End { job, .. }
            | Event::Retime { job, .. }
            | Event::Kill { job, .. } => Some(*job),
            Event::CapChange { .. }
            | Event::NodeDown { .. }
            | Event::NodeUp { .. }
            | Event::LinkDegraded { .. }
            | Event::LinkRestored { .. } => None,
        }
    }

    /// Total node count of a `Start`/`End`/`Kill` placement (0
    /// otherwise).
    pub fn nodes(&self) -> u32 {
        match self {
            Event::Start { cells, .. }
            | Event::End { cells, .. }
            | Event::Kill { cells, .. } => cells.iter().map(|&(_, n)| n).sum(),
            _ => 0,
        }
    }
}

/// An event bound to a future instant.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduledEvent {
    pub time: f64,
    pub event: Event,
}

impl ScheduledEvent {
    pub fn at(time: f64, event: Event) -> Self {
        ScheduledEvent { time, event }
    }
}

/// A simulation participant. Events are dispatched to every component in
/// registration order; events pushed into `out` are fed back into the
/// queue. `out` is a scratch buffer owned by the driver and reused
/// across dispatches — implementations must only `push` to it, never
/// clear or drain it.
///
/// `on_quiescent` fires once per timestamp after the batch at that time
/// has fully drained — schedule follow-up work (e.g. a scheduling pass)
/// there. Events it pushes at the *same* timestamp form a new batch and
/// trigger another quiescent callback, so implementations must be
/// idempotent at a fixed time (track a dirty flag).
pub trait Component {
    fn on_event(&mut self, now: f64, ev: &Event, out: &mut Vec<ScheduledEvent>);

    fn on_quiescent(&mut self, _now: f64, _out: &mut Vec<ScheduledEvent>) {}

    /// Pre-dispatch validity check: return `false` to drop the popped
    /// event before *any* component sees it. The scheduler's coupled
    /// mode uses this to skip stale generation-stamped `End`s that were
    /// re-timed after they were enqueued — the skip happens at pop
    /// time, so queue order (and the FIFO tie-break) is untouched.
    /// Default accepts everything.
    fn accept_event(&mut self, _now: f64, _ev: &Event) -> bool {
        true
    }

    /// Capture the component's run state into an internal snapshot slot
    /// (the component owns its buffer so repeated snapshots reuse the
    /// allocation). Default: stateless component, nothing to save.
    fn snapshot(&mut self) {}

    /// Restore the state captured by the last [`Component::snapshot`].
    /// Calling it without a prior snapshot is a contract violation;
    /// implementations may panic. Default: stateless, nothing to do.
    fn restore(&mut self) {}
}

/// Monotone virtual clock, seconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct Clock {
    now: f64,
}

impl Clock {
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance to `t` (must not move backwards).
    pub fn advance_to(&mut self, t: f64) {
        assert!(
            t.is_finite() && t >= self.now,
            "clock regression: {} -> {t}",
            self.now
        );
        self.now = t;
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct Entry {
    time: SimTime,
    seq: u64,
    event: Event,
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

// Event lacks Eq (f64 payloads); Entry equality is (time, seq), which is
// unique per push, so derived PartialEq on Event is never consulted by
// the heap ordering.
impl Eq for Event {}

/// Deterministic min-queue of timestamped events.
#[derive(Debug, Clone, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Entry>>,
    seq: u64,
}

impl EventQueue {
    pub fn push(&mut self, time: f64, event: Event) {
        assert!(time.is_finite(), "non-finite event time {time}");
        self.heap.push(Reverse(Entry {
            time: SimTime(time),
            seq: self.seq,
            event,
        }));
        self.seq += 1;
    }

    /// Push with an explicit sequence number in the divergent band
    /// (`DIVERGENT_SEQ_BASE + rank`) instead of the FIFO counter. The
    /// counter is *not* advanced, so the ordering of normal pushes is
    /// unaffected. Callers must use distinct ranks per timestamp —
    /// duplicate `(time, seq)` keys would leave the tie order at the
    /// heap's mercy.
    pub fn push_ranked(&mut self, time: f64, event: Event, rank: u64) {
        assert!(time.is_finite(), "non-finite event time {time}");
        self.heap.push(Reverse(Entry {
            time: SimTime(time),
            seq: DIVERGENT_SEQ_BASE + rank,
            event,
        }));
    }

    pub fn pop(&mut self) -> Option<(f64, Event)> {
        self.heap.pop().map(|Reverse(e)| (e.time.0, e.event))
    }

    /// Drop every pending event and rewind the FIFO counter, keeping the
    /// heap's backing allocation (arena reuse across scenarios).
    pub fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
    }

    /// Capacity of the backing heap allocation — asserted stable by the
    /// arena identity test so snapshot churn never reallocates.
    pub fn capacity(&self) -> usize {
        self.heap.capacity()
    }

    /// Timestamp of the earliest pending event.
    pub fn next_time(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse(e)| e.time.0)
    }

    /// Whether the earliest pending event is an `End`.
    pub fn next_is_end(&self) -> bool {
        self.heap
            .peek()
            .map(|Reverse(e)| e.event.is_end())
            .unwrap_or(false)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// A saved point-in-time image of a [`Simulation`]: clock, pending
/// events (with their `(time, seq)` stamps intact) and dispatch
/// counters. Produced by [`Simulation::save_into`] into a caller-owned
/// buffer so repeated snapshots reuse the entry allocation.
#[derive(Debug, Clone, Default)]
pub struct SimSnapshot {
    now: f64,
    entries: Vec<Entry>,
    seq: u64,
    events_processed: u64,
    events_skipped: u64,
}

/// The driver: clock + queue + dispatch loop.
#[derive(Debug, Clone, Default)]
pub struct Simulation {
    pub clock: Clock,
    pub queue: EventQueue,
    events_processed: u64,
    events_skipped: u64,
}

impl Simulation {
    pub fn new() -> Self {
        Simulation::default()
    }

    pub fn schedule(&mut self, time: f64, event: Event) {
        self.queue.push(time, event);
    }

    /// Schedule in the divergent sequence band (see
    /// [`EventQueue::push_ranked`]).
    pub fn schedule_ranked(&mut self, time: f64, event: Event, rank: u64) {
        self.queue.push_ranked(time, event, rank);
    }

    /// Clear every pending event and rewind clock and counters to zero,
    /// keeping the queue's heap allocation (arena reuse).
    pub fn reset(&mut self) {
        self.queue.clear();
        self.clock = Clock::default();
        self.events_processed = 0;
        self.events_skipped = 0;
    }

    /// Capture the current state into `snap`, reusing its entry buffer.
    /// The heap is walked in internal order — arbitrary but paired with
    /// [`Simulation::restore_from`], which rebuilds a heap whose pop
    /// order is fully determined by the unique `(time, seq)` keys, so
    /// restored runs are bit-for-bit identical regardless of internal
    /// arrangement.
    pub fn save_into(&self, snap: &mut SimSnapshot) {
        snap.now = self.clock.now();
        snap.entries.clear();
        snap.entries
            .extend(self.queue.heap.iter().map(|Reverse(e)| e.clone()));
        snap.seq = self.queue.seq;
        snap.events_processed = self.events_processed;
        snap.events_skipped = self.events_skipped;
    }

    /// Restore the state captured by [`Simulation::save_into`]. The
    /// clock is rebuilt from zero, so restoring *backwards* (the fork
    /// case: run a suffix, rewind, run another) is allowed.
    pub fn restore_from(&mut self, snap: &SimSnapshot) {
        self.queue.heap.clear();
        self.queue
            .heap
            .extend(snap.entries.iter().cloned().map(Reverse));
        self.queue.seq = snap.seq;
        self.clock = Clock::default();
        self.clock.advance_to(snap.now);
        self.events_processed = snap.events_processed;
        self.events_skipped = snap.events_skipped;
    }

    /// Run to queue exhaustion. Returns the number of events dispatched.
    ///
    /// One scratch buffer is reused for every `on_event`/`on_quiescent`
    /// dispatch: components push follow-up events into it and the loop
    /// drains it into the queue, so steady-state dispatch allocates
    /// nothing.
    pub fn run(&mut self, components: &mut [&mut dyn Component]) -> u64 {
        self.run_until(f64::INFINITY, components)
    }

    /// Run until the queue is exhausted or the next batch would start at
    /// `t_limit` or later, leaving that batch (and everything after it)
    /// queued. Returns the number of events dispatched so far. With
    /// `t_limit = f64::INFINITY` this is exactly [`Simulation::run`].
    pub fn run_until(&mut self, t_limit: f64, components: &mut [&mut dyn Component]) -> u64 {
        let mut out: Vec<ScheduledEvent> = Vec::new();
        while let Some(t) = self.queue.next_time() {
            if t >= t_limit {
                break;
            }
            self.clock.advance_to(t);
            // Drain the batch: everything at exactly t, plus Ends within
            // TIME_EPS of it. Events scheduled during the batch at <= t
            // join it.
            loop {
                let take = match self.queue.next_time() {
                    Some(tn) => tn <= t || (self.queue.next_is_end() && tn <= t + TIME_EPS),
                    None => false,
                };
                if !take {
                    break;
                }
                let (_, ev) = self.queue.pop().expect("peeked");
                // Stale-pop filter: a component may invalidate an event
                // it scheduled earlier (re-timed provisional Ends). The
                // veto runs before any dispatch, so observers never see
                // a stale event.
                if !components.iter_mut().all(|c| c.accept_event(t, &ev)) {
                    self.events_skipped += 1;
                    continue;
                }
                self.events_processed += 1;
                for c in components.iter_mut() {
                    c.on_event(t, &ev, &mut out);
                    for se in out.drain(..) {
                        self.queue.push(se.time, se.event);
                    }
                }
            }
            for c in components.iter_mut() {
                c.on_quiescent(t, &mut out);
                for se in out.drain(..) {
                    debug_assert!(
                        se.time >= t,
                        "quiescent event in the past: {} < {t}",
                        se.time
                    );
                    // Clamp so a sub-eps echo of a batched End can never
                    // drag the clock backwards in release builds.
                    self.queue.push(se.time.max(t), se.event);
                }
            }
        }
        self.events_processed
    }

    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Events dropped by the stale-pop filter ([`Component::accept_event`]).
    pub fn events_skipped(&self) -> u64 {
        self.events_skipped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Records every dispatch it sees.
    #[derive(Default)]
    struct Probe {
        log: Vec<(f64, Event)>,
        quiescents: Vec<f64>,
    }

    impl Component for Probe {
        fn on_event(&mut self, now: f64, ev: &Event, _out: &mut Vec<ScheduledEvent>) {
            self.log.push((now, ev.clone()));
        }

        fn on_quiescent(&mut self, now: f64, _out: &mut Vec<ScheduledEvent>) {
            self.quiescents.push(now);
        }
    }

    fn submit(job: JobId) -> Event {
        Event::Submit { job }
    }

    fn end(job: JobId) -> Event {
        Event::End {
            job,
            booster: true,
            cells: vec![(0, 1)].into(),
            gen: 0,
        }
    }

    #[test]
    fn pops_in_time_order_with_fifo_ties() {
        let mut q = EventQueue::default();
        q.push(5.0, submit(1));
        q.push(1.0, submit(2));
        q.push(5.0, submit(3));
        q.push(3.0, submit(4));
        let order: Vec<JobId> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| e.job().unwrap())
            .collect();
        assert_eq!(order, vec![2, 4, 1, 3]);
    }

    #[test]
    #[should_panic(expected = "clock regression")]
    fn clock_rejects_time_travel() {
        let mut c = Clock::default();
        c.advance_to(10.0);
        c.advance_to(9.0);
    }

    #[test]
    fn end_within_eps_joins_batch_but_submit_does_not() {
        let mut sim = Simulation::new();
        sim.schedule(1.0, submit(1));
        sim.schedule(1.0 + 0.5e-9, end(2));
        sim.schedule(1.0 + 0.5e-9, submit(3));
        let mut p = Probe::default();
        sim.run(&mut [&mut p]);
        // Batch 1 at t=1.0: submit(1) and the eps-close end(2); submit(3)
        // waits for its own batch.
        assert_eq!(p.log[0].1.job(), Some(1));
        assert_eq!(p.log[1].1.job(), Some(2));
        assert!((p.log[1].0 - 1.0).abs() < 1e-12, "end handled at batch time");
        assert_eq!(p.log[2].1.job(), Some(3));
        assert!(p.log[2].0 > 1.0);
        assert_eq!(p.quiescents.len(), 2);
    }

    #[test]
    fn dispatch_reaches_all_components_in_order() {
        let mut sim = Simulation::new();
        sim.schedule(0.0, submit(7));
        let mut a = Probe::default();
        let mut b = Probe::default();
        let n = sim.run(&mut [&mut a, &mut b]);
        assert_eq!(n, 1);
        assert_eq!(a.log.len(), 1);
        assert_eq!(b.log.len(), 1);
    }

    /// A component that reacts to a Submit by emitting a Start now and an
    /// End later — the scheduler's shape. The Start and End share one
    /// placement `Arc`.
    struct Reactor {
        started: u32,
    }

    impl Component for Reactor {
        fn on_event(&mut self, now: f64, ev: &Event, out: &mut Vec<ScheduledEvent>) {
            if let Event::Submit { job } = ev {
                self.started += 1;
                let cells: Cells = vec![(0, 4)].into();
                out.push(ScheduledEvent::at(
                    now,
                    Event::Start {
                        job: *job,
                        booster: true,
                        dvfs_scale: 1.0,
                        cells: cells.clone(),
                    },
                ));
                out.push(ScheduledEvent::at(
                    now + 10.0,
                    Event::End {
                        job: *job,
                        booster: true,
                        cells,
                        gen: 0,
                    },
                ));
            }
        }
    }

    #[test]
    fn emitted_events_flow_to_observers() {
        let mut sim = Simulation::new();
        sim.schedule(2.0, submit(1));
        sim.schedule(5.0, submit(2));
        let mut r = Reactor { started: 0 };
        let mut p = Probe::default();
        {
            let mut comps: Vec<&mut dyn Component> = vec![&mut r, &mut p];
            sim.run(&mut comps);
        }
        assert_eq!(r.started, 2);
        // Probe saw Submit+Start+End per job.
        assert_eq!(p.log.len(), 6);
        let ends: Vec<f64> = p
            .log
            .iter()
            .filter(|(_, e)| e.is_end())
            .map(|(t, _)| *t)
            .collect();
        assert_eq!(ends, vec![12.0, 15.0]);
        // Start events carry placement info for observers.
        let start_nodes: u32 = p
            .log
            .iter()
            .find(|(_, e)| matches!(e, Event::Start { .. }))
            .map(|(_, e)| e.nodes())
            .unwrap();
        assert_eq!(start_nodes, 4);
    }

    /// A job's Start and End events point at the same shared placement
    /// allocation, not two copies.
    #[test]
    fn start_and_end_share_one_placement_arc() {
        let mut sim = Simulation::new();
        sim.schedule(0.0, submit(1));
        let mut r = Reactor { started: 0 };
        let mut p = Probe::default();
        {
            let mut comps: Vec<&mut dyn Component> = vec![&mut r, &mut p];
            sim.run(&mut comps);
        }
        let start_cells = p
            .log
            .iter()
            .find_map(|(_, e)| match e {
                Event::Start { cells, .. } => Some(cells.clone()),
                _ => None,
            })
            .unwrap();
        let end_cells = p
            .log
            .iter()
            .find_map(|(_, e)| match e {
                Event::End { cells, .. } => Some(cells.clone()),
                _ => None,
            })
            .unwrap();
        assert!(Arc::ptr_eq(&start_cells, &end_cells), "placement copied");
    }

    /// A component that treats any `End` whose generation is below its
    /// floor as stale — the scheduler's coupled-retiming shape.
    struct GenGate {
        floor: u64,
    }

    impl Component for GenGate {
        fn on_event(&mut self, _now: f64, _ev: &Event, _out: &mut Vec<ScheduledEvent>) {}

        fn accept_event(&mut self, _now: f64, ev: &Event) -> bool {
            match ev {
                Event::End { gen, .. } => *gen >= self.floor,
                _ => true,
            }
        }
    }

    fn end_gen(job: JobId, gen: u64) -> Event {
        Event::End {
            job,
            booster: true,
            cells: vec![(0, 1)].into(),
            gen,
        }
    }

    /// Stale generation-stamped Ends are skipped at pop time: no
    /// component (observers included) ever sees them, while current
    /// ones flow through; FIFO ordering of the survivors is untouched.
    #[test]
    fn stale_ends_are_filtered_before_dispatch() {
        let mut sim = Simulation::new();
        sim.schedule(1.0, end_gen(1, 0)); // stale (re-timed away)
        sim.schedule(2.0, end_gen(2, 1)); // current
        sim.schedule(2.0, end_gen(3, 0)); // stale, same instant
        sim.schedule(3.0, end_gen(4, 2)); // current
        let mut gate = GenGate { floor: 1 };
        let mut p = Probe::default();
        let n = sim.run(&mut [&mut gate, &mut p]);
        assert_eq!(n, 2, "two current events dispatched");
        assert_eq!(sim.events_skipped(), 2, "two stale events skipped");
        let seen: Vec<JobId> = p.log.iter().map(|(_, e)| e.job().unwrap()).collect();
        assert_eq!(seen, vec![2, 4]);
    }

    /// Retime events reach observers like any other event and carry the
    /// job they concern.
    #[test]
    fn retime_events_flow_to_observers() {
        let mut sim = Simulation::new();
        sim.schedule(
            1.0,
            Event::Retime {
                job: 9,
                dvfs_scale: 0.8,
                end: 42.0,
            },
        );
        let mut p = Probe::default();
        sim.run(&mut [&mut p]);
        assert_eq!(p.log.len(), 1);
        assert_eq!(p.log[0].1.job(), Some(9));
        assert_eq!(p.log[0].1.nodes(), 0);
    }

    /// A ranked (divergent-band) event at a shared timestamp pops after
    /// every normally-pushed event at that time, whether it was pushed
    /// first or last — the invariant that makes fork-time injection
    /// byte-identical to upfront scheduling.
    #[test]
    fn ranked_events_sort_after_equal_time_normal_pushes() {
        let run = |ranked_first: bool| {
            let mut q = EventQueue::default();
            if ranked_first {
                q.push_ranked(5.0, Event::CapChange { cap_mw: Some(7.0) }, 0);
            }
            q.push(5.0, submit(1));
            q.push(5.0, submit(2));
            if !ranked_first {
                q.push_ranked(5.0, Event::CapChange { cap_mw: Some(7.0) }, 0);
            }
            std::iter::from_fn(|| q.pop())
                .map(|(_, e)| e.job())
                .collect::<Vec<Option<JobId>>>()
        };
        let expected = vec![Some(1), Some(2), None];
        assert_eq!(run(true), expected);
        assert_eq!(run(false), expected);
    }

    /// Two ranked events at one timestamp pop in rank order.
    #[test]
    fn ranked_events_pop_in_rank_order() {
        let mut q = EventQueue::default();
        q.push_ranked(1.0, submit(2), 1);
        q.push_ranked(1.0, submit(1), 0);
        let order: Vec<JobId> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| e.job().unwrap())
            .collect();
        assert_eq!(order, vec![1, 2]);
    }

    /// `run_until` stops before the limit batch and leaves it queued;
    /// resuming with `run` finishes identically to an uninterrupted run.
    #[test]
    fn run_until_stops_before_limit_and_resumes() {
        let build = || {
            let mut sim = Simulation::new();
            for i in 0..6u64 {
                sim.schedule(i as f64, submit(i));
            }
            sim
        };
        let mut whole = Probe::default();
        build().run(&mut [&mut whole]);

        let mut split = Probe::default();
        let mut sim = build();
        let n = sim.run_until(3.0, &mut [&mut split]);
        assert_eq!(n, 3, "events at t=0,1,2 dispatched");
        assert_eq!(sim.queue.len(), 3, "t=3,4,5 still queued");
        assert_eq!(sim.queue.next_time(), Some(3.0));
        sim.run(&mut [&mut split]);
        assert_eq!(split.log, whole.log);
    }

    /// save_into / restore_from round-trips: run a prefix, snapshot,
    /// run the suffix, restore, re-run the suffix — both suffixes match
    /// the uninterrupted run bit-for-bit and counters rewind exactly.
    #[test]
    fn snapshot_restore_replays_suffix_identically() {
        let build = |p: &mut Probe| {
            let mut sim = Simulation::new();
            for i in 0..8u64 {
                sim.schedule((i % 4) as f64, submit(i));
            }
            sim.schedule(1.0, end_gen(90, 0)); // skipped by the gate
            sim.schedule(3.0, end_gen(91, 1));
            let mut gate = GenGate { floor: 1 };
            sim.run_until(2.0, &mut [&mut gate, p]);
            sim
        };
        let mut whole = Probe::default();
        let mut sim_whole = build(&mut whole);
        {
            let mut gate = GenGate { floor: 1 };
            sim_whole.run(&mut [&mut gate, &mut whole]);
        }

        let mut split = Probe::default();
        let mut sim = build(&mut split);
        let mut snap = SimSnapshot::default();
        sim.save_into(&mut snap);
        let processed_at_snap = sim.events_processed();
        let skipped_at_snap = sim.events_skipped();
        let prefix_len = split.log.len();
        {
            let mut gate = GenGate { floor: 1 };
            sim.run(&mut [&mut gate, &mut split]);
        }
        let first_suffix: Vec<(f64, Event)> = split.log[prefix_len..].to_vec();
        sim.restore_from(&snap);
        assert_eq!(sim.events_processed(), processed_at_snap);
        assert_eq!(sim.events_skipped(), skipped_at_snap);
        // Clock restored to the last dispatched batch time (t=1), not
        // the run_until limit.
        assert!((sim.clock.now() - 1.0).abs() < 1e-12);
        split.log.truncate(prefix_len);
        {
            let mut gate = GenGate { floor: 1 };
            sim.run(&mut [&mut gate, &mut split]);
        }
        assert_eq!(split.log[prefix_len..], first_suffix[..]);
        assert_eq!(split.log, whole.log);
        assert_eq!(sim.events_processed(), sim_whole.events_processed());
        assert_eq!(sim.events_skipped(), sim_whole.events_skipped());
    }

    /// `reset` and `restore_from` keep the queue's heap allocation.
    #[test]
    fn reset_and_restore_retain_queue_capacity() {
        let mut sim = Simulation::new();
        for i in 0..100u64 {
            sim.schedule(i as f64, submit(i));
        }
        let cap = sim.queue.capacity();
        assert!(cap >= 100);
        let mut snap = SimSnapshot::default();
        sim.save_into(&mut snap);
        sim.reset();
        assert_eq!(sim.queue.len(), 0);
        assert_eq!(sim.queue.capacity(), cap, "reset reallocated the heap");
        assert_eq!(sim.clock.now(), 0.0);
        sim.restore_from(&snap);
        assert_eq!(sim.queue.len(), 100);
        assert_eq!(sim.queue.capacity(), cap, "restore reallocated the heap");
        // Restored pops honour the saved (time, seq) order exactly.
        let order: Vec<JobId> = std::iter::from_fn(|| sim.queue.pop())
            .map(|(_, e)| e.job().unwrap())
            .collect();
        let expected: Vec<JobId> = (0..100).collect();
        assert_eq!(order, expected);
    }

    #[test]
    fn deterministic_across_runs() {
        let build = || {
            let mut sim = Simulation::new();
            for i in 0..50u64 {
                sim.schedule((i % 7) as f64, submit(i));
            }
            sim
        };
        let mut p1 = Probe::default();
        let mut p2 = Probe::default();
        build().run(&mut [&mut p1]);
        build().run(&mut [&mut p2]);
        assert_eq!(p1.log, p2.log);
    }
}
