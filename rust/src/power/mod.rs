//! Power, energy and cooling models (paper §2.6, Table 4, Green500).
//!
//! Per-node power is a linear idle+dynamic model over CPU and GPU
//! utilisation, with a per-blade constant covering VRM/PSU losses, NICs
//! and the node's share of fabric and DLC pumping — calibrated once
//! against the TOP500 submission (7.4 MW at 3300 nodes under HPL) and
//! reused unchanged for every other experiment. Facility power applies
//! the warm-water-cooling PUE of 1.1; the Bull Dynamic Power Optimizer
//! analogue searches DVFS workpoints; energy-to-solution integrates
//! power over a job.
//!
//! [`PowerMonitor`] subscribes to the shared [`crate::sim`] event stream:
//! every job `Start`/`End` updates the fleet's busy-node and
//! DVFS-weighted dynamic-power accounting and appends facility power and
//! utilization samples to a [`crate::telemetry::MetricStore`] — series
//! are emitted per-event instead of being recomputed after the fact.

use std::collections::BTreeMap;

use crate::hardware::NodeSpec;
use crate::sim::{Component, Event, ScheduledEvent};
use crate::telemetry::MetricStore;

/// Per-blade constant draw: PSU/VRM losses, 2 x CX6 NICs, BMC, and the
/// node's share of switch + DLC pump power, W.
pub const BLADE_OVERHEAD_W: f64 = 310.0;

/// Utilisation of a node's components during a workload.
#[derive(Debug, Clone, Copy)]
pub struct Utilization {
    /// CPU dynamic-range fraction, 0..=1.
    pub cpu: f64,
    /// GPU dynamic-range fraction; `None` = GPUs not powered for this
    /// accounting (the paper's PLUTO row counts CPU power only).
    pub gpu: Option<f64>,
}

impl Utilization {
    pub fn hpl() -> Self {
        Utilization {
            cpu: 0.60,
            gpu: Some(1.0),
        }
    }

    pub fn idle() -> Self {
        Utilization {
            cpu: 0.0,
            gpu: Some(0.0),
        }
    }
}

/// Node power model.
#[derive(Debug, Clone)]
pub struct PowerModel {
    pub node: NodeSpec,
    pub pue: f64,
}

impl PowerModel {
    pub fn new(node: NodeSpec, pue: f64) -> Self {
        PowerModel { node, pue }
    }

    /// IT power of one node at utilisation `u`, W.
    pub fn node_power_w(&self, u: Utilization) -> f64 {
        let cpu = &self.node.cpu;
        let sockets = self.node.cpu_sockets as f64;
        let mut p = BLADE_OVERHEAD_W
            + sockets * (cpu.idle_w + u.cpu.clamp(0.0, 1.0) * (cpu.tdp_w - cpu.idle_w));
        if let (Some(gpu), Some(gu)) = (self.node.gpu.as_ref(), u.gpu) {
            p += self.node.gpus as f64
                * (gpu.idle_w + gu.clamp(0.0, 1.0) * (gpu.tdp_w - gpu.idle_w));
        }
        p
    }

    /// IT power of `nodes` nodes, MW.
    pub fn fleet_power_mw(&self, nodes: u32, u: Utilization) -> f64 {
        nodes as f64 * self.node_power_w(u) / 1e6
    }

    /// Facility power including cooling overhead, MW (PUE x IT).
    pub fn facility_power_mw(&self, nodes: u32, u: Utilization) -> f64 {
        self.fleet_power_mw(nodes, u) * self.pue
    }

    /// Energy-to-solution for a job, kWh (IT power, as in Table 6).
    pub fn energy_kwh(&self, nodes: u32, u: Utilization, seconds: f64) -> f64 {
        self.fleet_power_mw(nodes, u) * 1e3 * seconds / 3600.0
    }

    /// Green500 metric: GFLOPS per watt.
    pub fn gflops_per_watt(&self, rmax_flops: f64, nodes: u32, u: Utilization) -> f64 {
        rmax_flops / 1e9 / (self.fleet_power_mw(nodes, u) * 1e6)
    }
}

/// DVFS workpoint: clocks scaled to `s` of nominal.
///
/// Dynamic power scales ~ s^2 (voltage tracks frequency in the efficient
/// band), compute-bound runtime scales ~ 1/s. The Bull Dynamic Power
/// Optimizer's job is to pick `s` minimising energy at bounded slowdown.
#[derive(Debug, Clone, Copy)]
pub struct DvfsPoint {
    pub scale: f64,
}

impl DvfsPoint {
    /// Power multiplier on the *dynamic* component.
    pub fn power_factor(&self) -> f64 {
        self.scale * self.scale
    }

    /// Runtime multiplier for a compute-bound job (`boundness` in 0..=1:
    /// 1 = fully clock-bound, 0 = fully memory/IO-bound).
    pub fn time_factor(&self, boundness: f64) -> f64 {
        let b = boundness.clamp(0.0, 1.0);
        b / self.scale + (1.0 - b)
    }
}

/// Bull Dynamic Power Optimizer analogue: sweep DVFS workpoints and
/// return the one minimising energy subject to a slowdown bound.
pub fn best_workpoint(
    model: &PowerModel,
    u: Utilization,
    boundness: f64,
    max_slowdown: f64,
) -> DvfsPoint {
    let idle = model.node_power_w(Utilization::idle());
    let active = model.node_power_w(u);
    let dynamic = active - idle;
    let mut best = DvfsPoint { scale: 1.0 };
    let mut best_energy = f64::INFINITY;
    let mut s = 0.50;
    while s <= 1.0001 {
        let p = DvfsPoint { scale: s };
        let t = p.time_factor(boundness);
        if t <= max_slowdown {
            let energy = (idle + dynamic * p.power_factor()) * t;
            if energy < best_energy {
                best_energy = energy;
                best = p;
            }
        }
        s += 0.01;
    }
    best
}

/// Power capping (Bull Energy Optimizer analogue): the DVFS scale that
/// brings `nodes` under `cap_mw`, or `None` if even the floor won't fit.
pub fn cap_scale(
    model: &PowerModel,
    nodes: u32,
    u: Utilization,
    cap_mw: f64,
) -> Option<DvfsPoint> {
    let idle = model.node_power_w(Utilization::idle());
    let dynamic = model.node_power_w(u) - idle;
    let budget_w = cap_mw * 1e6 / nodes as f64;
    if idle + dynamic <= budget_w {
        return Some(DvfsPoint { scale: 1.0 });
    }
    // idle + dynamic*s^2 = budget  =>  s = sqrt((budget-idle)/dynamic)
    let s2 = (budget_w - idle) / dynamic;
    if s2 < 0.25 {
        return None; // below the 0.5 floor
    }
    Some(DvfsPoint {
        scale: s2.sqrt().min(1.0),
    })
}

/// Per-event facility power and utilization telemetry: a
/// [`Component`] fed by the scheduler's `Start`/`End` stream.
///
/// Running jobs contribute their nodes' dynamic power scaled by the DVFS
/// workpoint they started at (`power_factor = scale^2`); every other
/// node idles. Series written into [`PowerMonitor::store`]:
///
/// * `facility_power_w` — PUE-inclusive facility draw, watts;
/// * `utilization` — busy fraction of `total_nodes`;
/// * `busy_nodes` — absolute busy node count.
#[derive(Debug, Clone)]
pub struct PowerMonitor {
    pub model: PowerModel,
    /// Per-node utilisation assumed for running jobs.
    pub util: Utilization,
    /// Fleet size the idle floor and utilization are computed over.
    pub total_nodes: u32,
    /// Count only Booster-partition jobs. Set this when `total_nodes`
    /// is one partition's size and the event stream may carry both
    /// partitions — otherwise DataCentric starts inflate `busy_nodes`
    /// past the fleet and charge CPU nodes at GPU-node dynamic power.
    pub booster_only: bool,
    busy_nodes: u32,
    /// Σ nodes x scale^2 over running jobs (dynamic-power weight).
    dyn_weight: f64,
    /// PUE-inclusive energy charged to work a fault destroyed, kWh:
    /// each `Kill` adds its nodes' facility draw over the unrecoverable
    /// window (`wasted_s` — elapsed minus checkpointed progress).
    wasted_kwh: f64,
    running: BTreeMap<u64, (u32, f64)>,
    pub store: MetricStore,
    /// Internal snapshot slot ([`Component::snapshot`]): accounting
    /// state plus per-series length marks, buffers reused.
    snap: Option<Box<MonitorSnapshot>>,
}

/// Saved [`PowerMonitor`] run state: busy/dynamic-power accounting, the
/// tracked-job table as a sorted pair list (the `BTreeMap`'s node
/// allocations can't be retained, the flat buffer can), and a length
/// mark per metric series.
#[derive(Debug, Clone, Default)]
struct MonitorSnapshot {
    busy_nodes: u32,
    dyn_weight: f64,
    wasted_kwh: f64,
    running: Vec<(u64, (u32, f64))>,
    marks: Vec<(String, usize)>,
}

impl PowerMonitor {
    pub fn new(model: PowerModel, util: Utilization, total_nodes: u32) -> Self {
        PowerMonitor {
            model,
            util,
            total_nodes,
            booster_only: false,
            busy_nodes: 0,
            dyn_weight: 0.0,
            wasted_kwh: 0.0,
            running: BTreeMap::new(),
            store: MetricStore::default(),
            snap: None,
        }
    }

    /// Clear all run state (busy/dynamic-power accounting, tracked jobs,
    /// metric samples) while keeping the model and every series buffer
    /// allocated — the campaign arena reuses one monitor across
    /// scenarios. `total_nodes`/`booster_only` are re-armed because the
    /// next scenario may replay a different partition.
    pub fn reset(&mut self, total_nodes: u32, booster_only: bool) {
        self.total_nodes = total_nodes;
        self.booster_only = booster_only;
        self.busy_nodes = 0;
        self.dyn_weight = 0.0;
        self.wasted_kwh = 0.0;
        self.running.clear();
        self.store.reset();
    }

    /// PUE-inclusive facility energy destroyed by faults so far, kWh.
    pub fn wasted_kwh(&self) -> f64 {
        self.wasted_kwh
    }

    pub fn busy_nodes(&self) -> u32 {
        self.busy_nodes
    }

    pub fn utilization(&self) -> f64 {
        if self.total_nodes == 0 {
            return 0.0;
        }
        self.busy_nodes as f64 / self.total_nodes as f64
    }

    /// Current facility draw, W (PUE-inclusive).
    pub fn facility_w(&self) -> f64 {
        let idle = self.model.node_power_w(Utilization::idle());
        let active = self.model.node_power_w(self.util);
        let dynamic = active - idle;
        (self.total_nodes as f64 * idle + self.dyn_weight * dynamic) * self.model.pue
    }

    /// PUE-inclusive facility energy so far, kWh: the *step* integral of
    /// the per-event power series. Facility draw is piecewise-constant —
    /// every sample opens a rate segment that holds until the next
    /// `Start`/`End`/`Retime` — so the left-constant integral is exact,
    /// and DVFS-capped intervals show up in joules, not just watts.
    pub fn energy_kwh(&self) -> f64 {
        self.store.step_energy_kwh("facility_power_w")
    }

    fn sample(&mut self, now: f64) {
        let fac = self.facility_w();
        let util = self.utilization();
        self.store.record("facility_power_w", now, fac);
        self.store.record("utilization", now, util);
        self.store
            .record("busy_nodes", now, self.busy_nodes as f64);
    }
}

impl Component for PowerMonitor {
    fn on_event(&mut self, now: f64, ev: &Event, _out: &mut Vec<ScheduledEvent>) {
        match ev {
            Event::Start {
                job,
                booster,
                dvfs_scale,
                ..
            } => {
                if self.booster_only && !booster {
                    return;
                }
                let nodes = ev.nodes();
                self.busy_nodes += nodes;
                self.dyn_weight += nodes as f64 * dvfs_scale * dvfs_scale;
                self.running.insert(*job, (nodes, *dvfs_scale));
                self.sample(now);
            }
            Event::End { job, .. } => {
                if let Some((nodes, scale)) = self.running.remove(job) {
                    self.busy_nodes -= nodes;
                    self.dyn_weight -= nodes as f64 * scale * scale;
                    self.sample(now);
                }
            }
            Event::Kill { job, wasted_s, .. } => {
                // A fault destroyed the incarnation: release its power
                // accounting like an End, and charge the facility draw
                // its nodes held over the unrecoverable window as wasted
                // energy (at the scale the job was killed at — a
                // piecewise-exact split across retimes isn't worth the
                // bookkeeping for an attribution metric).
                if let Some((nodes, scale)) = self.running.remove(job) {
                    self.busy_nodes -= nodes;
                    self.dyn_weight -= nodes as f64 * scale * scale;
                    let idle = self.model.node_power_w(Utilization::idle());
                    let dynamic = self.model.node_power_w(self.util) - idle;
                    self.wasted_kwh += nodes as f64
                        * (idle + scale * scale * dynamic)
                        * self.model.pue
                        * wasted_s
                        / 3.6e6;
                    self.sample(now);
                }
            }
            Event::Retime {
                job, dvfs_scale, ..
            } => {
                // A running job's rate changed mid-flight (coupled
                // mode): close the old piecewise-constant segment and
                // open one at the new dynamic-power weight. Jobs this
                // monitor doesn't track (partition-filtered) are absent
                // from `running` and skipped.
                let Some(&(nodes, scale)) = self.running.get(job) else {
                    return;
                };
                self.dyn_weight += nodes as f64 * (dvfs_scale * dvfs_scale - scale * scale);
                self.running.insert(*job, (nodes, *dvfs_scale));
                self.sample(now);
            }
            _ => {}
        }
    }

    fn snapshot(&mut self) {
        let mut snap = self.snap.take().unwrap_or_default();
        snap.busy_nodes = self.busy_nodes;
        snap.dyn_weight = self.dyn_weight;
        snap.wasted_kwh = self.wasted_kwh;
        snap.running.clear();
        snap.running
            .extend(self.running.iter().map(|(&k, &v)| (k, v)));
        self.store.save_marks(&mut snap.marks);
        self.snap = Some(snap);
    }

    fn restore(&mut self) {
        let snap = self
            .snap
            .take()
            .expect("PowerMonitor::restore without a prior snapshot");
        self.busy_nodes = snap.busy_nodes;
        self.dyn_weight = snap.dyn_weight;
        self.wasted_kwh = snap.wasted_kwh;
        self.running.clear();
        self.running.extend(snap.running.iter().copied());
        self.store.restore_marks(&snap.marks);
        self.snap = Some(snap);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::NodeSpec;

    fn leo_model() -> PowerModel {
        PowerModel::new(NodeSpec::davinci(), 1.1)
    }

    #[test]
    fn hpl_power_matches_top500_submission() {
        // Table 4 context: 7.4 MW for 3300 nodes under HPL.
        let m = leo_model();
        let mw = m.fleet_power_mw(3300, Utilization::hpl());
        assert!((mw - 7.4).abs() / 7.4 < 0.02, "{mw} MW");
    }

    #[test]
    fn green500_is_32_gflops_per_watt() {
        let m = leo_model();
        let g = m.gflops_per_watt(238.7e15, 3300, Utilization::hpl());
        assert!((g - 32.2).abs() < 1.0, "{g}");
    }

    #[test]
    fn pue_overhead_is_10_percent() {
        let m = leo_model();
        let it = m.fleet_power_mw(3300, Utilization::hpl());
        let fac = m.facility_power_mw(3300, Utilization::hpl());
        assert!((fac / it - 1.1).abs() < 1e-9);
    }

    #[test]
    fn full_machine_fits_the_10mw_envelope() {
        // §2.6: 10 MW IT load supports the whole machine under HPL-class
        // load on the Booster plus the DC partition.
        let m = leo_model();
        let booster = m.fleet_power_mw(3456, Utilization::hpl());
        assert!(booster < 8.0, "{booster}");
    }

    #[test]
    fn idle_is_much_cheaper_than_loaded() {
        let m = leo_model();
        let idle = m.node_power_w(Utilization::idle());
        let hpl = m.node_power_w(Utilization::hpl());
        assert!(idle < 0.4 * hpl, "idle {idle} vs hpl {hpl}");
    }

    #[test]
    fn cpu_only_accounting_excludes_gpus() {
        let m = leo_model();
        let with = m.node_power_w(Utilization {
            cpu: 0.5,
            gpu: Some(0.0),
        });
        let without = m.node_power_w(Utilization {
            cpu: 0.5,
            gpu: None,
        });
        assert!(with > without + 4.0 * 50.0);
    }

    #[test]
    fn energy_integral_matches_hand_calc() {
        let m = leo_model();
        let u = Utilization {
            cpu: 0.35,
            gpu: Some(0.086),
        };
        let kwh = m.energy_kwh(12, u, 439.0);
        // QE row of Table 6: 1.14 kWh.
        assert!((kwh - 1.14).abs() < 0.06, "{kwh}");
    }

    #[test]
    fn dvfs_power_and_time_factors() {
        let p = DvfsPoint { scale: 0.8 };
        assert!((p.power_factor() - 0.64).abs() < 1e-12);
        assert!((p.time_factor(1.0) - 1.25).abs() < 1e-12);
        assert!((p.time_factor(0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn optimizer_downclocks_memory_bound_jobs() {
        let m = leo_model();
        let u = Utilization::hpl();
        // Memory-bound: slowdown tiny, so deep downclock wins.
        let mem = best_workpoint(&m, u, 0.1, 1.10);
        // Compute-bound with tight slowdown bound: stays near nominal.
        let cpu = best_workpoint(&m, u, 1.0, 1.05);
        assert!(mem.scale < cpu.scale, "{} vs {}", mem.scale, cpu.scale);
        assert!(cpu.scale > 0.9);
    }

    #[test]
    fn cap_scale_brings_fleet_under_cap() {
        let m = leo_model();
        let u = Utilization::hpl();
        let uncapped = m.fleet_power_mw(3300, u);
        let cap = uncapped * 0.8;
        let p = cap_scale(&m, 3300, u, cap).unwrap();
        assert!(p.scale < 1.0);
        let idle = m.node_power_w(Utilization::idle());
        let dynamic = m.node_power_w(u) - idle;
        let capped_mw = 3300.0 * (idle + dynamic * p.power_factor()) / 1e6;
        assert!(capped_mw <= cap * 1.001, "{capped_mw} vs {cap}");
    }

    #[test]
    fn cap_scale_none_when_impossible() {
        let m = leo_model();
        assert!(cap_scale(&m, 3300, Utilization::hpl(), 0.5).is_none());
    }

    fn start_ev(job: u64, nodes: u32, scale: f64) -> Event {
        Event::Start {
            job,
            booster: true,
            dvfs_scale: scale,
            cells: vec![(0, nodes)].into(),
        }
    }

    fn end_ev(job: u64, nodes: u32) -> Event {
        Event::End {
            job,
            booster: true,
            cells: vec![(0, nodes)].into(),
            gen: 0,
        }
    }

    #[test]
    fn monitor_tracks_busy_nodes_and_power() {
        let mut out = Vec::new();
        let mut mon = PowerMonitor::new(leo_model(), Utilization::hpl(), 3456);
        let idle_w = mon.facility_w();
        mon.on_event(0.0, &start_ev(1, 1000, 1.0), &mut out);
        assert_eq!(mon.busy_nodes(), 1000);
        let loaded_w = mon.facility_w();
        assert!(loaded_w > idle_w);
        mon.on_event(100.0, &end_ev(1, 1000), &mut out);
        assert_eq!(mon.busy_nodes(), 0);
        assert!((mon.facility_w() - idle_w).abs() < 1e-6);
        // Per-event series: one sample at start, one at end.
        assert_eq!(mon.store.get("facility_power_w").unwrap().len(), 2);
        assert!(mon.energy_kwh() > 0.0);
    }

    #[test]
    fn monitor_dvfs_scale_reduces_dynamic_power() {
        let mut out = Vec::new();
        let mut nominal = PowerMonitor::new(leo_model(), Utilization::hpl(), 3456);
        let mut capped = PowerMonitor::new(leo_model(), Utilization::hpl(), 3456);
        nominal.on_event(0.0, &start_ev(1, 2000, 1.0), &mut out);
        capped.on_event(0.0, &start_ev(1, 2000, 0.8), &mut out);
        assert!(capped.facility_w() < nominal.facility_w());
        // Idle floor identical: the difference is purely dynamic.
        let idle = PowerMonitor::new(leo_model(), Utilization::hpl(), 3456).facility_w();
        assert!(capped.facility_w() > idle);
    }

    #[test]
    fn booster_only_monitor_ignores_datacentric_jobs() {
        let mut out = Vec::new();
        let mut mon = PowerMonitor::new(leo_model(), Utilization::hpl(), 3456);
        mon.booster_only = true;
        let dc_start = Event::Start {
            job: 1,
            booster: false,
            dvfs_scale: 1.0,
            cells: vec![(19, 1200)].into(),
        };
        mon.on_event(0.0, &dc_start, &mut out);
        assert_eq!(mon.busy_nodes(), 0);
        mon.on_event(0.0, &start_ev(2, 3000, 1.0), &mut out);
        assert_eq!(mon.busy_nodes(), 3000);
        assert!(mon.utilization() <= 1.0);
    }

    /// A mid-job Retime re-weights dynamic power and the step-integral
    /// energy reflects the piecewise-constant segments exactly.
    #[test]
    fn monitor_retime_changes_dynamic_power_and_energy() {
        let mut out = Vec::new();
        let mut mon = PowerMonitor::new(leo_model(), Utilization::hpl(), 3456);
        mon.on_event(0.0, &start_ev(1, 2000, 1.0), &mut out);
        let full_w = mon.facility_w();
        // Capped to 0.8 of nominal clocks at t=100.
        mon.on_event(
            100.0,
            &Event::Retime {
                job: 1,
                dvfs_scale: 0.8,
                end: 300.0,
            },
            &mut out,
        );
        let capped_w = mon.facility_w();
        assert!(capped_w < full_w, "{capped_w} vs {full_w}");
        mon.on_event(300.0, &end_ev(1, 2000), &mut out);
        // Exact step integral: 100 s at full + 200 s capped.
        let joules = full_w * 100.0 + capped_w * 200.0;
        assert!((mon.energy_kwh() - joules / 3.6e6).abs() < 1e-9);
        // Retime of an untracked job is a no-op.
        let before = mon.store.get("facility_power_w").unwrap().len();
        mon.on_event(
            301.0,
            &Event::Retime {
                job: 99,
                dvfs_scale: 0.5,
                end: 400.0,
            },
            &mut out,
        );
        assert_eq!(mon.store.get("facility_power_w").unwrap().len(), before);
    }

    /// snapshot → perturb → restore leaves accounting and series exactly
    /// where the snapshot was taken, so a replayed suffix reproduces the
    /// unperturbed run sample-for-sample.
    #[test]
    fn monitor_snapshot_restore_round_trips() {
        let mut out = Vec::new();
        let mut mon = PowerMonitor::new(leo_model(), Utilization::hpl(), 3456);
        mon.on_event(0.0, &start_ev(1, 1000, 1.0), &mut out);
        mon.snapshot();
        let w_at_snap = mon.facility_w();
        mon.on_event(50.0, &start_ev(2, 500, 0.8), &mut out);
        mon.on_event(80.0, &end_ev(1, 1000), &mut out);
        mon.restore();
        assert_eq!(mon.busy_nodes(), 1000);
        assert!((mon.facility_w() - w_at_snap).abs() < 1e-9);
        assert_eq!(mon.store.get("facility_power_w").unwrap().len(), 1);
        // Replaying the same suffix lands in the same state.
        mon.on_event(50.0, &start_ev(2, 500, 0.8), &mut out);
        assert_eq!(mon.busy_nodes(), 1500);
        assert_eq!(mon.store.get("facility_power_w").unwrap().len(), 2);
    }

    /// A Kill releases the job's power accounting like an End and books
    /// the facility draw its nodes held over the wasted window.
    #[test]
    fn monitor_kill_releases_power_and_books_wasted_energy() {
        let mut out = Vec::new();
        let mut mon = PowerMonitor::new(leo_model(), Utilization::hpl(), 3456);
        let idle_w = mon.facility_w();
        mon.on_event(0.0, &start_ev(1, 1000, 1.0), &mut out);
        mon.on_event(
            60.0,
            &Event::Kill {
                job: 1,
                booster: true,
                cells: vec![(0, 1000)].into(),
                wasted_s: 60.0,
                requeued: false,
            },
            &mut out,
        );
        assert_eq!(mon.busy_nodes(), 0);
        assert!((mon.facility_w() - idle_w).abs() < 1e-6);
        // Wasted energy = the killed nodes' full facility draw (idle
        // floor included — those nodes burned it on discarded work) at
        // scale 1.0 over the 60 s window.
        let active = leo_model().node_power_w(Utilization::hpl());
        let expected = 1000.0 * active * 1.1 * 60.0 / 3.6e6;
        assert!(
            (mon.wasted_kwh() - expected).abs() < 1e-9,
            "{} vs {expected}",
            mon.wasted_kwh()
        );
        // A kill of an untracked job books nothing.
        mon.on_event(
            61.0,
            &Event::Kill {
                job: 99,
                booster: true,
                cells: vec![(0, 10)].into(),
                wasted_s: 10.0,
                requeued: true,
            },
            &mut out,
        );
        assert!((mon.wasted_kwh() - expected).abs() < 1e-9);
    }

    /// Wasted energy is part of the snapshot/restore round trip.
    #[test]
    fn monitor_snapshot_covers_wasted_energy() {
        let mut out = Vec::new();
        let mut mon = PowerMonitor::new(leo_model(), Utilization::hpl(), 3456);
        mon.on_event(0.0, &start_ev(1, 500, 1.0), &mut out);
        mon.snapshot();
        mon.on_event(
            30.0,
            &Event::Kill {
                job: 1,
                booster: true,
                cells: vec![(0, 500)].into(),
                wasted_s: 30.0,
                requeued: true,
            },
            &mut out,
        );
        assert!(mon.wasted_kwh() > 0.0);
        mon.restore();
        assert_eq!(mon.wasted_kwh(), 0.0);
        assert_eq!(mon.busy_nodes(), 500);
    }

    #[test]
    fn monitor_ignores_unknown_job_end() {
        let mut out = Vec::new();
        let mut mon = PowerMonitor::new(leo_model(), Utilization::hpl(), 3456);
        mon.on_event(0.0, &end_ev(42, 100), &mut out);
        assert_eq!(mon.busy_nodes(), 0);
        assert!(mon.store.get("facility_power_w").is_none());
    }
}
