//! Criterion-style micro-benchmark harness (the offline build has no
//! criterion crate). Same call shape as criterion's, so the `benches/`
//! files read like standard criterion benches: warmup, adaptive iteration
//! count, mean/min/max over samples, ns-per-iter reporting.

use std::time::{Duration, Instant};

/// Opaque-value helper preventing const-folding of benchmark inputs.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Harness entry point (mirrors criterion's `Criterion`).
pub struct Criterion {
    /// Target measurement time per benchmark.
    pub measure_time: Duration,
    pub warmup_time: Duration,
    pub samples: u32,
}

impl Default for Criterion {
    fn default() -> Self {
        Criterion {
            measure_time: Duration::from_millis(600),
            warmup_time: Duration::from_millis(150),
            samples: 12,
        }
    }
}

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct Sampled {
    pub name: String,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub iters: u64,
}

impl Criterion {
    pub fn bench_function(&mut self, name: &str, mut f: impl FnMut(&mut Bencher)) {
        let mut b = Bencher {
            cfg: BenchCfg {
                measure_time: self.measure_time,
                warmup_time: self.warmup_time,
                samples: self.samples,
            },
            result: None,
        };
        f(&mut b);
        if let Some(r) = b.result {
            report(name, &r);
        }
    }

    pub fn benchmark_group(&mut self, name: &str) -> Group<'_> {
        Group {
            parent: self,
            name: name.to_string(),
            sample_size: None,
        }
    }
}

/// A named group (mirrors criterion's `BenchmarkGroup`).
pub struct Group<'a> {
    parent: &'a mut Criterion,
    name: String,
    sample_size: Option<u32>,
}

impl<'a> Group<'a> {
    pub fn sample_size(&mut self, n: u32) -> &mut Self {
        self.sample_size = Some(n);
        self
    }

    pub fn bench_function(&mut self, name: &str, f: impl FnMut(&mut Bencher)) {
        let full = format!("{}/{}", self.name, name);
        let saved = self.parent.samples;
        if let Some(n) = self.sample_size {
            self.parent.samples = n;
        }
        self.parent.bench_function(&full, f);
        self.parent.samples = saved;
    }

    pub fn finish(&mut self) {}
}

#[derive(Clone, Copy)]
struct BenchCfg {
    measure_time: Duration,
    warmup_time: Duration,
    samples: u32,
}

/// Passed to the closure; call `iter` with the code under test.
pub struct Bencher {
    cfg: BenchCfg,
    result: Option<Sampled>,
}

impl Bencher {
    pub fn iter<R>(&mut self, mut f: impl FnMut() -> R) {
        // Warmup + calibration: how many iters fit in the warmup window?
        let t0 = Instant::now();
        let mut warm_iters = 0u64;
        while t0.elapsed() < self.cfg.warmup_time {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = self.cfg.warmup_time.as_secs_f64() / warm_iters as f64;
        let sample_target =
            self.cfg.measure_time.as_secs_f64() / self.cfg.samples as f64;
        let iters_per_sample = ((sample_target / per_iter) as u64).max(1);

        let mut samples_ns = Vec::with_capacity(self.cfg.samples as usize);
        for _ in 0..self.cfg.samples {
            let s = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            samples_ns
                .push(s.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        }
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let min = samples_ns.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = samples_ns.iter().cloned().fold(0.0, f64::max);
        self.result = Some(Sampled {
            name: String::new(),
            mean_ns: mean,
            min_ns: min,
            max_ns: max,
            iters: iters_per_sample * self.cfg.samples as u64 + warm_iters,
        });
    }
}

fn human(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn report(name: &str, r: &Sampled) {
    println!(
        "{name:<42} time: [{} {} {}]  ({} iters)",
        human(r.min_ns),
        human(r.mean_ns),
        human(r.max_ns),
        r.iters
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut c = Criterion {
            measure_time: Duration::from_millis(30),
            warmup_time: Duration::from_millis(5),
            samples: 3,
        };
        // No panic, and ordering min <= mean <= max enforced internally.
        c.bench_function("noop", |b| b.iter(|| black_box(1 + 1)));
    }

    #[test]
    fn group_api_compiles_and_runs() {
        let mut c = Criterion {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(4),
            samples: 2,
        };
        let mut g = c.benchmark_group("g");
        g.sample_size(2);
        g.bench_function("inner", |b| b.iter(|| black_box(vec![1u8; 16])));
        g.finish();
    }

    #[test]
    fn human_units() {
        assert!(human(12.0).ends_with("ns"));
        assert!(human(12_000.0).ends_with("us"));
        assert!(human(12_000_000.0).ends_with("ms"));
        assert!(human(2e9).ends_with(" s"));
    }
}
