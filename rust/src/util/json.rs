//! Minimal JSON parser — just enough for `artifacts/manifest.json`.
//!
//! The build environment is fully offline (no serde_json), so the twin
//! carries its own ~150-line recursive-descent parser. Supports objects,
//! arrays, strings (with escapes), numbers, booleans and null.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(anyhow!("expected object, got {other:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(anyhow!("expected array, got {other:?}")),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {other:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(anyhow!("expected number, got {other:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key '{key}'"))
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {other:?} at byte {}", self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}', got {other:?}"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => bail!("expected ',' or ']', got {other:?}"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
          "dgemm_256": {
            "hlo_chars": 8497,
            "inputs": [
              {"dtype": "float32", "shape": [256, 256]},
              {"dtype": "float32", "shape": [256, 256]}
            ],
            "outputs": [{"dtype": "float32", "shape": [256, 256]}]
          }
        }"#;
        let v = Json::parse(text).unwrap();
        let entry = v.get("dgemm_256").unwrap();
        assert_eq!(entry.get("hlo_chars").unwrap().as_usize().unwrap(), 8497);
        let inputs = entry.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs.len(), 2);
        let shape = inputs[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 256);
        assert_eq!(
            inputs[0].get("dtype").unwrap().as_str().unwrap(),
            "float32"
        );
    }

    #[test]
    fn scalar_values() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3]]").unwrap();
        let outer = v.as_arr().unwrap();
        assert_eq!(outer[0].as_arr().unwrap().len(), 2);
        assert_eq!(outer[1].as_arr().unwrap()[0].as_f64().unwrap(), 3.0);
    }
}
