//! Minimal JSON parser and serializer.
//!
//! The build environment is fully offline (no serde_json), so the twin
//! carries its own ~150-line recursive-descent parser. Supports objects,
//! arrays, strings (with escapes), numbers, booleans and null.
//!
//! [`Json::render`] is the inverse used by the distributed sweep
//! service's wire protocol ([`crate::service`]), and the
//! [`stats_to_json`]/[`stats_from_json`] pair below defines the one
//! canonical encoding of [`ScenarioStats`] rows so a worker-serialized
//! row merges back byte-identical on the coordinator.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

use crate::campaign::{CampaignReport, ScenarioStats};
use crate::scheduler::PolicyKind;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(anyhow!("expected object, got {other:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(anyhow!("expected array, got {other:?}")),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {other:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(anyhow!("expected number, got {other:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    /// Serialize to compact JSON text. `Json::parse(v.render())` is the
    /// identity: numbers go through Rust's shortest-round-trip `f64`
    /// `Display`, object keys come out in `BTreeMap` order, and strings
    /// are escaped with the same set of escapes the parser accepts.
    ///
    /// Non-finite numbers have no JSON literal; [`f64_to_json`] tags
    /// them as strings before they ever reach a `Json::Num`, so a
    /// non-finite `Num` here is a constructor bug and panics rather
    /// than emitting unparseable text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                assert!(n.is_finite(), "Json::Num({n}) is not renderable; use f64_to_json");
                let _ = write!(out, "{n}");
            }
            Json::Str(s) => escape_into(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (key, val)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(key, out);
                    out.push(':');
                    val.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Wire encoding of campaign stats
// ---------------------------------------------------------------------------
//
// Byte-identity across the distributed service hinges on three encoding
// rules, all enforced here and nowhere else:
//
//  * finite f64 uses `Display` (shortest text that parses back to the
//    same bits); non-finite f64 becomes the tagged strings "inf" /
//    "-inf" / "nan" since JSON has no literal for them;
//  * u64 travels as a decimal *string*: `Json::Num` is an f64 and would
//    silently round seeds and counters above 2^53;
//  * `stats_to_json` destructures `ScenarioStats` exhaustively (no `..`)
//    and `stats_from_json` builds it with a struct literal, so adding a
//    field without teaching the wire about it is a compile error — a
//    column can never silently drop.

/// Encode an `f64`, tagging non-finite values as strings.
pub fn f64_to_json(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else if v.is_nan() {
        Json::Str("nan".into())
    } else if v > 0.0 {
        Json::Str("inf".into())
    } else {
        Json::Str("-inf".into())
    }
}

/// Decode an `f64` encoded by [`f64_to_json`].
pub fn f64_from_json(j: &Json) -> Result<f64> {
    match j {
        Json::Num(n) => Ok(*n),
        Json::Str(s) => match s.as_str() {
            "nan" => Ok(f64::NAN),
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            other => bail!("expected a number or inf/-inf/nan tag, got \"{other}\""),
        },
        other => bail!("expected number, got {other:?}"),
    }
}

/// Encode a `u64` as a decimal string (`Json::Num` is an f64 and loses
/// integer precision above 2^53).
pub fn u64_to_json(v: u64) -> Json {
    Json::Str(v.to_string())
}

/// Decode a `u64` encoded by [`u64_to_json`].
pub fn u64_from_json(j: &Json) -> Result<u64> {
    let s = j.as_str().context("u64 travels as a decimal string")?;
    s.parse::<u64>().with_context(|| format!("bad u64 \"{s}\""))
}

fn opt_f64_to_json(v: Option<f64>) -> Json {
    match v {
        None => Json::Null,
        Some(x) => f64_to_json(x),
    }
}

fn opt_f64_from_json(j: &Json) -> Result<Option<f64>> {
    match j {
        Json::Null => Ok(None),
        other => Ok(Some(f64_from_json(other)?)),
    }
}

/// Encode one [`ScenarioStats`] row for the wire. Exhaustive by
/// construction: a new field breaks this destructuring pattern at
/// compile time until it gets a column here and in
/// [`stats_from_json`].
pub fn stats_to_json(s: &ScenarioStats) -> Json {
    let ScenarioStats {
        mix,
        seed,
        cap_mw,
        policy,
        faults,
        jobs,
        makespan_h,
        mean_wait_min,
        p95_wait_min,
        max_wait_min,
        utilization,
        peak_mw,
        energy_mwh,
        throttled,
        peak_congestion,
        peak_link_util,
        mean_link_util,
        mean_stretch,
        p95_stretch,
        events_skipped,
        retimes_elided,
        forks,
        restores,
        killed,
        requeued,
        wasted_node_h,
        goodput,
        p95_recovery_stretch,
    } = s;
    let mut m = BTreeMap::new();
    m.insert("mix".to_string(), Json::Str(mix.clone()));
    m.insert("seed".to_string(), u64_to_json(*seed));
    m.insert("cap_mw".to_string(), opt_f64_to_json(*cap_mw));
    m.insert("policy".to_string(), Json::Str(policy.name().to_string()));
    m.insert("faults".to_string(), Json::Str(faults.clone()));
    m.insert("jobs".to_string(), u64_to_json(*jobs as u64));
    m.insert("makespan_h".to_string(), f64_to_json(*makespan_h));
    m.insert("mean_wait_min".to_string(), f64_to_json(*mean_wait_min));
    m.insert("p95_wait_min".to_string(), f64_to_json(*p95_wait_min));
    m.insert("max_wait_min".to_string(), f64_to_json(*max_wait_min));
    m.insert("utilization".to_string(), f64_to_json(*utilization));
    m.insert("peak_mw".to_string(), f64_to_json(*peak_mw));
    m.insert("energy_mwh".to_string(), f64_to_json(*energy_mwh));
    m.insert("throttled".to_string(), u64_to_json(*throttled as u64));
    m.insert("peak_congestion".to_string(), f64_to_json(*peak_congestion));
    m.insert("peak_link_util".to_string(), f64_to_json(*peak_link_util));
    m.insert("mean_link_util".to_string(), f64_to_json(*mean_link_util));
    m.insert("mean_stretch".to_string(), f64_to_json(*mean_stretch));
    m.insert("p95_stretch".to_string(), f64_to_json(*p95_stretch));
    m.insert("events_skipped".to_string(), u64_to_json(*events_skipped));
    m.insert("retimes_elided".to_string(), u64_to_json(*retimes_elided));
    m.insert("forks".to_string(), u64_to_json(*forks));
    m.insert("restores".to_string(), u64_to_json(*restores));
    m.insert("killed".to_string(), u64_to_json(*killed));
    m.insert("requeued".to_string(), u64_to_json(*requeued));
    m.insert("wasted_node_h".to_string(), f64_to_json(*wasted_node_h));
    m.insert("goodput".to_string(), f64_to_json(*goodput));
    m.insert(
        "p95_recovery_stretch".to_string(),
        f64_to_json(*p95_recovery_stretch),
    );
    Json::Obj(m)
}

/// Decode one [`ScenarioStats`] row encoded by [`stats_to_json`].
pub fn stats_from_json(j: &Json) -> Result<ScenarioStats> {
    Ok(ScenarioStats {
        mix: j.get("mix")?.as_str()?.to_string(),
        seed: u64_from_json(j.get("seed")?)?,
        cap_mw: opt_f64_from_json(j.get("cap_mw")?)?,
        policy: PolicyKind::from_name(j.get("policy")?.as_str()?)?,
        faults: j.get("faults")?.as_str()?.to_string(),
        jobs: u64_from_json(j.get("jobs")?)? as usize,
        makespan_h: f64_from_json(j.get("makespan_h")?)?,
        mean_wait_min: f64_from_json(j.get("mean_wait_min")?)?,
        p95_wait_min: f64_from_json(j.get("p95_wait_min")?)?,
        max_wait_min: f64_from_json(j.get("max_wait_min")?)?,
        utilization: f64_from_json(j.get("utilization")?)?,
        peak_mw: f64_from_json(j.get("peak_mw")?)?,
        energy_mwh: f64_from_json(j.get("energy_mwh")?)?,
        throttled: u64_from_json(j.get("throttled")?)? as usize,
        peak_congestion: f64_from_json(j.get("peak_congestion")?)?,
        peak_link_util: f64_from_json(j.get("peak_link_util")?)?,
        mean_link_util: f64_from_json(j.get("mean_link_util")?)?,
        mean_stretch: f64_from_json(j.get("mean_stretch")?)?,
        p95_stretch: f64_from_json(j.get("p95_stretch")?)?,
        events_skipped: u64_from_json(j.get("events_skipped")?)?,
        retimes_elided: u64_from_json(j.get("retimes_elided")?)?,
        forks: u64_from_json(j.get("forks")?)?,
        restores: u64_from_json(j.get("restores")?)?,
        killed: u64_from_json(j.get("killed")?)?,
        requeued: u64_from_json(j.get("requeued")?)?,
        wasted_node_h: f64_from_json(j.get("wasted_node_h")?)?,
        goodput: f64_from_json(j.get("goodput")?)?,
        p95_recovery_stretch: f64_from_json(j.get("p95_recovery_stretch")?)?,
    })
}

/// Encode a whole [`CampaignReport`] (per-scenario rows in grid order).
pub fn report_to_json(r: &CampaignReport) -> Json {
    let mut m = BTreeMap::new();
    m.insert(
        "stats".to_string(),
        Json::Arr(r.stats.iter().map(stats_to_json).collect()),
    );
    Json::Obj(m)
}

/// Decode a [`CampaignReport`] encoded by [`report_to_json`].
pub fn report_from_json(j: &Json) -> Result<CampaignReport> {
    let stats = j
        .get("stats")?
        .as_arr()?
        .iter()
        .map(stats_from_json)
        .collect::<Result<Vec<_>>>()?;
    Ok(CampaignReport { stats })
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {other:?} at byte {}", self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}', got {other:?}"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => bail!("expected ',' or ']', got {other:?}"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
          "dgemm_256": {
            "hlo_chars": 8497,
            "inputs": [
              {"dtype": "float32", "shape": [256, 256]},
              {"dtype": "float32", "shape": [256, 256]}
            ],
            "outputs": [{"dtype": "float32", "shape": [256, 256]}]
          }
        }"#;
        let v = Json::parse(text).unwrap();
        let entry = v.get("dgemm_256").unwrap();
        assert_eq!(entry.get("hlo_chars").unwrap().as_usize().unwrap(), 8497);
        let inputs = entry.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs.len(), 2);
        let shape = inputs[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 256);
        assert_eq!(
            inputs[0].get("dtype").unwrap().as_str().unwrap(),
            "float32"
        );
    }

    #[test]
    fn scalar_values() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3]]").unwrap();
        let outer = v.as_arr().unwrap();
        assert_eq!(outer[0].as_arr().unwrap().len(), 2);
        assert_eq!(outer[1].as_arr().unwrap()[0].as_f64().unwrap(), 3.0);
    }

    #[test]
    fn render_round_trips_nested_values() {
        let text = r#"{"a":[1,2.5,-3e-2],"b":{"x":null,"y":true},"s":"q\"\\\n\tz"}"#;
        let v = Json::parse(text).unwrap();
        let rendered = v.render();
        assert_eq!(Json::parse(&rendered).unwrap(), v);
        // Rendering is deterministic (BTreeMap key order).
        assert_eq!(v.render(), rendered);
    }

    #[test]
    fn render_escapes_control_characters() {
        let v = Json::Str("a\u{1}b\u{c}c".into());
        let rendered = v.render();
        assert_eq!(rendered, "\"a\\u0001b\\fc\"");
        assert_eq!(Json::parse(&rendered).unwrap(), v);
    }

    #[test]
    fn f64_codec_is_exact_and_tags_non_finite() {
        for v in [
            0.0,
            -0.0,
            1.5,
            f64::MIN_POSITIVE,
            5e-324,
            f64::MAX,
            -123456789.000001,
        ] {
            let j = f64_to_json(v);
            let text = j.render();
            let back = f64_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(v.to_bits(), back.to_bits(), "f64 {v} did not round-trip");
        }
        assert_eq!(f64_to_json(f64::INFINITY), Json::Str("inf".into()));
        assert_eq!(f64_to_json(f64::NEG_INFINITY), Json::Str("-inf".into()));
        assert_eq!(f64_to_json(f64::NAN), Json::Str("nan".into()));
        assert!(f64_from_json(&Json::Str("nan".into())).unwrap().is_nan());
        assert!(f64_from_json(&Json::Str("bogus".into())).is_err());
    }

    #[test]
    fn u64_codec_survives_beyond_f64_precision() {
        for v in [0u64, 1, (1 << 53) + 1, u64::MAX] {
            let j = u64_to_json(v);
            let back = u64_from_json(&Json::parse(&j.render()).unwrap()).unwrap();
            assert_eq!(v, back);
        }
        assert!(u64_from_json(&Json::Num(3.0)).is_err());
    }

    #[test]
    fn stats_round_trip_preserves_every_field() {
        let s = ScenarioStats {
            mix: "hpc \"quoted\"\n".into(),
            seed: u64::MAX,
            cap_mw: Some(7.123456789012345),
            policy: PolicyKind::SpreadLinks,
            faults: "mtbf86400/grp4".into(),
            jobs: 1000,
            makespan_h: 23.000000000000004,
            mean_wait_min: 1.5,
            p95_wait_min: 0.1 + 0.2,
            max_wait_min: 99.0,
            utilization: 0.9999999999999999,
            peak_mw: 7.5,
            energy_mwh: 151.25,
            throttled: 42,
            peak_congestion: 1.75,
            peak_link_util: 0.875,
            mean_link_util: 0.3333333333333333,
            mean_stretch: 1.0625,
            p95_stretch: f64::INFINITY,
            events_skipped: (1 << 53) + 1,
            retimes_elided: 7,
            forks: 3,
            restores: 2,
            killed: 5,
            requeued: 4,
            wasted_node_h: 12.000000000000002,
            goodput: 0.95,
            p95_recovery_stretch: 1.5,
        };
        let text = stats_to_json(&s).render();
        let back = stats_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn report_round_trip() {
        let row = ScenarioStats {
            mix: "day".into(),
            seed: 1,
            cap_mw: None,
            policy: PolicyKind::PackFirst,
            faults: "none".into(),
            jobs: 10,
            makespan_h: 1.0,
            mean_wait_min: 0.0,
            p95_wait_min: 0.0,
            max_wait_min: 0.0,
            utilization: 0.5,
            peak_mw: 2.0,
            energy_mwh: 2.0,
            throttled: 0,
            peak_congestion: 0.0,
            peak_link_util: 0.0,
            mean_link_util: 0.0,
            mean_stretch: 1.0,
            p95_stretch: 1.0,
            events_skipped: 0,
            retimes_elided: 0,
            forks: 0,
            restores: 0,
            killed: 0,
            requeued: 0,
            wasted_node_h: 0.0,
            goodput: 1.0,
            p95_recovery_stretch: 0.0,
        };
        let mut second = row.clone();
        second.seed = 2;
        second.cap_mw = Some(6.0);
        let report = CampaignReport {
            stats: vec![row, second],
        };
        let text = report_to_json(&report).render();
        let back = report_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(report, back);
    }
}
