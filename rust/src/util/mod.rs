//! Self-contained replacements for crates unavailable in the offline
//! build: a JSON parser ([`json`]), a criterion-style bench harness
//! ([`bench`]) and a deterministic PRNG ([`rng`]).

pub mod bench;
pub mod json;
pub mod rng;
