//! Deterministic SplitMix64 PRNG for tests and property sweeps (the
//! offline build has no `rand`; determinism is a feature for a twin —
//! every simulated campaign is exactly reproducible from its seed).

/// SplitMix64: tiny, fast, passes BigCrush for this purpose.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(lo <= hi);
        lo + (self.next_u64() % (hi - lo + 1) as u64) as u32
    }

    /// Uniform float in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next_u64() % xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_bounds_inclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range_u32(3, 6);
            assert!((3..=6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Rng::new(11);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }
}
