//! Frontend and service partitions (paper §2.4, Appendix B): 32 frontend
//! servers (16 login + 16 graphical/visualization) and the 11 Operational
//! Management Nodes, plus a login load-balancer and a session model for
//! the typical frontend operations the paper lists (development,
//! compilation, data management, submission, post-processing).

use crate::hardware::CpuSpec;
use crate::metrics::Table;

/// Role of a frontend/service node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrontendRole {
    /// Login node: 6 TB HDD RAID-1 (BullSequana X430-E6).
    Login,
    /// Visualization node: 6.4 TB NVMe + 2 x Quadro RTX8000 (X450-E6).
    Graphical,
    /// Operational Management Node master (EPYC Rome, 128 GiB).
    OmnMaster,
    /// OMN worker (512 GiB, bulk storage).
    OmnWorker,
}

/// A frontend/service server.
#[derive(Debug, Clone)]
pub struct ServiceNode {
    pub role: FrontendRole,
    pub cpu: CpuSpec,
    pub cpu_sockets: u32,
    pub local_storage_tb: f64,
    pub gpus: u32,
    /// Concurrent interactive sessions the node is sized for.
    pub session_capacity: u32,
}

/// The whole frontend + service complement of §2.4 / Appendix B.
pub fn leonardo_service_fleet() -> Vec<ServiceNode> {
    let mut fleet = Vec::new();
    for _ in 0..16 {
        fleet.push(ServiceNode {
            role: FrontendRole::Login,
            cpu: CpuSpec::icelake_8358(),
            cpu_sockets: 2,
            local_storage_tb: 6.0,
            gpus: 0,
            session_capacity: 64,
        });
    }
    for _ in 0..16 {
        fleet.push(ServiceNode {
            role: FrontendRole::Graphical,
            cpu: CpuSpec::icelake_8358(),
            cpu_sockets: 2,
            local_storage_tb: 6.4,
            gpus: 2, // Quadro RTX8000 48 GB each
            session_capacity: 8,
        });
    }
    for _ in 0..3 {
        fleet.push(ServiceNode {
            role: FrontendRole::OmnMaster,
            cpu: CpuSpec::epyc_rome_7h12(),
            cpu_sockets: 1,
            local_storage_tb: 2.0 * 0.96 + 2.0 * 3.84,
            gpus: 0,
            session_capacity: 0,
        });
    }
    for _ in 0..8 {
        fleet.push(ServiceNode {
            role: FrontendRole::OmnWorker,
            cpu: CpuSpec::epyc_rome_7h12(),
            cpu_sockets: 1,
            local_storage_tb: 2.0 * 3.2 + 4.0 * 3.84 + 8.0 * 12.0,
            gpus: 0,
            session_capacity: 0,
        });
    }
    fleet
}

/// Least-loaded login balancer (what the login DNS round-robin plus
/// session caps amount to).
#[derive(Debug, Clone)]
pub struct LoginBalancer {
    capacity: Vec<u32>,
    load: Vec<u32>,
}

impl LoginBalancer {
    pub fn new(fleet: &[ServiceNode]) -> Self {
        let capacity: Vec<u32> = fleet
            .iter()
            .filter(|n| n.role == FrontendRole::Login)
            .map(|n| n.session_capacity)
            .collect();
        LoginBalancer {
            load: vec![0; capacity.len()],
            capacity,
        }
    }

    /// Place a session; returns the node index or None when full.
    pub fn connect(&mut self) -> Option<usize> {
        let (idx, &load) = self
            .load
            .iter()
            .enumerate()
            .min_by_key(|(i, &l)| (l, *i))?;
        if load >= self.capacity[idx] {
            return None;
        }
        self.load[idx] += 1;
        Some(idx)
    }

    pub fn disconnect(&mut self, node: usize) {
        assert!(self.load[node] > 0, "disconnect from idle node");
        self.load[node] -= 1;
    }

    pub fn total_sessions(&self) -> u32 {
        self.load.iter().sum()
    }

    pub fn total_capacity(&self) -> u32 {
        self.capacity.iter().sum()
    }
}

/// §2.4 summary table.
pub fn fleet_table() -> Table {
    let fleet = leonardo_service_fleet();
    let mut t = Table::new(
        "Frontend & service partitions (§2.4)",
        &["Role", "Count", "Sockets", "Local TB", "GPUs", "Sessions"],
    );
    for role in [
        FrontendRole::Login,
        FrontendRole::Graphical,
        FrontendRole::OmnMaster,
        FrontendRole::OmnWorker,
    ] {
        let nodes: Vec<&ServiceNode> = fleet.iter().filter(|n| n.role == role).collect();
        let n0 = nodes[0];
        t.row(vec![
            format!("{role:?}"),
            nodes.len().to_string(),
            n0.cpu_sockets.to_string(),
            format!("{:.1}", n0.local_storage_tb),
            n0.gpus.to_string(),
            n0.session_capacity.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_counts_match_paper() {
        let fleet = leonardo_service_fleet();
        let count = |r: FrontendRole| fleet.iter().filter(|n| n.role == r).count();
        assert_eq!(count(FrontendRole::Login), 16);
        assert_eq!(count(FrontendRole::Graphical), 16);
        assert_eq!(count(FrontendRole::OmnMaster), 3);
        assert_eq!(count(FrontendRole::OmnWorker), 8);
        assert_eq!(fleet.len(), 32 + 11);
    }

    #[test]
    fn graphical_nodes_have_two_rtx8000() {
        let fleet = leonardo_service_fleet();
        let g = fleet
            .iter()
            .find(|n| n.role == FrontendRole::Graphical)
            .unwrap();
        assert_eq!(g.gpus, 2);
        assert!((g.local_storage_tb - 6.4).abs() < 1e-9);
    }

    #[test]
    fn omn_uses_rome() {
        let fleet = leonardo_service_fleet();
        let m = fleet
            .iter()
            .find(|n| n.role == FrontendRole::OmnMaster)
            .unwrap();
        assert_eq!(m.cpu.cores, 64);
    }

    #[test]
    fn balancer_spreads_least_loaded_and_caps() {
        let fleet = leonardo_service_fleet();
        let mut lb = LoginBalancer::new(&fleet);
        assert_eq!(lb.total_capacity(), 16 * 64);
        // First 16 sessions land on 16 distinct nodes.
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..16 {
            seen.insert(lb.connect().unwrap());
        }
        assert_eq!(seen.len(), 16);
        // Fill to capacity, then reject.
        while lb.total_sessions() < lb.total_capacity() {
            assert!(lb.connect().is_some());
        }
        assert!(lb.connect().is_none());
        lb.disconnect(0);
        assert!(lb.connect().is_some());
    }

    #[test]
    fn fleet_table_has_four_roles() {
        assert_eq!(fleet_table().rows.len(), 4);
    }
}
