//! Table/CSV/markdown emitters used by the CLI, examples and benches to
//! print the paper's tables next to the twin's numbers.

use std::fmt::Write as _;

/// A rectangular report table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity mismatch in '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// GitHub-flavoured markdown rendering.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}\n", self.title);
        }
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        out
    }

    /// RFC-4180-ish CSV rendering.
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(esc).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(esc).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Fixed-width console rendering.
    pub fn to_console(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let fmt_row = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for r in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(r, &widths));
        }
        out
    }
}

/// Format helpers.
pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn sig3(v: f64) -> String {
    if v == 0.0 {
        return "0".into();
    }
    let mag = v.abs().log10().floor() as i32;
    let dec = (2 - mag).max(0) as usize;
    format!("{v:.dec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        t.row(vec!["2".into(), "z\"q".into()]);
        t
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
        assert!(md.lines().count() >= 5);
    }

    #[test]
    fn csv_escaping() {
        let csv = sample().to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"z\"\"q\""));
    }

    #[test]
    fn console_aligns() {
        let c = sample().to_console();
        assert!(c.contains("== Demo =="));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn sig3_formatting() {
        assert_eq!(sig3(0.0476), "0.0476");
        assert_eq!(sig3(51.2), "51.2");
        assert_eq!(sig3(1.38), "1.38");
        assert_eq!(sig3(0.0), "0");
    }
}
