//! The dragonfly+ interconnect topology (paper §2.2, Fig 4).
//!
//! LEONARDO's fabric is a two-level hierarchy: inside each of the 23
//! cells, leaf and spine switches form a fully-connected bipartite graph
//! (the "+" of dragonfly+); at the top level the 23 cells are fully
//! connected through spine up-links. This module *constructs* that graph
//! from a [`MachineConfig`] — spine counts, per-cell-type leaf counts,
//! node attachments, global link budget — and provides minimal/Valiant
//! routing with the paper's per-hop latency budget.
//!
//! Paper invariants reproduced (and unit-tested):
//! * 18 spines per cell, 40-port 200G mode, 22 up / 18 down (pruning
//!   factor 18/22 = 0.82);
//! * 18 leaves in Booster/Hybrid cells, 16 in DC cells, 13 in the I/O
//!   cell, HDR100 toward nodes;
//! * Booster nodes attach to two leaves (dual rail), DC nodes to one;
//! * 823 switches in total (including the 4 Ethernet gateways);
//! * worst-case node-to-node latency ~3 us, NIC-dominated (§2.2).



use crate::config::{CellKind, MachineConfig};

/// Spines per cell — constant across cell types (§2.2).
pub const SPINES_PER_CELL: u32 = 18;
/// Up-links per spine toward other cells (40-port switch, 18 down).
pub const SPINE_UPLINKS: u32 = 22;
/// InfiniBand gateways to external networks (§2.2).
pub const GATEWAYS: u32 = 4;
/// Per-port HDR bandwidth in the spine layer, Gbps.
pub const HDR_GBPS: f64 = 200.0;
/// Leaf-to-node HDR100 bandwidth, Gbps.
pub const HDR100_GBPS: f64 = 100.0;

/// Per-hop latency budget (§2.2).
pub mod latency {
    /// Switch port-to-port latency, ns (QM8700).
    pub const SWITCH_NS: f64 = 90.0;
    /// NIC latency per side, ns (ConnectX-6).
    pub const NIC_NS: f64 = 600.0;
    /// Optical fiber propagation, ns per meter (~c/1.5).
    pub const FIBER_NS_PER_M: f64 = 5.0;
    /// Fiber runs, meters (§2.2).
    pub const NODE_LEAF_M: f64 = 1.0;
    pub const LEAF_SPINE_M: f64 = 5.0;
    pub const SPINE_SPINE_M: f64 = 20.0;
}

/// Routing policy across the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routing {
    /// Shortest path (leaf-spine-global-spine-leaf between cells).
    Minimal,
    /// Valiant load balancing through a random intermediate cell —
    /// the adaptive-routing worst case that bounds latency (§2.2).
    Valiant,
    /// Per-flow adaptive routing: each flow takes the minimal path
    /// unless the measured load imbalance on its direct link bundle
    /// makes the Valiant detour (two hops over less-loaded bundles)
    /// the better deal — the decision
    /// [`crate::network::Network::link_bw_for_cells`] makes from the
    /// per-link load table. Latency-wise an adaptive flow on an idle
    /// fabric is a minimal flow.
    Adaptive,
}

impl Routing {
    /// CLI/wire name (`minimal` / `valiant` / `adaptive`).
    pub fn name(self) -> &'static str {
        match self {
            Routing::Minimal => "minimal",
            Routing::Valiant => "valiant",
            Routing::Adaptive => "adaptive",
        }
    }

    /// Inverse of [`Routing::name`], used by the sweep-spec wire codec.
    pub fn from_name(name: &str) -> anyhow::Result<Routing> {
        match name {
            "minimal" => Ok(Routing::Minimal),
            "valiant" => Ok(Routing::Valiant),
            "adaptive" => Ok(Routing::Adaptive),
            other => anyhow::bail!(
                "unknown routing '{other}' (known: minimal, valiant, adaptive)"
            ),
        }
    }
}

/// Dense index of the global link bundle joining the unordered cell
/// pair `(a, b)` on an `n_cells`-cell fabric: pairs are numbered
/// row-major over the strict upper triangle, so ids are `0..n(n-1)/2`.
/// Shared by [`Topology::link_bundle_id`] and the scheduler's
/// engine-side link table so both sides agree on addressing without
/// holding a `Topology`.
pub fn cell_pair_index(n_cells: usize, a: u32, b: u32) -> usize {
    debug_assert!(a != b, "a cell has no global link to itself");
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    let (lo, hi, n) = (lo as usize, hi as usize, n_cells);
    debug_assert!(hi < n, "cell {hi} outside the {n}-cell fabric");
    lo * n - lo * (lo + 1) / 2 + (hi - lo - 1)
}

/// Number of link bundles (unordered cell pairs) on an `n_cells` fabric.
pub fn cell_pair_count(n_cells: usize) -> usize {
    n_cells * n_cells.saturating_sub(1) / 2
}

/// Where a node sits in the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeAddr {
    pub cell: u32,
    /// Primary leaf within the cell.
    pub leaf: u32,
    /// Position under the leaf.
    pub port: u32,
    /// Rails (1 = single HDR100 uplink, 2 = dual rail).
    pub rails: u32,
}

/// Summary of a route through the fabric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Route {
    pub switch_hops: u32,
    pub fiber_m: f64,
    /// Inter-cell (global) links traversed.
    pub global_hops: u32,
}

impl Route {
    /// End-to-end small-message latency over this route, ns.
    pub fn latency_ns(&self) -> f64 {
        2.0 * latency::NIC_NS
            + self.switch_hops as f64 * latency::SWITCH_NS
            + self.fiber_m * latency::FIBER_NS_PER_M
    }
}

/// One cell of the fabric.
#[derive(Debug, Clone)]
pub struct CellTopo {
    pub kind: CellKind,
    pub spines: u32,
    pub leaves: u32,
    pub nodes: u32,
    /// Rails per node (2 for Booster-style attach, 1 for DC).
    pub rails: u32,
}

/// The whole fabric.
#[derive(Debug, Clone)]
pub struct Topology {
    pub cells: Vec<CellTopo>,
    /// Global links between each unordered pair of cells.
    pub links_per_cell_pair: u32,
    /// Cumulative node counts for address lookup.
    starts: Vec<u32>,
    /// Optional per-bundle capacity overrides, Gbps, indexed by
    /// [`cell_pair_index`]. `None` — the LEONARDO default — means every
    /// bundle carries the uniform [`Topology::cell_pair_bw_gbps`]
    /// budget; [`Topology::with_bundle_capacities`] installs a
    /// heterogeneous table (e.g. a cabling defect or a thin long-reach
    /// pair).
    bundle_caps: Option<Vec<f64>>,
}

impl Topology {
    /// Wire the fabric for a machine description.
    pub fn build(cfg: &MachineConfig) -> Self {
        let cells: Vec<CellTopo> = cfg
            .cells
            .iter()
            .map(|c| {
                let leaves = match c.kind {
                    CellKind::Booster | CellKind::Hybrid => 18,
                    CellKind::DataCentric => 16,
                    CellKind::Io => 13,
                };
                let rails = match c.kind {
                    CellKind::DataCentric => 1,
                    _ => 2,
                };
                CellTopo {
                    kind: c.kind,
                    spines: SPINES_PER_CELL,
                    leaves,
                    nodes: c.nodes(),
                    rails,
                }
            })
            .collect();
        // Full cell-to-cell connectivity: every spine spends its up-links
        // one per peer cell; a pair of cells is joined by one link per
        // spine pair up to the up-link budget.
        let n_cells = cells.len() as u32;
        let links_per_cell_pair = if n_cells > 1 {
            (SPINES_PER_CELL * SPINE_UPLINKS / (n_cells - 1)).min(SPINES_PER_CELL)
        } else {
            0
        };
        let mut starts = Vec::with_capacity(cells.len() + 1);
        let mut acc = 0;
        for c in &cells {
            starts.push(acc);
            acc += c.nodes;
        }
        starts.push(acc);
        Topology {
            cells,
            links_per_cell_pair,
            starts,
            bundle_caps: None,
        }
    }

    /// Install a heterogeneous per-bundle capacity table (Gbps, one
    /// entry per unordered cell pair in [`cell_pair_index`] order).
    /// Every entry must be positive and finite; the length must cover
    /// every bundle.
    pub fn with_bundle_capacities(mut self, caps: Vec<f64>) -> Self {
        assert_eq!(
            caps.len(),
            self.num_link_bundles(),
            "bundle capacity table must cover every unordered cell pair"
        );
        assert!(
            caps.iter().all(|&c| c.is_finite() && c > 0.0),
            "bundle capacities must be positive and finite"
        );
        self.bundle_caps = Some(caps);
        self
    }

    pub fn total_nodes(&self) -> u32 {
        *self.starts.last().unwrap()
    }

    /// Leaf + spine switches, plus the external gateways.
    pub fn total_switches(&self) -> u32 {
        self.cells
            .iter()
            .map(|c| c.spines + c.leaves)
            .sum::<u32>()
            + GATEWAYS
    }

    /// Global (inter-cell) links in the whole fabric.
    pub fn total_global_links(&self) -> u32 {
        let n = self.cells.len() as u32;
        n * (n - 1) / 2 * self.links_per_cell_pair
    }

    /// Address of a node by global index (nodes are numbered cell-major,
    /// round-robin across the cell's leaves — the wiring ATOS uses to
    /// balance leaf down-links).
    pub fn node_addr(&self, node: u32) -> NodeAddr {
        assert!(node < self.total_nodes(), "node {node} out of range");
        let cell = match self.starts.binary_search(&node) {
            Ok(i) if i + 1 < self.starts.len() => i,
            Ok(i) => i - 1,
            Err(i) => i - 1,
        };
        let c = &self.cells[cell];
        let local = node - self.starts[cell];
        NodeAddr {
            cell: cell as u32,
            leaf: local % c.leaves,
            port: local / c.leaves,
            rails: c.rails,
        }
    }

    /// Route between two nodes under `policy`.
    pub fn route(&self, a: u32, b: u32, policy: Routing) -> Route {
        use latency::*;
        let ia = self.node_addr(a);
        let ib = self.node_addr(b);
        if a == b {
            return Route {
                switch_hops: 0,
                fiber_m: 0.0,
                global_hops: 0,
            };
        }
        if ia.cell == ib.cell {
            if ia.leaf == ib.leaf {
                // node -> leaf -> node
                return Route {
                    switch_hops: 1,
                    fiber_m: 2.0 * NODE_LEAF_M,
                    global_hops: 0,
                };
            }
            // node -> leaf -> spine -> leaf -> node
            return Route {
                switch_hops: 3,
                fiber_m: 2.0 * NODE_LEAF_M + 2.0 * LEAF_SPINE_M,
                global_hops: 0,
            };
        }
        match policy {
            // An adaptive flow on an idle fabric takes the minimal
            // path; the load-dependent detour decision lives in the
            // bandwidth model, which has the per-link loads.
            Routing::Minimal | Routing::Adaptive => Route {
                // leaf -> spine -> (global) -> spine -> leaf
                switch_hops: 4,
                fiber_m: 2.0 * NODE_LEAF_M + 2.0 * LEAF_SPINE_M + SPINE_SPINE_M,
                global_hops: 1,
            },
            Routing::Valiant => Route {
                // detour through an intermediate cell: two global hops and
                // a leaf bounce inside the intermediate group.
                switch_hops: 6,
                fiber_m: 2.0 * NODE_LEAF_M
                    + 4.0 * LEAF_SPINE_M
                    + 2.0 * SPINE_SPINE_M,
                global_hops: 2,
            },
        }
    }

    /// Worst-case small-message latency across the machine, ns: the
    /// Valiant route between nodes in different cells (§2.2 quotes 3 us,
    /// dominated by the two NIC traversals).
    pub fn max_latency_ns(&self) -> f64 {
        let last = self.total_nodes() - 1;
        self.route(0, last, Routing::Valiant).latency_ns()
    }

    /// Aggregate bandwidth between two distinct cells, Gbps.
    pub fn cell_pair_bw_gbps(&self) -> f64 {
        self.links_per_cell_pair as f64 * HDR_GBPS
    }

    /// Number of addressable global link bundles (one per unordered
    /// cell pair — each bundle is `links_per_cell_pair` physical HDR
    /// links).
    pub fn num_link_bundles(&self) -> usize {
        cell_pair_count(self.cells.len())
    }

    /// Dense id of the link bundle joining cells `a` and `b` (`None`
    /// for `a == b` or an out-of-fabric cell).
    pub fn link_bundle_id(&self, a: u32, b: u32) -> Option<usize> {
        let n = self.cells.len();
        if a == b || a as usize >= n || b as usize >= n {
            return None;
        }
        Some(cell_pair_index(n, a, b))
    }

    /// Inverse of [`Topology::link_bundle_id`]: the `(low, high)` cell
    /// pair a bundle id addresses.
    pub fn link_bundle_cells(&self, id: usize) -> (u32, u32) {
        let n = self.cells.len();
        assert!(id < cell_pair_count(n), "bundle {id} out of range");
        let mut lo = 0usize;
        let mut base = 0usize;
        while base + (n - lo - 1) <= id {
            base += n - lo - 1;
            lo += 1;
        }
        (lo as u32, (lo + 1 + (id - base)) as u32)
    }

    /// Capacity of link bundle `id`, Gbps. Uniform
    /// ([`Topology::cell_pair_bw_gbps`] — every pair gets the same
    /// `links_per_cell_pair` budget on the fully connected top level)
    /// unless a heterogeneous table was installed with
    /// [`Topology::with_bundle_capacities`].
    pub fn link_bundle_capacity_gbps(&self, id: usize) -> f64 {
        match &self.bundle_caps {
            Some(caps) => caps[id],
            None => self.cell_pair_bw_gbps(),
        }
    }

    /// Whether every bundle carries the uniform budget (no heterogeneous
    /// table installed) — the fast path the bandwidth model keeps
    /// allocation- and scan-free.
    pub fn uniform_bundles(&self) -> bool {
        self.bundle_caps.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;

    fn leo() -> Topology {
        Topology::build(&MachineConfig::leonardo())
    }

    #[test]
    fn switch_census_is_823() {
        // §2.2: "The total number of HDR switches is 823."
        // 23 x 18 spines + (19x18 + 18 + 2x16 + 13) leaves + 4 gateways.
        assert_eq!(leo().total_switches(), 823);
    }

    #[test]
    fn leaf_counts_by_cell_kind() {
        let t = leo();
        for c in &t.cells {
            let expect = match c.kind {
                CellKind::Booster | CellKind::Hybrid => 18,
                CellKind::DataCentric => 16,
                CellKind::Io => 13,
            };
            assert_eq!(c.leaves, expect);
            assert_eq!(c.spines, 18);
        }
    }

    #[test]
    fn pruning_factor_is_0_82() {
        // 18 down / 22 up on every spine (§2.2).
        let f = SPINES_PER_CELL as f64 / SPINE_UPLINKS as f64;
        assert!((f - 0.818).abs() < 0.01);
    }

    #[test]
    fn global_links_per_pair() {
        let t = leo();
        // 18 spines x 22 uplinks / 22 peers = 18 links to each other cell.
        assert_eq!(t.links_per_cell_pair, 18);
        assert_eq!(t.cell_pair_bw_gbps(), 3600.0);
        assert_eq!(t.total_global_links(), 23 * 22 / 2 * 18);
    }

    #[test]
    fn booster_nodes_are_dual_rail() {
        let t = leo();
        let a = t.node_addr(0);
        assert_eq!(a.rails, 2);
        // DC nodes start after the 19 Booster cells (19 x 180 nodes).
        let dc = t.node_addr(19 * 180 + 5);
        assert_eq!(dc.rails, 1);
    }

    #[test]
    fn addresses_partition_the_machine() {
        let t = leo();
        assert_eq!(t.total_nodes(), 1536 + 3456);
        let mut per_cell = vec![0u32; t.cells.len()];
        for n in 0..t.total_nodes() {
            per_cell[t.node_addr(n).cell as usize] += 1;
        }
        for (c, &count) in t.cells.iter().zip(&per_cell) {
            assert_eq!(count, c.nodes);
        }
    }

    #[test]
    fn leaf_attachment_is_balanced() {
        let t = leo();
        // Booster cell 0: 180 nodes over 18 leaves = 10 per leaf.
        let mut per_leaf = vec![0u32; 18];
        for n in 0..180 {
            per_leaf[t.node_addr(n).leaf as usize] += 1;
        }
        assert!(per_leaf.iter().all(|&c| c == 10));
    }

    #[test]
    fn same_leaf_route_is_one_switch() {
        let t = leo();
        // Nodes 0 and 18 share leaf 0 of cell 0 (round-robin attach).
        let r = t.route(0, 18, Routing::Minimal);
        assert_eq!(r.switch_hops, 1);
        assert_eq!(r.global_hops, 0);
    }

    #[test]
    fn intra_cell_route_is_three_switches() {
        let t = leo();
        let r = t.route(0, 1, Routing::Minimal);
        assert_eq!(r.switch_hops, 3);
        assert_eq!(r.global_hops, 0);
    }

    #[test]
    fn inter_cell_minimal_is_four_switches_one_global() {
        let t = leo();
        let r = t.route(0, 2000, Routing::Minimal);
        assert_eq!(r.switch_hops, 4);
        assert_eq!(r.global_hops, 1);
    }

    #[test]
    fn valiant_is_longer_than_minimal() {
        let t = leo();
        let m = t.route(0, 2000, Routing::Minimal);
        let v = t.route(0, 2000, Routing::Valiant);
        assert!(v.switch_hops > m.switch_hops);
        assert!(v.latency_ns() > m.latency_ns());
    }

    #[test]
    fn max_latency_is_about_3us_and_nic_dominated() {
        let t = leo();
        let max = t.max_latency_ns();
        // §2.2: worst case ~3 us; NICs contribute 1.2 us regardless.
        assert!(max <= 3000.0, "max {max} ns");
        assert!(max >= 1500.0, "max {max} ns");
        let nic = 2.0 * latency::NIC_NS;
        assert!(nic / max > 0.35, "NIC share {}", nic / max);
    }

    #[test]
    fn self_route_is_free() {
        let t = leo();
        let r = t.route(42, 42, Routing::Minimal);
        assert_eq!(r.switch_hops, 0);
        assert_eq!(r.latency_ns(), 2.0 * latency::NIC_NS);
    }

    #[test]
    fn link_bundle_ids_are_a_dense_bijection() {
        let t = leo();
        let n = t.cells.len();
        assert_eq!(t.num_link_bundles(), n * (n - 1) / 2);
        let mut seen = vec![false; t.num_link_bundles()];
        for a in 0..n as u32 {
            for b in (a + 1)..n as u32 {
                let id = t.link_bundle_id(a, b).unwrap();
                assert_eq!(t.link_bundle_id(b, a), Some(id), "unordered");
                assert!(!seen[id], "bundle {id} assigned twice");
                seen[id] = true;
                assert_eq!(t.link_bundle_cells(id), (a, b), "inverse");
            }
        }
        assert!(seen.iter().all(|&s| s), "ids not dense");
        assert_eq!(t.link_bundle_id(3, 3), None);
        assert_eq!(t.link_bundle_id(0, 999), None);
    }

    #[test]
    fn link_bundle_capacity_matches_pair_bandwidth() {
        let t = leo();
        assert!(t.uniform_bundles());
        for id in 0..t.num_link_bundles() {
            assert_eq!(t.link_bundle_capacity_gbps(id), 3600.0);
        }
        // The bundle space covers every physical global link.
        assert_eq!(t.num_link_bundles() as u32 * t.links_per_cell_pair, t.total_global_links());
    }

    #[test]
    fn heterogeneous_bundle_capacities_override_the_uniform_budget() {
        let t = leo();
        let narrow = t.link_bundle_id(0, 1).unwrap();
        let mut caps = vec![3600.0; t.num_link_bundles()];
        caps[narrow] = 400.0;
        let t = t.with_bundle_capacities(caps);
        assert!(!t.uniform_bundles());
        assert_eq!(t.link_bundle_capacity_gbps(narrow), 400.0);
        let other = t.link_bundle_id(2, 3).unwrap();
        assert_eq!(t.link_bundle_capacity_gbps(other), 3600.0);
    }

    #[test]
    #[should_panic(expected = "cover every unordered cell pair")]
    fn short_bundle_capacity_table_is_rejected() {
        let t = leo();
        let _ = t.with_bundle_capacities(vec![3600.0; 3]);
    }

    #[test]
    fn adaptive_routing_is_minimal_on_an_idle_fabric() {
        let t = leo();
        let a = t.route(0, 2000, Routing::Adaptive);
        let m = t.route(0, 2000, Routing::Minimal);
        assert_eq!(a, m, "idle adaptive flow must take the minimal path");
    }

    #[test]
    fn marconi_topology_builds() {
        let t = Topology::build(&MachineConfig::marconi100());
        assert_eq!(t.total_nodes(), 980);
        assert!(t.links_per_cell_pair >= 18);
    }
}
