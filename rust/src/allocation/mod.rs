//! Access and resource allocation (paper §3): LEONARDO's computing time
//! is granted through peer-reviewed Calls for Proposal — 50% EuroHPC,
//! 50% CINECA/ISCRA — and consumed as node-hour budgets that the
//! scheduler accounts against.
//!
//! This module models that pipeline: calls, proposals with review
//! scores, the 50/50 capacity split, awarded projects with node-hour
//! budgets, and job-level accounting (a job is admitted only while its
//! project has budget; usage is charged on completion).

use std::collections::BTreeMap;

use crate::metrics::{f1, Table};
use crate::scheduler::{Job, JobRecord};

/// The two access routes of §3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CallKind {
    EuroHpc,
    Iscra,
}

/// A submitted proposal.
#[derive(Debug, Clone)]
pub struct Proposal {
    pub id: u64,
    pub call: CallKind,
    pub title: String,
    /// Peer-review scientific merit, 0..=10.
    pub merit: f64,
    /// Technical suitability for the architecture, 0..=10.
    pub technical: f64,
    /// Requested budget, node-hours.
    pub requested_nh: f64,
}

impl Proposal {
    /// Combined score: merit gates, technical weighs (the §3 process:
    /// peer review for merit plus a technical assessment).
    pub fn score(&self) -> f64 {
        if self.technical < 5.0 {
            0.0 // not suitable for the architecture
        } else {
            0.7 * self.merit + 0.3 * self.technical
        }
    }
}

/// An awarded project.
#[derive(Debug, Clone)]
pub struct Project {
    pub proposal: Proposal,
    pub awarded_nh: f64,
    pub used_nh: f64,
}

impl Project {
    pub fn remaining_nh(&self) -> f64 {
        (self.awarded_nh - self.used_nh).max(0.0)
    }
}

/// One allocation round over a capacity of node-hours.
#[derive(Debug, Default)]
pub struct AllocationRound {
    pub projects: BTreeMap<u64, Project>,
}

/// Run a call: rank by score, award in order until the call's share of
/// capacity runs out (half-awards are allowed for the last grantee).
pub fn run_round(proposals: Vec<Proposal>, capacity_nh: f64) -> AllocationRound {
    let mut round = AllocationRound::default();
    // §3: 50% EuroHPC / 50% ISCRA.
    for (kind, share) in [(CallKind::EuroHpc, 0.5), (CallKind::Iscra, 0.5)] {
        let mut pool: Vec<&Proposal> = proposals
            .iter()
            .filter(|p| p.call == kind && p.score() > 0.0)
            .collect();
        pool.sort_by(|a, b| b.score().total_cmp(&a.score()).then(a.id.cmp(&b.id)));
        let mut left = capacity_nh * share;
        for p in pool {
            if left <= 0.0 {
                break;
            }
            let award = p.requested_nh.min(left);
            left -= award;
            round.projects.insert(
                p.id,
                Project {
                    proposal: p.clone(),
                    awarded_nh: award,
                    used_nh: 0.0,
                },
            );
        }
    }
    round
}

impl AllocationRound {
    /// Can `project` run a job of this size/length?
    pub fn admit(&self, project: u64, job: &Job) -> bool {
        self.projects
            .get(&project)
            .map(|p| p.remaining_nh() >= job_cost_nh(job))
            .unwrap_or(false)
    }

    /// Charge a completed job to its project.
    pub fn charge(&mut self, project: u64, job: &Job, record: &JobRecord) {
        let hours = (record.end_time - record.start_time) / 3600.0;
        let cost = job.nodes as f64 * hours;
        if let Some(p) = self.projects.get_mut(&project) {
            p.used_nh += cost;
        }
    }

    pub fn report(&self) -> Table {
        let mut t = Table::new(
            "Allocation accounting (ISCRA/EuroHPC, §3)",
            &["Project", "Call", "Score", "Awarded [kNh]", "Used [kNh]", "Left [kNh]"],
        );
        for p in self.projects.values() {
            t.row(vec![
                p.proposal.title.clone(),
                format!("{:?}", p.proposal.call),
                f1(p.proposal.score()),
                f1(p.awarded_nh / 1e3),
                f1(p.used_nh / 1e3),
                f1(p.remaining_nh() / 1e3),
            ]);
        }
        t
    }

    pub fn total_awarded(&self) -> f64 {
        self.projects.values().map(|p| p.awarded_nh).sum()
    }
}

/// Estimated cost of a job, node-hours.
pub fn job_cost_nh(job: &Job) -> f64 {
    job.nodes as f64 * job.est_seconds / 3600.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::Placement;
    use crate::scheduler::Partition;

    fn proposal(id: u64, call: CallKind, merit: f64, technical: f64, nh: f64) -> Proposal {
        Proposal {
            id,
            call,
            title: format!("P{id}"),
            merit,
            technical,
            requested_nh: nh,
        }
    }

    fn job(nodes: u32, secs: f64) -> Job {
        Job {
            id: 0,
            partition: Partition::Booster,
            nodes,
            est_seconds: secs,
            run_seconds: secs,
            submit_time: 0.0,
            boundness: 1.0,
            comm_fraction: 0.0,
            checkpoint: crate::scheduler::CheckpointPolicy::None,
        }
    }

    #[test]
    fn fifty_fifty_split_respected() {
        let proposals = vec![
            proposal(1, CallKind::EuroHpc, 10.0, 10.0, 1e6),
            proposal(2, CallKind::Iscra, 10.0, 10.0, 1e6),
        ];
        let round = run_round(proposals, 1000.0);
        assert_eq!(round.projects[&1].awarded_nh, 500.0);
        assert_eq!(round.projects[&2].awarded_nh, 500.0);
    }

    #[test]
    fn ranking_by_score_with_merit_weight() {
        let proposals = vec![
            proposal(1, CallKind::Iscra, 9.0, 8.0, 400.0),
            proposal(2, CallKind::Iscra, 6.0, 10.0, 400.0),
        ];
        // capacity 500 total -> ISCRA share 250: only the better one fits
        // fully, second gets the remainder.
        let round = run_round(proposals, 500.0);
        assert!((round.projects[&1].awarded_nh - 250.0).abs() < 1e-9);
        assert!(!round.projects.contains_key(&2));
    }

    #[test]
    fn technically_unsuitable_proposals_are_rejected() {
        let proposals = vec![proposal(1, CallKind::EuroHpc, 10.0, 3.0, 100.0)];
        let round = run_round(proposals, 1000.0);
        assert!(round.projects.is_empty());
    }

    #[test]
    fn admission_and_charging() {
        let proposals = vec![proposal(1, CallKind::Iscra, 9.0, 9.0, 100.0)];
        let mut round = run_round(proposals, 200.0);
        let j = job(50, 3600.0); // 50 node-hours
        assert!(round.admit(1, &j));
        let record = JobRecord {
            id: 0,
            start_time: 0.0,
            end_time: 3600.0,
            placement: Placement {
                nodes_per_cell: vec![(0, 50)],
            },
            dvfs_scale: 1.0,
            min_dvfs_scale: 1.0,
        };
        round.charge(1, &j, &record);
        assert!((round.projects[&1].used_nh - 50.0).abs() < 1e-9);
        assert!(round.admit(1, &j)); // 50 left, job costs 50
        round.charge(1, &j, &record);
        assert!(!round.admit(1, &j)); // budget exhausted
    }

    #[test]
    fn unknown_project_never_admits() {
        let round = run_round(vec![], 100.0);
        assert!(!round.admit(42, &job(1, 60.0)));
    }

    #[test]
    fn report_lists_projects() {
        let proposals = vec![
            proposal(1, CallKind::EuroHpc, 8.0, 9.0, 50.0),
            proposal(2, CallKind::Iscra, 7.0, 9.0, 50.0),
        ];
        let round = run_round(proposals, 1000.0);
        assert_eq!(round.report().rows.len(), 2);
        assert!((round.total_awarded() - 100.0).abs() < 1e-9);
    }
}
