//! Distributed lattice-Boltzmann driver: the weak-scaling study of
//! Appendix A.3 (Table 7, Fig 5).
//!
//! The paper scales a D3Q19 LBM code (Falcucci et al. 2021, Succi et al.
//! 2019) from 8 to 9,900 GPUs with a fixed per-GPU subdomain and reports
//! lattice updates per second (LUPS) and efficiency normalised to the
//! 2-node run. The twin reproduces the experiment end-to-end:
//!
//! * per-GPU compute rate — LBM is HBM-bandwidth bound (the fused
//!   collide+stream touches 19 distributions twice: 152 B/site/step in
//!   f32); sustained rate = bw x eff / 152 with the architecture
//!   efficiency measured for this kernel family (A100 ~0.55 of HBM;
//!   V100 ~0.40 — these two constants also reproduce the paper's "2.5x
//!   faster than Marconi100" claim, see tests);
//! * the *kernel itself is real*: [`crate::coordinator`] executes the
//!   Pallas `lbm_step` artifact via PJRT and projects the measured
//!   per-site rate onto the GPU roofline (calibration);
//! * halo exchange — 5 distributions cross each face per step; the
//!   decomposition picks near-cubic node grids, and face traffic rides
//!   the [`Network`] flow model (multi-cell congestion included);
//! * a small allreduce every `DIAG_EVERY` steps for global diagnostics.



use crate::hardware::NodeSpec;
use crate::network::{Network, Placement};

/// Bytes touched per lattice site per step (19 loads + 19 stores, f32).
pub const BYTES_PER_SITE: f64 = 19.0 * 4.0 * 2.0;
/// Distributions crossing a subdomain face per site (D3Q19: 5 per face).
pub const DISTS_PER_FACE: f64 = 5.0;
/// Steps between global diagnostic allreduces.
pub const DIAG_EVERY: f64 = 100.0;

/// HBM efficiency of the fused collide-stream kernel per architecture.
pub fn lbm_hbm_efficiency(gpu_name: &str) -> f64 {
    if gpu_name.contains("V100") {
        0.40
    } else {
        0.55
    }
}

/// Weak-scaling experiment configuration.
#[derive(Debug, Clone, Copy)]
pub struct LbmConfig {
    /// Cubic per-GPU subdomain edge (paper-scale: 320 -> 32.8 Msites/GPU).
    pub per_gpu_edge: u32,
    /// Override per-GPU site-update rate, LUPS (from calibration); if
    /// `None` the HBM roofline model is used.
    pub per_gpu_lups: Option<f64>,
}

impl Default for LbmConfig {
    fn default() -> Self {
        LbmConfig {
            per_gpu_edge: 320,
            per_gpu_lups: None,
        }
    }
}

/// One point of the weak-scaling curve.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub nodes: u32,
    pub gpus: u32,
    /// Aggregate lattice updates per second.
    pub lups: f64,
    /// Efficiency normalised to the smallest run of the sweep.
    pub efficiency: f64,
    /// Per-step wall time, s.
    pub step_seconds: f64,
}

/// Near-cubic factorisation of `n` into a 3-D node grid.
pub fn decompose_3d(n: u32) -> (u32, u32, u32) {
    let mut best = (n, 1, 1);
    let mut best_cost = u64::MAX;
    let mut x = 1;
    while x * x * x <= n {
        if n % x == 0 {
            let rest = n / x;
            let mut y = x;
            while y * y <= rest {
                if rest % y == 0 {
                    let z = rest / y;
                    // surface-minimising: cost ~ sum of pairwise products
                    let (a, b, c) = (x as u64, y as u64, rest as u64 / y as u64);
                    let cost = a * b + b * c + a * c;
                    let _ = z;
                    if cost < best_cost {
                        best_cost = cost;
                        best = (x, y, (rest / y));
                    }
                }
                y += 1;
            }
        }
        x += 1;
    }
    best
}

/// The LBM weak-scaling simulator over one machine's node type + network.
pub struct LbmDriver<'a> {
    pub node: &'a NodeSpec,
    pub net: &'a Network,
    pub cfg: LbmConfig,
}

impl<'a> LbmDriver<'a> {
    pub fn new(node: &'a NodeSpec, net: &'a Network, cfg: LbmConfig) -> Self {
        LbmDriver { node, net, cfg }
    }

    /// Sustained per-GPU update rate, LUPS.
    pub fn per_gpu_lups(&self) -> f64 {
        if let Some(r) = self.cfg.per_gpu_lups {
            return r;
        }
        let gpu = self.node.gpu.as_ref().expect("LBM driver needs GPUs");
        gpu.memory_bw_gbs * 1e9 * lbm_hbm_efficiency(gpu.name) / BYTES_PER_SITE
    }

    /// Per-node compute time for one step, s.
    pub fn compute_time(&self) -> f64 {
        let sites_per_node = (self.cfg.per_gpu_edge as f64).powi(3)
            * self.node.gpus as f64;
        sites_per_node / (self.per_gpu_lups() * self.node.gpus as f64)
    }

    /// Per-step halo time for a job of `nodes` nodes placed as `placement`.
    pub fn halo_time(&self, nodes: u32, placement: &Placement) -> f64 {
        if nodes <= 1 {
            return 0.0;
        }
        let (px, py, pz) = decompose_3d(nodes);
        // Node subdomain edge: 4 GPU cubes per node.
        let node_sites = (self.cfg.per_gpu_edge as f64).powi(3) * self.node.gpus as f64;
        let edge = node_sites.cbrt();
        let face_bytes = (edge * edge * DISTS_PER_FACE * 4.0) as u64;
        let faces = [px, py, pz].iter().filter(|&&d| d > 1).count() as u32 * 2;
        let wire = self.net.halo_exchange_time(placement, faces, face_bytes);
        // Without GPUDirect RDMA the halo bounces through host memory
        // (pack -> D2H -> wire -> H2D): the staging path bounds the rate.
        match self.node.host_staging_gbs {
            None => wire,
            Some(bw) => {
                let volume = faces as f64 * face_bytes as f64;
                wire.max(volume / (bw * 1e9))
            }
        }
    }

    /// Per-step amortised diagnostic allreduce time.
    pub fn diag_time(&self, placement: &Placement) -> f64 {
        self.net.allreduce_time(placement, 8 * 16) / DIAG_EVERY
    }

    /// One scaling point.
    pub fn point(&self, nodes: u32, placement: &Placement) -> ScalingPoint {
        let t = self.compute_time()
            + self.halo_time(nodes, placement)
            + self.diag_time(placement);
        let sites = (self.cfg.per_gpu_edge as f64).powi(3)
            * self.node.gpus as f64
            * nodes as f64;
        ScalingPoint {
            nodes,
            gpus: nodes * self.node.gpus,
            lups: sites / t,
            efficiency: 0.0, // normalised by `sweep`
            step_seconds: t,
        }
    }

    /// A weak-scaling sweep; efficiency normalised to the first point
    /// (the paper normalises to the 2-node run). The placer may fail
    /// (e.g. a node count exceeding the machine), which aborts the sweep.
    pub fn sweep(
        &self,
        node_counts: &[u32],
        placer: impl Fn(u32) -> crate::Result<Placement>,
    ) -> crate::Result<Vec<ScalingPoint>> {
        let mut points = Vec::with_capacity(node_counts.len());
        for &n in node_counts {
            points.push(self.point(n, &placer(n)?));
        }
        if let Some(base) = points.first() {
            let base_rate = base.lups / base.gpus as f64;
            for p in &mut points {
                p.efficiency = (p.lups / p.gpus as f64) / base_rate;
            }
        }
        Ok(points)
    }
}

/// The paper's Table 7 node counts.
pub const TABLE7_NODES: &[u32] = &[2, 8, 64, 128, 256, 512, 1024, 2048, 2475];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::network::Network;
    use crate::scheduler::{Partition, Scheduler};
    use crate::topology::Topology;

    fn leo_infra() -> (MachineConfig, Network) {
        let cfg = MachineConfig::leonardo();
        let inj = cfg.gpu_node_spec().unwrap().injection_gbps();
        let net = Network::new(Topology::build(&cfg), inj);
        (cfg, net)
    }

    fn placer(cfg: &MachineConfig) -> impl Fn(u32) -> crate::Result<Placement> + '_ {
        move |n| {
            let mut s = Scheduler::new(cfg);
            Ok(s.place(Partition::Booster, n).expect("fits"))
        }
    }

    #[test]
    fn decompose_3d_is_exact_and_near_cubic() {
        for n in [1u32, 2, 8, 64, 128, 256, 512, 1024, 2048, 2475, 97] {
            let (x, y, z) = decompose_3d(n);
            assert_eq!(x * y * z, n, "n={n}");
        }
        assert_eq!(decompose_3d(64), (4, 4, 4));
        let (x, y, z) = decompose_3d(512);
        assert_eq!(x * y * z, 512);
        assert!(z / x <= 2, "{x} {y} {z}");
    }

    #[test]
    fn per_gpu_rate_matches_paper_scale() {
        // Table 7: 0.0476e12 LUPS on 8 GPUs = 5.95 GLUPS/GPU.
        let (cfg, net) = leo_infra();
        let node = cfg.gpu_node_spec().unwrap();
        let d = LbmDriver::new(node, &net, LbmConfig::default());
        let g = d.per_gpu_lups() / 1e9;
        assert!((g - 5.93).abs() < 0.3, "{g}");
    }

    #[test]
    fn table7_two_node_point() {
        let (cfg, net) = leo_infra();
        let node = cfg.gpu_node_spec().unwrap();
        let d = LbmDriver::new(node, &net, LbmConfig::default());
        let place = placer(&cfg);
        let p = d.point(2, &place(2).unwrap());
        // Paper: 0.0476 TLUPS at 2 nodes (8 GPUs), +-10%.
        assert!((p.lups / 1e12 - 0.0476).abs() / 0.0476 < 0.10, "{}", p.lups / 1e12);
    }

    #[test]
    fn table7_full_sweep_shape() {
        let (cfg, net) = leo_infra();
        let node = cfg.gpu_node_spec().unwrap();
        let d = LbmDriver::new(node, &net, LbmConfig::default());
        let place = placer(&cfg);
        let pts = d.sweep(TABLE7_NODES, place).unwrap();
        // Paper efficiencies: 1.00 1.01 0.91 0.91 0.86 0.89 0.89 0.89 0.88.
        // The 8-node point (1.01, superlinear) is measurement noise a
        // deterministic model cannot produce — wider band there.
        let paper = [1.00, 1.01, 0.91, 0.91, 0.86, 0.89, 0.89, 0.89, 0.88];
        let tol = [0.02, 0.12, 0.08, 0.08, 0.08, 0.08, 0.08, 0.08, 0.08];
        for ((p, want), tol) in pts.iter().zip(paper).zip(tol) {
            assert!(
                (p.efficiency - want).abs() < tol,
                "nodes={} eff={} want={want}",
                p.nodes,
                p.efficiency
            );
        }
        // Largest run: 51.2 TLUPS +-10%.
        let last = pts.last().unwrap();
        assert_eq!(last.gpus, 9900);
        assert!(
            (last.lups / 1e12 - 51.2).abs() / 51.2 < 0.10,
            "{}",
            last.lups / 1e12
        );
    }

    #[test]
    fn efficiency_plateaus_not_collapses() {
        let (cfg, net) = leo_infra();
        let node = cfg.gpu_node_spec().unwrap();
        let d = LbmDriver::new(node, &net, LbmConfig::default());
        let place = placer(&cfg);
        let pts = d.sweep(TABLE7_NODES, place).unwrap();
        for p in &pts {
            assert!(p.efficiency > 0.80, "nodes={} eff={}", p.nodes, p.efficiency);
            assert!(p.efficiency <= 1.05);
        }
    }

    #[test]
    fn leonardo_is_about_2_5x_faster_than_marconi_per_gpu() {
        // Appendix A.3: "LEONARDO was about 2.5 times faster than
        // Marconi100" on the same code.
        let (leo_cfg, leo_net) = leo_infra();
        let leo = LbmDriver::new(
            leo_cfg.gpu_node_spec().unwrap(),
            &leo_net,
            LbmConfig::default(),
        );
        let m_cfg = MachineConfig::marconi100();
        let m_inj = m_cfg.gpu_node_spec().unwrap().injection_gbps();
        let m_net = Network::new(Topology::build(&m_cfg), m_inj);
        let marconi = LbmDriver::new(
            m_cfg.gpu_node_spec().unwrap(),
            &m_net,
            LbmConfig::default(),
        );
        let ratio = leo.per_gpu_lups() / marconi.per_gpu_lups();
        assert!((ratio - 2.5).abs() < 0.15, "{ratio}");
    }

    #[test]
    fn calibrated_rate_overrides_model() {
        let (cfg, net) = leo_infra();
        let node = cfg.gpu_node_spec().unwrap();
        let d = LbmDriver::new(
            node,
            &net,
            LbmConfig {
                per_gpu_edge: 320,
                per_gpu_lups: Some(1e9),
            },
        );
        assert_eq!(d.per_gpu_lups(), 1e9);
    }

    #[test]
    fn halo_time_zero_for_single_node() {
        let (cfg, net) = leo_infra();
        let node = cfg.gpu_node_spec().unwrap();
        let d = LbmDriver::new(node, &net, LbmConfig::default());
        let p = Placement {
            nodes_per_cell: vec![(0, 1)],
        };
        assert_eq!(d.halo_time(1, &p), 0.0);
    }
}

