//! A real (small-scale) HPCG: conjugate gradient on the 27-point stencil
//! operator, driven from Rust with the whole iteration executing inside
//! the AOT `cg_iter_64` / `cg_iters8_64` artifacts (one PJRT dispatch per
//! iteration or per 8 iterations).
//!
//! This is the benchmark behind Table 4's HPCG row, implemented: the
//! driver mirrors the reference HPCG flow (set up b, iterate to
//! tolerance, count flops, report GFLOPS) and its numerics are validated
//! against a host-side stencil implementation in tests.

use anyhow::Result;

use crate::runtime::{literal_f32, scalar_f32, Engine};

/// Grid edge of the AOT CG artifacts.
pub const GRID: usize = 64;

/// Flops per CG iteration on an n-point grid with the 27-point operator:
/// SpMV (53 per row) + 2 dots (2n each) + 3 axpy-likes (2n each).
pub fn flops_per_iteration(points: usize) -> f64 {
    (53.0 + 10.0) * points as f64
}

/// Result of a CG run.
#[derive(Debug, Clone)]
pub struct CgResult {
    pub iterations: u32,
    /// Final ||r||^2.
    pub rz: f64,
    /// Relative residual vs the initial one.
    pub rel_residual: f64,
    pub seconds: f64,
    pub gflops: f64,
}

/// Run CG on `A x = b` from x = 0, via PJRT, until `rel_tol` or
/// `max_iters`. Uses the scan-of-8 artifact for the bulk and checks the
/// residual every 8 iterations (the chunking that keeps the hot path at
/// one dispatch per 8 iterations — see EXPERIMENTS.md §Perf).
pub fn solve(engine: &Engine, b: &[f32], rel_tol: f64, max_iters: u32) -> Result<CgResult> {
    let points = GRID * GRID * GRID;
    anyhow::ensure!(b.len() == points, "rhs must be {GRID}^3");
    let rz0: f64 = b.iter().map(|&v| (v as f64) * (v as f64)).sum();

    let start = std::time::Instant::now();
    let mut x = literal_f32(&vec![0f32; points], &[GRID, GRID, GRID])?;
    let mut r = literal_f32(b, &[GRID, GRID, GRID])?;
    let mut p = literal_f32(b, &[GRID, GRID, GRID])?;
    let mut rz = scalar_f32(rz0 as f32)?;

    let mut iters = 0u32;
    let mut rz_now = rz0;
    while iters < max_iters && rz_now > rel_tol * rel_tol * rz0 {
        let out = engine.execute("cg_iters8_64", &[x, r, p, rz])?;
        let mut it = out.into_iter();
        x = it.next().unwrap();
        r = it.next().unwrap();
        p = it.next().unwrap();
        rz = it.next().unwrap();
        rz_now = rz.to_vec::<f32>()?[0] as f64;
        iters += 8;
    }
    let seconds = start.elapsed().as_secs_f64();
    Ok(CgResult {
        iterations: iters,
        rz: rz_now,
        rel_residual: (rz_now / rz0).sqrt(),
        seconds,
        gflops: flops_per_iteration(points) * iters as f64 / seconds / 1e9,
    })
}

/// Host-side 27-point stencil (zero Dirichlet) for validation.
pub fn stencil_host(x: &[f32], n: usize) -> Vec<f32> {
    let idx = |i: isize, j: isize, k: isize| -> Option<usize> {
        if i < 0 || j < 0 || k < 0 || i >= n as isize || j >= n as isize || k >= n as isize
        {
            None
        } else {
            Some((i as usize * n + j as usize) * n + k as usize)
        }
    };
    let mut y = vec![0f32; n * n * n];
    for i in 0..n as isize {
        for j in 0..n as isize {
            for k in 0..n as isize {
                let mut acc = 26.0 * x[idx(i, j, k).unwrap()];
                for di in -1..=1 {
                    for dj in -1..=1 {
                        for dk in -1..=1 {
                            if di == 0 && dj == 0 && dk == 0 {
                                continue;
                            }
                            if let Some(s) = idx(i + di, j + dj, k + dk) {
                                acc -= x[s];
                            }
                        }
                    }
                }
                y[idx(i, j, k).unwrap()] = acc;
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flop_count_matches_hpcg_convention() {
        // 27 mults + 26 adds = 53 for SpMV, 10 for the vector ops.
        assert_eq!(flops_per_iteration(1000) as u64, 63_000);
    }

    #[test]
    fn host_stencil_constant_interior_is_zero() {
        let n = 6;
        let x = vec![1.0f32; n * n * n];
        let y = stencil_host(&x, n);
        let centre = (2 * n + 2) * n + 2;
        assert!(y[centre].abs() < 1e-5);
        assert!(y[0] > 0.0);
    }

    #[test]
    fn host_stencil_is_symmetric() {
        let n = 5;
        let mut rng = crate::util::rng::Rng::new(3);
        let x: Vec<f32> = (0..n * n * n).map(|_| rng.f64() as f32 - 0.5).collect();
        let y: Vec<f32> = (0..n * n * n).map(|_| rng.f64() as f32 - 0.5).collect();
        let ax = stencil_host(&x, n);
        let ay = stencil_host(&y, n);
        let lhs: f64 = ax.iter().zip(&y).map(|(a, b)| *a as f64 * *b as f64).sum();
        let rhs: f64 = ay.iter().zip(&x).map(|(a, b)| *a as f64 * *b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0));
    }
}
