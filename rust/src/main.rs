//! `leonardo-twin` CLI: regenerate any table or figure of the paper, run
//! calibration against the AOT kernel artifacts, replay an operational
//! day, sweep a scenario grid across cores, or dump machine facts.
//!
//! ```text
//! leonardo-twin table1                 # rack inventory (Table 1)
//! leonardo-twin table7 --calibrated    # LBM scaling from measured kernels
//! leonardo-twin operations --jobs 10000 --cap 8.0
//! leonardo-twin sweep --seeds 4 --caps none,7.5,6.5 --mixes day,ai
//! leonardo-twin all --markdown         # every table, markdown to stdout
//! leonardo-twin topology --dot > fabric.dot
//! ```
//!
//! (Hand-rolled argument parsing: the offline build has no clap.)

use std::net::SocketAddr;
use std::time::Duration;

use leonardo_twin::campaign::{
    parse_caps, parse_checkpoint, parse_faults, parse_mixes, parse_policies, parse_routing,
    parse_threads, parse_workers, CampaignReport, SweepGrid,
};
use leonardo_twin::coordinator::Twin;
use leonardo_twin::metrics::Table;
use leonardo_twin::runtime::Engine;
use leonardo_twin::scheduler::{CheckpointPolicy, Coupling, PolicyKind};
use leonardo_twin::service::{self, parse_addr, CoordinatorConfig, SweepSpec};
use leonardo_twin::topology::Routing;
use leonardo_twin::workloads::{FaultTrace, TraceGen};

const USAGE: &str = "\
leonardo-twin — digital twin of the LEONARDO pre-exascale supercomputer

USAGE: leonardo-twin <COMMAND> [--markdown] [--calibrated] [--artifacts DIR]

COMMANDS:
  table1      Compute partition rack inventory        (Table 1)
  table2      GPU specifications and derived peaks    (Table 2)
  table3      Filesystem organisation                 (Table 3)
  table4      HPL / HPCG / Green500                   (Table 4)   [--calibrated]
  table5      IO500 phases and score                  (Table 5)
  table6      Application benchmarks TTS/ETS          (Table 6)
  table7      LBM weak scaling                        (Table 7)   [--calibrated]
  fig5        LBM efficiency: LEONARDO vs Marconi100  (Fig 5)
  latency     Fabric latency budget                   (Sec 2.2)
  topology    Dragonfly+ facts                        (Fig 4)     [--dot]
  overview    Architecture + blade summary            (Fig 1/3)
  operations  Replay a mixed HPC+AI day on the Booster partition
              through the event-driven scheduler      [--jobs N] [--seed S] [--cap MW]
                                                      [--coupled] [--routing P]
                                                      [--policy pack|spread]
                                                      [--faults SPEC] [--checkpoint CP]
  sweep       Multi-threaded scenario-sweep campaign: replay a
              seeds x power-caps x mixes x policies x fault-traces grid
              of operational days and merge the outcomes (per-scenario,
              cap-sensitivity, policy-comparison and aggregate-percentile
              tables — identical for any thread count)
                       [--jobs N] [--seed S] [--seeds K] [--caps LIST]
                       [--mixes LIST] [--threads T] [--coupled] [--routing P]
                       [--policy LIST] [--cap-time SEC] [--fork]
                       [--faults SPEC] [--checkpoint CP]
  serve       Distributed sweep service coordinator: distribute a
              sweep grid's scenario groups across a worker fleet —
              adaptive pull dispatch by default (longest-estimated
              group first to whoever asks), or static consistent-hash
              sharding (--dispatch static) — and merge the streamed
              rows into the same report `sweep` prints —
              byte-identical for any worker count, thread count, join
              order, or worker failure. Fleet is either in-process
              (--workers N [--threads T]) or TCP (--listen ADDR,
              serving `work` processes). Takes every sweep grid flag;
              a grid must be given explicitly unless --persist (then
              clients `submit` grids). With --persist the coordinator
              outlives its grids: jobs queue FIFO (bounded by
              --queue) until a `submit --drain`
                       [--workers N [--threads T] | --listen ADDR
                        [--expect N] [--persist] [--queue N]]
                       [--dispatch adaptive|static]
                       [--jobs N] [--seed S] [--seeds K] [--caps LIST]
                       [--mixes LIST] [--coupled] [--routing P]
                       [--policy LIST] [--cap-time SEC] [--fork]
                       [--faults SPEC] [--checkpoint CP]
  submit      Distributed sweep client: send an explicit sweep grid
              to a running `serve` coordinator, wait for the fleet's
              byte-identical report, print it like `sweep` would; or
              ask the service to finish its queue and exit (--drain)
                       --connect HOST:PORT [--drain]
                       [sweep grid flags as above]
  work        Distributed sweep worker: connect to a `serve`
              coordinator, pull scenario-group credit, replay granted
              groups on a pool of persistent arenas (--threads), send
              each finished group back as one batched frame, answer
              heartbeats, rejoin across coordinator restarts, exit on
              shutdown
                       --connect HOST:PORT [--threads N] [--prefetch N]
                       [--die-after N] [--chaos SEED]
  calibrate   Measure the AOT kernels through PJRT
  all         Every table in paper order              [--calibrated]

OPTIONS:
  --markdown        markdown tables instead of console layout
  --calibrated      calibrate models with real PJRT kernel runs first
  --artifacts DIR   artifacts directory (default ./artifacts)
  --jobs N          operations/sweep: jobs per synthetic day
                    (default 10000 for operations, 2000 per sweep scenario)
  --seed S          operations: trace seed; sweep: first seed (default 2023)
  --cap MW          operations: facility power cap in MW (default uncapped)
  --seeds K         sweep: number of arrival seeds S, S+1, ... (default 4)
  --caps LIST       sweep: comma-separated cap levels in MW; 'none' lifts
                    the cap (default none,7.5,6.5)
  --mixes LIST      sweep: comma-separated TraceGen mixes: day, ai, hpc
                    (default day,ai)
  --threads T       sweep: worker threads (default: available cores);
                    work / serve --workers: replay threads per worker,
                    each with its own persistent arena (default 1)
  --coupled         operations/sweep: runtime coupling on — running jobs'
                    provisional end times re-time under fabric contention
                    and cap moves (default: off, end times frozen at Start)
  --routing P       operations/sweep: fabric routing policy — minimal,
                    valiant or adaptive (default minimal; valiant is the
                    adaptive-routing worst case, detours halve global
                    supply; adaptive decides minimal-vs-valiant per flow
                    from the measured per-link imbalance; both require
                    --coupled, the uncoupled replay never consults the
                    network model)
  --policy LIST     operations: one placement policy; sweep: comma-
                    separated policy axis (pack = fullest-first packing,
                    spread = link-aware anti-fragmentation; default pack)
  --cap-time SEC    sweep: defer every cap level to arrive SEC seconds
                    into the day as a CapChange event instead of at t=0
                    (default 0 = caps apply from the start); required
                    > 0 for --fork to have prefixes to share
  --fork            sweep: divergence-tree engine — scenarios differing
                    only in the (deferred) cap level share one simulated
                    prefix per worker and fork at the cap move; report
                    byte-identical to the streaming engine apart from
                    the Forks/Restores bookkeeping columns
  --faults SPEC     operations: inject a failure trace into the day;
                    sweep: add it as a grid axis (fault-free vs faulted).
                    SPEC is 'none' or comma-separated key:value pairs —
                    mtbf:SECS (per-node MTBF, arms node failures),
                    repair:SECS, group:N (nodes per failure),
                    linkmtbf:SECS (per-bundle MTBF, arms degradations;
                    requires --coupled), linkrepair:SECS, factor:F in
                    (0,1], dur:SECS (arrival window), seed:N
                    (e.g. --faults mtbf:250000,repair:7200,group:18)
  --checkpoint CP   operations/sweep: checkpoint policy forced on every
                    job — 'none' (a fault kill repeats everything) or an
                    interval in seconds (a kill repeats at most one
                    interval); default: per-app-class policies
  --workers N       serve: run an in-process fleet of N workers on an
                    ephemeral loopback port (tests/CI; mutually
                    exclusive with --listen)
  --listen ADDR     serve: listen for `work` processes on ADDR
                    (host:port)
  --expect N        serve: wait for N workers before the first dispatch
                    (default 1; --listen mode only)
  --persist         serve: keep serving after the initial grid (if any),
                    accepting `submit` jobs until a `submit --drain`
                    (--listen mode only)
  --queue N         serve: queued jobs beyond the active one before a
                    submission is rejected (default 8; --listen mode
                    only)
  --connect ADDR    submit/work: coordinator address (host:port);
                    retries for up to 30s while the coordinator starts
  --drain           submit: ask the coordinator to finish its active and
                    queued jobs, then exit; blocks until it has
  --die-after N     work: crash (drop the connection) after
                    acknowledging N groups — fault-drill hook for the
                    chaos harness and CI
  --chaos SEED      work: run this worker over a seeded fault-injecting
                    transport (deterministic drop/delay/truncate/corrupt
                    schedule) — it will misbehave mid-protocol and the
                    coordinator must survive it
  --prefetch N      work: group credit window per replay thread — up to
                    threads x N groups granted-or-running at once so
                    the pipe never runs dry between a batch and the
                    next grant (default 2)
  --dispatch MODE   serve: 'adaptive' (default) pull-based LPT dispatch
                    seeded from structural group-cost hints and refined
                    from observed per-class service times, or 'static'
                    up-front consistent-hash sharding (the PR 8
                    dispatcher, kept as a baseline)
";

struct Args {
    cmd: String,
    markdown: bool,
    calibrated: bool,
    dot: bool,
    artifacts: Option<String>,
    jobs: Option<usize>,
    seed: u64,
    cap_mw: Option<f64>,
    seeds: u64,
    caps: String,
    mixes: String,
    threads: Option<usize>,
    coupled: bool,
    routing: String,
    policy: String,
    cap_time: f64,
    fork: bool,
    faults: Option<String>,
    checkpoint: Option<String>,
    workers: Option<usize>,
    listen: Option<String>,
    expect: Option<usize>,
    connect: Option<String>,
    persist: bool,
    queue: Option<usize>,
    drain: bool,
    die_after: Option<usize>,
    chaos: Option<u64>,
    prefetch: Option<usize>,
    dispatch: Option<String>,
    /// Whether any grid-shaping flag (`--seeds`/`--caps`/`--mixes`/
    /// `--jobs`) was given explicitly — `serve` and `submit` refuse to
    /// fall back to the `sweep` defaults, a service replays
    /// *submitted* grids.
    grid_given: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut argv = std::env::args().skip(1);
    let cmd = argv.next().ok_or_else(|| USAGE.to_string())?;
    let mut args = Args {
        cmd,
        markdown: false,
        calibrated: false,
        dot: false,
        artifacts: None,
        jobs: None,
        seed: 2023,
        cap_mw: None,
        seeds: 4,
        caps: "none,7.5,6.5".to_string(),
        mixes: "day,ai".to_string(),
        threads: None,
        coupled: false,
        routing: "minimal".to_string(),
        policy: "pack".to_string(),
        cap_time: 0.0,
        fork: false,
        faults: None,
        checkpoint: None,
        workers: None,
        listen: None,
        expect: None,
        connect: None,
        persist: false,
        queue: None,
        drain: false,
        die_after: None,
        chaos: None,
        prefetch: None,
        dispatch: None,
        grid_given: false,
    };
    while let Some(a) = argv.next() {
        match a.as_str() {
            "--markdown" => args.markdown = true,
            "--calibrated" => args.calibrated = true,
            "--dot" => args.dot = true,
            "--coupled" => args.coupled = true,
            "--fork" => args.fork = true,
            "--cap-time" => {
                args.cap_time = argv
                    .next()
                    .ok_or("--cap-time needs a value")?
                    .parse()
                    .map_err(|e| format!("--cap-time: {e}"))?
            }
            "--routing" => args.routing = argv.next().ok_or("--routing needs a value")?,
            "--policy" => args.policy = argv.next().ok_or("--policy needs a value")?,
            "--faults" => {
                args.faults = Some(argv.next().ok_or("--faults needs a value")?)
            }
            "--checkpoint" => {
                args.checkpoint = Some(argv.next().ok_or("--checkpoint needs a value")?)
            }
            "--artifacts" => {
                args.artifacts = Some(argv.next().ok_or("--artifacts needs a value")?)
            }
            "--jobs" => {
                args.jobs = Some(
                    argv.next()
                        .ok_or("--jobs needs a value")?
                        .parse()
                        .map_err(|e| format!("--jobs: {e}"))?,
                );
                args.grid_given = true;
            }
            "--workers" => {
                args.workers = Some(
                    argv.next()
                        .ok_or("--workers needs a value")?
                        .parse()
                        .map_err(|e| format!("--workers: {e}"))?,
                )
            }
            "--expect" => {
                args.expect = Some(
                    argv.next()
                        .ok_or("--expect needs a value")?
                        .parse()
                        .map_err(|e| format!("--expect: {e}"))?,
                )
            }
            "--listen" => args.listen = Some(argv.next().ok_or("--listen needs a value")?),
            "--connect" => args.connect = Some(argv.next().ok_or("--connect needs a value")?),
            "--persist" => args.persist = true,
            "--drain" => args.drain = true,
            "--queue" => {
                args.queue = Some(
                    argv.next()
                        .ok_or("--queue needs a value")?
                        .parse()
                        .map_err(|e| format!("--queue: {e}"))?,
                )
            }
            "--die-after" => {
                args.die_after = Some(
                    argv.next()
                        .ok_or("--die-after needs a value")?
                        .parse()
                        .map_err(|e| format!("--die-after: {e}"))?,
                )
            }
            "--chaos" => {
                args.chaos = Some(
                    argv.next()
                        .ok_or("--chaos needs a value")?
                        .parse()
                        .map_err(|e| format!("--chaos: {e}"))?,
                )
            }
            "--prefetch" => {
                args.prefetch = Some(
                    argv.next()
                        .ok_or("--prefetch needs a value")?
                        .parse()
                        .map_err(|e| format!("--prefetch: {e}"))?,
                )
            }
            "--dispatch" => {
                args.dispatch = Some(argv.next().ok_or("--dispatch needs a value")?)
            }
            "--seed" => {
                args.seed = argv
                    .next()
                    .ok_or("--seed needs a value")?
                    .parse()
                    .map_err(|e| format!("--seed: {e}"))?
            }
            "--cap" => {
                args.cap_mw = Some(
                    argv.next()
                        .ok_or("--cap needs a value")?
                        .parse()
                        .map_err(|e| format!("--cap: {e}"))?,
                )
            }
            "--seeds" => {
                args.seeds = argv
                    .next()
                    .ok_or("--seeds needs a value")?
                    .parse()
                    .map_err(|e| format!("--seeds: {e}"))?;
                args.grid_given = true;
            }
            "--caps" => {
                args.caps = argv.next().ok_or("--caps needs a value")?;
                args.grid_given = true;
            }
            "--mixes" => {
                args.mixes = argv.next().ok_or("--mixes needs a value")?;
                args.grid_given = true;
            }
            "--threads" => {
                args.threads = Some(
                    argv.next()
                        .ok_or("--threads needs a value")?
                        .parse()
                        .map_err(|e| format!("--threads: {e}"))?,
                )
            }
            "-h" | "--help" => return Err(USAGE.to_string()),
            other => return Err(format!("unknown flag '{other}'\n\n{USAGE}")),
        }
    }
    Ok(args)
}

/// Resolve the `--routing`/`--coupled` flags shared by `operations` and
/// `sweep`, enforcing that a non-minimal routing policy has coupling to
/// act on (the uncoupled replay freezes end times at `Start` and never
/// consults the network model, so the policy would silently change
/// nothing).
fn routing_and_coupling(args: &Args) -> anyhow::Result<(Routing, Coupling)> {
    let routing = parse_routing(&args.routing)?;
    let coupling = if args.coupled {
        Coupling::full()
    } else {
        Coupling::default()
    };
    anyhow::ensure!(
        routing == Routing::Minimal || coupling.enabled(),
        "--routing valiant/adaptive requires --coupled: the uncoupled replay \
         freezes end times at Start and never consults the network model, so \
         the routing policy would silently change nothing"
    );
    Ok((routing, coupling))
}

/// Resolve the `--faults`/`--checkpoint` flags shared by `operations`
/// and `sweep`, enforcing that link-degradation episodes have coupling
/// to act on (the uncoupled replay never consults the network model, so
/// a degraded bundle would silently change nothing).
fn fault_inputs(
    args: &Args,
    coupling: Coupling,
) -> anyhow::Result<(FaultTrace, Option<CheckpointPolicy>)> {
    let faults = match &args.faults {
        Some(spec) => parse_faults(spec)?,
        None => FaultTrace::none(),
    };
    anyhow::ensure!(
        faults.link_mtbf_s <= 0.0 || coupling.congestion,
        "--faults linkmtbf requires --coupled: the uncoupled replay freezes end \
         times at Start and never consults the network model, so a degraded \
         link bundle would silently change nothing"
    );
    let checkpoint = args.checkpoint.as_deref().map(parse_checkpoint).transpose()?;
    Ok((faults, checkpoint))
}

/// Resolve the single placement policy an `operations` replay uses.
fn operations_policy(args: &Args) -> anyhow::Result<PolicyKind> {
    let policies = parse_policies(&args.policy)?;
    anyhow::ensure!(
        policies.len() == 1,
        "operations replays one day under one policy: pass a single --policy \
         (the policy axis belongs to sweep)"
    );
    Ok(policies[0])
}

/// Validate and assemble every `sweep` input (grid, worker threads,
/// routing policy, coupling) from the raw flags. Malformed input —
/// unparsable `--caps`, an unknown mix, `--threads 0`, a bogus
/// `--routing` or `--policy` — comes back as an `anyhow` error for the
/// CLI to print, never a panic inside a worker.
fn sweep_inputs(args: &Args) -> anyhow::Result<(SweepGrid, usize, Routing, Coupling)> {
    anyhow::ensure!(
        args.cap_mw.is_none(),
        "sweep sweeps a grid of cap levels: use --caps LIST (e.g. --caps none,6.0), \
         not the operations flag --cap"
    );
    let caps = parse_caps(&args.caps)?;
    let mixes = parse_mixes(&args.mixes)?;
    let policies = parse_policies(&args.policy)?;
    let threads = parse_threads(args.threads)?;
    let (routing, coupling) = routing_and_coupling(args)?;
    anyhow::ensure!(args.seeds > 0, "--seeds must be at least 1");
    anyhow::ensure!(
        args.cap_time.is_finite() && args.cap_time >= 0.0,
        "--cap-time {} must be a finite number of seconds >= 0",
        args.cap_time
    );
    let (faults, checkpoint) = fault_inputs(args, coupling)?;
    let seeds: Vec<u64> = (0..args.seeds).map(|k| args.seed + k).collect();
    let mut grid = SweepGrid::new(seeds, caps, mixes, args.jobs.unwrap_or(2_000))?
        .with_coupling(coupling)
        .with_policies(policies)
        .with_cap_time(args.cap_time)
        .with_checkpoint(checkpoint);
    if !faults.is_none() {
        // `--faults` turns the grid's fault axis on: every scenario
        // replayed fault-free AND under the failure trace, so the
        // report's robustness columns have their clean baseline.
        grid = grid.with_fault_traces(vec![FaultTrace::none(), faults]);
    }
    Ok((grid, threads, routing, coupling))
}

/// How `serve` runs its fleet.
#[derive(Debug)]
enum ServeMode {
    /// `--workers N`: coordinator + N worker threads on an ephemeral
    /// loopback port, all in this process.
    InProcess(usize),
    /// `--listen ADDR [--expect N]`: TCP fleet of `work` processes.
    Listen { addr: SocketAddr, expect: usize },
}

/// Validate and assemble every `serve` input. On top of the shared
/// sweep grid validation: the grid must be explicit (a service replays
/// *submitted* grids, there is no default sweep) unless the
/// coordinator is a persistent listener fed by `submit` clients;
/// `--workers 0`, `--expect 0` and `--queue 0` are errors, `--listen`
/// must parse as host:port, the two fleet modes are mutually
/// exclusive, and `--persist`/`--queue` belong to the listener.
fn serve_inputs(args: &Args) -> anyhow::Result<(Option<SweepGrid>, Routing, ServeMode)> {
    let workers = parse_workers("--workers", args.workers)?;
    let expect = parse_workers("--expect", args.expect)?;
    if args.queue == Some(0) {
        anyhow::bail!("--queue 0 would reject every submission: pass at least 1");
    }
    let mode = match (workers, &args.listen) {
        (Some(_), Some(_)) => anyhow::bail!(
            "--workers (in-process fleet) and --listen (TCP fleet) are mutually \
             exclusive: pick one"
        ),
        (Some(n), None) => {
            anyhow::ensure!(
                expect.is_none(),
                "--expect applies to --listen mode: an in-process fleet always \
                 has exactly --workers workers"
            );
            anyhow::ensure!(
                !args.persist && args.queue.is_none(),
                "--persist/--queue apply to --listen mode: an in-process fleet \
                 serves exactly one grid"
            );
            ServeMode::InProcess(n)
        }
        (None, Some(listen)) => ServeMode::Listen {
            addr: parse_addr(listen)?,
            expect: expect.unwrap_or(1),
        },
        (None, None) => anyhow::bail!(
            "serve needs a fleet: --listen ADDR (TCP `work` processes) or \
             --workers N (in-process)"
        ),
    };
    if args.grid_given {
        let (grid, _threads, routing, _coupling) = sweep_inputs(args)?;
        Ok((Some(grid), routing, mode))
    } else {
        anyhow::ensure!(
            args.persist,
            "serve replays a submitted sweep grid and has no default grid: pass at \
             least one of --seeds/--caps/--mixes/--jobs (or --listen --persist and \
             let `submit` clients bring the grids)"
        );
        let (routing, _coupling) = routing_and_coupling(args)?;
        Ok((None, routing, mode))
    }
}

/// Resolve `--dispatch`: adaptive pull (default) or the retained
/// static consistent-hash sharding.
fn parse_dispatch(v: Option<&str>) -> anyhow::Result<service::DispatchMode> {
    match v.unwrap_or("adaptive") {
        "adaptive" => Ok(service::DispatchMode::Adaptive),
        "static" => Ok(service::DispatchMode::Static),
        other => anyhow::bail!("--dispatch must be 'adaptive' or 'static', got '{other}'"),
    }
}

/// Validate `submit` inputs: `--connect` is required; `--drain` takes
/// no grid flags (it stops the service, it doesn't run one); a
/// submission needs an explicit grid, same rule as `serve`.
fn submit_inputs(args: &Args) -> anyhow::Result<(SocketAddr, Option<(SweepGrid, Routing)>)> {
    let connect = args
        .connect
        .as_deref()
        .ok_or_else(|| anyhow::anyhow!("submit needs --connect HOST:PORT"))?;
    let addr = parse_addr(connect)?;
    if args.drain {
        anyhow::ensure!(
            !args.grid_given,
            "--drain asks the coordinator to finish its queue and exit: it takes \
             no grid flags"
        );
        return Ok((addr, None));
    }
    anyhow::ensure!(
        args.grid_given,
        "submit sends an explicit sweep grid: pass at least one of \
         --seeds/--caps/--mixes/--jobs (or --drain to stop the coordinator)"
    );
    let (grid, _threads, routing, _coupling) = sweep_inputs(args)?;
    Ok((addr, Some((grid, routing))))
}

/// The `sweep`-identical stdout block every report-producing command
/// ends with — `sweep`, `serve` and `submit` all print through here so
/// their outputs diff byte-for-byte.
fn print_sweep_report(report: &CampaignReport, grid: &SweepGrid, md: bool) {
    print(&report.scenario_table(), md);
    print(&report.cap_table(), md);
    if grid.policies.len() > 1 {
        print(&report.policy_table(), md);
    }
    print(&report.summary_table(), md);
}

/// Fleet observability line (stderr, never in the diffable report).
fn print_fleet(fleet: &service::ServiceStats) {
    eprintln!(
        "serve: fleet joined={} lost={} groups reassigned={} duplicate rows={} \
         stale rows={} jobs served={} rejected={}",
        fleet.workers_joined,
        fleet.workers_lost,
        fleet.groups_reassigned,
        fleet.duplicate_rows,
        fleet.stale_rows,
        fleet.jobs_served,
        fleet.jobs_rejected,
    );
    if fleet.workers_lost > 0 {
        eprintln!(
            "serve: reassignment latency mean={:.3}s max={:.3}s",
            fleet.reassign_latency_mean_s, fleet.reassign_latency_max_s,
        );
    }
}

fn print(t: &Table, markdown: bool) {
    if markdown {
        println!("{}", t.to_markdown());
    } else {
        println!("{}", t.to_console());
    }
}

fn engine(dir: &Option<String>) -> anyhow::Result<Engine> {
    match dir {
        Some(d) => Engine::load(d),
        None => Engine::load(Engine::default_dir()),
    }
}

fn maybe_calibrate(
    twin: &Twin,
    args: &Args,
) -> anyhow::Result<Option<leonardo_twin::perfmodel::Calibration>> {
    if !args.calibrated {
        return Ok(None);
    }
    let eng = engine(&args.artifacts)?;
    Ok(Some(twin.calibrate(&eng)?))
}

fn main() -> anyhow::Result<()> {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let mut twin = Twin::leonardo();
    let md = args.markdown;
    match args.cmd.as_str() {
        "table1" => print(&twin.table1(), md),
        "table2" => print(&twin.table2(), md),
        "table3" => print(&twin.table3(), md),
        "table4" => {
            let c = maybe_calibrate(&twin, &args)?;
            print(&twin.table4(c.as_ref()), md);
        }
        "table5" => print(&twin.table5(), md),
        "table6" => print(&twin.table6()?, md),
        "table7" => {
            let c = maybe_calibrate(&twin, &args)?;
            print(&twin.table7(c.as_ref())?, md);
        }
        "fig5" => print(&twin.fig5()?, md),
        "latency" => print(&twin.latency_table(), md),
        "topology" => {
            if args.dot {
                println!("{}", topology_dot(&twin));
            } else {
                topology_summary(&twin);
            }
        }
        "overview" => overview(&twin),
        "operations" => {
            let inputs = routing_and_coupling(&args).and_then(|(routing, coupling)| {
                let policy = operations_policy(&args)?;
                let (faults, checkpoint) = fault_inputs(&args, coupling)?;
                Ok((routing, coupling, policy, faults, checkpoint))
            });
            let (routing, coupling, policy, faults, checkpoint) = match inputs {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            };
            twin.net.routing = routing;
            let mut trace = TraceGen::booster_day(args.jobs.unwrap_or(10_000), args.seed);
            trace.checkpoint = checkpoint;
            let report =
                twin.operations_replay_faulted(&trace, args.cap_mw, coupling, policy, &faults)?;
            print(&report.summary, md);
            print(&report.power, md);
        }
        "sweep" => {
            let (grid, threads, routing, coupling) = match sweep_inputs(&args) {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            };
            twin.net.routing = routing;
            eprintln!(
                "sweep: {} scenarios ({} seeds x {} caps x {} mixes x {} policies \
                 x {} fault traces, {} jobs each) on {} threads{}{}",
                grid.len(),
                grid.seeds.len(),
                grid.caps.len(),
                grid.mixes.len(),
                grid.policies.len(),
                grid.faults.len(),
                grid.jobs,
                threads,
                if coupling.enabled() { ", coupled" } else { "" },
                match routing {
                    Routing::Minimal => "",
                    Routing::Valiant => ", valiant routing",
                    Routing::Adaptive => ", adaptive routing",
                },
            );
            let report = if args.fork {
                twin.sweep_forked(&grid, threads)
            } else {
                twin.sweep(&grid, threads)
            };
            print(&report.scenario_table(), md);
            print(&report.cap_table(), md);
            if grid.policies.len() > 1 {
                print(&report.policy_table(), md);
            }
            print(&report.summary_table(), md);
        }
        "serve" => {
            let (grid, routing, mode) = match serve_inputs(&args) {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            };
            let dispatch = match parse_dispatch(args.dispatch.as_deref()) {
                Ok(d) => d,
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            };
            twin.net.routing = routing;
            let spec = grid.as_ref().map(|g| SweepSpec {
                grid: g.clone(),
                routing,
                fork: args.fork,
            });
            match mode {
                ServeMode::InProcess(n) => {
                    let spec = spec.expect("in-process serve always has a grid");
                    let threads = args.threads.unwrap_or(1).max(1);
                    eprintln!(
                        "serve: {} scenarios ({} groups) on an in-process fleet of \
                         {n} worker(s) x {threads} thread(s)",
                        spec.grid.len(),
                        spec.grid.work_groups(args.fork).len(),
                    );
                    let cfg = CoordinatorConfig {
                        dispatch,
                        ..CoordinatorConfig::default()
                    };
                    let (report, fleet) = service::run_fleet(&twin, &spec, n, threads, &[], &cfg)?;
                    print_fleet(&fleet);
                    // Same stdout as `sweep`, so reports diff
                    // byte-for-byte.
                    print_sweep_report(&report, &spec.grid, md);
                }
                ServeMode::Listen { addr, expect } => {
                    match &spec {
                        Some(spec) => eprintln!(
                            "serve: {} scenarios ({} groups), listening on {addr}, \
                             dispatching at {expect} worker(s){}",
                            spec.grid.len(),
                            spec.grid.work_groups(args.fork).len(),
                            if args.persist {
                                ", persistent (submit --drain to stop)"
                            } else {
                                ""
                            },
                        ),
                        None => eprintln!(
                            "serve: listening on {addr}, dispatching at {expect} worker(s), \
                             persistent (grids arrive by submit; submit --drain to stop)",
                        ),
                    }
                    let cfg = CoordinatorConfig {
                        listen: addr,
                        expect,
                        queue_cap: args.queue.unwrap_or(8),
                        persist: args.persist,
                        dispatch,
                        ..CoordinatorConfig::default()
                    };
                    let (report, fleet) = service::serve_service(spec.as_ref(), &cfg)?;
                    print_fleet(&fleet);
                    if let (Some(report), Some(spec)) = (report, &spec) {
                        print_sweep_report(&report, &spec.grid, md);
                    }
                }
            }
        }
        "submit" => {
            let (addr, job) = match submit_inputs(&args) {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            };
            match job {
                None => {
                    let pending = service::drain(addr, Duration::from_secs(30))?;
                    eprintln!(
                        "drain: coordinator at {addr} finished {pending} pending job(s) and exited"
                    );
                }
                Some((grid, routing)) => {
                    let spec = SweepSpec {
                        grid: grid.clone(),
                        routing,
                        fork: args.fork,
                    };
                    eprintln!(
                        "submit: {} scenarios ({} groups) to {addr}",
                        grid.len(),
                        grid.work_groups(args.fork).len(),
                    );
                    let report = service::submit(addr, &spec, Duration::from_secs(30))?;
                    // Same stdout as `sweep`, so reports diff
                    // byte-for-byte.
                    print_sweep_report(&report, &grid, md);
                }
            }
        }
        "work" => {
            let out = match args.connect.as_deref() {
                Some(_) if args.threads == Some(0) => Err(anyhow::anyhow!(
                    "--threads 0: a worker needs at least one replay thread"
                )),
                Some(_) if args.prefetch == Some(0) => Err(anyhow::anyhow!(
                    "--prefetch 0 would starve the replay pipeline: pass at least 1"
                )),
                Some(connect) => service::work(
                    connect,
                    args.die_after,
                    args.chaos,
                    args.threads.unwrap_or(1),
                    args.prefetch.unwrap_or(2),
                ),
                None => Err(anyhow::anyhow!("work needs --connect HOST:PORT")),
            };
            if let Err(e) = out {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
        "calibrate" => {
            let eng = engine(&args.artifacts)?;
            println!("platform: {}", eng.platform());
            let c = twin.calibrate(&eng)?;
            print(&twin.calibration_table(&c), md);
        }
        "all" => {
            let c = maybe_calibrate(&twin, &args)?;
            print(&twin.table1(), md);
            print(&twin.table2(), md);
            print(&twin.table3(), md);
            print(&twin.table4(c.as_ref()), md);
            print(&twin.table5(), md);
            print(&twin.table6()?, md);
            print(&twin.table7(c.as_ref())?, md);
            print(&twin.fig5()?, md);
            print(&twin.latency_table(), md);
            if let Some(c) = &c {
                print(&twin.calibration_table(c), md);
            }
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

fn topology_summary(twin: &Twin) {
    let t = &twin.topo;
    println!(
        "dragonfly+ fabric: {} cells, {} switches ({} gateways)",
        t.cells.len(),
        t.total_switches(),
        leonardo_twin::topology::GATEWAYS
    );
    println!(
        "global links: {} total ({} per cell pair, {:.1} Tbps per pair)",
        t.total_global_links(),
        t.links_per_cell_pair,
        t.cell_pair_bw_gbps() / 1000.0
    );
    println!(
        "max node-to-node latency: {:.2} us (valiant), {:.2} us (minimal)",
        t.max_latency_ns() / 1000.0,
        t.route(0, t.total_nodes() - 1, Routing::Minimal)
            .latency_ns()
            / 1000.0
    );
}

fn topology_dot(twin: &Twin) -> String {
    use std::fmt::Write;
    let mut out = String::from("graph leonardo {\n  layout=circo;\n");
    for (i, c) in twin.topo.cells.iter().enumerate() {
        let color = match c.kind {
            leonardo_twin::config::CellKind::Booster => "green",
            leonardo_twin::config::CellKind::DataCentric => "blue",
            leonardo_twin::config::CellKind::Hybrid => "orange",
            leonardo_twin::config::CellKind::Io => "pink",
        };
        let _ = writeln!(
            out,
            "  c{i} [label=\"cell {i}\\n{} nodes\", style=filled, fillcolor={color}];",
            c.nodes
        );
    }
    for i in 0..twin.topo.cells.len() {
        for j in (i + 1)..twin.topo.cells.len() {
            let _ = writeln!(out, "  c{i} -- c{j};");
        }
    }
    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args() -> Args {
        Args {
            cmd: "sweep".into(),
            markdown: false,
            calibrated: false,
            dot: false,
            artifacts: None,
            jobs: None,
            seed: 2023,
            cap_mw: None,
            seeds: 4,
            caps: "none,7.5,6.5".to_string(),
            mixes: "day,ai".to_string(),
            threads: None,
            coupled: false,
            routing: "minimal".to_string(),
            policy: "pack".to_string(),
            cap_time: 0.0,
            fork: false,
            faults: None,
            checkpoint: None,
            workers: None,
            listen: None,
            expect: None,
            connect: None,
            persist: false,
            queue: None,
            drain: false,
            die_after: None,
            chaos: None,
            prefetch: None,
            dispatch: None,
            grid_given: false,
        }
    }

    #[test]
    fn dispatch_flag_parses_both_modes_and_rejects_garbage() {
        assert_eq!(
            parse_dispatch(None).unwrap(),
            service::DispatchMode::Adaptive,
            "adaptive is the default"
        );
        assert_eq!(
            parse_dispatch(Some("adaptive")).unwrap(),
            service::DispatchMode::Adaptive
        );
        assert_eq!(
            parse_dispatch(Some("static")).unwrap(),
            service::DispatchMode::Static
        );
        let err = parse_dispatch(Some("hash")).unwrap_err();
        assert!(format!("{err}").contains("--dispatch"), "{err}");
    }

    /// Malformed sweep flags come back as anyhow errors (the CLI prints
    /// them and exits 2), never as panics.
    #[test]
    fn sweep_inputs_validates_flags() {
        let (grid, threads, routing, coupling) = sweep_inputs(&args()).unwrap();
        assert_eq!(grid.len(), 4 * 3 * 2);
        assert!(threads >= 1);
        assert_eq!(routing, Routing::Minimal);
        assert!(!coupling.enabled());

        let mut a = args();
        a.caps = "7.5,oops".into();
        assert!(sweep_inputs(&a).is_err(), "malformed cap accepted");

        let mut a = args();
        a.caps = "-1.0".into();
        assert!(sweep_inputs(&a).is_err(), "negative cap accepted");

        let mut a = args();
        a.mixes = "day,bogus".into();
        let err = sweep_inputs(&a).unwrap_err();
        assert!(format!("{err}").contains("unknown mix"), "{err}");

        let mut a = args();
        a.threads = Some(0);
        assert!(sweep_inputs(&a).is_err(), "--threads 0 accepted");

        let mut a = args();
        a.routing = "random".into();
        assert!(sweep_inputs(&a).is_err(), "unknown routing accepted");

        let mut a = args();
        a.policy = "pack,bogus".into();
        assert!(sweep_inputs(&a).is_err(), "unknown policy accepted");

        // Valiant/adaptive without coupling would silently change
        // nothing: error.
        let mut a = args();
        a.routing = "valiant".into();
        let err = sweep_inputs(&a).unwrap_err();
        assert!(format!("{err}").contains("requires --coupled"), "{err}");

        let mut a = args();
        a.routing = "adaptive".into();
        let err = sweep_inputs(&a).unwrap_err();
        assert!(format!("{err}").contains("requires --coupled"), "{err}");

        let mut a = args();
        a.seeds = 0;
        assert!(sweep_inputs(&a).is_err(), "--seeds 0 accepted");

        let mut a = args();
        a.cap_mw = Some(6.0);
        assert!(sweep_inputs(&a).is_err(), "--cap accepted by sweep");

        let mut a = args();
        a.cap_time = -5.0;
        assert!(sweep_inputs(&a).is_err(), "negative --cap-time accepted");

        let mut a = args();
        a.cap_time = f64::NAN;
        assert!(sweep_inputs(&a).is_err(), "NaN --cap-time accepted");
    }

    /// `--cap-time` flows into the grid; `--fork` is a pure engine
    /// selector that changes no grid input.
    #[test]
    fn sweep_inputs_wires_cap_time() {
        let mut a = args();
        a.cap_time = 7200.0;
        a.fork = true;
        let (grid, _, _, _) = sweep_inputs(&a).unwrap();
        assert_eq!(grid.cap_time, 7200.0);
        assert!(grid.scenarios().iter().all(|s| s.cap_time == 7200.0));
        let (plain, _, _, _) = sweep_inputs(&args()).unwrap();
        assert_eq!(plain.cap_time, 0.0);
    }

    /// The shared operations/sweep flag resolution enforces the
    /// valiant-needs-coupling rule in one place.
    #[test]
    fn routing_and_coupling_shared_rule() {
        let mut a = args();
        a.routing = "valiant".into();
        assert!(routing_and_coupling(&a).is_err(), "valiant without coupling");
        a.coupled = true;
        let (routing, coupling) = routing_and_coupling(&a).unwrap();
        assert_eq!(routing, Routing::Valiant);
        assert_eq!(coupling, Coupling::full());
        let (routing, coupling) = routing_and_coupling(&args()).unwrap();
        assert_eq!(routing, Routing::Minimal);
        assert!(!coupling.enabled());
    }

    #[test]
    fn sweep_inputs_wires_coupling_and_valiant() {
        let mut a = args();
        a.coupled = true;
        a.routing = "valiant".into();
        a.jobs = Some(10);
        let (grid, _, routing, coupling) = sweep_inputs(&a).unwrap();
        assert_eq!(routing, Routing::Valiant);
        assert_eq!(coupling, Coupling::full());
        assert_eq!(grid.coupling, Coupling::full());
        assert_eq!(grid.jobs, 10);
        assert_eq!(grid.policies, vec![PolicyKind::PackFirst]);
    }

    #[test]
    fn sweep_inputs_wires_policy_axis_and_adaptive_routing() {
        let mut a = args();
        a.coupled = true;
        a.routing = "adaptive".into();
        a.policy = "pack,spread".into();
        a.jobs = Some(10);
        let (grid, _, routing, coupling) = sweep_inputs(&a).unwrap();
        assert_eq!(routing, Routing::Adaptive);
        assert!(coupling.enabled());
        assert_eq!(grid.policies, vec![PolicyKind::PackFirst, PolicyKind::SpreadLinks]);
        assert_eq!(grid.len(), 4 * 3 * 2 * 2);
    }

    /// Satellite: the `serve` flag-validation gaps — `--workers 0`,
    /// bad `--listen` addresses and a grid-less `serve` all come back
    /// as anyhow errors, never panics or silent defaults.
    #[test]
    fn serve_inputs_validates_fleet_flags() {
        // A well-formed in-process submission.
        let mut a = args();
        a.grid_given = true;
        a.workers = Some(2);
        let (grid, routing, mode) = serve_inputs(&a).unwrap();
        assert_eq!(grid.expect("explicit grid").len(), 4 * 3 * 2);
        assert_eq!(routing, Routing::Minimal);
        assert!(matches!(mode, ServeMode::InProcess(2)));

        // A well-formed TCP submission, --expect defaulting to 1.
        let mut a = args();
        a.grid_given = true;
        a.listen = Some("127.0.0.1:7723".into());
        let (_, _, mode) = serve_inputs(&a).unwrap();
        match mode {
            ServeMode::Listen { addr, expect } => {
                assert_eq!(addr, "127.0.0.1:7723".parse::<SocketAddr>().unwrap());
                assert_eq!(expect, 1);
            }
            other => panic!("expected listen mode, got {other:?}"),
        }

        // serve without any explicit grid flag: refused, a service
        // replays submitted grids.
        let mut a = args();
        a.workers = Some(2);
        let err = serve_inputs(&a).unwrap_err();
        assert!(format!("{err}").contains("no default grid"), "{err}");

        // --workers 0 / --expect 0: errors, not silent clamps.
        let mut a = args();
        a.grid_given = true;
        a.workers = Some(0);
        let err = serve_inputs(&a).unwrap_err();
        assert!(format!("{err}").contains("--workers 0"), "{err}");

        let mut a = args();
        a.grid_given = true;
        a.listen = Some("127.0.0.1:7723".into());
        a.expect = Some(0);
        let err = serve_inputs(&a).unwrap_err();
        assert!(format!("{err}").contains("--expect 0"), "{err}");

        // Bad --listen addresses error cleanly through parse_addr.
        for bad in ["nonsense", "127.0.0.1", "127.0.0.1:notaport", ""] {
            let mut a = args();
            a.grid_given = true;
            a.listen = Some(bad.into());
            assert!(serve_inputs(&a).is_err(), "--listen '{bad}' accepted");
        }

        // Mode conflicts and the fleet-less serve.
        let mut a = args();
        a.grid_given = true;
        a.workers = Some(2);
        a.listen = Some("127.0.0.1:7723".into());
        let err = serve_inputs(&a).unwrap_err();
        assert!(format!("{err}").contains("mutually"), "{err}");

        let mut a = args();
        a.grid_given = true;
        a.workers = Some(2);
        a.expect = Some(2);
        assert!(serve_inputs(&a).is_err(), "--expect with --workers accepted");

        let mut a = args();
        a.grid_given = true;
        let err = serve_inputs(&a).unwrap_err();
        assert!(format!("{err}").contains("needs a fleet"), "{err}");

        // Grid validation still applies underneath.
        let mut a = args();
        a.grid_given = true;
        a.workers = Some(2);
        a.mixes = "day,bogus".into();
        assert!(serve_inputs(&a).is_err(), "bad grid accepted by serve");
    }

    /// Tentpole: the persistent-service flag surface — a grid-less
    /// `serve` is legal exactly when it's a persistent listener, the
    /// queue bound must be positive, and the persistence flags don't
    /// apply to an in-process fleet.
    #[test]
    fn serve_inputs_validates_persistence_flags() {
        // Persistent listener without a grid: legal, grids arrive by
        // submit.
        let mut a = args();
        a.listen = Some("127.0.0.1:7723".into());
        a.persist = true;
        let (grid, _, mode) = serve_inputs(&a).unwrap();
        assert!(grid.is_none(), "grid invented out of nowhere");
        assert!(matches!(mode, ServeMode::Listen { expect: 1, .. }));

        // Persistent listener with an initial grid: also legal.
        let mut a = args();
        a.listen = Some("127.0.0.1:7723".into());
        a.persist = true;
        a.grid_given = true;
        a.queue = Some(2);
        let (grid, _, _) = serve_inputs(&a).unwrap();
        assert!(grid.is_some());

        // --queue 0 would reject everything: error, not a footgun.
        let mut a = args();
        a.listen = Some("127.0.0.1:7723".into());
        a.persist = true;
        a.queue = Some(0);
        let err = serve_inputs(&a).unwrap_err();
        assert!(format!("{err}").contains("--queue 0"), "{err}");

        // --persist / --queue on an in-process fleet: errors.
        let mut a = args();
        a.grid_given = true;
        a.workers = Some(2);
        a.persist = true;
        let err = serve_inputs(&a).unwrap_err();
        assert!(format!("{err}").contains("--listen mode"), "{err}");

        let mut a = args();
        a.grid_given = true;
        a.workers = Some(2);
        a.queue = Some(4);
        assert!(serve_inputs(&a).is_err(), "--queue with --workers accepted");
    }

    /// Tentpole: `submit` validation — `--connect` required, `--drain`
    /// excludes grid flags, a submission requires an explicit grid, and
    /// grid validation applies underneath.
    #[test]
    fn submit_inputs_validates_flags() {
        let mut a = args();
        a.grid_given = true;
        let err = submit_inputs(&a).unwrap_err();
        assert!(format!("{err}").contains("--connect"), "{err}");

        let mut a = args();
        a.connect = Some("127.0.0.1:7723".into());
        a.grid_given = true;
        let (addr, job) = submit_inputs(&a).unwrap();
        assert_eq!(addr, "127.0.0.1:7723".parse::<SocketAddr>().unwrap());
        let (grid, routing) = job.expect("explicit grid");
        assert_eq!(grid.len(), 4 * 3 * 2);
        assert_eq!(routing, Routing::Minimal);

        // Drain is grid-less by construction.
        let mut a = args();
        a.connect = Some("127.0.0.1:7723".into());
        a.drain = true;
        let (_, job) = submit_inputs(&a).unwrap();
        assert!(job.is_none());

        let mut a = args();
        a.connect = Some("127.0.0.1:7723".into());
        a.drain = true;
        a.grid_given = true;
        let err = submit_inputs(&a).unwrap_err();
        assert!(format!("{err}").contains("no grid flags"), "{err}");

        // No grid, no drain: refused, same rule as serve.
        let mut a = args();
        a.connect = Some("127.0.0.1:7723".into());
        let err = submit_inputs(&a).unwrap_err();
        assert!(format!("{err}").contains("explicit sweep grid"), "{err}");

        // Bad addresses and bad grids error cleanly.
        let mut a = args();
        a.connect = Some("nonsense".into());
        a.grid_given = true;
        assert!(submit_inputs(&a).is_err(), "bad --connect accepted");

        let mut a = args();
        a.connect = Some("127.0.0.1:7723".into());
        a.grid_given = true;
        a.mixes = "day,bogus".into();
        assert!(submit_inputs(&a).is_err(), "bad grid accepted by submit");
    }

    #[test]
    fn operations_accepts_one_policy_only() {
        let mut a = args();
        a.policy = "spread".into();
        assert_eq!(operations_policy(&a).unwrap(), PolicyKind::SpreadLinks);
        a.policy = "pack,spread".into();
        let err = operations_policy(&a).unwrap_err();
        assert!(format!("{err}").contains("single --policy"), "{err}");
    }

    /// Satellite: malformed `--faults`/`--checkpoint` specs error
    /// cleanly, and link-degradation episodes without `--coupled` are
    /// rejected before any worker runs.
    #[test]
    fn fault_flags_validate_and_wire_into_the_grid() {
        // No flags: the fault axis stays the single fault-free entry
        // and the per-app-class checkpoint defaults are kept.
        let (grid, _, _, _) = sweep_inputs(&args()).unwrap();
        assert_eq!(grid.faults, vec![FaultTrace::none()]);
        assert_eq!(grid.checkpoint, None);

        // A fault spec doubles the grid: fault-free baseline + faulted.
        let mut a = args();
        a.faults = Some("mtbf:250000,repair:7200,group:18".into());
        a.checkpoint = Some("1800".into());
        let (grid, _, _, _) = sweep_inputs(&a).unwrap();
        assert_eq!(grid.faults.len(), 2);
        assert!(grid.faults[0].is_none() && !grid.faults[1].is_none());
        assert_eq!(grid.faults[1].node_mtbf_s, 250_000.0);
        assert_eq!(grid.checkpoint, Some(CheckpointPolicy::Periodic(1800.0)));
        assert_eq!(grid.len(), 2 * 4 * 3 * 2);

        // Malformed specs come back as flag-shaped errors.
        let mut a = args();
        a.faults = Some("mtbf:0".into());
        assert!(sweep_inputs(&a).is_err(), "zero MTBF accepted");

        let mut a = args();
        a.faults = Some("mtbf:250000,factor:-0.5".into());
        assert!(sweep_inputs(&a).is_err(), "negative factor accepted");

        let mut a = args();
        a.checkpoint = Some("oops".into());
        assert!(sweep_inputs(&a).is_err(), "bogus checkpoint accepted");

        // Link episodes without coupling would silently change nothing:
        // error, and --coupled fixes it.
        let mut a = args();
        a.faults = Some("linkmtbf:90000,factor:0.5".into());
        let err = sweep_inputs(&a).unwrap_err();
        assert!(format!("{err}").contains("requires --coupled"), "{err}");
        a.coupled = true;
        assert!(sweep_inputs(&a).is_ok());
    }
}

fn overview(twin: &Twin) {
    let cfg = &twin.cfg;
    let node = cfg.gpu_node_spec().unwrap();
    println!("LEONARDO digital twin — architecture overview (Fig 1/3)");
    println!(
        "  Booster: {} nodes x 4 custom A100 = {} GPUs",
        cfg.gpu_nodes(),
        cfg.total_gpus()
    );
    println!(
        "  Data-Centric: {} nodes (2 x Sapphire Rapids 8480+)",
        cfg.cpu_nodes()
    );
    println!("  blade: {}", node.name);
    println!(
        "    host {} | PCIe Gen4 x16 per GPU ({} GB/s, {} GB/s total)",
        node.cpu.name,
        node.pcie_bw_per_gpu_gbs(),
        node.pcie_total_bw_gbs()
    );
    println!(
        "    NVLink 3.0: {} GB/s per GPU | HBM2e aggregate {:.1} TB/s",
        node.nvlink_bw_per_gpu_gbs(),
        node.gpu_memory_bw_gbs() / 1000.0
    );
    println!(
        "    injection: {} Gbps over {} HDR100 rails",
        node.injection_gbps(),
        node.nic_rails
    );
    println!(
        "  power: {:.1} MW facility envelope, PUE {:.2}",
        cfg.facility_power_mw, cfg.pue
    );
}
