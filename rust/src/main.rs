//! `leonardo-twin` CLI: regenerate any table or figure of the paper, run
//! calibration against the AOT kernel artifacts, replay an operational
//! day, sweep a scenario grid across cores, or dump machine facts.
//!
//! ```text
//! leonardo-twin table1                 # rack inventory (Table 1)
//! leonardo-twin table7 --calibrated    # LBM scaling from measured kernels
//! leonardo-twin operations --jobs 10000 --cap 8.0
//! leonardo-twin sweep --seeds 4 --caps none,7.5,6.5 --mixes day,ai
//! leonardo-twin all --markdown         # every table, markdown to stdout
//! leonardo-twin topology --dot > fabric.dot
//! ```
//!
//! (Hand-rolled argument parsing: the offline build has no clap.)

use leonardo_twin::campaign::SweepGrid;
use leonardo_twin::coordinator::Twin;
use leonardo_twin::metrics::Table;
use leonardo_twin::runtime::Engine;
use leonardo_twin::topology::Routing;
use leonardo_twin::workloads::TraceGen;

const USAGE: &str = "\
leonardo-twin — digital twin of the LEONARDO pre-exascale supercomputer

USAGE: leonardo-twin <COMMAND> [--markdown] [--calibrated] [--artifacts DIR]

COMMANDS:
  table1      Compute partition rack inventory        (Table 1)
  table2      GPU specifications and derived peaks    (Table 2)
  table3      Filesystem organisation                 (Table 3)
  table4      HPL / HPCG / Green500                   (Table 4)   [--calibrated]
  table5      IO500 phases and score                  (Table 5)
  table6      Application benchmarks TTS/ETS          (Table 6)
  table7      LBM weak scaling                        (Table 7)   [--calibrated]
  fig5        LBM efficiency: LEONARDO vs Marconi100  (Fig 5)
  latency     Fabric latency budget                   (Sec 2.2)
  topology    Dragonfly+ facts                        (Fig 4)     [--dot]
  overview    Architecture + blade summary            (Fig 1/3)
  operations  Replay a mixed HPC+AI day on the Booster partition
              through the event-driven scheduler      [--jobs N] [--seed S] [--cap MW]
  sweep       Multi-threaded scenario-sweep campaign: replay a
              seeds x power-caps x mixes grid of operational days and
              merge the outcomes (per-scenario, cap-sensitivity and
              aggregate-percentile tables — identical for any thread
              count)   [--jobs N] [--seed S] [--seeds K] [--caps LIST]
                       [--mixes LIST] [--threads T]
  calibrate   Measure the AOT kernels through PJRT
  all         Every table in paper order              [--calibrated]

OPTIONS:
  --markdown        markdown tables instead of console layout
  --calibrated      calibrate models with real PJRT kernel runs first
  --artifacts DIR   artifacts directory (default ./artifacts)
  --jobs N          operations/sweep: jobs per synthetic day
                    (default 10000 for operations, 2000 per sweep scenario)
  --seed S          operations: trace seed; sweep: first seed (default 2023)
  --cap MW          operations: facility power cap in MW (default uncapped)
  --seeds K         sweep: number of arrival seeds S, S+1, ... (default 4)
  --caps LIST       sweep: comma-separated cap levels in MW; 'none' lifts
                    the cap (default none,7.5,6.5)
  --mixes LIST      sweep: comma-separated TraceGen mixes: day, ai, hpc
                    (default day,ai)
  --threads T       sweep: worker threads (default: available cores)
";

struct Args {
    cmd: String,
    markdown: bool,
    calibrated: bool,
    dot: bool,
    artifacts: Option<String>,
    jobs: Option<usize>,
    seed: u64,
    cap_mw: Option<f64>,
    seeds: u64,
    caps: String,
    mixes: String,
    threads: Option<usize>,
}

fn parse_args() -> Result<Args, String> {
    let mut argv = std::env::args().skip(1);
    let cmd = argv.next().ok_or_else(|| USAGE.to_string())?;
    let mut args = Args {
        cmd,
        markdown: false,
        calibrated: false,
        dot: false,
        artifacts: None,
        jobs: None,
        seed: 2023,
        cap_mw: None,
        seeds: 4,
        caps: "none,7.5,6.5".to_string(),
        mixes: "day,ai".to_string(),
        threads: None,
    };
    while let Some(a) = argv.next() {
        match a.as_str() {
            "--markdown" => args.markdown = true,
            "--calibrated" => args.calibrated = true,
            "--dot" => args.dot = true,
            "--artifacts" => {
                args.artifacts = Some(argv.next().ok_or("--artifacts needs a value")?)
            }
            "--jobs" => {
                args.jobs = Some(
                    argv.next()
                        .ok_or("--jobs needs a value")?
                        .parse()
                        .map_err(|e| format!("--jobs: {e}"))?,
                )
            }
            "--seed" => {
                args.seed = argv
                    .next()
                    .ok_or("--seed needs a value")?
                    .parse()
                    .map_err(|e| format!("--seed: {e}"))?
            }
            "--cap" => {
                args.cap_mw = Some(
                    argv.next()
                        .ok_or("--cap needs a value")?
                        .parse()
                        .map_err(|e| format!("--cap: {e}"))?,
                )
            }
            "--seeds" => {
                args.seeds = argv
                    .next()
                    .ok_or("--seeds needs a value")?
                    .parse()
                    .map_err(|e| format!("--seeds: {e}"))?
            }
            "--caps" => args.caps = argv.next().ok_or("--caps needs a value")?,
            "--mixes" => args.mixes = argv.next().ok_or("--mixes needs a value")?,
            "--threads" => {
                args.threads = Some(
                    argv.next()
                        .ok_or("--threads needs a value")?
                        .parse()
                        .map_err(|e| format!("--threads: {e}"))?,
                )
            }
            "-h" | "--help" => return Err(USAGE.to_string()),
            other => return Err(format!("unknown flag '{other}'\n\n{USAGE}")),
        }
    }
    Ok(args)
}

/// Parse the sweep's `--caps` list: MW floats, with `none`/`off`/
/// `uncapped` lifting the cap for that grid level.
fn parse_caps(list: &str) -> Result<Vec<Option<f64>>, String> {
    list.split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| match s.to_ascii_lowercase().as_str() {
            "none" | "off" | "uncapped" => Ok(None),
            _ => s
                .parse::<f64>()
                .map(Some)
                .map_err(|e| format!("--caps '{s}': {e}")),
        })
        .collect()
}

fn print(t: &Table, markdown: bool) {
    if markdown {
        println!("{}", t.to_markdown());
    } else {
        println!("{}", t.to_console());
    }
}

fn engine(dir: &Option<String>) -> anyhow::Result<Engine> {
    match dir {
        Some(d) => Engine::load(d),
        None => Engine::load(Engine::default_dir()),
    }
}

fn maybe_calibrate(
    twin: &Twin,
    args: &Args,
) -> anyhow::Result<Option<leonardo_twin::perfmodel::Calibration>> {
    if !args.calibrated {
        return Ok(None);
    }
    let eng = engine(&args.artifacts)?;
    Ok(Some(twin.calibrate(&eng)?))
}

fn main() -> anyhow::Result<()> {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let twin = Twin::leonardo();
    let md = args.markdown;
    match args.cmd.as_str() {
        "table1" => print(&twin.table1(), md),
        "table2" => print(&twin.table2(), md),
        "table3" => print(&twin.table3(), md),
        "table4" => {
            let c = maybe_calibrate(&twin, &args)?;
            print(&twin.table4(c.as_ref()), md);
        }
        "table5" => print(&twin.table5(), md),
        "table6" => print(&twin.table6()?, md),
        "table7" => {
            let c = maybe_calibrate(&twin, &args)?;
            print(&twin.table7(c.as_ref())?, md);
        }
        "fig5" => print(&twin.fig5()?, md),
        "latency" => print(&twin.latency_table(), md),
        "topology" => {
            if args.dot {
                println!("{}", topology_dot(&twin));
            } else {
                topology_summary(&twin);
            }
        }
        "overview" => overview(&twin),
        "operations" => {
            let trace = TraceGen::booster_day(args.jobs.unwrap_or(10_000), args.seed);
            let report = twin.operations_replay(&trace, args.cap_mw)?;
            print(&report.summary, md);
            print(&report.power, md);
        }
        "sweep" => {
            if args.cap_mw.is_some() {
                eprintln!(
                    "sweep sweeps a grid of cap levels: use --caps LIST (e.g. \
                     --caps none,6.0), not the operations flag --cap"
                );
                std::process::exit(2);
            }
            let caps = match parse_caps(&args.caps) {
                Ok(c) => c,
                Err(msg) => {
                    eprintln!("{msg}");
                    std::process::exit(2);
                }
            };
            let seeds: Vec<u64> = (0..args.seeds).map(|k| args.seed + k).collect();
            let mixes: Vec<String> = args
                .mixes
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            let grid = match SweepGrid::new(seeds, caps, mixes, args.jobs.unwrap_or(2_000)) {
                Ok(g) => g,
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            };
            let threads = args.threads.unwrap_or_else(|| {
                std::thread::available_parallelism().map_or(1, |n| n.get())
            });
            eprintln!(
                "sweep: {} scenarios ({} seeds x {} caps x {} mixes, {} jobs each) on {} threads",
                grid.len(),
                grid.seeds.len(),
                grid.caps.len(),
                grid.mixes.len(),
                grid.jobs,
                threads
            );
            let report = twin.sweep(&grid, threads);
            print(&report.scenario_table(), md);
            print(&report.cap_table(), md);
            print(&report.summary_table(), md);
        }
        "calibrate" => {
            let eng = engine(&args.artifacts)?;
            println!("platform: {}", eng.platform());
            let c = twin.calibrate(&eng)?;
            print(&twin.calibration_table(&c), md);
        }
        "all" => {
            let c = maybe_calibrate(&twin, &args)?;
            print(&twin.table1(), md);
            print(&twin.table2(), md);
            print(&twin.table3(), md);
            print(&twin.table4(c.as_ref()), md);
            print(&twin.table5(), md);
            print(&twin.table6()?, md);
            print(&twin.table7(c.as_ref())?, md);
            print(&twin.fig5()?, md);
            print(&twin.latency_table(), md);
            if let Some(c) = &c {
                print(&twin.calibration_table(c), md);
            }
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

fn topology_summary(twin: &Twin) {
    let t = &twin.topo;
    println!(
        "dragonfly+ fabric: {} cells, {} switches ({} gateways)",
        t.cells.len(),
        t.total_switches(),
        leonardo_twin::topology::GATEWAYS
    );
    println!(
        "global links: {} total ({} per cell pair, {:.1} Tbps per pair)",
        t.total_global_links(),
        t.links_per_cell_pair,
        t.cell_pair_bw_gbps() / 1000.0
    );
    println!(
        "max node-to-node latency: {:.2} us (valiant), {:.2} us (minimal)",
        t.max_latency_ns() / 1000.0,
        t.route(0, t.total_nodes() - 1, Routing::Minimal)
            .latency_ns()
            / 1000.0
    );
}

fn topology_dot(twin: &Twin) -> String {
    use std::fmt::Write;
    let mut out = String::from("graph leonardo {\n  layout=circo;\n");
    for (i, c) in twin.topo.cells.iter().enumerate() {
        let color = match c.kind {
            leonardo_twin::config::CellKind::Booster => "green",
            leonardo_twin::config::CellKind::DataCentric => "blue",
            leonardo_twin::config::CellKind::Hybrid => "orange",
            leonardo_twin::config::CellKind::Io => "pink",
        };
        let _ = writeln!(
            out,
            "  c{i} [label=\"cell {i}\\n{} nodes\", style=filled, fillcolor={color}];",
            c.nodes
        );
    }
    for i in 0..twin.topo.cells.len() {
        for j in (i + 1)..twin.topo.cells.len() {
            let _ = writeln!(out, "  c{i} -- c{j};");
        }
    }
    out.push_str("}\n");
    out
}

fn overview(twin: &Twin) {
    let cfg = &twin.cfg;
    let node = cfg.gpu_node_spec().unwrap();
    println!("LEONARDO digital twin — architecture overview (Fig 1/3)");
    println!(
        "  Booster: {} nodes x 4 custom A100 = {} GPUs",
        cfg.gpu_nodes(),
        cfg.total_gpus()
    );
    println!(
        "  Data-Centric: {} nodes (2 x Sapphire Rapids 8480+)",
        cfg.cpu_nodes()
    );
    println!("  blade: {}", node.name);
    println!(
        "    host {} | PCIe Gen4 x16 per GPU ({} GB/s, {} GB/s total)",
        node.cpu.name,
        node.pcie_bw_per_gpu_gbs(),
        node.pcie_total_bw_gbs()
    );
    println!(
        "    NVLink 3.0: {} GB/s per GPU | HBM2e aggregate {:.1} TB/s",
        node.nvlink_bw_per_gpu_gbs(),
        node.gpu_memory_bw_gbs() / 1000.0
    );
    println!(
        "    injection: {} Gbps over {} HDR100 rails",
        node.injection_gbps(),
        node.nic_rails
    );
    println!(
        "  power: {:.1} MW facility envelope, PUE {:.2}",
        cfg.facility_power_mw, cfg.pue
    );
}
