//! Multi-threaded scenario-sweep campaign engine (ROADMAP north star:
//! "as many scenarios as you can imagine", paper §2.5–2.6: flexible,
//! scalable operation).
//!
//! A single [`crate::coordinator::Twin::operations_replay`] answers
//! "what did one day look like"; operators of machines like JUWELS
//! Booster and Isambard-AI ask grid questions — *how does p95 wait move
//! across power-cap levels, per workload mix, robust over arrival
//! seeds?* This module expands a [`SweepGrid`]
//! (`seeds x cap levels x TraceGen mixes`) into scenarios and fans them
//! across cores with `std::thread::scope` (no extra dependencies — the
//! build stays offline-hermetic). Each worker owns its own
//! [`Scheduler`], [`PowerMonitor`] and [`CongestionTracker`], so
//! workers share nothing but the read-only [`Twin`]; scenarios are
//! handed out through one atomic cursor and results are merged back in
//! grid order, which makes the [`CampaignReport`] bit-for-bit identical
//! for any worker-thread count (the `campaign_sweep` integration suite
//! pins 1 == 2 == 8 threads).
//!
//! The per-scenario replay runs on the scheduler's allocation-free hot
//! path (see `rust/src/scheduler`), which is what makes thousand-
//! scenario campaigns tractable.
//!
//! A [`SweepGrid::with_coupling`] grid replays every scenario with
//! runtime coupling on: job end times become provisional and re-time
//! under fabric contention and cap moves, the report gains runtime-
//! stretch percentiles, and the cap-sensitivity curve turns into a real
//! time/energy trade-off. Coupling changes nothing about the engine's
//! determinism, so coupled reports are still bit-for-bit identical for
//! any worker-thread count.
//!
//! Two fan-out engines share every scenario-level brick:
//! [`run_sweep_streaming`] (the production path — each worker keeps a
//! persistent [`ReplayRig`] *arena* it [`ReplayRig::reset`]s per
//! scenario, and streams `(grid index, stats)` over an `mpsc` channel
//! so the merged report builds as workers finish) and [`run_sweep`]
//! (the retained join-then-merge baseline: fresh rig per scenario,
//! merge after the join). Both produce byte-identical
//! [`CampaignReport`]s — the streaming merge fills a pre-sized slot
//! table by grid index, so completion order is invisible.

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{anyhow, ensure};

use std::collections::BTreeMap;

use crate::config::MachineConfig;
use crate::coordinator::Twin;
use crate::metrics::{f1, f2, Table};
use crate::network::CongestionTracker;
use crate::power::{PowerMonitor, Utilization};
use crate::scheduler::{
    CheckpointPolicy, Coupling, Job, JobRecord, Partition, PolicyKind, PowerCap, ReplaySession,
    RunCounters, Scheduler,
};
use crate::sim::{Component, Event, ScheduledEvent, Simulation};
use crate::workloads::{FaultTrace, TraceGen};
use crate::Result;

/// One cell of the scenario grid: a trace (mix + seed) under an
/// optional facility power cap, a placement policy, with or without
/// runtime coupling.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub mix: String,
    pub seed: u64,
    pub cap_mw: Option<f64>,
    pub coupling: Coupling,
    /// Placement policy the scheduler replays under.
    pub policy: PolicyKind,
    /// Replay on the PR 3 retime-all walk instead of the incremental
    /// cell-indexed retimer (see [`crate::scheduler::Scheduler::retime_all`]) —
    /// the bench baseline; records are bit-identical either way.
    pub retime_all: bool,
    /// Seconds into the day at which the cap level arrives. 0 (default)
    /// = the cap applies from t=0 like the pre-fork grids. Positive =
    /// the scheduler starts uncapped-equivalent (an armed infinite cap)
    /// and a `CapChange` event lands at this time — the late-divergence
    /// shape the divergence-tree sweep shares prefixes across.
    pub cap_time: f64,
    /// Failure processes injected into the replay
    /// ([`FaultTrace::none`] — the default axis value — renders no
    /// events and leaves the scenario byte-identical to a fault-free
    /// one).
    pub faults: FaultTrace,
    pub trace: TraceGen,
}

impl Scenario {
    pub fn label(&self) -> String {
        let policy = self.policy.name();
        let mut label =
            format!("{} seed={} {} {policy}", self.mix, self.seed, cap_label(self.cap_mw));
        if !self.faults.is_none() {
            label.push(' ');
            label.push_str(&self.faults.label());
        }
        label
    }

    /// The cap level the rig is armed with at t=0. With a deferred cap
    /// (`cap_time > 0`) every scenario of a fork group — capped or not —
    /// arms an *infinite* cap: `dvfs_scale_at` returns exactly 1.0 below
    /// any finite draw, so the armed-but-infinite prefix is bit-identical
    /// to capless, and the divergent `CapChange` only has to move the
    /// level ([`crate::sim::Event::CapChange`] on a capless scheduler is
    /// a no-op by design).
    pub fn armed_cap(&self) -> Option<f64> {
        if self.cap_time > 0.0 {
            Some(f64::INFINITY)
        } else {
            self.cap_mw
        }
    }

    /// The scenario's injected event stream: the fault trace rendered
    /// against the machine, then the deferred `CapChange` when it has
    /// one. Shared by the streaming path (scheduled upfront) and the
    /// forked path (faults at session creation, the member cap injected
    /// after restore) — both enter the kernel's divergent sequence band
    /// at the same ranks (faults at `0..F`, the cap at `F`), which is
    /// what keeps the two engines byte-identical.
    pub fn extra_events(&self, cfg: &MachineConfig) -> Vec<ScheduledEvent> {
        let mut out = self.faults.events(cfg);
        if self.cap_time > 0.0 {
            if let Some(mw) = self.cap_mw {
                out.push(ScheduledEvent::at(
                    self.cap_time,
                    Event::CapChange { cap_mw: Some(mw) },
                ));
            }
        }
        out
    }
}

fn cap_label(cap_mw: Option<f64>) -> String {
    match cap_mw {
        Some(mw) => format!("cap {mw:.1} MW"),
        None => "uncapped".to_string(),
    }
}

/// The sweep grid: arrival seeds x facility power-cap levels x workload
/// mixes (by [`TraceGen::named`] name) x placement policies, each
/// scenario a `jobs`-job day.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepGrid {
    pub seeds: Vec<u64>,
    pub caps: Vec<Option<f64>>,
    pub mixes: Vec<String>,
    /// Placement-policy axis (default `[PackFirst]` — the seed order,
    /// so a policy-less grid is exactly the pre-policy grid).
    pub policies: Vec<PolicyKind>,
    /// Jobs per scenario trace.
    pub jobs: usize,
    /// Runtime coupling applied to every scenario (default off — the
    /// replay is then bit-for-bit the uncoupled oracle engines).
    pub coupling: Coupling,
    /// Replay every scenario on the PR 3 retime-all walk (default off:
    /// incremental cell-indexed retiming). Identical records; kept as
    /// the throughput-bench baseline and identity-test oracle.
    pub retime_all: bool,
    /// Seconds into the day at which each scenario's cap level arrives
    /// (see [`Scenario::cap_time`]). 0 (default) = caps apply from t=0
    /// and the grid has no shared prefixes to fork.
    pub cap_time: f64,
    /// Failure-trace axis (default `[FaultTrace::none()]` — a single
    /// fault-free entry, so a fault-less grid expands exactly like the
    /// pre-fault grids).
    pub faults: Vec<FaultTrace>,
    /// Checkpoint policy forced on every generated job (`None`, the
    /// default, keeps each [`crate::workloads::AppClass`]'s own
    /// [`crate::workloads::AppClass::checkpoint_policy`]).
    pub checkpoint: Option<CheckpointPolicy>,
}

impl SweepGrid {
    /// Validate and build a grid. Every axis must be non-empty and all
    /// mix names must resolve via [`TraceGen::named`].
    pub fn new(
        seeds: Vec<u64>,
        caps: Vec<Option<f64>>,
        mixes: Vec<String>,
        jobs: usize,
    ) -> Result<Self> {
        ensure!(!seeds.is_empty(), "sweep grid needs at least one seed");
        ensure!(!caps.is_empty(), "sweep grid needs at least one cap level");
        ensure!(!mixes.is_empty(), "sweep grid needs at least one mix");
        ensure!(jobs > 0, "sweep grid needs jobs > 0 per scenario");
        for cap in caps.iter().flatten() {
            // A NaN/negative cap would poison DVFS scales and panic a
            // worker on a non-finite event time — reject it here, at
            // the CLI-facing boundary.
            ensure!(
                cap.is_finite() && *cap > 0.0,
                "cap level {cap} MW must be finite and positive"
            );
        }
        for mix in &mixes {
            if TraceGen::named(mix, 1, 0).is_none() {
                return Err(anyhow!(
                    "unknown mix '{mix}' (known: {})",
                    TraceGen::known_mixes().join(", ")
                ));
            }
        }
        Ok(SweepGrid {
            seeds,
            caps,
            mixes,
            policies: vec![PolicyKind::PackFirst],
            jobs,
            coupling: Coupling::default(),
            retime_all: false,
            cap_time: 0.0,
            faults: vec![FaultTrace::none()],
            checkpoint: None,
        })
    }

    /// Same grid with runtime coupling applied to every scenario.
    pub fn with_coupling(mut self, coupling: Coupling) -> Self {
        self.coupling = coupling;
        self
    }

    /// Same grid swept over a placement-policy axis (scored against
    /// each other in the report's policy table). Panics on an empty
    /// axis — the CLI boundary ([`parse_policies`]) rejects it first.
    pub fn with_policies(mut self, policies: Vec<PolicyKind>) -> Self {
        assert!(!policies.is_empty(), "policy axis needs at least one policy");
        self.policies = policies;
        self
    }

    /// Same grid replayed on the PR 3 retime-all walk (bench baseline).
    pub fn with_retime_all(mut self, retime_all: bool) -> Self {
        self.retime_all = retime_all;
        self
    }

    /// Same grid with every cap level arriving `cap_time` seconds into
    /// the day instead of at t=0 — the late-divergence grid shape the
    /// forked sweep shares prefixes across. Panics on a non-finite or
    /// negative time; the CLI boundary (`--cap-time`) rejects it first.
    pub fn with_cap_time(mut self, cap_time: f64) -> Self {
        assert!(
            cap_time.is_finite() && cap_time >= 0.0,
            "cap_time must be finite and >= 0, got {cap_time}"
        );
        self.cap_time = cap_time;
        self
    }

    /// Same grid swept over a failure-trace axis (an extra outer grid
    /// dimension, like the policy axis). Panics on an empty axis — the
    /// CLI boundary ([`parse_faults`]) always yields one trace.
    pub fn with_fault_traces(mut self, faults: Vec<FaultTrace>) -> Self {
        assert!(!faults.is_empty(), "fault axis needs at least one trace");
        self.faults = faults;
        self
    }

    /// Same grid with one checkpoint policy forced on every generated
    /// job (`None` restores the per-[`crate::workloads::AppClass`]
    /// defaults).
    pub fn with_checkpoint(mut self, checkpoint: Option<CheckpointPolicy>) -> Self {
        self.checkpoint = checkpoint;
        self
    }

    pub fn len(&self) -> usize {
        self.seeds.len()
            * self.caps.len()
            * self.mixes.len()
            * self.policies.len()
            * self.faults.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the grid in deterministic policy-major, then fault-trace,
    /// then mix, then cap, then seed order — the order scenarios are
    /// numbered, reported and merged in, regardless of which worker ran
    /// which. (With the default single-policy, single-fault axes this
    /// is exactly the pre-policy expansion.)
    pub fn scenarios(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.len());
        for &policy in &self.policies {
            for faults in &self.faults {
                for mix in &self.mixes {
                    for &cap_mw in &self.caps {
                        for &seed in &self.seeds {
                            let mut trace = TraceGen::named(mix, self.jobs, seed)
                                .expect("mix names validated at grid construction");
                            trace.checkpoint = self.checkpoint;
                            out.push(Scenario {
                                mix: mix.clone(),
                                seed,
                                cap_mw,
                                coupling: self.coupling,
                                policy,
                                retime_all: self.retime_all,
                                cap_time: self.cap_time,
                                faults: faults.clone(),
                                trace,
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// Partition the grid's scenario indices into *divergence-tree fork
    /// groups*: scenarios in one group share every event before the
    /// deferred cap move (same policy, mix and seed — the axes that
    /// shape the whole day) and differ only in the cap level arriving at
    /// [`SweepGrid::cap_time`], so a worker can simulate the shared
    /// prefix once, snapshot, and replay only the suffix per member.
    ///
    /// The grouping is pinned to the canonical [`SweepGrid::scenarios`]
    /// expansion (policy-major, then fault trace, then mix, then cap,
    /// then seed): member `c` of group `(p, f, m, s)` is grid index
    /// `(((p * faults + f) * mixes + m) * caps + c) * seeds + s`. Groups
    /// are emitted in `(policy, fault, mix, seed)` order, each with its
    /// members in cap order — re-ordering an axis re-numbers scenarios
    /// but never changes which scenarios share a prefix. Fault traces
    /// differ *across* groups only: every member of a group replays the
    /// identical failure stream, so the shared prefix stays shared.
    ///
    /// A grid without a deferred cap (`cap_time == 0`) is all-divergent:
    /// every scenario is its own singleton group and the forked sweep
    /// degenerates to plain streaming with zero forks. A single-cap grid
    /// degenerates the same way (groups of one).
    pub fn fork_groups(&self) -> Vec<Vec<usize>> {
        if self.cap_time <= 0.0 {
            return (0..self.len()).map(|i| vec![i]).collect();
        }
        let (n_caps, n_seeds) = (self.caps.len(), self.seeds.len());
        let (n_mixes, n_faults) = (self.mixes.len(), self.faults.len());
        let mut out =
            Vec::with_capacity(self.policies.len() * n_faults * n_mixes * n_seeds);
        for p in 0..self.policies.len() {
            for f in 0..n_faults {
                for m in 0..n_mixes {
                    for s in 0..n_seeds {
                        out.push(
                            (0..n_caps)
                                .map(|c| {
                                    (((p * n_faults + f) * n_mixes + m) * n_caps + c) * n_seeds
                                        + s
                                })
                                .collect(),
                        );
                    }
                }
            }
        }
        out
    }

    /// The grid's canonical work units for a given engine mode: fork
    /// groups when `fork` is on, one singleton group per scenario
    /// otherwise. This is the unit the distributed service assigns to
    /// workers, and pinning it here (rather than letting coordinator
    /// and worker each decide) is what lets both sides number groups
    /// identically from the grid alone — the wire only ever carries
    /// group *ids*.
    pub fn work_groups(&self, fork: bool) -> Vec<Vec<usize>> {
        if fork {
            self.fork_groups()
        } else {
            (0..self.len()).map(|i| vec![i]).collect()
        }
    }

    /// Structural cost hints for [`SweepGrid::work_groups`], one per
    /// group in group order — what the distributed coordinator seeds
    /// its longest-estimated-first ready queue from before it has any
    /// observed service times. Derived arithmetically from the
    /// canonical expansion (no scenario generation): every member of a
    /// group shares the same fault-trace index, so `members[0]` names
    /// the group's fault axis value.
    ///
    /// The hint is a *relative* unit — fork members × jobs, scaled up
    /// for an armed fault trace and for runtime coupling — refined
    /// online by the coordinator's per-class service-time rates, so
    /// only its ordering has to be roughly right, never its scale.
    pub fn group_cost_hints(&self, fork: bool) -> Vec<GroupCost> {
        let span = self.seeds.len() * self.caps.len() * self.mixes.len();
        self.work_groups(fork)
            .iter()
            .map(|members| {
                let f = (members[0] / span) % self.faults.len();
                let fault_armed = !self.faults[f].is_none();
                let mut hint = members.len() as f64 * self.jobs as f64;
                if fault_armed {
                    hint *= 1.5;
                }
                if self.coupling.enabled() {
                    hint *= 1.25;
                }
                GroupCost {
                    members: members.len(),
                    fault_armed,
                    hint,
                }
            })
            .collect()
    }
}

/// Structural cost estimate for one work group — the shape the
/// distributed scheduler reasons about a group with before (and while)
/// it runs. See [`SweepGrid::group_cost_hints`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupCost {
    /// Fork-group member count (1 for a streaming singleton).
    pub members: usize,
    /// Whether the group's fault-trace axis value renders events.
    pub fault_armed: bool,
    /// Relative cost estimate in arbitrary units (ordering is what
    /// matters; observed service times calibrate the scale online).
    pub hint: f64,
}

impl GroupCost {
    /// Number of cost classes ([`GroupCost::class`] values).
    pub const CLASSES: usize = 4;

    /// The group's cost class for service-time pooling:
    /// fork-group-vs-singleton × fault-armed-vs-clean. Progress
    /// deadlines and cost-rate calibration pool observations per class
    /// so a 6-member fork group is never judged by singleton acks.
    pub fn class(&self) -> usize {
        usize::from(self.members > 1) * 2 + usize::from(self.fault_armed)
    }
}

/// Numeric outcome of one scenario replay. Plain data, so merged
/// campaign results compare bit-for-bit across thread counts.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioStats {
    pub mix: String,
    pub seed: u64,
    pub cap_mw: Option<f64>,
    /// Placement policy the scenario replayed under.
    pub policy: PolicyKind,
    /// Fault-trace label ([`FaultTrace::label`]) the scenario replayed
    /// under ("none" on the fault-free axis value).
    pub faults: String,
    pub jobs: usize,
    pub makespan_h: f64,
    pub mean_wait_min: f64,
    pub p95_wait_min: f64,
    pub max_wait_min: f64,
    /// Mean busy fraction of the partition over the makespan.
    pub utilization: f64,
    /// Peak PUE-inclusive facility draw, MW.
    pub peak_mw: f64,
    /// PUE-inclusive facility energy, MWh.
    pub energy_mwh: f64,
    /// Jobs that ran DVFS-throttled under the cap.
    pub throttled: usize,
    /// Highest mean global-link load observed.
    pub peak_congestion: f64,
    /// Highest single link-bundle utilization observed (the hottest
    /// global link of the day).
    pub peak_link_util: f64,
    /// Mean over events of the mean link-bundle utilization.
    pub mean_link_util: f64,
    /// Mean runtime stretch (actual / nominal runtime; 1.0 = no
    /// slowdown). Above 1 only when DVFS capping or runtime coupling
    /// extended jobs.
    pub mean_stretch: f64,
    /// 95th-percentile runtime stretch.
    pub p95_stretch: f64,
    /// Stale re-timed `End`s skipped at pop time (0 when uncoupled).
    pub events_skipped: u64,
    /// Re-time evaluations elided by the cell index / rate-unchanged
    /// check (0 when uncoupled or on the retime-all baseline's
    /// untouched-job skips). Pure observability — never feeds back into
    /// any scheduling number.
    pub retimes_elided: u64,
    /// Shared-prefix forks this scenario benefited from (1 when it ran
    /// as a member of a multi-scenario divergence-tree group, 0 on the
    /// streaming path). Pure bookkeeping — zeroed by
    /// [`CampaignReport::with_fork_counters_zeroed`] for the
    /// forked-vs-streaming identity oracle.
    pub forks: u64,
    /// Snapshot restores paid to replay this scenario's suffix (0 for
    /// the group's first member, which rides the live prefix).
    pub restores: u64,
    /// Jobs fault-killed during the replay (one job killed twice counts
    /// twice).
    pub killed: u64,
    /// Fault kills whose job held a [`CheckpointPolicy::Periodic`]
    /// policy and re-queued with checkpoint-truncated rework (the rest
    /// repeat everything).
    pub requeued: u64,
    /// Node-hours of work destroyed by fault kills (wall-clock time no
    /// checkpoint covered, weighted by the job's nodes).
    pub wasted_node_h: f64,
    /// Useful node-time fraction: committed node-seconds over committed
    /// plus destroyed. Exactly 1.0 on a fault-free replay.
    pub goodput: f64,
    /// p95 over fault-killed jobs of total recovery stretch (first
    /// start to final completion, over nominal runtime; 0 when nothing
    /// was killed).
    pub p95_recovery_stretch: f64,
}

/// Index-percentile over an ascending-sorted slice (the same
/// convention `Twin::operations_replay` reports).
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * p) as usize]
}

impl ScenarioStats {
    /// Compute the numeric outcome of a finished replay from its job
    /// records and observers. The identity fields (`mix`/`seed`/
    /// `cap_mw`) are left empty for the caller to fill. Shared by
    /// [`run_scenario`] and `Twin::operations_replay`, so the single-
    /// day CLI and the sweep always report identical arithmetic.
    pub fn collect(
        jobs: &[Job],
        records: &BTreeMap<u64, JobRecord>,
        total_nodes: u32,
        monitor: &PowerMonitor,
        congestion: &CongestionTracker,
    ) -> Self {
        assert!(!jobs.is_empty(), "stats over an empty replay");
        let makespan = records.values().fold(0.0f64, |m, r| m.max(r.end_time));
        let mut waits: Vec<f64> = jobs.iter().map(|j| records[&j.id].wait(j)).collect();
        waits.sort_by(f64::total_cmp);
        let mean_wait = waits.iter().sum::<f64>() / waits.len() as f64;
        // "Ever ran below nominal", not "finished below nominal" — a
        // coupled job relieved by a mid-day cap lift still counts.
        let throttled = records.values().filter(|r| r.min_dvfs_scale < 1.0).count();
        let mut stretches: Vec<f64> = jobs
            .iter()
            .map(|j| {
                let r = &records[&j.id];
                (r.end_time - r.start_time) / j.run_seconds.max(1e-9)
            })
            .collect();
        stretches.sort_by(f64::total_cmp);
        let mean_stretch = stretches.iter().sum::<f64>() / stretches.len() as f64;
        let node_seconds: f64 = jobs
            .iter()
            .map(|j| {
                j.nodes as f64 * (records[&j.id].end_time - records[&j.id].start_time)
            })
            .sum();
        let utilization = node_seconds / (total_nodes as f64 * makespan.max(1e-9));
        let peak_mw =
            monitor.store.get("facility_power_w").map_or(0.0, |s| s.max()) / 1e6;
        ScenarioStats {
            mix: String::new(),
            seed: 0,
            cap_mw: None,
            policy: PolicyKind::default(),
            faults: String::new(),
            jobs: records.len(),
            makespan_h: makespan / 3600.0,
            mean_wait_min: mean_wait / 60.0,
            p95_wait_min: percentile(&waits, 0.95) / 60.0,
            max_wait_min: percentile(&waits, 1.0) / 60.0,
            utilization,
            peak_mw,
            energy_mwh: monitor.energy_kwh() / 1e3,
            throttled,
            peak_congestion: congestion.peak_load(),
            peak_link_util: congestion.peak_link_load(),
            mean_link_util: congestion.link_series.mean(),
            mean_stretch,
            p95_stretch: percentile(&stretches, 0.95),
            events_skipped: 0,
            retimes_elided: 0,
            forks: 0,
            restores: 0,
            killed: 0,
            requeued: 0,
            wasted_node_h: 0.0,
            goodput: 1.0,
            p95_recovery_stretch: 0.0,
        }
    }
}

/// Fold one replay's [`RunCounters`] into its stats: the fault
/// bookkeeping plus the goodput fraction (committed node-seconds over
/// committed + destroyed). On a fault-free replay the destroyed term is
/// exactly 0.0 and the fraction is exactly 1.0 — `x / x` is IEEE-exact
/// — so fault-free stats stay bit-identical to pre-fault reports.
pub(crate) fn apply_fault_counters(
    stats: &mut ScenarioStats,
    counters: &RunCounters,
    jobs: &[Job],
    records: &BTreeMap<u64, JobRecord>,
) {
    stats.killed = counters.killed;
    stats.requeued = counters.requeued;
    stats.wasted_node_h = counters.wasted_node_seconds / 3600.0;
    stats.p95_recovery_stretch = counters.recovery_p95;
    let useful: f64 = jobs
        .iter()
        .map(|j| {
            let r = &records[&j.id];
            j.nodes as f64 * (r.end_time - r.start_time)
        })
        .sum();
    let committed = useful + counters.wasted_node_seconds;
    stats.goodput = if committed > 0.0 { useful / committed } else { 1.0 };
}

/// One replay's scheduler + observer set, wired identically for every
/// surface that replays a trace — the sweep workers here and
/// `Twin::operations_replay` — so a `sweep` scenario and a matching
/// `operations` run can never model the machine differently.
pub struct ReplayRig {
    pub sched: Scheduler,
    pub monitor: PowerMonitor,
    pub congestion: CongestionTracker,
    pub total_nodes: u32,
    /// The rig's event-kernel arena: one [`Simulation`] reused across
    /// scenarios (and across fork-group snapshots), so replays retain
    /// the event heap and snapshot buffers instead of reallocating.
    pub sim: Simulation,
    /// Memo of generated traces: scenarios that differ only along the
    /// cap/policy axes share a `(mix, seed)` trace, and a persistent
    /// arena replays many of them back to back — clone the cached jobs
    /// instead of re-running the Poisson generator per scenario.
    /// Deliberately *not* cleared by [`ReplayRig::reset`]: the cache is
    /// keyed on the full generator state, so an entry can go unused but
    /// never stale.
    pub traces: TraceCache,
}

/// Bounded memo of [`TraceGen::generate`] outputs, keyed on the full
/// generator state (every field `generate` reads), so a hit is exactly
/// the trace a fresh `generate` would have produced — byte-identity of
/// cached replays falls out of the generator's determinism.
#[derive(Debug, Clone, Default)]
pub struct TraceCache {
    /// `(key, jobs)` in insertion order; evicted FIFO past
    /// [`TraceCache::CAP`]. Linear scan: the cache holds a handful of
    /// entries and a lookup amortizes a full trace generation.
    entries: Vec<(String, Vec<Job>)>,
    hits: u64,
    misses: u64,
}

impl TraceCache {
    /// Entries kept; a sweep touches `mixes × seeds` distinct traces
    /// and anything past this bound just regenerates.
    const CAP: usize = 16;

    /// The jobs `gen.generate()` would produce, cloned from the cache
    /// when an identical generator was seen before.
    pub fn jobs_for(&mut self, gen: &TraceGen) -> Vec<Job> {
        // `TraceGen` derives no `PartialEq` (f64 mix weights); the
        // `Debug` rendering covers every field and is deterministic,
        // which is all a memo key needs.
        let key = format!("{gen:?}");
        if let Some((_, jobs)) = self.entries.iter().find(|(k, _)| *k == key) {
            self.hits += 1;
            return jobs.clone();
        }
        self.misses += 1;
        let jobs = gen.generate();
        if self.entries.len() >= Self::CAP {
            self.entries.remove(0);
        }
        self.entries.push((key, jobs.clone()));
        jobs
    }

    /// `(hits, misses)` since construction — observability for the
    /// cache-effectiveness test and the worker's exit log.
    pub fn counters(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

impl ReplayRig {
    pub fn new(
        twin: &Twin,
        partition: Partition,
        cap_mw: Option<f64>,
        coupling: Coupling,
        policy: PolicyKind,
    ) -> Self {
        let mut sched = Scheduler::new(&twin.cfg);
        sched.coupling = coupling;
        sched.set_policy(policy);
        if coupling.congestion {
            // The coupled engine derives comm slowdowns from the twin's
            // network model (routing policy included).
            sched.net = Some(twin.net.clone());
        }
        if let Some(mw) = cap_mw {
            sched.power_cap = Some(PowerCap::for_model(&twin.power, mw));
        }
        let total_nodes = sched.total_nodes(partition);
        // Mixed-day fleet utilisation: busy but not HPL-saturated.
        let util = Utilization {
            cpu: 0.40,
            gpu: Some(0.80),
        };
        let mut monitor = PowerMonitor::new(twin.power.clone(), util, total_nodes);
        monitor.booster_only = partition == Partition::Booster;
        let congestion = CongestionTracker::for_booster(&twin.cfg);
        ReplayRig {
            sched,
            monitor,
            congestion,
            total_nodes,
            sim: Simulation::new(),
            traces: TraceCache::default(),
        }
    }

    /// Re-arm the rig for another scenario, reusing every long-lived
    /// allocation — scheduler pools and order buffers, the monitor's
    /// metric series, the tracker's cell map — instead of rebuilding
    /// from the `Twin`. This is the per-worker *scenario arena* of the
    /// streaming sweep; a reset rig replays bit-identically to a fresh
    /// [`ReplayRig::new`] (pinned by the arena identity test).
    pub fn reset(
        &mut self,
        twin: &Twin,
        partition: Partition,
        cap_mw: Option<f64>,
        coupling: Coupling,
        policy: PolicyKind,
    ) {
        self.sched.reset();
        self.sched.coupling = coupling;
        self.sched.set_policy(policy);
        if coupling.congestion && self.sched.net.is_none() {
            self.sched.net = Some(twin.net.clone());
        }
        if let Some(mw) = cap_mw {
            self.sched.power_cap = Some(PowerCap::for_model(&twin.power, mw));
        }
        self.total_nodes = self.sched.total_nodes(partition);
        self.monitor.reset(self.total_nodes, partition == Partition::Booster);
        self.congestion.reset();
    }
}

/// Replay one scenario on an already-armed rig — the core the fresh-rig
/// path and the arena path share, so they cannot diverge. Runs as a
/// [`ReplaySession`] over the rig's kernel arena: the fault trace and a
/// deferred cap ([`Scenario::extra_events`]) are scheduled upfront in
/// the divergent band, exactly where the forked path injects them.
fn replay(rig: &mut ReplayRig, sc: &Scenario, cfg: &MachineConfig) -> ScenarioStats {
    let jobs = rig.traces.jobs_for(&sc.trace);
    assert!(!jobs.is_empty(), "empty scenario trace");
    rig.sched.retime_all = sc.retime_all;
    let ReplayRig {
        sched,
        monitor,
        congestion,
        total_nodes,
        sim,
        traces: _,
    } = rig;
    let records = {
        let mut session = ReplaySession::new(sim, sched, jobs.clone(), sc.extra_events(cfg));
        let mut observers: [&mut dyn Component; 2] = [&mut *monitor, &mut *congestion];
        session.run_to_end(&mut observers);
        session.finish()
    };
    let mut stats = ScenarioStats::collect(&jobs, &records, *total_nodes, monitor, congestion);
    stats.mix = sc.mix.clone();
    stats.seed = sc.seed;
    stats.cap_mw = sc.cap_mw;
    stats.policy = sc.policy;
    stats.faults = sc.faults.label();
    stats.events_skipped = sched.last_run.events_skipped;
    stats.retimes_elided = sched.last_run.retimes_elided;
    apply_fault_counters(&mut stats, &sched.last_run, &jobs, &records);
    stats
}

/// Replay one scenario on a private scheduler + observer set. Pure in
/// `(twin, scenario)` — the unit of work [`run_sweep`] fans out, paying
/// a fresh rig per scenario (the PR 3 cost shape the streaming arena is
/// benched against).
pub fn run_scenario(twin: &Twin, sc: &Scenario) -> ScenarioStats {
    let mut rig =
        ReplayRig::new(twin, sc.trace.partition, sc.armed_cap(), sc.coupling, sc.policy);
    replay(&mut rig, sc, &twin.cfg)
}

/// Arm a worker's persistent arena for `sc`: the first call builds the
/// rig, every later call [`ReplayRig::reset`]s it — no Twin cloning, no
/// pool/series reallocation.
fn arm_arena<'a>(
    arena: &'a mut Option<ReplayRig>,
    twin: &Twin,
    sc: &Scenario,
) -> &'a mut ReplayRig {
    match arena {
        Some(rig) => {
            rig.reset(twin, sc.trace.partition, sc.armed_cap(), sc.coupling, sc.policy)
        }
        None => {
            *arena = Some(ReplayRig::new(
                twin,
                sc.trace.partition,
                sc.armed_cap(),
                sc.coupling,
                sc.policy,
            ))
        }
    }
    arena.as_mut().expect("arena armed above")
}

/// Replay one scenario on a worker's persistent arena. Bit-identical to
/// [`run_scenario`] (pinned by the arena identity test).
pub fn run_scenario_arena(
    arena: &mut Option<ReplayRig>,
    twin: &Twin,
    sc: &Scenario,
) -> ScenarioStats {
    replay(arm_arena(arena, twin, sc), sc, &twin.cfg)
}

/// Replay one divergence-tree fork group on a worker's arena: simulate
/// the shared prefix once up to the deferred cap move, snapshot every
/// layer, then per member restore + inject that member's `CapChange` +
/// replay only the suffix. Returns `(grid index, stats)` per member.
///
/// Byte-identity with the streaming path rests on three invariants:
/// the armed infinite cap makes the prefix bit-identical to every
/// member's own full replay ([`Scenario::armed_cap`]); restore rewinds
/// kernel counters and generation stamps exactly, so stale-End skips
/// re-count identically; and the injected cap move enters the divergent
/// sequence band at the same rank the streaming path schedules it at.
/// Only the `forks`/`restores` bookkeeping differs.
///
/// Public because it is the unit of work the distributed sweep service
/// dispatches: a [`crate::service`] worker replays assigned groups on
/// its own persistent arena with exactly this function, which is how
/// the distributed merge stays byte-identical to the local engines.
pub fn replay_group(
    arena: &mut Option<ReplayRig>,
    twin: &Twin,
    scenarios: &[Scenario],
    group: &[usize],
) -> Vec<(usize, ScenarioStats)> {
    if group.len() == 1 {
        // Singleton (degenerate grid or single-cap axis): plain
        // streaming replay, zero forks.
        let i = group[0];
        return vec![(i, run_scenario_arena(arena, twin, &scenarios[i]))];
    }
    let sc0 = &scenarios[group[0]];
    let rig = arm_arena(arena, twin, sc0);
    rig.sched.retime_all = sc0.retime_all;
    // Group members share policy/fault trace/mix/seed, so one generated
    // trace and one rendered fault stream serve every member.
    let jobs = rig.traces.jobs_for(&sc0.trace);
    assert!(!jobs.is_empty(), "empty scenario trace");
    let fault_events = sc0.faults.events(&twin.cfg);
    // The member cap diverges at the rank just past the fault events —
    // the same divergent-band slot the streaming path's upfront
    // `extra_events` schedule gives it.
    let cap_rank = fault_events.len() as u64;
    let ReplayRig {
        sched,
        monitor,
        congestion,
        total_nodes,
        sim,
        traces: _,
    } = rig;
    let mut session = ReplaySession::new(sim, sched, jobs.clone(), fault_events);
    {
        let mut observers: [&mut dyn Component; 2] = [&mut *monitor, &mut *congestion];
        session.run_until(sc0.cap_time, &mut observers);
        session.snapshot(&mut observers);
    }
    let mut out = Vec::with_capacity(group.len());
    for (k, &i) in group.iter().enumerate() {
        let sc = &scenarios[i];
        {
            let mut observers: [&mut dyn Component; 2] = [&mut *monitor, &mut *congestion];
            if k > 0 {
                session.restore(&mut observers);
            }
            if let Some(mw) = sc.cap_mw {
                session.schedule_ranked(
                    sc.cap_time,
                    Event::CapChange { cap_mw: Some(mw) },
                    cap_rank,
                );
            }
            session.run_to_end(&mut observers);
            session.assert_complete();
        }
        let mut stats =
            ScenarioStats::collect(&jobs, session.records(), *total_nodes, monitor, congestion);
        stats.mix = sc.mix.clone();
        stats.seed = sc.seed;
        stats.cap_mw = sc.cap_mw;
        stats.policy = sc.policy;
        stats.faults = sc.faults.label();
        let counters = session.counters();
        stats.events_skipped = counters.events_skipped;
        stats.retimes_elided = counters.retimes_elided;
        apply_fault_counters(&mut stats, &counters, &jobs, session.records());
        stats.forks = 1;
        stats.restores = u64::from(k > 0);
        out.push((i, stats));
    }
    out
}

/// Merged outcome of a sweep: per-scenario stats in grid order plus
/// rendered report tables. Identical for any worker-thread count.
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignReport {
    pub stats: Vec<ScenarioStats>,
}

impl CampaignReport {
    /// The report with the `forks`/`restores` bookkeeping zeroed — what
    /// the forked-vs-streaming identity oracle compares, since the two
    /// engines agree on every simulated number and differ only in how
    /// much replay work they shared.
    pub fn with_fork_counters_zeroed(&self) -> CampaignReport {
        let mut r = self.clone();
        for s in &mut r.stats {
            s.forks = 0;
            s.restores = 0;
        }
        r
    }

    /// The report with every fault-robustness metric reset to its
    /// fault-free value (no kills, no waste, goodput exactly 1.0) — the
    /// comparator for "an empty [`FaultTrace`] axis is byte-identical
    /// to a pre-fault report": on a fault-free report this is a no-op.
    pub fn with_fault_counters_zeroed(&self) -> CampaignReport {
        let mut r = self.clone();
        for s in &mut r.stats {
            s.killed = 0;
            s.requeued = 0;
            s.wasted_node_h = 0.0;
            s.goodput = 1.0;
            s.p95_recovery_stretch = 0.0;
        }
        r
    }

    /// One row per scenario, in grid order.
    pub fn scenario_table(&self) -> Table {
        let mut t = Table::new(
            "Campaign sweep — per-scenario outcomes",
            &[
                "Mix",
                "Seed",
                "Cap",
                "Policy",
                "Faults",
                "Jobs",
                "Makespan [h]",
                "Mean wait [min]",
                "p95 wait [min]",
                "Util",
                "Peak [MW]",
                "Energy [MWh]",
                "Throttled",
                "p95 stretch",
                "Killed",
                "Requeued",
                "Wasted [nh]",
                "Goodput",
                "Skipped",
                "Elided",
                "Forks",
                "Restores",
            ],
        );
        for s in &self.stats {
            t.row(vec![
                s.mix.clone(),
                s.seed.to_string(),
                cap_label(s.cap_mw),
                s.policy.name().to_string(),
                s.faults.clone(),
                s.jobs.to_string(),
                f2(s.makespan_h),
                f1(s.mean_wait_min),
                f1(s.p95_wait_min),
                f2(s.utilization),
                f2(s.peak_mw),
                f2(s.energy_mwh),
                s.throttled.to_string(),
                f2(s.p95_stretch),
                s.killed.to_string(),
                s.requeued.to_string(),
                f2(s.wasted_node_h),
                f2(s.goodput),
                s.events_skipped.to_string(),
                s.retimes_elided.to_string(),
                s.forks.to_string(),
                s.restores.to_string(),
            ]);
        }
        t
    }

    /// Aggregate percentiles of the headline metrics across scenarios.
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Campaign summary — {} scenarios (percentiles across the grid)",
                self.stats.len()
            ),
            &["Metric", "min", "p50", "p95", "max", "Unit"],
        );
        let mut metric = |name: &str, unit: &str, pick: &dyn Fn(&ScenarioStats) -> f64| {
            let mut vals: Vec<f64> = self.stats.iter().map(pick).collect();
            vals.sort_by(f64::total_cmp);
            t.row(vec![
                name.to_string(),
                f2(percentile(&vals, 0.0)),
                f2(percentile(&vals, 0.5)),
                f2(percentile(&vals, 0.95)),
                f2(percentile(&vals, 1.0)),
                unit.to_string(),
            ]);
        };
        metric("mean wait", "min", &|s| s.mean_wait_min);
        metric("p95 wait", "min", &|s| s.p95_wait_min);
        metric("utilization", "of nodes", &|s| s.utilization);
        metric("facility energy", "MWh", &|s| s.energy_mwh);
        metric("peak facility power", "MW", &|s| s.peak_mw);
        metric("peak congestion", "link load", &|s| s.peak_congestion);
        metric("peak link util", "bundle load", &|s| s.peak_link_util);
        metric("mean link util", "bundle load", &|s| s.mean_link_util);
        metric("mean stretch", "x nominal", &|s| s.mean_stretch);
        metric("p95 stretch", "x nominal", &|s| s.p95_stretch);
        metric("jobs killed", "fault kills", &|s| s.killed as f64);
        metric("jobs requeued", "checkpointed kills", &|s| s.requeued as f64);
        metric("wasted node-hours", "node-h destroyed", &|s| s.wasted_node_h);
        metric("goodput", "useful fraction", &|s| s.goodput);
        metric("p95 recovery stretch", "x nominal", &|s| s.p95_recovery_stretch);
        metric("stale events skipped", "re-timed Ends", &|s| s.events_skipped as f64);
        metric("re-times elided", "walks avoided", &|s| s.retimes_elided as f64);
        metric("prefix forks", "shared prefixes", &|s| s.forks as f64);
        metric("snapshot restores", "suffix replays", &|s| s.restores as f64);
        t
    }

    /// Cap-sensitivity curve: metrics averaged over seeds and mixes per
    /// cap level, in first-appearance (grid) order.
    pub fn cap_table(&self) -> Table {
        let mut t = Table::new(
            "Cap sensitivity — means over seeds and mixes per cap level",
            &[
                "Cap",
                "Scenarios",
                "Mean wait [min]",
                "p95 wait [min]",
                "Util",
                "Energy [MWh]",
                "Throttled jobs",
                "Mean stretch",
            ],
        );
        let mut caps: Vec<Option<f64>> = Vec::new();
        for s in &self.stats {
            if !caps.contains(&s.cap_mw) {
                caps.push(s.cap_mw);
            }
        }
        for cap in caps {
            let group: Vec<&ScenarioStats> =
                self.stats.iter().filter(|s| s.cap_mw == cap).collect();
            let n = group.len() as f64;
            let mean = |pick: &dyn Fn(&ScenarioStats) -> f64| {
                group.iter().copied().map(pick).sum::<f64>() / n
            };
            t.row(vec![
                cap_label(cap),
                group.len().to_string(),
                f1(mean(&|s| s.mean_wait_min)),
                f1(mean(&|s| s.p95_wait_min)),
                f2(mean(&|s| s.utilization)),
                f2(mean(&|s| s.energy_mwh)),
                group.iter().map(|s| s.throttled).sum::<usize>().to_string(),
                f2(mean(&|s| s.mean_stretch)),
            ]);
        }
        t
    }

    /// Policy comparison: metrics averaged over seeds, caps and mixes
    /// per placement policy, in first-appearance (grid) order — the row
    /// pair that scores [`crate::scheduler::SpreadLinks`] against
    /// [`crate::scheduler::PackFirst`] on the same scenarios.
    pub fn policy_table(&self) -> Table {
        let mut t = Table::new(
            "Placement policies — means over seeds, caps and mixes per policy",
            &[
                "Policy",
                "Scenarios",
                "Mean wait [min]",
                "p95 wait [min]",
                "Util",
                "Mean stretch",
                "p95 stretch",
                "Peak link util",
                "Mean link util",
                "Goodput",
                "Wasted [nh]",
            ],
        );
        let mut policies: Vec<PolicyKind> = Vec::new();
        for s in &self.stats {
            if !policies.contains(&s.policy) {
                policies.push(s.policy);
            }
        }
        for policy in policies {
            let group: Vec<&ScenarioStats> =
                self.stats.iter().filter(|s| s.policy == policy).collect();
            let n = group.len() as f64;
            let mean = |pick: &dyn Fn(&ScenarioStats) -> f64| {
                group.iter().copied().map(pick).sum::<f64>() / n
            };
            t.row(vec![
                policy.name().to_string(),
                group.len().to_string(),
                f1(mean(&|s| s.mean_wait_min)),
                f1(mean(&|s| s.p95_wait_min)),
                f2(mean(&|s| s.utilization)),
                f2(mean(&|s| s.mean_stretch)),
                f2(mean(&|s| s.p95_stretch)),
                f2(mean(&|s| s.peak_link_util)),
                f2(mean(&|s| s.mean_link_util)),
                f2(mean(&|s| s.goodput)),
                f2(mean(&|s| s.wasted_node_h)),
            ]);
        }
        t
    }
}

// ---------------------------------------------------------------------
// CLI boundary: `sweep`/`operations` flag parsing. Malformed input must
// come back as an `anyhow` error the CLI can print (exit 2), never a
// panic inside a worker.
// ---------------------------------------------------------------------

/// First-appearance dedup shared by the grid-axis parsers: a repeated
/// `--caps`/`--mixes`/`--policy` value cannot silently multiply the
/// grid with identical scenarios.
fn dedup_first<T: PartialEq>(items: Vec<T>) -> Vec<T> {
    let mut out: Vec<T> = Vec::new();
    for item in items {
        if !out.contains(&item) {
            out.push(item);
        }
    }
    out
}

/// Parse a `--caps` list: comma-separated MW levels, with
/// `none`/`off`/`uncapped` lifting the cap for that grid level.
/// Duplicate levels are collapsed (first appearance wins).
pub fn parse_caps(list: &str) -> Result<Vec<Option<f64>>> {
    let parsed: Vec<Option<f64>> = list
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| match s.to_ascii_lowercase().as_str() {
            "none" | "off" | "uncapped" => Ok(None),
            _ => s
                .parse::<f64>()
                .map(Some)
                .map_err(|e| anyhow!("--caps '{s}': {e}")),
        })
        .collect::<Result<_>>()?;
    let caps = dedup_first(parsed);
    ensure!(!caps.is_empty(), "--caps needs at least one level");
    // Non-finite or non-positive levels are rejected again by
    // `SweepGrid::new`; catching them here gives the flag-shaped error.
    for cap in caps.iter().flatten() {
        ensure!(
            cap.is_finite() && *cap > 0.0,
            "--caps level {cap} MW must be finite and positive"
        );
    }
    Ok(caps)
}

/// Parse a `--mixes` list: comma-separated [`TraceGen::named`] names.
/// Duplicates are collapsed (first appearance wins).
pub fn parse_mixes(list: &str) -> Result<Vec<String>> {
    let parsed: Vec<String> = list
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let mixes = dedup_first(parsed);
    ensure!(!mixes.is_empty(), "--mixes needs at least one mix");
    for mix in &mixes {
        ensure!(
            TraceGen::named(mix, 1, 0).is_some(),
            "--mixes: unknown mix '{mix}' (known: {})",
            TraceGen::known_mixes().join(", ")
        );
    }
    Ok(mixes)
}

/// Resolve a `--threads` flag: `None` means all available cores, and an
/// explicit 0 is an error rather than a silent clamp.
pub fn parse_threads(threads: Option<usize>) -> Result<usize> {
    match threads {
        Some(0) => Err(anyhow!("--threads 0: need at least one worker thread")),
        Some(t) => Ok(t),
        None => Ok(std::thread::available_parallelism().map_or(1, |n| n.get())),
    }
}

/// Resolve a distributed-service worker-count flag (`--workers`,
/// `--expect`): an explicit 0 is an error rather than a silent clamp,
/// an absent flag stays absent for the caller's default to apply.
pub fn parse_workers(flag: &str, value: Option<usize>) -> Result<Option<usize>> {
    match value {
        Some(0) => Err(anyhow!("{flag} 0: need at least one worker")),
        other => Ok(other),
    }
}

/// Parse a `--routing` flag into a [`crate::topology::Routing`] policy.
pub fn parse_routing(name: &str) -> Result<crate::topology::Routing> {
    match name.to_ascii_lowercase().as_str() {
        "minimal" => Ok(crate::topology::Routing::Minimal),
        "valiant" => Ok(crate::topology::Routing::Valiant),
        "adaptive" => Ok(crate::topology::Routing::Adaptive),
        other => Err(anyhow!(
            "--routing '{other}': expected minimal, valiant or adaptive"
        )),
    }
}

/// Parse a `--policy` list: comma-separated placement policies
/// (`pack` = the seed's fullest-first packing, `spread` = link-aware
/// anti-fragmentation). More than one value turns the sweep's policy
/// axis on; duplicates are collapsed (first appearance wins).
pub fn parse_policies(list: &str) -> Result<Vec<PolicyKind>> {
    let parsed: Vec<PolicyKind> = list
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| match s.to_ascii_lowercase().as_str() {
            "packfirst" => Ok(PolicyKind::PackFirst),
            "spreadlinks" => Ok(PolicyKind::SpreadLinks),
            other => PolicyKind::from_name(other)
                .map_err(|_| anyhow!("--policy '{other}': expected pack or spread")),
        })
        .collect::<Result<_>>()?;
    let policies = dedup_first(parsed);
    ensure!(!policies.is_empty(), "--policy needs at least one policy");
    Ok(policies)
}

/// Parse a `--faults` spec into a [`FaultTrace`]: `none` (the
/// fault-free trace), or comma-separated `key:value` pairs —
/// `mtbf:SECS` (per-node MTBF; arms node failures), `repair:SECS`
/// (mean node-group repair time, default 7200), `group:N` (nodes
/// downed per failure, default 18), `linkmtbf:SECS` (per-bundle MTBF;
/// arms link degradations), `linkrepair:SECS` (mean episode length,
/// default 3600), `factor:F` (degraded capacity factor in (0, 1],
/// default 0.5), `dur:SECS` (failure-arrival window, default 86400)
/// and `seed:N` (default 1). At least one of `mtbf`/`linkmtbf` must be
/// given — a spec that arms no failure process is a typo, not a quiet
/// no-op.
pub fn parse_faults(spec: &str) -> Result<FaultTrace> {
    if spec.trim().eq_ignore_ascii_case("none") {
        return Ok(FaultTrace::none());
    }
    let mut ft = FaultTrace {
        seed: 1,
        duration_s: 86_400.0,
        node_mtbf_s: 0.0,
        repair_mean_s: 7_200.0,
        group: 18,
        link_mtbf_s: 0.0,
        link_repair_mean_s: 3_600.0,
        degraded_factor: 0.5,
    };
    for pair in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (key, value) = pair
            .split_once(':')
            .ok_or_else(|| anyhow!("--faults '{pair}': expected key:value"))?;
        let key = key.trim().to_ascii_lowercase();
        let value = value.trim();
        let secs = |name: &str| -> Result<f64> {
            let v: f64 = value
                .parse()
                .map_err(|e| anyhow!("--faults {name}:'{value}': {e}"))?;
            ensure!(
                v.is_finite() && v > 0.0,
                "--faults {name}:{value}: must be finite and positive"
            );
            Ok(v)
        };
        match key.as_str() {
            "mtbf" => ft.node_mtbf_s = secs("mtbf")?,
            "repair" => ft.repair_mean_s = secs("repair")?,
            "group" => {
                let v: u32 = value
                    .parse()
                    .map_err(|e| anyhow!("--faults group:'{value}': {e}"))?;
                ensure!(v >= 1, "--faults group:{value}: need at least one node");
                ft.group = v;
            }
            "linkmtbf" => ft.link_mtbf_s = secs("linkmtbf")?,
            "linkrepair" => ft.link_repair_mean_s = secs("linkrepair")?,
            "factor" => {
                let v = secs("factor")?;
                ensure!(v <= 1.0, "--faults factor:{value}: must be in (0, 1]");
                ft.degraded_factor = v;
            }
            "dur" => ft.duration_s = secs("dur")?,
            "seed" => {
                ft.seed = value
                    .parse()
                    .map_err(|e| anyhow!("--faults seed:'{value}': {e}"))?;
            }
            other => {
                return Err(anyhow!(
                    "--faults: unknown key '{other}' (known: mtbf, repair, group, \
                     linkmtbf, linkrepair, factor, dur, seed)"
                ))
            }
        }
    }
    ensure!(
        !ft.is_none(),
        "--faults '{spec}': arms no failure process (set mtbf: and/or linkmtbf:, or use 'none')"
    );
    Ok(ft)
}

/// Parse a `--checkpoint` flag into the [`CheckpointPolicy`] forced on
/// every generated job: `none` disables checkpointing (a fault kill
/// repeats everything), a positive interval in seconds checkpoints
/// periodically (a kill repeats at most one interval of work). The
/// flag's absence — not this parser — keeps the per-app-class defaults.
pub fn parse_checkpoint(spec: &str) -> Result<CheckpointPolicy> {
    if spec.trim().eq_ignore_ascii_case("none") {
        return Ok(CheckpointPolicy::None);
    }
    let secs: f64 = spec
        .trim()
        .parse()
        .map_err(|e| anyhow!("--checkpoint '{spec}': {e}"))?;
    ensure!(
        secs.is_finite() && secs > 0.0,
        "--checkpoint {spec}: interval must be finite and positive seconds"
    );
    Ok(CheckpointPolicy::Periodic(secs))
}

/// Fan the grid across `threads` workers with `std::thread::scope`,
/// joining all workers before merging (the PR 2/3 shape: each worker
/// buffers its results and the merge happens after the join, and every
/// scenario pays a fresh [`ReplayRig`]). Retained as the cost-faithful
/// baseline and identity oracle for [`run_sweep_streaming`] — both
/// produce byte-identical reports.
///
/// Work distribution is an atomic cursor (cheap work stealing — long
/// scenarios don't convoy short ones); each worker owns its scheduler
/// and observers and shares only the read-only `twin`. Results carry
/// their grid index and are merged in index order after the join, so
/// the report does not depend on `threads` or on OS scheduling.
pub fn run_sweep(twin: &Twin, grid: &SweepGrid, threads: usize) -> CampaignReport {
    let scenarios = grid.scenarios();
    let workers = threads.clamp(1, scenarios.len().max(1));
    let next = AtomicUsize::new(0);
    let mut indexed: Vec<(usize, ScenarioStats)> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let next = &next;
            let scenarios = &scenarios;
            handles.push(s.spawn(move || {
                let mut done: Vec<(usize, ScenarioStats)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= scenarios.len() {
                        break;
                    }
                    done.push((i, run_scenario(twin, &scenarios[i])));
                }
                done
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("campaign worker panicked"))
            .collect()
    });
    indexed.sort_by_key(|&(i, _)| i);
    CampaignReport {
        stats: indexed.into_iter().map(|(_, s)| s).collect(),
    }
}

/// Streaming sweep: the production engine. Workers own a persistent
/// scenario arena ([`run_scenario_arena`] — one [`ReplayRig`] reset per
/// scenario instead of rebuilt) and send `(grid index, stats)` over an
/// `std::sync::mpsc` channel the moment each scenario finishes, so the
/// merged report fills in while slower scenarios are still running —
/// no join barrier, no per-worker result buffers.
///
/// The merge is by grid index into a pre-sized slot table, so the
/// report is byte-identical to [`run_sweep`]'s for any thread count and
/// any completion order (pinned by `rust/tests/campaign_sweep.rs`).
pub fn run_sweep_streaming(twin: &Twin, grid: &SweepGrid, threads: usize) -> CampaignReport {
    let scenarios = grid.scenarios();
    let workers = threads.clamp(1, scenarios.len().max(1));
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<ScenarioStats>> = vec![None; scenarios.len()];
    std::thread::scope(|s| {
        let (tx, rx) = std::sync::mpsc::channel::<(usize, ScenarioStats)>();
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let scenarios = &scenarios;
            s.spawn(move || {
                let mut arena: Option<ReplayRig> = None;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= scenarios.len() {
                        break;
                    }
                    let stats = run_scenario_arena(&mut arena, twin, &scenarios[i]);
                    if tx.send((i, stats)).is_err() {
                        break; // receiver gone: the scope is unwinding
                    }
                }
            });
        }
        // The workers hold the only remaining senders: the receive loop
        // ends exactly when the last worker finishes its last scenario.
        drop(tx);
        for (i, stats) in rx {
            slots[i] = Some(stats);
        }
    });
    CampaignReport {
        stats: slots
            .into_iter()
            .map(|s| s.expect("worker died before streaming its scenario"))
            .collect(),
    }
}

/// Divergence-tree sweep: the streaming engine's fan-out with fork
/// groups as the unit of work. Each worker pulls a [`SweepGrid::fork_groups`]
/// group off the atomic cursor, simulates the shared prefix once on its
/// arena, and streams each member's `(grid index, stats)` as its suffix
/// finishes — the same pre-sized slot merge as [`run_sweep_streaming`],
/// so completion order and thread count stay invisible.
///
/// Reports are byte-identical to [`run_sweep_streaming`]'s for any
/// thread count, modulo the `forks`/`restores` bookkeeping (zeroed by
/// [`CampaignReport::with_fork_counters_zeroed`], which is how the
/// identity test compares them). On an all-divergent grid
/// (`cap_time == 0` or a single cap level) every group is a singleton
/// and this *is* plain streaming, zero forks paid.
pub fn run_sweep_forked(twin: &Twin, grid: &SweepGrid, threads: usize) -> CampaignReport {
    let scenarios = grid.scenarios();
    let groups = grid.fork_groups();
    let workers = threads.clamp(1, groups.len().max(1));
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<ScenarioStats>> = vec![None; scenarios.len()];
    std::thread::scope(|s| {
        let (tx, rx) = std::sync::mpsc::channel::<(usize, ScenarioStats)>();
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let scenarios = &scenarios;
            let groups = &groups;
            s.spawn(move || {
                let mut arena: Option<ReplayRig> = None;
                loop {
                    let g = next.fetch_add(1, Ordering::Relaxed);
                    if g >= groups.len() {
                        break;
                    }
                    for (i, stats) in replay_group(&mut arena, twin, scenarios, &groups[g]) {
                        if tx.send((i, stats)).is_err() {
                            return; // receiver gone: the scope is unwinding
                        }
                    }
                }
            });
        }
        drop(tx);
        for (i, stats) in rx {
            slots[i] = Some(stats);
        }
    });
    CampaignReport {
        stats: slots
            .into_iter()
            .map(|s| s.expect("worker died before streaming its scenario"))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_grid() -> SweepGrid {
        SweepGrid::new(
            vec![1, 2],
            vec![None, Some(5.5)],
            vec!["day".into()],
            60,
        )
        .unwrap()
    }

    #[test]
    fn grid_expands_in_mix_cap_seed_order() {
        let g = SweepGrid::new(
            vec![7, 8],
            vec![None, Some(6.0)],
            vec!["day".into(), "ai".into()],
            10,
        )
        .unwrap();
        assert_eq!(g.len(), 8);
        let sc = g.scenarios();
        assert_eq!(sc.len(), 8);
        assert_eq!((sc[0].mix.as_str(), sc[0].cap_mw, sc[0].seed), ("day", None, 7));
        assert_eq!((sc[1].mix.as_str(), sc[1].cap_mw, sc[1].seed), ("day", None, 8));
        assert_eq!(sc[2].cap_mw, Some(6.0));
        assert_eq!(sc[4].mix, "ai");
        assert_eq!(sc[7].label(), "ai seed=8 cap 6.0 MW pack");
    }

    #[test]
    fn grid_rejects_bad_input() {
        assert!(SweepGrid::new(vec![], vec![None], vec!["day".into()], 10).is_err());
        assert!(SweepGrid::new(vec![1], vec![], vec!["day".into()], 10).is_err());
        assert!(SweepGrid::new(vec![1], vec![None], vec![], 10).is_err());
        assert!(SweepGrid::new(vec![1], vec![None], vec!["day".into()], 0).is_err());
        assert!(
            SweepGrid::new(vec![1], vec![Some(f64::NAN)], vec!["day".into()], 10).is_err()
        );
        assert!(
            SweepGrid::new(vec![1], vec![Some(-2.0)], vec!["day".into()], 10).is_err()
        );
        let err = SweepGrid::new(vec![1], vec![None], vec!["nope".into()], 10)
            .unwrap_err();
        assert!(format!("{err}").contains("unknown mix"), "{err}");
    }

    #[test]
    fn single_scenario_matches_direct_replay() {
        let twin = Twin::leonardo();
        let grid =
            SweepGrid::new(vec![3], vec![Some(6.0)], vec!["day".into()], 80).unwrap();
        let report = run_sweep(&twin, &grid, 1);
        assert_eq!(report.stats.len(), 1);
        let direct = run_scenario(&twin, &grid.scenarios()[0]);
        assert_eq!(report.stats[0], direct);
        assert_eq!(direct.jobs, 80);
        assert!(direct.makespan_h > 0.0);
        assert!(direct.energy_mwh > 0.0);
        assert!(direct.utilization > 0.0 && direct.utilization <= 1.0 + 1e-9);
    }

    #[test]
    fn sweep_is_thread_count_independent() {
        let twin = Twin::leonardo();
        let grid = small_grid();
        let one = run_sweep(&twin, &grid, 1);
        let two = run_sweep(&twin, &grid, 2);
        let many = run_sweep(&twin, &grid, 16);
        assert_eq!(one, two);
        assert_eq!(one, many);
        assert_eq!(one.stats.len(), 4);
    }

    #[test]
    fn tight_cap_throttles_and_report_tables_render() {
        let twin = Twin::leonardo();
        // 1.0 MW sits below the fleet's idle floor (~1.26 MW), so every
        // start sees the cap exceeded — throttling is guaranteed, not
        // load-dependent.
        let grid = SweepGrid::new(
            vec![1, 2],
            vec![None, Some(1.0)],
            vec!["day".into()],
            150,
        )
        .unwrap();
        let report = run_sweep(&twin, &grid, 4);
        let uncapped: usize = report
            .stats
            .iter()
            .filter(|s| s.cap_mw.is_none())
            .map(|s| s.throttled)
            .sum();
        let capped: usize = report
            .stats
            .iter()
            .filter(|s| s.cap_mw.is_some())
            .map(|s| s.throttled)
            .sum();
        assert_eq!(uncapped, 0, "no cap, no throttling");
        assert!(capped > 0, "a sub-idle-floor cap must throttle every job");
        let t = report.scenario_table();
        assert_eq!(t.rows.len(), 4);
        let caps = report.cap_table();
        assert_eq!(caps.rows.len(), 2);
        let summary = report.summary_table();
        assert_eq!(summary.rows.len(), 19);
        // Sub-idle-floor capping forces every job onto the 0.5 DVFS
        // floor: clock-bound work stretches, and the stretch percentiles
        // surface it.
        let capped_stretch = report
            .stats
            .iter()
            .filter(|s| s.cap_mw.is_some())
            .map(|s| s.mean_stretch)
            .fold(0.0f64, f64::max);
        assert!(capped_stretch > 1.0, "{capped_stretch}");
    }

    #[test]
    fn coupled_grid_propagates_to_scenarios_and_changes_outcomes() {
        let twin = Twin::leonardo();
        // The hpc mix's capability heroes (128-256 nodes) span cells, so
        // a day this size reliably contains comm-bound multi-cell jobs.
        let grid = SweepGrid::new(vec![3], vec![None], vec!["hpc".into()], 800)
            .unwrap()
            .with_coupling(Coupling::full());
        assert!(grid.scenarios().iter().all(|s| s.coupling == Coupling::full()));
        let coupled = run_sweep(&twin, &grid, 2);
        let mut plain_grid = grid.clone();
        plain_grid.coupling = Coupling::default();
        let plain = run_sweep(&twin, &plain_grid, 2);
        // Uncoupled days never stretch without a cap; coupled days do
        // (comm-bound multi-cell capability jobs).
        assert!(plain.stats[0].mean_stretch <= 1.0 + 1e-9);
        assert!(
            coupled.stats[0].mean_stretch > plain.stats[0].mean_stretch,
            "{} vs {}",
            coupled.stats[0].mean_stretch,
            plain.stats[0].mean_stretch
        );
    }

    /// The streaming engine (arena rigs + mpsc merge) is byte-identical
    /// to the retained join-then-merge path for any thread count.
    #[test]
    fn streaming_sweep_matches_join_then_merge() {
        let twin = Twin::leonardo();
        for coupling in [Coupling::default(), Coupling::full()] {
            let grid = small_grid().with_coupling(coupling);
            let joined = run_sweep(&twin, &grid, 2);
            for threads in [1, 2, 8] {
                let streamed = run_sweep_streaming(&twin, &grid, threads);
                assert_eq!(
                    joined, streamed,
                    "streaming vs join-then-merge diverged (coupled={}, {threads} threads)",
                    coupling.enabled()
                );
            }
        }
    }

    /// A reset arena rig replays bit-identically to a fresh rig, across
    /// partition/cap/coupling changes between scenarios — and the
    /// arena's event queue keeps its heap allocation across resets
    /// (reuse means no per-scenario reallocation ramp).
    #[test]
    fn arena_reset_matches_fresh_rig() {
        let twin = Twin::leonardo();
        let grid = SweepGrid::new(
            vec![5, 6],
            vec![None, Some(6.0)],
            vec!["day".into(), "hpc".into()],
            60,
        )
        .unwrap()
        .with_coupling(Coupling::full());
        let mut arena: Option<ReplayRig> = None;
        let mut cap_after_first = 0;
        for (k, sc) in grid.scenarios().iter().enumerate() {
            let fresh = run_scenario(&twin, sc);
            let reused = run_scenario_arena(&mut arena, &twin, sc);
            assert_eq!(fresh, reused, "arena drift on {}", sc.label());
            let cap = arena.as_ref().unwrap().sim.queue.capacity();
            if k == 0 {
                cap_after_first = cap;
                assert!(cap > 0, "first replay left no queue allocation");
            } else {
                assert!(
                    cap >= cap_after_first,
                    "arena reset shed the queue allocation ({cap} < {cap_after_first})"
                );
            }
        }
    }

    /// The counters surface in the report tables: per-scenario columns
    /// and aggregate rows, formatted as plain integers.
    #[test]
    fn counter_columns_render_in_tables() {
        let mut s = ScenarioStats::collect(
            &[crate::scheduler::Job {
                id: 1,
                partition: Partition::Booster,
                nodes: 10,
                est_seconds: 10.0,
                run_seconds: 10.0,
                submit_time: 0.0,
                boundness: 1.0,
                comm_fraction: 0.0,
                checkpoint: crate::scheduler::CheckpointPolicy::None,
            }],
            &{
                let mut m = BTreeMap::new();
                m.insert(
                    1,
                    JobRecord {
                        id: 1,
                        start_time: 0.0,
                        end_time: 10.0,
                        placement: crate::network::Placement {
                            nodes_per_cell: vec![(0, 10)],
                        },
                        dvfs_scale: 1.0,
                        min_dvfs_scale: 1.0,
                    },
                );
                m
            },
            3456,
            &PowerMonitor::new(
                crate::power::PowerModel::new(crate::hardware::NodeSpec::davinci(), 1.1),
                Utilization::hpl(),
                3456,
            ),
            &CongestionTracker::new([(0, 180)]),
        );
        s.mix = "day".into();
        s.faults = "mtbf250k".into();
        s.events_skipped = 42;
        s.retimes_elided = 1337;
        s.forks = 7;
        s.restores = 3;
        s.killed = 11;
        s.requeued = 9;
        s.wasted_node_h = 4.25;
        s.goodput = 0.97;
        s.p95_recovery_stretch = 2.5;
        let report = CampaignReport { stats: vec![s] };
        let t = report.scenario_table();
        assert_eq!(t.headers[t.headers.len() - 4], "Skipped");
        assert_eq!(t.headers[t.headers.len() - 3], "Elided");
        assert_eq!(t.headers[t.headers.len() - 2], "Forks");
        assert_eq!(t.headers[t.headers.len() - 1], "Restores");
        assert_eq!(t.headers[t.headers.len() - 8], "Killed");
        assert_eq!(t.headers[t.headers.len() - 7], "Requeued");
        assert_eq!(t.headers[t.headers.len() - 6], "Wasted [nh]");
        assert_eq!(t.headers[t.headers.len() - 5], "Goodput");
        assert_eq!(t.headers[4], "Faults");
        let row = &t.rows[0];
        assert_eq!(row[4], "mtbf250k");
        assert_eq!(row[row.len() - 4], "42");
        assert_eq!(row[row.len() - 3], "1337");
        assert_eq!(row[row.len() - 2], "7");
        assert_eq!(row[row.len() - 1], "3");
        assert_eq!(row[row.len() - 8], "11");
        assert_eq!(row[row.len() - 7], "9");
        assert_eq!(row[row.len() - 6], "4.25");
        assert_eq!(row[row.len() - 5], "0.97");
        let summary = report.summary_table();
        let md = summary.to_markdown();
        assert!(md.contains("stale events skipped"), "{md}");
        assert!(md.contains("re-times elided"), "{md}");
        assert!(md.contains("prefix forks"), "{md}");
        assert!(md.contains("snapshot restores"), "{md}");
        assert!(md.contains("jobs killed"), "{md}");
        assert!(md.contains("jobs requeued"), "{md}");
        assert!(md.contains("wasted node-hours"), "{md}");
        assert!(md.contains("goodput"), "{md}");
        assert!(md.contains("p95 recovery stretch"), "{md}");
        assert!(md.contains("42"), "{md}");
        assert!(md.contains("1337"), "{md}");
        // Zeroing the fork bookkeeping touches nothing else.
        let zeroed = report.with_fork_counters_zeroed();
        assert_eq!(zeroed.stats[0].forks, 0);
        assert_eq!(zeroed.stats[0].restores, 0);
        assert_eq!(zeroed.stats[0].events_skipped, 42);
        assert_eq!(zeroed.stats[0].killed, 11, "fork zeroing keeps fault counters");
        // Zeroing the fault counters resets the robustness metrics to
        // their fault-free values and touches nothing else.
        let fz = report.with_fault_counters_zeroed();
        assert_eq!(fz.stats[0].killed, 0);
        assert_eq!(fz.stats[0].requeued, 0);
        assert_eq!(fz.stats[0].wasted_node_h, 0.0);
        assert_eq!(fz.stats[0].goodput, 1.0);
        assert_eq!(fz.stats[0].p95_recovery_stretch, 0.0);
        assert_eq!(fz.stats[0].forks, 7, "fault zeroing keeps fork counters");
    }

    /// Satellite: fork grouping is pinned to the canonical expansion —
    /// members of a group differ only in cap, groups cover the grid
    /// exactly once, and degenerate grids fall back to all-singletons.
    #[test]
    fn fork_groups_are_canonical_and_degenerate_grids_fall_back() {
        let g = SweepGrid::new(
            vec![7, 8],
            vec![None, Some(6.0), Some(5.0)],
            vec!["day".into(), "ai".into()],
            10,
        )
        .unwrap()
        .with_cap_time(3600.0);
        let groups = g.fork_groups();
        let sc = g.scenarios();
        // One group per (policy, mix, seed); members in cap order.
        assert_eq!(groups.len(), 4);
        assert_eq!(groups[0], vec![0, 2, 4]);
        assert_eq!(groups[1], vec![1, 3, 5]);
        assert_eq!(groups[2], vec![6, 8, 10]);
        let mut seen: Vec<usize> = groups.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..g.len()).collect::<Vec<_>>(), "exact cover");
        for group in &groups {
            let first = &sc[group[0]];
            let caps: Vec<Option<f64>> = group.iter().map(|&i| sc[i].cap_mw).collect();
            assert_eq!(caps, g.caps, "members walk the cap axis in order");
            for &i in group {
                assert_eq!(sc[i].mix, first.mix, "mix shared within a group");
                assert_eq!(sc[i].seed, first.seed, "seed shared within a group");
                assert_eq!(sc[i].policy, first.policy, "policy shared within a group");
            }
        }
        // Degenerate: no deferred cap → every scenario is its own group.
        let plain = g.clone().with_cap_time(0.0);
        assert!(plain.fork_groups().iter().all(|grp| grp.len() == 1));
        assert_eq!(plain.fork_groups().len(), plain.len());
        // Degenerate: a single-cap (e.g. seed-axis) grid groups to
        // singletons even with a deferred cap.
        let seed_axis = SweepGrid::new(vec![1, 2, 3], vec![Some(6.0)], vec!["day".into()], 10)
            .unwrap()
            .with_cap_time(3600.0);
        assert!(seed_axis.fork_groups().iter().all(|grp| grp.len() == 1));
    }

    /// A deferred cap changes scenario semantics (the day starts
    /// uncapped), and the armed-infinite-cap prefix is bit-identical to
    /// a genuinely capless day.
    #[test]
    fn deferred_cap_arms_infinite_and_injects_cap_change() {
        let twin = Twin::leonardo();
        let g = small_grid().with_cap_time(7200.0);
        let sc = g.scenarios();
        assert!(sc.iter().all(|s| s.armed_cap() == Some(f64::INFINITY)));
        let uncapped = &sc[0];
        assert!(uncapped.cap_mw.is_none() && uncapped.extra_events(&twin.cfg).is_empty());
        let capped = sc.iter().find(|s| s.cap_mw.is_some()).unwrap();
        let evs = capped.extra_events(&twin.cfg);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].time, 7200.0);
        // An armed-but-infinite cap day is bit-identical to a capless
        // day: the cap-free scenario of a deferred grid replays exactly
        // like the same scenario of a plain grid.
        let plain = run_scenario(&twin, &small_grid().scenarios()[0]);
        let deferred = run_scenario(&twin, uncapped);
        assert_eq!(plain, deferred);
    }

    /// The divergence-tree engine is byte-identical to streaming for
    /// any thread count, modulo the fork bookkeeping — uncoupled and
    /// fully coupled, with the deferred cap landing mid-day.
    #[test]
    fn forked_sweep_matches_streaming_modulo_fork_counters() {
        let twin = Twin::leonardo();
        for coupling in [Coupling::default(), Coupling::full()] {
            let grid = small_grid().with_coupling(coupling).with_cap_time(7200.0);
            let streamed = run_sweep_streaming(&twin, &grid, 2);
            for threads in [1, 2, 8] {
                let forked = run_sweep_forked(&twin, &grid, threads);
                assert_eq!(
                    streamed,
                    forked.with_fork_counters_zeroed(),
                    "forked vs streaming diverged (coupled={}, {threads} threads)",
                    coupling.enabled()
                );
                // Two caps per (seed): every scenario rode a fork, and
                // exactly the non-first group members paid a restore.
                assert!(forked.stats.iter().all(|s| s.forks == 1));
                let restores: u64 = forked.stats.iter().map(|s| s.restores).sum();
                assert_eq!(restores, grid.len() as u64 / grid.caps.len() as u64);
            }
        }
        // All-divergent grid: forked IS streaming, fork counters zero.
        let plain = small_grid();
        let forked = run_sweep_forked(&twin, &plain, 2);
        assert_eq!(forked, run_sweep_streaming(&twin, &plain, 2));
        assert!(forked.stats.iter().all(|s| s.forks == 0 && s.restores == 0));
    }

    #[test]
    fn cli_parsers_reject_malformed_input() {
        // Caps: floats with none/off/uncapped sentinels; duplicates
        // collapse.
        assert_eq!(parse_caps("none,7.5").unwrap(), vec![None, Some(7.5)]);
        assert_eq!(parse_caps("7.5,7.5,none").unwrap(), vec![Some(7.5), None]);
        assert!(parse_caps("7.5,oops").is_err());
        assert!(parse_caps("").is_err());
        assert!(parse_caps("-3.0").is_err());
        assert!(parse_caps("nan").is_err());
        // Mixes: validated against TraceGen's registry; duplicates
        // collapse.
        assert_eq!(parse_mixes(" day , ai ").unwrap(), vec!["day", "ai"]);
        assert_eq!(parse_mixes("day,day,ai").unwrap(), vec!["day", "ai"]);
        assert!(parse_mixes("day,bogus").is_err());
        assert!(parse_mixes(",").is_err());
        // Threads: 0 is an error, None resolves to the core count.
        assert!(parse_threads(Some(0)).is_err());
        assert_eq!(parse_threads(Some(3)).unwrap(), 3);
        assert!(parse_threads(None).unwrap() >= 1);
        // Distributed worker counts: 0 is an error, absent stays
        // absent so the caller's default applies.
        let err = parse_workers("--workers", Some(0)).unwrap_err();
        assert!(err.to_string().contains("--workers 0"));
        assert_eq!(parse_workers("--expect", Some(2)).unwrap(), Some(2));
        assert_eq!(parse_workers("--workers", None).unwrap(), None);
        // Routing policies.
        assert!(matches!(parse_routing("valiant"), Ok(crate::topology::Routing::Valiant)));
        assert!(matches!(parse_routing("MINIMAL"), Ok(crate::topology::Routing::Minimal)));
        assert!(matches!(
            parse_routing("adaptive"),
            Ok(crate::topology::Routing::Adaptive)
        ));
        assert!(parse_routing("random").is_err());
        // Placement policies.
        assert_eq!(
            parse_policies("pack,spread").unwrap(),
            vec![PolicyKind::PackFirst, PolicyKind::SpreadLinks]
        );
        assert_eq!(parse_policies(" SPREAD ").unwrap(), vec![PolicyKind::SpreadLinks]);
        assert_eq!(
            parse_policies("pack,pack,spread").unwrap(),
            vec![PolicyKind::PackFirst, PolicyKind::SpreadLinks],
            "duplicates must collapse"
        );
        assert!(parse_policies("pack,bogus").is_err());
        assert!(parse_policies("").is_err());
    }

    /// The policy axis expands the grid, shows up in the report tables,
    /// and PackFirst rows are bit-identical to a policy-less grid.
    #[test]
    fn policy_axis_expands_and_reports() {
        let twin = Twin::leonardo();
        let base = SweepGrid::new(vec![1, 2], vec![None], vec!["day".into()], 60).unwrap();
        let both = base
            .clone()
            .with_policies(vec![PolicyKind::PackFirst, PolicyKind::SpreadLinks]);
        assert_eq!(both.len(), 2 * base.len());
        let sc = both.scenarios();
        assert_eq!(sc.len(), 4);
        assert!(sc[..2].iter().all(|s| s.policy == PolicyKind::PackFirst));
        assert!(sc[2..].iter().all(|s| s.policy == PolicyKind::SpreadLinks));
        let report = run_sweep_streaming(&twin, &both, 2);
        let plain = run_sweep_streaming(&twin, &base, 2);
        assert_eq!(report.stats.len(), 4);
        // Policy-major expansion: the PackFirst half IS the plain grid.
        assert_eq!(&report.stats[..2], &plain.stats[..]);
        // Tables carry the policy column and the comparison rows.
        let t = report.scenario_table();
        assert_eq!(t.headers[3], "Policy");
        assert_eq!(t.rows[0][3], "pack");
        assert_eq!(t.rows[3][3], "spread");
        let pt = report.policy_table();
        assert_eq!(pt.rows.len(), 2);
        assert_eq!(pt.rows[0][0], "pack");
        assert_eq!(pt.rows[1][0], "spread");
        assert_eq!(pt.rows[0][1], "2");
    }

    /// Satellite: the fault/checkpoint CLI boundary — malformed specs
    /// come back as flag-shaped errors, never worker panics.
    #[test]
    fn fault_parsers_reject_malformed_specs() {
        assert_eq!(parse_faults("none").unwrap(), FaultTrace::none());
        assert_eq!(parse_faults(" NONE ").unwrap(), FaultTrace::none());
        let ft = parse_faults("mtbf:250000,repair:3600,group:36,seed:9").unwrap();
        assert_eq!(ft.node_mtbf_s, 250_000.0);
        assert_eq!(ft.repair_mean_s, 3_600.0);
        assert_eq!(ft.group, 36);
        assert_eq!(ft.seed, 9);
        assert_eq!(ft.duration_s, 86_400.0, "default window");
        assert_eq!(ft.link_mtbf_s, 0.0, "links unarmed unless asked");
        let link = parse_faults("linkmtbf:90000,factor:0.25,dur:43200").unwrap();
        assert_eq!(link.link_mtbf_s, 90_000.0);
        assert_eq!(link.degraded_factor, 0.25);
        assert_eq!(link.duration_s, 43_200.0);
        assert_eq!(link.node_mtbf_s, 0.0);
        // Zero/negative/non-finite rates, out-of-range factors, unknown
        // keys, bare words and no-op specs all error cleanly.
        assert!(parse_faults("mtbf:0").is_err());
        assert!(parse_faults("mtbf:-100").is_err());
        assert!(parse_faults("mtbf:nan").is_err());
        assert!(parse_faults("mtbf:250000,repair:0").is_err());
        assert!(parse_faults("mtbf:250000,factor:1.5").is_err());
        assert!(parse_faults("mtbf:250000,factor:-0.5").is_err());
        assert!(parse_faults("mtbf:250000,group:0").is_err());
        assert!(parse_faults("mtbf:250000,bogus:1").is_err());
        assert!(parse_faults("mtbf").is_err(), "missing value");
        assert!(parse_faults("").is_err(), "arms nothing");
        assert!(parse_faults("repair:3600").is_err(), "arms nothing");
        // Checkpoint: none or a positive interval.
        assert_eq!(parse_checkpoint("none").unwrap(), CheckpointPolicy::None);
        assert_eq!(
            parse_checkpoint("1800").unwrap(),
            CheckpointPolicy::Periodic(1800.0)
        );
        assert!(parse_checkpoint("0").is_err());
        assert!(parse_checkpoint("-5").is_err());
        assert!(parse_checkpoint("inf").is_err());
        assert!(parse_checkpoint("soon").is_err());
    }

    /// Satellite: the fault-free axis value is invisible — a grid swept
    /// over `[FaultTrace::none()]` produces a report byte-identical to
    /// the same grid without a fault axis, the robustness metrics sit
    /// at their exact fault-free values (goodput is IEEE-exactly 1.0),
    /// and the fault-counter comparator is a no-op on it.
    #[test]
    fn fault_free_axis_is_byte_identical() {
        let twin = Twin::leonardo();
        let grid = small_grid();
        let with_axis = grid.clone().with_fault_traces(vec![FaultTrace::none()]);
        assert_eq!(with_axis.len(), grid.len());
        let plain = run_sweep_streaming(&twin, &grid, 2);
        let axis = run_sweep_streaming(&twin, &with_axis, 2);
        assert_eq!(plain, axis);
        assert!(plain.stats.iter().all(|s| {
            s.killed == 0
                && s.requeued == 0
                && s.wasted_node_h == 0.0
                && s.goodput == 1.0
                && s.p95_recovery_stretch == 0.0
                && s.faults == "none"
        }));
        assert_eq!(plain.with_fault_counters_zeroed(), plain);
    }

    /// Tentpole: a faulted, checkpointed sweep kills and requeues jobs,
    /// burns node-hours, drops goodput below 1 — and the report stays
    /// bit-identical for any worker-thread count, faults included.
    #[test]
    fn faulted_sweep_kills_requeues_and_stays_thread_independent() {
        let twin = Twin::leonardo();
        // Per-node MTBF of 1e6 s over a day on ~3.5k nodes ≈ 300
        // failure events of 32 nodes: enough that packed cells are hit
        // many times over, so kills are statistically certain.
        let faults = FaultTrace {
            seed: 9,
            duration_s: 86_400.0,
            node_mtbf_s: 1_000_000.0,
            repair_mean_s: 7_200.0,
            group: 32,
            link_mtbf_s: 0.0,
            link_repair_mean_s: 0.0,
            degraded_factor: 1.0,
        };
        let grid = SweepGrid::new(vec![1, 2], vec![None], vec!["day".into()], 300)
            .unwrap()
            .with_fault_traces(vec![FaultTrace::none(), faults])
            .with_checkpoint(Some(CheckpointPolicy::Periodic(1800.0)));
        assert_eq!(grid.len(), 4, "fault axis multiplies the grid");
        let one = run_sweep_streaming(&twin, &grid, 1);
        let many = run_sweep_streaming(&twin, &grid, 8);
        assert_eq!(one, many, "fault columns must be thread-count independent");
        assert_eq!(one, run_sweep(&twin, &grid, 2), "and engine independent");
        // Fault-axis-major expansion: the first half is the fault-free
        // sub-grid, the second half replayed under the failure stream.
        let (clean, faulted) = one.stats.split_at(2);
        assert!(clean.iter().all(|s| s.killed == 0 && s.goodput == 1.0));
        let killed: u64 = faulted.iter().map(|s| s.killed).sum();
        let requeued: u64 = faulted.iter().map(|s| s.requeued).sum();
        assert!(killed > 0, "an aggressive fault trace must kill something");
        assert_eq!(requeued, killed, "a forced Periodic policy requeues every kill");
        assert!(faulted.iter().any(|s| s.wasted_node_h > 0.0));
        assert!(faulted.iter().all(|s| s.goodput <= 1.0));
        assert!(faulted.iter().any(|s| s.goodput < 1.0));
        assert!(faulted.iter().any(|s| s.p95_recovery_stretch >= 1.0));
        assert!(faulted.iter().all(|s| s.faults == "mtbf1000k"));
        // Every job still completes: record counts match the trace.
        assert!(one.stats.iter().all(|s| s.jobs == 300));
    }

    /// Tentpole: the divergence-tree engine composes with the fault
    /// axis — fault events ride the shared prefix (rendered once per
    /// group) and the member cap diverges at the rank just past them,
    /// so forked reports stay byte-identical to streaming, faults and
    /// checkpoints included.
    #[test]
    fn forked_sweep_matches_streaming_over_fault_axis() {
        let twin = Twin::leonardo();
        let faults = FaultTrace {
            seed: 5,
            duration_s: 86_400.0,
            node_mtbf_s: 2_000_000.0,
            repair_mean_s: 5_400.0,
            group: 32,
            link_mtbf_s: 0.0,
            link_repair_mean_s: 0.0,
            degraded_factor: 1.0,
        };
        let grid = small_grid()
            .with_cap_time(7200.0)
            .with_fault_traces(vec![FaultTrace::none(), faults])
            .with_checkpoint(Some(CheckpointPolicy::Periodic(3600.0)));
        // Groups share (policy, fault, mix, seed) and walk the cap axis.
        let sc = grid.scenarios();
        for group in grid.fork_groups() {
            let first = &sc[group[0]];
            for &i in &group {
                assert_eq!(sc[i].faults, first.faults, "fault trace shared in-group");
            }
        }
        let streamed = run_sweep_streaming(&twin, &grid, 2);
        for threads in [1, 2] {
            let forked = run_sweep_forked(&twin, &grid, threads);
            assert_eq!(
                streamed,
                forked.with_fork_counters_zeroed(),
                "forked vs streaming diverged over the fault axis ({threads} threads)"
            );
        }
        let faulted_killed: u64 = streamed
            .stats
            .iter()
            .filter(|s| s.faults != "none")
            .map(|s| s.killed)
            .sum();
        assert!(faulted_killed > 0, "the faulted half must exercise kills");
    }

    /// Cost hints line up with the canonical group numbering: singleton
    /// clean groups sit in class 0 at `jobs` units, and fork members,
    /// armed fault traces and coupling each scale the hint up — the
    /// ordering the distributed scheduler's LPT queue is seeded with.
    #[test]
    fn group_cost_hints_track_fork_fault_and_coupling_axes() {
        let plain = small_grid(); // 2 seeds × 2 caps × 1 mix, 60 jobs
        let hints = plain.group_cost_hints(false);
        assert_eq!(hints.len(), plain.len());
        for h in &hints {
            assert_eq!((h.members, h.fault_armed, h.class()), (1, false, 0));
            assert_eq!(h.hint, 60.0);
        }

        let armed = FaultTrace {
            seed: 5,
            duration_s: 86_400.0,
            node_mtbf_s: 2_000_000.0,
            repair_mean_s: 5_400.0,
            group: 32,
            link_mtbf_s: 0.0,
            link_repair_mean_s: 0.0,
            degraded_factor: 1.0,
        };
        let skew = small_grid()
            .with_coupling(Coupling::full())
            .with_cap_time(3600.0)
            .with_fault_traces(vec![FaultTrace::none(), armed]);
        let groups = skew.work_groups(true);
        let hints = skew.group_cost_hints(true);
        assert_eq!(hints.len(), groups.len());
        let span = skew.seeds.len() * skew.caps.len() * skew.mixes.len();
        for (g, h) in hints.iter().enumerate() {
            assert_eq!(h.members, groups[g].len());
            assert_eq!(h.members, 2, "cap axis forks in pairs");
            // The hint's fault flag must match the fault index of the
            // group's members under the canonical expansion.
            let f = (groups[g][0] / span) % skew.faults.len();
            assert_eq!(h.fault_armed, !skew.faults[f].is_none());
            let expect = 2.0 * 60.0 * if h.fault_armed { 1.5 } else { 1.0 } * 1.25;
            assert_eq!(h.hint, expect);
            assert_eq!(h.class(), 2 + usize::from(h.fault_armed));
        }
        assert!(hints.iter().any(|h| h.fault_armed));
        assert!(hints.iter().any(|h| !h.fault_armed));
    }

    /// The trace memo returns byte-identical jobs on a hit, keys on the
    /// full generator state (a different seed is a different trace),
    /// and counts its own effectiveness.
    #[test]
    fn trace_cache_hits_clone_the_exact_generated_trace() {
        let mut cache = TraceCache::default();
        let gen_a = TraceGen::booster_day(40, 7);
        let first = cache.jobs_for(&gen_a);
        let again = cache.jobs_for(&gen_a);
        assert_eq!(cache.counters(), (1, 1), "one miss then one hit");
        assert_eq!(
            format!("{first:?}"),
            format!("{again:?}"),
            "cache hit diverged from the generated trace"
        );
        assert_eq!(format!("{first:?}"), format!("{:?}", gen_a.generate()));

        let gen_b = TraceGen::booster_day(40, 8);
        let other = cache.jobs_for(&gen_b);
        assert_eq!(cache.counters(), (1, 2), "new seed must miss");
        assert_ne!(format!("{first:?}"), format!("{other:?}"));
    }

    /// Scenarios that differ only along the cap/policy axes hit the
    /// cache on a persistent arena — the distributed worker's win — and
    /// the cached replay is bit-identical to the fresh-rig oracle.
    #[test]
    fn arena_replays_share_one_trace_across_cap_and_policy_axes() {
        let twin = Twin::leonardo();
        let grid = small_grid().with_policies(PolicyKind::all().to_vec());
        let scenarios = grid.scenarios();
        let mut arena: Option<ReplayRig> = None;
        for (i, sc) in scenarios.iter().enumerate() {
            let cached = run_scenario_arena(&mut arena, &twin, sc);
            let fresh = run_scenario(&twin, sc);
            assert_eq!(cached, fresh, "scenario {i} diverged through the cache");
        }
        let (hits, misses) = arena.expect("arena armed").traces.counters();
        // 2 policies × 2 caps × 2 seeds, but only 2 distinct traces
        // (one per seed): everything past the first pass per seed hits.
        assert_eq!(misses, 2, "one generation per (mix, seed)");
        assert_eq!(hits, scenarios.len() as u64 - 2);
    }
}
