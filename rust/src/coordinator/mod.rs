//! The campaign coordinator: composes config, topology, network, storage,
//! scheduler, power, performance models, the LBM driver and the PJRT
//! runtime to regenerate every table and figure of the paper.
//!
//! Each `table*`/`fig*` method returns a [`Table`] whose rows mirror the
//! paper's layout, so the CLI, the examples and the criterion benches all
//! print the same artifact the paper prints.

use std::collections::BTreeMap;

use anyhow::anyhow;

use crate::config::MachineConfig;
use crate::hardware::{GpuSpec, NodeSpec, Precision};
use crate::lbm::{LbmConfig, LbmDriver, TABLE7_NODES};
use crate::metrics::{f1, f2, sig3, Table};
use crate::network::{Network, Placement};
use crate::perfmodel::{Calibration, HpcgModel, HplModel};
use crate::power::{PowerModel, Utilization};
use crate::runtime::{literal_f32, scalar_f32, Engine};
use crate::scheduler::{JobRecord, Partition, Scheduler};
use crate::sim::Component;
use crate::storage::{io500, StorageSystem};
use crate::telemetry::{EventCounter, MetricStore};
use crate::topology::{Routing, Topology};
use crate::workloads::{AppBenchmark, TraceGen};
use crate::Result;

/// Documented host-roofline estimates used to project measured kernel
/// rates onto device rooflines (see DESIGN.md §Hardware-Adaptation and
/// EXPERIMENTS.md §Calibration): a single CPU core running the interpret
/// -mode kernel sustains at most ~20 GB/s of memory traffic and
/// ~50 GFLOPS f32.
pub const HOST_BW_GBS: f64 = 20.0;
pub const HOST_GFLOPS: f64 = 50.0;

/// The assembled twin of one machine. `Clone` so the distributed sweep
/// service can hand each in-process worker its own instance.
#[derive(Clone)]
pub struct Twin {
    pub cfg: MachineConfig,
    pub topo: Topology,
    pub net: Network,
    pub power: PowerModel,
}

/// Output of [`Twin::operations_replay`]: per-job records, the per-event
/// telemetry store, and rendered report tables.
pub struct OpsReport {
    pub records: BTreeMap<u64, JobRecord>,
    /// Per-event facility power / utilization / busy-node series.
    pub store: MetricStore,
    /// Highest mean global-link load observed.
    pub peak_congestion: f64,
    pub summary: Table,
    pub power: Table,
}

impl Twin {
    pub fn new(cfg: MachineConfig) -> Self {
        let topo = Topology::build(&cfg);
        let node = cfg
            .gpu_node_spec()
            .cloned()
            .unwrap_or_else(NodeSpec::davinci);
        let mut net = Network::new(topo.clone(), node.injection_gbps());
        net.oversubscription = cfg.network_oversubscription;
        let power = PowerModel::new(node, cfg.pue);
        Twin {
            cfg,
            topo,
            net,
            power,
        }
    }

    pub fn leonardo() -> Self {
        Self::new(MachineConfig::leonardo())
    }

    pub fn marconi100() -> Self {
        Self::new(MachineConfig::marconi100())
    }

    /// Topology-aware placement for an `n`-node Booster job on an
    /// otherwise idle machine. Errs when the request exceeds the
    /// partition instead of crashing the caller.
    pub fn place(&self, n: u32) -> Result<Placement> {
        let mut s = Scheduler::new(&self.cfg);
        s.place(Partition::Booster, n).ok_or_else(|| {
            anyhow!(
                "{n} nodes do not fit: the Booster partition has {} GPU nodes",
                self.cfg.gpu_nodes()
            )
        })
    }

    // ------------------------------------------------------------------
    // Tables
    // ------------------------------------------------------------------

    /// Table 1: compute partitions racks.
    pub fn table1(&self) -> Table {
        let mut t = Table::new(
            "Table 1 — Compute partition racks",
            &["Type", "Cells", "Racks", "CPU nodes", "GPU nodes"],
        );
        for (name, cells, racks, cpu, gpu) in self.cfg.table1() {
            t.row(vec![
                name,
                cells.to_string(),
                racks.to_string(),
                cpu.to_string(),
                gpu.to_string(),
            ]);
        }
        t.row(vec![
            "Total".into(),
            self.cfg.compute_cells().to_string(),
            self.cfg.compute_racks().to_string(),
            self.cfg.cpu_nodes().to_string(),
            self.cfg.gpu_nodes().to_string(),
        ]);
        t
    }

    /// Table 2: GPU specifications and peak performance (derived).
    pub fn table2(&self) -> Table {
        let gpus = [
            GpuSpec::a100_custom(),
            GpuSpec::a100_standard(),
            GpuSpec::v100(),
        ];
        let mut t = Table::new(
            "Table 2 — GPU chips specifications and peak performance",
            &["Metric", "A100 (custom)", "A100", "V100"],
        );
        let fmt = |v: Option<f64>, scale: f64| {
            v.map(|x| sig3(x / scale)).unwrap_or_else(|| "n.a.".into())
        };
        let rows: Vec<(&str, Box<dyn Fn(&GpuSpec) -> String>)> = vec![
            (
                "FP64 [teraFLOPS]",
                Box::new(|g: &GpuSpec| fmt(g.peak_flops(Precision::Fp64), 1e12)),
            ),
            (
                "FP32 [teraFLOPS]",
                Box::new(|g: &GpuSpec| fmt(g.peak_flops(Precision::Fp32), 1e12)),
            ),
            (
                "FP64 TC [teraFLOPS]",
                Box::new(|g: &GpuSpec| {
                    fmt(g.peak_flops(Precision::Fp64TensorCore), 1e12)
                }),
            ),
            (
                "TF32 TC [teraFLOPS]",
                Box::new(|g: &GpuSpec| {
                    fmt(g.peak_flops(Precision::Tf32TensorCore), 1e12)
                }),
            ),
            (
                "FP16 TC [teraFLOPS]",
                Box::new(|g: &GpuSpec| {
                    fmt(g.peak_flops(Precision::Fp16TensorCore), 1e12)
                }),
            ),
            (
                "INT8 TC [teraOPS]",
                Box::new(|g: &GpuSpec| {
                    fmt(g.peak_flops(Precision::Int8TensorCore), 1e12)
                }),
            ),
            (
                "INT4 TC [teraOPS]",
                Box::new(|g: &GpuSpec| {
                    fmt(g.peak_flops(Precision::Int4TensorCore), 1e12)
                }),
            ),
            ("SM [#]", Box::new(|g: &GpuSpec| g.sm_count.to_string())),
            (
                "CUDA FP64 core [#]",
                Box::new(|g: &GpuSpec| g.fp64_cores().to_string()),
            ),
            (
                "CUDA FP32 core [#]",
                Box::new(|g: &GpuSpec| g.fp32_cores().to_string()),
            ),
            (
                "Tensor core [#]",
                Box::new(|g: &GpuSpec| g.tensor_cores().to_string()),
            ),
            (
                "Max Clock [MHz]",
                Box::new(|g: &GpuSpec| g.boost_clock_mhz.to_string()),
            ),
            (
                "L2 Cache [MB]",
                Box::new(|g: &GpuSpec| g.l2_cache_mib.to_string()),
            ),
            (
                "Memory [GB]",
                Box::new(|g: &GpuSpec| g.memory_gib.to_string()),
            ),
            (
                "Memory BW [GB/s]",
                Box::new(|g: &GpuSpec| format!("{:.0}", g.memory_bw_gbs)),
            ),
            ("TDP [W]", Box::new(|g: &GpuSpec| format!("{:.0}", g.tdp_w))),
        ];
        for (name, f) in rows {
            let mut row = vec![name.to_string()];
            for g in &gpus {
                row.push(f(g));
            }
            t.row(row);
        }
        t
    }

    /// Table 3: filesystem organisation and specifications.
    pub fn table3(&self) -> Table {
        let sys = StorageSystem::leonardo();
        let mut t = Table::new(
            "Table 3 — Filesystem organization and specifications",
            &[
                "Work area",
                "ES7990X #",
                "ES400NVX2 #",
                "ES400NV #",
                "NetSize PiB",
                "Bandwidth GB/s",
            ],
        );
        for ns in &sys.namespaces {
            let count = |name: &str| -> u32 {
                ns.data_appliances
                    .iter()
                    .chain(ns.md_appliances.iter())
                    .filter(|(a, _)| a.name == name)
                    .map(|(_, n)| *n)
                    .sum()
            };
            t.row(vec![
                ns.mount.to_string(),
                count("ES7990X").to_string(),
                count("ES400NVX2").to_string(),
                count("ES400NV").to_string(),
                f1(ns.net_pib()),
                format!("{:.0}", ns.nominal_bw_gbs),
            ]);
        }
        t
    }

    /// Table 4: HPL + HPCG at the TOP500 submission scale, plus Green500.
    pub fn table4(&self, calib: Option<&Calibration>) -> Table {
        let node = self.power.node.clone();
        let hpl = HplModel::new(node.clone());
        let hpcg = HpcgModel::new(node);
        let nodes = 3300u32;
        let rmax = hpl.rmax(nodes);
        let power_mw = self.power.fleet_power_mw(nodes, Utilization::hpl());
        let green = self.power.gflops_per_watt(rmax, nodes, Utilization::hpl());
        let mut t = Table::new(
            "Table 4 — LEONARDO at TOP500 (modelled vs paper)",
            &["Benchmark", "Twin", "Paper", "Unit"],
        );
        t.row(vec![
            "HPL Rmax".into(),
            f1(rmax / 1e15),
            "238.7".into(),
            "petaFLOPS".into(),
        ]);
        t.row(vec![
            "HPL Rpeak (3300 nodes)".into(),
            f1(hpl.rpeak(nodes) / 1e15),
            "304.5 (full)".into(),
            "petaFLOPS".into(),
        ]);
        t.row(vec![
            "HPL efficiency".into(),
            f2(hpl.efficiency(nodes)),
            "0.78".into(),
            "Rmax/Rpeak".into(),
        ]);
        t.row(vec![
            "HPCG".into(),
            f2(hpcg.rate(nodes) / 1e15),
            "3.11".into(),
            "petaFLOPS".into(),
        ]);
        t.row(vec![
            "Power".into(),
            f1(power_mw),
            "7.4".into(),
            "MW".into(),
        ]);
        t.row(vec![
            "Green500".into(),
            f1(green),
            "32.2".into(),
            "GFLOPS/W".into(),
        ]);
        if let Some(c) = calib {
            t.row(vec![
                "host DGEMM (measured)".into(),
                f1(c.dgemm_gflops),
                "-".into(),
                "GFLOPS".into(),
            ]);
        }
        t
    }

    /// Table 5: IO500.
    pub fn table5(&self) -> Table {
        let r = io500::run_leonardo();
        let mut t = Table::new(
            "Table 5 — IO500 (twin vs ISC23 submission)",
            &["Phase", "Twin", "Paper", "Unit"],
        );
        let paper: &[(&str, &str)] = &[
            ("ior-easy-write", "1533"),
            ("ior-easy-read", "1883"),
        ];
        for p in &r.phases {
            let ref_v = paper
                .iter()
                .find(|(n, _)| *n == p.name)
                .map(|(_, v)| v.to_string())
                .unwrap_or_else(|| "-".into());
            t.row(vec![
                p.name.to_string(),
                f1(p.value),
                ref_v,
                if p.is_bandwidth { "GiB/s" } else { "kIOP/s" }.into(),
            ]);
        }
        t.row(vec!["BW score".into(), f1(r.bw_gibs), "807".into(), "GiB/s".into()]);
        t.row(vec![
            "MD score".into(),
            f1(r.md_kiops),
            "522".into(),
            "kIOP/s".into(),
        ]);
        t.row(vec!["IO500 score".into(), f1(r.score), "649".into(), "".into()]);
        t
    }

    /// Table 6: application benchmarks.
    pub fn table6(&self) -> Result<Table> {
        let mut t = Table::new(
            "Table 6 — Application benchmarks (twin vs paper)",
            &[
                "Application",
                "Domain",
                "Nodes",
                "TTS twin [s]",
                "TTS paper [s]",
                "ETS twin [kWh]",
                "ETS paper [kWh]",
            ],
        );
        for app in AppBenchmark::table6() {
            let placement = self.place(app.ref_nodes)?;
            let tts = app.tts(app.ref_nodes, &self.net, &placement);
            let ets = app.ets(app.ref_nodes, tts, &self.power);
            t.row(vec![
                app.name.into(),
                app.domain.into(),
                app.ref_nodes.to_string(),
                format!("{tts:.0}"),
                format!("{:.0}", app.ref_tts),
                f2(ets),
                f2(app.ref_ets),
            ]);
        }
        Ok(t)
    }

    /// Table 7: LBM weak scaling.
    pub fn table7(&self, calib: Option<&Calibration>) -> Result<Table> {
        let node = self.cfg.gpu_node_spec().expect("GPU machine").clone();
        let cfg = LbmConfig {
            per_gpu_lups: calib.and_then(|c| self.project_lbm_lups(c)),
            ..LbmConfig::default()
        };
        let driver = LbmDriver::new(&node, &self.net, cfg);
        let pts = driver.sweep(TABLE7_NODES, |n| self.place(n))?;
        let paper_lups = [
            0.0476, 0.192, 1.38, 2.76, 5.24, 10.8, 21.6, 43.3, 51.2,
        ];
        let paper_eff = [1.00, 1.01, 0.91, 0.91, 0.86, 0.89, 0.89, 0.89, 0.88];
        let mut t = Table::new(
            "Table 7 — LBM weak scaling (twin vs paper)",
            &[
                "Nodes",
                "GPUs",
                "TLUPS twin",
                "TLUPS paper",
                "Eff twin",
                "Eff paper",
            ],
        );
        for (i, p) in pts.iter().enumerate() {
            t.row(vec![
                p.nodes.to_string(),
                p.gpus.to_string(),
                sig3(p.lups / 1e12),
                sig3(paper_lups[i]),
                f2(p.efficiency),
                f2(paper_eff[i]),
            ]);
        }
        Ok(t)
    }

    /// Fig 5: weak-scaling efficiency, LEONARDO vs Marconi100.
    pub fn fig5(&self) -> Result<Table> {
        let leo_pts = {
            let node = self.cfg.gpu_node_spec().unwrap().clone();
            let d = LbmDriver::new(&node, &self.net, LbmConfig::default());
            d.sweep(TABLE7_NODES, |n| self.place(n))?
        };
        let marconi = Twin::marconi100();
        let m_nodes: Vec<u32> = TABLE7_NODES
            .iter()
            .copied()
            .filter(|&n| n <= marconi.cfg.gpu_nodes())
            .collect();
        let m_pts = {
            let node = marconi.cfg.gpu_node_spec().unwrap().clone();
            let d = LbmDriver::new(&node, &marconi.net, LbmConfig::default());
            d.sweep(&m_nodes, |n| marconi.place(n))?
        };
        let mut t = Table::new(
            "Fig 5 — LBM weak-scaling efficiency comparison",
            &["GPUs", "LEONARDO eff", "Marconi100 eff"],
        );
        for (i, p) in leo_pts.iter().enumerate() {
            let m = m_pts
                .get(i)
                .map(|m| f2(m.efficiency))
                .unwrap_or_else(|| "-".into());
            t.row(vec![p.gpus.to_string(), f2(p.efficiency), m]);
        }
        Ok(t)
    }

    // ------------------------------------------------------------------
    // Operations replay: the event-driven day on the Booster partition
    // ------------------------------------------------------------------

    /// Replay an operational trace through the event-driven scheduler
    /// with the power monitor, congestion tracker and telemetry scraper
    /// subscribed to the same [`crate::sim`] stream. `cap_mw` optionally
    /// applies a facility power cap (Bull Energy Optimizer analogue).
    pub fn operations_replay(
        &self,
        trace: &TraceGen,
        cap_mw: Option<f64>,
    ) -> Result<OpsReport> {
        self.operations_replay_with(trace, cap_mw, crate::scheduler::Coupling::default())
    }

    /// [`Twin::operations_replay`] with runtime coupling: job end times
    /// become provisional and re-time under fabric contention and cap
    /// moves (CLI: `operations --coupled`).
    pub fn operations_replay_with(
        &self,
        trace: &TraceGen,
        cap_mw: Option<f64>,
        coupling: crate::scheduler::Coupling,
    ) -> Result<OpsReport> {
        self.operations_replay_policy(
            trace,
            cap_mw,
            coupling,
            crate::scheduler::PolicyKind::PackFirst,
        )
    }

    /// [`Twin::operations_replay_with`] under a named placement policy
    /// (CLI: `operations --policy pack|spread`).
    pub fn operations_replay_policy(
        &self,
        trace: &TraceGen,
        cap_mw: Option<f64>,
        coupling: crate::scheduler::Coupling,
        policy: crate::scheduler::PolicyKind,
    ) -> Result<OpsReport> {
        self.operations_replay_faulted(
            trace,
            cap_mw,
            coupling,
            policy,
            &crate::workloads::FaultTrace::none(),
        )
    }

    /// [`Twin::operations_replay_policy`] with a failure trace injected
    /// into the day (CLI: `operations --faults ...`). The fault-free
    /// trace ([`crate::workloads::FaultTrace::none`]) renders zero
    /// events, so the un-faulted surfaces above replay byte-identically
    /// to their pre-fault selves.
    pub fn operations_replay_faulted(
        &self,
        trace: &TraceGen,
        cap_mw: Option<f64>,
        coupling: crate::scheduler::Coupling,
        policy: crate::scheduler::PolicyKind,
        faults: &crate::workloads::FaultTrace,
    ) -> Result<OpsReport> {
        let jobs = trace.generate();
        anyhow::ensure!(!jobs.is_empty(), "empty trace");

        // Shared replay wiring + arithmetic: the same rig and the same
        // stats code path the campaign sweep uses, so `operations` and
        // `sweep` can never model or report differently.
        let mut rig =
            crate::campaign::ReplayRig::new(self, trace.partition, cap_mw, coupling, policy);
        let mut counter = EventCounter::default();
        let records = {
            let mut observers: [&mut dyn Component; 3] =
                [&mut rig.monitor, &mut rig.congestion, &mut counter];
            rig.sched
                .run_with(jobs.clone(), faults.events(&self.cfg), &mut observers)
        };
        let mut stats = crate::campaign::ScenarioStats::collect(
            &jobs,
            &records,
            rig.total_nodes,
            &rig.monitor,
            &rig.congestion,
        );
        stats.policy = policy;
        stats.faults = faults.label();
        stats.events_skipped = rig.sched.last_run.events_skipped;
        stats.retimes_elided = rig.sched.last_run.retimes_elided;
        crate::campaign::apply_fault_counters(&mut stats, &rig.sched.last_run, &jobs, &records);

        let mut summary = Table::new(
            "Operations replay — event-driven day on the Booster partition",
            &["Metric", "Value", "Unit"],
        );
        let row = |t: &mut Table, k: &str, v: String, u: &str| {
            t.row(vec![k.to_string(), v, u.to_string()]);
        };
        row(&mut summary, "jobs completed", stats.jobs.to_string(), "");
        row(&mut summary, "makespan", f2(stats.makespan_h), "h");
        row(&mut summary, "mean wait", f1(stats.mean_wait_min), "min");
        row(&mut summary, "p95 wait", f1(stats.p95_wait_min), "min");
        row(&mut summary, "max wait", f1(stats.max_wait_min), "min");
        row(&mut summary, "mean utilization", f2(stats.utilization), "of nodes");
        row(&mut summary, "peak facility power", f2(stats.peak_mw), "MW");
        row(&mut summary, "facility energy", f2(stats.energy_mwh), "MWh");
        row(&mut summary, "DVFS-throttled jobs", stats.throttled.to_string(), "");
        row(
            &mut summary,
            "peak fabric congestion",
            f2(stats.peak_congestion),
            "global-link load",
        );
        row(
            &mut summary,
            "peak link utilization",
            f2(stats.peak_link_util),
            "bundle load",
        );
        row(
            &mut summary,
            "mean link utilization",
            f2(stats.mean_link_util),
            "bundle load",
        );
        row(&mut summary, "placement policy", policy.name().to_string(), "");
        row(
            &mut summary,
            "mean runtime stretch",
            f2(stats.mean_stretch),
            "x nominal",
        );
        row(
            &mut summary,
            "p95 runtime stretch",
            f2(stats.p95_stretch),
            "x nominal",
        );
        let (submitted, started, ended) = counter.totals();
        row(
            &mut summary,
            "lifecycle events",
            format!("{submitted}/{started}/{ended}"),
            "submit/start/end",
        );
        row(
            &mut summary,
            "stale events skipped",
            stats.events_skipped.to_string(),
            "re-timed Ends",
        );
        row(
            &mut summary,
            "re-times elided",
            stats.retimes_elided.to_string(),
            "cell index + rate-unchanged",
        );
        row(&mut summary, "jobs fault-killed", stats.killed.to_string(), "");
        row(
            &mut summary,
            "jobs checkpoint-requeued",
            stats.requeued.to_string(),
            "",
        );
        row(
            &mut summary,
            "wasted node-hours",
            f2(stats.wasted_node_h),
            "node-h destroyed",
        );
        row(
            &mut summary,
            "wasted energy",
            f2(rig.monitor.wasted_kwh() / 1e3),
            "MWh (PUE-incl)",
        );
        row(&mut summary, "goodput", f2(stats.goodput), "useful fraction");
        row(
            &mut summary,
            "p95 recovery stretch",
            f2(stats.p95_recovery_stretch),
            "x nominal",
        );
        let (_, nodes_down) = counter.fault_totals();
        row(&mut summary, "nodes down at day end", nodes_down.to_string(), "");

        let power = rig.monitor.store.energy_report();
        let store = rig.monitor.store.clone();
        Ok(OpsReport {
            records,
            store,
            peak_congestion: stats.peak_congestion,
            summary,
            power,
        })
    }

    /// Fan a `seeds x caps x mixes` scenario grid across `threads`
    /// workers on the streaming engine — persistent per-worker scenario
    /// arenas, results merged over an mpsc channel as they finish — and
    /// return the deterministic, thread-count-independent campaign
    /// report (see [`crate::campaign`]; CLI: `leonardo-twin sweep`).
    pub fn sweep(
        &self,
        grid: &crate::campaign::SweepGrid,
        threads: usize,
    ) -> crate::campaign::CampaignReport {
        crate::campaign::run_sweep_streaming(self, grid, threads)
    }

    /// The same grid on the divergence-tree engine: scenarios sharing a
    /// prefix up to the grid's deferred cap move are forked from one
    /// snapshot instead of each replaying the whole day (CLI:
    /// `leonardo-twin sweep --fork`). Byte-identical to [`Twin::sweep`]
    /// modulo the fork bookkeeping columns.
    pub fn sweep_forked(
        &self,
        grid: &crate::campaign::SweepGrid,
        threads: usize,
    ) -> crate::campaign::CampaignReport {
        crate::campaign::run_sweep_forked(self, grid, threads)
    }

    /// The same grid on the distributed sweep service's in-process
    /// fleet: a coordinator on an ephemeral loopback port plus
    /// `workers` worker threads, each pulling groups off the
    /// coordinator's cost-ranked ready queue (adaptive LPT dispatch —
    /// the default; `crate::service::run_fleet` additionally exposes
    /// per-worker replay threads and static ring sharding) and
    /// streaming each finished group back as one batched frame over
    /// the TCP protocol (CLI: `leonardo-twin serve --workers N`).
    /// Byte-identical to [`Twin::sweep`] (`fork = false`) or
    /// [`Twin::sweep_forked`] (`fork = true`) for any worker count.
    pub fn sweep_distributed(
        &self,
        grid: &crate::campaign::SweepGrid,
        fork: bool,
        workers: usize,
    ) -> Result<crate::campaign::CampaignReport> {
        let spec = crate::service::SweepSpec {
            grid: grid.clone(),
            routing: self.net.routing,
            fork,
        };
        let (report, _service) = crate::service::run_distributed(self, &spec, workers, &[])?;
        Ok(report)
    }

    /// §2.2 latency budget table.
    pub fn latency_table(&self) -> Table {
        let mut t = Table::new(
            "§2.2 — Fabric latency budget",
            &["Path", "Switch hops", "Latency us"],
        );
        let total = self.topo.total_nodes();
        let cases: &[(&str, u32, u32, Routing)] = &[
            ("same leaf", 0, 18, Routing::Minimal),
            ("same cell", 0, 1, Routing::Minimal),
            ("cross cell minimal", 0, total - 1, Routing::Minimal),
            ("cross cell valiant (max)", 0, total - 1, Routing::Valiant),
        ];
        for (name, a, b, policy) in cases {
            let r = self.topo.route(*a, *b, *policy);
            t.row(vec![
                name.to_string(),
                r.switch_hops.to_string(),
                format!("{:.2}", r.latency_ns() / 1000.0),
            ]);
        }
        t
    }

    // ------------------------------------------------------------------
    // Calibration: real kernels through PJRT
    // ------------------------------------------------------------------

    /// Run the AOT kernels and measure host rates.
    pub fn calibrate(&self, engine: &Engine) -> Result<Calibration> {
        // DGEMM 512: 2*512^3 flops per call.
        let n = 512usize;
        let a = literal_f32(&vec![1.0f32; n * n], &[n, n])?;
        let b = literal_f32(&vec![0.5f32; n * n], &[n, n])?;
        let t = engine.time_execute("dgemm_512", &[a, b], 3)?;
        let dgemm_gflops = 2.0 * (n as f64).powi(3) / t / 1e9;

        // LBM step on 32^3 (scan-of-8 artifact amortises dispatch).
        let f = equilibrium_f32(32);
        let omega = literal_f32(&[1.2f32], &[1])?;
        let lat = literal_f32(&f, &[19, 32, 32, 32])?;
        let t = engine.time_execute("lbm_steps8_32", &[lat, omega], 2)?;
        let lbm_mlups = 8.0 * 32f64.powi(3) / t / 1e6;

        // CG iteration on 64^3.
        let g = 64usize;
        let zeros = vec![0f32; g * g * g];
        let ones = vec![1f32; g * g * g];
        let x = literal_f32(&zeros, &[g, g, g])?;
        let r = literal_f32(&ones, &[g, g, g])?;
        let p = literal_f32(&ones, &[g, g, g])?;
        let rz = scalar_f32((g * g * g) as f32)?;
        let cg_iter_seconds = engine.time_execute("cg_iter_64", &[x, r, p, rz], 3)?;

        Ok(Calibration {
            dgemm_gflops,
            lbm_mlups,
            cg_iter_seconds,
        })
    }

    /// Project the measured host LBM rate onto the A100 HBM roofline:
    /// rate_gpu = rate_host x (bw_gpu x eff_gpu) / bw_host, capped at the
    /// device model rate. Returns None when the measurement is missing.
    pub fn project_lbm_lups(&self, c: &Calibration) -> Option<f64> {
        if c.lbm_mlups <= 0.0 {
            return None;
        }
        let gpu = self.cfg.gpu_node_spec()?.gpu.as_ref()?;
        let device_model = gpu.memory_bw_gbs * 1e9
            * crate::lbm::lbm_hbm_efficiency(gpu.name)
            / crate::lbm::BYTES_PER_SITE;
        let host_rate = c.lbm_mlups * 1e6;
        let projected = host_rate * (gpu.memory_bw_gbs / HOST_BW_GBS);
        Some(projected.min(device_model))
    }

    /// Calibration report table.
    pub fn calibration_table(&self, c: &Calibration) -> Table {
        let mut t = Table::new(
            "Calibration — measured kernel rates (PJRT CPU host)",
            &["Kernel", "Measured", "Unit", "Projected (A100)", "Unit"],
        );
        t.row(vec![
            "blocked DGEMM 512".into(),
            f1(c.dgemm_gflops),
            "GFLOPS".into(),
            "-".into(),
            "".into(),
        ]);
        let proj = self
            .project_lbm_lups(c)
            .map(|v| f2(v / 1e9))
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            "LBM D3Q19 step".into(),
            f2(c.lbm_mlups),
            "MLUPS".into(),
            proj,
            "GLUPS/GPU".into(),
        ]);
        t.row(vec![
            "CG iteration 64^3".into(),
            format!("{:.2}", c.cg_iter_seconds * 1e3),
            "ms".into(),
            "-".into(),
            "".into(),
        ]);
        t
    }
}

/// Equilibrium D3Q19 distributions for a quiescent fluid on an n^3 grid
/// (weights w_i tiled over the lattice) — the standard LBM initial state.
pub fn equilibrium_f32(n: usize) -> Vec<f32> {
    const W: [f32; 19] = [
        1.0 / 3.0,
        1.0 / 18.0,
        1.0 / 18.0,
        1.0 / 18.0,
        1.0 / 18.0,
        1.0 / 18.0,
        1.0 / 18.0,
        1.0 / 36.0,
        1.0 / 36.0,
        1.0 / 36.0,
        1.0 / 36.0,
        1.0 / 36.0,
        1.0 / 36.0,
        1.0 / 36.0,
        1.0 / 36.0,
        1.0 / 36.0,
        1.0 / 36.0,
        1.0 / 36.0,
        1.0 / 36.0,
    ];
    let mut out = Vec::with_capacity(19 * n * n * n);
    for w in W {
        out.extend(std::iter::repeat(w).take(n * n * n));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape() {
        let t = Twin::leonardo().table1();
        assert_eq!(t.rows.len(), 4); // Booster, DC, Hybrid, Total
        let total = t.rows.last().unwrap();
        assert_eq!(total[3], "1536");
        assert_eq!(total[4], "3456");
    }

    #[test]
    fn table2_has_na_for_volta_tc() {
        let t = Twin::leonardo().table2();
        let tf32 = t
            .rows
            .iter()
            .find(|r| r[0].starts_with("TF32"))
            .unwrap();
        assert_eq!(tf32[3], "n.a.");
        assert_eq!(tf32[1], "177"); // 124 SM x 1024 x 1.395 GHz / 1e12
    }

    #[test]
    fn table4_hits_paper_numbers() {
        let t = Twin::leonardo().table4(None);
        let rmax: f64 = t.rows[0][1].parse().unwrap();
        assert!((rmax - 238.7).abs() < 5.0, "{rmax}");
        let hpcg: f64 = t.rows[3][1].parse().unwrap();
        assert!((hpcg - 3.11).abs() < 0.1, "{hpcg}");
    }

    #[test]
    fn table5_score_column() {
        let t = Twin::leonardo().table5();
        let score: f64 = t.rows.last().unwrap()[1].parse().unwrap();
        assert!((score - 649.0).abs() / 649.0 < 0.10, "{score}");
    }

    #[test]
    fn table6_four_apps() {
        let t = Twin::leonardo().table6().unwrap();
        assert_eq!(t.rows.len(), 4);
    }

    #[test]
    fn table7_nine_points() {
        let t = Twin::leonardo().table7(None).unwrap();
        assert_eq!(t.rows.len(), 9);
        assert_eq!(t.rows[8][1], "9900");
    }

    #[test]
    fn oversized_placement_is_an_error_not_a_panic() {
        let twin = Twin::leonardo();
        assert!(twin.place(3456).is_ok());
        let err = twin.place(10_000).unwrap_err();
        assert!(format!("{err}").contains("do not fit"), "{err}");
    }

    #[test]
    fn fig5_marconi_series_is_shorter_and_worse_at_scale() {
        let t = Twin::leonardo().fig5().unwrap();
        assert_eq!(t.rows.len(), 9);
        // Marconi runs out of nodes before 1024 (980 max).
        assert_eq!(t.rows[8][2], "-");
        // Where both exist at scale, LEONARDO's efficiency is >= Marconi's.
        let leo: f64 = t.rows[5][1].parse().unwrap();
        let mar: f64 = t.rows[5][2].parse().unwrap();
        assert!(leo >= mar - 0.02, "{leo} vs {mar}");
    }

    #[test]
    fn latency_table_max_under_3us() {
        let t = Twin::leonardo().latency_table();
        let max: f64 = t.rows.last().unwrap()[2].parse().unwrap();
        assert!(max <= 3.0, "{max}");
    }

    #[test]
    fn operations_replay_small_day() {
        let twin = Twin::leonardo();
        let trace = crate::workloads::TraceGen::booster_day(300, 3);
        let r = twin.operations_replay(&trace, Some(6.0)).unwrap();
        assert_eq!(r.records.len(), 300);
        // Per-event power series exists and integrates to positive energy.
        let fac = r.store.get("facility_power_w").unwrap();
        assert!(fac.len() >= 600, "one sample per start and per end");
        assert!(fac.integral() > 0.0);
        // Utilization gauge stays in [0, 1].
        let util = r.store.get("utilization").unwrap();
        assert!(util.max() <= 1.0 + 1e-9);
        assert!(r.summary.rows.len() >= 10);
    }

    #[test]
    fn coupled_operations_replay_runs_and_differs() {
        let twin = Twin::leonardo();
        // hpc mix: capability heroes span cells, so coupling has comm-
        // bound multi-cell jobs to stretch.
        let trace = crate::workloads::TraceGen::booster_hpc_day(600, 11);
        let plain = twin.operations_replay(&trace, None).unwrap();
        let coupled = twin
            .operations_replay_with(&trace, None, crate::scheduler::Coupling::full())
            .unwrap();
        assert_eq!(coupled.records.len(), 600);
        // At least one job's completion moved under coupling.
        let moved = coupled
            .records
            .iter()
            .filter(|(id, r)| r.end_time != plain.records[id].end_time)
            .count();
        assert!(moved > 0, "coupling changed no completion");
        assert!(coupled.summary.rows.len() >= 12);
        // The coupled summary surfaces the hot-path counters, as plain
        // integers (`--coupled` CLI output prints this table).
        let cell = |name: &str| -> String {
            coupled
                .summary
                .rows
                .iter()
                .find(|r| r[0] == name)
                .unwrap_or_else(|| panic!("missing '{name}' row"))[1]
                .clone()
        };
        let skipped: u64 = cell("stale events skipped").parse().unwrap();
        let elided: u64 = cell("re-times elided").parse().unwrap();
        assert!(skipped > 0, "a coupled hpc day must re-time some Ends");
        assert!(elided > 0, "the cell index elided nothing");
    }

    #[test]
    fn operations_summary_reports_link_utilization_and_policy() {
        let twin = Twin::leonardo();
        let trace = crate::workloads::TraceGen::booster_hpc_day(400, 3);
        let r = twin
            .operations_replay_policy(
                &trace,
                None,
                crate::scheduler::Coupling::full(),
                crate::scheduler::PolicyKind::SpreadLinks,
            )
            .unwrap();
        let cell = |name: &str| -> String {
            r.summary
                .rows
                .iter()
                .find(|row| row[0] == name)
                .unwrap_or_else(|| panic!("missing '{name}' row"))[1]
                .clone()
        };
        assert_eq!(cell("placement policy"), "spread");
        let peak: f64 = cell("peak link utilization").parse().unwrap();
        let mean: f64 = cell("mean link utilization").parse().unwrap();
        assert!(peak > 0.0, "an hpc day must load some bundle");
        assert!(peak <= 1.0 + 1e-9);
        assert!(mean <= peak + 1e-9, "mean over bundles exceeds the peak");
    }

    #[test]
    fn operations_replay_is_deterministic() {
        let twin = Twin::leonardo();
        let trace = crate::workloads::TraceGen::booster_day(200, 9);
        let a = twin.operations_replay(&trace, None).unwrap();
        let b = twin.operations_replay(&trace, None).unwrap();
        for (id, ra) in &a.records {
            let rb = &b.records[id];
            assert_eq!(ra.start_time, rb.start_time);
            assert_eq!(ra.end_time, rb.end_time);
        }
        assert_eq!(a.peak_congestion, b.peak_congestion);
    }

    #[test]
    fn equilibrium_sums_to_rho_one() {
        let f = equilibrium_f32(4);
        let sites = 64;
        let mut rho = vec![0f32; sites];
        for q in 0..19 {
            for s in 0..sites {
                rho[s] += f[q * sites + s];
            }
        }
        for r in rho {
            assert!((r - 1.0).abs() < 1e-6);
        }
    }
}
