//! SLURM-like batch scheduler (paper §2.5: SLURM is LEONARDO's workload
//! manager; §2.6: power-aware operation via the Bull Energy Optimizer).
//!
//! Event-driven simulation of partitions on the shared [`crate::sim`]
//! kernel: a FIFO queue with EASY backfill, topology-aware placement
//! (pack a job into as few dragonfly cells as possible — locality is
//! what keeps the Table 7 efficiencies flat), and an optional facility
//! power cap that DVFS-throttles jobs (extending their runtime) instead
//! of starving the queue.
//!
//! [`Scheduler::run`] drives the job lifecycle purely from
//! `Submit`/`End`/`CapChange` events — running jobs live in an
//! end-time-ordered map, a scheduling pass fires only when state changed
//! — and emits `Start`/`End` events observers (power, telemetry, network
//! congestion) subscribe to via [`Scheduler::run_with`]. The legacy
//! scan-and-rescan loop is preserved as [`Scheduler::run_rescan`]: it is
//! the baseline `benches/scheduler_throughput.rs` measures against, and
//! the equivalence oracle the tests hold the event engine to.

use std::collections::BTreeMap;

use crate::config::{CellKind, MachineConfig};
use crate::network::Placement;
use crate::power::{PowerModel, Utilization};
use crate::sim::{Component, Event, ScheduledEvent, SimTime, Simulation, TIME_EPS};

/// Target partition of a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partition {
    Booster,
    DataCentric,
}

fn pidx(p: Partition) -> usize {
    match p {
        Partition::Booster => 0,
        Partition::DataCentric => 1,
    }
}

/// A batch job.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u64,
    pub partition: Partition,
    pub nodes: u32,
    /// Wall-time estimate, seconds (used for backfill reservations).
    pub est_seconds: f64,
    /// True runtime at nominal clocks, seconds.
    pub run_seconds: f64,
    pub submit_time: f64,
    /// Clock-boundness for DVFS slowdown (1 = fully clock-bound).
    pub boundness: f64,
}

/// Outcome of a completed job.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub id: u64,
    pub start_time: f64,
    pub end_time: f64,
    pub placement: Placement,
    /// DVFS scale the job ran at (1.0 = nominal).
    pub dvfs_scale: f64,
}

impl JobRecord {
    pub fn wait(&self, job: &Job) -> f64 {
        self.start_time - job.submit_time
    }
}

/// Free-node tracking per cell for one partition.
#[derive(Debug, Clone)]
struct CellPool {
    cell_id: u32,
    free: u32,
    total: u32,
}

/// The scheduler over one machine.
#[derive(Debug, Clone)]
pub struct Scheduler {
    booster: Vec<CellPool>,
    dc: Vec<CellPool>,
    /// Optional facility IT power cap, MW, with per-node-at-load watts.
    pub power_cap: Option<PowerCap>,
}

/// Facility power cap configuration.
#[derive(Debug, Clone, Copy)]
pub struct PowerCap {
    pub cap_mw: f64,
    /// Per-node power at job load, W (from [`crate::power::PowerModel`]).
    pub node_watts: f64,
    /// Per-node idle power, W.
    pub idle_watts: f64,
}

impl PowerCap {
    /// Cap at `cap_mw` with per-node watts taken from `model` (HPL-class
    /// load for running nodes, idle for the rest).
    pub fn for_model(model: &PowerModel, cap_mw: f64) -> Self {
        PowerCap {
            cap_mw,
            node_watts: model.node_power_w(Utilization::hpl()),
            idle_watts: model.node_power_w(Utilization::idle()),
        }
    }
}

impl Scheduler {
    pub fn new(cfg: &MachineConfig) -> Self {
        let mut booster = Vec::new();
        let mut dc = Vec::new();
        for (cell_id, cell) in cfg.cells.iter().enumerate() {
            let gpu: u32 = cell.groups.iter().map(|g| g.gpu_nodes()).sum();
            let cpu: u32 = cell.groups.iter().map(|g| g.cpu_nodes()).sum();
            if gpu > 0 {
                booster.push(CellPool {
                    cell_id: cell_id as u32,
                    free: gpu,
                    total: gpu,
                });
            }
            if cpu > 0 && cell.kind != CellKind::Io {
                dc.push(CellPool {
                    cell_id: cell_id as u32,
                    free: cpu,
                    total: cpu,
                });
            }
        }
        Scheduler {
            booster,
            dc,
            power_cap: None,
        }
    }

    fn pools(&mut self, p: Partition) -> &mut Vec<CellPool> {
        match p {
            Partition::Booster => &mut self.booster,
            Partition::DataCentric => &mut self.dc,
        }
    }

    pub fn free_nodes(&self, p: Partition) -> u32 {
        let pools = match p {
            Partition::Booster => &self.booster,
            Partition::DataCentric => &self.dc,
        };
        pools.iter().map(|c| c.free).sum()
    }

    pub fn total_nodes(&self, p: Partition) -> u32 {
        let pools = match p {
            Partition::Booster => &self.booster,
            Partition::DataCentric => &self.dc,
        };
        pools.iter().map(|c| c.total).sum()
    }

    /// Topology-aware placement: greedily fill the cells with the most
    /// free nodes, minimising the number of cells the job spans.
    pub fn place(&mut self, p: Partition, nodes: u32) -> Option<Placement> {
        if self.free_nodes(p) < nodes {
            return None;
        }
        let pools = self.pools(p);
        let mut order: Vec<usize> = (0..pools.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(pools[i].free));
        let mut left = nodes;
        let mut placement = Placement::default();
        for i in order {
            if left == 0 {
                break;
            }
            let take = pools[i].free.min(left);
            if take > 0 {
                pools[i].free -= take;
                placement.nodes_per_cell.push((pools[i].cell_id, take));
                left -= take;
            }
        }
        debug_assert_eq!(left, 0);
        Some(placement)
    }

    /// Return a placement's nodes to the free pools.
    pub fn release(&mut self, p: Partition, placement: &Placement) {
        let pools = self.pools(p);
        for &(cell_id, n) in &placement.nodes_per_cell {
            let pool = pools
                .iter_mut()
                .find(|c| c.cell_id == cell_id)
                .expect("release to unknown cell");
            pool.free += n;
            assert!(pool.free <= pool.total, "double release");
        }
    }

    /// Run a workload to completion with FIFO + EASY backfill on the
    /// event engine. Returns per-job records. Virtual time; deterministic.
    pub fn run(&mut self, jobs: Vec<Job>) -> BTreeMap<u64, JobRecord> {
        self.run_with(jobs, Vec::new(), &mut [])
    }

    /// Event-driven run with external events (e.g. `CapChange`) injected
    /// into the stream and `observers` subscribed to every event the job
    /// lifecycle produces (`Submit`, `Start`, `End`, `CapChange`).
    pub fn run_with(
        &mut self,
        mut jobs: Vec<Job>,
        extra_events: Vec<ScheduledEvent>,
        observers: &mut [&mut dyn Component],
    ) -> BTreeMap<u64, JobRecord> {
        jobs.sort_by(|a, b| {
            a.submit_time
                .partial_cmp(&b.submit_time)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        let mut sim = Simulation::new();
        for job in &jobs {
            // Virtual time starts at 0: the legacy loop admitted any
            // earlier submit at t=0, so clamp to keep that behaviour.
            sim.schedule(job.submit_time.max(0.0), Event::Submit { job: job.id });
        }
        for se in extra_events {
            sim.schedule(se.time, se.event);
        }
        let mut engine = JobEngine::new(self, jobs);
        {
            let mut comps: Vec<&mut dyn Component> = Vec::with_capacity(1 + observers.len());
            comps.push(&mut engine);
            for o in observers.iter_mut() {
                comps.push(&mut **o);
            }
            sim.run(&mut comps);
        }
        assert!(
            engine.queue.is_empty(),
            "scheduler stuck: {} jobs can never be placed",
            engine.queue.len()
        );
        engine.records
    }

    /// The legacy scan-and-rescan loop (the seed implementation):
    /// recomputes the next wake-up by scanning the running vector,
    /// re-sorts it for every head reservation and rescans the whole
    /// queue each iteration. Kept as the baseline for
    /// `benches/scheduler_throughput.rs` and as the semantic oracle the
    /// event engine is tested against — use [`Scheduler::run`].
    pub fn run_rescan(&mut self, mut jobs: Vec<Job>) -> BTreeMap<u64, JobRecord> {
        jobs.sort_by(|a, b| {
            a.submit_time
                .partial_cmp(&b.submit_time)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        let mut records: BTreeMap<u64, JobRecord> = BTreeMap::new();
        // (end_time, job idx) of running jobs.
        let mut running: Vec<(f64, usize)> = Vec::new();
        let mut queue: Vec<usize> = Vec::new();
        let mut next_submit = 0usize;
        let mut now = 0.0f64;

        loop {
            // Admit arrivals.
            while next_submit < jobs.len() && jobs[next_submit].submit_time <= now {
                queue.push(next_submit);
                next_submit += 1;
            }

            // Try to start queued jobs: head strictly FIFO, the rest may
            // backfill only if they fit *now* and finish before the
            // head's earliest possible start (EASY).
            let mut started = Vec::new();
            let head_reservation = self.head_reservation(&jobs, &queue, &running, now);
            for (qpos, &ji) in queue.iter().enumerate() {
                let job = &jobs[ji];
                if self.free_nodes(job.partition) < job.nodes {
                    continue; // head waits; others may backfill
                }
                if qpos > 0 {
                    if let Some((res_time, res_part, res_nodes)) = head_reservation {
                        // Would this backfill delay the head?
                        let fits_before = now + job.est_seconds <= res_time + 1e-9;
                        let disjoint = job.partition != res_part
                            || self.free_nodes(job.partition) - job.nodes >= res_nodes;
                        if !fits_before && !disjoint {
                            continue;
                        }
                    }
                }
                let scale = self.dvfs_scale_for(&jobs, &running, job.nodes);
                let placement = self
                    .place(job.partition, job.nodes)
                    .expect("checked free_nodes");
                let slowdown = crate::power::DvfsPoint { scale }.time_factor(job.boundness);
                let end = now + job.run_seconds * slowdown;
                records.insert(
                    job.id,
                    JobRecord {
                        id: job.id,
                        start_time: now,
                        end_time: end,
                        placement,
                        dvfs_scale: scale,
                    },
                );
                running.push((end, ji));
                started.push(qpos);
            }
            for &qpos in started.iter().rev() {
                queue.remove(qpos);
            }

            if running.is_empty() && queue.is_empty() && next_submit >= jobs.len() {
                break;
            }

            // Advance virtual time to the next event.
            let next_end = running
                .iter()
                .map(|(t, _)| *t)
                .fold(f64::INFINITY, f64::min);
            let next_arrival = if next_submit < jobs.len() {
                jobs[next_submit].submit_time
            } else {
                f64::INFINITY
            };
            let t = next_end.min(next_arrival);
            assert!(
                t.is_finite() && t >= now,
                "scheduler stuck at t={now} (queue {}, running {})",
                queue.len(),
                running.len()
            );
            now = t;

            // Complete finished jobs.
            let mut i = 0;
            while i < running.len() {
                if running[i].0 <= now + 1e-9 {
                    let (_, ji) = running.remove(i);
                    let job = &jobs[ji];
                    let placement = records.get(&job.id).unwrap().placement.clone();
                    self.release(job.partition, &placement);
                } else {
                    i += 1;
                }
            }
        }
        records
    }

    /// Earliest time the queue head could start, given running jobs:
    /// (time, partition, nodes it needs). Legacy-loop helper.
    fn head_reservation(
        &self,
        jobs: &[Job],
        queue: &[usize],
        running: &[(f64, usize)],
        now: f64,
    ) -> Option<(f64, Partition, u32)> {
        let &head = queue.first()?;
        let job = &jobs[head];
        let mut free = self.free_nodes(job.partition);
        if free >= job.nodes {
            return Some((now, job.partition, job.nodes));
        }
        let mut ends: Vec<(f64, u32)> = running
            .iter()
            .filter(|(_, ji)| jobs[*ji].partition == job.partition)
            .map(|(t, ji)| (*t, jobs[*ji].nodes))
            .collect();
        ends.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for (t, n) in ends {
            free += n;
            if free >= job.nodes {
                return Some((t, job.partition, job.nodes));
            }
        }
        None
    }

    /// DVFS scale for a job about to start (`new_nodes`) under the
    /// facility power cap, if any. Legacy-loop helper.
    fn dvfs_scale_for(&self, jobs: &[Job], running: &[(f64, usize)], new_nodes: u32) -> f64 {
        if self.power_cap.is_none() {
            return 1.0;
        }
        let busy: u32 =
            running.iter().map(|(_, ji)| jobs[*ji].nodes).sum::<u32>() + new_nodes;
        self.dvfs_scale_at(busy)
    }

    /// DVFS scale when `busy` nodes (including the one about to start)
    /// are loaded, under the facility power cap.
    fn dvfs_scale_at(&self, busy: u32) -> f64 {
        let Some(cap) = self.power_cap else {
            return 1.0;
        };
        let idle_nodes = self.total_nodes(Partition::Booster).saturating_sub(busy);
        let draw_mw =
            (busy as f64 * cap.node_watts + idle_nodes as f64 * cap.idle_watts) / 1e6;
        if draw_mw <= cap.cap_mw {
            1.0
        } else {
            // Quadratic power law: scale clocks so the dynamic part fits.
            let over = cap.cap_mw / draw_mw;
            over.sqrt().clamp(0.5, 1.0)
        }
    }
}

/// The event-driven job lifecycle: a [`Component`] translating
/// `Submit`/`End`/`CapChange` events into placement decisions, emitting
/// `Start`/`End` events for observers.
///
/// State the legacy loop recomputed per wake-up is maintained
/// incrementally: free nodes per partition are O(1) counters, running
/// jobs live in a `BTreeMap` keyed by `(end time, start seq)` so both
/// the next completion and the head reservation walk come out in order
/// without re-sorting, and the scheduling pass runs only when an event
/// actually changed capacity or the queue (`dirty`).
struct JobEngine<'a> {
    sched: &'a mut Scheduler,
    jobs: Vec<Job>,
    idx_of: BTreeMap<u64, usize>,
    /// Queued job indices in FIFO (submit) order.
    queue: Vec<usize>,
    /// Running jobs: (end time, start seq) -> job index.
    running: BTreeMap<(SimTime, u64), usize>,
    start_seq: u64,
    /// Total running nodes across both partitions (power-cap accounting,
    /// matching the legacy loop).
    running_nodes: u32,
    /// Cached free nodes per partition (indexed by [`pidx`]).
    free: [u32; 2],
    records: BTreeMap<u64, JobRecord>,
    dirty: bool,
}

impl<'a> JobEngine<'a> {
    fn new(sched: &'a mut Scheduler, jobs: Vec<Job>) -> Self {
        let mut idx_of = BTreeMap::new();
        for (i, job) in jobs.iter().enumerate() {
            let prev = idx_of.insert(job.id, i);
            assert!(prev.is_none(), "duplicate job id {}", job.id);
        }
        let free = [
            sched.free_nodes(Partition::Booster),
            sched.free_nodes(Partition::DataCentric),
        ];
        JobEngine {
            sched,
            jobs,
            idx_of,
            queue: Vec::new(),
            running: BTreeMap::new(),
            start_seq: 0,
            running_nodes: 0,
            free,
            records: BTreeMap::new(),
            dirty: false,
        }
    }

    /// Earliest time the queue head could start: walk running jobs in
    /// end-time order (the map's native order) instead of re-sorting.
    fn head_reservation(&self, now: f64) -> Option<(f64, Partition, u32)> {
        let &head = self.queue.first()?;
        let job = &self.jobs[head];
        let mut free = self.free[pidx(job.partition)];
        if free >= job.nodes {
            return Some((now, job.partition, job.nodes));
        }
        for (&(t, _), &ji) in &self.running {
            let j = &self.jobs[ji];
            if j.partition != job.partition {
                continue;
            }
            free += j.nodes;
            if free >= job.nodes {
                return Some((t.0, job.partition, job.nodes));
            }
        }
        None
    }

    /// DVFS scale for a start of `new_nodes` (O(1) via the counter;
    /// same formula as the legacy loop via [`Scheduler::dvfs_scale_at`]).
    fn dvfs_scale(&self, new_nodes: u32) -> f64 {
        self.sched.dvfs_scale_at(self.running_nodes + new_nodes)
    }

    /// Complete every running job whose end falls within `TIME_EPS` of
    /// `now` (the legacy loop's completion tolerance).
    fn complete_due(&mut self, now: f64) {
        while let Some((&(t, seq), &ji)) = self.running.first_key_value() {
            if t.0 > now + TIME_EPS {
                break;
            }
            self.running.remove(&(t, seq));
            let job = &self.jobs[ji];
            let placement = self.records.get(&job.id).unwrap().placement.clone();
            self.sched.release(job.partition, &placement);
            self.free[pidx(job.partition)] += job.nodes;
            self.running_nodes -= job.nodes;
            self.dirty = true;
        }
    }

    /// One scheduling pass: head strictly FIFO, the rest EASY backfill.
    /// Semantically identical to one iteration of the legacy loop.
    fn pass(&mut self, now: f64) -> Vec<ScheduledEvent> {
        let head_res = self.head_reservation(now);
        let mut started: Vec<usize> = Vec::new();
        let mut out = Vec::new();
        for qpos in 0..self.queue.len() {
            let ji = self.queue[qpos];
            let job = &self.jobs[ji];
            let p = pidx(job.partition);
            if self.free[p] < job.nodes {
                continue; // head waits; others may backfill
            }
            if qpos > 0 {
                if let Some((res_time, res_part, res_nodes)) = head_res {
                    // Would this backfill delay the head?
                    let fits_before = now + job.est_seconds <= res_time + 1e-9;
                    let disjoint = job.partition != res_part
                        || self.free[p] - job.nodes >= res_nodes;
                    if !fits_before && !disjoint {
                        continue;
                    }
                }
            }
            let scale = self.dvfs_scale(job.nodes);
            let placement = self
                .sched
                .place(job.partition, job.nodes)
                .expect("checked free counter");
            self.free[p] -= job.nodes;
            let slowdown = crate::power::DvfsPoint { scale }.time_factor(job.boundness);
            let end = now + job.run_seconds * slowdown;
            let booster = job.partition == Partition::Booster;
            out.push(ScheduledEvent::at(
                now,
                Event::Start {
                    job: job.id,
                    booster,
                    dvfs_scale: scale,
                    cells: placement.nodes_per_cell.clone(),
                },
            ));
            out.push(ScheduledEvent::at(
                end,
                Event::End {
                    job: job.id,
                    booster,
                    cells: placement.nodes_per_cell.clone(),
                },
            ));
            self.records.insert(
                job.id,
                JobRecord {
                    id: job.id,
                    start_time: now,
                    end_time: end,
                    placement,
                    dvfs_scale: scale,
                },
            );
            self.running.insert((SimTime(end), self.start_seq), ji);
            self.start_seq += 1;
            self.running_nodes += job.nodes;
            started.push(qpos);
        }
        if !started.is_empty() {
            let mut rm = started.iter().copied().peekable();
            let mut i = 0usize;
            self.queue.retain(|_| {
                let drop = rm.peek() == Some(&i);
                if drop {
                    rm.next();
                }
                i += 1;
                !drop
            });
        }
        out
    }
}

impl Component for JobEngine<'_> {
    fn on_event(&mut self, _now: f64, ev: &Event) -> Vec<ScheduledEvent> {
        match ev {
            Event::Submit { job } => {
                if let Some(&ji) = self.idx_of.get(job) {
                    self.queue.push(ji);
                    self.dirty = true;
                }
            }
            // Releases happen in the quiescent completion sweep so
            // equal-time Ends and Submits see one consistent pass.
            Event::End { .. } => self.dirty = true,
            Event::CapChange { cap_mw } => {
                match *cap_mw {
                    None => self.sched.power_cap = None,
                    Some(mw) => match self.sched.power_cap.as_mut() {
                        Some(cap) => cap.cap_mw = mw,
                        // No watt model configured: the scheduler cannot
                        // invent one for an arbitrary machine, so a level
                        // change on a capless scheduler is a no-op. Set
                        // `power_cap` (see `PowerCap::for_model`) before
                        // the run to make cap events effective.
                        None => return Vec::new(),
                    },
                }
                self.dirty = true;
            }
            Event::Start { .. } => {} // self-emitted
        }
        Vec::new()
    }

    fn on_quiescent(&mut self, now: f64) -> Vec<ScheduledEvent> {
        self.complete_due(now);
        if !self.dirty {
            return Vec::new();
        }
        self.dirty = false;
        self.pass(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::util::rng::Rng;

    fn sched() -> Scheduler {
        Scheduler::new(&MachineConfig::leonardo())
    }

    fn job(id: u64, nodes: u32, secs: f64, submit: f64) -> Job {
        Job {
            id,
            partition: Partition::Booster,
            nodes,
            est_seconds: secs,
            run_seconds: secs,
            submit_time: submit,
            boundness: 1.0,
        }
    }

    #[test]
    fn pools_match_machine_inventory() {
        let s = sched();
        assert_eq!(s.total_nodes(Partition::Booster), 3456);
        assert_eq!(s.total_nodes(Partition::DataCentric), 1536);
        assert_eq!(s.free_nodes(Partition::Booster), 3456);
    }

    #[test]
    fn small_jobs_stay_in_one_cell() {
        let mut s = sched();
        // A Booster cell holds 6 x 30 = 180 nodes.
        let p = s.place(Partition::Booster, 150).unwrap();
        assert_eq!(p.cells_used(), 1);
        assert_eq!(p.total_nodes(), 150);
    }

    #[test]
    fn big_jobs_span_minimal_cells() {
        let mut s = sched();
        // 2475 nodes (the Table 7 maximum) needs ceil(2475/180) = 14 cells.
        let p = s.place(Partition::Booster, 2475).unwrap();
        assert_eq!(p.cells_used(), 14);
        assert_eq!(p.total_nodes(), 2475);
    }

    #[test]
    fn place_release_roundtrip() {
        let mut s = sched();
        let p = s.place(Partition::Booster, 2000).unwrap();
        assert_eq!(s.free_nodes(Partition::Booster), 3456 - 2000);
        s.release(Partition::Booster, &p);
        assert_eq!(s.free_nodes(Partition::Booster), 3456);
    }

    #[test]
    fn oversized_request_is_rejected() {
        let mut s = sched();
        assert!(s.place(Partition::Booster, 4000).is_none());
    }

    #[test]
    fn fifo_order_without_contention() {
        let mut s = sched();
        let jobs = vec![job(1, 100, 50.0, 0.0), job(2, 100, 50.0, 0.0)];
        let rec = s.run(jobs);
        assert_eq!(rec[&1].start_time, 0.0);
        assert_eq!(rec[&2].start_time, 0.0); // capacity for both at once
    }

    #[test]
    fn backfill_runs_small_job_in_the_hole() {
        let mut s = sched();
        // Job 1 takes the whole machine for 100 s. Job 2 (huge) must wait.
        // Job 3 (small, short) backfills without delaying job 2.
        let jobs = vec![
            job(1, 3456, 100.0, 0.0),
            job(2, 3456, 100.0, 1.0),
            job(3, 10, 50.0, 2.0),
        ];
        let rec = s.run(jobs);
        assert_eq!(rec[&1].start_time, 0.0);
        assert!((rec[&2].start_time - 100.0).abs() < 1e-6);
        // job 3 ran inside job 2's shadow — after 1 ends it fits before 2
        // could ever need the nodes... but 2 needs ALL nodes, so 3 may
        // only run once 1 is done and must not push 2 beyond its
        // reservation. With est 50 > 0 overlap impossible: 3 starts at
        // 100 would delay 2 — so 3 waits until 2 finishes.
        assert!(rec[&3].start_time >= rec[&2].start_time);
        assert!((rec[&2].start_time - 100.0).abs() < 1e-6, "head not delayed");
    }

    #[test]
    fn backfill_uses_disjoint_capacity() {
        let mut s = sched();
        // Head needs 3456 (whole booster); a 100-node job cannot help
        // delaying it. But a DC job is disjoint and backfills freely.
        let mut dcjob = job(3, 100, 500.0, 2.0);
        dcjob.partition = Partition::DataCentric;
        let jobs = vec![job(1, 3000, 100.0, 0.0), job(2, 3456, 100.0, 1.0), dcjob];
        let rec = s.run(jobs);
        assert!((rec[&3].start_time - 2.0).abs() < 1e-6);
        assert!((rec[&2].start_time - 100.0).abs() < 1e-6);
    }

    #[test]
    fn power_cap_throttles_runtime() {
        let mut s = sched();
        s.power_cap = Some(PowerCap {
            cap_mw: 4.0,
            node_watts: 2238.0,
            idle_watts: 365.0,
        });
        let jobs = vec![job(1, 3000, 100.0, 0.0)];
        let rec = s.run(jobs);
        assert!(rec[&1].dvfs_scale < 1.0);
        assert!(rec[&1].end_time > 100.0);
    }

    #[test]
    fn no_power_cap_runs_at_nominal() {
        let mut s = sched();
        let rec = s.run(vec![job(1, 3000, 100.0, 0.0)]);
        assert_eq!(rec[&1].dvfs_scale, 1.0);
        assert!((rec[&1].end_time - 100.0).abs() < 1e-9);
    }

    #[test]
    fn all_jobs_eventually_complete() {
        let mut s = sched();
        let jobs: Vec<Job> = (0..50)
            .map(|i| job(i, 500 + (i as u32 * 97) % 2000, 10.0 + i as f64, i as f64))
            .collect();
        let rec = s.run(jobs.clone());
        assert_eq!(rec.len(), jobs.len());
        for j in &jobs {
            let r = &rec[&j.id];
            assert!(r.start_time >= j.submit_time - 1e-9);
            assert!(r.end_time > r.start_time);
            assert_eq!(r.placement.total_nodes(), j.nodes);
        }
        // Machine fully free afterwards.
        assert_eq!(s.free_nodes(Partition::Booster), 3456);
    }

    fn random_stream(seed: u64, n_jobs: u32) -> Vec<Job> {
        let mut rng = Rng::new(seed);
        (0..n_jobs)
            .map(|i| {
                let booster = rng.f64() < 0.7;
                Job {
                    id: i as u64,
                    partition: if booster {
                        Partition::Booster
                    } else {
                        Partition::DataCentric
                    },
                    nodes: rng.range_u32(1, if booster { 3456 } else { 1536 }),
                    est_seconds: rng.range_f64(1.0, 500.0),
                    run_seconds: rng.range_f64(1.0, 500.0),
                    submit_time: rng.range_f64(0.0, 100.0),
                    boundness: rng.f64(),
                }
            })
            .collect()
    }

    /// The event engine is bit-for-bit equivalent to the legacy loop.
    #[test]
    fn event_engine_matches_rescan_loop() {
        for seed in 0..6u64 {
            let jobs = random_stream(seed, 80);
            let ev = sched().run(jobs.clone());
            let legacy = sched().run_rescan(jobs);
            assert_eq!(ev.len(), legacy.len(), "seed {seed}");
            for (id, r) in &ev {
                let l = &legacy[id];
                assert_eq!(r.start_time, l.start_time, "seed {seed} job {id}");
                assert_eq!(r.end_time, l.end_time, "seed {seed} job {id}");
                assert_eq!(r.dvfs_scale, l.dvfs_scale, "seed {seed} job {id}");
                assert_eq!(
                    r.placement.nodes_per_cell, l.placement.nodes_per_cell,
                    "seed {seed} job {id}"
                );
            }
        }
    }

    /// Same equivalence under a facility power cap (DVFS path).
    #[test]
    fn event_engine_matches_rescan_under_cap() {
        for seed in 10..14u64 {
            let jobs = random_stream(seed, 50);
            let cap = PowerCap {
                cap_mw: 5.0,
                node_watts: 2238.0,
                idle_watts: 365.0,
            };
            let mut a = sched();
            a.power_cap = Some(cap);
            let ev = a.run(jobs.clone());
            let mut b = sched();
            b.power_cap = Some(cap);
            let legacy = b.run_rescan(jobs);
            for (id, r) in &ev {
                let l = &legacy[id];
                assert_eq!(r.start_time, l.start_time, "seed {seed} job {id}");
                assert_eq!(r.end_time, l.end_time, "seed {seed} job {id}");
                assert_eq!(r.dvfs_scale, l.dvfs_scale, "seed {seed} job {id}");
            }
        }
    }

    #[test]
    fn cap_change_event_throttles_later_jobs_only() {
        let mut s = sched();
        // Two identical whole-machine jobs back to back; the cap lands
        // between their starts.
        let jobs = vec![job(1, 3000, 100.0, 0.0), job(2, 3000, 100.0, 50.0)];
        let cap = PowerCap {
            cap_mw: 4.0,
            node_watts: 2238.0,
            idle_watts: 365.0,
        };
        let events = vec![ScheduledEvent::at(
            99.0,
            Event::CapChange {
                cap_mw: Some(cap.cap_mw),
            },
        )];
        s.power_cap = Some(PowerCap { cap_mw: 99.0, ..cap });
        let rec = s.run_with(jobs, events, &mut []);
        assert_eq!(rec[&1].dvfs_scale, 1.0, "started under the loose cap");
        assert!(rec[&2].dvfs_scale < 1.0, "started after the 4 MW cap");
    }

    #[test]
    fn cap_change_without_watt_model_is_ignored() {
        let mut s = sched();
        assert!(s.power_cap.is_none());
        let events = vec![ScheduledEvent::at(0.0, Event::CapChange { cap_mw: Some(4.0) })];
        let rec = s.run_with(vec![job(1, 3000, 100.0, 1.0)], events, &mut []);
        // No watt model to build a cap from: the job runs at nominal.
        assert_eq!(rec[&1].dvfs_scale, 1.0);
        assert!(s.power_cap.is_none());
    }

    /// Observers receive the full lifecycle stream.
    #[test]
    fn observers_see_submit_start_end() {
        struct Counter {
            submits: u32,
            starts: u32,
            ends: u32,
        }
        impl Component for Counter {
            fn on_event(&mut self, _now: f64, ev: &Event) -> Vec<ScheduledEvent> {
                match ev {
                    Event::Submit { .. } => self.submits += 1,
                    Event::Start { .. } => self.starts += 1,
                    Event::End { .. } => self.ends += 1,
                    _ => {}
                }
                Vec::new()
            }
        }
        let mut c = Counter {
            submits: 0,
            starts: 0,
            ends: 0,
        };
        let jobs: Vec<Job> = (0..20).map(|i| job(i, 200, 30.0, i as f64)).collect();
        let rec = sched().run_with(jobs, Vec::new(), &mut [&mut c]);
        assert_eq!(rec.len(), 20);
        assert_eq!((c.submits, c.starts, c.ends), (20, 20, 20));
    }
}
