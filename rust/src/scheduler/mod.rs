//! SLURM-like batch scheduler (paper §2.5: SLURM is LEONARDO's workload
//! manager; §2.6: power-aware operation via the Bull Energy Optimizer).
//!
//! Virtual-time event simulation of partitions, a FIFO queue with EASY
//! backfill, topology-aware placement (pack a job into as few dragonfly
//! cells as possible — locality is what keeps the Table 7 efficiencies
//! flat), and an optional facility power cap that DVFS-throttles jobs
//! (extending their runtime) instead of starving the queue.

use std::collections::BTreeMap;



use crate::config::{CellKind, MachineConfig};
use crate::network::Placement;

/// Target partition of a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partition {
    Booster,
    DataCentric,
}

/// A batch job.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u64,
    pub partition: Partition,
    pub nodes: u32,
    /// Wall-time estimate, seconds (used for backfill reservations).
    pub est_seconds: f64,
    /// True runtime at nominal clocks, seconds.
    pub run_seconds: f64,
    pub submit_time: f64,
    /// Clock-boundness for DVFS slowdown (1 = fully clock-bound).
    pub boundness: f64,
}

/// Outcome of a completed job.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub id: u64,
    pub start_time: f64,
    pub end_time: f64,
    pub placement: Placement,
    /// DVFS scale the job ran at (1.0 = nominal).
    pub dvfs_scale: f64,
}

impl JobRecord {
    pub fn wait(&self, job: &Job) -> f64 {
        self.start_time - job.submit_time
    }
}

/// Free-node tracking per cell for one partition.
#[derive(Debug, Clone)]
struct CellPool {
    cell_id: u32,
    free: u32,
    total: u32,
}

/// The scheduler over one machine.
#[derive(Debug, Clone)]
pub struct Scheduler {
    booster: Vec<CellPool>,
    dc: Vec<CellPool>,
    /// Optional facility IT power cap, MW, with per-node-at-load watts.
    pub power_cap: Option<PowerCap>,
}

/// Facility power cap configuration.
#[derive(Debug, Clone, Copy)]
pub struct PowerCap {
    pub cap_mw: f64,
    /// Per-node power at job load, W (from [`crate::power::PowerModel`]).
    pub node_watts: f64,
    /// Per-node idle power, W.
    pub idle_watts: f64,
}

impl Scheduler {
    pub fn new(cfg: &MachineConfig) -> Self {
        let mut booster = Vec::new();
        let mut dc = Vec::new();
        for (cell_id, cell) in cfg.cells.iter().enumerate() {
            let gpu: u32 = cell.groups.iter().map(|g| g.gpu_nodes()).sum();
            let cpu: u32 = cell.groups.iter().map(|g| g.cpu_nodes()).sum();
            if gpu > 0 {
                booster.push(CellPool {
                    cell_id: cell_id as u32,
                    free: gpu,
                    total: gpu,
                });
            }
            if cpu > 0 && cell.kind != CellKind::Io {
                dc.push(CellPool {
                    cell_id: cell_id as u32,
                    free: cpu,
                    total: cpu,
                });
            }
        }
        Scheduler {
            booster,
            dc,
            power_cap: None,
        }
    }

    fn pools(&mut self, p: Partition) -> &mut Vec<CellPool> {
        match p {
            Partition::Booster => &mut self.booster,
            Partition::DataCentric => &mut self.dc,
        }
    }

    pub fn free_nodes(&self, p: Partition) -> u32 {
        let pools = match p {
            Partition::Booster => &self.booster,
            Partition::DataCentric => &self.dc,
        };
        pools.iter().map(|c| c.free).sum()
    }

    pub fn total_nodes(&self, p: Partition) -> u32 {
        let pools = match p {
            Partition::Booster => &self.booster,
            Partition::DataCentric => &self.dc,
        };
        pools.iter().map(|c| c.total).sum()
    }

    /// Topology-aware placement: greedily fill the cells with the most
    /// free nodes, minimising the number of cells the job spans.
    pub fn place(&mut self, p: Partition, nodes: u32) -> Option<Placement> {
        if self.free_nodes(p) < nodes {
            return None;
        }
        let pools = self.pools(p);
        let mut order: Vec<usize> = (0..pools.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(pools[i].free));
        let mut left = nodes;
        let mut placement = Placement::default();
        for i in order {
            if left == 0 {
                break;
            }
            let take = pools[i].free.min(left);
            if take > 0 {
                pools[i].free -= take;
                placement.nodes_per_cell.push((pools[i].cell_id, take));
                left -= take;
            }
        }
        debug_assert_eq!(left, 0);
        Some(placement)
    }

    /// Return a placement's nodes to the free pools.
    pub fn release(&mut self, p: Partition, placement: &Placement) {
        let pools = self.pools(p);
        for &(cell_id, n) in &placement.nodes_per_cell {
            let pool = pools
                .iter_mut()
                .find(|c| c.cell_id == cell_id)
                .expect("release to unknown cell");
            pool.free += n;
            assert!(pool.free <= pool.total, "double release");
        }
    }

    /// Run a workload to completion with FIFO + EASY backfill.
    ///
    /// Returns per-job records. Virtual time; deterministic.
    pub fn run(&mut self, mut jobs: Vec<Job>) -> BTreeMap<u64, JobRecord> {
        jobs.sort_by(|a, b| {
            a.submit_time
                .partial_cmp(&b.submit_time)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        let mut records: BTreeMap<u64, JobRecord> = BTreeMap::new();
        // (end_time, job idx) of running jobs.
        let mut running: Vec<(f64, usize)> = Vec::new();
        let mut queue: Vec<usize> = Vec::new();
        let mut next_submit = 0usize;
        let mut now = 0.0f64;

        loop {
            // Admit arrivals.
            while next_submit < jobs.len() && jobs[next_submit].submit_time <= now {
                queue.push(next_submit);
                next_submit += 1;
            }

            // Try to start queued jobs: head strictly FIFO, the rest may
            // backfill only if they fit *now* and finish before the
            // head's earliest possible start (EASY).
            let mut started = Vec::new();
            let head_reservation = self.head_reservation(&jobs, &queue, &running, now);
            for (qpos, &ji) in queue.iter().enumerate() {
                let job = &jobs[ji];
                if self.free_nodes(job.partition) < job.nodes {
                    if qpos == 0 {
                        continue; // head waits; others may backfill
                    }
                    continue;
                }
                if qpos > 0 {
                    if let Some((res_time, res_part, res_nodes)) = head_reservation {
                        // Would this backfill delay the head?
                        let fits_before = now + job.est_seconds <= res_time + 1e-9;
                        let disjoint = job.partition != res_part
                            || self.free_nodes(job.partition) - job.nodes >= res_nodes;
                        if !fits_before && !disjoint {
                            continue;
                        }
                    }
                }
                let scale = self.dvfs_scale_for(&jobs, &running, job.nodes);
                let placement = self
                    .place(job.partition, job.nodes)
                    .expect("checked free_nodes");
                let slowdown = crate::power::DvfsPoint { scale }
                    .time_factor(job.boundness);
                let end = now + job.run_seconds * slowdown;
                records.insert(
                    job.id,
                    JobRecord {
                        id: job.id,
                        start_time: now,
                        end_time: end,
                        placement,
                        dvfs_scale: scale,
                    },
                );
                running.push((end, ji));
                started.push(qpos);
            }
            for &qpos in started.iter().rev() {
                queue.remove(qpos);
            }

            if running.is_empty() && queue.is_empty() && next_submit >= jobs.len() {
                break;
            }

            // Advance virtual time to the next event.
            let next_end = running
                .iter()
                .map(|(t, _)| *t)
                .fold(f64::INFINITY, f64::min);
            let next_arrival = if next_submit < jobs.len() {
                jobs[next_submit].submit_time
            } else {
                f64::INFINITY
            };
            let t = next_end.min(next_arrival);
            assert!(
                t.is_finite() && t >= now,
                "scheduler stuck at t={now} (queue {}, running {})",
                queue.len(),
                running.len()
            );
            now = t;

            // Complete finished jobs.
            let mut i = 0;
            while i < running.len() {
                if running[i].0 <= now + 1e-9 {
                    let (_, ji) = running.remove(i);
                    let job = &jobs[ji];
                    let placement =
                        records.get(&job.id).unwrap().placement.clone();
                    self.release(job.partition, &placement);
                } else {
                    i += 1;
                }
            }
        }
        records
    }

    /// Earliest time the queue head could start, given running jobs:
    /// (time, partition, nodes it needs).
    fn head_reservation(
        &self,
        jobs: &[Job],
        queue: &[usize],
        running: &[(f64, usize)],
        now: f64,
    ) -> Option<(f64, Partition, u32)> {
        let &head = queue.first()?;
        let job = &jobs[head];
        let mut free = self.free_nodes(job.partition);
        if free >= job.nodes {
            return Some((now, job.partition, job.nodes));
        }
        let mut ends: Vec<(f64, u32)> = running
            .iter()
            .filter(|(_, ji)| jobs[*ji].partition == job.partition)
            .map(|(t, ji)| (*t, jobs[*ji].nodes))
            .collect();
        ends.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for (t, n) in ends {
            free += n;
            if free >= job.nodes {
                return Some((t, job.partition, job.nodes));
            }
        }
        None
    }

    /// DVFS scale for a job about to start (`new_nodes`) under the
    /// facility power cap, if any.
    fn dvfs_scale_for(
        &self,
        jobs: &[Job],
        running: &[(f64, usize)],
        new_nodes: u32,
    ) -> f64 {
        let Some(cap) = self.power_cap else {
            return 1.0;
        };
        let busy: u32 = running.iter().map(|(_, ji)| jobs[*ji].nodes).sum::<u32>()
            + new_nodes;
        let idle_nodes = self
            .total_nodes(Partition::Booster)
            .saturating_sub(busy);
        let draw_mw = (busy as f64 * cap.node_watts
            + idle_nodes as f64 * cap.idle_watts)
            / 1e6;
        if draw_mw <= cap.cap_mw {
            1.0
        } else {
            // Quadratic power law: scale clocks so the dynamic part fits.
            let over = cap.cap_mw / draw_mw;
            over.sqrt().clamp(0.5, 1.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;

    fn sched() -> Scheduler {
        Scheduler::new(&MachineConfig::leonardo())
    }

    fn job(id: u64, nodes: u32, secs: f64, submit: f64) -> Job {
        Job {
            id,
            partition: Partition::Booster,
            nodes,
            est_seconds: secs,
            run_seconds: secs,
            submit_time: submit,
            boundness: 1.0,
        }
    }

    #[test]
    fn pools_match_machine_inventory() {
        let s = sched();
        assert_eq!(s.total_nodes(Partition::Booster), 3456);
        assert_eq!(s.total_nodes(Partition::DataCentric), 1536);
        assert_eq!(s.free_nodes(Partition::Booster), 3456);
    }

    #[test]
    fn small_jobs_stay_in_one_cell() {
        let mut s = sched();
        // A Booster cell holds 6 x 30 = 180 nodes.
        let p = s.place(Partition::Booster, 150).unwrap();
        assert_eq!(p.cells_used(), 1);
        assert_eq!(p.total_nodes(), 150);
    }

    #[test]
    fn big_jobs_span_minimal_cells() {
        let mut s = sched();
        // 2475 nodes (the Table 7 maximum) needs ceil(2475/180) = 14 cells.
        let p = s.place(Partition::Booster, 2475).unwrap();
        assert_eq!(p.cells_used(), 14);
        assert_eq!(p.total_nodes(), 2475);
    }

    #[test]
    fn place_release_roundtrip() {
        let mut s = sched();
        let p = s.place(Partition::Booster, 2000).unwrap();
        assert_eq!(s.free_nodes(Partition::Booster), 3456 - 2000);
        s.release(Partition::Booster, &p);
        assert_eq!(s.free_nodes(Partition::Booster), 3456);
    }

    #[test]
    fn oversized_request_is_rejected() {
        let mut s = sched();
        assert!(s.place(Partition::Booster, 4000).is_none());
    }

    #[test]
    fn fifo_order_without_contention() {
        let mut s = sched();
        let jobs = vec![job(1, 100, 50.0, 0.0), job(2, 100, 50.0, 0.0)];
        let rec = s.run(jobs);
        assert_eq!(rec[&1].start_time, 0.0);
        assert_eq!(rec[&2].start_time, 0.0); // capacity for both at once
    }

    #[test]
    fn backfill_runs_small_job_in_the_hole() {
        let mut s = sched();
        // Job 1 takes the whole machine for 100 s. Job 2 (huge) must wait.
        // Job 3 (small, short) backfills without delaying job 2.
        let jobs = vec![
            job(1, 3456, 100.0, 0.0),
            job(2, 3456, 100.0, 1.0),
            job(3, 10, 50.0, 2.0),
        ];
        let rec = s.run(jobs);
        assert_eq!(rec[&1].start_time, 0.0);
        assert!((rec[&2].start_time - 100.0).abs() < 1e-6);
        // job 3 ran inside job 2's shadow — after 1 ends it fits before 2
        // could ever need the nodes... but 2 needs ALL nodes, so 3 may
        // only run once 1 is done and must not push 2 beyond its
        // reservation. With est 50 > 0 overlap impossible: 3 starts at
        // 100 would delay 2 — so 3 waits until 2 finishes.
        assert!(rec[&3].start_time >= rec[&2].start_time);
        assert!((rec[&2].start_time - 100.0).abs() < 1e-6, "head not delayed");
    }

    #[test]
    fn backfill_uses_disjoint_capacity() {
        let mut s = sched();
        // Head needs 3456 (whole booster); a 100-node job cannot help
        // delaying it. But a DC job is disjoint and backfills freely.
        let mut dcjob = job(3, 100, 500.0, 2.0);
        dcjob.partition = Partition::DataCentric;
        let jobs = vec![job(1, 3000, 100.0, 0.0), job(2, 3456, 100.0, 1.0), dcjob];
        let rec = s.run(jobs);
        assert!((rec[&3].start_time - 2.0).abs() < 1e-6);
        assert!((rec[&2].start_time - 100.0).abs() < 1e-6);
    }

    #[test]
    fn power_cap_throttles_runtime() {
        let mut s = sched();
        s.power_cap = Some(PowerCap {
            cap_mw: 4.0,
            node_watts: 2238.0,
            idle_watts: 365.0,
        });
        let jobs = vec![job(1, 3000, 100.0, 0.0)];
        let rec = s.run(jobs);
        assert!(rec[&1].dvfs_scale < 1.0);
        assert!(rec[&1].end_time > 100.0);
    }

    #[test]
    fn no_power_cap_runs_at_nominal() {
        let mut s = sched();
        let rec = s.run(vec![job(1, 3000, 100.0, 0.0)]);
        assert_eq!(rec[&1].dvfs_scale, 1.0);
        assert!((rec[&1].end_time - 100.0).abs() < 1e-9);
    }

    #[test]
    fn all_jobs_eventually_complete() {
        let mut s = sched();
        let jobs: Vec<Job> = (0..50)
            .map(|i| job(i, 500 + (i as u32 * 97) % 2000, 10.0 + i as f64, i as f64))
            .collect();
        let rec = s.run(jobs.clone());
        assert_eq!(rec.len(), jobs.len());
        for j in &jobs {
            let r = &rec[&j.id];
            assert!(r.start_time >= j.submit_time - 1e-9);
            assert!(r.end_time > r.start_time);
            assert_eq!(r.placement.total_nodes(), j.nodes);
        }
        // Machine fully free afterwards.
        assert_eq!(s.free_nodes(Partition::Booster), 3456);
    }
}
