//! SLURM-like batch scheduler (paper §2.5: SLURM is LEONARDO's workload
//! manager; §2.6: power-aware operation via the Bull Energy Optimizer).
//!
//! Event-driven simulation of partitions on the shared [`crate::sim`]
//! kernel: a FIFO queue with EASY backfill, topology-aware placement
//! behind a pluggable [`PlacementPolicy`] ([`PackFirst`] — pack a job
//! into as few dragonfly cells as possible, locality is what keeps the
//! Table 7 efficiencies flat — or [`SpreadLinks`] — trade packing
//! against predicted per-global-link interference), and an optional
//! facility power cap that DVFS-throttles jobs (extending their
//! runtime) instead of starving the queue.
//!
//! [`Scheduler::run`] drives the job lifecycle purely from
//! `Submit`/`End`/`CapChange` events — running jobs live in an
//! end-time-ordered map, a scheduling pass fires only when state changed
//! — and emits `Start`/`End` events observers (power, telemetry, network
//! congestion) subscribe to via [`Scheduler::run_with`].
//!
//! ## The allocation-free hot path
//!
//! The scenario-sweep campaigns (see [`crate::campaign`]) replay
//! thousands of day traces, so the per-event path holds these
//! invariants (enforced by the bit-for-bit oracle suites in
//! `rust/tests/sim_scheduler.rs`):
//!
//! * **O(1) free/total counters** per partition — `free_nodes` /
//!   `total_nodes` never re-sum pools;
//! * **indexed release** — pools are indexed by cell id, so
//!   [`Scheduler::release`] is O(1) per placed cell instead of a linear
//!   `find`;
//! * **in-place placement order** — [`Scheduler::place`] re-sorts a
//!   persistent fullest-first index buffer in place behind an O(1)
//!   capacity guard, replacing the seed's allocate-and-sort-and-re-sum
//!   on every call;
//! * **interned placements** — a job's `Start` and `End` events share
//!   one [`Cells`] `Arc` instead of cloning the cell list per event,
//!   and completion releases straight from the job record without a
//!   placement clone;
//! * **pruned passes** — the engine tracks a per-partition lower bound
//!   on the smallest queued node count; a pass is skipped (and a pass's
//!   queue scan cut short) whenever no queued job can possibly fit;
//! * **settled-prefix scans** — across Submit-only intervals (free
//!   counts and running jobs unchanged) a pass resumes from the first
//!   unevaluated queue position instead of rescanning the whole queue;
//!   any `End`/`CapChange` or started job resets the cursor.
//!
//! Two cost-faithful baselines are kept for the throughput bench and
//! the oracle tests: [`Scheduler::run_rescan`] (the seed's
//! scan-and-rescan loop) and [`Scheduler::run_event_baseline`] (the
//! PR 1 event engine: allocate-and-sort placement, full queue scan per
//! pass, per-event placement copies). All three paths produce identical
//! records.
//!
//! ## Runtime coupling
//!
//! With a [`Coupling`] configured, a running job's completion is
//! *provisional*: the engine tracks per-job remaining work and a
//! progress rate (DVFS x congestion) instead of a frozen end time, and
//! re-times the generation-stamped `End` whenever the machine state
//! around the job changes — a multi-cell neighbour starting or ending
//! on shared cells or link bundles (congestion axis: the engine keeps
//! a dense per-global-link load table next to the per-cell one, and
//! [`Network::comm_slowdown_links`] prices the max-loaded link on a
//! placement's routes), or a `CapChange` moving the DVFS workpoint of
//! every running job (cap axis). Stale `End`s are skipped
//! at pop time ([`Component::accept_event`]), `Retime` events let the
//! power monitor integrate energy over the piecewise-constant rate
//! segments, and head reservations read the re-timed map, so EASY
//! backfill sees the feedback too. With coupling off (default) none of
//! this machinery runs and every engine stays bit-for-bit the seed
//! loop.
//!
//! ### Incremental cell-indexed retiming
//!
//! The optimized engine does not walk every running coupled job per
//! perturbation (the PR 3 shape, retained behind
//! [`Scheduler::retime_all`] as the cost-faithful oracle). Instead it
//! keeps a *cell → running-coupled-job index* over the
//! congestion-sensitive jobs (multi-cell Booster jobs that
//! communicate): a `Start`/`End` dirties only the cells of its
//! placement, and the re-time pass visits only the jobs indexed under a
//! dirty cell — every other job's background inputs are provably
//! unchanged, so skipping them is bit-identical (each skip counts into
//! [`RunCounters::retimes_elided`]). A `CapChange` re-scales every
//! running job through one cached DVFS workpoint while *reusing* each
//! job's cached congestion factor (`CoupledJob::comm`), so cap-only
//! sweep deltas warm-start without touching the network model.
//! Remaining work is derived from the provisional end
//! (`(end - now) / slowdown`) rather than accumulated through
//! settlements, so elided re-times leave no floating-point residue and
//! the incremental walk stays bit-for-bit the retime-all walk (pinned
//! by `rust/tests/coupling.rs`).
//!
//! ## Faults & resilience
//!
//! Fault events ride the same stream: `NodeDown` carves failed nodes
//! out of a cell's free pool — killing the lowest-id running jobs on
//! the cell when free capacity doesn't cover the loss — `NodeUp`
//! restores them (clamped to the downed count, so a stray repair can
//! never double-free), and `LinkDegraded`/`LinkRestored` scale a
//! bundle's capacity in the scheduler's network model. A killed job is
//! requeued at the kill instant with its remaining work truncated by
//! its [`CheckpointPolicy`] (`None` repeats everything, `Periodic`
//! resumes from the last completed checkpoint boundary); its pending
//! `End` is invalidated through a per-job generation base, a `Kill`
//! event notifies observers (the power monitor charges the wasted
//! joules), and survivors sharing the perturbed cells re-time through
//! the incremental coupled retimer — a downed node is just another
//! dirty-cell perturbation. Kill/requeue counts, wasted node-seconds
//! and the p95 recovery stretch land in [`RunCounters`]; with no fault
//! events in the stream none of this machinery runs and every engine
//! stays bit-for-bit its fault-free self.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::{CellKind, MachineConfig};
use crate::network::{link_contributions, placement_backgrounds, Network, Placement};
use crate::power::{PowerModel, Utilization};
use crate::sim::{
    Cells, Component, Event, ScheduledEvent, SimSnapshot, SimTime, Simulation, TIME_EPS,
};
use crate::topology::{cell_pair_count, cell_pair_index, Topology};

/// Target partition of a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partition {
    Booster,
    DataCentric,
}

fn pidx(p: Partition) -> usize {
    match p {
        Partition::Booster => 0,
        Partition::DataCentric => 1,
    }
}

/// Read-only view of one candidate cell during a placement decision —
/// what a [`PlacementPolicy`] is allowed to see.
#[derive(Debug, Clone, Copy)]
pub struct CellView {
    pub cell_id: u32,
    pub free: u32,
    pub total: u32,
    /// Nodes of currently placed multi-cell Booster jobs in the cell —
    /// the endpoint load that drives per-global-link congestion (see
    /// [`crate::network::Network::link_bw_for_cells`]).
    pub cross_nodes: u32,
}

/// A pluggable placement-order policy: given the candidate cells of a
/// partition, produce the greedy fill order [`Scheduler::place`]
/// consumes. Implementations must be deterministic pure functions of
/// the views — the oracle suites replay the same placements through
/// every engine (`run` / `run_event_baseline` / `run_rescan`), so a
/// policy that read hidden state would silently diverge them. Stable
/// sorts keep ties in pool (= cell-id) order.
pub trait PlacementPolicy: std::fmt::Debug + Send + Sync {
    /// Short CLI/report name.
    fn name(&self) -> &'static str;

    /// Reorder `order` (arriving as the identity permutation over
    /// `cells`) into the greedy fill order for a `nodes`-node request.
    fn order(&self, nodes: u32, cells: &[CellView], order: &mut [u32]);
}

/// The seed's fullest-first packing: a stable sort by descending free
/// count — bit-for-bit the order every engine used before policies
/// were pluggable (pinned by the oracle identity suites).
#[derive(Debug, Clone, Copy, Default)]
pub struct PackFirst;

impl PlacementPolicy for PackFirst {
    fn name(&self) -> &'static str {
        "pack"
    }

    fn order(&self, _nodes: u32, cells: &[CellView], order: &mut [u32]) {
        order.sort_by_key(|&i| std::cmp::Reverse(cells[i as usize].free));
    }
}

/// Anti-fragmentation placement that minimizes predicted per-link
/// congestion:
///
/// * a request that fits in one cell is *parked* on the most
///   link-loaded cell it fits in — single-cell jobs are immune to link
///   congestion and add no cross traffic, so they should consume the
///   capacity next to existing multi-cell jobs and preserve link-clean
///   cells for jobs that must span;
/// * a request that must span takes the least link-loaded cells first
///   (minimizing the predicted max route load the coupled retimer will
///   charge it), fullest-first among equals to keep the span short.
///
/// With no multi-cell job placed every `cross_nodes` is 0 and both
/// branches order fitting capacity fullest-first — an idle machine
/// places exactly like [`PackFirst`].
#[derive(Debug, Clone, Copy, Default)]
pub struct SpreadLinks;

impl PlacementPolicy for SpreadLinks {
    fn name(&self) -> &'static str {
        "spread"
    }

    fn order(&self, nodes: u32, cells: &[CellView], order: &mut [u32]) {
        if cells.iter().any(|c| c.free >= nodes) {
            order.sort_by_key(|&i| {
                let c = &cells[i as usize];
                (
                    c.free < nodes,
                    std::cmp::Reverse(c.cross_nodes),
                    std::cmp::Reverse(c.free),
                )
            });
        } else {
            order.sort_by_key(|&i| {
                let c = &cells[i as usize];
                (c.cross_nodes, std::cmp::Reverse(c.free))
            });
        }
    }
}

/// Named, data-plumbable placement policies — the `--policy` flag and
/// the policy axis of the campaign sweep grid. [`PolicyKind::build`]
/// resolves the [`PlacementPolicy`] object.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyKind {
    /// The seed's fullest-first packing ([`PackFirst`]).
    #[default]
    PackFirst,
    /// Link-aware anti-fragmentation ([`SpreadLinks`]).
    SpreadLinks,
}

impl PolicyKind {
    /// CLI/report name (`pack` / `spread`).
    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::PackFirst => "pack",
            PolicyKind::SpreadLinks => "spread",
        }
    }

    /// Inverse of [`PolicyKind::name`] — the one place CLI flags and
    /// the wire decode policy names, so a new policy that gets a
    /// `name` arm without one here is caught by the round-trip tests.
    pub fn from_name(name: &str) -> anyhow::Result<PolicyKind> {
        match name {
            "pack" => Ok(PolicyKind::PackFirst),
            "spread" => Ok(PolicyKind::SpreadLinks),
            other => anyhow::bail!(
                "unknown placement policy '{other}' (known: pack, spread)"
            ),
        }
    }

    /// Resolve the policy object.
    pub fn build(self) -> Arc<dyn PlacementPolicy> {
        match self {
            PolicyKind::PackFirst => Arc::new(PackFirst),
            PolicyKind::SpreadLinks => Arc::new(SpreadLinks),
        }
    }

    /// Every named policy, in report order.
    pub fn all() -> [PolicyKind; 2] {
        [PolicyKind::PackFirst, PolicyKind::SpreadLinks]
    }
}

/// How a running job recovers when a fault kills it mid-run
/// ([`crate::sim::Event::NodeDown`]) — the per-job lever the fault
/// campaign sweeps. Modeled as remaining-work truncation on requeue: a
/// checkpointed job resumes from its last completed checkpoint
/// boundary, an uncheckpointed one repeats everything.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum CheckpointPolicy {
    /// No checkpoints: a kill discards every second of progress.
    #[default]
    None,
    /// A checkpoint every `interval` seconds of nominal work: a kill
    /// rolls back to the last completed multiple of the interval.
    Periodic(f64),
}

/// A batch job.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u64,
    pub partition: Partition,
    pub nodes: u32,
    /// Wall-time estimate, seconds (used for backfill reservations).
    pub est_seconds: f64,
    /// True runtime at nominal clocks, seconds.
    pub run_seconds: f64,
    pub submit_time: f64,
    /// Clock-boundness for DVFS slowdown (1 = fully clock-bound).
    pub boundness: f64,
    /// Fraction of runtime spent communicating (0 = pure compute).
    /// Drives congestion coupling — comm-bound multi-cell jobs stretch
    /// under fabric contention; inert when [`Coupling`] is off.
    pub comm_fraction: f64,
    /// Recovery behaviour when a fault kills the job (inert unless
    /// fault events are injected into the stream).
    pub checkpoint: CheckpointPolicy,
}

/// Outcome of a completed job.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub id: u64,
    pub start_time: f64,
    pub end_time: f64,
    pub placement: Placement,
    /// DVFS scale the job ran at (1.0 = nominal). In coupled runs this
    /// is the workpoint in effect at completion (re-timed cap moves
    /// update it); uncoupled runs freeze it at `Start`.
    pub dvfs_scale: f64,
    /// Lowest DVFS scale the job ever ran at — the "was it throttled"
    /// question. Equal to `dvfs_scale` in uncoupled runs; in coupled
    /// runs a job capped mid-life keeps the evidence here even if the
    /// cap lifts before it completes.
    pub min_dvfs_scale: f64,
}

impl JobRecord {
    pub fn wait(&self, job: &Job) -> f64 {
        self.start_time - job.submit_time
    }
}

/// Free-node tracking per cell for one partition.
#[derive(Debug, Clone)]
struct CellPool {
    cell_id: u32,
    free: u32,
    total: u32,
    /// Nodes currently failed (`NodeDown`) — carved out of `free` until
    /// the matching `NodeUp` restores them. `free + down + allocated ==
    /// total` at every event (the fault conservation invariant).
    down: u32,
}

/// `cell id -> pool position` sentinel for cells outside a partition.
const NO_POOL: u32 = u32::MAX;

/// The scheduler over one machine.
///
/// Pools are indexed by cell id for O(1) release, free/total node
/// counts are maintained as O(1) counters, and placement re-sorts a
/// persistent order buffer in place (see the module docs for the full
/// hot-path contract).
#[derive(Debug, Clone)]
pub struct Scheduler {
    booster: Vec<CellPool>,
    dc: Vec<CellPool>,
    /// `cell id -> pool position` per partition ([`NO_POOL`] when the
    /// cell has no nodes of that partition).
    booster_by_cell: Vec<u32>,
    dc_by_cell: Vec<u32>,
    /// Persistent placement-order buffers: pool positions in the order
    /// the placement policy produced (PackFirst = fullest cell first
    /// with pool order breaking ties — exactly the stable sort the seed
    /// performed per call), rebuilt in place instead of allocated
    /// fresh.
    booster_order: Vec<u32>,
    dc_order: Vec<u32>,
    /// Persistent [`CellView`] scratch per partition ([`pidx`]-indexed)
    /// the policy orders over — rebuilt in place per placement.
    views: [Vec<CellView>; 2],
    /// Per-cell nodes of currently *placed* multi-cell Booster
    /// placements, indexed by cell id — the policy-facing congestion
    /// view. Maintained at place/release time, so every engine
    /// (including the rescan baseline) shows a policy the same
    /// predicted link loads; mirrors what the coupled engine's
    /// event-driven cross counts see.
    placed_cross: Vec<u32>,
    /// The placement policy ([`PackFirst`] by default — the seed
    /// order).
    policy: Arc<dyn PlacementPolicy>,
    policy_kind: PolicyKind,
    /// O(1) free/total node counters per partition, indexed by [`pidx`].
    free: [u32; 2],
    total: [u32; 2],
    /// Optional facility IT power cap, MW, with per-node-at-load watts.
    pub power_cap: Option<PowerCap>,
    /// Runtime feedback coupling (default off: job end times are frozen
    /// at `Start` and every engine is bit-for-bit the seed loop).
    pub coupling: Coupling,
    /// Force the PR 3 retime-all walk even on the optimized engine: every
    /// re-time perturbation re-derives every running coupled job's rate.
    /// Kept cost-faithful as the oracle (and bench baseline) the
    /// incremental cell-indexed retimer is pinned bit-for-bit against.
    /// Default off — the optimized engine re-times incrementally.
    pub retime_all: bool,
    /// Counters of the most recent `run*` call (see [`RunCounters`]).
    pub last_run: RunCounters,
    /// Network model congestion coupling derives comm slowdowns from.
    /// Required when `coupling.congestion` is on (see
    /// [`Scheduler::with_coupling`]).
    pub net: Option<Network>,
}

/// Bookkeeping counters of one scheduler run — pure observability: the
/// numbers never feed back into any scheduling or retiming decision
/// (pinned by the `retimes_elided` neutrality test in
/// `rust/tests/coupling.rs`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RunCounters {
    /// Stale generation-stamped `End`s dropped at pop time
    /// ([`crate::sim::Simulation::events_skipped`]).
    pub events_skipped: u64,
    /// Running-coupled-job re-time evaluations elided: the cell index
    /// proved the job untouched, or the recomputed rate was
    /// bit-identical so no event was emitted.
    pub retimes_elided: u64,
    /// Running jobs killed by fault events.
    pub killed: u64,
    /// Killed jobs whose [`CheckpointPolicy`] let them requeue with
    /// checkpoint-truncated rework (the rest repeat everything).
    pub requeued: u64,
    /// Wall-clock node-seconds of progress lost to kills (time spent
    /// past the last checkpoint a requeue could resume from).
    pub wasted_node_seconds: f64,
    /// p95 over killed jobs of `(final completion - first start) /
    /// nominal runtime` — the recovery stretch. 0 when nothing was
    /// killed (or no killed job completed).
    pub recovery_p95: f64,
}

/// Which feedback loops retime a *running* job's provisional `End`.
///
/// With both axes off (the default), a job's completion is frozen at
/// `Start` exactly like the seed loop — the oracle suites pin this
/// bit-for-bit. With an axis on, the event engine keeps per-job
/// remaining work and a progress rate, and re-times the generation-
/// stamped `End` whenever the machine state around the job changes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Coupling {
    /// Comm-bound multi-cell jobs stretch under fabric contention
    /// (per-cell cross-traffic load folded into
    /// [`Network::comm_slowdown`]).
    pub congestion: bool,
    /// A `CapChange` re-scales every *running* job's DVFS workpoint
    /// mid-flight instead of only affecting later starts.
    pub cap: bool,
}

impl Coupling {
    /// Both feedback loops on.
    pub fn full() -> Self {
        Coupling {
            congestion: true,
            cap: true,
        }
    }

    pub fn enabled(&self) -> bool {
        self.congestion || self.cap
    }
}

/// Facility power cap configuration.
#[derive(Debug, Clone, Copy)]
pub struct PowerCap {
    pub cap_mw: f64,
    /// Per-node power at job load, W (from [`crate::power::PowerModel`]).
    pub node_watts: f64,
    /// Per-node idle power, W.
    pub idle_watts: f64,
}

impl PowerCap {
    /// Cap at `cap_mw` with per-node watts taken from `model` (HPL-class
    /// load for running nodes, idle for the rest).
    pub fn for_model(model: &PowerModel, cap_mw: f64) -> Self {
        PowerCap {
            cap_mw,
            node_watts: model.node_power_w(Utilization::hpl()),
            idle_watts: model.node_power_w(Utilization::idle()),
        }
    }
}

impl Scheduler {
    pub fn new(cfg: &MachineConfig) -> Self {
        let mut booster = Vec::new();
        let mut dc = Vec::new();
        let mut booster_by_cell = vec![NO_POOL; cfg.cells.len()];
        let mut dc_by_cell = vec![NO_POOL; cfg.cells.len()];
        for (cell_id, cell) in cfg.cells.iter().enumerate() {
            let gpu: u32 = cell.groups.iter().map(|g| g.gpu_nodes()).sum();
            let cpu: u32 = cell.groups.iter().map(|g| g.cpu_nodes()).sum();
            if gpu > 0 {
                booster_by_cell[cell_id] = booster.len() as u32;
                booster.push(CellPool {
                    cell_id: cell_id as u32,
                    free: gpu,
                    total: gpu,
                    down: 0,
                });
            }
            if cpu > 0 && cell.kind != CellKind::Io {
                dc_by_cell[cell_id] = dc.len() as u32;
                dc.push(CellPool {
                    cell_id: cell_id as u32,
                    free: cpu,
                    total: cpu,
                    down: 0,
                });
            }
        }
        let free = [
            booster.iter().map(|c| c.free).sum(),
            dc.iter().map(|c| c.free).sum(),
        ];
        Scheduler {
            booster,
            dc,
            booster_by_cell,
            dc_by_cell,
            booster_order: Vec::new(),
            dc_order: Vec::new(),
            views: [Vec::new(), Vec::new()],
            placed_cross: vec![0; cfg.cells.len()],
            policy: PolicyKind::PackFirst.build(),
            policy_kind: PolicyKind::PackFirst,
            free,
            total: free,
            power_cap: None,
            coupling: Coupling::default(),
            retime_all: false,
            last_run: RunCounters::default(),
            net: None,
        }
    }

    /// A scheduler with runtime coupling configured. Congestion coupling
    /// needs a network model to derive comm slowdowns from, so one is
    /// built from `cfg` when that axis is on.
    pub fn with_coupling(cfg: &MachineConfig, coupling: Coupling) -> Self {
        let mut s = Self::new(cfg);
        s.coupling = coupling;
        if coupling.congestion {
            let inj = cfg.gpu_node_spec().map(|n| n.injection_gbps()).unwrap_or(400.0);
            s.net = Some(Network::new(Topology::build(cfg), inj));
        }
        s
    }

    /// A scheduler with a named placement policy installed
    /// ([`PolicyKind::PackFirst`] is the default — the seed's
    /// fullest-first order, bit-for-bit).
    pub fn with_policy(cfg: &MachineConfig, policy: PolicyKind) -> Self {
        let mut s = Self::new(cfg);
        s.set_policy(policy);
        s
    }

    /// Install a named placement policy (a per-scenario input like
    /// `coupling`: the campaign arena re-arms it on every reset).
    pub fn set_policy(&mut self, policy: PolicyKind) {
        self.policy_kind = policy;
        self.policy = policy.build();
    }

    /// The named policy currently installed.
    pub fn policy_kind(&self) -> PolicyKind {
        self.policy_kind
    }

    /// Free nodes in partition `p` — an O(1) counter read.
    pub fn free_nodes(&self, p: Partition) -> u32 {
        self.free[pidx(p)]
    }

    /// Total nodes in partition `p` — an O(1) counter read (this is the
    /// cached Booster total the per-start DVFS check reads, replacing
    /// the seed's per-call pool re-sum).
    pub fn total_nodes(&self, p: Partition) -> u32 {
        self.total[pidx(p)]
    }

    /// The seed's per-call pool re-sum, kept only so the cost-faithful
    /// baselines ([`Scheduler::run_rescan`]) pay the price the seed
    /// paid. Equals [`Scheduler::free_nodes`].
    fn free_nodes_scan(&self, p: Partition) -> u32 {
        let pools = match p {
            Partition::Booster => &self.booster,
            Partition::DataCentric => &self.dc,
        };
        pools.iter().map(|c| c.free).sum()
    }

    /// Rebuild the persistent placement-order buffer of partition `p`
    /// in place: refresh the [`CellView`] scratch, reset the identity
    /// permutation, then let the installed [`PlacementPolicy`] sort it.
    /// With [`PackFirst`] this is bit-for-bit the stable
    /// descending-free sort the seed performed per call, with no
    /// allocation.
    fn rebuild_order(&mut self, p: Partition, nodes: u32) {
        let (pools, order) = match p {
            Partition::Booster => (&self.booster, &mut self.booster_order),
            Partition::DataCentric => (&self.dc, &mut self.dc_order),
        };
        let views = &mut self.views[pidx(p)];
        views.clear();
        for pool in pools {
            views.push(CellView {
                cell_id: pool.cell_id,
                free: pool.free,
                total: pool.total,
                cross_nodes: self.placed_cross[pool.cell_id as usize],
            });
        }
        order.clear();
        order.extend(0..pools.len() as u32);
        self.policy.order(nodes, views.as_slice(), order);
    }

    /// Fold a placement into (+1) or out of (-1) the policy-facing
    /// per-cell cross view. Only multi-cell Booster placements load
    /// the global links — the same traffic-class rule the coupled
    /// engine's event-driven accounting applies.
    fn note_placed(&mut self, p: Partition, placement: &Placement, sign: i64) {
        if p != Partition::Booster || placement.nodes_per_cell.len() <= 1 {
            return;
        }
        for &(cell, n) in &placement.nodes_per_cell {
            if let Some(c) = self.placed_cross.get_mut(cell as usize) {
                let next = *c as i64 + sign * n as i64;
                *c = next.max(0) as u32;
            }
        }
    }

    /// Topology-aware placement: greedily fill cells in the installed
    /// policy's order ([`PackFirst`]: most free nodes first, minimising
    /// the number of cells the job spans; [`SpreadLinks`]: minimising
    /// predicted per-link congestion).
    ///
    /// Allocation-free: the capacity check is an O(1) counter read (no
    /// pool re-sum) and the policy order is re-sorted into a persistent
    /// buffer (no per-call `Vec`).
    pub fn place(&mut self, p: Partition, nodes: u32) -> Option<Placement> {
        let pi = pidx(p);
        if self.free[pi] < nodes {
            return None;
        }
        self.rebuild_order(p, nodes);
        let (pools, order) = match p {
            Partition::Booster => (&mut self.booster, &self.booster_order),
            Partition::DataCentric => (&mut self.dc, &self.dc_order),
        };
        let mut left = nodes;
        let mut placement = Placement::default();
        for &i in order {
            if left == 0 {
                break;
            }
            let pool = &mut pools[i as usize];
            let take = pool.free.min(left);
            if take > 0 {
                pool.free -= take;
                placement.nodes_per_cell.push((pool.cell_id, take));
                left -= take;
            }
        }
        debug_assert_eq!(left, 0);
        self.free[pi] -= nodes;
        self.note_placed(p, &placement, 1);
        Some(placement)
    }

    /// The seed's placement path, kept cost-faithful for the throughput
    /// bench and the oracle suites: re-sums free nodes, allocates view
    /// and index `Vec`s and re-sorts the pools on every call. Routed
    /// through the *same* policy object as [`Scheduler::place`], so the
    /// rescan and event-baseline engines make identical placement
    /// decisions per policy (no silent divergence between optimized and
    /// baseline paths).
    pub fn place_scan(&mut self, p: Partition, nodes: u32) -> Option<Placement> {
        let pi = pidx(p);
        if self.free_nodes_scan(p) < nodes {
            return None;
        }
        let views: Vec<CellView> = {
            let pools = match p {
                Partition::Booster => &self.booster,
                Partition::DataCentric => &self.dc,
            };
            pools
                .iter()
                .map(|pool| CellView {
                    cell_id: pool.cell_id,
                    free: pool.free,
                    total: pool.total,
                    cross_nodes: self.placed_cross[pool.cell_id as usize],
                })
                .collect()
        };
        let mut order: Vec<u32> = (0..views.len() as u32).collect();
        self.policy.order(nodes, &views, &mut order);
        let pools = match p {
            Partition::Booster => &mut self.booster,
            Partition::DataCentric => &mut self.dc,
        };
        let mut left = nodes;
        let mut placement = Placement::default();
        for &i in &order {
            if left == 0 {
                break;
            }
            let pool = &mut pools[i as usize];
            let take = pool.free.min(left);
            if take > 0 {
                pool.free -= take;
                placement.nodes_per_cell.push((pool.cell_id, take));
                left -= take;
            }
        }
        debug_assert_eq!(left, 0);
        self.free[pi] -= nodes;
        self.note_placed(p, &placement, 1);
        Some(placement)
    }

    /// Return a placement's nodes to the free pools — O(1) per placed
    /// cell via the cell-id index (the seed did a linear `find` per
    /// cell).
    pub fn release(&mut self, p: Partition, placement: &Placement) {
        let (pools, by_cell) = match p {
            Partition::Booster => (&mut self.booster, &self.booster_by_cell),
            Partition::DataCentric => (&mut self.dc, &self.dc_by_cell),
        };
        let mut released = 0u32;
        for &(cell_id, n) in &placement.nodes_per_cell {
            let idx = by_cell
                .get(cell_id as usize)
                .copied()
                .filter(|&i| i != NO_POOL)
                .expect("release to unknown cell");
            let pool = &mut pools[idx as usize];
            pool.free += n;
            assert!(pool.free <= pool.total, "double release");
            released += n;
        }
        let pi = pidx(p);
        self.free[pi] += released;
        self.note_placed(p, placement, -1);
    }

    /// Restore the state [`Scheduler::new`] builds — every pool fully
    /// free, no power cap, counters cleared, cross view drained —
    /// without reallocating any buffer. The campaign arena
    /// ([`crate::campaign::ReplayRig::reset`]) reuses one scheduler
    /// across scenarios through this; `coupling`, `retime_all`, `net`
    /// and the placement policy are per-scenario inputs the caller
    /// re-arms.
    pub fn reset(&mut self) {
        for pool in self.booster.iter_mut().chain(self.dc.iter_mut()) {
            pool.free = pool.total;
            pool.down = 0;
        }
        self.placed_cross.fill(0);
        self.free = self.total;
        self.power_cap = None;
        self.last_run = RunCounters::default();
        if let Some(net) = self.net.as_mut() {
            net.reset_link_health();
        }
    }

    /// Run a workload to completion with FIFO + EASY backfill on the
    /// optimized event engine. Returns per-job records. Virtual time;
    /// deterministic.
    pub fn run(&mut self, jobs: Vec<Job>) -> BTreeMap<u64, JobRecord> {
        self.run_with(jobs, Vec::new(), &mut [])
    }

    /// Event-driven run with external events (e.g. `CapChange`) injected
    /// into the stream and `observers` subscribed to every event the job
    /// lifecycle produces (`Submit`, `Start`, `End`, `CapChange`).
    pub fn run_with(
        &mut self,
        jobs: Vec<Job>,
        extra_events: Vec<ScheduledEvent>,
        observers: &mut [&mut dyn Component],
    ) -> BTreeMap<u64, JobRecord> {
        self.run_mode(jobs, extra_events, observers, true)
    }

    /// The PR 1 event engine, kept cost-faithful as the middle rung of
    /// the throughput ladder (`rescan < event baseline < optimized`):
    /// allocate-and-sort placement per start, a full queue scan per
    /// pass, and per-event placement copies. Record-identical to
    /// [`Scheduler::run`].
    pub fn run_event_baseline(&mut self, jobs: Vec<Job>) -> BTreeMap<u64, JobRecord> {
        self.run_mode(jobs, Vec::new(), &mut [], false)
    }

    fn run_mode(
        &mut self,
        jobs: Vec<Job>,
        extra_events: Vec<ScheduledEvent>,
        observers: &mut [&mut dyn Component],
        optimized: bool,
    ) -> BTreeMap<u64, JobRecord> {
        let mut sim = Simulation::new();
        let mut session = ReplaySession::with_mode(&mut sim, self, jobs, extra_events, optimized);
        session.run_to_end(observers);
        session.finish()
    }

    /// The legacy scan-and-rescan loop (the seed implementation):
    /// recomputes the next wake-up by scanning the running vector,
    /// re-sorts it for every head reservation, rescans the whole queue
    /// each iteration and re-sums per-cell free counts per check. Kept
    /// as the baseline for `benches/scheduler_throughput.rs` and as the
    /// semantic oracle the event engine is tested against — use
    /// [`Scheduler::run`].
    pub fn run_rescan(&mut self, mut jobs: Vec<Job>) -> BTreeMap<u64, JobRecord> {
        self.last_run = RunCounters::default();
        jobs.sort_by(|a, b| a.submit_time.total_cmp(&b.submit_time).then(a.id.cmp(&b.id)));
        let mut records: BTreeMap<u64, JobRecord> = BTreeMap::new();
        // (end_time, job idx) of running jobs.
        let mut running: Vec<(f64, usize)> = Vec::new();
        // Running-node counter for the per-start DVFS cap check — the
        // one O(R) re-sum the rescan baseline does *not* keep: it is
        // pure cost (`Σ nodes` over the running vector per start), not
        // a semantic of the seed loop, and the counter is arithmetic-
        // identical (the oracle equivalence suites stay green).
        let mut running_nodes: u32 = 0;
        let mut queue: Vec<usize> = Vec::new();
        let mut next_submit = 0usize;
        let mut now = 0.0f64;

        loop {
            // Admit arrivals.
            while next_submit < jobs.len() && jobs[next_submit].submit_time <= now {
                queue.push(next_submit);
                next_submit += 1;
            }

            // Try to start queued jobs: head strictly FIFO, the rest may
            // backfill only if they fit *now* and finish before the
            // head's earliest possible start (EASY).
            let mut started = Vec::new();
            let head_reservation = self.head_reservation(&jobs, &queue, &running, now);
            for (qpos, &ji) in queue.iter().enumerate() {
                let job = &jobs[ji];
                if self.free_nodes_scan(job.partition) < job.nodes {
                    continue; // head waits; others may backfill
                }
                if qpos > 0 {
                    if let Some((res_time, res_part, res_nodes)) = head_reservation {
                        // Would this backfill delay the head?
                        let fits_before = now + job.est_seconds <= res_time + 1e-9;
                        let disjoint = job.partition != res_part
                            || self.free_nodes_scan(job.partition) - job.nodes >= res_nodes;
                        if !fits_before && !disjoint {
                            continue;
                        }
                    }
                }
                let scale = if self.power_cap.is_none() {
                    1.0
                } else {
                    self.dvfs_scale_at(running_nodes + job.nodes)
                };
                let placement = self
                    .place_scan(job.partition, job.nodes)
                    .expect("checked free_nodes");
                let slowdown = crate::power::DvfsPoint { scale }.time_factor(job.boundness);
                let end = now + job.run_seconds * slowdown;
                records.insert(
                    job.id,
                    JobRecord {
                        id: job.id,
                        start_time: now,
                        end_time: end,
                        placement,
                        dvfs_scale: scale,
                        min_dvfs_scale: scale,
                    },
                );
                running.push((end, ji));
                running_nodes += job.nodes;
                started.push(qpos);
            }
            for &qpos in started.iter().rev() {
                queue.remove(qpos);
            }

            if running.is_empty() && queue.is_empty() && next_submit >= jobs.len() {
                break;
            }

            // Advance virtual time to the next event.
            let next_end = running
                .iter()
                .map(|(t, _)| *t)
                .fold(f64::INFINITY, f64::min);
            let next_arrival = if next_submit < jobs.len() {
                jobs[next_submit].submit_time
            } else {
                f64::INFINITY
            };
            let t = next_end.min(next_arrival);
            assert!(
                t.is_finite() && t >= now,
                "scheduler stuck at t={now} (queue {}, running {})",
                queue.len(),
                running.len()
            );
            now = t;

            // Complete finished jobs.
            let mut i = 0;
            while i < running.len() {
                if running[i].0 <= now + 1e-9 {
                    let (_, ji) = running.remove(i);
                    let job = &jobs[ji];
                    let placement = records.get(&job.id).unwrap().placement.clone();
                    self.release(job.partition, &placement);
                    running_nodes -= job.nodes;
                } else {
                    i += 1;
                }
            }
        }
        records
    }

    /// Earliest time the queue head could start, given running jobs:
    /// (time, partition, nodes it needs). Legacy-loop helper.
    fn head_reservation(
        &self,
        jobs: &[Job],
        queue: &[usize],
        running: &[(f64, usize)],
        now: f64,
    ) -> Option<(f64, Partition, u32)> {
        let &head = queue.first()?;
        let job = &jobs[head];
        let mut free = self.free_nodes_scan(job.partition);
        if free >= job.nodes {
            return Some((now, job.partition, job.nodes));
        }
        let mut ends: Vec<(f64, u32)> = running
            .iter()
            .filter(|(_, ji)| jobs[*ji].partition == job.partition)
            .map(|(t, ji)| (*t, jobs[*ji].nodes))
            .collect();
        ends.sort_by(|a, b| a.0.total_cmp(&b.0));
        for (t, n) in ends {
            free += n;
            if free >= job.nodes {
                return Some((t, job.partition, job.nodes));
            }
        }
        None
    }

    /// DVFS scale when `busy` nodes (including the one about to start)
    /// are loaded, under the facility power cap. The Booster total is
    /// the O(1) cached counter, so the per-start check never re-sums
    /// pools.
    fn dvfs_scale_at(&self, busy: u32) -> f64 {
        let Some(cap) = self.power_cap else {
            return 1.0;
        };
        let idle_nodes = self.total[pidx(Partition::Booster)].saturating_sub(busy);
        let draw_mw =
            (busy as f64 * cap.node_watts + idle_nodes as f64 * cap.idle_watts) / 1e6;
        if draw_mw <= cap.cap_mw {
            1.0
        } else {
            // Quadratic power law: scale clocks so the dynamic part fits.
            let over = cap.cap_mw / draw_mw;
            over.sqrt().clamp(0.5, 1.0)
        }
    }
}

/// `(direct, detour)` background load on `cells` given the engine's
/// per-cell and per-link cross counts, aggregated by the shared
/// [`placement_backgrounds`] (the same aggregation
/// [`Network::effective_node_bw`] feeds from its own tables, so the
/// engine-side and observer-side accountings cannot drift). The one
/// entry point both the start-time slowdown and the re-time pass use,
/// kept as a free function so the re-timer (which holds a mutable
/// borrow of the coupled map) shares it with
/// `JobEngine::background_for` instead of diverging. `exclude_own`
/// subtracts this job's own per-cell and per-pair contributions.
fn link_backgrounds(
    cell_cross: &[u32],
    cell_total: &[u32],
    link_cross: &[u32],
    cells: &[(u32, u32)],
    exclude_own: bool,
) -> (f64, f64) {
    let n = cell_total.len();
    placement_backgrounds(
        cells,
        |cell, own| {
            let Some(&total) = cell_total.get(cell as usize) else {
                return 0.0;
            };
            if total == 0 {
                return 0.0;
            }
            let mut cross = cell_cross[cell as usize];
            if exclude_own {
                cross = cross.saturating_sub(own);
            }
            cross as f64 / total as f64
        },
        |a, b, own| {
            if a as usize >= n || b as usize >= n {
                return 0.0;
            }
            let cap = cell_total[a as usize] + cell_total[b as usize];
            if cap == 0 {
                return 0.0;
            }
            let mut cross = link_cross[cell_pair_index(n, a, b)];
            if exclude_own {
                cross = cross.saturating_sub(own);
            }
            cross as f64 / cap as f64
        },
    )
}

/// Nearest-rank p95 of `samples`; 0 when empty.
fn p95(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = ((v.len() as f64 * 0.95).ceil() as usize).clamp(1, v.len());
    v[rank - 1]
}

/// Outcome of re-timing one coupled job (see [`retime_job`]).
enum Retimed {
    /// Rate and workpoint unchanged bit-for-bit: no event emitted, no
    /// state touched — the elision the incremental walk counts.
    Unchanged,
    /// The provisional `End` moved: a fresh generation was enqueued.
    Moved,
    /// The workpoint moved but the rate didn't (fully memory-bound work
    /// under a cap move): power-only `Retime`, the `End` stays put.
    Power,
}

/// Where a re-time visit gets its congestion factor from.
enum CommSource<'a> {
    /// Re-query the network model over the current per-link cross
    /// loads — jobs whose cells (and with them every link they ride)
    /// were perturbed, and every sensitive job in the retime-all
    /// oracle.
    Fresh(&'a Network),
    /// Reuse the cached [`CoupledJob::comm`] — untouched jobs on a
    /// cap-only re-scale (bit-identical to a fresh query by the cache
    /// invariant: cap moves change no link load).
    Cached,
    /// Congestion cannot apply (insensitive job in the oracle walk).
    Unit,
}

/// Re-time one coupled job against a (possibly re-scaled) DVFS
/// workpoint and the congestion factor `source` selects. The one
/// arithmetic both the incremental walk and the retime-all oracle
/// share, so they cannot diverge. Takes the engine's state as split
/// borrows because callers iterate the coupled map while calling it.
#[allow(clippy::too_many_arguments)]
fn retime_job(
    cj: &mut CoupledJob,
    job: &Job,
    now: f64,
    rescale: bool,
    new_scale: f64,
    source: CommSource<'_>,
    cell_cross: &[u32],
    cell_total: &[u32],
    link_cross: &[u32],
    running: &mut BTreeMap<(SimTime, u64), RunEntry>,
    records: &mut BTreeMap<u64, JobRecord>,
    out: &mut Vec<ScheduledEvent>,
) -> Retimed {
    let comm = match source {
        CommSource::Fresh(net) => {
            let (direct_bg, detour_bg) =
                link_backgrounds(cell_cross, cell_total, link_cross, &cj.cells, true);
            net.comm_slowdown_links(&cj.cells, job.comm_fraction, direct_bg, detour_bg)
        }
        CommSource::Cached => cj.comm,
        CommSource::Unit => 1.0,
    };
    let old_scale = cj.scale;
    if rescale {
        cj.scale = new_scale;
    }
    let dvfs = crate::power::DvfsPoint { scale: cj.scale }.time_factor(job.boundness);
    let slowdown = dvfs * comm;
    // Refresh the cache on *every* visit, elided or not: the invariant
    // the cap-only warm start relies on is "`cj.comm` equals what a
    // fresh recompute would return right now", and `dvfs * a == dvfs
    // * b` does not imply `a == b` bitwise.
    cj.comm = comm;
    // A scale move that leaves the rate untouched (fully memory-bound
    // work: time_factor == 1 for any scale) still changes the job's
    // *power*, so observers must hear about it even though the End
    // stays put.
    if slowdown == cj.slowdown && cj.scale == old_scale {
        return Retimed::Unchanged;
    }
    let mut moved = false;
    if slowdown != cj.slowdown {
        // Work left at nominal rate, derived from the provisional end
        // (exact at any instant while the rate is constant — no settle
        // residue, see the CoupledJob docs).
        let remaining = ((cj.end - now) / cj.slowdown).max(0.0);
        cj.slowdown = slowdown;
        let new_end = now + remaining * slowdown;
        let entry = running
            .remove(&(SimTime(cj.end), cj.seq))
            .expect("running entry of coupled job");
        running.insert((SimTime(new_end), cj.seq), entry);
        cj.end = new_end;
        cj.gen += 1;
        out.push(ScheduledEvent::at(
            new_end,
            Event::End {
                job: job.id,
                booster: cj.booster,
                cells: cj.cells.clone(),
                gen: cj.gen,
            },
        ));
        moved = true;
    }
    if let Some(rec) = records.get_mut(&job.id) {
        rec.end_time = cj.end;
        rec.dvfs_scale = cj.scale;
        rec.min_dvfs_scale = rec.min_dvfs_scale.min(cj.scale);
    }
    out.push(ScheduledEvent::at(
        now,
        Event::Retime {
            job: job.id,
            dvfs_scale: cj.scale,
            end: cj.end,
        },
    ));
    if moved {
        Retimed::Moved
    } else {
        Retimed::Power
    }
}

/// A queued job, compact (12 bytes) so the optimized pass streams a
/// dense array instead of dereferencing into the 56-byte [`Job`] table
/// per entry — the scan over can't-fit entries is the hottest loop in a
/// saturated replay. The baseline path still dereferences `jobs[ji]`
/// per entry (the PR 1 access pattern).
#[derive(Debug, Clone, Copy)]
struct QEntry {
    ji: u32,
    nodes: u32,
    partition: Partition,
}

/// A running job as the engine's hot loops need it (head-reservation
/// walks and completions read nodes/partition without touching the job
/// table).
#[derive(Debug, Clone, Copy)]
struct RunEntry {
    ji: u32,
    nodes: u32,
    partition: Partition,
}

/// Coupled-progress state of one running job (coupled mode only): the
/// job's completion is provisional — the engine keeps the progress rate
/// in effect and re-times the generation-stamped `End` when it changes.
///
/// Remaining work is *derived* from the provisional end —
/// `(end - now) / slowdown`, seconds at nominal rate — never settled
/// into a field. At a constant rate the derivation is exact at any
/// instant, so a re-time that visits a job whose rate is unchanged
/// leaves zero floating-point residue; that is what lets the
/// incremental cell-indexed walk skip untouched jobs bit-for-bit
/// against the retime-all oracle.
#[derive(Debug, Clone)]
struct CoupledJob {
    ji: u32,
    /// Start sequence — the second half of the running-map key.
    seq: u64,
    booster: bool,
    multi_cell: bool,
    /// Interned placement (shared with the Start/End events).
    cells: Cells,
    /// Runtime multiplier in effect (DVFS x congestion), >= 1.
    slowdown: f64,
    /// DVFS workpoint in effect (re-scaled on `CapChange` when cap
    /// coupling is on).
    scale: f64,
    /// Cached congestion factor last folded into `slowdown` — the warm
    /// start for cap-only re-times: a `CapChange` re-scales the DVFS
    /// term and reuses this instead of re-querying the network model
    /// (bit-identical: nothing congestion-relevant changed).
    comm: f64,
    /// Currently scheduled provisional end (the running-map key time).
    end: f64,
    /// Generation of the current `End` event; stale generations are
    /// skipped at pop time.
    gen: u64,
}

impl CoupledJob {
    /// Can the congestion axis change this job's rate? The single
    /// predicate the cell index registration (job start), the index
    /// de-registration (completion) and both re-time walks must agree
    /// on — drift between call sites would desynchronize `cell_jobs`
    /// from the coupled map.
    fn congestion_sensitive(&self, coupling: Coupling, job: &Job) -> bool {
        coupling.congestion && self.booster && self.multi_cell && job.comm_fraction > 0.0
    }
}

/// The event-driven job lifecycle: a [`Component`] translating
/// `Submit`/`End`/`CapChange` events into placement decisions, emitting
/// `Start`/`End` events for observers.
///
/// State the legacy loop recomputed per wake-up is maintained
/// incrementally: free nodes per partition are the scheduler's O(1)
/// counters, running jobs live in a `BTreeMap` keyed by
/// `(end time, start seq)` so both the next completion and the head
/// reservation walk come out in order without re-sorting, and the
/// scheduling pass runs only when an event actually changed capacity or
/// the queue (`dirty`). In optimized mode the pass is additionally
/// pruned by `min_queued_lb`, a per-partition lower bound on the
/// smallest queued node count: when neither partition's free count
/// reaches its bound, no queued job can fit and the pass (or the rest
/// of its queue scan) is skipped — a pure necessary-condition prune, so
/// records stay bit-for-bit identical.
struct JobEngine<'a> {
    sched: &'a mut Scheduler,
    jobs: Vec<Job>,
    idx_of: BTreeMap<u64, usize>,
    /// Queued jobs in FIFO (submit) order.
    queue: Vec<QEntry>,
    /// Running jobs: (end time, start seq) -> run entry.
    running: BTreeMap<(SimTime, u64), RunEntry>,
    start_seq: u64,
    /// Total running nodes across both partitions (power-cap accounting,
    /// matching the legacy loop).
    running_nodes: u32,
    records: BTreeMap<u64, JobRecord>,
    dirty: bool,
    /// Allocation-free fast path on; off = the PR 1 cost baseline.
    optimized: bool,
    /// Lower bound on the smallest queued node count per partition
    /// (`u32::MAX` when nothing of that partition is queued). Tightened
    /// on submit; reset only when a partition's queue empties, so it is
    /// always a sound lower bound.
    min_queued_lb: [u32; 2],
    /// Queued-job count per partition (keeps `min_queued_lb` resettable).
    queued: [u32; 2],
    /// First queue position the next pass must evaluate. Positions
    /// below it are *settled*: they were rejected by a previous pass
    /// and nothing since has made them startable — a Submit changes
    /// neither free counts nor running jobs, rejection by capacity is
    /// unchanged at constant free, and rejection by the EASY window
    /// (`now + est <= res_time`) only hardens as `now` advances toward
    /// a reservation pinned to a running job's end. Reset to 0 by any
    /// `End`/`CapChange` and by any pass that starts a job (starts
    /// change free and may promote a new queue head).
    scan_from: usize,
    /// Scratch: queue positions started by the current pass (reused
    /// across passes — no per-pass allocation).
    started_scratch: Vec<usize>,
    /// Copy of the scheduler's [`Coupling`] config.
    coupling: Coupling,
    /// Coupled-progress state per running job id (coupled mode only).
    coupled: BTreeMap<u64, CoupledJob>,
    /// Per-cell nodes of running multi-cell Booster jobs (the traffic
    /// class that loads the dragonfly global links), indexed by cell id.
    /// The engine's own congestion view — mirrors what a
    /// [`crate::network::CongestionTracker`] observes, but queryable
    /// mid-pass and self-excludable per job.
    cell_cross: Vec<u32>,
    /// Per-global-link cross nodes, indexed by
    /// [`cell_pair_index`] over the `cell_total` id space: the sum over
    /// running multi-cell Booster jobs of their per-route bundle
    /// contributions ([`link_contributions`]). The engine-side dense
    /// per-link load table the re-time pass prices.
    link_cross: Vec<u32>,
    /// Booster node total per cell id (0 = cell not in the partition).
    cell_total: Vec<u32>,
    /// A `Start`/`End`/`CapChange` changed the machine state: re-time
    /// running jobs at the next quiescent point.
    recouple: bool,
    /// A `CapChange` moved the cap level: re-derive every running job's
    /// DVFS workpoint during the next re-time.
    rescale: bool,
    /// Incremental cell-indexed retiming on (optimized engine without
    /// [`Scheduler::retime_all`]); off = the PR 3 retime-all oracle.
    incremental: bool,
    /// Cell → ids of running congestion-sensitive coupled jobs
    /// (multi-cell Booster, `comm_fraction > 0`) — the index a
    /// `Start`/`End` perturbation resolves to the jobs it can actually
    /// re-time. Maintained only in incremental mode.
    cell_jobs: Vec<Vec<u64>>,
    /// Cells whose cross load changed since the last re-time pass
    /// (membership flags + dense list, both persistent scratch).
    cell_dirty: Vec<bool>,
    dirty_cells: Vec<u32>,
    /// Scratch: candidate job ids of the current re-time walk, sorted
    /// ascending so events are emitted in the oracle's (job-id) order.
    retime_ids: Vec<u64>,
    /// Running congestion-sensitive coupled jobs (sizes the elision
    /// count: sensitive jobs minus walked jobs were proven untouched).
    sensitive: usize,
    /// Re-time evaluations elided this run (see [`RunCounters`]).
    retimes_elided: u64,
    /// Remaining nominal work per fault-killed job id, seconds at
    /// nominal clocks (the checkpoint-truncated rework a requeue runs).
    /// Populated only by kills — empty in fault-free runs, so the
    /// pass's run-seconds lookup is byte-neutral.
    rework: BTreeMap<u64, f64>,
    /// `End`-generation base per fault-killed job id: only generations
    /// derived from the base after the latest kill are real, which is
    /// what invalidates a killed job's pending `End` at pop time even
    /// in uncoupled runs. Monotone per job; empty in fault-free runs.
    gen_base: BTreeMap<u64, u64>,
    /// First start time per job killed at least once (the recovery-
    /// stretch anchor).
    fault_first_start: BTreeMap<u64, f64>,
    /// Recovery-stretch samples of killed jobs that finally completed.
    recovery_stretch: Vec<f64>,
    /// Fault counters (see [`RunCounters`]).
    killed: u64,
    requeued: u64,
    wasted_node_seconds: f64,
    /// Internal snapshot slot ([`Component::snapshot`]): boxed so an
    /// engine that never snapshots pays one pointer, and repeated
    /// snapshots reuse every buffer inside.
    snap: Option<Box<EngineSnapshot>>,
}

/// Point-in-time image of a [`JobEngine`] *and* the scheduler-side
/// state it drives (pool free counts, policy-facing cross view, O(1)
/// counters, power cap). Run-constant state (job table, id index, cell
/// totals, coupling/policy config) is not captured — a snapshot is only
/// valid for the session that took it. Maps are saved as sorted pair
/// vectors so the save side is a buffer reuse, not a tree clone.
#[derive(Debug, Clone, Default)]
struct EngineSnapshot {
    booster_free: Vec<u32>,
    dc_free: Vec<u32>,
    placed_cross: Vec<u32>,
    free: [u32; 2],
    power_cap: Option<PowerCap>,
    queue: Vec<QEntry>,
    running: Vec<((SimTime, u64), RunEntry)>,
    start_seq: u64,
    running_nodes: u32,
    records: Vec<(u64, JobRecord)>,
    dirty: bool,
    min_queued_lb: [u32; 2],
    queued: [u32; 2],
    scan_from: usize,
    coupled: Vec<(u64, CoupledJob)>,
    cell_cross: Vec<u32>,
    link_cross: Vec<u32>,
    recouple: bool,
    rescale: bool,
    cell_jobs: Vec<Vec<u64>>,
    cell_dirty: Vec<bool>,
    dirty_cells: Vec<u32>,
    sensitive: usize,
    retimes_elided: u64,
    booster_down: Vec<u32>,
    dc_down: Vec<u32>,
    link_health: Vec<f64>,
    rework: Vec<(u64, f64)>,
    gen_base: Vec<(u64, u64)>,
    fault_first_start: Vec<(u64, f64)>,
    recovery_stretch: Vec<f64>,
    killed: u64,
    requeued: u64,
    wasted_node_seconds: f64,
}

impl<'a> JobEngine<'a> {
    fn new(sched: &'a mut Scheduler, jobs: Vec<Job>, optimized: bool) -> Self {
        let mut idx_of = BTreeMap::new();
        for (i, job) in jobs.iter().enumerate() {
            let prev = idx_of.insert(job.id, i);
            assert!(prev.is_none(), "duplicate job id {}", job.id);
        }
        let coupling = sched.coupling;
        let incremental = optimized && !sched.retime_all;
        let mut cell_total = Vec::new();
        if coupling.congestion {
            cell_total = vec![0u32; sched.booster_by_cell.len()];
            for pool in &sched.booster {
                cell_total[pool.cell_id as usize] = pool.total;
            }
        }
        let cell_cross = vec![0u32; cell_total.len()];
        let link_cross = vec![0u32; cell_pair_count(cell_total.len())];
        let cell_jobs = vec![Vec::new(); cell_total.len()];
        let cell_dirty = vec![false; cell_total.len()];
        JobEngine {
            sched,
            jobs,
            idx_of,
            queue: Vec::new(),
            running: BTreeMap::new(),
            start_seq: 0,
            running_nodes: 0,
            records: BTreeMap::new(),
            dirty: false,
            optimized,
            min_queued_lb: [u32::MAX; 2],
            queued: [0; 2],
            scan_from: 0,
            started_scratch: Vec::new(),
            coupling,
            coupled: BTreeMap::new(),
            cell_cross,
            link_cross,
            cell_total,
            recouple: false,
            rescale: false,
            incremental,
            cell_jobs,
            cell_dirty,
            dirty_cells: Vec::new(),
            retime_ids: Vec::new(),
            sensitive: 0,
            retimes_elided: 0,
            rework: BTreeMap::new(),
            gen_base: BTreeMap::new(),
            fault_first_start: BTreeMap::new(),
            recovery_stretch: Vec::new(),
            killed: 0,
            requeued: 0,
            wasted_node_seconds: 0.0,
            snap: None,
        }
    }

    /// Fill `snap` with the engine's (and its scheduler's) mutable run
    /// state. Every buffer in `snap` is reused — clear+extend or
    /// `clone_from`, never a fresh collection.
    fn save_state_into(&self, snap: &mut EngineSnapshot) {
        snap.booster_free.clear();
        snap.booster_free
            .extend(self.sched.booster.iter().map(|p| p.free));
        snap.dc_free.clear();
        snap.dc_free.extend(self.sched.dc.iter().map(|p| p.free));
        snap.placed_cross.clone_from(&self.sched.placed_cross);
        snap.free = self.sched.free;
        snap.power_cap = self.sched.power_cap;
        snap.queue.clone_from(&self.queue);
        snap.running.clear();
        snap.running.extend(self.running.iter().map(|(&k, &v)| (k, v)));
        snap.start_seq = self.start_seq;
        snap.running_nodes = self.running_nodes;
        snap.records.clear();
        snap.records
            .extend(self.records.iter().map(|(&k, v)| (k, v.clone())));
        snap.dirty = self.dirty;
        snap.min_queued_lb = self.min_queued_lb;
        snap.queued = self.queued;
        snap.scan_from = self.scan_from;
        snap.coupled.clear();
        snap.coupled
            .extend(self.coupled.iter().map(|(&k, v)| (k, v.clone())));
        snap.cell_cross.clone_from(&self.cell_cross);
        snap.link_cross.clone_from(&self.link_cross);
        snap.recouple = self.recouple;
        snap.rescale = self.rescale;
        snap.cell_jobs.clone_from(&self.cell_jobs);
        snap.cell_dirty.clone_from(&self.cell_dirty);
        snap.dirty_cells.clone_from(&self.dirty_cells);
        snap.sensitive = self.sensitive;
        snap.retimes_elided = self.retimes_elided;
        snap.booster_down.clear();
        snap.booster_down
            .extend(self.sched.booster.iter().map(|p| p.down));
        snap.dc_down.clear();
        snap.dc_down.extend(self.sched.dc.iter().map(|p| p.down));
        match self.sched.net.as_ref() {
            Some(net) => net.save_link_health(&mut snap.link_health),
            None => snap.link_health.clear(),
        }
        snap.rework.clear();
        snap.rework.extend(self.rework.iter().map(|(&k, &v)| (k, v)));
        snap.gen_base.clear();
        snap.gen_base
            .extend(self.gen_base.iter().map(|(&k, &v)| (k, v)));
        snap.fault_first_start.clear();
        snap.fault_first_start
            .extend(self.fault_first_start.iter().map(|(&k, &v)| (k, v)));
        snap.recovery_stretch.clone_from(&self.recovery_stretch);
        snap.killed = self.killed;
        snap.requeued = self.requeued;
        snap.wasted_node_seconds = self.wasted_node_seconds;
    }

    /// Rewind the engine (and its scheduler) to the state `snap` holds.
    /// The generation stamps inside `coupled` come back exactly as
    /// saved, so any stale `End` restored into the kernel queue is
    /// re-skipped at pop time with the same accounting as the original
    /// run — `events_skipped` stays report-neutral across a fork.
    fn load_state_from(&mut self, snap: &EngineSnapshot) {
        for (pool, &free) in self.sched.booster.iter_mut().zip(&snap.booster_free) {
            pool.free = free;
        }
        for (pool, &free) in self.sched.dc.iter_mut().zip(&snap.dc_free) {
            pool.free = free;
        }
        self.sched.placed_cross.clone_from(&snap.placed_cross);
        self.sched.free = snap.free;
        self.sched.power_cap = snap.power_cap;
        self.queue.clone_from(&snap.queue);
        self.running.clear();
        self.running.extend(snap.running.iter().copied());
        self.start_seq = snap.start_seq;
        self.running_nodes = snap.running_nodes;
        self.records.clear();
        self.records
            .extend(snap.records.iter().map(|(k, v)| (*k, v.clone())));
        self.dirty = snap.dirty;
        self.min_queued_lb = snap.min_queued_lb;
        self.queued = snap.queued;
        self.scan_from = snap.scan_from;
        self.coupled.clear();
        self.coupled
            .extend(snap.coupled.iter().map(|(k, v)| (*k, v.clone())));
        self.cell_cross.clone_from(&snap.cell_cross);
        self.link_cross.clone_from(&snap.link_cross);
        self.recouple = snap.recouple;
        self.rescale = snap.rescale;
        self.cell_jobs.clone_from(&snap.cell_jobs);
        self.cell_dirty.clone_from(&snap.cell_dirty);
        self.dirty_cells.clone_from(&snap.dirty_cells);
        self.sensitive = snap.sensitive;
        self.retimes_elided = snap.retimes_elided;
        for (pool, &down) in self.sched.booster.iter_mut().zip(&snap.booster_down) {
            pool.down = down;
        }
        for (pool, &down) in self.sched.dc.iter_mut().zip(&snap.dc_down) {
            pool.down = down;
        }
        if let Some(net) = self.sched.net.as_mut() {
            if !snap.link_health.is_empty() {
                net.restore_link_health(&snap.link_health);
            }
        }
        self.rework.clear();
        self.rework.extend(snap.rework.iter().copied());
        self.gen_base.clear();
        self.gen_base.extend(snap.gen_base.iter().copied());
        self.fault_first_start.clear();
        self.fault_first_start
            .extend(snap.fault_first_start.iter().copied());
        self.recovery_stretch.clone_from(&snap.recovery_stretch);
        self.killed = snap.killed;
        self.requeued = snap.requeued;
        self.wasted_node_seconds = snap.wasted_node_seconds;
    }

    /// True unless the free-vs-lower-bound prune proves no queued job
    /// of either partition can fit right now.
    fn any_could_fit(&self) -> bool {
        self.sched.free[0] >= self.min_queued_lb[0]
            || self.sched.free[1] >= self.min_queued_lb[1]
    }

    /// Earliest time the queue head could start: walk running jobs in
    /// end-time order (the map's native order) instead of re-sorting.
    fn head_reservation(&self, now: f64) -> Option<(f64, Partition, u32)> {
        let head = *self.queue.first()?;
        let mut free = self.sched.free[pidx(head.partition)];
        if free >= head.nodes {
            return Some((now, head.partition, head.nodes));
        }
        for (&(t, _), r) in &self.running {
            if r.partition != head.partition {
                continue;
            }
            free += r.nodes;
            if free >= head.nodes {
                return Some((t.0, head.partition, head.nodes));
            }
        }
        None
    }

    /// DVFS scale for a start of `new_nodes` (O(1) via the counter;
    /// same formula as the legacy loop via [`Scheduler::dvfs_scale_at`]).
    fn dvfs_scale(&self, new_nodes: u32) -> f64 {
        self.sched.dvfs_scale_at(self.running_nodes + new_nodes)
    }

    /// `(direct, detour)` background on `cells` from *other* running
    /// multi-cell Booster jobs — the per-link picture
    /// [`Network::link_bw_for_cells`] prices. `exclude_own` subtracts
    /// this job's own per-cell and per-pair contributions — set once
    /// the job's `Start` has been folded into the counts (a job's own
    /// surface traffic is already modelled by the cross-fraction term
    /// of the bandwidth model, not background).
    fn background_for(&self, cells: &[(u32, u32)], exclude_own: bool) -> (f64, f64) {
        link_backgrounds(
            &self.cell_cross,
            &self.cell_total,
            &self.link_cross,
            cells,
            exclude_own,
        )
    }

    /// Fold a multi-cell Booster job's placement into (sign > 0) or out
    /// of (sign < 0) the per-cell and per-link cross-traffic counts.
    /// Single-cell jobs never touch the global links; DataCentric
    /// traffic does not ride the GPU fabric's global link budget.
    /// Returns whether the congestion picture changed — the caller's
    /// re-time trigger, so the (dominant) single-cell traffic never
    /// provokes a no-op re-time walk.
    fn cross_update(&mut self, booster: bool, cells: &[(u32, u32)], sign: i64) -> bool {
        if !self.coupling.congestion || !booster || cells.len() <= 1 {
            return false;
        }
        for &(cell, nodes) in cells {
            if let Some(c) = self.cell_cross.get_mut(cell as usize) {
                let total = self.cell_total[cell as usize] as i64;
                let next = *c as i64 + sign * nodes as i64;
                *c = next.clamp(0, total) as u32;
                // Incremental retiming: remember which cells moved so
                // the next re-time pass visits only jobs indexed there.
                // A link bundle is dirty exactly when both its endpoint
                // cells are, so the dirty-cell set already covers the
                // dirty-link walk (link-sharing implies cell-sharing).
                if self.incremental && !self.cell_dirty[cell as usize] {
                    self.cell_dirty[cell as usize] = true;
                    self.dirty_cells.push(cell);
                }
            }
        }
        // Per-route bundle loads: the same contribution definition the
        // observing tracker and the conservation property test use.
        let n = self.cell_total.len();
        for ((a, b), nodes) in link_contributions(cells) {
            let (ai, bi) = (a as usize, b as usize);
            if ai >= n || bi >= n {
                continue;
            }
            let cap = (self.cell_total[ai] + self.cell_total[bi]) as i64;
            let idx = cell_pair_index(n, a, b);
            let next = self.link_cross[idx] as i64 + sign * nodes as i64;
            self.link_cross[idx] = next.clamp(0, cap) as u32;
        }
        true
    }

    /// Congestion slowdown for a job under the current per-link cross
    /// loads. 1.0 when the axis is off, the job is DataCentric or
    /// single-cell, or it does not communicate.
    fn comm_slowdown_for(
        &self,
        booster: bool,
        cells: &[(u32, u32)],
        comm_fraction: f64,
        exclude_own: bool,
    ) -> f64 {
        if !self.coupling.congestion || !booster || cells.len() <= 1 {
            return 1.0;
        }
        let net = self.sched.net.as_ref().expect("checked in run_mode");
        let (direct_bg, detour_bg) = self.background_for(cells, exclude_own);
        net.comm_slowdown_links(cells, comm_fraction, direct_bg, detour_bg)
    }

    /// Complete every running job whose end falls within `TIME_EPS` of
    /// `now` (the legacy loop's completion tolerance).
    fn complete_due(&mut self, now: f64) {
        while let Some((&(t, seq), &r)) = self.running.first_key_value() {
            if t.0 > now + TIME_EPS {
                break;
            }
            self.running.remove(&(t, seq));
            let id = self.jobs[r.ji as usize].id;
            if self.optimized {
                // Release straight from the record — no placement clone.
                let rec = self.records.get(&id).expect("record of running job");
                self.sched.release(r.partition, &rec.placement);
            } else {
                // PR 1 copied the placement out of the record per
                // release; the baseline keeps that cost.
                let placement = self.records.get(&id).unwrap().placement.clone();
                self.sched.release(r.partition, &placement);
            }
            self.running_nodes -= r.nodes;
            if self.coupling.enabled() {
                if let Some(cj) = self.coupled.remove(&id) {
                    if cj.congestion_sensitive(self.coupling, &self.jobs[cj.ji as usize]) {
                        self.sensitive -= 1;
                        if self.incremental {
                            // Drop the job from the cell index (order
                            // within a cell list is irrelevant: walks
                            // sort candidate ids).
                            for &(cell, _) in cj.cells.iter() {
                                if let Some(list) = self.cell_jobs.get_mut(cell as usize)
                                {
                                    if let Some(p) = list.iter().position(|&j| j == id) {
                                        list.swap_remove(p);
                                    }
                                }
                            }
                        }
                    }
                }
            }
            // A previously killed job finally made it: close its rework
            // entry and sample the recovery stretch. No-op (one empty-
            // map lookup) in fault-free runs.
            if self.rework.remove(&id).is_some() {
                if let Some(&first) = self.fault_first_start.get(&id) {
                    let run_s = self.jobs[r.ji as usize].run_seconds;
                    if run_s > 0.0 {
                        self.recovery_stretch.push((t.0 - first) / run_s);
                    }
                }
            }
            self.dirty = true;
        }
    }

    /// Re-time running jobs' provisional `End`s from the current machine
    /// state (coupled mode): derive each affected job's new slowdown
    /// (DVFS x congestion) and, when the completion moved, bump the
    /// job's generation, re-key the running map and enqueue a fresh
    /// `End` (plus a `Retime` so observers close their rate segments).
    /// The stale `End` stays in the queue and is skipped at pop time.
    ///
    /// In incremental mode only the jobs the cell index resolves from
    /// the dirty cells are visited (all jobs on a cap re-scale, which
    /// reuses each job's cached congestion factor); in retime-all mode
    /// (the baseline engine, or [`Scheduler::retime_all`]) every coupled
    /// job is re-derived — the PR 3 cost shape. Both walks funnel into
    /// [`retime_job`], and emit in ascending job-id order, so they are
    /// bit-for-bit identical.
    fn retime(&mut self, now: f64, out: &mut Vec<ScheduledEvent>) {
        let rescale = std::mem::take(&mut self.rescale) && self.coupling.cap;
        // One cached DVFS workpoint for the whole pass: the cap moved
        // once, so every running job re-scales through this single
        // factor (the cap-only warm start).
        let new_scale = if rescale {
            self.sched.dvfs_scale_at(self.running_nodes)
        } else {
            1.0
        };
        let mut moved = false;
        if self.incremental {
            // Candidate set: every coupled job on a cap re-scale, else
            // exactly the jobs indexed under a perturbed cell. Sorted
            // ascending so the emission order matches the oracle's
            // coupled-map (job-id) walk.
            self.retime_ids.clear();
            if rescale {
                self.retime_ids.extend(self.coupled.keys().copied());
            } else {
                for &cell in &self.dirty_cells {
                    self.retime_ids.extend_from_slice(&self.cell_jobs[cell as usize]);
                }
                self.retime_ids.sort_unstable();
                self.retime_ids.dedup();
                // Everything the index proved untouched is an elided
                // re-time the oracle would have recomputed for nothing.
                self.retimes_elided += (self.sensitive - self.retime_ids.len()) as u64;
            }
            for &job_id in &self.retime_ids {
                let cj = self
                    .coupled
                    .get_mut(&job_id)
                    .expect("indexed job missing from coupled map");
                let job = &self.jobs[cj.ji as usize];
                let congestion_sensitive = cj.congestion_sensitive(self.coupling, job);
                if !rescale && !congestion_sensitive {
                    continue; // index holds only sensitive jobs; guard anyway
                }
                // Re-query the network model only when one of this
                // job's cells actually moved; cap-only re-times reuse
                // the cached factor (bit-identical by construction).
                let touched = congestion_sensitive
                    && cj
                        .cells
                        .iter()
                        .any(|&(c, _)| self.cell_dirty.get(c as usize).copied().unwrap_or(false));
                let source = if touched {
                    CommSource::Fresh(self.sched.net.as_ref().expect("checked in run_mode"))
                } else {
                    CommSource::Cached
                };
                match retime_job(
                    cj,
                    job,
                    now,
                    rescale,
                    new_scale,
                    source,
                    &self.cell_cross,
                    &self.cell_total,
                    &self.link_cross,
                    &mut self.running,
                    &mut self.records,
                    out,
                ) {
                    Retimed::Unchanged => self.retimes_elided += 1,
                    Retimed::Moved => moved = true,
                    Retimed::Power => {}
                }
            }
        } else {
            // The retained PR 3 retime-all oracle: walk every coupled
            // job (ascending id — the map order) and re-derive its rate
            // from scratch.
            for cj in self.coupled.values_mut() {
                let job = &self.jobs[cj.ji as usize];
                let congestion_sensitive = cj.congestion_sensitive(self.coupling, job);
                if !rescale && !congestion_sensitive {
                    // Neither axis can change this job's rate.
                    continue;
                }
                let source = if congestion_sensitive {
                    CommSource::Fresh(self.sched.net.as_ref().expect("checked in run_mode"))
                } else {
                    CommSource::Unit
                };
                match retime_job(
                    cj,
                    job,
                    now,
                    rescale,
                    new_scale,
                    source,
                    &self.cell_cross,
                    &self.cell_total,
                    &self.link_cross,
                    &mut self.running,
                    &mut self.records,
                    out,
                ) {
                    Retimed::Unchanged => self.retimes_elided += 1,
                    Retimed::Moved => moved = true,
                    Retimed::Power => {}
                }
            }
        }
        // The perturbations are consumed either way (the oracle never
        // reads them, but they must not leak into the next pass).
        for &cell in &self.dirty_cells {
            self.cell_dirty[cell as usize] = false;
        }
        self.dirty_cells.clear();
        if moved {
            // Provisional ends moved: head reservations (and with them
            // the EASY backfill window) changed, so the settled-prefix
            // and no-op-pass conclusions no longer hold.
            self.dirty = true;
            self.scan_from = 0;
        }
    }

    /// One scheduling pass: head strictly FIFO, the rest EASY backfill.
    /// Semantically identical to one iteration of the legacy loop.
    fn pass(&mut self, now: f64, out: &mut Vec<ScheduledEvent>) {
        if self.optimized && !self.any_could_fit() {
            // Nothing queued can fit — provably a no-op pass, and every
            // entry is settled until free nodes change.
            self.scan_from = self.queue.len();
            return;
        }
        // The head reservation walks the running map. Optimized passes
        // defer it until first needed — but it must be pinned to the
        // *pass-entry* state, so it is always materialized before the
        // pass's first start mutates free/running (see below). The
        // baseline computes it eagerly per pass like PR 1 did.
        let mut head_res: Option<Option<(f64, Partition, u32)>> = if self.optimized {
            None
        } else {
            Some(self.head_reservation(now))
        };
        self.started_scratch.clear();
        // Settled prefix (optimized mode): positions below `scan_from`
        // were rejected by an earlier pass and nothing startable has
        // changed for them — a full sweep would reject them again with
        // identical free counts, so skipping them is decision-identical.
        let begin = if self.optimized {
            self.scan_from.min(self.queue.len())
        } else {
            0
        };
        for qpos in begin..self.queue.len() {
            if self.optimized && !self.any_could_fit() {
                break; // remaining scan provably starts nothing
            }
            let entry = self.queue[qpos];
            // The optimized scan reads the dense queue entry; the
            // baseline keeps PR 1's per-entry deref into the job table.
            let (nodes, partition) = if self.optimized {
                (entry.nodes, entry.partition)
            } else {
                let j = &self.jobs[entry.ji as usize];
                (j.nodes, j.partition)
            };
            let pi = pidx(partition);
            let free_p = self.sched.free[pi];
            if free_p < nodes {
                continue; // head waits; others may backfill
            }
            if qpos > 0 {
                let hr = match head_res {
                    Some(hr) => hr,
                    None => {
                        let hr = self.head_reservation(now);
                        head_res = Some(hr);
                        hr
                    }
                };
                if let Some((res_time, res_part, res_nodes)) = hr {
                    // Would this backfill delay the head?
                    let est = self.jobs[entry.ji as usize].est_seconds;
                    let fits_before = now + est <= res_time + 1e-9;
                    let disjoint = partition != res_part || free_p - nodes >= res_nodes;
                    if !fits_before && !disjoint {
                        continue;
                    }
                }
            }
            if head_res.is_none() {
                // This start is the queue head (qpos == 0 never consults
                // the reservation). Materialize it NOW, while free and
                // running are still the pass-entry state — a lazy
                // computation after this start would see the head's own
                // nodes as consumed and mis-reserve for later backfill
                // candidates (any qpos > 0 path materialized it above).
                head_res = Some(self.head_reservation(now));
            }
            let job = &self.jobs[entry.ji as usize];
            let scale = self.dvfs_scale(nodes);
            let placement = if self.optimized {
                self.sched.place(partition, nodes)
            } else {
                self.sched.place_scan(partition, nodes)
            }
            .expect("checked free counter");
            let booster = partition == Partition::Booster;
            let coupled = self.coupling.enabled();
            let dvfs = crate::power::DvfsPoint { scale }.time_factor(job.boundness);
            // Initial provisional rate: the congestion term joins the
            // DVFS term. Loads from starts earlier in this same batch
            // are folded in by the re-time pass that follows the Start
            // dispatches at this same timestamp (which also refreshes
            // the cached factor to its self-excluded form).
            let comm = if coupled {
                self.comm_slowdown_for(
                    booster,
                    &placement.nodes_per_cell,
                    job.comm_fraction,
                    false,
                )
            } else {
                1.0
            };
            let slowdown = dvfs * comm;
            // A requeued job runs only its checkpoint-truncated rework;
            // its generations restart above the post-kill base so the
            // dead attempt's pending End stays stale. Both lookups hit
            // empty maps in fault-free runs.
            let run_s = self.rework.get(&job.id).copied().unwrap_or(job.run_seconds);
            let end = now + run_s * slowdown;
            let gen = self.gen_base.get(&job.id).copied().unwrap_or(0) + u64::from(coupled);
            let (start_cells, end_cells): (Cells, Cells) = if self.optimized {
                // One interned copy per job, shared by Start and End.
                let cells: Cells = Arc::from(placement.nodes_per_cell.as_slice());
                (cells.clone(), cells)
            } else {
                // PR 1 cloned the cell list once per event.
                (
                    Arc::from(placement.nodes_per_cell.as_slice()),
                    Arc::from(placement.nodes_per_cell.as_slice()),
                )
            };
            if coupled {
                let cj = CoupledJob {
                    ji: entry.ji,
                    seq: self.start_seq,
                    booster,
                    multi_cell: placement.nodes_per_cell.len() > 1,
                    cells: end_cells.clone(),
                    slowdown,
                    scale,
                    comm,
                    end,
                    gen,
                };
                if cj.congestion_sensitive(self.coupling, job) {
                    self.sensitive += 1;
                    if self.incremental {
                        // Register the job under every cell it spans so
                        // perturbations there resolve straight to it —
                        // and mark those cells dirty: a re-time in THIS
                        // quiescent (triggered by an earlier event in
                        // the batch, before the job's own Start has
                        // dispatched) walks every coupled job in the
                        // oracle, so the index must resolve the newborn
                        // too.
                        for &(cell, _) in placement.nodes_per_cell.iter() {
                            if let Some(list) = self.cell_jobs.get_mut(cell as usize) {
                                list.push(job.id);
                            }
                            if let Some(flag) = self.cell_dirty.get_mut(cell as usize) {
                                if !*flag {
                                    *flag = true;
                                    self.dirty_cells.push(cell);
                                }
                            }
                        }
                    }
                }
                self.coupled.insert(job.id, cj);
            }
            out.push(ScheduledEvent::at(
                now,
                Event::Start {
                    job: job.id,
                    booster,
                    dvfs_scale: scale,
                    cells: start_cells,
                },
            ));
            out.push(ScheduledEvent::at(
                end,
                Event::End {
                    job: job.id,
                    booster,
                    cells: end_cells,
                    gen,
                },
            ));
            self.records.insert(
                job.id,
                JobRecord {
                    id: job.id,
                    start_time: now,
                    end_time: end,
                    placement,
                    dvfs_scale: scale,
                    min_dvfs_scale: scale,
                },
            );
            self.running.insert(
                (SimTime(end), self.start_seq),
                RunEntry {
                    ji: entry.ji,
                    nodes,
                    partition,
                },
            );
            self.start_seq += 1;
            self.running_nodes += nodes;
            self.queued[pi] -= 1;
            if self.queued[pi] == 0 {
                self.min_queued_lb[pi] = u32::MAX;
            }
            self.started_scratch.push(qpos);
        }
        if !self.started_scratch.is_empty() {
            let mut rm = self.started_scratch.iter().copied().peekable();
            let mut i = 0usize;
            self.queue.retain(|_| {
                let drop = rm.peek() == Some(&i);
                if drop {
                    rm.next();
                }
                i += 1;
                !drop
            });
        }
        // Starts changed free counts (and may have promoted a new
        // head): rescan everything next time. A no-start pass settles
        // the whole queue until an End/CapChange perturbs it.
        self.scan_from = if self.started_scratch.is_empty() {
            self.queue.len()
        } else {
            0
        };
    }

    /// Resolve a cell id to `(partition, pool position)` — Booster
    /// first (GPU cells), then DataCentric; `None` for a cell with no
    /// schedulable nodes.
    fn pool_of_cell(&self, cell: u32) -> Option<(Partition, usize)> {
        if let Some(&pos) = self.sched.booster_by_cell.get(cell as usize) {
            if pos != NO_POOL {
                return Some((Partition::Booster, pos as usize));
            }
        }
        if let Some(&pos) = self.sched.dc_by_cell.get(cell as usize) {
            if pos != NO_POOL {
                return Some((Partition::DataCentric, pos as usize));
            }
        }
        None
    }

    /// A `NodeDown` fault: kill running jobs on the cell (lowest id
    /// first — deterministic victim order) until the downed capacity
    /// can be carved out of the free pool, then move it from `free` to
    /// `down`. Kills release their placements, requeue through fresh
    /// `Submit`s in this same batch, and charge wasted work; survivors
    /// sharing perturbed cells re-time at the next quiescent point.
    fn node_down(&mut self, now: f64, cell: u32, nodes: u32, out: &mut Vec<ScheduledEvent>) {
        let Some((partition, pos)) = self.pool_of_cell(cell) else {
            return;
        };
        let pool = match partition {
            Partition::Booster => &self.sched.booster[pos],
            Partition::DataCentric => &self.sched.dc[pos],
        };
        let want = nodes.min(pool.total - pool.down);
        if want == 0 {
            return;
        }
        loop {
            let free = match partition {
                Partition::Booster => self.sched.booster[pos].free,
                Partition::DataCentric => self.sched.dc[pos].free,
            };
            if free >= want {
                break;
            }
            let mut victim: Option<u64> = None;
            for r in self.running.values() {
                if r.partition != partition {
                    continue;
                }
                let id = self.jobs[r.ji as usize].id;
                let rec = &self.records[&id];
                if rec.placement.nodes_per_cell.iter().any(|&(c, _)| c == cell) {
                    victim = Some(victim.map_or(id, |v| v.min(id)));
                }
            }
            let Some(id) = victim else { break };
            self.kill_job(now, id, out);
        }
        let pi = pidx(partition);
        let pool = match partition {
            Partition::Booster => &mut self.sched.booster[pos],
            Partition::DataCentric => &mut self.sched.dc[pos],
        };
        let take = want.min(pool.free);
        pool.free -= take;
        pool.down += take;
        self.sched.free[pi] -= take;
        self.dirty = true;
        self.scan_from = 0;
    }

    /// A `NodeUp` repair: return downed nodes to the free pool, clamped
    /// to the downed count so a stray (or oversized) repair can never
    /// double-free capacity.
    fn node_up(&mut self, cell: u32, nodes: u32) {
        let Some((partition, pos)) = self.pool_of_cell(cell) else {
            return;
        };
        let pool = match partition {
            Partition::Booster => &mut self.sched.booster[pos],
            Partition::DataCentric => &mut self.sched.dc[pos],
        };
        let restore = nodes.min(pool.down);
        if restore == 0 {
            return;
        }
        pool.down -= restore;
        pool.free += restore;
        self.sched.free[pidx(partition)] += restore;
        self.dirty = true;
        self.scan_from = 0;
    }

    /// A link fault: scale the bundle's capacity (`factor < 1`) or
    /// restore it (`1.0`) in the scheduler's network model, and mark
    /// both endpoint cells dirty so every sensitive job priced over the
    /// bundle re-times at the next quiescent point.
    fn link_health_change(&mut self, bundle: u32, factor: f64) {
        let Some(net) = self.sched.net.as_mut() else {
            return;
        };
        net.set_link_health(bundle as usize, factor);
        if !self.coupling.congestion {
            return;
        }
        let n = self.cell_total.len();
        'pairs: for a in 0..n as u32 {
            for b in (a + 1)..n as u32 {
                if cell_pair_index(n, a, b) != bundle as usize {
                    continue;
                }
                for cell in [a, b] {
                    if self.incremental && !self.cell_dirty[cell as usize] {
                        self.cell_dirty[cell as usize] = true;
                        self.dirty_cells.push(cell);
                    }
                }
                self.recouple = true;
                break 'pairs;
            }
        }
    }

    /// Kill one running job at `now`: release its placement, invalidate
    /// its pending `End` (generation-base bump), charge the wall-clock
    /// node time its [`CheckpointPolicy`] cannot recover, and requeue
    /// it with the remaining (possibly truncated) rework. Emits a
    /// `Kill` notification for observers plus the requeueing `Submit`.
    fn kill_job(&mut self, now: f64, id: u64, out: &mut Vec<ScheduledEvent>) {
        let key = self
            .running
            .iter()
            .find(|(_, r)| self.jobs[r.ji as usize].id == id)
            .map(|(&k, _)| k)
            .expect("kill of a job that is not running");
        let entry = self.running.remove(&key).expect("running entry");
        let rec = self.records.remove(&id).expect("record of running job");
        self.sched.release(entry.partition, &rec.placement);
        self.running_nodes -= entry.nodes;
        let job = &self.jobs[entry.ji as usize];
        let run_seconds = job.run_seconds;
        let checkpoint = job.checkpoint;
        let booster = entry.partition == Partition::Booster;
        let nominal_total = self.rework.get(&id).copied().unwrap_or(run_seconds);
        let planned = (rec.end_time - rec.start_time).max(0.0);
        let elapsed = (now - rec.start_time).clamp(0.0, planned);
        let cj = self.coupled.remove(&id);
        // Remaining nominal work: exact from the coupled provisional
        // end (the rate is piecewise-constant and the end tracks every
        // move); proportional for the frozen uncoupled end.
        let remaining_nominal = match &cj {
            Some(cj) => ((cj.end - now) / cj.slowdown).max(0.0),
            None if planned > 0.0 => ((rec.end_time - now).max(0.0) / planned) * nominal_total,
            None => 0.0,
        };
        let done = (nominal_total - remaining_nominal).max(0.0);
        let gen = match &cj {
            Some(cj) => cj.gen,
            None => self.gen_base.get(&id).copied().unwrap_or(0),
        };
        self.gen_base.insert(id, gen + 1);
        let kill_cells: Cells = match &cj {
            Some(cj) => cj.cells.clone(),
            None => Arc::from(rec.placement.nodes_per_cell.as_slice()),
        };
        if let Some(cj) = cj {
            if cj.congestion_sensitive(self.coupling, &self.jobs[cj.ji as usize]) {
                self.sensitive -= 1;
                if self.incremental {
                    for &(c, _) in cj.cells.iter() {
                        if let Some(list) = self.cell_jobs.get_mut(c as usize) {
                            if let Some(p) = list.iter().position(|&j| j == id) {
                                list.swap_remove(p);
                            }
                        }
                    }
                }
            }
            if self.cross_update(cj.booster, &cj.cells, -1) {
                self.recouple = true;
            }
        }
        let retained = match checkpoint {
            CheckpointPolicy::None => 0.0,
            CheckpointPolicy::Periodic(interval) if interval > 0.0 => {
                ((done / interval).floor() * interval).min(done)
            }
            CheckpointPolicy::Periodic(_) => done,
        };
        let requeued = matches!(checkpoint, CheckpointPolicy::Periodic(_));
        // Wall-clock share of the elapsed time whose progress no
        // checkpoint covers — the node time actually thrown away.
        let wasted_s = if done > 0.0 {
            elapsed * (1.0 - retained / done)
        } else {
            0.0
        };
        self.rework.insert(id, (nominal_total - retained).max(0.0));
        self.fault_first_start.entry(id).or_insert(rec.start_time);
        self.killed += 1;
        if requeued {
            self.requeued += 1;
        }
        self.wasted_node_seconds += entry.nodes as f64 * wasted_s;
        out.push(ScheduledEvent::at(
            now,
            Event::Kill {
                job: id,
                booster,
                cells: kill_cells,
                wasted_s,
                requeued,
            },
        ));
        out.push(ScheduledEvent::at(now, Event::Submit { job: id }));
        self.dirty = true;
        self.scan_from = 0;
    }

    /// The fault conservation invariant: per partition, pool free
    /// counts sum to the O(1) counter and `free + down + running ==
    /// total`; per cell, `free + down <= total`.
    fn assert_conserved(&self) {
        let mut running = [0u64; 2];
        for r in self.running.values() {
            running[pidx(r.partition)] += r.nodes as u64;
        }
        for (pi, pools) in [&self.sched.booster, &self.sched.dc].into_iter().enumerate() {
            let mut free = 0u64;
            let mut down = 0u64;
            let mut total = 0u64;
            for pool in pools.iter() {
                assert!(
                    pool.free + pool.down <= pool.total,
                    "cell {}: free {} + down {} exceeds total {}",
                    pool.cell_id,
                    pool.free,
                    pool.down,
                    pool.total
                );
                free += pool.free as u64;
                down += pool.down as u64;
                total += pool.total as u64;
            }
            assert_eq!(free, self.sched.free[pi] as u64, "free counter drift");
            assert_eq!(
                free + down + running[pi],
                total,
                "partition {pi}: free {free} + down {down} + running {} != total",
                running[pi]
            );
        }
    }
}

impl Component for JobEngine<'_> {
    fn on_event(&mut self, now: f64, ev: &Event, out: &mut Vec<ScheduledEvent>) {
        match ev {
            Event::Submit { job } => {
                if let Some(&ji) = self.idx_of.get(job) {
                    let job = &self.jobs[ji];
                    let pi = pidx(job.partition);
                    self.queue.push(QEntry {
                        ji: ji as u32,
                        nodes: job.nodes,
                        partition: job.partition,
                    });
                    self.queued[pi] += 1;
                    if job.nodes < self.min_queued_lb[pi] {
                        self.min_queued_lb[pi] = job.nodes;
                    }
                    self.dirty = true;
                }
            }
            // Releases happen in the quiescent completion sweep so
            // equal-time Ends and Submits see one consistent pass.
            Event::End { booster, cells, .. } => {
                self.dirty = true;
                self.scan_from = 0; // free nodes change: full rescan
                if self.coupling.enabled() && self.cross_update(*booster, cells, -1) {
                    self.recouple = true;
                }
            }
            Event::CapChange { cap_mw } => {
                match *cap_mw {
                    None => {
                        self.sched.power_cap = None;
                        self.dirty = true;
                        self.scan_from = 0;
                        if self.coupling.cap {
                            self.recouple = true;
                            self.rescale = true;
                        }
                    }
                    Some(mw) => match self.sched.power_cap.as_mut() {
                        Some(cap) => {
                            cap.cap_mw = mw;
                            self.dirty = true;
                            self.scan_from = 0;
                            if self.coupling.cap {
                                self.recouple = true;
                                self.rescale = true;
                            }
                        }
                        // No watt model configured: the scheduler cannot
                        // invent one for an arbitrary machine, so a level
                        // change on a capless scheduler is a no-op. Set
                        // `power_cap` (see `PowerCap::for_model`) before
                        // the run to make cap events effective.
                        None => {}
                    },
                }
            }
            // Self-emitted. In coupled mode the Start dispatch is where
            // the job's cross-traffic joins the congestion view, so
            // every running job (itself included, self-excluded at
            // query time) re-times against it at the next quiescent.
            Event::Start { booster, cells, .. } => {
                if self.coupling.enabled() && self.cross_update(*booster, cells, 1) {
                    self.recouple = true;
                }
            }
            // Informational for observers; the engine produced it.
            Event::Retime { .. } => {}
            // Fault events: kills (and their requeueing Submits) are
            // processed synchronously here, so the pools are settled
            // before this batch's quiescent scheduling pass runs.
            Event::NodeDown { cell, nodes } => self.node_down(now, *cell, *nodes, out),
            Event::NodeUp { cell, nodes } => self.node_up(*cell, *nodes),
            Event::LinkDegraded { bundle, factor } => self.link_health_change(*bundle, *factor),
            Event::LinkRestored { bundle } => self.link_health_change(*bundle, 1.0),
            // Self-emitted notification for observers.
            Event::Kill { .. } => {}
        }
    }

    fn on_quiescent(&mut self, now: f64, out: &mut Vec<ScheduledEvent>) {
        self.complete_due(now);
        if self.dirty {
            self.dirty = false;
            self.pass(now, out);
        }
        // Re-time after the pass: the pass's own starts dispatch at this
        // same timestamp and set `recouple` again, so the state they
        // change is folded in before the clock moves.
        if self.coupling.enabled() && self.recouple {
            self.recouple = false;
            self.retime(now, out);
        }
    }

    fn accept_event(&mut self, _now: f64, ev: &Event) -> bool {
        if let Event::End { job, gen, .. } = ev {
            // A fault-killed job's pending End is stale the moment the
            // kill bumps its generation base: only the live coupled
            // generation (or, uncoupled, the base itself — what the
            // requeued start stamps) is real. Checked before the
            // coupling gate so kills invalidate Ends in uncoupled runs
            // too; the map is empty in fault-free runs.
            if let Some(&base) = self.gen_base.get(job) {
                return match self.coupled.get(job) {
                    Some(cj) => *gen == cj.gen,
                    None => *gen == base,
                };
            }
            if !self.coupling.enabled() {
                return true;
            }
            // Only the current generation of a coupled job's End is
            // real; re-timed-away generations are stale. A job absent
            // from the coupled map already completed (its current End
            // fired), so any stamped End left for it is stale too.
            return match self.coupled.get(job) {
                Some(cj) => *gen == cj.gen,
                None => *gen == 0,
            };
        }
        true
    }

    fn snapshot(&mut self) {
        let mut snap = self.snap.take().unwrap_or_default();
        self.save_state_into(&mut snap);
        self.snap = Some(snap);
    }

    fn restore(&mut self) {
        let snap = self
            .snap
            .take()
            .expect("JobEngine::restore without a prior snapshot");
        self.load_state_from(&snap);
        self.snap = Some(snap);
    }
}

/// A resumable replay over a caller-owned [`Simulation`] arena — the
/// in-flight form of [`Scheduler::run_with`] (which is now a thin
/// wrapper over it). Where `run_with` drives a private kernel to
/// exhaustion, a session exposes the run as first-class state: run to a
/// time limit, [`ReplaySession::snapshot`] every layer, keep going,
/// [`ReplaySession::restore`], and replay a different suffix. That
/// snapshot/fork/replay cycle is what the campaign's divergence-tree
/// sweeps use to simulate a shared scenario prefix once.
///
/// Injected `extra_events` are scheduled in the *divergent sequence
/// band* ([`crate::sim::DIVERGENT_SEQ_BASE`], ranked by list position),
/// so they tie-break after every runtime-emitted event at the same
/// timestamp whether they were queued upfront (streaming sweep) or
/// pushed after a fork ([`ReplaySession::schedule_ranked`]) — the
/// invariant that keeps forked suffixes byte-identical to full replays.
pub struct ReplaySession<'a> {
    sim: &'a mut Simulation,
    engine: JobEngine<'a>,
    sim_snap: SimSnapshot,
}

impl<'a> ReplaySession<'a> {
    /// Open a session on the optimized engine. `sim` is reset (queue
    /// cleared allocation-retained, clock and counters rewound) and
    /// seeded with the jobs' `Submit`s plus `extra_events` in the
    /// divergent band.
    pub fn new(
        sim: &'a mut Simulation,
        sched: &'a mut Scheduler,
        jobs: Vec<Job>,
        extra_events: Vec<ScheduledEvent>,
    ) -> Self {
        Self::with_mode(sim, sched, jobs, extra_events, true)
    }

    fn with_mode(
        sim: &'a mut Simulation,
        sched: &'a mut Scheduler,
        mut jobs: Vec<Job>,
        extra_events: Vec<ScheduledEvent>,
        optimized: bool,
    ) -> Self {
        assert!(
            !(sched.coupling.congestion && sched.net.is_none()),
            "congestion coupling needs a network model: use Scheduler::with_coupling \
             or set Scheduler::net"
        );
        jobs.sort_by(|a, b| a.submit_time.total_cmp(&b.submit_time).then(a.id.cmp(&b.id)));
        sim.reset();
        for job in &jobs {
            // Virtual time starts at 0: the legacy loop admitted any
            // earlier submit at t=0, so clamp to keep that behaviour.
            sim.schedule(job.submit_time.max(0.0), Event::Submit { job: job.id });
        }
        for (rank, se) in extra_events.into_iter().enumerate() {
            sim.schedule_ranked(se.time, se.event, rank as u64);
        }
        let engine = JobEngine::new(sched, jobs, optimized);
        ReplaySession {
            sim,
            engine,
            sim_snap: SimSnapshot::default(),
        }
    }

    /// Inject one event into the divergent band mid-session — the fork
    /// path pushes a scenario's cap move here after restoring. Ranks
    /// must not collide with still-pending injected events at the same
    /// timestamp.
    pub fn schedule_ranked(&mut self, time: f64, event: Event, rank: u64) {
        self.sim.schedule_ranked(time, event, rank);
    }

    /// Advance until the queue is exhausted or the next batch would
    /// start at `t_limit` or later.
    pub fn run_until(&mut self, t_limit: f64, observers: &mut [&mut dyn Component]) {
        let mut comps: Vec<&mut dyn Component> = Vec::with_capacity(1 + observers.len());
        comps.push(&mut self.engine);
        for o in observers.iter_mut() {
            comps.push(&mut **o);
        }
        self.sim.run_until(t_limit, &mut comps);
    }

    /// Run to queue exhaustion.
    pub fn run_to_end(&mut self, observers: &mut [&mut dyn Component]) {
        self.run_until(f64::INFINITY, observers);
    }

    /// Capture every layer — kernel (queue, clock, counters), engine +
    /// scheduler-side state, and each observer's internal slot. Repeat
    /// snapshots reuse every buffer.
    pub fn snapshot(&mut self, observers: &mut [&mut dyn Component]) {
        self.sim.save_into(&mut self.sim_snap);
        self.engine.snapshot();
        for o in observers.iter_mut() {
            o.snapshot();
        }
    }

    /// Rewind every layer to the last [`ReplaySession::snapshot`]. The
    /// observer list must match the one the snapshot saw.
    pub fn restore(&mut self, observers: &mut [&mut dyn Component]) {
        self.sim.restore_from(&self.sim_snap);
        self.engine.restore();
        for o in observers.iter_mut() {
            o.restore();
        }
    }

    /// Per-job records completed (or provisionally running) so far.
    pub fn records(&self) -> &BTreeMap<u64, JobRecord> {
        &self.engine.records
    }

    /// The session's job table (sorted by `(submit_time, id)`).
    pub fn jobs(&self) -> &[Job] {
        &self.engine.jobs
    }

    /// Kernel skip counter, retime elisions and fault-robustness
    /// counters of the session so far.
    pub fn counters(&self) -> RunCounters {
        RunCounters {
            events_skipped: self.sim.events_skipped(),
            retimes_elided: self.engine.retimes_elided,
            killed: self.engine.killed,
            requeued: self.engine.requeued,
            wasted_node_seconds: self.engine.wasted_node_seconds,
            recovery_p95: p95(&self.engine.recovery_stretch),
        }
    }

    /// Assert the fault conservation invariant: per partition,
    /// `free + down + running == total` and the O(1) free counter
    /// matches the pool sum. Cheap enough to call per step in tests.
    pub fn assert_conserved(&self) {
        self.engine.assert_conserved();
    }

    /// Assert the workload fully drained (every job placed and done).
    pub fn assert_complete(&self) {
        assert!(
            self.engine.queue.is_empty(),
            "scheduler stuck: {} jobs can never be placed",
            self.engine.queue.len()
        );
        debug_assert!(
            self.engine.coupled.is_empty(),
            "coupled jobs left running: {}",
            self.engine.coupled.len()
        );
    }

    /// Close the session: assert completion, publish the counters into
    /// [`Scheduler::last_run`] and hand back the records.
    pub fn finish(mut self) -> BTreeMap<u64, JobRecord> {
        self.assert_complete();
        let counters = self.counters();
        self.engine.sched.last_run = counters;
        std::mem::take(&mut self.engine.records)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::util::rng::Rng;

    fn sched() -> Scheduler {
        Scheduler::new(&MachineConfig::leonardo())
    }

    fn job(id: u64, nodes: u32, secs: f64, submit: f64) -> Job {
        Job {
            id,
            partition: Partition::Booster,
            nodes,
            est_seconds: secs,
            run_seconds: secs,
            submit_time: submit,
            boundness: 1.0,
            comm_fraction: 0.0,
            checkpoint: CheckpointPolicy::None,
        }
    }

    #[test]
    fn pools_match_machine_inventory() {
        let s = sched();
        assert_eq!(s.total_nodes(Partition::Booster), 3456);
        assert_eq!(s.total_nodes(Partition::DataCentric), 1536);
        assert_eq!(s.free_nodes(Partition::Booster), 3456);
    }

    #[test]
    fn small_jobs_stay_in_one_cell() {
        let mut s = sched();
        // A Booster cell holds 6 x 30 = 180 nodes.
        let p = s.place(Partition::Booster, 150).unwrap();
        assert_eq!(p.cells_used(), 1);
        assert_eq!(p.total_nodes(), 150);
    }

    #[test]
    fn big_jobs_span_minimal_cells() {
        let mut s = sched();
        // 2475 nodes (the Table 7 maximum) needs ceil(2475/180) = 14 cells.
        let p = s.place(Partition::Booster, 2475).unwrap();
        assert_eq!(p.cells_used(), 14);
        assert_eq!(p.total_nodes(), 2475);
    }

    #[test]
    fn place_release_roundtrip() {
        let mut s = sched();
        let p = s.place(Partition::Booster, 2000).unwrap();
        assert_eq!(s.free_nodes(Partition::Booster), 3456 - 2000);
        s.release(Partition::Booster, &p);
        assert_eq!(s.free_nodes(Partition::Booster), 3456);
    }

    /// Regression for the O(cells) release scan: a max-span 14-cell
    /// placement releases through the cell-id index, restores every
    /// pool exactly, and the next placement is bit-identical to a fresh
    /// scheduler's.
    #[test]
    fn max_span_release_restores_every_cell() {
        let mut s = sched();
        let p = s.place(Partition::Booster, 2475).unwrap();
        assert_eq!(p.cells_used(), 14);
        s.release(Partition::Booster, &p);
        assert_eq!(s.free_nodes(Partition::Booster), 3456);
        // Pool-level restoration: placing the same job again must give
        // the same cells as a fresh scheduler would.
        let again = s.place(Partition::Booster, 2475).unwrap();
        let fresh = sched().place(Partition::Booster, 2475).unwrap();
        assert_eq!(again.nodes_per_cell, fresh.nodes_per_cell);
    }

    #[test]
    #[should_panic(expected = "release to unknown cell")]
    fn release_to_unknown_cell_panics() {
        let mut s = sched();
        let bogus = Placement {
            nodes_per_cell: vec![(9999, 10)],
        };
        s.release(Partition::Booster, &bogus);
    }

    /// The in-place-order fast path and the seed's allocate-and-sort
    /// path make identical placement decisions through arbitrary
    /// place/release interleavings.
    #[test]
    fn place_matches_place_scan_through_interleavings() {
        let mut fast = sched();
        let mut slow = sched();
        let mut rng = Rng::new(31);
        let mut live: Vec<Placement> = Vec::new();
        for step in 0..400 {
            if !live.is_empty() && rng.f64() < 0.4 {
                let i = (rng.next_u64() % live.len() as u64) as usize;
                let p = live.swap_remove(i);
                fast.release(Partition::Booster, &p);
                slow.release(Partition::Booster, &p);
            } else {
                let n = rng.range_u32(1, 600);
                let a = fast.place(Partition::Booster, n);
                let b = slow.place_scan(Partition::Booster, n);
                match (a, b) {
                    (None, None) => {}
                    (Some(pa), Some(pb)) => {
                        assert_eq!(
                            pa.nodes_per_cell, pb.nodes_per_cell,
                            "step {step}: divergent placement for {n} nodes"
                        );
                        live.push(pa);
                    }
                    (a, b) => panic!("step {step}: fit disagreement {a:?} vs {b:?}"),
                }
            }
            assert_eq!(
                fast.free_nodes(Partition::Booster),
                slow.free_nodes(Partition::Booster),
                "step {step}: counter drift"
            );
        }
    }

    #[test]
    fn oversized_request_is_rejected() {
        let mut s = sched();
        assert!(s.place(Partition::Booster, 4000).is_none());
    }

    #[test]
    fn fifo_order_without_contention() {
        let mut s = sched();
        let jobs = vec![job(1, 100, 50.0, 0.0), job(2, 100, 50.0, 0.0)];
        let rec = s.run(jobs);
        assert_eq!(rec[&1].start_time, 0.0);
        assert_eq!(rec[&2].start_time, 0.0); // capacity for both at once
    }

    #[test]
    fn backfill_runs_small_job_in_the_hole() {
        let mut s = sched();
        // Job 1 takes the whole machine for 100 s. Job 2 (huge) must wait.
        // Job 3 (small, short) backfills without delaying job 2.
        let jobs = vec![
            job(1, 3456, 100.0, 0.0),
            job(2, 3456, 100.0, 1.0),
            job(3, 10, 50.0, 2.0),
        ];
        let rec = s.run(jobs);
        assert_eq!(rec[&1].start_time, 0.0);
        assert!((rec[&2].start_time - 100.0).abs() < 1e-6);
        // job 3 ran inside job 2's shadow — after 1 ends it fits before 2
        // could ever need the nodes... but 2 needs ALL nodes, so 3 may
        // only run once 1 is done and must not push 2 beyond its
        // reservation. With est 50 > 0 overlap impossible: 3 starts at
        // 100 would delay 2 — so 3 waits until 2 finishes.
        assert!(rec[&3].start_time >= rec[&2].start_time);
        assert!((rec[&2].start_time - 100.0).abs() < 1e-6, "head not delayed");
    }

    #[test]
    fn backfill_uses_disjoint_capacity() {
        let mut s = sched();
        // Head needs 3456 (whole booster); a 100-node job cannot help
        // delaying it. But a DC job is disjoint and backfills freely.
        let mut dcjob = job(3, 100, 500.0, 2.0);
        dcjob.partition = Partition::DataCentric;
        let jobs = vec![job(1, 3000, 100.0, 0.0), job(2, 3456, 100.0, 1.0), dcjob];
        let rec = s.run(jobs);
        assert!((rec[&3].start_time - 2.0).abs() < 1e-6);
        assert!((rec[&2].start_time - 100.0).abs() < 1e-6);
    }

    #[test]
    fn power_cap_throttles_runtime() {
        let mut s = sched();
        s.power_cap = Some(PowerCap {
            cap_mw: 4.0,
            node_watts: 2238.0,
            idle_watts: 365.0,
        });
        let jobs = vec![job(1, 3000, 100.0, 0.0)];
        let rec = s.run(jobs);
        assert!(rec[&1].dvfs_scale < 1.0);
        assert!(rec[&1].end_time > 100.0);
    }

    #[test]
    fn no_power_cap_runs_at_nominal() {
        let mut s = sched();
        let rec = s.run(vec![job(1, 3000, 100.0, 0.0)]);
        assert_eq!(rec[&1].dvfs_scale, 1.0);
        assert!((rec[&1].end_time - 100.0).abs() < 1e-9);
    }

    #[test]
    fn all_jobs_eventually_complete() {
        let mut s = sched();
        let jobs: Vec<Job> = (0..50)
            .map(|i| job(i, 500 + (i as u32 * 97) % 2000, 10.0 + i as f64, i as f64))
            .collect();
        let rec = s.run(jobs.clone());
        assert_eq!(rec.len(), jobs.len());
        for j in &jobs {
            let r = &rec[&j.id];
            assert!(r.start_time >= j.submit_time - 1e-9);
            assert!(r.end_time > r.start_time);
            assert_eq!(r.placement.total_nodes(), j.nodes);
        }
        // Machine fully free afterwards.
        assert_eq!(s.free_nodes(Partition::Booster), 3456);
    }

    fn random_stream(seed: u64, n_jobs: u32) -> Vec<Job> {
        let mut rng = Rng::new(seed);
        (0..n_jobs)
            .map(|i| {
                let booster = rng.f64() < 0.7;
                Job {
                    id: i as u64,
                    partition: if booster {
                        Partition::Booster
                    } else {
                        Partition::DataCentric
                    },
                    nodes: rng.range_u32(1, if booster { 3456 } else { 1536 }),
                    est_seconds: rng.range_f64(1.0, 500.0),
                    run_seconds: rng.range_f64(1.0, 500.0),
                    submit_time: rng.range_f64(0.0, 100.0),
                    boundness: rng.f64(),
                    comm_fraction: rng.f64() * 0.5,
                    checkpoint: CheckpointPolicy::None,
                }
            })
            .collect()
    }

    /// The optimized engine, the PR 1 event baseline and the legacy
    /// loop are bit-for-bit equivalent.
    #[test]
    fn event_engine_matches_rescan_loop() {
        for seed in 0..6u64 {
            let jobs = random_stream(seed, 80);
            let ev = sched().run(jobs.clone());
            let baseline = sched().run_event_baseline(jobs.clone());
            let legacy = sched().run_rescan(jobs);
            assert_eq!(ev.len(), legacy.len(), "seed {seed}");
            for (id, r) in &ev {
                let l = &legacy[id];
                assert_eq!(r.start_time, l.start_time, "seed {seed} job {id}");
                assert_eq!(r.end_time, l.end_time, "seed {seed} job {id}");
                assert_eq!(r.dvfs_scale, l.dvfs_scale, "seed {seed} job {id}");
                assert_eq!(
                    r.placement.nodes_per_cell, l.placement.nodes_per_cell,
                    "seed {seed} job {id}"
                );
                let b = &baseline[id];
                assert_eq!(r.start_time, b.start_time, "seed {seed} job {id} (base)");
                assert_eq!(r.end_time, b.end_time, "seed {seed} job {id} (base)");
                assert_eq!(
                    r.placement.nodes_per_cell, b.placement.nodes_per_cell,
                    "seed {seed} job {id} (base)"
                );
            }
        }
    }

    /// Same equivalence under a facility power cap (DVFS path).
    #[test]
    fn event_engine_matches_rescan_under_cap() {
        for seed in 10..14u64 {
            let jobs = random_stream(seed, 50);
            let cap = PowerCap {
                cap_mw: 5.0,
                node_watts: 2238.0,
                idle_watts: 365.0,
            };
            let mut a = sched();
            a.power_cap = Some(cap);
            let ev = a.run(jobs.clone());
            let mut b = sched();
            b.power_cap = Some(cap);
            let legacy = b.run_rescan(jobs);
            for (id, r) in &ev {
                let l = &legacy[id];
                assert_eq!(r.start_time, l.start_time, "seed {seed} job {id}");
                assert_eq!(r.end_time, l.end_time, "seed {seed} job {id}");
                assert_eq!(r.dvfs_scale, l.dvfs_scale, "seed {seed} job {id}");
            }
        }
    }

    #[test]
    fn cap_change_event_throttles_later_jobs_only() {
        let mut s = sched();
        // Two identical whole-machine jobs back to back; the cap lands
        // between their starts.
        let jobs = vec![job(1, 3000, 100.0, 0.0), job(2, 3000, 100.0, 50.0)];
        let cap = PowerCap {
            cap_mw: 4.0,
            node_watts: 2238.0,
            idle_watts: 365.0,
        };
        let events = vec![ScheduledEvent::at(
            99.0,
            Event::CapChange {
                cap_mw: Some(cap.cap_mw),
            },
        )];
        s.power_cap = Some(PowerCap { cap_mw: 99.0, ..cap });
        let rec = s.run_with(jobs, events, &mut []);
        assert_eq!(rec[&1].dvfs_scale, 1.0, "started under the loose cap");
        assert!(rec[&2].dvfs_scale < 1.0, "started after the 4 MW cap");
    }

    #[test]
    fn cap_change_without_watt_model_is_ignored() {
        let mut s = sched();
        assert!(s.power_cap.is_none());
        let events = vec![ScheduledEvent::at(0.0, Event::CapChange { cap_mw: Some(4.0) })];
        let rec = s.run_with(vec![job(1, 3000, 100.0, 1.0)], events, &mut []);
        // No watt model to build a cap from: the job runs at nominal.
        assert_eq!(rec[&1].dvfs_scale, 1.0);
        assert!(s.power_cap.is_none());
    }

    /// Cap coupling without any cap movement is a no-op: the retimer
    /// runs (every Start/End perturbs it) but recomputes the same
    /// slowdowns, so records stay bit-for-bit the uncoupled engine's.
    #[test]
    fn cap_coupling_without_cap_events_is_identity() {
        let cfg = MachineConfig::leonardo();
        for seed in 0..3u64 {
            let jobs = random_stream(seed, 60);
            let plain = sched().run(jobs.clone());
            let mut coupled = Scheduler::with_coupling(
                &cfg,
                Coupling {
                    congestion: false,
                    cap: true,
                },
            );
            let recs = coupled.run(jobs);
            assert_eq!(plain.len(), recs.len(), "seed {seed}");
            for (id, r) in &recs {
                let p = &plain[id];
                assert_eq!(r.start_time, p.start_time, "seed {seed} job {id}");
                assert_eq!(r.end_time, p.end_time, "seed {seed} job {id}");
                assert_eq!(r.dvfs_scale, p.dvfs_scale, "seed {seed} job {id}");
            }
        }
    }

    /// Congestion coupling leaves single-cell (and zero-comm) jobs at
    /// their nominal runtime.
    #[test]
    fn congestion_coupling_spares_compute_bound_jobs() {
        let cfg = MachineConfig::leonardo();
        let mut s = Scheduler::with_coupling(&cfg, Coupling::full());
        // Single-cell jobs: below the global links, no stretch.
        let mut a = job(1, 150, 100.0, 0.0);
        a.comm_fraction = 0.9;
        // Multi-cell but pure compute: no comm to stretch.
        let mut b = job(2, 400, 100.0, 0.0);
        b.comm_fraction = 0.0;
        let rec = s.run(vec![a, b]);
        assert!((rec[&1].end_time - rec[&1].start_time - 100.0).abs() < 1e-9);
        assert!((rec[&2].end_time - rec[&2].start_time - 100.0).abs() < 1e-9);
        assert!(rec[&2].placement.cells_used() > 1);
    }

    /// Congestion coupling stretches a comm-bound multi-cell job even on
    /// an otherwise idle machine (its own spread is the first congestion
    /// source), and the record's provisional end reflects it.
    #[test]
    fn congestion_coupling_stretches_comm_bound_multi_cell_job() {
        let cfg = MachineConfig::leonardo();
        let mut s = Scheduler::with_coupling(&cfg, Coupling::full());
        let mut a = job(1, 400, 100.0, 0.0);
        a.comm_fraction = 0.6;
        let rec = s.run(vec![a]);
        let dur = rec[&1].end_time - rec[&1].start_time;
        assert!(rec[&1].placement.cells_used() > 1);
        assert!(dur > 100.0, "no stretch: {dur}");
        // Bounded: the comm share can stretch, the compute share can't.
        assert!(dur < 100.0 * (0.4 + 0.6 * 10.0), "runaway stretch: {dur}");
    }

    /// A CapChange mid-job re-times the running job's End when cap
    /// coupling is on (and leaves it frozen when off).
    #[test]
    fn cap_change_retimes_running_job_when_coupled() {
        let cfg = MachineConfig::leonardo();
        let cap = PowerCap {
            cap_mw: 99.0,
            node_watts: 2238.0,
            idle_watts: 365.0,
        };
        let events = || vec![ScheduledEvent::at(50.0, Event::CapChange { cap_mw: Some(4.0) })];
        // Frozen end without coupling.
        let mut plain = sched();
        plain.power_cap = Some(cap);
        let rec = plain.run_with(vec![job(1, 3000, 100.0, 0.0)], events(), &mut []);
        assert_eq!(rec[&1].end_time, 100.0);
        // Coupled: 50 s at nominal, the remaining 50 s stretched by the
        // exact DVFS factor of the 4 MW cap on 3000 busy nodes.
        let mut coupled = Scheduler::with_coupling(
            &cfg,
            Coupling {
                congestion: false,
                cap: true,
            },
        );
        coupled.power_cap = Some(cap);
        let rec = coupled.run_with(vec![job(1, 3000, 100.0, 0.0)], events(), &mut []);
        let draw_mw = (3000.0 * 2238.0 + 456.0 * 365.0) / 1e6;
        let scale = (4.0 / draw_mw).sqrt().clamp(0.5, 1.0);
        let expected = 50.0 + 50.0 * (1.0 / scale);
        assert!(
            (rec[&1].end_time - expected).abs() < 1e-9,
            "{} vs {expected}",
            rec[&1].end_time
        );
        assert_eq!(rec[&1].dvfs_scale, scale, "record carries the final scale");
    }

    /// Observers receive the full lifecycle stream.
    #[test]
    fn observers_see_submit_start_end() {
        struct Counter {
            submits: u32,
            starts: u32,
            ends: u32,
        }
        impl Component for Counter {
            fn on_event(&mut self, _now: f64, ev: &Event, _out: &mut Vec<ScheduledEvent>) {
                match ev {
                    Event::Submit { .. } => self.submits += 1,
                    Event::Start { .. } => self.starts += 1,
                    Event::End { .. } => self.ends += 1,
                    _ => {}
                }
            }
        }
        let mut c = Counter {
            submits: 0,
            starts: 0,
            ends: 0,
        };
        let jobs: Vec<Job> = (0..20).map(|i| job(i, 200, 30.0, i as f64)).collect();
        let rec = sched().run_with(jobs, Vec::new(), &mut [&mut c]);
        assert_eq!(rec.len(), 20);
        assert_eq!((c.submits, c.starts, c.ends), (20, 20, 20));
    }

    #[test]
    fn policy_kind_registry_is_consistent() {
        assert_eq!(PolicyKind::default(), PolicyKind::PackFirst);
        assert_eq!(PolicyKind::PackFirst.name(), "pack");
        assert_eq!(PolicyKind::SpreadLinks.name(), "spread");
        for kind in PolicyKind::all() {
            assert_eq!(kind.build().name(), kind.name());
            let s = Scheduler::with_policy(&MachineConfig::leonardo(), kind);
            assert_eq!(s.policy_kind(), kind);
        }
    }

    /// An explicitly installed PackFirst is bit-for-bit the default
    /// scheduler — the pluggable-policy seam changes nothing.
    #[test]
    fn explicit_pack_first_is_bit_for_bit_the_default() {
        let cfg = MachineConfig::leonardo();
        for seed in 0..3u64 {
            let jobs = random_stream(seed, 60);
            let default_recs = sched().run(jobs.clone());
            let explicit = Scheduler::with_policy(&cfg, PolicyKind::PackFirst).run(jobs);
            assert_eq!(default_recs.len(), explicit.len(), "seed {seed}");
            for (id, r) in &explicit {
                let d = &default_recs[id];
                assert_eq!(r.start_time, d.start_time, "seed {seed} job {id}");
                assert_eq!(r.end_time, d.end_time, "seed {seed} job {id}");
                assert_eq!(
                    r.placement.nodes_per_cell, d.placement.nodes_per_cell,
                    "seed {seed} job {id}"
                );
            }
        }
    }

    /// SpreadLinks: spanning requests avoid cells hosting multi-cell
    /// placements, single-cell requests park next to them, and release
    /// drains the policy view back to PackFirst-equivalent behavior.
    #[test]
    fn spread_links_places_around_multi_cell_neighbours() {
        let cfg = MachineConfig::leonardo();
        let mut s = Scheduler::with_policy(&cfg, PolicyKind::SpreadLinks);
        // First spanning job: idle machine, places like PackFirst.
        let a = s.place(Partition::Booster, 270).unwrap();
        assert_eq!(a.nodes_per_cell, vec![(0, 180), (1, 90)]);
        // Second spanning job: link-clean cells come first, so it
        // avoids `a`'s cells entirely (PackFirst would reuse cell 1's
        // free nodes once the clean 180s ran out).
        let b = s.place(Partition::Booster, 270).unwrap();
        let a_cells: Vec<u32> = a.nodes_per_cell.iter().map(|&(c, _)| c).collect();
        assert!(
            b.nodes_per_cell.iter().all(|&(c, _)| !a_cells.contains(&c)),
            "spread placement overlapped a loaded cell: {:?} vs {:?}",
            b.nodes_per_cell,
            a.nodes_per_cell
        );
        // A single-cell request parks on a loaded cell (cell 1 and the
        // cells of `b` have 90 free and cross traffic; clean cells have
        // more free but stay reserved for spanners).
        let c = s.place(Partition::Booster, 60).unwrap();
        assert_eq!(c.nodes_per_cell.len(), 1);
        assert_eq!(c.nodes_per_cell[0].0, 1, "{:?}", c.nodes_per_cell);
        // Draining everything restores fresh-machine behavior.
        s.release(Partition::Booster, &a);
        s.release(Partition::Booster, &b);
        s.release(Partition::Booster, &c);
        let again = s.place(Partition::Booster, 270).unwrap();
        assert_eq!(again.nodes_per_cell, vec![(0, 180), (1, 90)]);
    }

    /// A NodeDown that doesn't fit in the free pool kills the running
    /// job; with no checkpoints the requeue repeats everything.
    #[test]
    fn node_down_kills_and_requeues_with_full_rework() {
        let mut s = sched();
        let events = vec![ScheduledEvent::at(60.0, Event::NodeDown { cell: 0, nodes: 10 })];
        let rec = s.run_with(vec![job(1, 180, 100.0, 0.0)], events, &mut []);
        // Killed at 60 on cell 0, restarted from scratch on surviving
        // capacity: completes a full 100 s later.
        assert_eq!(rec[&1].start_time, 60.0);
        assert_eq!(rec[&1].end_time, 160.0);
        assert_eq!(s.last_run.killed, 1);
        assert_eq!(s.last_run.requeued, 0);
        // All 60 elapsed seconds on 180 nodes were wasted.
        assert!((s.last_run.wasted_node_seconds - 60.0 * 180.0).abs() < 1e-6);
        // Recovery stretch: first start 0, final end 160, nominal 100.
        assert!((s.last_run.recovery_p95 - 1.6).abs() < 1e-9);
    }

    /// Periodic checkpoints truncate the rework to the last completed
    /// boundary and charge only the overshoot as waste.
    #[test]
    fn periodic_checkpoint_truncates_rework() {
        let mut s = sched();
        let mut j = job(1, 180, 100.0, 0.0);
        j.checkpoint = CheckpointPolicy::Periodic(45.0);
        let events = vec![ScheduledEvent::at(60.0, Event::NodeDown { cell: 0, nodes: 10 })];
        let rec = s.run_with(vec![j], events, &mut []);
        // 60 s done, last checkpoint at 45: requeue with 55 s rework.
        assert!((rec[&1].end_time - 115.0).abs() < 1e-9);
        assert_eq!(s.last_run.killed, 1);
        assert_eq!(s.last_run.requeued, 1);
        // Only the 15 s past the checkpoint were thrown away.
        assert!((s.last_run.wasted_node_seconds - 15.0 * 180.0).abs() < 1e-6);
    }

    /// NodeUp restores exactly the downed capacity: oversized and
    /// repeated repairs are clamped, never double-freeing nodes.
    #[test]
    fn node_up_restores_without_double_free() {
        let mut s = sched();
        let events = vec![
            ScheduledEvent::at(10.0, Event::NodeDown { cell: 0, nodes: 50 }),
            ScheduledEvent::at(20.0, Event::NodeUp { cell: 0, nodes: 500 }),
            ScheduledEvent::at(30.0, Event::NodeUp { cell: 0, nodes: 50 }),
        ];
        let jobs = vec![job(1, 10, 5.0, 0.0), job(2, 3456, 1.0, 25.0)];
        let rec = s.run_with(jobs, events, &mut []);
        // Free capacity is back to the full machine at 25, so the
        // whole-partition job starts on submit — and the late stray
        // NodeUp must not push free past total.
        assert_eq!(rec[&2].start_time, 25.0);
        assert_eq!(s.free_nodes(Partition::Booster), 3456);
        assert_eq!(s.last_run.killed, 0);
    }

    /// Fault events on a cell outside every partition are ignored.
    #[test]
    fn fault_on_unknown_cell_is_ignored() {
        let mut s = sched();
        let events = vec![
            ScheduledEvent::at(1.0, Event::NodeDown { cell: 9999, nodes: 10 }),
            ScheduledEvent::at(2.0, Event::NodeUp { cell: 9999, nodes: 10 }),
        ];
        let rec = s.run_with(vec![job(1, 100, 10.0, 0.0)], events, &mut []);
        assert_eq!(rec[&1].end_time, 10.0);
        assert_eq!(s.free_nodes(Partition::Booster), 3456);
    }

    /// Faults compose with runtime coupling: the killed job's stale
    /// coupled End is skipped, survivors re-time, and the requeued
    /// attempt completes with truncated rework.
    #[test]
    fn faults_compose_with_coupling() {
        let cfg = MachineConfig::leonardo();
        let mut s = Scheduler::with_coupling(&cfg, Coupling::full());
        let mut a = job(1, 400, 100.0, 0.0);
        a.comm_fraction = 0.5;
        a.checkpoint = CheckpointPolicy::Periodic(10.0);
        let mut b = job(2, 150, 400.0, 0.0);
        b.comm_fraction = 0.2;
        let events = vec![ScheduledEvent::at(50.0, Event::NodeDown { cell: 0, nodes: 30 })];
        let rec = s.run_with(vec![a, b], events, &mut []);
        assert_eq!(s.last_run.killed, 1, "the multi-cell job on cell 0 dies");
        assert_eq!(s.last_run.requeued, 1);
        assert!(rec[&1].start_time >= 50.0, "job 1 requeued after the fault");
        assert!(rec[&1].end_time > rec[&1].start_time);
        assert!(s.last_run.wasted_node_seconds > 0.0);
        assert_eq!(s.free_nodes(Partition::Booster), 3456 - 30);
    }

    /// Both engines and the rescan loop stay bit-for-bit identical
    /// under every named policy — the policy object is shared, so the
    /// oracle suites cover each policy on each engine.
    #[test]
    fn engines_agree_under_every_policy() {
        let cfg = MachineConfig::leonardo();
        for kind in PolicyKind::all() {
            for seed in 0..3u64 {
                let jobs = random_stream(seed, 60);
                let make = || Scheduler::with_policy(&cfg, kind);
                let ev = make().run(jobs.clone());
                let baseline = make().run_event_baseline(jobs.clone());
                let legacy = make().run_rescan(jobs);
                assert_eq!(ev.len(), legacy.len(), "{kind:?} seed {seed}");
                for (id, r) in &ev {
                    let l = &legacy[id];
                    let b = &baseline[id];
                    let ctx = format!("{kind:?} seed {seed} job {id}");
                    assert_eq!(r.start_time, l.start_time, "{ctx}");
                    assert_eq!(r.end_time, l.end_time, "{ctx}");
                    assert_eq!(r.placement.nodes_per_cell, l.placement.nodes_per_cell, "{ctx}");
                    assert_eq!(r.start_time, b.start_time, "{ctx} (base)");
                    assert_eq!(
                        r.placement.nodes_per_cell, b.placement.nodes_per_cell,
                        "{ctx} (base)"
                    );
                }
            }
        }
    }
}
