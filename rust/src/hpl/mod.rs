//! A real (small-scale) HPL: right-looking blocked LU factorization whose
//! trailing-matrix updates run on the AOT Pallas GEMM through PJRT.
//!
//! This is the algorithm behind Table 4's headline number, implemented
//! rather than merely modelled: panel factorization (partial pivoting)
//! on the host, `C <- C - A @ B` tile updates on the XLA executable. The
//! measured update rate is what `perfmodel::Calibration` feeds into the
//! fleet-scale HPL model; the factorization itself is validated by
//! reconstructing `P A ~ L U` in tests.
//!
//! The matrix is kept column-major-by-blocks? No — plain row-major with
//! explicit block staging into the 256x256 tiles the `hpl_update_256`
//! artifact expects.

use anyhow::Result;

use crate::runtime::{literal_f32, Engine};
use crate::util::rng::Rng;

/// Block size of the AOT trailing-update artifact (`hpl_update_256`).
pub const NB: usize = 256;

/// Outcome of a factorization.
#[derive(Debug, Clone)]
pub struct LuResult {
    /// Matrix order.
    pub n: usize,
    /// Row permutation (pivoting), `perm[i]` = original row index.
    pub perm: Vec<usize>,
    /// Wall time, seconds.
    pub seconds: f64,
    /// Achieved rate over the 2n^3/3 flops of LU, GFLOPS.
    pub gflops: f64,
    /// Fraction of flops executed on the PJRT executable.
    pub offload_fraction: f64,
}

/// In-place blocked LU with partial pivoting; `a` is row-major n x n.
///
/// Trailing updates for full NB x NB tiles are dispatched to the engine
/// when one is provided; edge tiles and panels run on the host.
pub fn lu_factor(a: &mut [f32], n: usize, engine: Option<&Engine>) -> Result<LuResult> {
    assert_eq!(a.len(), n * n);
    let start = std::time::Instant::now();
    let mut perm: Vec<usize> = (0..n).collect();
    let mut offloaded_flops = 0f64;

    let mut k = 0usize;
    while k < n {
        let nb = NB.min(n - k);

        // --- panel factorization (host): columns k..k+nb
        for j in k..k + nb {
            // pivot search in column j, rows j..n
            let mut piv = j;
            let mut best = a[j * n + j].abs();
            for i in (j + 1)..n {
                let v = a[i * n + j].abs();
                if v > best {
                    best = v;
                    piv = i;
                }
            }
            if piv != j {
                perm.swap(j, piv);
                for c in 0..n {
                    a.swap(j * n + c, piv * n + c);
                }
            }
            let d = a[j * n + j];
            anyhow::ensure!(d.abs() > 1e-12, "singular pivot at {j}");
            let inv = 1.0 / d;
            for i in (j + 1)..n {
                a[i * n + j] *= inv;
            }
            // rank-1 update within the panel
            let jmax = (k + nb).min(n);
            for i in (j + 1)..n {
                let lij = a[i * n + j];
                if lij != 0.0 {
                    for c in (j + 1)..jmax {
                        a[i * n + c] -= lij * a[j * n + c];
                    }
                }
            }
        }

        let rest = k + nb;
        if rest < n {
            // --- U12 solve: L11^-1 * A12 (unit lower triangular, host)
            for j in k..rest {
                for i in (j + 1)..rest {
                    let lij = a[i * n + j];
                    if lij != 0.0 {
                        for c in rest..n {
                            a[i * n + c] -= lij * a[j * n + c];
                        }
                    }
                }
            }

            // --- trailing update: A22 <- A22 - L21 * U12, tile by tile
            let m2 = n - rest;
            for bi in (0..m2).step_by(NB) {
                for bj in (0..m2).step_by(NB) {
                    let ti = NB.min(m2 - bi);
                    let tj = NB.min(m2 - bj);
                    if ti == NB && tj == NB && nb == NB && engine.is_some() {
                        offloaded_flops += 2.0 * (NB as f64).powi(3);
                        update_tile_pjrt(
                            a,
                            n,
                            rest + bi,
                            k,
                            rest + bj,
                            engine.unwrap(),
                        )?;
                    } else {
                        update_tile_host(a, n, rest + bi, ti, k, nb, rest + bj, tj);
                    }
                }
            }
        }
        k += nb;
    }

    let seconds = start.elapsed().as_secs_f64();
    let flops = 2.0 * (n as f64).powi(3) / 3.0;
    Ok(LuResult {
        n,
        perm,
        seconds,
        gflops: flops / seconds / 1e9,
        offload_fraction: offloaded_flops / flops,
    })
}

/// Host tile update C -= A * B for arbitrary tile sizes.
#[allow(clippy::too_many_arguments)]
fn update_tile_host(
    a: &mut [f32],
    n: usize,
    ci: usize,
    ti: usize,
    k: usize,
    nb: usize,
    cj: usize,
    tj: usize,
) {
    for i in 0..ti {
        for l in 0..nb {
            let lv = a[(ci + i) * n + (k + l)];
            if lv != 0.0 {
                for j in 0..tj {
                    a[(ci + i) * n + (cj + j)] -= lv * a[(k + l) * n + (cj + j)];
                }
            }
        }
    }
}

/// PJRT tile update through the `hpl_update_256` artifact.
fn update_tile_pjrt(
    a: &mut [f32],
    n: usize,
    ci: usize,
    k: usize,
    cj: usize,
    engine: &Engine,
) -> Result<()> {
    let gather = |r0: usize, c0: usize| -> Vec<f32> {
        let mut t = Vec::with_capacity(NB * NB);
        for i in 0..NB {
            t.extend_from_slice(&a[(r0 + i) * n + c0..(r0 + i) * n + c0 + NB]);
        }
        t
    };
    let c_tile = gather(ci, cj);
    let l_tile = gather(ci, k);
    let u_tile = gather(k, cj);
    let out = engine.execute(
        "hpl_update_256",
        &[
            literal_f32(&c_tile, &[NB, NB])?,
            literal_f32(&l_tile, &[NB, NB])?,
            literal_f32(&u_tile, &[NB, NB])?,
        ],
    )?;
    let updated: Vec<f32> = out[0].to_vec()?;
    for i in 0..NB {
        a[(ci + i) * n + cj..(ci + i) * n + cj + NB]
            .copy_from_slice(&updated[i * NB..(i + 1) * NB]);
    }
    Ok(())
}

/// Solve `A x = b` from the factorization (for the HPL residual check).
pub fn lu_solve(lu: &[f32], n: usize, perm: &[usize], b: &[f32]) -> Vec<f32> {
    // apply permutation, then forward/back substitution
    let mut y: Vec<f32> = perm.iter().map(|&p| b[p]).collect();
    for i in 0..n {
        for j in 0..i {
            y[i] -= lu[i * n + j] * y[j];
        }
    }
    for i in (0..n).rev() {
        for j in (i + 1)..n {
            y[i] -= lu[i * n + j] * y[j];
        }
        y[i] /= lu[i * n + i];
    }
    y
}

/// The HPL residual: ||A x - b||_inf / (||A||_inf ||x||_inf n eps).
pub fn hpl_residual(a0: &[f32], n: usize, x: &[f32], b: &[f32]) -> f64 {
    let mut rmax = 0f64;
    let mut anorm = 0f64;
    for i in 0..n {
        let mut dot = 0f64;
        let mut row = 0f64;
        for j in 0..n {
            dot += a0[i * n + j] as f64 * x[j] as f64;
            row += (a0[i * n + j] as f64).abs();
        }
        rmax = rmax.max((dot - b[i] as f64).abs());
        anorm = anorm.max(row);
    }
    let xnorm = x.iter().fold(0f64, |m, &v| m.max((v as f64).abs()));
    rmax / (anorm * xnorm * n as f64 * f32::EPSILON as f64)
}

/// Random well-conditioned test matrix (diagonally dominated).
pub fn random_matrix(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut a = vec![0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            a[i * n + j] = (rng.f64() as f32) - 0.5;
        }
        a[i * n + i] += n as f32 * 0.25; // dominance keeps pivots benign
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_factorization(n: usize, seed: u64) {
        let a0 = random_matrix(n, seed);
        let mut lu = a0.clone();
        let res = lu_factor(&mut lu, n, None).unwrap();
        // Solve against a known RHS and check the HPL residual.
        let x_true: Vec<f32> = (0..n).map(|i| ((i % 7) as f32) - 3.0).collect();
        let mut b = vec![0f32; n];
        for i in 0..n {
            b[i] = (0..n).map(|j| a0[i * n + j] * x_true[j]).sum();
        }
        let x = lu_solve(&lu, n, &res.perm, &b);
        let r = hpl_residual(&a0, n, &x, &b);
        // HPL passes at r < 16; stay well under.
        assert!(r < 16.0, "n={n}: residual {r}");
    }

    #[test]
    fn lu_small_sizes() {
        for (n, seed) in [(8usize, 1u64), (32, 2), (50, 3), (64, 4)] {
            check_factorization(n, seed);
        }
    }

    #[test]
    fn lu_crosses_block_boundaries() {
        // Exercises panel + U12 + trailing host path (n > NB).
        check_factorization(NB + 40, 7);
    }

    #[test]
    fn lu_pivoting_handles_zero_diagonal() {
        // A matrix whose (0,0) is zero still factors via pivoting.
        let n = 16;
        let mut a0 = random_matrix(n, 9);
        a0[0] = 0.0;
        let mut lu = a0.clone();
        let res = lu_factor(&mut lu, n, None).unwrap();
        assert_ne!(res.perm[0], 0, "pivot must move row 0");
    }

    #[test]
    fn gflops_and_offload_accounting() {
        let n = 64;
        let mut lu = random_matrix(n, 11);
        let res = lu_factor(&mut lu, n, None).unwrap();
        assert!(res.gflops > 0.0);
        assert_eq!(res.offload_fraction, 0.0); // no engine given
        assert_eq!(res.n, n);
    }

    #[test]
    fn residual_of_exact_solution_is_zero() {
        let n = 10;
        let a = random_matrix(n, 13);
        let x = vec![1.0f32; n];
        let mut b = vec![0f32; n];
        for i in 0..n {
            b[i] = (0..n).map(|j| a[i * n + j]).sum();
        }
        let r = hpl_residual(&a, n, &x, &b);
        assert!(r < 1.0, "{r}");
    }
}
