//! Machine configuration: the cell/rack/blade/node inventory of Table 1
//! and machine presets (LEONARDO, plus the Marconi100 comparator used by
//! the Fig 5 weak-scaling comparison).
//!
//! A [`MachineConfig`] is the single source of truth the other subsystems
//! consume: [`crate::topology`] wires its cells, [`crate::scheduler`]
//! allocates its nodes, [`crate::power`] integrates over its blades.



use crate::hardware::NodeSpec;

/// The kind of compute hosted by a cell (colours of Fig 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CellKind {
    /// GPU-accelerated Booster cells (green in Fig 4).
    Booster,
    /// CPU Data-Centric cells (blue).
    DataCentric,
    /// The mixed Booster/DC cell (cell 22 in LEONARDO).
    Hybrid,
    /// Storage + service cell (pink; the twenty-third cell).
    Io,
}

/// One group of identical racks inside a cell.
#[derive(Debug, Clone)]
pub struct RackGroup {
    /// Racks in this group.
    pub racks: u32,
    /// Blades per rack.
    pub blades_per_rack: u32,
    /// Nodes per blade (1 for the GPU blade, 3 for the DC X2140).
    pub nodes_per_blade: u32,
    /// Node hardware for this group.
    pub node: NodeSpec,
}

impl RackGroup {
    pub fn nodes(&self) -> u32 {
        self.racks * self.blades_per_rack * self.nodes_per_blade
    }

    pub fn gpu_nodes(&self) -> u32 {
        if self.node.gpus > 0 {
            self.nodes()
        } else {
            0
        }
    }

    pub fn cpu_nodes(&self) -> u32 {
        if self.node.gpus == 0 {
            self.nodes()
        } else {
            0
        }
    }
}

/// One dragonfly+ cell.
#[derive(Debug, Clone)]
pub struct CellConfig {
    pub kind: CellKind,
    pub groups: Vec<RackGroup>,
}

impl CellConfig {
    pub fn nodes(&self) -> u32 {
        self.groups.iter().map(RackGroup::nodes).sum()
    }

    pub fn racks(&self) -> u32 {
        self.groups.iter().map(|g| g.racks).sum()
    }
}

/// A whole machine.
#[derive(Debug, Clone)]
pub struct MachineConfig {
    pub name: String,
    pub cells: Vec<CellConfig>,
    /// Facility IT power envelope, MW (§2.6: 10 MW current step).
    pub facility_power_mw: f64,
    /// Power usage effectiveness (§2.6: 1.1 with warm-water DLC).
    pub pue: f64,
    /// Above-leaf fabric oversubscription (1.0 = non-blocking dragonfly+;
    /// Marconi100's island fat-tree prunes ~4x across islands).
    pub network_oversubscription: f64,
}

impl MachineConfig {
    /// The LEONARDO preset: 19 Booster cells (6 racks x 30 single-node GPU
    /// blades), 2 DC cells (8 racks x 26 three-node blades), one Hybrid
    /// cell (2 Booster-style racks of 18 blades + 6 DC-style racks of 16
    /// blades) and the I/O cell — Table 1 exactly.
    pub fn leonardo() -> Self {
        let mut cells = Vec::new();
        for _ in 0..19 {
            cells.push(CellConfig {
                kind: CellKind::Booster,
                groups: vec![RackGroup {
                    racks: 6,
                    blades_per_rack: 30,
                    nodes_per_blade: 1,
                    node: NodeSpec::davinci(),
                }],
            });
        }
        for _ in 0..2 {
            cells.push(CellConfig {
                kind: CellKind::DataCentric,
                groups: vec![RackGroup {
                    racks: 8,
                    blades_per_rack: 26,
                    nodes_per_blade: 3,
                    node: NodeSpec::dc_node(),
                }],
            });
        }
        cells.push(CellConfig {
            kind: CellKind::Hybrid,
            groups: vec![
                RackGroup {
                    racks: 2,
                    blades_per_rack: 18,
                    nodes_per_blade: 1,
                    node: NodeSpec::davinci(),
                },
                RackGroup {
                    racks: 6,
                    blades_per_rack: 16,
                    nodes_per_blade: 3,
                    node: NodeSpec::dc_node(),
                },
            ],
        });
        cells.push(CellConfig {
            kind: CellKind::Io,
            groups: vec![],
        });
        MachineConfig {
            name: "LEONARDO".into(),
            cells,
            facility_power_mw: 10.0,
            pue: 1.1,
            network_oversubscription: 1.0,
        }
    }

    /// Marconi100-like comparator for Fig 5: ~980 nodes of 4 x V100 on a
    /// fat-tree; modelled as 7 cells of 140 nodes so the same dragonfly+
    /// machinery can wire it (the comparison is about node technology and
    /// scaling shape, which this preserves — see DESIGN.md substitutions).
    pub fn marconi100() -> Self {
        let cells = (0..7)
            .map(|_| CellConfig {
                kind: CellKind::Booster,
                groups: vec![RackGroup {
                    racks: 5,
                    blades_per_rack: 28,
                    nodes_per_blade: 1,
                    node: NodeSpec::marconi100_node(),
                }],
            })
            .collect();
        MachineConfig {
            name: "Marconi100".into(),
            cells,
            facility_power_mw: 2.0,
            pue: 1.4,
            network_oversubscription: 4.0,
        }
    }

    pub fn total_nodes(&self) -> u32 {
        self.cells.iter().map(CellConfig::nodes).sum()
    }

    pub fn gpu_nodes(&self) -> u32 {
        self.cells
            .iter()
            .flat_map(|c| &c.groups)
            .map(RackGroup::gpu_nodes)
            .sum()
    }

    pub fn cpu_nodes(&self) -> u32 {
        self.cells
            .iter()
            .flat_map(|c| &c.groups)
            .map(RackGroup::cpu_nodes)
            .sum()
    }

    pub fn total_gpus(&self) -> u32 {
        self.cells
            .iter()
            .flat_map(|c| &c.groups)
            .map(|g| g.nodes() * g.node.gpus)
            .sum()
    }

    pub fn compute_racks(&self) -> u32 {
        self.cells.iter().map(CellConfig::racks).sum()
    }

    /// Cells hosting compute (excludes the I/O cell).
    pub fn compute_cells(&self) -> usize {
        self.cells
            .iter()
            .filter(|c| c.kind != CellKind::Io)
            .count()
    }

    /// The first GPU node spec (None on a CPU-only machine).
    pub fn gpu_node_spec(&self) -> Option<&NodeSpec> {
        self.cells
            .iter()
            .flat_map(|c| &c.groups)
            .find(|g| g.node.gpus > 0)
            .map(|g| &g.node)
    }

    /// Table 1 as rows: (type, cells, racks, cpu nodes, gpu nodes).
    pub fn table1(&self) -> Vec<(String, u32, u32, u32, u32)> {
        use std::collections::BTreeMap;
        let mut agg: BTreeMap<&str, (u32, u32, u32, u32)> = BTreeMap::new();
        for c in &self.cells {
            let name = match c.kind {
                CellKind::Booster => "Booster",
                CellKind::DataCentric => "DC",
                CellKind::Hybrid => "Hybrid",
                CellKind::Io => continue,
            };
            let e = agg.entry(name).or_default();
            e.0 += 1;
            e.1 += c.racks();
            e.2 += c.groups.iter().map(RackGroup::cpu_nodes).sum::<u32>();
            e.3 += c.groups.iter().map(RackGroup::gpu_nodes).sum::<u32>();
        }
        agg.into_iter()
            .map(|(k, (c, r, cn, gn))| (k.to_string(), c, r, cn, gn))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_booster_counts() {
        let m = MachineConfig::leonardo();
        let t = m.table1();
        let booster = t.iter().find(|r| r.0 == "Booster").unwrap();
        assert_eq!((booster.1, booster.2, booster.4), (19, 114, 3420));
        assert_eq!(booster.3, 0);
    }

    #[test]
    fn table1_dc_counts() {
        let m = MachineConfig::leonardo();
        let t = m.table1();
        let dc = t.iter().find(|r| r.0 == "DC").unwrap();
        assert_eq!((dc.1, dc.2, dc.3, dc.4), (2, 16, 1248, 0));
    }

    #[test]
    fn table1_hybrid_counts() {
        let m = MachineConfig::leonardo();
        let t = m.table1();
        let h = t.iter().find(|r| r.0 == "Hybrid").unwrap();
        assert_eq!((h.1, h.2, h.3, h.4), (1, 8, 288, 36));
    }

    #[test]
    fn table1_totals() {
        let m = MachineConfig::leonardo();
        assert_eq!(m.compute_cells(), 22);
        assert_eq!(m.compute_racks(), 138);
        assert_eq!(m.cpu_nodes(), 1536);
        assert_eq!(m.gpu_nodes(), 3456);
        assert_eq!(m.total_nodes(), 1536 + 3456);
    }

    #[test]
    fn leonardo_has_13824_gpus() {
        // §2.1: "about 14k GPUs" — exactly 3456 x 4.
        assert_eq!(MachineConfig::leonardo().total_gpus(), 13_824);
    }

    #[test]
    fn leonardo_has_23_cells_including_io() {
        assert_eq!(MachineConfig::leonardo().cells.len(), 23);
    }

    #[test]
    fn facility_envelope() {
        let m = MachineConfig::leonardo();
        assert_eq!(m.facility_power_mw, 10.0);
        assert!((m.pue - 1.1).abs() < 1e-9);
    }

    #[test]
    fn marconi_preset_is_v100() {
        let m = MachineConfig::marconi100();
        assert_eq!(m.gpu_node_spec().unwrap().gpu.as_ref().unwrap().name, "Volta V100");
        assert_eq!(m.gpu_nodes(), 980);
    }

    #[test]
    fn config_clones_consistently() {
        let m = MachineConfig::leonardo();
        let back = m.clone();
        assert_eq!(back.total_nodes(), m.total_nodes());
        assert_eq!(back.total_gpus(), m.total_gpus());
    }
}
