//! Seeded wire-fault injection for the distributed sweep service.
//!
//! [`FaultyTransport`] wraps one half of a framed connection and
//! misbehaves on a deterministic schedule: it drops the link, delays,
//! truncates a write mid-frame, or corrupts a byte. Schedules are
//! keyed by *operation count*, not wall-clock time, so a given
//! [`FaultPlan`] misbehaves at the same protocol position on every
//! run — the chaos suite and the CI chaos step replay identical
//! failures from a seed.
//!
//! Every fault mode funnels into the one recovery path the
//! coordinator has: the connection is (or becomes) unreadable, the
//! worker is declared lost, and its unacknowledged groups are
//! reassigned. Corruption is engineered to be *detectable by
//! construction* — the injected byte flip sets the top bit of the
//! first buffer byte, which turns a length prefix into an over-cap
//! length and a JSON body's leading `{` into invalid UTF-8, so a
//! corrupted frame can never parse into a plausible-but-wrong row and
//! poison the merge.

use std::collections::BTreeMap;
use std::io::{Error, ErrorKind, Read, Result, Write};
use std::time::Duration;

/// One scheduled misbehavior.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireFault {
    /// Kill the link: this operation and every later one fail.
    Drop,
    /// Stall this operation for the given number of milliseconds,
    /// then perform it normally (late, not wrong).
    DelayMs(u64),
    /// Write only half the buffer, then kill the link — the peer is
    /// left holding a partial frame that can never complete.
    TruncateWrite,
    /// Flip the top bit of the first byte of the buffer (read or
    /// write), guaranteeing the peer rejects the frame.
    CorruptByte,
}

/// xorshift64* — the same tiny deterministic generator the fault
/// traces use; good enough to scatter fault positions from a seed
/// (and, in [`super::worker`], retry jitter).
pub(crate) fn xorshift(mut x: u64) -> u64 {
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    x.wrapping_mul(0x2545_f491_4f6c_dd1d)
}

/// When to misbehave: a map from the transport's operation counter
/// (each `read`/`write` call increments it) to the fault injected at
/// that operation.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    faults: BTreeMap<u64, WireFault>,
}

impl FaultPlan {
    /// An explicit schedule, for tests that pin the protocol position
    /// of a fault.
    pub fn at(ops: &[(u64, WireFault)]) -> FaultPlan {
        FaultPlan {
            faults: ops.iter().copied().collect(),
        }
    }

    /// A seeded schedule: one fault, placed pseudo-randomly in
    /// operations 6..=120 of the wrapped half. The floor of 6 keeps
    /// the join handshake (`Hello` out, `Spec`/first `Assign` in)
    /// intact so a chaos worker always *joins* the fleet before it
    /// starts misbehaving — a worker that faults before `Hello` never
    /// enters the ring and tests nothing.
    pub fn seeded(seed: u64) -> FaultPlan {
        let r0 = xorshift(seed.wrapping_add(0x9e37_79b9_7f4a_7c15));
        let op = 6 + r0 % 115;
        let fault = match xorshift(r0) % 4 {
            0 => WireFault::Drop,
            1 => WireFault::DelayMs(1 + xorshift(r0 ^ 0xff) % 20),
            2 => WireFault::TruncateWrite,
            _ => WireFault::CorruptByte,
        };
        FaultPlan::at(&[(op, fault)])
    }

    /// The scheduled faults, for asserting determinism.
    pub fn schedule(&self) -> impl Iterator<Item = (u64, WireFault)> + '_ {
        self.faults.iter().map(|(&op, &f)| (op, f))
    }
}

/// A `Read + Write` wrapper that executes a [`FaultPlan`]. Wrap each
/// half of a split connection separately (reads and writes count on
/// independent op counters, keeping schedules deterministic per
/// direction).
#[derive(Debug)]
pub struct FaultyTransport<T> {
    inner: T,
    plan: FaultPlan,
    op: u64,
    dead: bool,
}

impl<T> FaultyTransport<T> {
    pub fn new(inner: T, plan: FaultPlan) -> FaultyTransport<T> {
        FaultyTransport {
            inner,
            plan,
            op: 0,
            dead: false,
        }
    }

    /// Decide this operation's fate and advance the counter. Timeouts
    /// don't count as operations — schedules stay stable however long
    /// the peer dawdles.
    fn next_fault(&mut self) -> std::result::Result<Option<WireFault>, Error> {
        if self.dead {
            return Err(Error::new(ErrorKind::BrokenPipe, "chaos: link dropped"));
        }
        let fault = self.plan.faults.get(&self.op).copied();
        self.op += 1;
        Ok(fault)
    }
}

impl<T: Read> Read for FaultyTransport<T> {
    fn read(&mut self, buf: &mut [u8]) -> Result<usize> {
        // Peek the fate first, but only commit the op count on a
        // non-timeout outcome so blocking-read retries don't slide
        // the schedule.
        if self.dead {
            return Err(Error::new(ErrorKind::BrokenPipe, "chaos: link dropped"));
        }
        let fault = self.plan.faults.get(&self.op).copied();
        match fault {
            Some(WireFault::Drop) | Some(WireFault::TruncateWrite) => {
                // Truncation is a write-side fault; on the read half
                // it degenerates to a drop.
                self.op += 1;
                self.dead = true;
                Err(Error::new(ErrorKind::BrokenPipe, "chaos: link dropped"))
            }
            Some(WireFault::DelayMs(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
                self.op += 1;
                self.inner.read(buf)
            }
            Some(WireFault::CorruptByte) => {
                let n = self.inner.read(buf)?;
                self.op += 1;
                if n > 0 {
                    buf[0] ^= 0x80;
                }
                Ok(n)
            }
            None => {
                let out = self.inner.read(buf);
                match &out {
                    Err(e)
                        if matches!(
                            e.kind(),
                            ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                        ) => {}
                    _ => self.op += 1,
                }
                out
            }
        }
    }
}

impl<T: Write> Write for FaultyTransport<T> {
    fn write(&mut self, buf: &[u8]) -> Result<usize> {
        match self.next_fault()? {
            Some(WireFault::Drop) => {
                self.dead = true;
                Err(Error::new(ErrorKind::BrokenPipe, "chaos: link dropped"))
            }
            Some(WireFault::DelayMs(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
                self.inner.write(buf)
            }
            Some(WireFault::TruncateWrite) => {
                let half = (buf.len() / 2).max(1).min(buf.len());
                let n = self.inner.write(&buf[..half])?;
                self.inner.flush().ok();
                self.dead = true;
                Ok(n)
            }
            Some(WireFault::CorruptByte) => {
                if buf.is_empty() {
                    return self.inner.write(buf);
                }
                let mut evil = buf.to_vec();
                evil[0] ^= 0x80;
                self.inner.write(&evil)
            }
            None => self.inner.write(buf),
        }
    }

    fn flush(&mut self) -> Result<()> {
        if self.dead {
            return Err(Error::new(ErrorKind::BrokenPipe, "chaos: link dropped"));
        }
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_deterministic_and_spare_the_handshake() {
        for seed in 0..64 {
            let a = FaultPlan::seeded(seed);
            let b = FaultPlan::seeded(seed);
            assert_eq!(a, b, "seed {seed} not deterministic");
            for (op, _) in a.schedule() {
                assert!(
                    (6..=120).contains(&op),
                    "seed {seed} schedules a fault at op {op}, inside the handshake"
                );
            }
        }
        // Different seeds produce different schedules somewhere.
        assert!(
            (0..64).any(|s| FaultPlan::seeded(s) != FaultPlan::seeded(s + 64)),
            "every seed collapsed to one schedule"
        );
    }

    #[test]
    fn corrupt_byte_sets_the_top_bit_of_the_first_byte() {
        let mut t = FaultyTransport::new(Vec::new(), FaultPlan::at(&[(1, WireFault::CorruptByte)]));
        t.write(b"ab").unwrap(); // op 0: clean
        t.write(b"cd").unwrap(); // op 1: corrupted
        t.write(b"ef").unwrap(); // op 2: clean again
        assert_eq!(&t.inner, &[b'a', b'b', b'c' ^ 0x80, b'd', b'e', b'f']);
    }

    #[test]
    fn truncate_writes_half_then_kills_the_link() {
        let mut t =
            FaultyTransport::new(Vec::new(), FaultPlan::at(&[(0, WireFault::TruncateWrite)]));
        assert_eq!(t.write(b"abcdef").unwrap(), 3);
        assert_eq!(&t.inner, b"abc");
        assert!(t.write(b"ghi").is_err(), "link survived truncation");
        assert!(t.flush().is_err());
    }

    #[test]
    fn drop_kills_reads_and_writes_alike() {
        let mut t = FaultyTransport::new(
            std::io::Cursor::new(b"hello".to_vec()),
            FaultPlan::at(&[(1, WireFault::Drop)]),
        );
        let mut buf = [0u8; 2];
        assert_eq!(t.read(&mut buf).unwrap(), 2); // op 0: clean
        assert!(t.read(&mut buf).is_err(), "op 1 should drop the link");
        assert!(t.read(&mut buf).is_err(), "a dropped link came back");
    }
}
