//! Consistent-hash ring: stable scenario-group → worker assignment.
//!
//! The coordinator shards fork groups across the worker fleet with a
//! classic consistent-hash ring (the Strata `data-shard` exemplar):
//! each worker owns [`DEFAULT_REPLICAS`] virtual points on a 64-bit
//! circle and a group belongs to the first point clockwise of its own
//! hash. Two properties matter to the service:
//!
//!  * **determinism** — the assignment is a pure function of the
//!    member set, never of join order or timing, so the in-process
//!    fleet, the churn test and the CLI fleet all agree on who runs
//!    what;
//!  * **minimal reassignment** — removing a worker only moves *that
//!    worker's* groups (to the next point clockwise); every surviving
//!    worker keeps exactly its assignment, which is what makes the
//!    straggler re-dispatch path cheap and the churn test's "only the
//!    lost worker's groups moved" assertion possible.
//!
//! Point hashes are FNV-1a 64 finished with the murmur3 `fmix64`
//! avalanche. Plain FNV-1a is catastrophically clustered on the short
//! sequential keys this ring sees ("g0", "g1", …, "w0#17"): without
//! the finalizer, 24 group keys land nearly adjacent on the circle
//! and a two-worker fleet splits 22/2. `fmix64` restores uniformity —
//! with 64 replicas the canonical 24-scenario grid splits exactly
//! 12/12.

use std::fmt::Write as _;

/// Virtual points per worker. 64 keeps the ring small (a few KiB per
/// worker) while splitting the canonical 24-group grid 12/12 across
/// two workers — the balance the distributed throughput gate rests on.
pub const DEFAULT_REPLICAS: usize = 64;

/// FNV-1a 64-bit.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0001_b3);
    }
    h
}

/// Murmur3's 64-bit finalizer: full avalanche over FNV's weak low bits.
fn fmix64(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    h ^= h >> 33;
    h
}

/// Position of a key on the ring circle.
pub fn ring_hash(key: &str) -> u64 {
    fmix64(fnv1a64(key.as_bytes()))
}

/// The ring itself: a sorted list of `(hash, worker)` virtual points.
#[derive(Debug, Clone)]
pub struct HashRing {
    replicas: usize,
    /// Sorted by `(hash, worker)` — the name tie-break keeps the walk
    /// order independent of insertion order even on a hash collision.
    points: Vec<(u64, String)>,
    /// Sorted member names.
    members: Vec<String>,
}

impl HashRing {
    pub fn new(replicas: usize) -> Self {
        assert!(replicas >= 1, "a ring needs at least one point per worker");
        HashRing {
            replicas,
            points: Vec::new(),
            members: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn contains(&self, worker: &str) -> bool {
        self.members.iter().any(|m| m == worker)
    }

    /// Sorted member names.
    pub fn members(&self) -> &[String] {
        &self.members
    }

    /// Add a worker (idempotent): inserts its virtual points.
    pub fn add(&mut self, worker: &str) {
        if self.contains(worker) {
            return;
        }
        let mut key = String::with_capacity(worker.len() + 8);
        for r in 0..self.replicas {
            key.clear();
            let _ = write!(key, "{worker}#{r}");
            let point = (ring_hash(&key), worker.to_string());
            let at = self.points.partition_point(|p| *p < point);
            self.points.insert(at, point);
        }
        let at = self.members.partition_point(|m| m.as_str() < worker);
        self.members.insert(at, worker.to_string());
    }

    /// Remove a worker (idempotent): drops its virtual points, which
    /// hands exactly its keys to the next points clockwise.
    pub fn remove(&mut self, worker: &str) {
        self.points.retain(|(_, w)| w != worker);
        self.members.retain(|m| m != worker);
    }

    /// Owner of an arbitrary key: the first virtual point at or
    /// clockwise of the key's hash, wrapping at the top of the circle.
    /// `None` on an empty ring.
    pub fn assign(&self, key: &str) -> Option<&str> {
        if self.points.is_empty() {
            return None;
        }
        let h = ring_hash(key);
        let at = self.points.partition_point(|(ph, _)| *ph < h);
        let (_, worker) = &self.points[if at == self.points.len() { 0 } else { at }];
        Some(worker)
    }

    /// Owner of scenario group `g` — the one canonical key format
    /// (`"g{g}"`) shared by initial dispatch, re-dispatch and tests.
    pub fn assign_group(&self, g: usize) -> Option<&str> {
        self.assign(&format!("g{g}"))
    }

    /// Owner of group `g` restricted to members passing `pred`: the
    /// first virtual point at or clockwise of the group's hash whose
    /// worker qualifies, wrapping the circle. With an always-true
    /// predicate this is exactly [`HashRing::assign_group`]; the
    /// adaptive pull dispatcher uses it as its deterministic tie-break
    /// — among the workers currently holding credit, the ring decides
    /// which one a group goes to, independent of map iteration order.
    /// `None` when no member passes.
    pub fn assign_group_filtered<F>(&self, g: usize, pred: F) -> Option<&str>
    where
        F: Fn(&str) -> bool,
    {
        if self.points.is_empty() {
            return None;
        }
        let h = ring_hash(&format!("g{g}"));
        let start = self.points.partition_point(|(ph, _)| *ph < h);
        let n = self.points.len();
        for k in 0..n {
            let (_, worker) = &self.points[(start + k) % n];
            if pred(worker) {
                return Some(worker);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_function_is_pinned() {
        // Values computed independently (FNV-1a 64 + murmur fmix64);
        // changing either constant silently re-shards every deployment,
        // so the function is pinned by value.
        assert_eq!(ring_hash("g0"), 0x247b_b163_7b2d_f32b);
        assert_eq!(ring_hash("w0#0"), 0xc3d7_26f6_0f48_d2c6);
    }

    fn counts(ring: &HashRing, groups: usize) -> Vec<(String, usize)> {
        let mut out: Vec<(String, usize)> =
            ring.members().iter().map(|m| (m.clone(), 0)).collect();
        for g in 0..groups {
            let w = ring.assign_group(g).unwrap();
            out.iter_mut().find(|(m, _)| m == w).unwrap().1 += 1;
        }
        out
    }

    #[test]
    fn canonical_grid_splits_evenly_across_two_workers() {
        let mut ring = HashRing::new(DEFAULT_REPLICAS);
        ring.add("w0");
        ring.add("w1");
        // The 24-scenario bench/CI grid: a 12/12 split is what the
        // 2-worker ≥1.6x throughput gate stands on.
        assert_eq!(
            counts(&ring, 24),
            vec![("w0".to_string(), 12), ("w1".to_string(), 12)]
        );
    }

    #[test]
    fn assignment_is_independent_of_join_order() {
        let mut a = HashRing::new(DEFAULT_REPLICAS);
        a.add("w0");
        a.add("w1");
        a.add("w2");
        let mut b = HashRing::new(DEFAULT_REPLICAS);
        b.add("w2");
        b.add("w0");
        b.add("w1");
        b.add("w0"); // idempotent re-add
        for g in 0..100 {
            assert_eq!(a.assign_group(g), b.assign_group(g));
        }
    }

    #[test]
    fn removal_moves_only_the_removed_workers_keys() {
        let mut before = HashRing::new(DEFAULT_REPLICAS);
        for w in ["w0", "w1", "w2"] {
            before.add(w);
        }
        let mut after = before.clone();
        after.remove("w1");
        assert!(!after.contains("w1"));
        assert_eq!(after.len(), 2);
        for g in 0..200 {
            let owner = before.assign_group(g).unwrap();
            if owner != "w1" {
                assert_eq!(
                    after.assign_group(g).unwrap(),
                    owner,
                    "group {g} moved although its owner survived"
                );
            }
        }
    }

    #[test]
    fn join_steals_keys_only_for_the_new_worker() {
        let mut before = HashRing::new(DEFAULT_REPLICAS);
        before.add("w0");
        before.add("w1");
        let mut after = before.clone();
        after.add("w9");
        for g in 0..200 {
            let now = after.assign_group(g).unwrap();
            if now != "w9" {
                assert_eq!(now, before.assign_group(g).unwrap());
            }
        }
    }

    #[test]
    fn empty_ring_assigns_nothing() {
        let ring = HashRing::new(DEFAULT_REPLICAS);
        assert!(ring.is_empty());
        assert_eq!(ring.assign_group(0), None);
    }

    #[test]
    fn filtered_walk_degenerates_to_assign_and_skips_excluded_members() {
        let mut ring = HashRing::new(DEFAULT_REPLICAS);
        for w in ["w0", "w1", "w2"] {
            ring.add(w);
        }
        // Always-true predicate: exactly the unfiltered assignment.
        for g in 0..100 {
            assert_eq!(
                ring.assign_group_filtered(g, |_| true),
                ring.assign_group(g)
            );
        }
        // Excluding one member is the same as removing it from the
        // ring: surviving assignments stay put, the excluded worker's
        // keys go to the next qualifying point clockwise.
        let mut without = ring.clone();
        without.remove("w1");
        for g in 0..100 {
            assert_eq!(
                ring.assign_group_filtered(g, |w| w != "w1"),
                without.assign_group(g),
                "group {g}"
            );
        }
        // Nobody qualifies: no owner, never a spin.
        assert_eq!(ring.assign_group_filtered(0, |_| false), None);
    }
}
