//! Wire protocol of the distributed sweep service.
//!
//! Hand-rolled length-prefixed JSON over a `std::net` TCP stream — no
//! serde, no tokio, the build stays offline-hermetic. A frame is a
//! 4-byte big-endian body length followed by that many bytes of
//! compact JSON ([`crate::util::json::Json::render`]); the body is a
//! tagged object (`{"type": "row", ...}`) decoded by [`msg_from_json`].
//!
//! Everything that crosses the wire round-trips exactly: f64 through
//! shortest-`Display` text, u64 as decimal strings, and
//! [`ScenarioStats`] rows through the one canonical codec in
//! [`crate::util::json`] — which is what lets the coordinator's merged
//! report be byte-identical to the single-process engines.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::campaign::{CampaignReport, ScenarioStats, SweepGrid};
use crate::scheduler::{CheckpointPolicy, Coupling, PolicyKind};
use crate::topology::Routing;
use crate::util::json::{
    f64_from_json, f64_to_json, report_from_json, report_to_json, stats_from_json,
    stats_to_json, u64_from_json, u64_to_json, Json,
};
use crate::workloads::FaultTrace;

/// Upper bound on one frame body. The largest real message is a `spec`
/// (a few KiB); 64 MiB is a garbage-detection guard, not a capacity
/// plan — a corrupt length prefix should fail fast, not allocate.
pub const MAX_FRAME: usize = 64 << 20;

/// Everything a worker needs to expand the identical scenario and
/// group numbering the coordinator uses: the grid, the fabric routing
/// the twin replays under, and the engine mode (forked vs streaming).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    pub grid: SweepGrid,
    pub routing: Routing,
    /// Replay fork groups on the divergence-tree engine (the CLI's
    /// `--fork`); off = one singleton group per scenario, exactly the
    /// streaming engine's work units.
    pub fork: bool,
}

/// Protocol messages. Worker → coordinator: `Hello`, `Next`,
/// `RowBatch`, `Row`, `GroupDone`, `Pong`. Coordinator → worker:
/// `Spec`, `Grant`, `Assign`, `Ping`, `Shutdown`. Client →
/// coordinator: `Submit`, `Drain`. Coordinator → client: `Accepted`,
/// `Rejected`, `Report`, `Draining`.
///
/// Job-scoped messages carry the coordinator-assigned job id so a row
/// straggling in from a previous grid is recognisably stale instead of
/// silently merging into the wrong report.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// First frame on a worker connection: the worker names itself.
    /// The name is the worker's identity on the consistent-hash ring.
    Hello { worker: String },
    /// The sweep one job replays. Sent to every fleet member when the
    /// job activates (and to late joiners while it runs), before any
    /// `Assign` for that job.
    Spec { job: u64, spec: SweepSpec },
    /// Group ids (into [`SweepGrid::work_groups`]) this worker now
    /// owns. May arrive more than once (initial dispatch, then
    /// re-dispatch after a peer is lost). Retained as the static-shard
    /// dispatch mode's push frame; workers treat `Assign` and `Grant`
    /// identically.
    Assign { job: u64, groups: Vec<u64> },
    /// Credit request: the worker's replay pipeline has room for up to
    /// `want` more groups. Credit accumulates on the coordinator until
    /// ready groups exist to grant against it, so an idle worker is
    /// never left unserved while work is queued.
    Next { job: u64, want: u64 },
    /// Groups granted against outstanding `Next` credit — the adaptive
    /// pull dispatcher's answer, longest-estimated-first. Ownership
    /// semantics are exactly `Assign`'s.
    Grant { job: u64, groups: Vec<u64> },
    /// One merged-report row: the scenario's grid index and its stats.
    Row { job: u64, index: u64, stats: ScenarioStats },
    /// Every row of one finished group plus its completion ack in a
    /// single frame (one write + flush per *group* instead of per
    /// scenario). Merging the rows and honoring the ack are atomic on
    /// the coordinator: a truncated or corrupted batch never merges
    /// partially — the frame either parses whole or kills the
    /// connection.
    RowBatch {
        job: u64,
        group: u64,
        /// `(grid index, stats)` per member, in member order.
        rows: Vec<(u64, ScenarioStats)>,
    },
    /// Acknowledges every `Row` of one group was sent. Until this
    /// frame arrives the coordinator considers the group unfinished
    /// and will re-dispatch it if the worker is lost. (Legacy path:
    /// production workers send `RowBatch`, which carries the ack;
    /// `Row`/`GroupDone` remain for hand-rolled protocol tests.)
    GroupDone { job: u64, group: u64 },
    /// The service is done with this worker; it should exit cleanly.
    Shutdown,
    /// Heartbeat probe. The coordinator pings every worker connection
    /// on a fixed cadence; a worker that owns no groups and stays
    /// silent past the liveness deadline is declared lost.
    Ping,
    /// Heartbeat reply (also sent unprompted as a keepalive is fine —
    /// any frame refreshes the sender's liveness).
    Pong,
    /// First frame on a client connection: enqueue a sweep. The
    /// coordinator replies `Accepted` or `Rejected` immediately and
    /// `Report` when the job's merge completes.
    Submit { spec: SweepSpec },
    /// The submission is queued under this job id.
    Accepted { job: u64 },
    /// The submission was refused (queue full, empty grid, draining).
    Rejected { reason: String },
    /// The submitted job's merged report, byte-identical to what a
    /// single-process `sweep` of the same grid prints.
    Report { job: u64, report: CampaignReport },
    /// First frame on a client connection: finish in-flight and queued
    /// jobs, then exit. Acknowledged with `Draining`; the coordinator
    /// closing the connection afterwards is the completion signal.
    Drain,
    /// Drain acknowledged; `pending` jobs (active + queued) remain.
    Draining { pending: u64 },
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

// ---------------------------------------------------------------------------
// Spec encoding (grid + fault traces + checkpoint policy)
// ---------------------------------------------------------------------------

fn fault_to_json(f: &FaultTrace) -> Json {
    let FaultTrace {
        seed,
        duration_s,
        node_mtbf_s,
        repair_mean_s,
        group,
        link_mtbf_s,
        link_repair_mean_s,
        degraded_factor,
    } = f;
    obj(vec![
        ("seed", u64_to_json(*seed)),
        ("duration_s", f64_to_json(*duration_s)),
        ("node_mtbf_s", f64_to_json(*node_mtbf_s)),
        ("repair_mean_s", f64_to_json(*repair_mean_s)),
        ("group", u64_to_json(*group as u64)),
        ("link_mtbf_s", f64_to_json(*link_mtbf_s)),
        ("link_repair_mean_s", f64_to_json(*link_repair_mean_s)),
        ("degraded_factor", f64_to_json(*degraded_factor)),
    ])
}

fn fault_from_json(j: &Json) -> Result<FaultTrace> {
    Ok(FaultTrace {
        seed: u64_from_json(j.get("seed")?)?,
        duration_s: f64_from_json(j.get("duration_s")?)?,
        node_mtbf_s: f64_from_json(j.get("node_mtbf_s")?)?,
        repair_mean_s: f64_from_json(j.get("repair_mean_s")?)?,
        group: u64_from_json(j.get("group")?)? as u32,
        link_mtbf_s: f64_from_json(j.get("link_mtbf_s")?)?,
        link_repair_mean_s: f64_from_json(j.get("link_repair_mean_s")?)?,
        degraded_factor: f64_from_json(j.get("degraded_factor")?)?,
    })
}

fn checkpoint_to_json(c: &Option<CheckpointPolicy>) -> Json {
    match c {
        None => Json::Null,
        Some(CheckpointPolicy::None) => Json::Str("none".into()),
        Some(CheckpointPolicy::Periodic(interval)) => f64_to_json(*interval),
    }
}

fn checkpoint_from_json(j: &Json) -> Result<Option<CheckpointPolicy>> {
    match j {
        Json::Null => Ok(None),
        Json::Str(s) if s == "none" => Ok(Some(CheckpointPolicy::None)),
        other => Ok(Some(CheckpointPolicy::Periodic(f64_from_json(other)?))),
    }
}

fn grid_to_json(g: &SweepGrid) -> Json {
    // Exhaustive destructuring, like the stats codec: a new grid axis
    // must get a wire column before this compiles again.
    let SweepGrid {
        seeds,
        caps,
        mixes,
        policies,
        jobs,
        coupling,
        retime_all,
        cap_time,
        faults,
        checkpoint,
    } = g;
    obj(vec![
        (
            "seeds",
            Json::Arr(seeds.iter().map(|&s| u64_to_json(s)).collect()),
        ),
        (
            "caps",
            Json::Arr(
                caps.iter()
                    .map(|c| match c {
                        None => Json::Null,
                        Some(v) => f64_to_json(*v),
                    })
                    .collect(),
            ),
        ),
        (
            "mixes",
            Json::Arr(mixes.iter().map(|m| Json::Str(m.clone())).collect()),
        ),
        (
            "policies",
            Json::Arr(
                policies
                    .iter()
                    .map(|p| Json::Str(p.name().to_string()))
                    .collect(),
            ),
        ),
        ("jobs", u64_to_json(*jobs as u64)),
        (
            "coupling",
            obj(vec![
                ("congestion", Json::Bool(coupling.congestion)),
                ("cap", Json::Bool(coupling.cap)),
            ]),
        ),
        ("retime_all", Json::Bool(*retime_all)),
        ("cap_time", f64_to_json(*cap_time)),
        ("faults", Json::Arr(faults.iter().map(fault_to_json).collect())),
        ("checkpoint", checkpoint_to_json(checkpoint)),
    ])
}

fn grid_from_json(j: &Json) -> Result<SweepGrid> {
    let seeds = j
        .get("seeds")?
        .as_arr()?
        .iter()
        .map(u64_from_json)
        .collect::<Result<Vec<_>>>()?;
    let caps = j
        .get("caps")?
        .as_arr()?
        .iter()
        .map(|c| match c {
            Json::Null => Ok(None),
            other => Ok(Some(f64_from_json(other)?)),
        })
        .collect::<Result<Vec<_>>>()?;
    let mixes = j
        .get("mixes")?
        .as_arr()?
        .iter()
        .map(|m| Ok(m.as_str()?.to_string()))
        .collect::<Result<Vec<_>>>()?;
    let policies = j
        .get("policies")?
        .as_arr()?
        .iter()
        .map(|p| PolicyKind::from_name(p.as_str()?))
        .collect::<Result<Vec<_>>>()?;
    ensure!(!policies.is_empty(), "sweep spec has an empty policy axis");
    let faults = j
        .get("faults")?
        .as_arr()?
        .iter()
        .map(fault_from_json)
        .collect::<Result<Vec<_>>>()?;
    ensure!(!faults.is_empty(), "sweep spec has an empty fault axis");
    let cap_time = f64_from_json(j.get("cap_time")?)?;
    ensure!(
        cap_time.is_finite() && cap_time >= 0.0,
        "sweep spec has a bad cap_time {cap_time}"
    );
    let coupling = j.get("coupling")?;
    let congestion = matches!(coupling.get("congestion")?, Json::Bool(true));
    let cap = matches!(coupling.get("cap")?, Json::Bool(true));
    let jobs = u64_from_json(j.get("jobs")?)? as usize;
    // `SweepGrid::new` revalidates axis shapes, cap levels and mix
    // names, so a corrupt spec errors here instead of panicking a
    // worker mid-replay.
    let grid = SweepGrid::new(seeds, caps, mixes, jobs)
        .context("sweep spec failed grid validation")?
        .with_policies(policies)
        .with_coupling(Coupling { congestion, cap })
        .with_retime_all(matches!(j.get("retime_all")?, Json::Bool(true)))
        .with_cap_time(cap_time)
        .with_fault_traces(faults)
        .with_checkpoint(checkpoint_from_json(j.get("checkpoint")?)?);
    Ok(grid)
}

fn spec_to_json(spec: &SweepSpec) -> Json {
    obj(vec![
        ("grid", grid_to_json(&spec.grid)),
        ("routing", Json::Str(spec.routing.name().to_string())),
        ("fork", Json::Bool(spec.fork)),
    ])
}

fn spec_from_json(j: &Json) -> Result<SweepSpec> {
    Ok(SweepSpec {
        grid: grid_from_json(j.get("grid")?)?,
        routing: Routing::from_name(j.get("routing")?.as_str()?)?,
        fork: matches!(j.get("fork")?, Json::Bool(true)),
    })
}

// ---------------------------------------------------------------------------
// Message encoding
// ---------------------------------------------------------------------------

pub fn msg_to_json(msg: &Msg) -> Json {
    match msg {
        Msg::Hello { worker } => obj(vec![
            ("type", Json::Str("hello".into())),
            ("worker", Json::Str(worker.clone())),
        ]),
        Msg::Spec { job, spec } => obj(vec![
            ("type", Json::Str("spec".into())),
            ("job", u64_to_json(*job)),
            ("spec", spec_to_json(spec)),
        ]),
        Msg::Assign { job, groups } => obj(vec![
            ("type", Json::Str("assign".into())),
            ("job", u64_to_json(*job)),
            (
                "groups",
                Json::Arr(groups.iter().map(|&g| u64_to_json(g)).collect()),
            ),
        ]),
        Msg::Next { job, want } => obj(vec![
            ("type", Json::Str("next".into())),
            ("job", u64_to_json(*job)),
            ("want", u64_to_json(*want)),
        ]),
        Msg::Grant { job, groups } => obj(vec![
            ("type", Json::Str("grant".into())),
            ("job", u64_to_json(*job)),
            (
                "groups",
                Json::Arr(groups.iter().map(|&g| u64_to_json(g)).collect()),
            ),
        ]),
        Msg::Row { job, index, stats } => obj(vec![
            ("type", Json::Str("row".into())),
            ("job", u64_to_json(*job)),
            ("index", u64_to_json(*index)),
            ("stats", stats_to_json(stats)),
        ]),
        Msg::RowBatch { job, group, rows } => obj(vec![
            ("type", Json::Str("row_batch".into())),
            ("job", u64_to_json(*job)),
            ("group", u64_to_json(*group)),
            (
                "rows",
                Json::Arr(
                    rows.iter()
                        .map(|(index, stats)| {
                            obj(vec![
                                ("index", u64_to_json(*index)),
                                ("stats", stats_to_json(stats)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
        Msg::GroupDone { job, group } => obj(vec![
            ("type", Json::Str("group_done".into())),
            ("job", u64_to_json(*job)),
            ("group", u64_to_json(*group)),
        ]),
        Msg::Shutdown => obj(vec![("type", Json::Str("shutdown".into()))]),
        Msg::Ping => obj(vec![("type", Json::Str("ping".into()))]),
        Msg::Pong => obj(vec![("type", Json::Str("pong".into()))]),
        Msg::Submit { spec } => obj(vec![
            ("type", Json::Str("submit".into())),
            ("spec", spec_to_json(spec)),
        ]),
        Msg::Accepted { job } => obj(vec![
            ("type", Json::Str("accepted".into())),
            ("job", u64_to_json(*job)),
        ]),
        Msg::Rejected { reason } => obj(vec![
            ("type", Json::Str("rejected".into())),
            ("reason", Json::Str(reason.clone())),
        ]),
        Msg::Report { job, report } => obj(vec![
            ("type", Json::Str("report".into())),
            ("job", u64_to_json(*job)),
            ("report", report_to_json(report)),
        ]),
        Msg::Drain => obj(vec![("type", Json::Str("drain".into()))]),
        Msg::Draining { pending } => obj(vec![
            ("type", Json::Str("draining".into())),
            ("pending", u64_to_json(*pending)),
        ]),
    }
}

pub fn msg_from_json(j: &Json) -> Result<Msg> {
    match j.get("type")?.as_str()? {
        "hello" => Ok(Msg::Hello {
            worker: j.get("worker")?.as_str()?.to_string(),
        }),
        "spec" => Ok(Msg::Spec {
            job: u64_from_json(j.get("job")?)?,
            spec: spec_from_json(j.get("spec")?)?,
        }),
        "assign" => Ok(Msg::Assign {
            job: u64_from_json(j.get("job")?)?,
            groups: j
                .get("groups")?
                .as_arr()?
                .iter()
                .map(u64_from_json)
                .collect::<Result<Vec<_>>>()?,
        }),
        "next" => Ok(Msg::Next {
            job: u64_from_json(j.get("job")?)?,
            want: u64_from_json(j.get("want")?)?,
        }),
        "grant" => Ok(Msg::Grant {
            job: u64_from_json(j.get("job")?)?,
            groups: j
                .get("groups")?
                .as_arr()?
                .iter()
                .map(u64_from_json)
                .collect::<Result<Vec<_>>>()?,
        }),
        "row" => Ok(Msg::Row {
            job: u64_from_json(j.get("job")?)?,
            index: u64_from_json(j.get("index")?)?,
            stats: stats_from_json(j.get("stats")?)?,
        }),
        "row_batch" => Ok(Msg::RowBatch {
            job: u64_from_json(j.get("job")?)?,
            group: u64_from_json(j.get("group")?)?,
            rows: j
                .get("rows")?
                .as_arr()?
                .iter()
                .map(|r| {
                    Ok((
                        u64_from_json(r.get("index")?)?,
                        stats_from_json(r.get("stats")?)?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?,
        }),
        "group_done" => Ok(Msg::GroupDone {
            job: u64_from_json(j.get("job")?)?,
            group: u64_from_json(j.get("group")?)?,
        }),
        "shutdown" => Ok(Msg::Shutdown),
        "ping" => Ok(Msg::Ping),
        "pong" => Ok(Msg::Pong),
        "submit" => Ok(Msg::Submit {
            spec: spec_from_json(j.get("spec")?)?,
        }),
        "accepted" => Ok(Msg::Accepted {
            job: u64_from_json(j.get("job")?)?,
        }),
        "rejected" => Ok(Msg::Rejected {
            reason: j.get("reason")?.as_str()?.to_string(),
        }),
        "report" => Ok(Msg::Report {
            job: u64_from_json(j.get("job")?)?,
            report: report_from_json(j.get("report")?)?,
        }),
        "drain" => Ok(Msg::Drain),
        "draining" => Ok(Msg::Draining {
            pending: u64_from_json(j.get("pending")?)?,
        }),
        other => bail!("unknown message type '{other}'"),
    }
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Write one frame (length prefix + JSON body) and flush, so a row is
/// mergeable on the coordinator the moment this returns.
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<()> {
    let body = msg_to_json(msg).render();
    let bytes = body.as_bytes();
    ensure!(bytes.len() <= MAX_FRAME, "frame of {} bytes too large", bytes.len());
    w.write_all(&(bytes.len() as u32).to_be_bytes())
        .context("write frame length")?;
    w.write_all(bytes).context("write frame body")?;
    w.flush().context("flush frame")?;
    Ok(())
}

/// Read one frame. An error means the peer is gone or spoke garbage;
/// the caller treats both as a lost connection.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Msg> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf).context("read frame length")?;
    let len = u32::from_be_bytes(len_buf) as usize;
    ensure!(len <= MAX_FRAME, "frame of {len} bytes too large");
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("read frame body")?;
    let text = std::str::from_utf8(&body).context("frame body is not UTF-8")?;
    msg_from_json(&Json::parse(text)?)
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Read one frame from a stream whose `set_read_timeout` is armed,
/// without ever blocking forever on a dead-but-connected peer.
///
/// A read timeout *between* frames (not a single byte of the next
/// frame yet) is benign idleness — `Ok(None)` — so the caller can tick
/// its own heartbeat/liveness bookkeeping and come back. Once a frame
/// has started, the peer committed to finishing it: a frame still
/// incomplete `frame_patience` after its first byte is an error (a
/// stalled or truncating peer), as is EOF, garbage, or an over-cap
/// length prefix. This is the read path both sides of the service use
/// on sockets; the blocking [`read_msg`] remains for in-memory streams.
pub fn read_msg_patient<R: Read>(r: &mut R, frame_patience: Duration) -> Result<Option<Msg>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    let mut frame_start: Option<Instant> = None;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) => {
                ensure!(got == 0, "peer closed mid-frame ({got} of 4 length bytes)");
                bail!("peer closed the connection");
            }
            Ok(n) => {
                got += n;
                frame_start.get_or_insert_with(Instant::now);
            }
            Err(e) if is_timeout(&e) => {
                let Some(started) = frame_start else {
                    return Ok(None); // idle between frames
                };
                ensure!(
                    started.elapsed() < frame_patience,
                    "partial frame stalled ({got} of 4 length bytes after {:.1?})",
                    started.elapsed()
                );
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("read frame length"),
        }
    }
    let len = u32::from_be_bytes(len_buf) as usize;
    ensure!(len <= MAX_FRAME, "frame of {len} bytes too large");
    let started = frame_start.unwrap_or_else(Instant::now);
    let mut body = vec![0u8; len];
    let mut got = 0usize;
    while got < len {
        match r.read(&mut body[got..]) {
            Ok(0) => bail!("peer closed mid-frame ({got} of {len} body bytes)"),
            Ok(n) => got += n,
            Err(e) if is_timeout(&e) => {
                ensure!(
                    started.elapsed() < frame_patience,
                    "partial frame stalled ({got} of {len} body bytes after {:.1?})",
                    started.elapsed()
                );
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("read frame body"),
        }
    }
    let text = std::str::from_utf8(&body).context("frame body is not UTF-8")?;
    msg_from_json(&Json::parse(text)?).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_spec() -> SweepSpec {
        let grid = SweepGrid::new(
            vec![1, u64::MAX],
            vec![None, Some(6.5)],
            vec!["day".into(), "hpc".into()],
            50,
        )
        .unwrap()
        .with_policies(vec![PolicyKind::PackFirst, PolicyKind::SpreadLinks])
        .with_coupling(Coupling::full())
        .with_cap_time(3600.0)
        .with_fault_traces(vec![
            FaultTrace::none(),
            FaultTrace {
                seed: 7,
                duration_s: 86400.0,
                node_mtbf_s: 250_000.0,
                repair_mean_s: 7200.0,
                group: 18,
                link_mtbf_s: 500_000.0,
                link_repair_mean_s: 3600.0,
                degraded_factor: 0.5,
            },
        ])
        .with_checkpoint(Some(CheckpointPolicy::Periodic(1800.0)));
        SweepSpec {
            grid,
            routing: Routing::Adaptive,
            fork: true,
        }
    }

    #[test]
    fn every_message_round_trips_through_a_byte_stream() {
        let row_stats = crate::util::json::stats_from_json(
            &crate::util::json::stats_to_json(&sample_row()),
        )
        .unwrap();
        let msgs = vec![
            Msg::Hello {
                worker: "w0".into(),
            },
            Msg::Spec {
                job: 1,
                spec: sample_spec(),
            },
            Msg::Assign {
                job: 1,
                groups: vec![0, 5, u64::from(u32::MAX)],
            },
            Msg::Next { job: 1, want: 2 },
            Msg::Grant {
                job: 1,
                groups: vec![2, 7],
            },
            Msg::Row {
                job: 1,
                index: 3,
                stats: row_stats.clone(),
            },
            Msg::RowBatch {
                job: 1,
                group: 7,
                rows: vec![(14, row_stats.clone()), (15, row_stats.clone())],
            },
            Msg::RowBatch {
                job: 2,
                group: 0,
                rows: vec![],
            },
            Msg::GroupDone { job: 1, group: 5 },
            Msg::Shutdown,
            Msg::Ping,
            Msg::Pong,
            Msg::Submit {
                spec: sample_spec(),
            },
            Msg::Accepted { job: u64::MAX },
            Msg::Rejected {
                reason: "queue full (8 jobs pending)".into(),
            },
            Msg::Report {
                job: 2,
                report: CampaignReport {
                    stats: vec![row_stats],
                },
            },
            Msg::Drain,
            Msg::Draining { pending: 3 },
        ];
        let mut buf: Vec<u8> = Vec::new();
        for m in &msgs {
            write_msg(&mut buf, m).unwrap();
        }
        let mut cursor = &buf[..];
        for m in &msgs {
            assert_eq!(&read_msg(&mut cursor).unwrap(), m);
        }
        // Stream fully consumed, no partial frame left over.
        assert!(cursor.is_empty());
    }

    fn sample_row() -> ScenarioStats {
        ScenarioStats {
            mix: "day".into(),
            seed: 3,
            cap_mw: Some(6.0),
            policy: PolicyKind::SpreadLinks,
            faults: "none".into(),
            jobs: 50,
            makespan_h: 10.5,
            mean_wait_min: 1.0,
            p95_wait_min: 2.0,
            max_wait_min: 3.0,
            utilization: 0.9,
            peak_mw: 6.0,
            energy_mwh: 60.0,
            throttled: 1,
            peak_congestion: 1.1,
            peak_link_util: 0.8,
            mean_link_util: 0.4,
            mean_stretch: 1.01,
            p95_stretch: 1.05,
            events_skipped: 10,
            retimes_elided: 20,
            forks: 1,
            restores: 1,
            killed: 0,
            requeued: 0,
            wasted_node_h: 0.0,
            goodput: 1.0,
            p95_recovery_stretch: 0.0,
        }
    }

    #[test]
    fn corrupt_frames_error_instead_of_hanging() {
        // Oversized length prefix.
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME as u32 + 1).to_be_bytes());
        assert!(read_msg(&mut &buf[..]).is_err());
        // Truncated body.
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(&10u32.to_be_bytes());
        buf.extend_from_slice(b"abc");
        assert!(read_msg(&mut &buf[..]).is_err());
        // Valid JSON, unknown message type.
        let body = br#"{"type":"bogus"}"#;
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(&(body.len() as u32).to_be_bytes());
        buf.extend_from_slice(body);
        assert!(read_msg(&mut &buf[..]).is_err());
    }

    #[test]
    fn spec_round_trip_preserves_every_grid_axis() {
        let spec = sample_spec();
        let back = spec_from_json(&spec_to_json(&spec)).unwrap();
        assert_eq!(spec, back);
        // The reconstructed grid numbers scenarios and groups
        // identically — the invariant the whole service rests on.
        assert_eq!(spec.grid.len(), back.grid.len());
        assert_eq!(spec.grid.work_groups(true), back.grid.work_groups(true));
        assert_eq!(spec.grid.work_groups(false), back.grid.work_groups(false));
    }

    #[test]
    fn corrupt_spec_errors_cleanly() {
        let mut j = spec_to_json(&sample_spec());
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(g)) = m.get_mut("grid") {
                g.insert("mixes".into(), Json::Arr(vec![Json::Str("bogus".into())]));
            }
        }
        assert!(spec_from_json(&j).is_err(), "unknown mix must not panic");
    }

    /// Protocol edge: a frame body of exactly `MAX_FRAME` bytes is
    /// legal and round-trips; one byte past the cap is refused on the
    /// write side (and an over-cap length prefix on the read side —
    /// covered above — fails before allocating).
    #[test]
    fn frame_exactly_at_the_cap_round_trips_and_one_past_is_refused() {
        // Measure the fixed JSON overhead of a `Hello`, then pad the
        // worker name (no escaping needed for 'a') to hit the cap
        // exactly.
        let overhead = msg_to_json(&Msg::Hello { worker: String::new() })
            .render()
            .len();
        let at_cap = Msg::Hello {
            worker: "a".repeat(MAX_FRAME - overhead),
        };
        let mut buf: Vec<u8> = Vec::new();
        write_msg(&mut buf, &at_cap).unwrap();
        assert_eq!(buf.len(), 4 + MAX_FRAME);
        let mut cursor = &buf[..];
        assert_eq!(read_msg(&mut cursor).unwrap(), at_cap);
        assert!(cursor.is_empty());

        let past_cap = Msg::Hello {
            worker: "a".repeat(MAX_FRAME - overhead + 1),
        };
        let mut buf: Vec<u8> = Vec::new();
        let err = write_msg(&mut buf, &past_cap).unwrap_err();
        assert!(format!("{err}").contains("too large"), "{err}");
        assert!(buf.is_empty(), "oversized frame partially written");
    }

    /// A connected loopback pair with a short read timeout armed on
    /// the reading end — the configuration both service sides run.
    fn timed_pair() -> (std::net::TcpStream, std::net::TcpStream) {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::net::TcpStream::connect(addr).unwrap();
        let (reader, _) = listener.accept().unwrap();
        reader
            .set_read_timeout(Some(Duration::from_millis(10)))
            .unwrap();
        writer.set_nodelay(true).unwrap();
        (writer, reader)
    }

    /// The patient reader's contract: a timeout between frames is
    /// benign idleness, a complete frame is delivered, and a frame
    /// that starts but stalls is an error once `frame_patience` runs
    /// out — never an indefinite block.
    #[test]
    fn patient_read_distinguishes_idle_from_a_stalled_partial_frame() {
        let patience = Duration::from_millis(60);
        let (mut writer, mut reader) = timed_pair();
        // Idle: no bytes at all.
        assert_eq!(read_msg_patient(&mut reader, patience).unwrap(), None);
        // A whole frame arrives intact.
        write_msg(&mut writer, &Msg::Ping).unwrap();
        assert_eq!(
            read_msg_patient(&mut reader, patience).unwrap(),
            Some(Msg::Ping)
        );
        // A frame that starts (length prefix promising 10 body bytes,
        // only 3 sent) must stall out, not hang.
        use std::io::Write as _;
        writer.write_all(&10u32.to_be_bytes()).unwrap();
        writer.write_all(b"abc").unwrap();
        writer.flush().unwrap();
        let err = read_msg_patient(&mut reader, patience).unwrap_err();
        assert!(format!("{err}").contains("stalled"), "{err}");

        // A truncated length prefix stalls out the same way.
        let (mut writer, mut reader) = timed_pair();
        writer.write_all(&[0u8, 0]).unwrap();
        writer.flush().unwrap();
        let err = read_msg_patient(&mut reader, patience).unwrap_err();
        assert!(format!("{err}").contains("stalled"), "{err}");

        // EOF between frames is a closed peer, not idleness.
        let (writer, mut reader) = timed_pair();
        drop(writer);
        let err = read_msg_patient(&mut reader, patience).unwrap_err();
        assert!(format!("{err}").contains("closed"), "{err}");
    }
}
