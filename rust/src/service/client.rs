//! Client side of the distributed sweep service: submit a grid to a
//! running coordinator and collect its report, or ask the coordinator
//! to drain.
//!
//! A submission is one connection for its whole life: `Submit` out,
//! `Accepted {job}` (or `Rejected {reason}`) back, then — once the
//! fleet has merged every queued grid ahead of it plus this one — the
//! `Report {job}` on the same socket. The read timeout stays armed
//! throughout, so a coordinator that *dies* mid-wait surfaces as a
//! clear connection error; a coordinator that is merely busy keeps
//! the client patiently idle.

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::campaign::CampaignReport;

use super::messages::{read_msg_patient, write_msg, Msg, SweepSpec};
use super::worker::connect_retry;

/// Poll granularity for client reads; responsiveness only, liveness
/// comes from the protocol.
const CLIENT_POLL: Duration = Duration::from_millis(100);

/// Submit `spec` to the coordinator at `addr` and wait for its
/// report. `patience` bounds connecting and the wait for the
/// accept/reject verdict; the report itself takes however long the
/// fleet needs, with connection death (not time) as the failure mode.
pub fn submit(addr: SocketAddr, spec: &SweepSpec, patience: Duration) -> Result<CampaignReport> {
    let (mut reader, mut writer) = connect_halves(addr, patience)?;
    write_msg(&mut writer, &Msg::Submit { spec: spec.clone() })
        .context("send sweep submission")?;
    let deadline = Instant::now() + patience;
    let job = loop {
        match read_msg_patient(&mut reader, patience).context("await submission verdict")? {
            Some(Msg::Accepted { job }) => break job,
            Some(Msg::Rejected { reason }) => bail!("sweep submission rejected: {reason}"),
            Some(other) => bail!("unexpected {other:?} while awaiting the submission verdict"),
            None => {
                if Instant::now() >= deadline {
                    bail!("no verdict from {addr} within {patience:?}");
                }
            }
        }
    };
    loop {
        match read_msg_patient(&mut reader, patience)
            .with_context(|| format!("await report for job {job}"))?
        {
            Some(Msg::Report { job: id, report }) if id == job => return Ok(report),
            Some(Msg::Rejected { reason }) => bail!("job {job} died on the coordinator: {reason}"),
            Some(other) => bail!("unexpected {other:?} while awaiting the report for job {job}"),
            None => {} // fleet still working; the connection is our liveness
        }
    }
}

/// Ask the coordinator at `addr` to finish its active and queued jobs
/// and exit. Returns how many jobs stood between the request and the
/// shutdown (active + queued). Blocks until the coordinator closes
/// the connection — i.e. until the drain actually completed.
pub fn drain(addr: SocketAddr, patience: Duration) -> Result<u64> {
    let (mut reader, mut writer) = connect_halves(addr, patience)?;
    write_msg(&mut writer, &Msg::Drain).context("send drain request")?;
    let deadline = Instant::now() + patience;
    let pending = loop {
        match read_msg_patient(&mut reader, patience).context("await drain acknowledgement")? {
            Some(Msg::Draining { pending }) => break pending,
            Some(other) => bail!("unexpected {other:?} while awaiting the drain acknowledgement"),
            None => {
                if Instant::now() >= deadline {
                    bail!("no drain acknowledgement from {addr} within {patience:?}");
                }
            }
        }
    };
    // The coordinator holds this connection open until its service
    // loop exits; the close (EOF on our side) is the completion
    // signal.
    loop {
        match read_msg_patient(&mut reader, patience) {
            Ok(Some(_)) | Ok(None) => continue,
            Err(_) => return Ok(pending),
        }
    }
}

fn connect_halves(
    addr: SocketAddr,
    patience: Duration,
) -> Result<(std::net::TcpStream, std::net::TcpStream)> {
    let stream = connect_retry(addr, patience)?;
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(CLIENT_POLL))
        .context("arm client read timeout")?;
    stream.set_write_timeout(Some(Duration::from_secs(10))).ok();
    let reader = stream.try_clone().context("clone client stream")?;
    Ok((reader, stream))
}
