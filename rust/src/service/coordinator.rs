//! Coordinator side of the distributed sweep service.
//!
//! One listener, one reader thread per connection, and a single
//! service loop that owns all fleet state — the consistent-hash ring,
//! the per-job group-ownership table, the bounded job queue, and the
//! same pre-sized slot table the mpsc streaming engine merges into.
//! Workers stream job-tagged `(grid index, stats)` rows; the service
//! loop drops each row into the active job's `slots[index]` and the
//! job's [`CampaignReport`] reads the slots out in grid order, so
//! every report is byte-identical to `run_sweep_streaming` /
//! `run_sweep_forked` for any worker count, join order, or timing.
//!
//! **Dispatch.** Two modes ([`DispatchMode`]). The default,
//! `Adaptive`, is pull-based: the coordinator keeps every undone
//! group in a ready-queue ordered longest-estimated-first (LPT),
//! workers request credit with `Next` as their replay pipelines drain,
//! and each `Next` is answered by granting the most expensive ready
//! groups to whoever holds credit. Estimates start from the grid's
//! structural cost hints ([`crate::campaign::SweepGrid::group_cost_hints`]:
//! fork member count × scenarios, fault armed, coupling) and are
//! refined online from per-cost-class service-time samples as acks
//! arrive, so a skewed grid converges toward mean-cost makespan
//! instead of max-shard makespan. The consistent-hash ring survives
//! only as the deterministic tie-break: among the workers currently
//! holding credit, the ring's clockwise walk picks the owner, so
//! assignment never depends on map iteration order. `Static` retains
//! the PR 8 behaviour — all groups sharded up-front by the ring via
//! unsolicited `Assign` — both as the bench baseline and for tests
//! that need assignment to be a pure function of membership.
//!
//! **Job queue.** The coordinator outlives one grid: clients connect,
//! send `Submit`, and get `Accepted {job}` plus — once the fleet has
//! merged that grid — `Report {job}` on the same connection. Jobs run
//! FIFO through the persistent fleet; the queue is bounded
//! ([`CoordinatorConfig::queue_cap`]) and over-cap submissions are
//! `Rejected`, never parked. A `Drain` request finishes the active
//! and queued jobs, then exits; closing the drain connection is the
//! completion signal.
//!
//! **Liveness.** Fault tolerance is ownership-based: a group belongs
//! to a worker from `Grant`/`Assign` until its `RowBatch` (or legacy
//! `GroupDone`) ack, and when a connection dies the worker leaves the
//! ring and exactly its unacknowledged groups go back to the ready
//! queue (adaptive) or are re-dispatched over the survivors (static —
//! consistent hashing keeps every surviving worker's assignment
//! intact, see [`super::shard`]). A *stalled* worker — connected but
//! silent — cannot hide behind an open socket: the coordinator pings
//! every connection each [`CoordinatorConfig::heartbeat`], declares an
//! idle worker lost when it stops answering, and declares a busy
//! worker lost when one of its groups shows no progress past a
//! deadline derived from observed service times of the group's own
//! *cost class* (fork-group vs singleton, faulted vs clean — never
//! below [`CoordinatorConfig::deadline_floor`]), so a worker
//! legitimately chewing a six-member fork group is not convicted by
//! fast singleton acks dragging a global mean down. Every socket
//! carries a read timeout, so neither readers nor the service loop can
//! block forever on a dead peer; the idempotent slot merge makes late
//! rows from a falsely-declared loss harmless.
//!
//! A group ack is only honored when every row of the group is already
//! merged (`RowBatch` carries its rows, so this holds by construction
//! unless the batch was truncated) — a lying or corrupted worker that
//! acks work it never streamed is declared lost instead of wedging the
//! sweep.

use std::collections::{BTreeMap, VecDeque};
use std::net::{Ipv4Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use crate::campaign::{CampaignReport, GroupCost, ScenarioStats};
use crate::coordinator::Twin;

use super::messages::{read_msg_patient, write_msg, Msg, SweepSpec};
use super::shard::{HashRing, DEFAULT_REPLICAS};
use super::worker::{connect_retry_seeded, run_worker, WorkerOptions};

/// Socket-level read poll. Bounds how late a reader notices frame
/// bytes trickling in; liveness judgements use the config deadlines,
/// not this.
const READ_POLL: Duration = Duration::from_millis(25);

/// Socket-level write timeout: a peer that stops draining its receive
/// buffer fails our writes instead of wedging the service loop.
const WRITE_PATIENCE: Duration = Duration::from_secs(10);

/// How the coordinator hands groups to the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchMode {
    /// Pull-based LPT: workers request credit with `Next`, the
    /// coordinator grants the longest-estimated ready groups to
    /// credited workers (ring walk as the deterministic tie-break).
    /// The default.
    Adaptive,
    /// Up-front consistent-hash sharding via unsolicited `Assign` —
    /// the PR 8 dispatcher, retained as the bench baseline and for
    /// assignment-predicting tests.
    Static,
}

/// Where and how the coordinator runs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Listen address (`--listen`).
    pub listen: SocketAddr,
    /// Workers that must have joined before the first dispatch
    /// (`--expect`). Cumulative: a worker that joins and dies still
    /// counts, so a chaos-ridden fleet can't deadlock the gate.
    pub expect: usize,
    /// Virtual ring points per worker.
    pub replicas: usize,
    /// Queued jobs beyond the active one before `Submit` is
    /// `Rejected` (`--queue`).
    pub queue_cap: usize,
    /// Ping cadence; also the grace before a silent *idle* worker
    /// (owning no groups) is declared lost is tied to
    /// `deadline_floor`.
    pub heartbeat: Duration,
    /// Minimum per-group progress deadline, and the patience granted
    /// to a partial frame and a pre-`Hello` connection.
    pub deadline_floor: Duration,
    /// Per-group deadline = max(floor, factor × observed mean group
    /// service time).
    pub deadline_factor: f64,
    /// Keep serving after the initial grid: accept `Submit`s until a
    /// `Drain` (`--persist`). Off, the coordinator exits once its
    /// initial job and anything queued behind it are merged.
    pub persist: bool,
    /// Work-distribution mode (`--dispatch`): adaptive pull (default)
    /// or static ring sharding.
    pub dispatch: DispatchMode,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            listen: SocketAddr::from((Ipv4Addr::LOCALHOST, 7723)),
            expect: 1,
            replicas: DEFAULT_REPLICAS,
            queue_cap: 8,
            heartbeat: Duration::from_secs(1),
            deadline_floor: Duration::from_secs(30),
            deadline_factor: 4.0,
            persist: false,
            dispatch: DispatchMode::Adaptive,
        }
    }
}

/// Fleet-side observability for one coordinator run (the simulated
/// numbers live in the [`CampaignReport`]s; these are about the
/// service itself).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServiceStats {
    /// Connections that completed the `Hello` handshake.
    pub workers_joined: usize,
    /// Workers lost before shutdown: crashed connections, stalled
    /// workers timed out by the progress deadline, and idle workers
    /// that stopped answering pings.
    pub workers_lost: usize,
    /// Group assignments re-dispatched after a loss (or to a rejoiner
    /// after the fleet was empty).
    pub groups_reassigned: usize,
    /// Rows that arrived for an already-filled slot (replay overlap
    /// after a re-dispatch); merged idempotently, never into the
    /// report twice.
    pub duplicate_rows: usize,
    /// Jobs merged to completion (initial grid + accepted `Submit`s).
    pub jobs_served: usize,
    /// `Submit`s refused: queue full, empty grid, or draining.
    pub jobs_rejected: usize,
    /// Rows dropped without merging: stale job id, or a grid index
    /// out of the active job's range.
    pub stale_rows: usize,
    /// Mean seconds from a group's (re)assignment to the loss that
    /// re-dispatched it — how long a failure held its groups hostage.
    pub reassign_latency_mean_s: f64,
    /// Worst-case seconds from assignment to re-dispatch.
    pub reassign_latency_max_s: f64,
    /// Service-loop iterations that observed ≥2 ready groups while
    /// some live worker held unspent credit — i.e. the adaptive
    /// dispatcher letting a worker idle with work queued. Stays 0 by
    /// construction (every credit/ready change re-runs the grant
    /// pass); the straggler test pins that invariant.
    pub starved_ticks: usize,
}

/// What reader threads distil every connection into.
enum CoEvent {
    Joined { name: String, stream: TcpStream },
    Row { job: u64, index: u64, stats: ScenarioStats },
    Done { worker: String, job: u64, group: u64 },
    Next { worker: String, job: u64, want: u64 },
    Batch { worker: String, job: u64, group: u64, rows: Vec<(u64, ScenarioStats)> },
    Pong { name: String },
    Lost { name: String },
    Submitted { spec: SweepSpec, client: TcpStream },
    DrainRequested { client: TcpStream },
}

/// Pump one connection into the event channel. The first frame picks
/// the role: `Hello` makes it a worker connection (write half handed
/// to the service loop, then rows/acks/pongs until it dies), `Submit`
/// and `Drain` make it a client connection (write half handed over,
/// reader exits — clients only listen from then on). Anything else is
/// a stranger and is dropped.
fn reader_loop(stream: TcpStream, tx: mpsc::Sender<CoEvent>, patience: Duration) {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(READ_POLL)).ok();
    stream.set_write_timeout(Some(WRITE_PATIENCE)).ok();
    let write_half = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = stream;
    let opened = Instant::now();
    let first = loop {
        match read_msg_patient(&mut reader, patience) {
            Ok(Some(m)) => break m,
            // A connection that never identifies itself doesn't get to
            // hold a reader thread forever.
            Ok(None) if opened.elapsed() <= patience => continue,
            _ => return,
        }
    };
    let name = match first {
        Msg::Hello { worker } => worker,
        Msg::Submit { spec } => {
            let _ = tx.send(CoEvent::Submitted {
                spec,
                client: write_half,
            });
            return;
        }
        Msg::Drain => {
            let _ = tx.send(CoEvent::DrainRequested { client: write_half });
            return;
        }
        _ => return,
    };
    let joined = CoEvent::Joined {
        name: name.clone(),
        stream: write_half,
    };
    if tx.send(joined).is_err() {
        return;
    }
    loop {
        let ev = match read_msg_patient(&mut reader, patience) {
            Ok(Some(Msg::Row { job, index, stats })) => CoEvent::Row { job, index, stats },
            Ok(Some(Msg::GroupDone { job, group })) => CoEvent::Done {
                worker: name.clone(),
                job,
                group,
            },
            Ok(Some(Msg::Next { job, want })) => CoEvent::Next {
                worker: name.clone(),
                job,
                want,
            },
            Ok(Some(Msg::RowBatch { job, group, rows })) => CoEvent::Batch {
                worker: name.clone(),
                job,
                group,
                rows,
            },
            Ok(Some(Msg::Pong)) => CoEvent::Pong { name: name.clone() },
            // Idle is the service loop's concern (it pings and times
            // out); the reader just keeps listening.
            Ok(None) => continue,
            _ => break,
        };
        if tx.send(ev).is_err() {
            return;
        }
    }
    let _ = tx.send(CoEvent::Lost { name });
}

/// One grid mid-merge: the ownership table, progress clocks and slot
/// merge for the job currently on the fleet.
struct ActiveJob {
    id: u64,
    spec: SweepSpec,
    groups: Vec<Vec<usize>>,
    /// Grid index → group id, for refreshing a group's progress clock
    /// when one of its rows arrives.
    idx_group: Vec<usize>,
    /// Who a group is assigned to until its ack. `None` after
    /// dispatch marks an orphan waiting for a (re)joiner.
    owner: Vec<Option<String>>,
    /// When the group was (re)assigned — feeds service-time and
    /// reassignment-latency measurements.
    assigned_at: Vec<Option<Instant>>,
    /// Last evidence the group is moving: its assignment, or the most
    /// recent row merged for it. The progress deadline measures from
    /// here.
    last_progress: Vec<Option<Instant>>,
    done: Vec<bool>,
    slots: Vec<Option<ScenarioStats>>,
    filled: usize,
    dispatched: bool,
    /// Structural cost hints per group — the LPT seed and the
    /// cost-class key for deadline/estimate refinement.
    costs: Vec<GroupCost>,
    /// Adaptive mode's ready queue: undone, unowned groups waiting for
    /// a credited worker. Re-sorted longest-estimated-first on every
    /// grant pass; empty in static mode.
    ready: Vec<usize>,
    /// Write half of the submitting client's connection; `None` for
    /// the coordinator's own initial grid.
    client: Option<TcpStream>,
}

impl ActiveJob {
    fn new(id: u64, spec: SweepSpec, client: Option<TcpStream>) -> ActiveJob {
        let groups = spec.grid.work_groups(spec.fork);
        let costs = spec.grid.group_cost_hints(spec.fork);
        let n = spec.grid.len();
        let mut idx_group = vec![0usize; n];
        for (g, members) in groups.iter().enumerate() {
            for &i in members {
                idx_group[i] = g;
            }
        }
        ActiveJob {
            id,
            idx_group,
            owner: vec![None; groups.len()],
            assigned_at: vec![None; groups.len()],
            last_progress: vec![None; groups.len()],
            done: vec![false; groups.len()],
            slots: vec![None; n],
            filled: 0,
            dispatched: false,
            costs,
            ready: Vec::new(),
            client,
            groups,
            spec,
        }
    }

    fn complete(&self) -> bool {
        self.filled == self.slots.len()
    }

    fn into_report(self) -> (CampaignReport, Option<TcpStream>) {
        let rows = self
            .slots
            .into_iter()
            .map(|s| s.expect("job completed with every slot filled"))
            .collect();
        (CampaignReport { stats: rows }, self.client)
    }
}

/// Queue a worker for loss processing, once, and only while it is
/// still a fleet member.
fn mark_lost(name: &str, writers: &BTreeMap<String, TcpStream>, pending_lost: &mut Vec<String>) {
    if writers.contains_key(name) && !pending_lost.iter().any(|n| n == name) {
        pending_lost.push(name.to_string());
    }
}

/// Assign `group_ids` across the ring and send each owner one `Assign`
/// frame. Workers whose send fails are queued on `pending_lost` for
/// the service loop to process as a loss. Returns how many groups got
/// an owner (0 on an empty ring — they stay orphaned for a rejoiner).
fn dispatch_groups(
    job: &mut ActiveJob,
    group_ids: &[usize],
    ring: &HashRing,
    writers: &mut BTreeMap<String, TcpStream>,
    pending_lost: &mut Vec<String>,
) -> usize {
    let now = Instant::now();
    let mut per: BTreeMap<String, Vec<u64>> = BTreeMap::new();
    for &g in group_ids {
        if let Some(w) = ring.assign_group(g) {
            job.owner[g] = Some(w.to_string());
            job.assigned_at[g] = Some(now);
            job.last_progress[g] = Some(now);
            per.entry(w.to_string()).or_default().push(g as u64);
        }
    }
    let mut assigned = 0;
    for (name, groups) in per {
        assigned += groups.len();
        if let Some(stream) = writers.get_mut(&name) {
            if write_msg(stream, &Msg::Assign { job: job.id, groups }).is_err() {
                mark_lost(&name, writers, pending_lost);
            }
        }
    }
    assigned
}

/// Per-cost-class cost rate (observed seconds per unit of structural
/// hint), with the pooled rate as the fallback for classes not yet
/// sampled and 1.0 before any sample at all — so LPT ordering is
/// meaningful from the first grant (hints alone) and sharpens as acks
/// arrive.
fn class_rates(
    class_secs: &[f64; GroupCost::CLASSES],
    class_hint: &[f64; GroupCost::CLASSES],
) -> [f64; GroupCost::CLASSES] {
    let tot_secs: f64 = class_secs.iter().sum();
    let tot_hint: f64 = class_hint.iter().sum();
    let pooled = if tot_hint > 0.0 { tot_secs / tot_hint } else { 1.0 };
    std::array::from_fn(|c| {
        if class_hint[c] > 0.0 {
            class_secs[c] / class_hint[c]
        } else {
            pooled
        }
    })
}

/// The adaptive grant pass: hand ready groups to credited workers,
/// longest-estimated-first, one `Grant` frame per worker. A group's
/// owner is the first *credited* live worker clockwise of its ring
/// hash — the deterministic tie-break that keeps assignment
/// reproducible for a fixed event order. Groups nobody has credit for
/// stay ready; workers whose grant write fails are queued on
/// `pending_lost` (their groups come back through the loss path).
fn grant_ready(
    job: &mut ActiveJob,
    rates: &[f64; GroupCost::CLASSES],
    ring: &HashRing,
    credit: &mut BTreeMap<String, u64>,
    writers: &mut BTreeMap<String, TcpStream>,
    pending_lost: &mut Vec<String>,
) -> usize {
    if !job.dispatched || job.ready.is_empty() {
        return 0;
    }
    let mut ready = std::mem::take(&mut job.ready);
    // LPT order; group id breaks estimate ties so the order is total.
    ready.sort_by(|&a, &b| {
        let ea = job.costs[a].hint * rates[job.costs[a].class()];
        let eb = job.costs[b].hint * rates[job.costs[b].class()];
        eb.total_cmp(&ea).then(a.cmp(&b))
    });
    let now = Instant::now();
    let mut per: BTreeMap<String, Vec<u64>> = BTreeMap::new();
    let mut still_ready = Vec::new();
    for g in ready {
        let owner = ring
            .assign_group_filtered(g, |w| {
                credit.get(w).is_some_and(|&c| c > 0) && writers.contains_key(w)
            })
            .map(str::to_string);
        match owner {
            Some(w) => {
                *credit.get_mut(&w).expect("filter checked credit") -= 1;
                job.owner[g] = Some(w.clone());
                job.assigned_at[g] = Some(now);
                job.last_progress[g] = Some(now);
                per.entry(w).or_default().push(g as u64);
            }
            None => still_ready.push(g),
        }
    }
    job.ready = still_ready;
    let mut granted = 0;
    for (name, groups) in per {
        granted += groups.len();
        if let Some(stream) = writers.get_mut(&name) {
            if write_msg(stream, &Msg::Grant { job: job.id, groups }).is_err() {
                mark_lost(&name, writers, pending_lost);
            }
        }
    }
    granted
}

/// Serve on an already-bound listener until the work runs out: the
/// initial grid (if any) plus every accepted submission, FIFO. With
/// `cfg.persist` the coordinator instead keeps accepting submissions
/// until a client sends `Drain`. Returns the initial grid's report
/// (submitted jobs answer to their own clients) and the service
/// stats. `cfg.listen` is ignored — the listener is already bound.
pub fn serve_listener(
    listener: TcpListener,
    initial: Option<&SweepSpec>,
    cfg: &CoordinatorConfig,
) -> Result<(Option<CampaignReport>, ServiceStats)> {
    ensure!(cfg.expect >= 1, "coordinator needs --expect >= 1 workers");
    ensure!(
        initial.is_some() || cfg.persist,
        "a coordinator without an initial grid must be persistent (--persist)"
    );
    if let Some(spec) = initial {
        ensure!(!spec.grid.is_empty(), "refusing to serve an empty sweep grid");
    }
    let local = listener.local_addr().context("coordinator local address")?;
    let stop = AtomicBool::new(false);
    let (tx, rx) = mpsc::channel::<CoEvent>();
    thread::scope(|s| {
        let accept_tx = tx.clone();
        let listener_ref = &listener;
        let stop_ref = &stop;
        let patience = cfg.deadline_floor;
        s.spawn(move || {
            for conn in listener_ref.incoming() {
                if stop_ref.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = conn else { break };
                let reader_tx = accept_tx.clone();
                s.spawn(move || reader_loop(stream, reader_tx, patience));
            }
        });
        let out = service_loop(initial, cfg, &rx);
        // Wind down: stop accepting (the self-connect unblocks the
        // accept thread), then answer anyone who connected too late
        // for the service loop to have seen them, so their reader
        // threads unblock before this scope joins.
        stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(local);
        while let Ok(ev) = rx.recv_timeout(Duration::from_millis(200)) {
            match ev {
                CoEvent::Joined { stream, .. } => {
                    let mut late = stream;
                    let _ = write_msg(&mut late, &Msg::Shutdown);
                }
                CoEvent::Submitted { client, .. } => {
                    let mut late = client;
                    let reason = "coordinator is shutting down".to_string();
                    let _ = write_msg(&mut late, &Msg::Rejected { reason });
                }
                CoEvent::DrainRequested { client } => {
                    let mut late = client;
                    let _ = write_msg(&mut late, &Msg::Draining { pending: 0 });
                }
                _ => {}
            }
        }
        out
    })
}

/// The single-threaded heart of the coordinator: consumes reader
/// events, owns every piece of fleet and queue state, merges rows by
/// grid index, and runs the heartbeat and progress-deadline clocks.
fn service_loop(
    initial: Option<&SweepSpec>,
    cfg: &CoordinatorConfig,
    rx: &mpsc::Receiver<CoEvent>,
) -> Result<(Option<CampaignReport>, ServiceStats)> {
    let mut ring = HashRing::new(cfg.replicas);
    let mut writers: BTreeMap<String, TcpStream> = BTreeMap::new();
    let mut last_seen: BTreeMap<String, Instant> = BTreeMap::new();
    let mut stats = ServiceStats::default();
    let mut pending_lost: Vec<String> = Vec::new();
    let mut queue: VecDeque<(u64, SweepSpec, Option<TcpStream>)> = VecDeque::new();
    let mut active: Option<ActiveJob> = None;
    let mut initial_report: Option<CampaignReport> = None;
    let mut next_job: u64 = 1;
    let mut draining = false;
    let mut drain_clients: Vec<TcpStream> = Vec::new();
    // Adaptive credit ledger: groups each worker has asked for and not
    // yet been granted. Cleared on job activation (workers re-request
    // against the new spec), dropped with the worker on loss.
    let mut credit: BTreeMap<String, u64> = BTreeMap::new();
    // Observed service times bucketed by cost class drive both the
    // progress deadlines and the LPT estimates; loss latencies feed
    // the reassignment fields of the service stats.
    let mut class_secs = [0.0f64; GroupCost::CLASSES];
    let mut class_hint = [0.0f64; GroupCost::CLASSES];
    let mut class_n = [0u64; GroupCost::CLASSES];
    let mut lat_sum = 0.0f64;
    let mut lat_max = 0.0f64;
    let mut lat_count = 0u64;
    let mut last_ping = Instant::now();
    let tick = cfg.heartbeat.min(Duration::from_millis(50));

    if let Some(spec) = initial {
        queue.push_back((next_job, spec.clone(), None));
        next_job += 1;
    }

    let outcome: Result<()> = 'service: loop {
        // Retire a finished job, then activate the next one.
        if active.as_ref().is_some_and(ActiveJob::complete) {
            let job = active.take().expect("checked above");
            stats.jobs_served += 1;
            let id = job.id;
            let (report, client) = job.into_report();
            match client {
                Some(mut c) => {
                    // A client that hung up forfeits its report; the
                    // fleet's work is already merged either way.
                    let _ = write_msg(&mut c, &Msg::Report { job: id, report });
                }
                None => initial_report = Some(report),
            }
        }
        if active.is_none() {
            if let Some((id, spec, client)) = queue.pop_front() {
                let mut job = ActiveJob::new(id, spec, client);
                // Stale credit belongs to the previous job; workers
                // re-request against the spec they are about to get.
                credit.clear();
                for (name, stream) in writers.iter_mut() {
                    let msg = Msg::Spec {
                        job: id,
                        spec: job.spec.clone(),
                    };
                    if write_msg(stream, &msg).is_err()
                        && !pending_lost.iter().any(|n| n == name)
                    {
                        pending_lost.push(name.clone());
                    }
                }
                if stats.workers_joined >= cfg.expect && !writers.is_empty() {
                    job.dispatched = true;
                    match cfg.dispatch {
                        DispatchMode::Adaptive => {
                            // Everything is ready; grants flow as
                            // `Next` requests arrive for this job.
                            job.ready = (0..job.groups.len()).collect();
                        }
                        DispatchMode::Static => {
                            let all: Vec<usize> = (0..job.groups.len()).collect();
                            dispatch_groups(&mut job, &all, &ring, &mut writers, &mut pending_lost);
                        }
                    }
                }
                active = Some(job);
            } else if draining || !cfg.persist {
                break 'service Ok(());
            }
        }

        // Heartbeats: ping the fleet, and time out idle workers that
        // have gone silent (busy workers answer to the group progress
        // deadline instead — they legitimately stop reading the
        // socket while replaying).
        if last_ping.elapsed() >= cfg.heartbeat {
            last_ping = Instant::now();
            let names: Vec<String> = writers.keys().cloned().collect();
            for name in names {
                if let Some(stream) = writers.get_mut(&name) {
                    if write_msg(stream, &Msg::Ping).is_err() {
                        mark_lost(&name, &writers, &mut pending_lost);
                    }
                }
            }
            let now = Instant::now();
            for (name, seen) in &last_seen {
                let busy = active.as_ref().is_some_and(|j| {
                    j.owner.iter().any(|o| o.as_deref() == Some(name.as_str()))
                });
                if !busy && now.duration_since(*seen) > cfg.deadline_floor {
                    mark_lost(name, &writers, &mut pending_lost);
                }
            }
        }

        // Progress deadline: a dispatched group whose clock has run
        // past max(floor, factor × mean service time *of its own cost
        // class*) convicts its owner of stalling. The pooled mean
        // stands in for classes with no sample yet, so a heterogeneous
        // grid's fork groups are judged against fork-group time, not
        // against singleton acks.
        if let Some(job) = active.as_ref() {
            if job.dispatched {
                let tot_n: u64 = class_n.iter().sum();
                let pooled_mean = if tot_n > 0 {
                    class_secs.iter().sum::<f64>() / tot_n as f64
                } else {
                    0.0
                };
                let now = Instant::now();
                for g in 0..job.groups.len() {
                    if job.done[g] {
                        continue;
                    }
                    if let (Some(owner), Some(t0)) = (&job.owner[g], job.last_progress[g]) {
                        let c = job.costs[g].class();
                        let mean = if class_n[c] > 0 {
                            class_secs[c] / class_n[c] as f64
                        } else {
                            pooled_mean
                        };
                        let deadline = cfg
                            .deadline_floor
                            .max(Duration::from_secs_f64(cfg.deadline_factor * mean));
                        if now.duration_since(t0) > deadline {
                            mark_lost(owner, &writers, &mut pending_lost);
                        }
                    }
                }
            }
        }

        // Starvation probe: a tick that sees queued work while a live
        // worker holds unspent credit means the grant pass missed an
        // opportunity. The grant sites below keep this at exactly 0.
        if active.as_ref().is_some_and(|j| j.dispatched && j.ready.len() >= 2)
            && credit
                .iter()
                .any(|(w, &c)| c > 0 && writers.contains_key(w) && !pending_lost.iter().any(|n| n == w))
        {
            stats.starved_ticks += 1;
        }

        // A dispatched job with no fleet left and no loss still being
        // processed can never finish: fail loudly instead of hanging.
        if pending_lost.is_empty()
            && writers.is_empty()
            && active.as_ref().is_some_and(|j| j.dispatched)
        {
            let job = active.as_ref().expect("checked above");
            break 'service Err(anyhow!(
                "entire worker fleet lost with {} of {} rows outstanding",
                job.slots.len() - job.filled,
                job.slots.len()
            ));
        }

        // One event: losses discovered while writing first, then the
        // channel (bounded wait, so the clocks above keep ticking).
        let ev = if let Some(name) = pending_lost.pop() {
            CoEvent::Lost { name }
        } else {
            match rx.recv_timeout(tick) {
                Ok(ev) => ev,
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    break 'service Err(anyhow!("coordinator event stream ended"))
                }
            }
        };
        match ev {
            CoEvent::Joined { name, stream } => {
                if writers.contains_key(&name) {
                    // Duplicate identity: refuse the newcomer by
                    // dropping its write half.
                    continue;
                }
                let mut stream = stream;
                if let Some(job) = active.as_ref() {
                    let msg = Msg::Spec {
                        job: job.id,
                        spec: job.spec.clone(),
                    };
                    if write_msg(&mut stream, &msg).is_err() {
                        continue; // died during the handshake
                    }
                }
                ring.add(&name);
                writers.insert(name.clone(), stream);
                last_seen.insert(name.clone(), Instant::now());
                stats.workers_joined += 1;
                if let Some(job) = active.as_mut() {
                    if !job.dispatched {
                        if stats.workers_joined >= cfg.expect {
                            job.dispatched = true;
                            match cfg.dispatch {
                                DispatchMode::Adaptive => {
                                    job.ready = (0..job.groups.len()).collect();
                                    // Credit banked before the gate
                                    // opened is live now; grant it
                                    // immediately instead of waiting
                                    // for the next `Next`.
                                    let rates = class_rates(&class_secs, &class_hint);
                                    grant_ready(
                                        job,
                                        &rates,
                                        &ring,
                                        &mut credit,
                                        &mut writers,
                                        &mut pending_lost,
                                    );
                                }
                                DispatchMode::Static => {
                                    let all: Vec<usize> = (0..job.groups.len()).collect();
                                    dispatch_groups(
                                        job,
                                        &all,
                                        &ring,
                                        &mut writers,
                                        &mut pending_lost,
                                    );
                                }
                            }
                        }
                    } else if cfg.dispatch == DispatchMode::Static {
                        // Rejoin path: in-flight groups stay with
                        // their owners (stealing them would waste
                        // replay), but anything orphaned while the
                        // fleet was short goes to the ring now. (In
                        // adaptive mode orphans already sit in the
                        // ready queue and the rejoiner's first `Next`
                        // pulls them.)
                        let orphans: Vec<usize> = (0..job.groups.len())
                            .filter(|&g| !job.done[g] && job.owner[g].is_none())
                            .collect();
                        if !orphans.is_empty() {
                            stats.groups_reassigned += dispatch_groups(
                                job,
                                &orphans,
                                &ring,
                                &mut writers,
                                &mut pending_lost,
                            );
                        }
                    }
                }
            }
            CoEvent::Row { job, index, stats: row } => {
                let Some(j) = active.as_mut() else {
                    stats.stale_rows += 1;
                    continue;
                };
                let i = index as usize;
                if job != j.id || i >= j.slots.len() {
                    stats.stale_rows += 1;
                    continue;
                }
                // Any row is progress for its group — the deadline
                // clock measures stalls, not long groups.
                let g = j.idx_group[i];
                if !j.done[g] {
                    j.last_progress[g] = Some(Instant::now());
                }
                if j.slots[i].is_none() {
                    j.slots[i] = Some(row);
                    j.filled += 1;
                } else {
                    stats.duplicate_rows += 1;
                }
            }
            CoEvent::Done { worker, job, group } => {
                if let Some(seen) = last_seen.get_mut(&worker) {
                    *seen = Instant::now();
                }
                let Some(j) = active.as_mut() else { continue };
                if job != j.id {
                    continue; // stale ack from a previous grid
                }
                let g = group as usize;
                if g >= j.groups.len() {
                    // An ack for a group that doesn't exist: the
                    // worker is corrupt, not the merge.
                    mark_lost(&worker, &writers, &mut pending_lost);
                    continue;
                }
                if j.done[g] {
                    continue; // duplicate ack: clean no-op
                }
                if j.groups[g].iter().any(|&i| j.slots[i].is_none()) {
                    // Acking a group whose rows never arrived would
                    // wedge the sweep (nobody left owns the work):
                    // treat the liar as lost so its groups re-run.
                    mark_lost(&worker, &writers, &mut pending_lost);
                    continue;
                }
                j.done[g] = true;
                if let Some(t0) = j.assigned_at[g] {
                    let c = j.costs[g].class();
                    class_secs[c] += t0.elapsed().as_secs_f64();
                    class_hint[c] += j.costs[g].hint;
                    class_n[c] += 1;
                }
                if j.owner[g].as_deref() == Some(worker.as_str()) {
                    j.owner[g] = None;
                }
            }
            CoEvent::Next { worker, job, want } => {
                if let Some(seen) = last_seen.get_mut(&worker) {
                    *seen = Instant::now();
                }
                // In static mode `Next` is liveness only — the shards
                // were pushed at dispatch. In adaptive mode it is the
                // pull: bank the credit and run a grant pass.
                if cfg.dispatch != DispatchMode::Adaptive || !writers.contains_key(&worker) {
                    continue;
                }
                let Some(j) = active.as_mut() else { continue };
                if job != j.id {
                    continue; // request against a grid that moved on
                }
                *credit.entry(worker).or_insert(0) += want;
                let rates = class_rates(&class_secs, &class_hint);
                grant_ready(j, &rates, &ring, &mut credit, &mut writers, &mut pending_lost);
            }
            CoEvent::Batch { worker, job, group, rows } => {
                if let Some(seen) = last_seen.get_mut(&worker) {
                    *seen = Instant::now();
                }
                let Some(j) = active.as_mut() else {
                    stats.stale_rows += rows.len();
                    continue;
                };
                if job != j.id {
                    stats.stale_rows += rows.len();
                    continue; // whole batch from a previous grid
                }
                let g = group as usize;
                if g >= j.groups.len() {
                    // A batch for a group that doesn't exist: the
                    // worker is corrupt, not the merge.
                    mark_lost(&worker, &writers, &mut pending_lost);
                    continue;
                }
                // Merge the member rows exactly as loose `Row` frames
                // would merge — idempotent by slot, duplicates counted.
                let now = Instant::now();
                for (index, row) in rows {
                    let i = index as usize;
                    if i >= j.slots.len() {
                        stats.stale_rows += 1;
                        continue;
                    }
                    let rg = j.idx_group[i];
                    if !j.done[rg] {
                        j.last_progress[rg] = Some(now);
                    }
                    if j.slots[i].is_none() {
                        j.slots[i] = Some(row);
                        j.filled += 1;
                    } else {
                        stats.duplicate_rows += 1;
                    }
                }
                if j.done[g] {
                    continue; // duplicate batch: clean no-op
                }
                if j.groups[g].iter().any(|&i| j.slots[i].is_none()) {
                    // The batch arrived but the group's rows are still
                    // incomplete — a short or cross-wired batch.
                    // Honoring the ack would wedge the sweep (nobody
                    // left owns the work): treat the sender as lost so
                    // its groups re-run.
                    mark_lost(&worker, &writers, &mut pending_lost);
                    continue;
                }
                j.done[g] = true;
                if let Some(t0) = j.assigned_at[g] {
                    let c = j.costs[g].class();
                    class_secs[c] += t0.elapsed().as_secs_f64();
                    class_hint[c] += j.costs[g].hint;
                    class_n[c] += 1;
                }
                if j.owner[g].as_deref() == Some(worker.as_str()) {
                    j.owner[g] = None;
                }
            }
            CoEvent::Pong { name } => {
                if let Some(seen) = last_seen.get_mut(&name) {
                    *seen = Instant::now();
                }
            }
            CoEvent::Lost { name } => {
                let Some(stream) = writers.remove(&name) else {
                    continue; // already processed (or never joined)
                };
                // Sever the socket so a stalled-but-connected worker's
                // reader thread unblocks (and the worker can't keep
                // streaming into a merge that moved on).
                let _ = stream.shutdown(Shutdown::Both);
                ring.remove(&name);
                last_seen.remove(&name);
                credit.remove(&name);
                stats.workers_lost += 1;
                if let Some(j) = active.as_mut() {
                    let orphaned: Vec<usize> = (0..j.groups.len())
                        .filter(|&g| !j.done[g] && j.owner[g].as_deref() == Some(name.as_str()))
                        .collect();
                    let now = Instant::now();
                    for &g in &orphaned {
                        if let Some(t0) = j.assigned_at[g] {
                            let lat = now.duration_since(t0).as_secs_f64();
                            lat_sum += lat;
                            lat_max = lat_max.max(lat);
                            lat_count += 1;
                        }
                        j.owner[g] = None;
                        j.assigned_at[g] = None;
                        j.last_progress[g] = None;
                    }
                    if j.dispatched && !orphaned.is_empty() {
                        match cfg.dispatch {
                            DispatchMode::Adaptive => {
                                // Back to the ready queue; any idle
                                // survivor still holds credit, so the
                                // grant pass re-places them now.
                                stats.groups_reassigned += orphaned.len();
                                j.ready.extend(orphaned.iter().copied());
                                let rates = class_rates(&class_secs, &class_hint);
                                grant_ready(
                                    j,
                                    &rates,
                                    &ring,
                                    &mut credit,
                                    &mut writers,
                                    &mut pending_lost,
                                );
                            }
                            DispatchMode::Static => {
                                if !ring.is_empty() {
                                    stats.groups_reassigned += dispatch_groups(
                                        j,
                                        &orphaned,
                                        &ring,
                                        &mut writers,
                                        &mut pending_lost,
                                    );
                                }
                            }
                        }
                    }
                }
            }
            CoEvent::Submitted { spec, client } => {
                let mut client = client;
                let reject = if draining {
                    Some("coordinator is draining".to_string())
                } else if spec.grid.is_empty() {
                    Some("refusing an empty sweep grid".to_string())
                } else if queue.len() >= cfg.queue_cap {
                    Some(format!("queue full ({} jobs pending)", queue.len()))
                } else {
                    None
                };
                if let Some(reason) = reject {
                    stats.jobs_rejected += 1;
                    let _ = write_msg(&mut client, &Msg::Rejected { reason });
                    continue;
                }
                let id = next_job;
                next_job += 1;
                if write_msg(&mut client, &Msg::Accepted { job: id }).is_ok() {
                    queue.push_back((id, spec, Some(client)));
                }
                // A client gone before its accept takes its job with
                // it — nobody is left to want the report.
            }
            CoEvent::DrainRequested { client } => {
                draining = true;
                let mut client = client;
                let pending = queue.len() as u64 + u64::from(active.is_some());
                let _ = write_msg(&mut client, &Msg::Draining { pending });
                // Held open until the loop exits; the drop (EOF) tells
                // the drain client the coordinator is done.
                drain_clients.push(client);
            }
        }
    };
    // Shut the fleet down on every exit path so workers (and their
    // reader threads) unblock; queued clients learn their jobs died
    // with the service.
    for stream in writers.values_mut() {
        let _ = write_msg(stream, &Msg::Shutdown);
    }
    for (_, _, client) in queue.drain(..) {
        if let Some(mut c) = client {
            let reason = "coordinator exited before this job ran".to_string();
            let _ = write_msg(&mut c, &Msg::Rejected { reason });
        }
    }
    drop(drain_clients);
    outcome?;
    if lat_count > 0 {
        stats.reassign_latency_mean_s = lat_sum / lat_count as f64;
        stats.reassign_latency_max_s = lat_max;
    }
    Ok((initial_report, stats))
}

/// Run the coordinator for one sweep (`leonardo-twin serve` with a
/// grid and no `--persist`): bind, wait for `cfg.expect` workers,
/// dispatch, merge, shut the fleet down.
pub fn serve(spec: &SweepSpec, cfg: &CoordinatorConfig) -> Result<(CampaignReport, ServiceStats)> {
    let (report, stats) = serve_service(Some(spec), cfg)?;
    Ok((
        report.expect("serve with an initial grid always yields its report"),
        stats,
    ))
}

/// Run the coordinator as a service: bind `cfg.listen` and serve the
/// optional initial grid plus submitted jobs per `cfg.persist` — the
/// `leonardo-twin serve --persist` entry point.
pub fn serve_service(
    initial: Option<&SweepSpec>,
    cfg: &CoordinatorConfig,
) -> Result<(Option<CampaignReport>, ServiceStats)> {
    let listener = TcpListener::bind(cfg.listen)
        .with_context(|| format!("bind coordinator listener on {}", cfg.listen))?;
    serve_listener(listener, initial, cfg)
}

/// One-call in-process fleet: a coordinator on an ephemeral loopback
/// port plus `workers` worker threads, each with its own cloned twin
/// and persistent arena — the distributed path the tests, benches and
/// `sweep --workers N` run. `die_after` is the churn hook: worker `k`
/// drops its connection after acknowledging `n` groups for each
/// `(k, n)` entry.
pub fn run_distributed(
    twin: &Twin,
    spec: &SweepSpec,
    workers: usize,
    die_after: &[(usize, usize)],
) -> Result<(CampaignReport, ServiceStats)> {
    let cfg = CoordinatorConfig::default();
    run_distributed_cfg(twin, spec, workers, die_after, &cfg)
}

/// [`run_distributed`] with explicit coordinator tuning — the hook the
/// liveness and chaos tests use to run real heartbeat/deadline clocks
/// at test-sized settings. Single-threaded workers; see [`run_fleet`]
/// for the full knob set.
pub fn run_distributed_cfg(
    twin: &Twin,
    spec: &SweepSpec,
    workers: usize,
    die_after: &[(usize, usize)],
    cfg: &CoordinatorConfig,
) -> Result<(CampaignReport, ServiceStats)> {
    run_fleet(twin, spec, workers, 1, die_after, cfg)
}

/// The fully-tunable in-process fleet: `workers` connections, each
/// driving `threads` replay arenas (`serve --workers N --threads T`).
/// `cfg.listen` and `cfg.expect` are ignored: the fleet runs on an
/// ephemeral loopback port and dispatch waits for all `workers`.
pub fn run_fleet(
    twin: &Twin,
    spec: &SweepSpec,
    workers: usize,
    threads: usize,
    die_after: &[(usize, usize)],
    cfg: &CoordinatorConfig,
) -> Result<(CampaignReport, ServiceStats)> {
    ensure!(workers >= 1, "in-process fleet needs at least one worker");
    let listener = TcpListener::bind((Ipv4Addr::LOCALHOST, 0))
        .context("bind in-process fleet listener")?;
    let addr = listener.local_addr().context("in-process fleet address")?;
    let cfg = CoordinatorConfig {
        expect: workers,
        ..cfg.clone()
    };
    thread::scope(|s| {
        let mut fleet = Vec::new();
        for k in 0..workers {
            let die = die_after
                .iter()
                .find(|&&(w, _)| w == k)
                .map(|&(_, n)| n);
            let mut worker_twin = twin.clone();
            fleet.push(s.spawn(move || -> Result<usize> {
                let stream = connect_retry_seeded(addr, Duration::from_secs(10), k as u64)?;
                let opts = WorkerOptions {
                    die_after_groups: die,
                    threads: threads.max(1),
                    ..WorkerOptions::named(&format!("w{k}"))
                };
                run_worker(&mut worker_twin, stream, &opts)
            }));
        }
        // All `workers` threads join before dispatch, so the ring
        // membership — and therefore the assignment — is deterministic.
        let out = serve_listener(listener, Some(spec), &cfg).map(|(report, stats)| {
            (
                report.expect("in-process fleet always yields the initial report"),
                stats,
            )
        });
        for handle in fleet {
            match handle.join() {
                Ok(Ok(_acked)) => {}
                Ok(Err(e)) => {
                    if out.is_ok() {
                        return Err(e.context("in-process worker failed"));
                    }
                }
                Err(panic) => std::panic::resume_unwind(panic),
            }
        }
        out
    })
}
